"""Batched solve service (DESIGN.md §8): ghost-padding fixed points,
batched-vs-solo parity (stop pass and iterate to 1e-10 in float64,
mixed-n batches including padded-ghost and empty slots), device pivot
rounding parity with the numpy oracle, the new stop rules and the
residual trajectory of ``run_until``, the micro-batching scheduler, and
the end-to-end graph -> clustering pipeline."""

import jax
import numpy as np
import pytest

from repro.core import engine, problems, rounding, schedule as sched
from repro.core.parallel_dykstra import ParallelSolver
from repro.graphs import generators, jaccard
from repro.serve import buckets as bk
from repro.serve.batching import BatchedSolver
from repro.serve.pipeline import cluster_graphs, round_device_batch
from repro.serve.scheduler import BatchScheduler


@pytest.fixture()
def x64():
    old = jax.config.jax_enable_x64
    jax.config.update("jax_enable_x64", True)
    yield
    jax.config.update("jax_enable_x64", old)


def _cc_problem(n, seed=0, eps=0.05):
    adj, _ = generators.planted_partition(n, seed=seed)
    dissim, w = jaccard.signed_instance(adj)
    return problems.correlation_clustering_lp(dissim, w, eps=eps)


def _l2_problem(n, seed=0):
    rng = np.random.default_rng(seed)
    d = np.triu(rng.uniform(0, 1, (n, n)), k=1)
    return problems.metric_nearness_l2(d)


# ------------------------------------------------------------- bucketing
def test_bucket_for_ladder():
    assert bk.bucket_for(10) == 32
    assert bk.bucket_for(32) == 32
    assert bk.bucket_for(33) == 64
    with pytest.raises(ValueError):
        bk.bucket_for(500)


def test_pad_problem_ghost_contract():
    p = _cc_problem(11, seed=2)
    pp = bk.pad_problem(p, 16)
    assert pp.n == 16 and pp.eps == p.eps and pp.box == p.box
    # inert ghost data: x0/f0 are exactly 0 on every ghost cell
    assert np.all(pp.x0()[11:, :] == 0) and np.all(pp.x0()[:, 11:] == 0)
    assert np.all(pp.f0()[11:, :] == 0) and np.all(pp.f0()[:, 11:] == 0)
    np.testing.assert_array_equal(pp.d[:11, :11], p.d)
    np.testing.assert_array_equal(pp.w[:11, :11], p.w)
    with pytest.raises(ValueError):
        bk.pad_problem(p, 10)


def test_family_mismatch_rejected():
    fam = bk.family_of(_cc_problem(10), np.float64)
    solver = BatchedSolver(16, batch=2, family=fam, num_buckets=2)
    with pytest.raises(ValueError):
        solver.stack([_l2_problem(10)])
    with pytest.raises(ValueError):
        solver.stack([_cc_problem(8)] * 3)  # more instances than slots


# ---------------------------------------------------- ghost fixed points
def test_ghost_cells_are_fixed_points(x64):
    """Padded standalone solve: ghost triangles are structurally masked
    (active step count == 3 real triangle visits per pass == C(n_real,3)
    steps) and ghost cells of X/F and the pair/box duals never move."""
    n_real, bucket_n = 11, 16
    pp = bk.pad_problem(_cc_problem(n_real, seed=4), bucket_n)
    solver = ParallelSolver(pp, dtype=np.float64, bucket_diagonals=3,
                            n_real=n_real)
    active = sum(int(np.asarray(b["act"]).sum()) for b in solver.staged_buckets)
    assert active == sched.n_triplets(n_real)
    st = solver.run(passes=7)
    for arr, name in ((st.x, "x"), (st.f, "f")):
        a = np.asarray(arr)
        assert np.all(a[n_real:, :] == 0) and np.all(a[:, n_real:] == 0), name
    for arr in (st.ypair, st.ybox):
        a = np.asarray(arr)
        assert np.all(a[:, n_real:, :] == 0) and np.all(a[:, :, n_real:] == 0)


def test_padded_solve_converges_to_native_optimum(x64):
    """The padded schedule visits the real constraints in a different
    order, so trajectories differ — but the strictly convex QP has one
    optimum, and both drivers must land on it."""
    n_real, bucket_n = 12, 16
    p = _l2_problem(n_real, seed=1)
    pad = ParallelSolver(bk.pad_problem(p, bucket_n), dtype=np.float64,
                         bucket_diagonals=3, n_real=n_real)
    nat = ParallelSolver(p, dtype=np.float64, bucket_diagonals=3)
    stp, ip = pad.run_until(tol=1e-8, max_passes=2000, check_every=50)
    stn, inn = nat.run_until(tol=1e-8, max_passes=2000, check_every=50)
    assert ip["converged"] and inn["converged"]
    np.testing.assert_allclose(
        np.asarray(stp.x)[:n_real, :n_real], np.asarray(stn.x),
        rtol=0, atol=1e-6,
    )


# ------------------------------------------------- batched vs solo parity
@pytest.mark.parametrize("stop_rule", ["absolute", "plateau"])
def test_batched_matches_solo_mixed_n(x64, stop_rule):
    """Every instance of a mixed-n B=4 batch (two ghost-padded, one at
    native bucket size, one empty slot) must stop at exactly the pass its
    standalone padded run_until stops at, with the identical iterate to
    1e-10 — the batched engine is the solo engine, vmapped."""
    bucket_n, B = 14, 4
    probs = [_cc_problem(14, seed=0), _cc_problem(10, seed=1),
             _cc_problem(12, seed=2)]
    fam = bk.family_of(probs[0], np.float64)
    bs = BatchedSolver(bucket_n, batch=B, family=fam, num_buckets=3)
    inst = bs.stack(probs)  # slot 3 stays empty
    kw = dict(tol=1e-4, max_passes=60, check_every=5, stop_rule=stop_rule)
    st, info = bs.run_until(inst, **kw)
    xb = np.asarray(st.x)
    for i, p in enumerate(probs):
        solo = ParallelSolver(bk.pad_problem(p, bucket_n), dtype=np.float64,
                              bucket_diagonals=3, n_real=p.n)
        sst, sinfo = solo.run_until(**kw)
        assert info["passes"][i] == sinfo["passes"], (i, stop_rule)
        assert bool(info["converged"][i]) == sinfo["converged"], i
        assert np.abs(xb[i] - np.asarray(sst.x)).max() <= 1e-10, i
        assert abs(info["max_violation"][i] - sinfo["max_violation"]) <= 1e-10
        assert abs(info["duality_gap"][i] - sinfo["duality_gap"]) <= 1e-10
    # the empty slot converges at the first possible check (plateau needs
    # two checks: the first has no objective baseline) and stays all-zero
    expect = 5 if stop_rule == "absolute" else 10
    assert bool(info["converged"][3]) and info["passes"][3] == expect
    assert np.all(xb[3] == 0)


def test_batched_max_passes_and_resume(x64):
    """tol=0 never converges: every slot must stop at exactly max_passes
    (partial final chunk included), and re-running at the same target is
    a no-op that still reports a finite stopping vector."""
    fam = bk.family_of(_cc_problem(8), np.float64)
    bs = BatchedSolver(10, batch=2, family=fam, num_buckets=2)
    inst = bs.stack([_cc_problem(8, seed=3), _cc_problem(10, seed=4)])
    st, info = bs.run_until(inst, tol=0.0, max_passes=7, check_every=3)
    assert list(info["passes"]) == [7, 7]
    assert not info["converged"].any()
    st2, info2 = bs.run_until(inst, state=st, tol=0.0, max_passes=7,
                              check_every=3)
    assert list(info2["passes"]) == [7, 7]
    assert np.all(np.isfinite(info2["max_violation"]))
    np.testing.assert_array_equal(np.asarray(st2.x), np.asarray(st.x))


# ------------------------------------------------- device pivot rounding
def test_pivot_round_device_matches_numpy(x64):
    rng = np.random.default_rng(7)
    n = 15
    x = np.triu(rng.uniform(0, 1, (n, n)), 1)
    orders = rounding.pivot_orders(n, seed=5, trials=4)
    for t in range(4):
        lab_np = rounding.pivot_round(x, seed=5 + t)
        lab_dev = np.asarray(
            rounding.pivot_round_device(x, orders[t].astype(np.int32))
        )
        np.testing.assert_array_equal(lab_np, lab_dev)
    # vmapped over trials
    vlab = jax.vmap(lambda o: rounding.pivot_round_device(x, o))(
        orders.astype(np.int32)
    )
    for t in range(4):
        np.testing.assert_array_equal(
            np.asarray(vlab[t]), rounding.pivot_round(x, seed=5 + t)
        )


def test_pivot_round_device_ghosts(x64):
    """Ghosts never pivot, never join a ball, come back labelled -1; the
    real labels equal numpy rounding with the order restricted to real
    nodes."""
    rng = np.random.default_rng(8)
    n, npad = 12, 18
    x = np.triu(rng.uniform(0, 1, (n, n)), 1)
    xp = np.zeros((npad, npad))
    xp[:n, :n] = x
    order = np.random.default_rng(3).permutation(npad).astype(np.int32)
    lab = np.asarray(rounding.pivot_round_device(xp, order, n_real=n))
    assert np.all(lab[n:] == -1)
    lab_np = rounding.pivot_round(x, order=order[order < n])
    np.testing.assert_array_equal(lab[:n], lab_np)


def test_cc_cost_device_matches_numpy(x64):
    rng = np.random.default_rng(9)
    n = 14
    dis = (rng.uniform(size=(n, n)) > 0.5).astype(float)
    w = rng.uniform(0.1, 2.0, (n, n))
    lab = rng.integers(0, 4, n)
    mask = np.triu(np.ones((n, n), bool), 1)
    c_np = rounding.cc_cost(lab, dis, w)
    c_dev = float(rounding.cc_cost_device(lab, dis, w, mask))
    assert abs(c_np - c_dev) < 1e-9


def test_round_device_batch_certificate(x64):
    """Device best-of-trials certificate on a perfectly clustered LP
    point recovers the clusters with ~zero cost."""
    n, npad = 10, 16
    truth = np.array([0] * 5 + [1] * 5)
    x = np.triu(np.where(truth[:, None] == truth[None, :], 0.0, 1.0), 1)
    xp = np.zeros((npad, npad))
    xp[:n, :n] = x
    dis = np.pad(x, ((0, npad - n), (0, npad - n)))
    w = np.ones((npad, npad))
    cert = round_device_batch(xp, dis, w, n, trials=3, seed=0)
    assert cert["cc_cost"] == 0.0 and cert["num_clusters"] == 2
    same = cert["labels"][:, None] == cert["labels"][None, :]
    np.testing.assert_array_equal(same, truth[:, None] == truth[None, :])


# ------------------------------------------- stop rules & residual export
def test_stop_converged_rules():
    import jax.numpy as jnp

    viol = jnp.asarray([1e-5, 0.5])  # slot 0 feasible, slot 1 not
    gap = jnp.asarray([5.0, 1e-9])
    obj = jnp.asarray([100.0, 100.0])
    prev = jnp.asarray([100.0, 100.0])
    tol = 0.05
    # absolute: the raw gap 5.0 fails everywhere; slot 1 is infeasible
    assert list(engine.stop_converged("absolute", tol, viol, gap, obj, prev)) \
        == [False, False]
    # rel_gap: 5.0 <= 0.05*(1+100) passes for the feasible slot only
    assert list(engine.stop_converged("rel_gap", tol, viol, gap, obj, prev)) \
        == [True, False]
    # plateau: unchanged objective passes for the feasible slot only
    assert list(engine.stop_converged("plateau", tol, viol, gap, obj, prev)) \
        == [True, False]
    with pytest.raises(ValueError):
        engine.stop_converged("bogus", 1e-4, viol, gap, obj, prev)


def test_run_until_stop_rules(x64):
    """rel_gap/plateau must stop a solve the absolute pair would keep
    running (the CC duality gap closes far slower than feasibility), and
    bogus rules are rejected up front."""
    p = _cc_problem(12, seed=6)
    base = ParallelSolver(p, dtype=np.float64, bucket_diagonals=2)
    _, ia = base.run_until(tol=1e-3, max_passes=120, check_every=5)
    passes = {}
    for rule in ("rel_gap", "plateau"):
        solver = ParallelSolver(p, dtype=np.float64, bucket_diagonals=2)
        _, info = solver.run_until(tol=1e-3, max_passes=120, check_every=5,
                                   stop_rule=rule)
        assert info["stop_rule"] == rule
        assert info["converged"]
        assert info["max_violation"] < 1e-3
        passes[rule] = info["passes"]
        assert info["passes"] <= ia["passes"]
    with pytest.raises(ValueError):
        base.run_until(stop_rule="bogus")


def test_run_until_residual_trajectory(x64):
    """info['residuals'] must be exactly the chunk-boundary ||Δx||_inf
    values of the solve, ring-buffered to the most recent
    residual_history chunks, and mirrored to solver.last_residuals."""
    p = _l2_problem(12, seed=3)
    solver = ParallelSolver(p, dtype=np.float64, bucket_diagonals=2)
    st, info = solver.run_until(tol=0.0, max_passes=12, check_every=3)
    res = info["residuals"]
    assert res.shape == (4,) and np.all(np.isfinite(res)) and np.all(res > 0)
    assert solver.last_residuals is res
    # oracle: recompute the chunk boundary states with the plain runner
    ref = ParallelSolver(p, dtype=np.float64, bucket_diagonals=2)
    s = ref.init_state()
    expect = []
    for _ in range(4):
        s2 = ref.run(s, passes=3)
        expect.append(float(np.max(np.abs(np.asarray(s2.x) - np.asarray(s.x)))))
        s = s2
    np.testing.assert_allclose(res, expect, rtol=0, atol=1e-14)
    # ring wrap: only the last 2 chunks survive with residual_history=2
    solver2 = ParallelSolver(p, dtype=np.float64, bucket_diagonals=2)
    _, info2 = solver2.run_until(tol=0.0, max_passes=12, check_every=3,
                                 residual_history=2)
    np.testing.assert_allclose(info2["residuals"], expect[-2:], atol=1e-14)


# ------------------------------------------- ghost-aware dual statistics
def test_padded_dual_stats_match_legacy_oracle(x64):
    """device_metrics(include_duals=True) on a ghost-padded solver (a
    PR-4 NotImplementedError) must reduce exactly the REAL (< n_real)
    duals: the ghost-aware valid masks drop ghost-set cells, whose
    values are don't-care under fused execution. Oracle: the legacy
    (fused=False) twin restores masked outputs, so its dense conversion
    is clean and the host stats over it are the truth."""
    from repro.core import convergence

    n_real, bucket_n = 11, 14
    pp = bk.pad_problem(_cc_problem(n_real, seed=4), bucket_n)
    fused = ParallelSolver(pp, dtype=np.float64, bucket_diagonals=3,
                           n_real=n_real)
    st = fused.run(passes=5)
    dev = fused.device_metrics(st, include_duals=True)
    legacy = ParallelSolver(pp, dtype=np.float64, bucket_diagonals=3,
                            n_real=n_real, fused=False)
    stl = legacy.run(passes=5)
    oracle = convergence.triangle_dual_stats(legacy.duals_to_dense(stl))
    for k in ("dual_min", "dual_max", "dual_l1", "active_constraints"):
        assert abs(dev[k] - oracle[k]) <= 1e-10 + 1e-10 * abs(oracle[k]), k
    # the host oracle still has no ghost support and must keep raising
    with pytest.raises(NotImplementedError):
        fused.metrics(st, include_duals=True)


def test_ghost_aware_slab_valid_masks_count(x64):
    """Ghost-aware masks mark exactly 3·C(n_real, 3) cells — one per
    real triangle dual — for any padding amount."""
    for n, nr, nb in ((14, 11, 3), (16, 16, 2), (12, 0, 2)):
        lay = sched.build_layout(n, num_buckets=nb, procs=1)
        masks = sched.slab_valid_masks(lay, n_real=nr)
        assert sum(int(m.sum()) for m in masks) == 3 * sched.n_triplets(nr)


def test_batched_dual_stats_match_dense_oracle(x64):
    """Per-instance batched dual stats (ghost-aware traced masks) must
    equal host stats over each instance's own duals converted densely and
    restricted to the real [:n_real]^3 cube (ghost-set cells land outside
    it by the largest-index argument)."""
    from repro.core import convergence

    probs = [_cc_problem(14, seed=0), _cc_problem(10, seed=1)]
    fam = bk.family_of(probs[0], np.float64)
    bs = BatchedSolver(14, batch=3, family=fam, num_buckets=3)
    inst = bs.stack(probs)  # slot 2 empty
    st, _ = bs.run_until(inst, tol=1e-4, max_passes=40, check_every=5)
    stats = bs.dual_stats(st, inst)
    for i, p in enumerate(probs + [None]):
        nr = 0 if p is None else p.n
        yd_i = [np.asarray(y[i]) for y in st.yd]
        dense = sched.duals_to_dense(bs.layout, yd_i)[:nr, :nr, :nr]
        oracle = convergence.triangle_dual_stats(dense)
        for k in ("dual_min", "dual_max", "dual_l1", "active_constraints"):
            got, want = float(stats[k][i]), float(oracle[k])
            assert abs(got - want) <= 1e-10 + 1e-10 * abs(want), (k, i)
    # the empty slot reduces over nothing: zero-folded stats
    assert stats["active_constraints"][2] == 0 and stats["dual_l1"][2] == 0


# ------------------------------------------- batched residual trajectories
def test_batched_residuals_match_solo(x64):
    """info['residuals'] row i must be exactly the chunk-boundary
    ||Δx||_inf trajectory solo run_until exports for instance i — a
    slot's cursor freezes with it, later cells stay -1."""
    probs = [_cc_problem(14, seed=0), _cc_problem(10, seed=1)]
    fam = bk.family_of(probs[0], np.float64)
    bs = BatchedSolver(14, batch=3, family=fam, num_buckets=3)
    inst = bs.stack(probs)
    kw = dict(tol=1e-4, max_passes=60, check_every=5)
    _, info = bs.run_until(inst, **kw)
    res = info["residuals"]
    assert res.shape == (3, 16)
    assert bs.last_residuals is res
    for i, p in enumerate(probs):
        solo = ParallelSolver(bk.pad_problem(p, 14), dtype=np.float64,
                              bucket_diagonals=3, n_real=p.n)
        _, sinfo = solo.run_until(**kw)
        sres = sinfo["residuals"]
        k = len(sres)
        np.testing.assert_allclose(res[i][:k], sres, rtol=0, atol=1e-14)
        assert np.all(res[i][k:] == -1.0)
    # ring wrap: only the most recent R chunks survive, oldest first
    bs2 = BatchedSolver(14, batch=3, family=fam, num_buckets=3)
    _, info2 = bs2.run_until(inst, tol=0.0, max_passes=20, check_every=5,
                             residual_history=2)
    solo = ParallelSolver(bk.pad_problem(probs[0], 14), dtype=np.float64,
                          bucket_diagonals=3, n_real=probs[0].n)
    _, sinfo2 = solo.run_until(tol=0.0, max_passes=20, check_every=5,
                               residual_history=2)
    np.testing.assert_allclose(
        info2["residuals"][0], sinfo2["residuals"], rtol=0, atol=1e-14
    )


# ------------------------------------------------ big-instance routing
def test_scheduler_routes_big_instance_sharded(x64):
    """An above-ladder instance must bypass the queue and solve NOW on a
    dedicated ShardedSolver.run_until slot at native n, with the result
    matching a direct sharded solve exactly and the stats counting it."""
    from repro.core.sharded_dykstra import ShardedSolver
    from repro.launch import mesh as mesh_lib

    kw = dict(tol=1e-3, max_passes=8, check_every=4)
    sch = BatchScheduler(ladder=(12,), batch=2, dtype=np.float64, **kw)
    big = _cc_problem(16, seed=7)
    sch.submit(big, tag="big")
    assert sch.pending == 0  # never queued
    r = sch.results()["big"]
    assert r["route"] == "sharded"
    assert r["bucket_n"] == 16 and r["n"] == 16
    assert r["x"].shape == (16, 16) and r["x_pad"] is r["x"]
    direct = ShardedSolver(big, mesh_lib.make_solver_mesh(),
                           dtype=np.float64, num_buckets=6)
    st, info = direct.run_until(**kw)
    np.testing.assert_array_equal(r["x"], np.asarray(st.x))
    assert r["passes"] == info["passes"]
    assert r["converged"] == info["converged"]
    assert abs(r["max_violation"] - info["max_violation"]) < 1e-12
    stats = sch.stats()
    assert stats["sharded_done"] == 1
    assert stats["instances_done"] == 1
    assert stats["occupancy"] == 0.0  # no batch slots consumed
    # ladder traffic still batches normally alongside
    sch.submit(_cc_problem(10, seed=1), tag="small")
    sch.drain()
    assert sch.stats()["sharded_done"] == 1
    assert sch.results()["small"]["route"] == "batch"


def test_pipeline_big_instance_end_to_end(x64):
    """Mixed ladder + above-ladder stream through cluster_graphs: the big
    graph routes sharded, gets the same certificate plumbing, and the
    label contract holds on both routes."""
    adjs = generators.graph_batch([10, 18], kind="sbm", seed=3)
    results, stats = cluster_graphs(
        adjs, ladder=(12,), batch=1, tol=1e-3, max_passes=40,
        check_every=10, trials=3, dtype=np.float64,
    )
    routes = {r["route"] for r in results}
    assert routes == {"batch", "sharded"}
    for r in results:
        labs = np.unique(r["labels"])
        np.testing.assert_array_equal(labs, np.arange(len(labs)))
        assert r["cc_cost"] >= r["lp_lower_bound"] - 1e-9
        assert r["labels"].shape == (r["n"],)
    big = next(r for r in results if r["route"] == "sharded")
    assert big["bucket_n"] == big["n"] == 18
    assert stats["sharded_done"] == 1


# ------------------------------------------------------ prewarm compiles
def test_scheduler_prewarm_warm_cold_stats(x64):
    """warmup(family) pre-compiles every ladder rung: the first real
    batch of a prewarmed slot dispatches warm; an unwarmed family is
    cold once, warm after."""
    fam = bk.family_of(_cc_problem(8), np.float64)
    sch = BatchScheduler(ladder=(10, 12), batch=2, dtype=np.float64,
                         tol=1e-3, max_passes=6, check_every=3)
    timings = sch.warmup(fam)
    assert set(timings) == {10, 12} and all(t >= 0 for t in timings.values())
    s0 = sch.stats()["prewarm"]
    assert s0 == {"buckets": 2, "warm_dispatches": 0, "cold_dispatches": 0}
    sch.submit(_cc_problem(9, seed=0), tag="a")
    sch.submit(_cc_problem(10, seed=1), tag="b")  # fills bucket 10
    s1 = sch.stats()["prewarm"]
    assert s1["warm_dispatches"] == 1 and s1["cold_dispatches"] == 0
    # different family (l2, no f) was never warmed -> cold, then warm
    sch.submit(_l2_problem(9, seed=2), tag="c")
    sch.submit(_l2_problem(9, seed=3), tag="d")
    s2 = sch.stats()["prewarm"]
    assert s2["cold_dispatches"] == 1
    sch.submit(_l2_problem(9, seed=4), tag="e")
    sch.submit(_l2_problem(9, seed=5), tag="f")
    s3 = sch.stats()["prewarm"]
    assert s3["warm_dispatches"] == 2 and s3["cold_dispatches"] == 1
    assert set(sch.results()) == {"a", "b", "c", "d", "e", "f"}


# ------------------------------------------------------------- scheduler
def test_scheduler_batches_and_stats(x64):
    clock = [0.0]
    sch = BatchScheduler(
        ladder=(12, 16), batch=2, deadline_s=1.0, dtype=np.float64,
        clock=lambda: clock[0], tol=1e-3, max_passes=6, check_every=3,
    )
    sch.cache.num_buckets = 2
    # two n<=12 requests -> full bucket-12 batch dispatches on submit
    sch.submit(_cc_problem(10, seed=0), tag="a")
    assert sch.pending == 1
    sch.submit(_cc_problem(12, seed=1), tag="b")
    assert sch.pending == 0 and set(sch.results()) == {"a", "b"}
    # a lone n=14 request waits for the deadline
    sch.submit(_cc_problem(14, seed=2), tag="c")
    sch.poll()
    assert sch.pending == 1  # not old enough
    clock[0] = 2.0
    sch.poll()
    assert sch.pending == 0 and "c" in sch.results()
    # same bucket again -> compile-cache hit
    sch.submit(_cc_problem(9, seed=3), tag="d")
    sch.submit(_cc_problem(11, seed=4), tag="e")
    stats = sch.stats()
    assert stats["instances_done"] == 5
    assert stats["batches_run"] == 3
    assert stats["occupancy"] == pytest.approx(5 / 6)
    assert stats["compile_cache"]["misses"] == 2  # bucket 12 and 16
    assert stats["compile_cache"]["hits"] == 1
    r = sch.results()["a"]
    assert r["x"].shape == (10, 10) and r["bucket_n"] == 12
    assert r["passes"] <= 6


def test_scheduler_result_matches_solo(x64):
    """A scheduler round trip returns exactly the standalone padded
    run_until solve of each request."""
    p = _cc_problem(9, seed=5)
    sch = BatchScheduler(ladder=(12,), batch=2, dtype=np.float64,
                         tol=1e-4, max_passes=40, check_every=5)
    sch.submit(p, tag="only")
    out = sch.drain()["only"]
    solo = ParallelSolver(bk.pad_problem(p, 12), dtype=np.float64,
                          bucket_diagonals=6, n_real=p.n)
    sst, sinfo = solo.run_until(tol=1e-4, max_passes=40, check_every=5)
    assert out["passes"] == sinfo["passes"]
    assert np.abs(out["x"] - np.asarray(sst.x)[:9, :9]).max() <= 1e-10


# -------------------------------------------------------------- pipeline
def test_pipeline_end_to_end(x64):
    """B=3 mixed-n batch of planted-partition graphs through the full
    pipeline: valid contiguous labels, sane certificates, occupancy 1."""
    adjs = generators.graph_batch([10, 12, 14], kind="sbm", seed=1)
    results, stats = cluster_graphs(
        adjs, ladder=(16,), batch=3, tol=1e-3, max_passes=80,
        check_every=10, trials=4, dtype=np.float64,
    )
    assert len(results) == 3
    for r, adj in zip(results, adjs):
        n = adj.shape[0]
        assert r["n"] == n and r["bucket_n"] == 16
        assert r["labels"].shape == (n,)
        labs = np.unique(r["labels"])
        np.testing.assert_array_equal(labs, np.arange(len(labs)))
        assert r["num_clusters"] == len(labs)
        assert r["cc_cost"] >= 0
        # LP objective is a lower bound on the rounded cost
        assert r["cc_cost"] >= r["lp_lower_bound"] - 1e-9
    assert stats["instances_done"] == 3
    assert stats["batches_run"] == 1
    assert stats["occupancy"] == pytest.approx(1.0)

"""Project-and-Forget active-set subsystem (DESIGN.md §13).

Pins the four claims the subsystem makes:

  * ORACLE — the sparse solve lands on the SAME full-constraint
    certificate as the dense solver (violation ≤ tol, LP objective
    within 1e-6 relative) on planted-partition CC-LP instances, with
    and without slab compaction;
  * FIXED POINTS — with everything active the sparse pass IS the dense
    pass (bitwise), and forget/revive only moves zeros around;
  * COMPACTION — one pass over compacted slabs is bitwise one masked
    pass over the full slabs (compaction skips time, never math), and
    the dual/mask plan round-trips exactly;
  * ROBUSTNESS — an absurdly aggressive forget tolerance (drop
    everything every round) still converges, because the revival probe
    re-admits what the iterate starts to violate.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import problems
from repro.core.parallel_dykstra import ParallelSolver
from repro.graphs import generators, jaccard
from repro.sparse import SparseSolver


@pytest.fixture()
def x64():
    old = jax.config.jax_enable_x64
    jax.config.update("jax_enable_x64", True)
    yield
    jax.config.update("jax_enable_x64", old)


def _cc_problem(n, seed=0, eps=0.05):
    adj, _ = generators.planted_partition(n, seed=seed)
    dissim, w = jaccard.signed_instance(adj)
    return problems.correlation_clustering_lp(dissim, w, eps=eps)


def _certificates_match(info_s, info_d, tol):
    assert info_s["converged"], info_s
    assert info_d["converged"], info_d
    assert info_s["max_violation"] <= tol
    assert info_d["max_violation"] <= tol
    lp_s, lp_d = info_s["lp_objective"], info_d["lp_objective"]
    assert abs(lp_s - lp_d) <= 1e-6 * max(1.0, abs(lp_d)), (lp_s, lp_d)


# ----------------------------------------------------------- oracle
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_sparse_matches_full_constraint_oracle(x64, seed):
    p = _cc_problem(20, seed=seed)
    tol = 1e-5
    sp = SparseSolver(p, bucket_diagonals=3, forget_every=5,
                     dtype=jnp.float64)
    st, info_s = sp.run_until(tol=tol, max_passes=400)
    dn = ParallelSolver(p, bucket_diagonals=3, dtype=jnp.float64)
    _, info_d = dn.run_until(tol=tol, max_passes=400)
    _certificates_match(info_s, info_d, tol)
    assert info_s["active_fraction"] <= 1.0
    assert info_s["rounds"] >= 1


def test_sparse_oracle_with_compaction(x64):
    p = _cc_problem(20, seed=1)
    tol = 1e-5
    sp = SparseSolver(
        p, bucket_diagonals=3, forget_every=5, compact_every=2,
        compact_pad=4, dtype=jnp.float64,
    )
    st, info_s = sp.run_until(tol=tol, max_passes=400)
    dn = ParallelSolver(p, bucket_diagonals=3, dtype=jnp.float64)
    _, info_d = dn.run_until(tol=tol, max_passes=400)
    _certificates_match(info_s, info_d, tol)
    assert info_s["compactions"] >= 1
    # dense interchange duals expand through the compaction plan
    dd = sp.duals_to_dense(st)
    assert np.all(np.isfinite(dd))


# ------------------------------------------------------ fixed points
def test_all_active_sparse_pass_is_dense_pass_bitwise(x64):
    p = _cc_problem(14, seed=4)
    sp = SparseSolver(p, bucket_diagonals=2, forget_every=10,
                     dtype=jnp.float64)
    dn = ParallelSolver(p, bucket_diagonals=2, dtype=jnp.float64)
    st_s = sp.run(passes=3)
    st_d = dn.run(dn.init_state(), passes=3)
    np.testing.assert_array_equal(np.asarray(st_s.x), np.asarray(st_d.x))
    # duals agree on every real cell (sparse pins padding/ghost cells at
    # 0.0 whereas the dense pass leaves them don't-care)
    for ys, yd, sl in zip(st_s.yd, st_d.yd, sp._slabs):
        act = np.broadcast_to(
            np.asarray(sl["valid"])[:, None], np.asarray(ys).shape
        )
        np.testing.assert_array_equal(
            np.asarray(ys)[act], np.asarray(yd)[act]
        )


def test_forget_zeroes_duals_and_shrinks_mask(x64):
    p = _cc_problem(16, seed=5)
    sp = SparseSolver(p, bucket_diagonals=2, forget_every=10,
                     dtype=jnp.float64)
    st = sp.run(passes=6)
    st2 = sp._forget_revive(st, sp._slabs, 0.0, 0.5 * 1e-4)
    shrank = False
    for sl, am0, am, yb in zip(sp._slabs, st.amask, st2.amask, st2.yd):
        am0, am = np.asarray(am0), np.asarray(am)
        assert not np.any(am & ~np.asarray(sl["valid"]))  # am ⊆ valid
        # duals outside the new mask are pinned at exactly 0.0
        off = np.broadcast_to(~am[:, None], np.asarray(yb).shape)
        assert np.all(np.asarray(yb)[off] == 0.0)
        shrank |= am.sum() < am0.sum()
    assert shrank  # some constraints really were slack after 6 passes
    assert sp.active_fraction(st2) < sp.active_fraction(st)


def test_revive_reactivates_violated_cells(x64):
    p = _cc_problem(16, seed=5)
    sp = SparseSolver(p, bucket_diagonals=2, dtype=jnp.float64)
    st = sp.run(passes=2)
    # forget EVERYTHING (ftol=inf): survivors are exactly the cells the
    # revival probe flags as violated beyond rtol.
    st2 = sp._forget_revive(st, sp._slabs, np.inf, 1e-9)
    for sl, am in zip(sp._slabs, st2.amask):
        viol = np.asarray(
            sl["valid"] & (sp._bucket_slack(st.x, sl) > 1e-9)
        )
        np.testing.assert_array_equal(np.asarray(am), viol)
        # revived cells restart from y = 0
    for yb, am in zip(st2.yd, st2.amask):
        off = np.broadcast_to(
            ~np.asarray(am)[:, None], np.asarray(yb).shape
        )
        assert np.all(np.asarray(yb)[off] == 0.0)


# -------------------------------------------------------- compaction
def test_compact_pass_is_masked_full_pass_bitwise(x64):
    p = _cc_problem(18, seed=6)
    kw = dict(bucket_diagonals=3, forget_every=3, dtype=jnp.float64)
    sp = SparseSolver(p, **kw, compact_every=2, compact_pad=4)
    st = sp.run(passes=6)
    rtol = 0.5 * 1e-4
    st = sp._forget_revive(st, sp._slabs, 0.0, rtol)
    assert sp.active_fraction(st) < 1.0
    stc = sp._recompact(st, rtol)
    assert sp._plan is not None
    # the full-slab twin runs the SAME mask over the uncompacted slabs
    full = SparseSolver(p, **kw)
    ams, yds = sp._expand_to_full(stc)
    stf = dataclasses.replace(
        stc,
        yd=[jnp.asarray(y, sp.dtype) for y in yds],
        amask=[jnp.asarray(m) for m in ams],
    )
    out_c = sp._masked_pass_fn()(stc, sp._slabs)
    out_f = full._masked_pass_fn()(stf, full._slabs)
    np.testing.assert_array_equal(np.asarray(out_c.x), np.asarray(out_f.x))
    ams_c, yds_c = sp._expand_to_full(out_c)
    for y_c, y_f, m in zip(yds_c, out_f.yd, ams_c):
        np.testing.assert_array_equal(
            y_c[m[:, None] & np.ones((1, 3, 1, 1), bool)],
            np.asarray(y_f)[m[:, None] & np.ones((1, 3, 1, 1), bool)],
        )


def test_compaction_plan_roundtrip(x64):
    p = _cc_problem(16, seed=7)
    sp = SparseSolver(
        p, bucket_diagonals=2, forget_every=3, compact_every=1,
        compact_pad=4, dtype=jnp.float64,
    )
    st = sp.run(passes=6)
    st = sp._forget_revive(st, sp._slabs, 0.0, 1e-5)
    stc = sp._recompact(st, 1e-5)
    rng = np.random.default_rng(0)
    for pb, sl in zip(sp._plan.buckets, sp._slabs):
        y = rng.normal(size=pb.comp_shape)  # (D', 3, T', Cl')
        y = np.where(np.asarray(sl["valid"])[:, None], y, 0.0)
        # expand → compact is the identity on compacted coordinates
        np.testing.assert_array_equal(pb.compact_duals(pb.expand_duals(y)), y)
        m = np.asarray(sl["valid"])
        np.testing.assert_array_equal(pb.compact_mask(pb.expand_mask(m)), m)
        # expanded mask stays within the full staged act mask
        assert pb.expand_mask(m).shape == (
            pb.full_shape[0], pb.full_shape[2], pb.full_shape[3]
        )


# -------------------------------------------------------- robustness
def test_aggressive_forget_still_converges(x64):
    p = _cc_problem(16, seed=8)
    tol = 1e-4
    sp = SparseSolver(
        p, bucket_diagonals=2, forget_every=2, forget_tol=1e9,
        dtype=jnp.float64,
    )
    st, info = sp.run_until(tol=tol, max_passes=600)
    assert info["converged"]
    assert info["max_violation"] <= tol
    dn = ParallelSolver(p, bucket_diagonals=2, dtype=jnp.float64)
    _, info_d = dn.run_until(tol=tol, max_passes=600)
    lp_s, lp_d = info["lp_objective"], info_d["lp_objective"]
    assert abs(lp_s - lp_d) <= 1e-5 * max(1.0, abs(lp_d))


def test_active_fraction_decays_with_telemetry():
    p = _cc_problem(30, seed=9)
    sp = SparseSolver(
        p, bucket_diagonals=4, forget_every=5, forget_tol=1e-6,
        compact_every=2, compact_pad=8,
    )
    st, info = sp.run_until(tol=1e-3, max_passes=200)
    assert info["converged"]
    traj = np.asarray(info["active_trajectory"])
    assert traj.size == min(info["rounds"], traj.size) and traj.size >= 1
    assert info["active_fraction"] < 0.9
    assert info["active_fraction"] == pytest.approx(
        sp.active_fraction(st)
    )
    assert info["rounds"] >= 2
    assert len(info["round_stats"]) >= 1
    for wall, passes, af in info["round_stats"]:
        assert wall >= 0.0 and passes >= 0 and 0.0 <= af <= 1.0


# ------------------------------------------------------------- stubs
def test_runtime_mode_stubs_raise():
    p = _cc_problem(12, seed=10)
    with pytest.raises(NotImplementedError, match="batched sparse"):
        SparseSolver.batched([p])
    with pytest.raises(NotImplementedError, match="sharded sparse"):
        SparseSolver.sharded(p)
    with pytest.raises(NotImplementedError, match="kernel route"):
        SparseSolver(p, use_kernel=True)
    with pytest.raises(NotImplementedError, match="fused execution"):
        SparseSolver(p, fused=False)
    sp = SparseSolver(p)
    with pytest.raises(NotImplementedError, match="no fixed-slab"):
        sp._one_pass(sp.init_state())
    with pytest.raises(ValueError, match="stop_rule"):
        sp.run_until(tol=1e-3, max_passes=2, stop_rule="bogus")

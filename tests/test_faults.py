"""Fault injection + hardening (DESIGN.md §11): the deterministic fault
plan grammar and seeded replay, scheduler retry / bisect-isolation /
dead-letter behavior, intake validation, the solo and batched divergence
guards, checkpoint corruption detection + walk-back (including injected
save/restore faults and a kill mid-save in a subprocess), and the
device-loss degrade-and-resume chaos drill on 8 host devices."""

import dataclasses
import os
import subprocess
import sys
import textwrap

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import problems
from repro.core.parallel_dykstra import ParallelSolver, ParallelState
from repro.graphs import generators, jaccard
from repro.serve import buckets as bk, faults as flt
from repro.serve.scheduler import BatchScheduler
from repro.train import checkpoint as ckpt


def _cc_problem(n, seed=0, eps=0.05):
    adj, _ = generators.planted_partition(n, seed=seed)
    dissim, w = jaccard.signed_instance(adj)
    return problems.correlation_clustering_lp(dissim, w, eps=eps)


#: shared compiled-runner cache — the schedulers below reuse warm runners
#: across tests instead of recompiling per test.
_CACHE = bk.SolverCache()

_SOLVE = dict(tol=1e-3, max_passes=60, check_every=10)


def _scheduler(**kw):
    kw.setdefault("ladder", (12,))
    kw.setdefault("batch", 3)
    kw.setdefault("cache", _CACHE)
    kw.setdefault("sleep", lambda dt: None)
    return BatchScheduler(**{**_SOLVE, **kw})


# ------------------------------------------------------------ fault plans
def test_spec_parse_roundtrip():
    s = flt.parse_spec("device_loss@mesh:2:p=4")
    assert (s.kind, s.site, s.at, s.payload) == ("device_loss", "mesh", 2, {"p": 4})
    assert flt.parse_spec(s.spec_str()) == s
    assert flt.parse_spec("nan_poison@dispatch").at == 0
    p = flt.FaultPlan.parse("kill@ckpt_save:1:code=17; straggler@chunk:0:seconds=0.5")
    assert len(p) == 2 and p.specs[0].payload == {"code": 17}
    assert p.specs[1].payload == {"seconds": 0.5}
    assert flt.FaultPlan.parse(p.specs[0].spec_str()) + flt.FaultPlan(
        [p.specs[1]]
    ) == p
    with pytest.raises(ValueError):
        flt.parse_spec("nonsense")  # no @site
    with pytest.raises(ValueError):
        flt.parse_spec("frobnicate@dispatch:0")  # unknown kind
    with pytest.raises(ValueError):
        flt.FaultSpec("nan_poison", "mesh")  # kind/site mismatch
    with pytest.raises(ValueError):
        flt.FaultSpec("nan_poison", "chunk", at=-1)


def test_seeded_plan_replayable():
    a = flt.FaultPlan.seeded(11)
    assert a == flt.FaultPlan.seeded(11) and len(a) == 3
    assert all(s.kind != "kill" for s in a)  # excluded by default
    assert all(s.site in flt.KIND_SITES[s.kind] for s in a)
    assert any(flt.FaultPlan.seeded(s) != a for s in range(1, 8))
    only = flt.FaultPlan.seeded(3, n_faults=5, kinds=("straggler",),
                                sites=("dispatch",))
    assert all(s.kind == "straggler" and s.site == "dispatch" for s in only)
    with pytest.raises(ValueError):
        flt.FaultPlan.seeded(0, kinds=("kill",), sites=("mesh",))


def test_injector_counter_and_tag_semantics():
    inj = flt.FaultInjector("straggler@chunk:1:seconds=0")
    assert inj.poll("chunk") == []  # count 0 < at
    assert [s.kind for s in inj.poll("chunk")] == ["straggler"]
    assert inj.poll("chunk") == []  # one-shot: at == count only
    assert inj.count("chunk") == 3 and inj.count("dispatch") == 0
    assert inj.log() == [("chunk", 1, "straggler")]

    # tag specs are persistent: every matching poll once count >= at
    spec = flt.FaultSpec("dispatch_error", "dispatch", at=1,
                         payload={"tag": "bad"})
    inj2 = flt.FaultInjector(flt.FaultPlan([spec]))
    assert inj2.poll("dispatch", tags=("bad",)) == []  # count 0 < at
    assert inj2.poll("dispatch", tags=("good",)) == []  # tag absent
    assert inj2.poll("dispatch", tags=("good", "bad")) == [spec]
    assert inj2.poll("dispatch", tags=("bad",)) == [spec]  # still firing
    assert inj2.log() == [("dispatch", 2, "dispatch_error"),
                          ("dispatch", 3, "dispatch_error")]
    with pytest.raises(ValueError):
        inj2.poll("nowhere")


# ------------------------------------------------------- intake hardening
def test_validation_rejects_dead_letter():
    p = _cc_problem(8)
    d_bad = np.array(p.d)
    d_bad[0, 1] = np.nan
    bad = dataclasses.replace(p, d=d_bad)
    s = _scheduler()
    assert s.submit(bad, tag="poison") == "poison"  # submit never raises
    r = s.results()["poison"]
    assert r["route"] == "failed" and r["error"] == "validation"
    assert r["error_type"] == "ValidationError" and r["x"] is None
    st = s.stats()["faults"]
    assert st["validation_rejects"] == 1 and st["dead_letters"] == 1
    # healthy traffic through the same scheduler still lands
    s.submit(p, tag="ok")
    out = s.drain()
    assert out["ok"]["route"] == "batch" and out["ok"]["x"] is not None

    with pytest.raises(bk.ValidationError):
        bk.validate_problem(dataclasses.replace(p, eps=0.0))
    with pytest.raises(bk.ValidationError):
        bk.validate_problem(dataclasses.replace(p, w=-np.array(p.w)))
    with pytest.raises(bk.ValidationError):
        bk.validate_problem(dataclasses.replace(p, box=(1.0, 0.0)))
    bk.validate_problem(p)  # the clean instance passes


def test_duplicate_tag_raises():
    s = _scheduler(batch=4)
    s.submit(_cc_problem(8), tag="t")
    with pytest.raises(ValueError):
        s.submit(_cc_problem(8), tag="t")  # still pending
    s.drain()
    with pytest.raises(ValueError):
        s.submit(_cc_problem(8), tag="t")  # unclaimed result
    auto = [s.submit(_cc_problem(8, seed=i)) for i in range(3)]
    assert len(set(auto)) == 3  # auto tags monotone-unique
    out = s.drain()
    assert all(t in out for t in auto)


# ------------------------------------------------- retry / bisect / guard
def test_retry_heals_transient_dispatch_error():
    sleeps = []
    inj = flt.FaultInjector("dispatch_error@dispatch:0")
    s = _scheduler(faults=inj, sleep=sleeps.append, backoff_s=0.05)
    tags = [s.submit(_cc_problem(8, seed=i)) for i in range(3)]  # full batch
    out = s.results()
    assert all(out[t]["route"] == "batch" for t in tags)
    st = s.stats()["faults"]
    assert st["retries"] == 1 and st["dead_letters"] == 0
    assert st["injected_fired"] == 1
    assert sleeps == [0.05]  # one backoff, then the retry healed
    assert inj.log() == [("dispatch", 0, "dispatch_error")]


def test_bisect_isolates_persistent_poison():
    spec = flt.FaultSpec("dispatch_error", "dispatch", payload={"tag": "bad"})
    inj = flt.FaultInjector(flt.FaultPlan([spec]))
    s = _scheduler(batch=4, faults=inj, max_retries=0)
    for i in range(4):
        s.submit(_cc_problem(8, seed=i), tag="bad" if i == 1 else f"ok{i}")
    out = s.results()
    assert out["bad"]["route"] == "failed" and out["bad"]["error"] == "injected"
    assert out["bad"]["error_type"] == "InjectedFault"
    for t in ("ok0", "ok2", "ok3"):
        assert out[t]["route"] == "batch"
        assert np.isfinite(out[t]["max_violation"])
    st = s.stats()
    assert st["faults"]["dead_letters"] == 1
    assert st["instances_done"] == 3


def test_nan_poison_slot_isolated_healthy_bitwise():
    """One request poisoned past intake (NaN in its problem data): the
    per-slot divergence guard dead-letters that slot; the healthy slots
    of the SAME batch land bitwise identical to a fault-free run."""
    probs = [_cc_problem(9, seed=i) for i in range(3)]
    clean = _scheduler()
    for i, p in enumerate(probs):
        clean.submit(p, tag=f"g{i}")
    ref = clean.drain()

    spec = flt.FaultSpec("nan_poison", "dispatch", payload={"tag": "g1"})
    inj = flt.FaultInjector(flt.FaultPlan([spec]))
    s = _scheduler(faults=inj)
    for i, p in enumerate(probs):
        s.submit(p, tag=f"g{i}")
    out = s.results()
    assert out["g1"]["route"] == "failed" and out["g1"]["error"] == "diverged"
    assert out["g1"]["error_type"] == "ArithmeticError"
    for t in ("g0", "g2"):
        assert out[t]["route"] == "batch"
        np.testing.assert_array_equal(out[t]["x"], ref[t]["x"])
        assert out[t]["passes"] == ref[t]["passes"]
    assert inj.log() == [("dispatch", 0, "nan_poison")]
    assert s.stats()["faults"]["dead_letters"] == 1


def test_engine_divergence_guard_entry_poison():
    p = _cc_problem(9)
    solver = ParallelSolver(p, bucket_diagonals=3)
    inj = flt.FaultInjector("nan_poison@chunk:0")
    st, info = solver.run_until(solver.init_state(), faults=inj, **_SOLVE)
    assert info["diverged"] and not info["converged"]
    assert info["passes"] == 0  # nothing finite ever ran
    assert inj.log() == [("chunk", 0, "nan_poison")]
    # a no-op injector leaves the solve untouched
    st2, info2 = solver.run_until(
        solver.init_state(), faults=flt.FaultInjector(), **_SOLVE
    )
    assert not info2["diverged"] and info2["converged"]


class _PoisonAtPass(ParallelSolver):
    """Solver whose iterate goes NaN ON DEVICE after a fixed pass — a
    mid-while_loop divergence the guard must catch without host help."""

    POISON_AT = 7

    def _one_pass(self, st):
        st = super()._one_pass(st)
        bad = st.passes == self.POISON_AT
        x = st.x + jnp.where(bad, jnp.nan, 0.0)
        return ParallelState(x, st.f, st.yd, st.ypair, st.ybox, st.passes)


def test_engine_divergence_guard_midloop_restores_last_finite():
    p = _cc_problem(9)
    solver = _PoisonAtPass(p, bucket_diagonals=3)
    st, info = solver.run_until(
        solver.init_state(), tol=1e-9, max_passes=40, check_every=5
    )
    assert info["diverged"] and not info["converged"]
    # poison lands during chunk (5, 10]; the guard rewinds to the pass-5
    # boundary — the last finite state — instead of burning max_passes.
    assert info["passes"] == 5
    assert np.isfinite(np.asarray(st.x)).all()
    assert np.isfinite(info["max_violation"]) and np.isfinite(info["duality_gap"])


# ---------------------------------------------------- checkpoint hardening
def _tree(step):
    return {"x": np.full((4, 4), float(step)), "k": np.arange(3) + step}


def test_ckpt_truncate_detected_and_walked_back(tmp_path):
    d = str(tmp_path)
    inj = flt.FaultInjector("ckpt_truncate@ckpt_save:2:fraction=0.5")
    mgr = ckpt.CheckpointManager(d, keep=5, every=1, faults=inj)
    for s in (1, 2, 3):
        mgr.maybe_save(s, _tree(s), asynchronous=False)
    assert inj.log() == [("ckpt_save", 2, "ckpt_truncate")]
    # the truncated step COMMITTED (the fault hits after staging) but the
    # checksum manifest convicts it at restore time...
    with pytest.raises(ckpt.CorruptCheckpointError):
        ckpt.restore(d, _tree(0), step=3)
    # ...and resume_or walks back to the newest intact step.
    tree, step = mgr.resume_or(_tree(0))
    assert step == 2 and tree["x"][0, 0] == 2.0


def test_ckpt_restore_fault_walks_back(tmp_path):
    d = str(tmp_path)
    mgr = ckpt.CheckpointManager(d, every=1)
    for s in (1, 2):
        mgr.maybe_save(s, _tree(s), asynchronous=False)
    inj = flt.FaultInjector("ckpt_corrupt@ckpt_restore:0")
    mgr2 = ckpt.CheckpointManager(d, every=1, faults=inj)
    tree, step = mgr2.resume_or(_tree(0))  # newest reports corrupt
    assert step == 1 and tree["x"][0, 0] == 1.0
    assert inj.log() == [("ckpt_restore", 0, "ckpt_corrupt")]


def test_wait_pending_surfaces_background_errors(tmp_path):
    blocker = tmp_path / "blocked"
    blocker.write_text("not a directory")
    ckpt.save_async(str(blocker), 1, _tree(1))
    with pytest.raises(ckpt.CheckpointError):
        ckpt.wait_pending()
    ckpt.wait_pending()  # the failure is consumed, not sticky


def test_maybe_save_force(tmp_path):
    d = str(tmp_path)
    mgr = ckpt.CheckpointManager(d, every=100)
    assert mgr.maybe_save(7, _tree(7), asynchronous=False) is None
    mgr.maybe_save(7, _tree(7), asynchronous=False, force=True)
    assert ckpt.latest_step(d) == 7


_KILL_SCRIPT = textwrap.dedent(
    """
    import numpy as np
    from repro.serve import faults as flt
    from repro.train import checkpoint as ckpt

    d = {ckpt_dir!r}
    inj = flt.FaultInjector("kill@ckpt_save:1:code=17")
    tree = lambda s: {{"x": np.full((4, 4), float(s)), "k": np.arange(3) + s}}
    ckpt.save(d, 1, tree(1), faults=inj)
    ckpt.save(d, 2, tree(2), faults=inj)  # os._exit(17) mid-save
    print("NOT_REACHED")
    """
)


def test_kill_mid_save_previous_checkpoint_survives(tmp_path):
    """A process killed between staging and commit must leave the previous
    checkpoint restorable and only orphan debris behind."""
    d = str(tmp_path)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run(
        [sys.executable, "-c", _KILL_SCRIPT.format(ckpt_dir=d)],
        capture_output=True, text=True, env=env, timeout=300,
    )
    assert out.returncode == 17, out.stderr[-3000:]
    assert "NOT_REACHED" not in out.stdout
    assert ckpt.latest_step(d) == 1  # step 2 never committed
    leftovers = [f for f in os.listdir(d) if ".tmp-" in f]
    assert leftovers  # the staged dir was orphaned by the kill...
    mgr = ckpt.CheckpointManager(d, every=1)  # ...and swept at startup
    assert not any(".tmp-" in f for f in os.listdir(d))
    tree, step = mgr.resume_or(_tree(0))
    assert step == 1 and tree["x"][0, 0] == 1.0


# --------------------------------------------- device-loss degrade-and-resume
_CHAOS8_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np, jax
    from jax.sharding import Mesh
    from repro.core import problems
    from repro.core.sharded_dykstra import ShardedSolver
    from repro.launch import elastic

    assert len(jax.devices()) == 8
    n = 14
    rng = np.random.default_rng(7)
    d = np.triu(rng.uniform(0, 1, (n, n)), k=1)
    p = problems.metric_nearness_l2(d)
    mesh = Mesh(np.array(jax.devices()), ("solver",))
    solve = dict(tol=1e-4, max_passes=200, check_every=10)

    # faulted run: 6 passes on p=8, lose half the mesh, finish on p=4
    solver = ShardedSolver(p, mesh, num_buckets=3)
    state = solver.init_state()
    state, _ = solver.run_until(state, tol=1e-12, max_passes=6, check_every=3)
    solver2, state2 = elastic.degrade_solver(solver, state, 4)
    assert int(solver2.nproc) == 4
    state2, info2 = solver2.run_until(state2, **solve)
    assert info2["converged"], info2

    # reference: the same solve on the fixed 8-device mesh
    ref = ShardedSolver(p, mesh, num_buckets=3)
    rstate, rinfo = ref.run_until(ref.init_state(), **solve)
    assert rinfo["converged"], rinfo

    # same certificate: the metric-nearness QP projection is unique, so
    # the degraded run must land on the fixed-mesh solution
    assert info2["max_violation"] <= 2e-4 and rinfo["max_violation"] <= 2e-4
    np.testing.assert_allclose(
        info2["qp_objective"], rinfo["qp_objective"], rtol=1e-3
    )
    np.testing.assert_allclose(
        np.asarray(state2.x), np.asarray(rstate.x), atol=5e-3
    )
    print("CHAOS8_OK")
    """
)


@pytest.mark.multidevice
def test_device_loss_degrade_certificate_matches_8dev_subprocess():
    """Chaos drill on 8 real host devices: lose half the mesh mid-solve,
    reshard the live duals onto the survivors, finish the solve — the
    degraded run's certificate must match the fixed-mesh run."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run(
        [sys.executable, "-c", _CHAOS8_SCRIPT],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    assert "CHAOS8_OK" in out.stdout


# ------------------------------------------------------- end-to-end chaos
def test_end_to_end_seeded_chaos():
    """Replayable chaos through the full serve stack: a transient
    dispatch error (heals on retry), a persistently poisoned request
    (isolated to a dead-letter), seeded stragglers — every submitted
    request reaches exactly one terminal result, the scheduler never
    raises, and the healthy certificates match the fault-free run."""
    plan = (
        flt.FaultPlan.parse("dispatch_error@dispatch:0")
        + flt.FaultPlan(
            [flt.FaultSpec("nan_poison", "dispatch", payload={"tag": "g1"})]
        )
        + flt.FaultPlan.seeded(
            5, n_faults=2, kinds=("straggler",), sites=("dispatch",)
        )
    )
    probs = [_cc_problem(9, seed=i) for i in range(6)]

    clean = _scheduler()
    for i, p in enumerate(probs):
        clean.submit(p, tag=f"g{i}")
    ref = clean.drain()

    inj = flt.FaultInjector(plan)
    s = _scheduler(faults=inj)
    tags = [s.submit(p, tag=f"g{i}") for i, p in enumerate(probs)]
    out = s.drain()

    assert set(out) == set(tags)  # every request terminal
    assert out["g1"]["route"] == "failed" and out["g1"]["error"] == "diverged"
    for t in tags:
        if t == "g1":
            continue
        assert out[t]["route"] == "batch"
        np.testing.assert_array_equal(out[t]["x"], ref[t]["x"])
    st = s.stats()["faults"]
    assert st["retries"] >= 1 and st["dead_letters"] == 1
    assert st["validation_rejects"] == 0
    assert st["injected_fired"] >= 2
    assert all(site == "dispatch" for site, _, _ in inj.log())

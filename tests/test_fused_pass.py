"""Fused-pass execution (DESIGN.md §4): oracle parity for the fused jnp
reference and the whole-bucket Pallas megakernel (interpret mode), static
staging consistency with ``folded_geometry`` bit-for-bit, and the jitted
multi-pass runner's contract."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # tier-1 containers lack hypothesis; @given tests skip
    from conftest import given, settings, st

from repro.core import dykstra, problems, schedule as sched
from repro.core.parallel_dykstra import ParallelSolver, folded_geometry

PASSES = 3


@pytest.fixture()
def x64():
    old = jax.config.jax_enable_x64
    jax.config.update("jax_enable_x64", True)
    yield
    jax.config.update("jax_enable_x64", old)


def _l2_problem(n, seed=0):
    rng = np.random.default_rng(seed)
    return problems.metric_nearness_l2(np.triu(rng.uniform(0, 1, (n, n)), k=1))


# ------------------------------------------------- fused pass vs the oracle
@pytest.mark.parametrize("use_kernel", [False, True],
                         ids=["fused-ref", "fused-megakernel"])
@pytest.mark.parametrize("buckets", [1, 4])
def test_fused_pass_matches_serial_oracle(x64, use_kernel, buckets):
    """>= 3 fused passes in float64 track the serial oracle to 1e-5 — the
    fused staging/megakernel reorganizes execution, never the math."""
    n = 14
    p = _l2_problem(n, seed=3)
    st_ser = dykstra.solve_serial(p, max_passes=PASSES, order="schedule")
    solver = ParallelSolver(
        p, dtype=np.float64, use_kernel=use_kernel, bucket_diagonals=buckets
    )
    assert solver.fused
    st = solver.run(passes=PASSES)
    np.testing.assert_allclose(np.asarray(st.x), st_ser.x, atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(
        solver.duals_to_dense(st), st_ser.ytri, atol=1e-5, rtol=1e-5
    )


def test_fused_pass_matches_oracle_cc_lp(x64):
    """Pair-constraint family through the fused multi-pass runner."""
    n = 11
    rng = np.random.default_rng(5)
    dis = np.triu((rng.uniform(0, 1, (n, n)) > 0.5).astype(float), k=1)
    p = problems.correlation_clustering_lp(dis, eps=0.05)
    st_ser = dykstra.solve_serial(p, max_passes=PASSES, order="schedule")
    solver = ParallelSolver(p, dtype=np.float64, bucket_diagonals=3)
    st = solver.run(passes=PASSES)
    np.testing.assert_allclose(np.asarray(st.x), st_ser.x, atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(st.f), st_ser.f, atol=1e-5, rtol=1e-5)


def test_megakernel_matches_fused_ref_bitwise():
    """The megakernel and the jnp reference share fused_step op-for-op, so
    X must agree bitwise in float32 on a non-trivial dual state."""
    from repro.kernels.metric_project import ops
    from repro.kernels.metric_project.ref import fused_bucket_pass_ref

    n = 16
    p = _l2_problem(n, seed=9)
    solver = ParallelSolver(p, bucket_diagonals=2)
    st = solver.run(passes=2)  # non-zero duals
    x = st.x
    for b, yb in zip(solver._buckets, st.yd):
        rx, ry = fused_bucket_pass_ref(x, yb, b)
        kx, ky = ops.fused_bucket_pass(x, yb, b)
        np.testing.assert_array_equal(np.asarray(rx), np.asarray(kx))
        x = rx
    # dual slabs agree on every real (non-padding) cell via the dense maps
    a = ParallelSolver(p, bucket_diagonals=2, use_kernel=False).run(passes=3)
    b = ParallelSolver(p, bucket_diagonals=2, use_kernel=True).run(passes=3)
    np.testing.assert_array_equal(
        ParallelSolver(p, bucket_diagonals=2).duals_to_dense(a),
        ParallelSolver(p, bucket_diagonals=2).duals_to_dense(b),
    )


# ------------------------------------------------- gen-3 megakernel (§10)
def test_megakernel_solo_bitwise_f64(x64):
    """Gen-3 solo path in float64 interpret mode: bitwise-equal X to
    ``ref.fused_bucket_pass_ref`` bucket-for-bucket (the staging engines
    reorganize execution, never the arithmetic)."""
    from repro.kernels.metric_project import ops
    from repro.kernels.metric_project.ref import fused_bucket_pass_ref

    p = _l2_problem(14, seed=21)
    solver = ParallelSolver(p, dtype=np.float64, bucket_diagonals=2)
    st = solver.run(passes=2)  # non-zero duals
    x = st.x
    for b, yb in zip(solver._buckets, st.yd):
        rx, _ = fused_bucket_pass_ref(x, yb, b)
        kx, _ = ops.fused_bucket_pass(x, yb, b)
        np.testing.assert_array_equal(np.asarray(rx), np.asarray(kx))
        x = rx


def test_megakernel_batched_mixed_ghost_bitwise(x64):
    """One (B=4, ...) megakernel call per bucket — mixed-n slots with
    ghost padding and one all-ghost empty slot — must be bitwise-equal to
    the vmapped jnp fused reference, end-to-end through ``run_until``
    (X, per-instance pass counters, stopping vectors, dual stats)."""
    from repro.serve.batching import BatchedSolver
    from repro.serve.buckets import family_of

    ps = [_l2_problem(12, seed=1), _l2_problem(9, seed=2),
          _l2_problem(12, seed=3), None]
    fam = family_of(ps[0], np.float64)
    ref = BatchedSolver(12, 4, fam, num_buckets=3)
    ker = BatchedSolver(12, 4, fam, num_buckets=3, use_kernel=True)
    inst = ref.stack(ps)
    sta, ia = ref.run_until(inst, tol=1e-5, max_passes=30, check_every=5)
    stb, ib = ker.run_until(inst, tol=1e-5, max_passes=30, check_every=5)
    np.testing.assert_array_equal(np.asarray(sta.x), np.asarray(stb.x))
    np.testing.assert_array_equal(ia["passes"], ib["passes"])
    np.testing.assert_array_equal(ia["max_violation"], ib["max_violation"])
    assert ib["converged"][3]  # the empty slot converges immediately
    da, db = ref.dual_stats(sta, inst), ker.dual_stats(stb, inst)
    for key in da:
        np.testing.assert_array_equal(da[key], db[key])


def test_megakernel_ghost_cells_fixed_points():
    """Ghost rows/columns of a padded instance are structural fixed
    points of the kernel pass (DESIGN.md §8/§10): the staged act masks
    zero every ghost delta, so ghost cells stay exactly 0.0 and the live
    block matches the jnp fused reference path bitwise — no jnp fallback
    is involved (the probe runs the n_live-masked violation kernel)."""
    from repro.serve.buckets import pad_problem

    n, npad = 10, 14
    p = _l2_problem(n, seed=3)
    pp = pad_problem(p, npad)
    ref = ParallelSolver(pp, bucket_diagonals=2, n_real=n)
    ker = ParallelSolver(pp, bucket_diagonals=2, n_real=n, use_kernel=True)
    sta, ia = ref.run_until(tol=1e-4, max_passes=30, check_every=5)
    stb, ib = ker.run_until(tol=1e-4, max_passes=30, check_every=5)
    xb = np.asarray(stb.x)
    np.testing.assert_array_equal(np.asarray(sta.x), xb)
    assert ia["passes"] == ib["passes"]
    assert ia["max_violation"] == ib["max_violation"]
    ghost = np.zeros((npad, npad), bool)
    ghost[n:, :] = True
    ghost[:, n:] = True
    assert np.all(np.abs(xb[ghost]) == 0.0)


def test_megakernel_compile_counter():
    """Weights-as-operands contract (DESIGN.md §10): new instances and
    new batches reuse the SAME compiled kernel program — the jit cache
    of the megakernel entrypoint must not grow when a second weight set
    (solo) or a second instance batch (batched) runs through it."""
    from repro.kernels.metric_project import ops
    from repro.kernels.metric_project.ref import fused_bucket_pass_ref
    from repro.serve.batching import BatchedSolver
    from repro.serve.buckets import family_of

    counter = getattr(ops._fused_pass_jit, "_cache_size", None)
    if counter is None:
        pytest.skip("jit cache introspection unavailable")

    a = ParallelSolver(_l2_problem(13, seed=1), bucket_diagonals=2,
                       use_kernel=True)
    a.run(passes=2)
    size_solo = counter()
    assert size_solo > 0
    b = ParallelSolver(_l2_problem(13, seed=2), bucket_diagonals=2,
                       use_kernel=True)
    b.run(passes=2)
    assert counter() == size_solo  # second weight set: zero recompiles

    fam = family_of(_l2_problem(10, seed=1), np.float32)
    solver = BatchedSolver(10, 3, fam, num_buckets=2, use_kernel=True)
    inst1 = solver.stack([_l2_problem(10, seed=3), _l2_problem(7, seed=4)])
    solver.run_until(inst1, tol=1e-4, max_passes=10, check_every=5)
    size_batched = counter()
    inst2 = solver.stack([_l2_problem(9, seed=5), _l2_problem(10, seed=6),
                          _l2_problem(8, seed=7)])
    solver.run_until(inst2, tol=1e-4, max_passes=10, check_every=5)
    assert counter() == size_batched  # new batch: zero recompiles


def test_demoted_gen1_fallback_warns():
    """use_kernel=True with fused=False has no kernel path anymore (gen-1
    is test-oracle-only): the fallback to the jnp sweep must be LOUD."""
    p = _l2_problem(10, seed=2)
    solver = ParallelSolver(p, use_kernel=True, fused=False,
                            bucket_diagonals=2)
    with pytest.warns(UserWarning, match="test-oracle"):
        solver.run(passes=1)


def test_gen1_oracle_vs_gen3_parity(x64):
    """Gen-1 (``diagonal_sweep_slab``, demoted to test-oracle status) vs
    gen-3 on one diagonal. The generations intentionally differ in float
    association — gen-1 divides by (w, eps) at runtime, gen-3 consumes
    staged gains — so cross-generation agreement is tight-tolerance in
    f64 while each generation stays bitwise-pinned to its own jnp oracle
    (gen-1 in test_kernels.py, gen-3 above)."""
    import jax.numpy as jnp

    from repro.kernels.metric_project import ops

    p = _l2_problem(12, seed=1)
    solver = ParallelSolver(p, dtype=np.float64, bucket_diagonals=2)
    st = solver.run(passes=2)
    b, yb = solver._buckets[0], st.yd[0]
    d = 0
    i1, k1, s1 = b["i"][d], b["k"][d], b["s"][d]
    i2, k2, s2 = b["i2"][d], b["k2"][d], b["s2"][d]
    J, iN, kN = b["J"][d], b["iN"][d], b["kN"][d]
    act, seg = b["act"][d], b["seg"][d]
    x = st.x
    get = lambda a, idx, f: a.at[idx].get(mode="fill", fill_value=f)
    rowb, colb = get(x, (iN, J), 0.0), get(x, (J, kN), 0.0)
    xikp = jnp.stack([get(x, (i1, k1), 0.0), get(x, (i2, k2), 0.0)])
    w = jnp.asarray(p.w, jnp.float64)
    w_row, w_col = get(w, (iN, J), 1.0), get(w, (J, kN), 1.0)
    w_ikp = jnp.stack([get(w, (i1, k1), 1.0), get(w, (i2, k2), 1.0)])
    nr1, nc1, nx1, _ = ops.diagonal_sweep_slab(
        rowb, colb, xikp, yb[d], w_row, w_col, w_ikp, act, seg,
        float(p.eps)
    )
    sc = lambda a, idx, v: a.at[idx].add(v, mode="drop",
                                         unique_indices=True)
    x1 = sc(x, (iN, J), jnp.where(act, nr1 - rowb, 0))
    x1 = sc(x1, (J, kN), jnp.where(act, nc1 - colb, 0))
    x1 = sc(x1, (i1, k1), jnp.where(s1 > 0, nx1[0] - xikp[0], 0))
    x1 = sc(x1, (i2, k2), jnp.where(s2 > 0, nx1[1] - xikp[1], 0))
    dx, _ = ops.fused_diag_pass_delta(
        x, yb[d], jnp.stack([i1, k1, s1, i2, k2, s2]),
        jnp.stack([J, iN, kN]), b["g_row"][d], b["g_col"][d],
        b["g_sel"][d], b["dinv"][d], act, seg
    )
    np.testing.assert_allclose(
        np.asarray(x1), np.asarray(x + dx), rtol=1e-13, atol=1e-14
    )


def test_legacy_path_matches_oracle(x64):
    """``fused=False`` (the benchmark baseline) still tracks the oracle."""
    n = 12
    p = _l2_problem(n, seed=11)
    st_ser = dykstra.solve_serial(p, max_passes=2, order="schedule")
    solver = ParallelSolver(p, dtype=np.float64, fused=False,
                            bucket_diagonals=2)
    st = solver.run(passes=2)
    np.testing.assert_allclose(np.asarray(st.x), st_ser.x, atol=1e-5, rtol=1e-5)


# --------------------------------------------------- static staging slabs
@given(n=st.integers(5, 22), nb=st.integers(1, 4), procs=st.integers(1, 3))
@settings(max_examples=15, deadline=None)
def test_property_static_stage_matches_folded_geometry(n, nb, procs):
    """build_static_stage's precomputed geometry/mask slabs must agree
    BIT-FOR-BIT with the jnp folded_geometry every solver path shares —
    any drift would silently desynchronize the fused pass from the
    conflict-free schedule."""
    lay = sched.build_layout(n, num_buckets=nb, procs=procs)
    rng = np.random.default_rng(n * 100 + nb * 10 + procs)
    w = np.triu(rng.uniform(0.5, 2.0, (n, n)), k=1)
    w = w + w.T + np.eye(n)
    stage = sched.build_static_stage(lay, w)
    for bl, sb in zip(lay.buckets, stage):
        for dev in range(procs):
            for r in range(bl.slab_shape[1]):
                J, iN, kN, act, seg = folded_geometry(
                    jnp.asarray(bl.i[dev, r]), jnp.asarray(bl.k[dev, r]),
                    jnp.asarray(bl.sizes[dev, r]), jnp.asarray(bl.i2[dev, r]),
                    jnp.asarray(bl.k2[dev, r]), jnp.asarray(bl.sizes2[dev, r]),
                    bl.T,
                )
                np.testing.assert_array_equal(np.asarray(J), sb.J[dev, r])
                np.testing.assert_array_equal(np.asarray(iN), sb.iN[dev, r])
                np.testing.assert_array_equal(np.asarray(kN), sb.kN[dev, r])
                np.testing.assert_array_equal(np.asarray(act),
                                              sb.active[dev, r])
                np.testing.assert_array_equal(np.asarray(seg),
                                              sb.seg[dev, r])


def test_static_stage_weights_active_cells():
    """Active cells of the staged weight slabs equal W at the folded
    indices; masked cells are finite (sanitized to the fill value)."""
    n = 15
    lay = sched.build_layout(n, num_buckets=2, procs=1)
    rng = np.random.default_rng(4)
    w = np.triu(rng.uniform(0.5, 2.0, (n, n)), k=1)
    w = w + w.T + np.eye(n)
    stage = sched.build_static_stage(lay, w)
    for sb in stage:
        act = sb.active
        np.testing.assert_array_equal(
            sb.w_row[act], w[sb.iN[act], sb.J[act]].astype(np.float32)
        )
        np.testing.assert_array_equal(
            sb.w_col[act], w[sb.J[act], sb.kN[act]].astype(np.float32)
        )
        assert np.isfinite(sb.w_row).all() and (sb.w_row > 0).all()
        assert np.isfinite(sb.w_col).all() and (sb.w_col > 0).all()
        assert np.isfinite(sb.w_ikp).all() and (sb.w_ikp > 0).all()


def test_static_stage_preserves_zero_weights_on_active_cells():
    """Sanitization must touch MASKED cells only: a user-supplied zero
    weight on a real pair reaches the staged slabs verbatim (the serial
    oracle's 1/w = inf semantics), never silently replaced by the fill."""
    n = 9
    lay = sched.build_layout(n, num_buckets=1, procs=1)
    w = np.ones((n, n))
    w[2, 5] = w[5, 2] = 0.0
    stage = sched.build_static_stage(lay, w)
    hits = 0
    for sb in stage:
        act = sb.active
        zero_row = act & (sb.iN == 2) & (sb.J == 5)
        zero_col = act & (sb.J == 2) & (sb.kN == 5)
        hits += int(zero_row.sum()) + int(zero_col.sum())
        assert (sb.w_row[zero_row] == 0).all()
        assert (sb.w_col[zero_col] == 0).all()
    assert hits > 0  # the pair really is visited by the schedule


# ------------------------------------------------------ multi-pass runner
def test_multi_pass_runner_equals_repeated_single_pass():
    """One scan over P passes must produce exactly the same state as P
    single-pass runs (the scan only removes dispatch, never reorders)."""
    n = 13
    p = _l2_problem(n, seed=6)
    solver = ParallelSolver(p, bucket_diagonals=3)
    st_scan = solver.run(passes=4)
    st_loop = solver.init_state()
    for _ in range(4):
        st_loop = solver.run(st_loop, passes=1)
    np.testing.assert_array_equal(np.asarray(st_scan.x), np.asarray(st_loop.x))
    for a, b in zip(st_scan.yd, st_loop.yd):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert int(st_scan.passes) == 4


def test_runner_probe_trajectory():
    """The periodic probe reports a per-pass ||Δx||_inf trajectory: finite,
    non-negative, and shrinking as Dykstra converges; probe_every gates
    which passes are measured (-1 elsewhere)."""
    n = 12
    p = _l2_problem(n, seed=8)
    solver = ParallelSolver(p, bucket_diagonals=2)
    solver.run(passes=6)
    res = np.asarray(solver.last_residuals)
    assert res.shape == (6,)
    assert (res >= 0).all()
    assert res[5] < res[0]

    sparse = ParallelSolver(p, bucket_diagonals=2, probe_every=3)
    sparse.run(passes=6)
    res3 = np.asarray(sparse.last_residuals)
    assert (res3[[0, 1, 3, 4]] == -1).all()
    np.testing.assert_allclose(res3[[2, 5]], res[[2, 5]], rtol=1e-6)


def test_zero_passes_is_identity():
    p = _l2_problem(10, seed=1)
    solver = ParallelSolver(p)
    st = solver.init_state()
    st2 = solver.run(st, passes=0)
    np.testing.assert_array_equal(np.asarray(st2.x), np.asarray(st.x))


# ------------------------------ masked-cell fixed points (DESIGN.md §13)
def _engine_bucket_pass(engine, x, yb, stage, am):
    """One bucket pass with a DYNAMIC act mask through one engine. The
    mask is a runtime operand on every path — exactly how SparseSolver
    threads its active masks."""
    from repro.kernels.metric_project import fused_pass
    from repro.kernels.metric_project import ref as kref

    if engine == "ref":
        return kref.fused_bucket_pass_ref(x, yb, dict(stage) | {"act": am})
    lanes = jnp.stack(
        [stage[k] for k in ("i", "k", "s", "i2", "k2", "s2")]
    )
    geom = jnp.stack([stage["J"], stage["iN"], stage["kN"]])
    one = lambda a: a[None]
    nx, ny = fused_pass.fused_bucket_pass_pallas(
        x[None], yb[None], lanes, one(stage["g_row"]),
        one(stage["g_col"]), one(stage["g_sel"]), one(stage["dinv"]),
        one(am), stage["seg"], geom,
        block_c=2 if engine == "vector-tiled" else 128,
        interpret=True, mode="dma" if engine == "dma" else "vector",
    )
    return nx[0], ny[0]


@pytest.mark.parametrize(
    "engine", ["vector", "vector-tiled", "dma"]
)
def test_property_masked_cells_are_fixed_points(engine):
    """Ghost cells AND dynamically forgotten cells are structural fixed
    points of the fused pass, on every engine (extends the ghost parity
    test above to Project-and-Forget's runtime masks, DESIGN.md §13):

      * masked cells contribute ZERO delta to X — garbage duals parked
        on masked cells (ghost, padding, or forgotten) must not change
        the X output by a single bit;
      * the engine agrees bitwise with the jnp reference under the same
        dynamic mask;
      * ghost rows/columns of the padded iterate stay exactly 0.0.
    """
    from repro.serve.buckets import pad_problem

    n, npad = 10, 13
    p = pad_problem(_l2_problem(n, seed=7), npad)
    solver = ParallelSolver(p, bucket_diagonals=2, n_real=n)
    st = solver.run(passes=2)  # non-zero duals, non-trivial iterate
    rng = np.random.default_rng(42)
    x_ref = x_eng = st.x
    for b, yb in zip(solver._buckets, st.yd):
        act = np.asarray(b["act"])
        # dynamic mask: forget ~40% of the (ghost-masked) active cells
        am = jnp.asarray(act & (rng.random(act.shape) < 0.6))
        y_clean = jnp.where(am[:, None], yb, 0.0)
        y_dirty = jnp.where(am[:, None], yb, 777.0)  # masked-cell garbage
        rx, ry = _engine_bucket_pass("ref", x_ref, y_clean, b, am)
        rx_d, _ = _engine_bucket_pass("ref", x_ref, y_dirty, b, am)
        np.testing.assert_array_equal(np.asarray(rx), np.asarray(rx_d))
        ex, ey = _engine_bucket_pass(engine, x_eng, y_clean, b, am)
        ex_d, _ = _engine_bucket_pass(engine, x_eng, y_dirty, b, am)
        np.testing.assert_array_equal(np.asarray(ex), np.asarray(ex_d))
        np.testing.assert_array_equal(np.asarray(rx), np.asarray(ex))
        # active dual cells agree across engines (masked are don't-care)
        amn = np.asarray(am)
        np.testing.assert_array_equal(
            np.asarray(ry)[amn[:, None] & np.ones((1, 3, 1, 1), bool)],
            np.asarray(ey)[amn[:, None] & np.ones((1, 3, 1, 1), bool)],
        )
        x_ref, x_eng = rx, ex
    ghost = np.zeros((npad, npad), bool)
    ghost[n:, :] = True
    ghost[:, n:] = True
    assert np.all(np.asarray(x_eng)[ghost] == 0.0)

"""Continuous-batching serve loop (DESIGN.md §12): background dispatch
futures, slot-level refill parity with drain mode (bitwise, randomized
arrival order), per-slot fault isolation under injected chaos, and the
new scheduler telemetry (occupancy, queue high-water marks, refill and
chunk counters)."""

import time

import jax
import numpy as np
import pytest

from repro.core.parallel_dykstra import ParallelSolver
from repro.core import problems
from repro.graphs import generators, jaccard
from repro.serve import buckets as bk
from repro.serve.faults import FaultInjector, FaultPlan
from repro.serve.scheduler import BatchScheduler, ServeFuture


@pytest.fixture()
def x64():
    old = jax.config.jax_enable_x64
    jax.config.update("jax_enable_x64", True)
    yield
    jax.config.update("jax_enable_x64", old)


def _cc_problem(n, seed=0, eps=0.05):
    adj, _ = generators.planted_partition(n, seed=seed)
    dissim, w = jaccard.signed_instance(adj)
    return problems.correlation_clustering_lp(dissim, w, eps=eps)


KW = dict(tol=1e-3, max_passes=40, check_every=5)


def _run_stream(mode, probs, **extra):
    sch = BatchScheduler(ladder=(12,), batch=3, dtype=np.float64,
                         mode=mode, **KW, **extra)
    for i, p in enumerate(probs):
        sch.submit(p, tag=i)
    res = sch.drain()
    stats = sch.stats()
    sch.close()
    return res, stats


# ------------------------------------------------------- refill parity
def test_continuous_matches_drain_randomized_arrivals(x64):
    """Continuous mode re-batches the SAME per-instance trajectories the
    drain-mode batches run (per-slot freeze at chunk boundaries, refill
    with the drain-mode init expression): every instance of a shuffled
    mixed-n stream must land bitwise equal — iterate, stop pass,
    convergence flag — to its drain-mode result."""
    sizes = [9, 12, 10, 11, 8, 12, 10]
    rng = np.random.default_rng(3)
    order = rng.permutation(len(sizes))
    probs = [_cc_problem(sizes[i], seed=int(i)) for i in order]

    drain, _ = _run_stream("drain", probs)
    cont, stats = _run_stream("continuous", probs)

    assert set(drain) == set(cont) == set(range(len(probs)))
    for i in range(len(probs)):
        rd, rc = drain[i], cont[i]
        assert rc["route"] == "batch"
        assert rc["passes"] == rd["passes"], f"instance {i}"
        assert rc["converged"] == rd["converged"]
        np.testing.assert_array_equal(rc["x_pad"], rd["x_pad"])
        np.testing.assert_array_equal(rc["x"], rd["x"])
    # telemetry of the continuous run: every instance was a refill, the
    # worker stepped at least one chunk, occupancy is a real fraction
    assert stats["mode"] == "continuous"
    assert stats["refills"] == len(probs)
    assert stats["chunks_run"] > 0
    assert 0.0 < stats["occupancy"] <= 1.0
    assert stats["queue_depth_hwm"][12] >= 1


def test_continuous_matches_solo(x64):
    """One instance through the continuous scheduler == its standalone
    padded run_until solve to the §8 batched-vs-solo pin (1e-10 — the
    vmapped engine differs from the solo driver in last-ulp rounding;
    the *bitwise* contract is continuous-vs-drain, tested above)."""
    p = _cc_problem(9, seed=5)
    sch = BatchScheduler(ladder=(12,), batch=2, dtype=np.float64,
                         mode="continuous", **KW)
    fut = sch.submit(p, tag="only")
    out = fut.result(timeout=300)
    sch.close()
    solo = ParallelSolver(bk.pad_problem(p, 12), dtype=np.float64,
                          bucket_diagonals=6, n_real=p.n)
    sst, sinfo = solo.run_until(**KW)
    assert out["passes"] == sinfo["passes"]
    assert np.abs(out["x_pad"] - np.asarray(sst.x)).max() <= 1e-10


# -------------------------------------------------- per-slot fault blast
def test_continuous_fault_isolates_slot(x64):
    """A persistent nan_poison on one tag dead-letters exactly that
    request (divergence guard, error="diverged") while its co-resident
    slots land bitwise equal to a fault-free run — mid-flight isolation,
    no bisection, and every submitted request reaches exactly one
    terminal result."""
    probs = [_cc_problem(n, seed=s) for n, s in
             [(10, 0), (12, 1), (9, 2), (11, 3)]]
    clean, _ = _run_stream("continuous", probs)

    inj = FaultInjector(FaultPlan.parse("nan_poison@dispatch:0:tag=1"))
    sch = BatchScheduler(ladder=(12,), batch=3, dtype=np.float64,
                         mode="continuous", faults=inj, **KW)
    for i, p in enumerate(probs):
        sch.submit(p, tag=i)
    res = sch.drain()
    stats = sch.stats()
    sch.close()

    assert set(res) == set(range(len(probs)))  # exactly-one-terminal
    bad = res[1]
    assert bad["route"] == "failed" and bad["error"] == "diverged"
    assert any(spec.kind == "nan_poison" for _, _, spec in inj.fired)
    for i in (0, 2, 3):
        assert res[i]["route"] == "batch"
        assert res[i]["passes"] == clean[i]["passes"]
        np.testing.assert_array_equal(res[i]["x_pad"], clean[i]["x_pad"])
    assert stats["faults"]["dead_letters"] == 1


def test_continuous_transient_dispatch_error_heals(x64):
    """A one-shot injected dispatch_error at admission retries and heals:
    the request still lands normally (admission is the per-request retry
    unit in continuous mode)."""
    inj = FaultInjector(FaultPlan.parse("dispatch_error@dispatch:0"))
    sch = BatchScheduler(ladder=(12,), batch=2, dtype=np.float64,
                         mode="continuous", faults=inj, **KW)
    fut = sch.submit(_cc_problem(10, seed=4), tag="t")
    out = fut.result(timeout=300)
    sch.close()
    assert out["route"] == "batch"
    assert ("dispatch", 0, "dispatch_error") in inj.log()
    assert inj.count("dispatch") >= 2  # the retry re-polled the site


# ------------------------------------------------------ futures / async
def test_submit_returns_future_immediately(x64):
    """submit() hands back a ServeFuture without waiting on any solve —
    including the above-ladder sharded route, which used to block the
    caller for the whole solve."""
    sch = BatchScheduler(ladder=(12,), batch=2, dtype=np.float64,
                         tol=1e-3, max_passes=8, check_every=4)
    t0 = time.perf_counter()
    fut = sch.submit(_cc_problem(16, seed=7), tag="big")  # above ladder
    submit_s = time.perf_counter() - t0
    assert isinstance(fut, ServeFuture)
    assert submit_s < 1.0  # the sharded solve alone takes much longer
    out = fut.result(timeout=600)
    assert out["route"] == "sharded" and fut.done()
    assert sch.stats()["sharded_done"] == 1
    sch.close()


def test_future_tag_compat_and_duplicates(x64):
    """The future is a drop-in for the tag submit() used to return: it
    compares and hashes as the tag, indexes results(), and a duplicate
    in-flight tag still raises at submit."""
    sch = BatchScheduler(ladder=(12,), batch=2, dtype=np.float64, **KW)
    fut = sch.submit(_cc_problem(9, seed=0), tag="a")
    assert fut == "a" and hash(fut) == hash("a")
    assert fut in {"a"}
    with pytest.raises(ValueError):
        sch.submit(_cc_problem(9, seed=1), tag="a")
    assert sch.future("a") is fut
    fut2 = sch.submit(_cc_problem(10, seed=1), tag="b")
    res = sch.results()
    assert fut.done() and fut2.done()
    assert res[fut]["passes"] == fut.result()["passes"]
    with pytest.raises(TimeoutError):
        ServeFuture("never").result(timeout=0.01)
    sch.close()


# ------------------------------------------------------------ telemetry
def test_drain_stats_new_fields(x64):
    """Drain mode reports the new telemetry too: queue-depth high-water
    marks per bucket, zero refills/chunks (whole-batch dispatch), and the
    classic slots-run occupancy."""
    sch = BatchScheduler(ladder=(12, 16), batch=2, dtype=np.float64, **KW)
    for i, n in enumerate([9, 12, 14]):
        sch.submit(_cc_problem(n, seed=i), tag=i)
    sch.drain()
    stats = sch.stats()
    sch.close()
    assert stats["mode"] == "drain"
    assert stats["refills"] == 0 and stats["chunks_run"] == 0
    assert stats["queue_depth_hwm"][12] == 2
    assert stats["queue_depth_hwm"][16] == 1
    assert stats["instances_done"] == 3

"""System-level invariants (hypothesis property tests).

These are the paper's mathematical guarantees, checked as executable
properties of the implementation rather than single examples.
"""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # tier-1 containers lack hypothesis; @given tests skip
    from conftest import given, settings, st

from repro.core import convergence, dykstra, problems
from repro.core.parallel_dykstra import ParallelSolver


def _metric_matrix(n, rng):
    """A guaranteed-metric distance matrix: shortest paths of a random
    positive graph (metric closure)."""
    w = rng.uniform(0.2, 1.0, (n, n))
    w = np.minimum(w, w.T)
    np.fill_diagonal(w, 0.0)
    # Floyd–Warshall
    d = w.copy()
    for k in range(n):
        d = np.minimum(d, d[:, k][:, None] + d[k, :][None, :])
    return np.triu(d, 1)


@given(n=st.integers(4, 12), seed=st.integers(0, 10**6))
@settings(max_examples=15, deadline=None)
def test_metric_input_is_fixed_point(n, seed):
    """If D already satisfies all triangle inequalities, the l2-nearness
    solution is D itself and one pass changes nothing (all θ = 0)."""
    rng = np.random.default_rng(seed)
    d = _metric_matrix(n, rng)
    p = problems.metric_nearness_l2(d)
    assert convergence.max_violation(p, d) <= 1e-9
    st_ = ParallelSolver(p).run(passes=1)
    np.testing.assert_allclose(np.asarray(st_.x), d, rtol=1e-5, atol=1e-6)
    # schedule-native dual slabs: every dual must stay (near) zero
    assert max(float(np.abs(np.asarray(y)).max()) for y in st_.yd) <= 1e-6


@given(n=st.integers(4, 10), seed=st.integers(0, 10**6))
@settings(max_examples=10, deadline=None)
def test_duals_nonnegative_and_violation_decreases(n, seed):
    rng = np.random.default_rng(seed)
    d = np.triu((rng.uniform(0, 1, (n, n)) > 0.5).astype(float), k=1)
    p = problems.metric_nearness_l2(d)
    solver = ParallelSolver(p)
    st1 = solver.run(passes=2)
    st2 = solver.run(st1, passes=20)
    assert min(float(np.asarray(y).min()) for y in st2.yd) >= -1e-6  # θ ≥ 0
    v1 = convergence.max_violation(p, np.asarray(st1.x, np.float64))
    v2 = convergence.max_violation(p, np.asarray(st2.x, np.float64))
    assert v2 <= v1 + 1e-6


@given(seed=st.integers(0, 10**6))
@settings(max_examples=8, deadline=None)
def test_dykstra_invariant_x_equals_x0_minus_duals(seed):
    """Dykstra maintains x = x0 − (1/ε)W⁻¹Aᵀy exactly (the relation behind
    the cheap duality gap; DESIGN.md §2) — reconstruct x from the duals."""
    n = 8
    rng = np.random.default_rng(seed)
    d = np.triu(rng.uniform(0, 1, (n, n)), k=1)
    p = problems.metric_nearness_l2(d)
    st_ = dykstra.solve_serial(p, max_passes=3, order="schedule")
    # rebuild: x = d + (1/(eps w)) Σ_constraints y_i * (∓a_i)
    x_rec = p.x0().copy()
    for a in range(n):
        for b in range(a + 1, n):
            for c in range(n):
                if c in (a, b):
                    continue
                y = st_.ytri[a, b, c]
                if y == 0.0:
                    continue
                ac = (min(a, c), max(a, c))
                bc = (min(b, c), max(b, c))
                x_rec[a, b] -= y / (p.eps * p.w[a, b])
                x_rec[ac] += y / (p.eps * p.w[ac])
                x_rec[bc] += y / (p.eps * p.w[bc])
    np.testing.assert_allclose(x_rec, st_.x, rtol=1e-8, atol=1e-10)


@given(n=st.integers(4, 9), seed=st.integers(0, 10**6), passes=st.integers(1, 4))
@settings(max_examples=8, deadline=None)
def test_parallel_equals_serial_property(n, seed, passes):
    """Property form of the §III.A theorem: the conflict-free reordering
    never changes the iterate, for any instance and pass count."""
    rng = np.random.default_rng(seed)
    d = np.triu(rng.uniform(0, 1, (n, n)), k=1)
    p = problems.metric_nearness_l2(d)
    st_ser = dykstra.solve_serial(p, max_passes=passes, order="schedule")
    st_par = ParallelSolver(p).run(passes=passes)
    np.testing.assert_allclose(np.asarray(st_par.x), st_ser.x,
                               rtol=3e-4, atol=3e-5)


def test_solution_symmetric_under_relabeling():
    """Permuting the points permutes the solution (schedule introduces no
    labeling bias in the fixed point)."""
    n = 9
    rng = np.random.default_rng(3)
    dfull = rng.uniform(0, 1, (n, n))
    dfull = np.triu(dfull, 1) + np.triu(dfull, 1).T
    perm = rng.permutation(n)

    def solve(dm):
        p = problems.metric_nearness_l2(np.triu(dm, 1))
        stx = ParallelSolver(p).run(passes=300)
        x = np.asarray(stx.x, np.float64)
        return np.triu(x, 1) + np.triu(x, 1).T

    x1 = solve(dfull)
    x2 = solve(dfull[np.ix_(perm, perm)])
    np.testing.assert_allclose(x2, x1[np.ix_(perm, perm)], atol=2e-3)

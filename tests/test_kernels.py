"""Per-kernel validation: Pallas (interpret mode) vs pure-jnp ref oracle,
swept over shapes, dtypes, and block sizes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # tier-1 containers lack hypothesis; @given tests skip
    from conftest import given, settings, st

from repro.kernels.metric_project import ops, ref
from repro.kernels.metric_project.metric_project import sweep_pallas


def _inputs(T, C, dtype, seed=0, weighted=True):
    rng = np.random.default_rng(seed)
    mk = lambda *s: jnp.asarray(rng.uniform(0.0, 1.0, s), dtype)
    rowb, colb = mk(T, C), mk(T, C)
    xik = mk(C)
    y0, y1, y2 = mk(T, C), mk(T, C), mk(T, C)
    if weighted:
        w = lambda *s: jnp.asarray(rng.uniform(0.5, 2.0, s), dtype)
    else:
        w = lambda *s: jnp.ones(s, dtype)
    w_row, w_col, w_ik = w(T, C), w(T, C), w(C)
    sizes = rng.integers(0, T + 1, size=(C,))
    active = jnp.asarray(np.arange(T)[:, None] < sizes[None, :])
    return rowb, colb, xik, y0, y1, y2, w_row, w_col, w_ik, active


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("T,C", [(1, 1), (4, 3), (16, 128), (33, 200), (128, 7)])
def test_pallas_matches_ref(T, C, dtype):
    args = _inputs(T, C, dtype, seed=T * 1000 + C)
    eps = 0.7
    out_ref = ref.sweep_ref(*args, eps)
    out_pal = sweep_pallas(*args, eps, block_c=128, interpret=True)
    tol = 1e-6 if dtype == jnp.float32 else 3e-2
    for a, b in zip(out_ref, out_pal):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32),
            rtol=tol, atol=tol,
        )


@pytest.mark.parametrize("block_c", [8, 32, 128, 256])
def test_block_size_invariance(block_c):
    """Fig. 7 analogue: tile size must not change results, only speed."""
    args = _inputs(12, 130, jnp.float32, seed=9)
    out_ref = ref.sweep_ref(*args, 1.0)
    out_pal = sweep_pallas(*args, 1.0, block_c=block_c, interpret=True)
    for a, b in zip(out_ref, out_pal):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6, atol=1e-6)


@given(
    T=st.integers(1, 24),
    C=st.integers(1, 40),
    seed=st.integers(0, 2**16),
)
@settings(max_examples=25, deadline=None)
def test_property_sweep_invariants(T, C, seed):
    """Invariants of one sweep: duals nonnegative; masked lanes untouched;
    visited triplets satisfy their three constraints post-visit iff the last
    projection left them feasible (theta2 complementary slackness)."""
    args = _inputs(T, C, jnp.float32, seed=seed)
    rowb, colb, xik, y0, y1, y2, w_row, w_col, w_ik, active = args
    nrow, ncol, nxik, n0, n1, n2 = ref.sweep_ref(*args, 1.0)
    act = np.asarray(active)
    for arr in (n0, n1, n2):
        assert np.all(np.asarray(arr)[act] >= -1e-6)
    # untouched where inactive
    np.testing.assert_array_equal(np.asarray(nrow)[~act], np.asarray(rowb)[~act])
    np.testing.assert_array_equal(np.asarray(ncol)[~act], np.asarray(colb)[~act])
    np.testing.assert_array_equal(np.asarray(n0)[~act], np.asarray(y0)[~act])
    # lanes with no active steps keep xik
    no_act = ~act.any(axis=0)
    np.testing.assert_array_equal(np.asarray(nxik)[no_act], np.asarray(xik)[no_act])


def test_ops_wrapper_jits():
    args = _inputs(8, 64, jnp.float32, seed=3)
    out = ops.diagonal_sweep(*args, 0.5)
    ref_out = ref.sweep_ref(*args, 0.5)
    for a, b in zip(out, ref_out):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6, atol=1e-6)


def test_solver_with_kernel_matches_solver_with_ref():
    from repro.core import problems
    from repro.core.parallel_dykstra import ParallelSolver

    rng = np.random.default_rng(0)
    n = 12
    d = np.triu(rng.uniform(0, 1, (n, n)), k=1)
    p = problems.metric_nearness_l2(d)
    a = ParallelSolver(p, use_kernel=False).run(passes=2)
    b = ParallelSolver(p, use_kernel=True).run(passes=2)
    np.testing.assert_allclose(np.asarray(a.x), np.asarray(b.x), rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# pair/box projection kernel
# ---------------------------------------------------------------------------

from repro.kernels.pair_project import ops as pair_ops
from repro.kernels.pair_project import ref as pair_ref
from repro.kernels.pair_project.pair_project import pair_box_pallas


def _pair_inputs(n0, n1, dtype, seed=0):
    rng = np.random.default_rng(seed)
    mk = lambda lo, hi: jnp.asarray(rng.uniform(lo, hi, (n0, n1)), dtype)
    mask = jnp.asarray(np.triu(np.ones((n0, n1), bool), k=1))
    return (mk(0, 1), mk(0, 1), mk(0, 1), mk(0.5, 2), mk(0.5, 2),
            mk(0, 0.2), mk(0, 0.2), mk(0, 0.2), mk(0, 0.2), mask)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("n0,n1", [(5, 5), (64, 64), (100, 130)])
@pytest.mark.parametrize("has_box", [True, False])
def test_pair_box_kernel_matches_ref(n0, n1, dtype, has_box):
    args = _pair_inputs(n0, n1, dtype, seed=n0 + n1)
    eps = 0.3
    out_ref = pair_ref.pair_box_ref(*args, eps, 0.0, 1.0, has_box)
    out_pal = pair_box_pallas(*args, eps, 0.0, 1.0, has_box,
                              block=(32, 64), interpret=True)
    tol = 1e-6 if dtype == jnp.float32 else 3e-2
    for a, b in zip(out_ref, out_pal):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=tol, atol=tol)


def test_pair_box_kernel_matches_solver_pair_step():
    """The fused kernel must reproduce the solver's unfused pair+box step."""
    from repro.core import problems
    from repro.core.parallel_dykstra import ParallelSolver

    rng = np.random.default_rng(1)
    n = 12
    dis = np.triu((rng.uniform(0, 1, (n, n)) > 0.5).astype(float), k=1)
    p = problems.correlation_clustering_lp(dis, eps=0.05)
    solver = ParallelSolver(p)
    st = solver.run(passes=1)

    x = jnp.asarray(st.x)
    f = jnp.asarray(st.f)
    mask = jnp.asarray(np.triu(np.ones((n, n), bool), 1))
    # unfused (solver internals)
    x2, f2, ypair = solver._pair_step(x, f, st.ypair)
    x3, ybox = solver._box_step(x2, st.ybox)
    # fused kernel
    out = pair_ops.pair_box_project(
        x, f, jnp.asarray(p.d, jnp.float32), jnp.asarray(p.w, jnp.float32),
        jnp.asarray(p.w_f, jnp.float32), st.ypair[0], st.ypair[1],
        st.ybox[0], st.ybox[1], mask, p.eps, 0.0, 1.0, True,
    )
    m = np.asarray(mask)
    np.testing.assert_allclose(np.asarray(out[0])[m], np.asarray(x3)[m],
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(out[1])[m], np.asarray(f2)[m],
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(out[2])[m], np.asarray(ypair[0])[m],
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(out[4])[m], np.asarray(ybox[0])[m],
                               rtol=1e-5, atol=1e-6)

import os
import sys

import pytest

# Make `import repro` work regardless of how pytest is invoked.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


# ---------------------------------------------------------------------------
# hypothesis fallback: property tests skip individually when hypothesis is
# not installed (tier-1 containers), while every plain test in the same
# module still runs. Test modules use:
#
#     try:
#         from hypothesis import given, settings, strategies as st
#     except ImportError:
#         from conftest import given, settings, st
# ---------------------------------------------------------------------------


class _SkipStrategies:
    """Stand-in for ``hypothesis.strategies``: any strategy constructor
    returns None (only ever passed to the stub ``given`` below)."""

    def __getattr__(self, name):
        return lambda *a, **k: None


st = _SkipStrategies()


def settings(*_a, **_k):
    return lambda f: f


def given(*_a, **_k):
    def deco(f):
        # zero-arg replacement: no fixture resolution, just a clean skip
        def skipper():
            pytest.skip("hypothesis not installed")

        skipper.__name__ = f.__name__
        skipper.__doc__ = f.__doc__
        return skipper

    return deco

"""Schedule correctness: coverage, conflict-freedom, load balance."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import schedule as sched


@pytest.mark.parametrize("n", [3, 4, 5, 8, 13, 20])
def test_enumeration_covers_T_exactly_once(n):
    trips = sched.enumerate_triplets(n)
    assert trips.shape == (sched.n_triplets(n), 3)
    seen = set(map(tuple, trips.tolist()))
    expect = {
        (i, j, k)
        for i in range(n)
        for j in range(i + 1, n)
        for k in range(j + 1, n)
    }
    assert seen == expect
    assert len(trips) == len(seen)  # no duplicates


@pytest.mark.parametrize("n", [5, 9, 14, 24])
def test_diagonals_are_conflict_free(n):
    for d in sched.diagonal_list(n):
        assert sched.validate_conflict_free(d), (d.i, d.k)


@given(st.integers(min_value=3, max_value=40))
@settings(max_examples=20, deadline=None)
def test_property_conflict_free_and_partition(n):
    diags = sched.diagonal_list(n)
    total = 0
    for d in diags:
        # Within a diagonal, (i, k) pairs are distinct and i+k is constant.
        s = d.i + d.k
        assert np.all(s == s[0])
        assert len(set(d.i.tolist())) == d.num_sets
        assert np.all(d.k >= d.i + 2)
        total += d.num_triplets
    assert total == sched.n_triplets(n)


@given(st.integers(min_value=3, max_value=28))
@settings(max_examples=15, deadline=None)
def test_property_two_triplets_share_le_one_index(n):
    rng = np.random.default_rng(n)
    for d in sched.diagonal_list(n):
        if d.num_sets < 2:
            continue
        # sample pairs of sets rather than all (keeps the property test fast)
        for _ in range(10):
            a, b = rng.choice(d.num_sets, size=2, replace=False)
            ia, ka = int(d.i[a]), int(d.k[a])
            ib, kb = int(d.i[b]), int(d.k[b])
            ja = rng.integers(ia + 1, ka)
            jb = rng.integers(ib + 1, kb)
            assert len({ia, ja, ka} & {ib, jb, kb}) <= 1


def test_padded_schedule_consistent():
    n = 17
    s = sched.build_schedule(n)
    assert s.num_diagonals == len(sched.diagonal_list(n))
    # masked entries are -1; active ones satisfy k >= i+2
    m = s.set_mask
    assert np.all(s.diag_i[~m] == -1)
    assert np.all(s.diag_k[m] >= s.diag_i[m] + 2)
    # padding to lane multiples
    s128 = sched.build_schedule(n, pad_sets_to=8)
    assert s128.max_sets % 8 == 0


def test_device_assignment_balance():
    # paper Fig. 3: r mod p keeps per-processor triplet counts balanced
    n, p = 200, 16
    d = max(sched.diagonal_list(n), key=lambda d: d.num_sets)
    asg = sched.device_assignment(d.num_sets, p)
    loads = np.zeros(p)
    for r, sz in zip(asg, d.sizes):
        loads[r] += sz
    assert loads.max() <= 1.5 * max(loads.mean(), 1.0)

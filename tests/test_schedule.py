"""Schedule correctness: coverage, conflict-freedom, load balance."""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # tier-1 containers lack hypothesis; @given tests skip
    from conftest import given, settings, st

from repro.core import schedule as sched


@pytest.mark.parametrize("n", [3, 4, 5, 8, 13, 20])
def test_enumeration_covers_T_exactly_once(n):
    trips = sched.enumerate_triplets(n)
    assert trips.shape == (sched.n_triplets(n), 3)
    seen = set(map(tuple, trips.tolist()))
    expect = {
        (i, j, k)
        for i in range(n)
        for j in range(i + 1, n)
        for k in range(j + 1, n)
    }
    assert seen == expect
    assert len(trips) == len(seen)  # no duplicates


@pytest.mark.parametrize("n", [5, 9, 14, 24])
def test_diagonals_are_conflict_free(n):
    for d in sched.diagonal_list(n):
        assert sched.validate_conflict_free(d), (d.i, d.k)


@given(st.integers(min_value=3, max_value=40))
@settings(max_examples=20, deadline=None)
def test_property_conflict_free_and_partition(n):
    diags = sched.diagonal_list(n)
    total = 0
    for d in diags:
        # Within a diagonal, (i, k) pairs are distinct and i+k is constant.
        s = d.i + d.k
        assert np.all(s == s[0])
        assert len(set(d.i.tolist())) == d.num_sets
        assert np.all(d.k >= d.i + 2)
        total += d.num_triplets
    assert total == sched.n_triplets(n)


@given(st.integers(min_value=3, max_value=28))
@settings(max_examples=15, deadline=None)
def test_property_two_triplets_share_le_one_index(n):
    rng = np.random.default_rng(n)
    for d in sched.diagonal_list(n):
        if d.num_sets < 2:
            continue
        # sample pairs of sets rather than all (keeps the property test fast)
        for _ in range(10):
            a, b = rng.choice(d.num_sets, size=2, replace=False)
            ia, ka = int(d.i[a]), int(d.k[a])
            ib, kb = int(d.i[b]), int(d.k[b])
            ja = rng.integers(ia + 1, ka)
            jb = rng.integers(ib + 1, kb)
            assert len({ia, ja, ka} & {ib, jb, kb}) <= 1


def test_padded_schedule_consistent():
    n = 17
    s = sched.build_schedule(n)
    assert s.num_diagonals == len(sched.diagonal_list(n))
    # masked entries are -1; active ones satisfy k >= i+2
    m = s.set_mask
    assert np.all(s.diag_i[~m] == -1)
    assert np.all(s.diag_k[m] >= s.diag_i[m] + 2)
    # padding to lane multiples
    s128 = sched.build_schedule(n, pad_sets_to=8)
    assert s128.max_sets % 8 == 0


def test_device_assignment_balance():
    # paper Fig. 3: r mod p keeps per-processor triplet counts balanced
    n, p = 200, 16
    d = max(sched.diagonal_list(n), key=lambda d: d.num_sets)
    asg = sched.device_assignment(d.num_sets, p)
    loads = np.zeros(p)
    for r, sz in zip(asg, d.sizes):
        loads[r] += sz
    assert loads.max() <= 1.5 * max(loads.mean(), 1.0)


# ------------------------------------------------- schedule-native layout
@pytest.mark.parametrize("n,nb,procs", [(5, 1, 1), (8, 3, 1), (13, 4, 2), (16, 2, 3)])
def test_layout_covers_all_duals_once(n, nb, procs):
    """Every triplet contributes exactly 3 duals; the layout's conversion
    maps must cover each dense slot exactly once, with no slab collisions."""
    lay = sched.build_layout(n, num_buckets=nb, procs=procs)
    assert lay.num_duals == 3 * sched.n_triplets(n)
    seen_dense = set()
    for bl in lay.buckets:
        # no two duals share a slab slot
        assert len(np.unique(bl.slab_index)) == bl.num_duals
        a, b, c = bl.dense_index
        seen_dense.update(zip(a.tolist(), b.tolist(), c.tolist()))
    expect = set()
    for (i, j, k) in sched.enumerate_triplets(n):
        expect.update({(i, j, k), (i, k, j), (j, k, i)})
    assert seen_dense == expect


@given(n=st.integers(3, 20), nb=st.integers(1, 5), procs=st.integers(1, 4))
@settings(max_examples=20, deadline=None)
def test_property_layout_roundtrip(n, nb, procs):
    """dense → slabs → dense is the identity on the support of real duals."""
    lay = sched.build_layout(n, num_buckets=nb, procs=procs)
    rng = np.random.default_rng(n * 100 + nb * 10 + procs)
    ytri = np.zeros((n, n, n))
    for (i, j, k) in sched.enumerate_triplets(n):
        ytri[i, j, k], ytri[i, k, j], ytri[j, k, i] = rng.uniform(size=3)
    slabs = sched.dense_to_duals(lay, ytri, np.float64)
    np.testing.assert_array_equal(sched.duals_to_dense(lay, slabs), ytri)


def test_layout_matches_device_assignment():
    """Folded-lane placement follows the paper's Fig. 3 r mod p rule: lane f
    of a diagonal holds sets (f, C-1-f) and goes to device f mod p."""
    n, p = 14, 3
    lay = sched.build_layout(n, num_buckets=1, procs=p)
    diags = sched.diagonal_list(n)
    bl = lay.buckets[0]
    for r, d in enumerate(diags):
        C = d.num_sets
        for f in range((C + 1) // 2):
            dev, slot = f % p, f // p
            assert bl.i[dev, r, slot] == d.i[f]
            assert bl.k[dev, r, slot] == d.k[f]
            assert bl.sizes[dev, r, slot] == d.k[f] - d.i[f] - 1
            cB = C - 1 - f
            if cB > f:
                assert bl.i2[dev, r, slot] == d.i[cB]
                assert bl.k2[dev, r, slot] == d.k[cB]
            else:
                assert bl.i2[dev, r, slot] == -1
                assert bl.sizes2[dev, r, slot] == 0


def test_layout_folded_lanes_have_uniform_height():
    """Folding pairs set f with set C-1-f, whose sizes sum to a constant —
    all *paired* lanes of a diagonal have exactly equal height (the odd
    middle set rides alone at no more than that height)."""
    n = 23
    lay = sched.build_layout(n, num_buckets=1, procs=1)
    bl = lay.buckets[0]
    heights = bl.sizes + bl.sizes2  # (1, D, Cl)
    for r in range(heights.shape[1]):
        lane = bl.i[0, r] >= 0
        paired = lane & (bl.i2[0, r] >= 0)
        h = heights[0, r]
        if paired.any():
            assert h[paired].max() == h[paired].min(), (r, h)
            assert h[lane].max() == h[paired].max()


def test_layout_memory_is_3_choose_n3_plus_padding():
    """The whole point: folded slab memory tracks 3·C(n,3) (padding factor
    < 1.7 at modest bucket counts), well under the dense n^3 tensor."""
    n = 40
    lay = sched.build_layout(n, num_buckets=8, procs=1)
    slab_floats = sum(bl.slab_size for bl in lay.buckets)
    real = 3 * sched.n_triplets(n)
    assert slab_floats >= real  # covers every dual
    assert slab_floats <= 1.7 * real  # bounded padding
    assert slab_floats < n ** 3  # strictly under the dense tensor

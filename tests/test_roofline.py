"""Roofline machinery: HLO collective parsing with trip-count correction,
analytic accounting sanity, elastic remesh plans."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.shapes import SHAPES
from repro import configs
from repro.launch import elastic
from repro.roofline import accounting, hlo_parse

HLO_SAMPLE = """
HloModule test

%cond (p: (s32[], f32[8])) -> pred[] {
  %p = (s32[], f32[8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %c = s32[] constant(12)
  ROOT %lt = pred[] compare(%i, %c), direction=LT
}

%body (p: (s32[], f32[8])) -> (s32[], f32[8]) {
  %p = (s32[], f32[8]) parameter(0)
  %x = f32[8] get-tuple-element(%p), index=1
  %ar = f32[8]{0} all-reduce(%x), channel_id=1, replica_groups=[2,4]<=[8], to_apply=%add
  %i = s32[] get-tuple-element(%p), index=0
  ROOT %t = (s32[], f32[8]) tuple(%i, %ar)
}

ENTRY %main (a: f32[16]) -> f32[16] {
  %a = f32[16] parameter(0)
  %ag = f32[16]{0} all-gather(%a), channel_id=2, replica_groups=[1,8]<=[8], dimensions={0}
  %w = (s32[], f32[8]) while(%t0), condition=%cond, body=%body
  ROOT %out = f32[16] add(%ag, %ag)
}
"""


def test_collective_parse_trip_counts():
    out = hlo_parse.collective_bytes(HLO_SAMPLE)
    # all-gather: 16 f32 = 64 B (entry, ×1); all-reduce: 8 f32 = 32 B × 12 trips
    assert out["all-gather"] == 64
    assert out["all-reduce"] == 32 * 12
    assert out["total"] == 64 + 384


def test_collective_parse_real_module():
    """Parse a real sharded compile and sanity-check order of magnitude."""
    import os
    from repro.launch import mesh as mesh_lib
    from repro.models import common
    from repro.models.model import build_model
    from jax.sharding import NamedSharding, PartitionSpec as P

    if len(jax.devices()) < 1:
        pytest.skip("no devices")
    cfg = configs.get_smoke_config("olmo-1b").scaled(dtype=jnp.float32)
    lm = build_model(cfg)
    mesh = mesh_lib.make_host_mesh(1, 1)
    p = common.tree_shape_structs(lm.param_specs(), jnp.float32)
    batch = {"tokens": jax.ShapeDtypeStruct((2, 17), jnp.int32)}
    with mesh:
        comp = jax.jit(lambda pp, b: lm.loss(pp, b)).lower(p, batch).compile()
    out = hlo_parse.collective_bytes(comp.as_text())
    assert out["total"] >= 0  # single device → usually no collectives
    comps, entry = hlo_parse.parse_computations(comp.as_text())
    assert entry is not None and len(comps) > 3
    trips = hlo_parse.while_trips(comps)
    # the layer scan must be visible with the right trip count
    assert any(t[3] == cfg.n_layers for t in trips), trips


@pytest.mark.parametrize("arch", ["olmo-1b", "qwen2-moe-a2.7b", "falcon-mamba-7b"])
@pytest.mark.parametrize("shape", ["train_4k", "decode_32k"])
def test_accounting_positive_and_consistent(arch, shape):
    cfg = configs.get_config(arch)
    acct = accounting.cell_accounting(cfg, SHAPES[shape], chips=256)
    assert acct["analytic_flops_global"] > 0
    assert acct["analytic_hbm_bytes_per_device"] > 0
    assert acct["model_flops"] <= acct["analytic_flops_global"] * 1.01
    if cfg.moe:
        assert acct["active_params"] < acct["total_params"]


def test_accounting_moe_active_params():
    cfg = configs.get_config("qwen2-moe-a2.7b")
    acct = accounting.cell_accounting(cfg, SHAPES["train_4k"], chips=256)
    # A2.7B: ~2.7B activated of ~14.3B total
    assert 1.5e9 < acct["active_params"] < 4.5e9
    assert 1.2e10 < acct["total_params"] < 1.7e10


def test_remesh_plan_handles_failures():
    plan = elastic.remesh_plan(512, 512 - 16)  # lost a 16-chip slice
    assert plan.new_devices % plan.model == 0
    assert plan.pod * plan.data * plan.model == plan.new_devices
    with pytest.raises(ValueError):
        elastic.remesh_plan(512, 7)


def test_reshard_duals_exact():
    """Dual slabs re-sharded 1→3 devices must encode identical dense duals."""
    import numpy as np
    from repro.core import problems, schedule as sched
    from repro.core.sharded_dykstra import ShardedSolver
    from jax.sharding import Mesh

    n = 10
    rng = np.random.default_rng(0)
    d = np.triu(rng.uniform(0, 1, (n, n)), k=1)
    p = problems.metric_nearness_l2(d)
    mesh = Mesh(np.array(jax.devices()[:1]), ("solver",))
    solver = ShardedSolver(p, mesh, num_buckets=2)
    st = solver.run(passes=2)
    dense_before = solver.duals_to_dense(st)
    new_slabs, new_layout = elastic.reshard_duals(st.yd, n, 1, 3, 2)
    assert new_layout.procs == 3
    assert all(s.shape == bl.slab_shape
               for s, bl in zip(new_slabs, new_layout.buckets))
    # decode the new slabs back to dense via the target layout's maps
    dense_after = sched.duals_to_dense(new_layout, new_slabs)
    np.testing.assert_allclose(dense_after, dense_before, rtol=1e-6, atol=1e-7)

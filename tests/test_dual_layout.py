"""Schedule-native dual storage parity (DESIGN.md §3).

Runs >= 3 passes of ``ParallelSolver`` — with both the pure-jnp reference
sweep and the Pallas kernel sweep (interpret mode on CPU) — against the
serial ``dykstra.py`` oracle, asserting X and the converted duals agree to
1e-5. Run in float64 so tolerance reflects layout/ordering fidelity, not
float32 rounding.
"""

import jax
import numpy as np
import pytest

from repro.core import dykstra, problems, schedule as sched
from repro.core.parallel_dykstra import ParallelSolver

PASSES = 3


@pytest.fixture()
def x64():
    old = jax.config.jax_enable_x64
    jax.config.update("jax_enable_x64", True)
    yield
    jax.config.update("jax_enable_x64", old)


def _l2_problem(n, seed=0):
    rng = np.random.default_rng(seed)
    return problems.metric_nearness_l2(np.triu(rng.uniform(0, 1, (n, n)), k=1))


@pytest.mark.parametrize("use_kernel", [False, True],
                         ids=["ref-sweep", "pallas-interpret"])
@pytest.mark.parametrize("buckets", [1, 4])
def test_schedule_native_matches_serial_oracle(x64, use_kernel, buckets):
    n = 14
    p = _l2_problem(n, seed=3)
    st_ser = dykstra.solve_serial(p, max_passes=PASSES, order="schedule")
    solver = ParallelSolver(
        p, dtype=np.float64, use_kernel=use_kernel, bucket_diagonals=buckets
    )
    st = solver.run(passes=PASSES)
    np.testing.assert_allclose(np.asarray(st.x), st_ser.x, atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(
        solver.duals_to_dense(st), st_ser.ytri, atol=1e-5, rtol=1e-5
    )


def test_schedule_native_matches_oracle_cc_lp(x64):
    """Pair-constraint problem family (correlation-clustering LP)."""
    n = 11
    rng = np.random.default_rng(5)
    dis = np.triu((rng.uniform(0, 1, (n, n)) > 0.5).astype(float), k=1)
    p = problems.correlation_clustering_lp(dis, eps=0.05)
    st_ser = dykstra.solve_serial(p, max_passes=PASSES, order="schedule")
    solver = ParallelSolver(p, dtype=np.float64, bucket_diagonals=3)
    st = solver.run(passes=PASSES)
    np.testing.assert_allclose(np.asarray(st.x), st_ser.x, atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(st.f), st_ser.f, atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(
        solver.duals_to_dense(st), st_ser.ytri, atol=1e-5, rtol=1e-5
    )


def test_no_dense_dual_tensor_in_solver_state():
    """The acceptance criterion made executable: dual memory is the
    schedule-native slabs — no (n, n, n) array anywhere in solver state,
    and total slab size tracks 3·C(n,3), not n^3."""
    n = 24
    p = _l2_problem(n, seed=1)
    solver = ParallelSolver(p, bucket_diagonals=6)
    st = solver.run(passes=1)
    leaves = jax.tree_util.tree_leaves(st)
    assert all(leaf.ndim < 3 or leaf.shape.count(n) < 3 for leaf in leaves)
    assert not any(leaf.shape == (n, n, n) for leaf in leaves)
    slab_floats = sum(int(np.prod(y.shape)) for y in st.yd)
    assert slab_floats == sum(bl.slab_size for bl in solver.layout.buckets)
    assert slab_floats < n ** 3
    assert slab_floats >= 3 * sched.n_triplets(n)


def test_resume_from_dense_duals(x64):
    """dense_to_duals is a faithful inverse: loading the oracle's duals and
    continuing must track the oracle exactly."""
    n = 12
    p = _l2_problem(n, seed=7)
    st_ser = dykstra.solve_serial(p, max_passes=2, order="schedule")
    solver = ParallelSolver(p, dtype=np.float64, bucket_diagonals=2)
    st = solver.init_state()
    st.x = np.asarray(st_ser.x)
    st.yd = solver.dense_to_duals(st_ser.ytri)
    st = solver.run(st, passes=1)
    st_ser = dykstra.run_pass(p, st_ser, order="schedule")
    np.testing.assert_allclose(np.asarray(st.x), st_ser.x, atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(
        solver.duals_to_dense(st), st_ser.ytri, atol=1e-5, rtol=1e-5
    )

"""Device-resident convergence engine (DESIGN.md §7): float64 property
tests that the device metrics match the host numpy oracle
(core/convergence.py) to 1e-10 — with/without f, with/without box, jnp and
interpret-kernel probes, single-device and sharded — that ``run_until``
stops at exactly the pass the host-driven chunk loop would, and that the
direct slab→slab re-shard permutation equals the dense round-trip oracle."""

import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest
from jax.sharding import Mesh

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # tier-1 containers lack hypothesis; @given tests skip
    from conftest import given, settings, st

from repro.core import convergence, problems, schedule as sched
from repro.core.parallel_dykstra import ParallelSolver
from repro.core.sharded_dykstra import ShardedSolver
from repro.launch import elastic

TOL = 1e-10


@pytest.fixture()
def x64():
    old = jax.config.jax_enable_x64
    jax.config.update("jax_enable_x64", True)
    yield
    jax.config.update("jax_enable_x64", old)


def _problem(n, seed=0, kind="l2"):
    rng = np.random.default_rng(seed)
    d = np.triu(rng.uniform(0, 1, (n, n)), k=1)
    if kind == "l2":
        return problems.metric_nearness_l2(d)
    if kind == "l1":  # f, no box
        return problems.metric_nearness_l1(d, eps=0.05)
    return problems.correlation_clustering_lp((d > 0.5).astype(float), eps=0.05)


def _assert_reports_match(host: dict, dev: dict, tol=TOL):
    assert set(host) == set(dev)
    for k in host:
        assert abs(host[k] - dev[k]) <= tol + tol * abs(host[k]), (
            k, host[k], dev[k],
        )


# ----------------------------------------------- device metrics vs oracle
@pytest.mark.parametrize("kind", ["l2", "l1", "cc"])
@pytest.mark.parametrize("use_kernel", [False, True],
                         ids=["jnp-probe", "pallas-probe"])
def test_device_metrics_match_host_oracle(x64, kind, use_kernel):
    """Every scalar of the device report — objectives, duality gap, max
    violation, slab-native dual stats — must match convergence.report
    (fed by duals_to_dense) to 1e-10 in float64."""
    solver = ParallelSolver(
        _problem(14, seed=3, kind=kind), dtype=np.float64,
        use_kernel=use_kernel, bucket_diagonals=3,
    )
    st_ = solver.run(passes=3)
    _assert_reports_match(
        solver.metrics(st_, include_duals=True),
        solver.device_metrics(st_, include_duals=True),
    )


@pytest.mark.parametrize("kind", ["l2", "l1", "cc"])
def test_device_metrics_match_host_oracle_sharded(x64, kind):
    mesh = Mesh(np.array(jax.devices()[:1]), ("solver",))
    solver = ShardedSolver(
        _problem(12, seed=5, kind=kind), mesh, dtype=np.float64, num_buckets=2
    )
    st_ = solver.run(passes=3)
    _assert_reports_match(
        solver.metrics(st_, include_duals=True),
        solver.device_metrics(st_, include_duals=True),
    )


@given(n=st.integers(5, 18), seed=st.integers(0, 50))
@settings(max_examples=10, deadline=None)
def test_property_device_metrics_match_oracle(n, seed):
    """Random instances, random pass counts: device == host to 1e-10."""
    old = jax.config.jax_enable_x64
    jax.config.update("jax_enable_x64", True)
    try:
        kind = ["l2", "l1", "cc"][seed % 3]
        solver = ParallelSolver(
            _problem(n, seed=seed, kind=kind), dtype=np.float64,
            bucket_diagonals=1 + seed % 3,
        )
        st_ = solver.run(passes=1 + seed % 4)
        _assert_reports_match(
            solver.metrics(st_, include_duals=True),
            solver.device_metrics(st_, include_duals=True),
        )
    finally:
        jax.config.update("jax_enable_x64", old)


def test_device_metrics_fresh_state(x64):
    """Zero-pass state: duals all zero, violation from x0 alone — exercises
    the stats' empty/zero edge (min/max fold a 0 in like the dense form)."""
    solver = ParallelSolver(_problem(10, seed=1), dtype=np.float64)
    st_ = solver.init_state()
    _assert_reports_match(
        solver.metrics(st_, include_duals=True),
        solver.device_metrics(st_, include_duals=True),
    )


# ------------------------------------------------------------- run_until
def _host_loop(solver, tol, max_passes, chunk):
    """The PR-2 host-driven reference loop: run a chunk, report on host,
    stop on the stopping pair."""
    st_ = solver.init_state()
    done = 0
    while done < max_passes:
        k = min(chunk, max_passes - done)
        st_ = solver.run(st_, passes=k)
        done += k
        m = solver.metrics(st_)
        if m["max_violation"] < tol and abs(m["duality_gap"]) < tol:
            break
    return st_, done


@pytest.mark.parametrize("chunk", [3, 4])
def test_run_until_stops_at_host_loop_pass(x64, chunk):
    """The fused while_loop must stop at exactly the chunk boundary the
    host-driven loop stops at, with the identical iterate."""
    solver = ParallelSolver(_problem(16, seed=0), dtype=np.float64)
    tol = 1e-3
    st_host, done = _host_loop(solver, tol, 60, chunk)
    st_dev, info = solver.run_until(tol=tol, max_passes=60, check_every=chunk)
    assert info["passes"] == done
    assert info["converged"]
    assert 0 < done < 60
    np.testing.assert_array_equal(np.asarray(st_dev.x), np.asarray(st_host.x))


def test_run_until_respects_max_passes_and_remainder(x64):
    """tol=0 never converges: the runner must stop at exactly max_passes,
    including a final partial chunk (host semantics k=min(chunk, rem))."""
    solver = ParallelSolver(_problem(10, seed=2), dtype=np.float64)
    st_, info = solver.run_until(tol=0.0, max_passes=7, check_every=3)
    assert info["passes"] == 7 and not info["converged"]
    # the guarded partial chunk must be bit-identical to 7 plain passes
    np.testing.assert_array_equal(
        np.asarray(st_.x), np.asarray(solver.run(passes=7).x)
    )
    # cumulative semantics: resuming with the same target is a no-op but
    # still reports a real stopping pair.
    st2, info2 = solver.run_until(st_, tol=0.0, max_passes=7, check_every=3)
    assert info2["passes"] == 7
    assert np.isfinite(info2["max_violation"])
    np.testing.assert_array_equal(np.asarray(st2.x), np.asarray(st_.x))
    # and the stopping pair equals the host oracle's
    m = solver.metrics(st_)
    assert abs(info["max_violation"] - m["max_violation"]) < TOL
    assert abs(info["duality_gap"] - m["duality_gap"]) < TOL


def test_run_until_sharded(x64):
    mesh = Mesh(np.array(jax.devices()[:1]), ("solver",))
    solver = ShardedSolver(_problem(12, seed=4), mesh, dtype=np.float64,
                           num_buckets=2)
    tol = 1e-3
    st_host, done = _host_loop(solver, tol, 40, 5)
    st_dev, info = solver.run_until(tol=tol, max_passes=40, check_every=5)
    assert info["passes"] == done and info["converged"]
    np.testing.assert_allclose(
        np.asarray(st_dev.x), np.asarray(st_host.x), rtol=1e-12, atol=1e-12
    )


_SHARDED8_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax
    jax.config.update("jax_enable_x64", True)
    import numpy as np
    from jax.sharding import Mesh
    from repro.core import problems
    from repro.core.sharded_dykstra import ShardedSolver

    rng = np.random.default_rng(7)
    n = 14
    d = np.triu(rng.uniform(0, 1, (n, n)), k=1)
    p = problems.metric_nearness_l2(d)
    mesh = Mesh(np.array(jax.devices()), ("solver",))
    solver = ShardedSolver(p, mesh, dtype=np.float64, num_buckets=3)
    st, info = solver.run_until(tol=1e-3, max_passes=40, check_every=5)
    assert info["converged"], info
    host = solver.metrics(st, include_duals=True)
    dev = solver.device_metrics(st, include_duals=True)
    for k in host:
        assert abs(host[k] - dev[k]) <= 1e-10 + 1e-10 * abs(host[k]), (
            k, host[k], dev[k])
    print("ENGINE8_OK", info["passes"])
    """
)


@pytest.mark.multidevice
def test_engine_sharded_8_devices_subprocess():
    """True multi-device engine: the psum-max violation probe and the
    while_loop runner on 8 host devices must match the host oracle."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run(
        [sys.executable, "-c", _SHARDED8_SCRIPT],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    assert "ENGINE8_OK" in out.stdout


# ------------------------------------------------- direct slab→slab reshard
@pytest.mark.parametrize("p_old,p_new", [(1, 3), (3, 2), (2, 8)])
def test_reshard_direct_matches_dense_oracle(p_old, p_new):
    """The device-side composed slab→slab permutation must reproduce the
    dense (n, n, n) round trip AND the host-float64 permutation
    bit-for-bit — a gather moves values, it never rounds."""
    n, nb = 13, 2
    rng = np.random.default_rng(p_old * 10 + p_new)
    lay = sched.build_layout(n, num_buckets=nb, procs=p_old)
    slabs = [rng.uniform(0, 1, bl.slab_shape).astype(np.float32)
             for bl in lay.buckets]
    # zero the padding cells (real states keep padding at don't-care, but
    # the dense oracle drops it; the permutation only moves real cells)
    for s, m in zip(slabs, sched.slab_valid_masks(lay)):
        s[~m] = 0.0
    a, la = elastic.reshard_duals(slabs, n, p_old, p_new, nb)
    b, lb = elastic.reshard_duals_dense(slabs, n, p_old, p_new, nb)
    c, _ = elastic.reshard_duals_host(slabs, n, p_old, p_new, nb)
    assert [x.shape for x in a] == [x.shape for x in b]
    for sa, sb, sc in zip(a, b, c):
        assert isinstance(sa, jax.Array)  # the device path stays on device
        np.testing.assert_array_equal(np.asarray(sa), sb)
        np.testing.assert_array_equal(sb, sc)
    assert la.procs == lb.procs == p_new


def test_reshard_device_padding_garbage_dropped():
    """Fused-execution states carry don't-care padding values; the
    device permutation must mask them out, never copy them."""
    n, nb = 11, 2
    lay = sched.build_layout(n, num_buckets=nb, procs=2)
    rng = np.random.default_rng(0)
    slabs = [rng.uniform(0, 1, bl.slab_shape).astype(np.float32)
             for bl in lay.buckets]  # padding cells hold garbage
    a, la = elastic.reshard_duals(slabs, n, 2, 3, nb)
    clean = [np.array(s) for s in slabs]
    for s, m in zip(clean, sched.slab_valid_masks(lay)):
        s[~m] = 0.0
    b, _ = elastic.reshard_duals_host(clean, n, 2, 3, nb)
    for sa, sb, m in zip(a, b, sched.slab_valid_masks(la)):
        np.testing.assert_array_equal(np.asarray(sa), sb)
        assert np.all(np.asarray(sa)[~m] == 0.0)


def test_reshard_device_mesh_placement():
    """With a target mesh the new slabs come back committed + sharded on
    the solver axis (slabs never round-trip through the host)."""
    from jax.sharding import NamedSharding

    n, nb = 10, 2
    lay = sched.build_layout(n, num_buckets=nb, procs=2)
    rng = np.random.default_rng(1)
    slabs = [rng.uniform(0, 1, bl.slab_shape).astype(np.float32)
             for bl in lay.buckets]
    mesh = Mesh(np.array(jax.devices()[:1]), ("solver",))
    a, _ = elastic.reshard_duals(slabs, n, 2, 1, nb, mesh=mesh)
    b, _ = elastic.reshard_duals_host(slabs, n, 2, 1, nb)
    for sa, sb in zip(a, b):
        assert isinstance(sa.sharding, NamedSharding)
        assert sa.sharding.mesh.axis_names == ("solver",)
        np.testing.assert_array_equal(np.asarray(sa), sb)


# --------------------------------------------- 2-D-grid violation kernel
@pytest.mark.parametrize(
    "n,block,block_r",
    [(40, 8, 16), (97, 4, 32), (9, 8, 128), (50, 16, 8)],
)
def test_violation_kernel_2d_grid_matches_jnp(n, block, block_r):
    """The 2-D grid (apex × row blocks) must reduce to the exact jnp
    oracle value at sizes needing MULTIPLE row blocks per apex block —
    the regime where the old whole-matrix kernel would have required a
    resident (npad, npad) block."""
    import jax.numpy as jnp

    from repro.core import metrics_device
    from repro.kernels.metric_project.violation import (
        max_triangle_violation_pallas,
    )

    rng = np.random.default_rng(n)
    x = np.triu(rng.uniform(0, 1, (n, n)), 1)
    mask = jnp.triu(jnp.ones((n, n), bool), 1)
    xs = metrics_device.symmetrize(mask, jnp.asarray(x))
    want = float(metrics_device.triangle_violation(xs))
    got = float(
        max_triangle_violation_pallas(xs, block=block, block_r=block_r)
    )
    assert want == got
    if n > block_r:
        assert -(-max(n, block) // block_r) > 1  # really multi-row-block


def test_slab_valid_masks_count():
    """Masks mark exactly 3·C(n, 3) real cells across the layout."""
    for n, nb, procs in ((9, 1, 1), (14, 3, 2)):
        lay = sched.build_layout(n, num_buckets=nb, procs=procs)
        masks = sched.slab_valid_masks(lay)
        total = sum(int(m.sum()) for m in masks)
        assert total == 3 * sched.n_triplets(n)


# --------------------------------------------- engine keys & host parity
def test_device_metrics_keys_match_host_report():
    p = _problem(9, seed=6, kind="cc")
    solver = ParallelSolver(p)
    st_ = solver.run(passes=2)
    host = solver.metrics(st_)
    dev = solver.device_metrics(st_)
    assert set(host) == set(dev)
    host_d = solver.metrics(st_, include_duals=True)
    dev_d = solver.device_metrics(st_, include_duals=True)
    assert set(host_d) == set(dev_d)
    assert {"dual_min", "dual_max", "dual_l1", "active_constraints"} <= set(dev_d)

"""Solver correctness: serial oracle vs vectorized parallel schedule, and
optimality vs scipy reference solutions."""

import numpy as np
import pytest
import scipy.optimize

from repro.core import convergence, dykstra, problems
from repro.core.parallel_dykstra import ParallelSolver


def _rand_dissim(n, seed=0, metricish=False):
    rng = np.random.default_rng(seed)
    d = rng.uniform(0.0, 1.0, size=(n, n))
    d = np.triu(d, k=1)
    return d


def _rand_weights(n, seed=1):
    rng = np.random.default_rng(seed)
    w = rng.uniform(0.5, 2.0, size=(n, n))
    return np.triu(w, k=1) + np.triu(w, k=1).T + np.eye(n)  # any positive


# ------------------------------------------------------------------ equality
@pytest.mark.parametrize("n", [6, 11, 16])
def test_parallel_matches_serial_l2(n):
    """The parallel schedule is a conflict-free reordering → identical result
    to serially executing the same order (paper §III.A)."""
    p = problems.metric_nearness_l2(_rand_dissim(n), _rand_weights(n))
    st_ser = dykstra.solve_serial(p, max_passes=3, order="schedule")
    solver = ParallelSolver(p, dtype=np.float32)
    st_par = solver.run(passes=3)
    np.testing.assert_allclose(
        np.asarray(st_par.x), st_ser.x, rtol=2e-4, atol=2e-5
    )
    np.testing.assert_allclose(
        solver.duals_to_dense(st_par), st_ser.ytri, rtol=2e-4, atol=2e-5
    )


@pytest.mark.parametrize("n", [7, 12])
def test_parallel_matches_serial_cc_lp(n):
    p = problems.correlation_clustering_lp(_rand_dissim(n, seed=3), eps=0.05)
    st_ser = dykstra.solve_serial(p, max_passes=3, order="schedule")
    st_par = ParallelSolver(p, dtype=np.float32).run(passes=3)
    np.testing.assert_allclose(np.asarray(st_par.x), st_ser.x, rtol=3e-4, atol=3e-5)
    np.testing.assert_allclose(np.asarray(st_par.f), st_ser.f, rtol=3e-4, atol=3e-5)


def test_bucketing_does_not_change_result():
    n = 13
    p = problems.metric_nearness_l2(_rand_dissim(n, 5), _rand_weights(n, 6))
    a = ParallelSolver(p, bucket_diagonals=1).run(passes=2)
    b = ParallelSolver(p, bucket_diagonals=4).run(passes=2)
    np.testing.assert_allclose(np.asarray(a.x), np.asarray(b.x), rtol=1e-6)


# ---------------------------------------------------------------- optimality
def test_l2_nearness_converges_to_qp_optimum():
    """Dykstra fixed point == projection of D onto the metric cone.
    Verify against scipy SLSQP on a small instance."""
    n = 6
    d = _rand_dissim(n, seed=7)
    p = problems.metric_nearness_l2(d)
    st = dykstra.solve_serial(p, max_passes=300, order="schedule")
    assert convergence.max_violation(p, st.x) < 1e-6

    iu = np.triu_indices(n, k=1)
    trips = [
        (i, j, k) for i in range(n) for j in range(i + 1, n) for k in range(j + 1, n)
    ]
    pair_pos = {(a, b): t for t, (a, b) in enumerate(zip(*iu))}

    def cons(v):
        out = []
        for (i, j, k) in trips:
            xij, xik, xjk = v[pair_pos[i, j]], v[pair_pos[i, k]], v[pair_pos[j, k]]
            out += [xik + xjk - xij, xij + xjk - xik, xij + xik - xjk]
        return np.array(out)

    res = scipy.optimize.minimize(
        lambda v: np.sum((v - d[iu]) ** 2),
        x0=d[iu],
        jac=lambda v: 2 * (v - d[iu]),
        constraints=[{"type": "ineq", "fun": cons}],
        method="SLSQP",
        options={"maxiter": 500, "ftol": 1e-12},
    )
    assert res.success
    np.testing.assert_allclose(st.x[iu], res.x, atol=2e-4)


def test_cc_lp_approaches_lp_optimum_small_eps():
    """Regularized QP → LP as eps→0 (paper eq. (4)/(5), [31]).
    Compare the LP objective against scipy.linprog (HiGHS) ground truth."""
    n = 7
    rng = np.random.default_rng(11)
    dis = np.triu((rng.uniform(0, 1, (n, n)) > 0.5).astype(float), k=1)
    # eps trades LP fidelity against Dykstra's convergence rate ([37] §5):
    # 0.01 reaches the exact LP optimum on this instance within ~400 passes,
    # while 1e-3 needs >>1500 passes to leave the unregularized fixed point.
    p = problems.correlation_clustering_lp(dis, eps=0.01)
    st = dykstra.solve_serial(p, max_passes=600, order="schedule")

    # ground-truth LP via HiGHS
    iu = np.triu_indices(n, k=1)
    m = len(iu[0])
    pair_pos = {(a, b): t for t, (a, b) in enumerate(zip(*iu))}
    rows = []
    for i in range(n):
        for j in range(i + 1, n):
            for k in range(j + 1, n):
                for (lng, o1, o2) in [
                    ((i, j), (i, k), (j, k)),
                    ((i, k), (i, j), (j, k)),
                    ((j, k), (i, j), (i, k)),
                ]:
                    r = np.zeros(2 * m)
                    r[pair_pos[lng]] = 1
                    r[pair_pos[o1]] = -1
                    r[pair_pos[o2]] = -1
                    rows.append(r)
    # pair constraints: x - f <= d ; -x - f <= -d
    for (a, b), t in pair_pos.items():
        r = np.zeros(2 * m)
        r[t] = 1
        r[m + t] = -1
        rows.append(r)
    bs = [0.0] * (len(rows) - m) + [dis[a, b] for (a, b) in zip(*iu)]
    for (a, b), t in pair_pos.items():
        r = np.zeros(2 * m)
        r[t] = -1
        r[m + t] = -1
        rows.append(r)
        bs.append(-dis[a, b])
    c = np.concatenate([np.zeros(m), np.ones(m)])
    res = scipy.optimize.linprog(
        c, A_ub=np.array(rows), b_ub=np.array(bs),
        bounds=[(0, 1)] * m + [(0, None)] * m, method="highs",
    )
    assert res.status == 0
    ours = p.lp_objective(st.x)
    assert convergence.max_violation(p, st.x, st.f) < 1e-4
    assert abs(ours - res.fun) < 0.05 * max(1.0, abs(res.fun))


# ------------------------------------------------------------- certificates
def test_duality_gap_shrinks():
    n = 10
    p = problems.metric_nearness_l2(_rand_dissim(n, 2))
    solver = ParallelSolver(p)
    st5 = solver.run(passes=5)
    st40 = solver.run(st5, passes=35)
    m5, m40 = solver.metrics(st5), solver.metrics(st40)
    assert m40["max_violation"] <= m5["max_violation"] + 1e-7
    assert abs(m40["duality_gap"]) <= abs(m5["duality_gap"]) + 1e-6


def test_ordering_effect_runs_both_orders():
    # paper §IV.D: convergence holds for any ordering; both must satisfy
    # constraints eventually.
    n = 8
    p = problems.metric_nearness_l2(_rand_dissim(n, 4))
    for order in ("lex", "schedule"):
        st = dykstra.solve_serial(p, max_passes=150, order=order)
        assert convergence.max_violation(p, st.x) < 1e-5

"""Per-architecture smoke tests: reduced configs, one forward + one grad step
+ a decode step on CPU; output shapes and finiteness asserted."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import common
from repro.models.model import build_model

ARCHS = list(configs.ARCH_NAMES)
B, S = 2, 16


def _batch(cfg, rng):
    tok = jax.random.randint(rng, (B, S + 1), 0, cfg.vocab_size)
    batch = {"tokens": tok}
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            rng, (B, cfg.encoder_seq, cfg.d_model), jnp.float32
        )
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(
            rng, (B, cfg.num_patches, cfg.d_model), jnp.float32
        )
    return batch


def _params(lm, cfg, seed=0):
    return common.materialize(
        lm.param_specs(), jax.random.PRNGKey(seed), jnp.float32
    )


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = configs.get_smoke_config(arch).scaled(dtype=jnp.float32)
    lm = build_model(cfg)
    params = _params(lm, cfg)
    batch = _batch(cfg, jax.random.PRNGKey(1))
    logits, extra = jax.jit(lm.forward)(params, batch)
    assert logits.shape == (B, S, cfg.padded_vocab)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))


@pytest.mark.parametrize("arch", ARCHS)
def test_loss_and_grad_step(arch):
    cfg = configs.get_smoke_config(arch).scaled(dtype=jnp.float32)
    lm = build_model(cfg)
    params = _params(lm, cfg)
    batch = _batch(cfg, jax.random.PRNGKey(2))
    loss, grads = jax.jit(jax.value_and_grad(lm.loss))(params, batch)
    assert np.isfinite(float(loss)) and float(loss) > 0
    gnorm = jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads))
    )
    assert np.isfinite(float(gnorm)) and float(gnorm) > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_step(arch):
    cfg = configs.get_smoke_config(arch).scaled(dtype=jnp.float32)
    lm = build_model(cfg)
    params = _params(lm, cfg)
    cache = common.materialize(
        lm.cache_specs(B, max_seq=32), jax.random.PRNGKey(0), jnp.float32
    )
    cache["pos"] = jnp.zeros((), jnp.int32)
    if cfg.family == "encdec":
        cache["enc_out"] = jax.random.normal(
            jax.random.PRNGKey(3), (B, cfg.encoder_seq, cfg.d_model), jnp.float32
        )
    tok = jnp.ones((B, 1), jnp.int32)
    step = jax.jit(lm.decode_step)
    logits, cache = step(params, cache, tok)
    logits2, cache = step(params, cache, tok)
    assert logits.shape == (B, 1, cfg.padded_vocab)
    assert np.all(np.isfinite(np.asarray(logits2, np.float32)))
    assert int(cache["pos"]) == 2


def test_decode_matches_forward_dense():
    """Teacher-forced forward and step-by-step decode must agree (olmo)."""
    cfg = configs.get_smoke_config("olmo-1b").scaled(dtype=jnp.float32)
    lm = build_model(cfg)
    params = _params(lm, cfg)
    tok = jax.random.randint(jax.random.PRNGKey(5), (B, 9), 0, cfg.vocab_size)
    logits_tf, _ = jax.jit(lm.forward)(params, {"tokens": tok})
    cache = common.materialize(lm.cache_specs(B, 16), jax.random.PRNGKey(0), jnp.float32)
    cache = jax.tree.map(jnp.zeros_like, cache)
    outs = []
    step = jax.jit(lm.decode_step)
    for t in range(8):
        lg, cache = step(params, cache, tok[:, t : t + 1])
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(dec), np.asarray(logits_tf), rtol=2e-3, atol=2e-3
    )


def test_decode_matches_forward_ssm():
    """Same agreement check for the mamba1 recurrence (falcon-mamba)."""
    cfg = configs.get_smoke_config("falcon-mamba-7b").scaled(dtype=jnp.float32)
    lm = build_model(cfg)
    params = _params(lm, cfg)
    tok = jax.random.randint(jax.random.PRNGKey(6), (B, 9), 0, cfg.vocab_size)
    logits_tf, _ = jax.jit(lm.forward)(params, {"tokens": tok})
    cache = common.materialize(lm.cache_specs(B, 16), jax.random.PRNGKey(0), jnp.float32)
    cache = jax.tree.map(jnp.zeros_like, cache)
    outs = []
    step = jax.jit(lm.decode_step)
    for t in range(8):
        lg, cache = step(params, cache, tok[:, t : t + 1])
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(dec), np.asarray(logits_tf), rtol=2e-3, atol=2e-3
    )


def test_param_counts_full_configs():
    """Full configs must be in the right parameter-count ballpark
    (catches transposed/wrong-size specs without allocating)."""
    expected = {
        "gemma-7b": (7.7e9, 9.5e9),     # incl. 256k vocab embedding
        "olmo-1b": (1.0e9, 1.4e9),
        "codeqwen1.5-7b": (6.5e9, 8.5e9),
        "deepseek-67b": (6.0e10, 7.2e10),
        "pixtral-12b": (1.1e10, 1.4e10),
        "zamba2-1.2b": (0.9e9, 1.6e9),
        # upper bound includes the 32k-position learned decoder table sized
        # for the decode_32k shape (DESIGN.md; whisper's native max is 448)
        "whisper-base": (6.0e7, 1.35e8),
        "qwen2-moe-a2.7b": (1.2e10, 1.7e10),
        "deepseek-v2-lite-16b": (1.3e10, 1.8e10),
        "falcon-mamba-7b": (6.5e9, 8.5e9),
    }
    for arch, (lo, hi) in expected.items():
        cfg = configs.get_config(arch)
        n = common.count_params(build_model(cfg).param_specs())
        assert lo <= n <= hi, f"{arch}: {n:.3e} params not in [{lo:.1e}, {hi:.1e}]"


def test_all_cells_enumeration():
    cells = configs.all_cells()
    assert len(cells) == 40
    runnable = [c for c in cells if c[2]]
    skipped = [c for c in cells if not c[2]]
    assert len(skipped) == 8  # long_500k × 8 full-attention archs
    assert all(c[1] == "long_500k" for c in skipped)
    assert {c[0] for c in cells if c[1] == "long_500k" and c[2]} == {
        "zamba2-1.2b", "falcon-mamba-7b",
    }

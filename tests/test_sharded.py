"""Distributed solver: exactness vs the serial oracle, dual-slab round trip,
and a true multi-device run in a subprocess (8 host devices)."""

import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest
from jax.sharding import Mesh

from repro.core import dykstra, problems
from repro.core.sharded_dykstra import ShardedSolver


def _mesh1():
    return Mesh(np.array(jax.devices()[:1]), ("solver",))


def _problem(n, seed=0, cc=False):
    rng = np.random.default_rng(seed)
    d = np.triu(rng.uniform(0, 1, (n, n)), k=1)
    if cc:
        return problems.correlation_clustering_lp((d > 0.5).astype(float), eps=0.05)
    return problems.metric_nearness_l2(d)


@pytest.mark.parametrize("n,buckets", [(8, 1), (13, 3)])
def test_sharded_p1_matches_serial(n, buckets):
    p = _problem(n)
    st_ser = dykstra.solve_serial(p, max_passes=2, order="schedule")
    solver = ShardedSolver(p, _mesh1(), num_buckets=buckets)
    st = solver.run(passes=2)
    np.testing.assert_allclose(np.asarray(st.x), st_ser.x, rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(
        solver.duals_to_dense(st), st_ser.ytri, rtol=2e-4, atol=2e-5
    )


def test_sharded_cc_lp_p1():
    p = _problem(9, seed=2, cc=True)
    st_ser = dykstra.solve_serial(p, max_passes=3, order="schedule")
    st = ShardedSolver(p, _mesh1(), num_buckets=2).run(passes=3)
    np.testing.assert_allclose(np.asarray(st.x), st_ser.x, rtol=3e-4, atol=3e-5)
    np.testing.assert_allclose(np.asarray(st.f), st_ser.f, rtol=3e-4, atol=3e-5)


def test_sharded_metrics_report():
    p = _problem(10, seed=4)
    solver = ShardedSolver(p, _mesh1())
    st = solver.run(passes=20)
    m = solver.metrics(st)
    assert m["max_violation"] < 0.05
    assert np.isfinite(m["duality_gap"])


_SUBPROCESS_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np, jax
    from jax.sharding import Mesh
    from repro.core import dykstra, problems
    from repro.core.sharded_dykstra import ShardedSolver

    assert len(jax.devices()) == 8
    n = 14
    rng = np.random.default_rng(7)
    d = np.triu(rng.uniform(0, 1, (n, n)), k=1)
    p = problems.metric_nearness_l2(d)
    st_ser = dykstra.solve_serial(p, max_passes=2, order="schedule")
    mesh = Mesh(np.array(jax.devices()), ("solver",))
    solver = ShardedSolver(p, mesh, num_buckets=3)
    st = solver.run(passes=2)
    np.testing.assert_allclose(np.asarray(st.x), st_ser.x, rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(solver.duals_to_dense(st), st_ser.ytri,
                               rtol=2e-4, atol=2e-5)
    print("SHARDED8_OK")
    """
)


@pytest.mark.multidevice
def test_sharded_8_devices_subprocess():
    """True multi-device execution: 8 host devices, r mod 8 set assignment,
    per-device dual slabs, exact delta psum — must equal the serial oracle."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run(
        [sys.executable, "-c", _SUBPROCESS_SCRIPT],
        capture_output=True,
        text=True,
        env=env,
        timeout=600,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    assert "SHARDED8_OK" in out.stdout


def test_packed_delta_mode_matches_psum_p1():
    p = _problem(11, seed=9)
    a = ShardedSolver(p, _mesh1(), num_buckets=2, delta_mode="psum").run(passes=2)
    b = ShardedSolver(p, _mesh1(), num_buckets=2, delta_mode="packed").run(passes=2)
    np.testing.assert_allclose(np.asarray(a.x), np.asarray(b.x), rtol=1e-6, atol=1e-7)


_PACKED_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np, jax
    from jax.sharding import Mesh
    from repro.core import dykstra, problems
    from repro.core.sharded_dykstra import ShardedSolver

    n = 14
    rng = np.random.default_rng(7)
    d = np.triu(rng.uniform(0, 1, (n, n)), k=1)
    p = problems.metric_nearness_l2(d)
    st_ser = dykstra.solve_serial(p, max_passes=2, order="schedule")
    mesh = Mesh(np.array(jax.devices()), ("solver",))
    solver = ShardedSolver(p, mesh, num_buckets=3, delta_mode="packed")
    st = solver.run(passes=2)
    np.testing.assert_allclose(np.asarray(st.x), st_ser.x, rtol=2e-4, atol=2e-5)
    print("PACKED8_OK")
    """
)


@pytest.mark.multidevice
def test_packed_delta_8_devices_subprocess():
    """§Perf H3 exactness: packed all_gather delta exchange on 8 real host
    devices must equal the serial oracle."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run(
        [sys.executable, "-c", _PACKED_SCRIPT],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    assert "PACKED8_OK" in out.stdout


# ------------------------------------------------ fused multi-pass runner
def test_sharded_fused_scan_matches_host_loop_bitwise():
    """DESIGN.md §9 runner contract: ``run(passes=P)`` (one jitted scan
    over the shard_map pass) must produce bit-identical state to P
    host-looped single-pass dispatches, emit the P-pass residual
    trajectory, and treat ``run(st, 0)`` as the identity."""
    p = _problem(12, seed=3)
    solver = ShardedSolver(p, _mesh1(), num_buckets=2)
    st_scan = solver.run(passes=3)
    res = np.asarray(solver.last_residuals)
    st_loop = solver.init_state()
    for _ in range(3):
        st_loop = solver._pass_fn(st_loop)
    np.testing.assert_array_equal(np.asarray(st_scan.x), np.asarray(st_loop.x))
    for a, b in zip(st_scan.yd, st_loop.yd):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert int(st_scan.passes) == 3
    assert res.shape == (3,) and np.all(res > 0)
    assert solver.run(st_scan, passes=0) is st_scan


def test_sharded_kernel_matches_fused_jnp_bitwise():
    """DESIGN.md §10: ``use_kernel=True`` routes every diagonal through
    the gen-3 megakernel in delta-output mode — X and the dense dual
    maps must equal the jnp fused path bitwise (the kernel emits the
    same act-masked delta matrix the jnp path scatters)."""
    p = _problem(13, seed=0)
    a = ShardedSolver(p, _mesh1(), num_buckets=3).run(passes=2)
    b = ShardedSolver(p, _mesh1(), num_buckets=3, use_kernel=True).run(
        passes=2
    )
    np.testing.assert_array_equal(np.asarray(a.x), np.asarray(b.x))
    probe = ShardedSolver(p, _mesh1(), num_buckets=3)
    np.testing.assert_array_equal(
        probe.duals_to_dense(a), probe.duals_to_dense(b)
    )


def test_sharded_kernel_rejects_packed_mode():
    """The megakernel emits the psum delta matrix directly; the packed
    compact exchange has no kernel path and must refuse loudly."""
    p = _problem(9, seed=1)
    with pytest.raises(ValueError, match="psum"):
        ShardedSolver(p, _mesh1(), use_kernel=True, delta_mode="packed")


_KERNEL8_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np, jax
    from jax.sharding import Mesh
    from repro.core import problems
    from repro.core.sharded_dykstra import ShardedSolver

    assert len(jax.devices()) == 8
    n = 14
    rng = np.random.default_rng(7)
    d = np.triu(rng.uniform(0, 1, (n, n)), k=1)
    p = problems.metric_nearness_l2(d)
    mesh = Mesh(np.array(jax.devices()), ("solver",))
    a = ShardedSolver(p, mesh, num_buckets=3).run(passes=2)
    b = ShardedSolver(p, mesh, num_buckets=3, use_kernel=True).run(passes=2)
    np.testing.assert_array_equal(np.asarray(a.x), np.asarray(b.x))
    probe = ShardedSolver(p, mesh, num_buckets=3)
    np.testing.assert_array_equal(
        probe.duals_to_dense(a), probe.duals_to_dense(b)
    )
    print("KERNEL8_OK")
    """
)


@pytest.mark.multidevice
def test_sharded_kernel_8_devices_subprocess():
    """True multi-device megakernel execution: on 8 host devices the
    gen-3 delta-output kernel inside shard_map must equal the jnp fused
    path bit-for-bit (per-device deltas scattered into zeros, one exact
    psum per diagonal)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run(
        [sys.executable, "-c", _KERNEL8_SCRIPT],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    assert "KERNEL8_OK" in out.stdout


def test_sharded_fused_baseline_matches_serial():
    """``fused=False`` (the benchmark baseline: legacy sweep, one
    dispatch per pass) must still match the serial oracle."""
    p = _problem(10, seed=5)
    st_ser = dykstra.solve_serial(p, max_passes=2, order="schedule")
    solver = ShardedSolver(p, _mesh1(), num_buckets=2, fused=False)
    st = solver.run(passes=2)
    np.testing.assert_allclose(np.asarray(st.x), st_ser.x, rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(
        solver.duals_to_dense(st), st_ser.ytri, rtol=2e-4, atol=2e-5
    )


_FUSED8_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np, jax
    from jax.sharding import Mesh
    from repro.core import problems
    from repro.core.sharded_dykstra import ShardedSolver
    from repro.launch import elastic

    n = 14
    rng = np.random.default_rng(7)
    d = np.triu(rng.uniform(0, 1, (n, n)), k=1)
    p = problems.metric_nearness_l2(d)
    mesh = Mesh(np.array(jax.devices()), ("solver",))
    solver = ShardedSolver(p, mesh, num_buckets=3)
    # fused P-pass scan (ONE compiled program) == P host-looped passes
    st_scan = solver.run(passes=3)
    st_loop = solver.init_state()
    for _ in range(3):
        st_loop = solver._pass_fn(st_loop)
    np.testing.assert_array_equal(np.asarray(st_scan.x), np.asarray(st_loop.x))
    for a, b in zip(st_scan.yd, st_loop.yd):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # device-side reshard of the LIVE sharded slabs, 8 -> 4 devices,
    # output left sharded on a 4-device mesh == dense round-trip oracle
    mesh4 = Mesh(np.array(jax.devices()[:4]), ("solver",))
    new_slabs, lay = elastic.reshard_duals(
        st_scan.yd, n, 8, 4, 3, mesh=mesh4
    )
    oracle, _ = elastic.reshard_duals_dense(
        [np.asarray(s) for s in st_scan.yd], n, 8, 4, 3
    )
    for sa, sb in zip(new_slabs, oracle):
        assert len(sa.sharding.device_set) == 4, sa.sharding
        np.testing.assert_array_equal(np.asarray(sa), sb)
    assert lay.procs == 4
    print("FUSED8_OK")
    """
)


@pytest.mark.multidevice
def test_sharded_fused_8_devices_subprocess():
    """True multi-device fused runtime: the P-pass scan on 8 host devices
    must equal P host-looped dispatches bit-for-bit, and the device-side
    reshard of the live sharded state must equal the dense oracle with
    slabs left sharded."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run(
        [sys.executable, "-c", _FUSED8_SCRIPT],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    assert "FUSED8_OK" in out.stdout

"""Integration: the dry-run lowering machinery on host-size meshes with
reduced configs — exercises train_specs/serve_specs/sharding rules end to end
(the 512-device production run lives in launch/dryrun.py + results/)."""

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import NamedSharding

from repro import configs
from repro.configs.shapes import InputShape
from repro.launch import mesh as mesh_lib
from repro.launch import specs as specs_lib
from repro.models.model import build_model
from repro.roofline import accounting, hlo_parse
from repro.train import optimizer as opt_lib
from repro.train.train_step import make_serve_step, make_train_step

SMALL_TRAIN = InputShape("t", seq_len=32, global_batch=2, kind="train")
SMALL_DECODE = InputShape("d", seq_len=64, global_batch=2, kind="decode")


@pytest.mark.parametrize("arch", ["olmo-1b", "qwen2-moe-a2.7b",
                                  "falcon-mamba-7b", "whisper-base"])
def test_train_lowering_compiles_on_host_mesh(arch):
    cfg = configs.get_smoke_config(arch).scaled(dtype=jnp.float32)
    lm = build_model(cfg)
    mesh = mesh_lib.make_host_mesh(1, 1)
    with mesh:
        st, st_sh, b, b_sh = specs_lib.train_specs(cfg, SMALL_TRAIN, mesh)
        step = make_train_step(lm, opt_lib.AdamWConfig(), remat="dots")
        compiled = jax.jit(
            step, in_shardings=(st_sh, b_sh), out_shardings=(st_sh, None)
        ).lower(st, b).compile()
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    assert float(cost.get("flops", 0)) > 0


@pytest.mark.parametrize("arch", ["olmo-1b", "zamba2-1.2b",
                                  "deepseek-v2-lite-16b"])
def test_serve_lowering_compiles_on_host_mesh(arch):
    cfg = configs.get_smoke_config(arch).scaled(dtype=jnp.float32)
    lm = build_model(cfg)
    mesh = mesh_lib.make_host_mesh(1, 1)
    with mesh:
        (p, p_sh, c, c_sh, t, t_sh) = specs_lib.serve_specs(
            cfg, SMALL_DECODE, mesh
        )
        serve = make_serve_step(lm)
        compiled = jax.jit(
            serve, in_shardings=(p_sh, c_sh, t_sh["tokens"]),
            out_shardings=(None, c_sh),
        ).lower(p, c, t["tokens"]).compile()
    assert "while" in compiled.as_text()  # scanned layers present


def test_zero1_shardings_shard_moments():
    cfg = configs.get_smoke_config("olmo-1b").scaled(dtype=jnp.float32)
    mesh = mesh_lib.make_host_mesh(1, 1)
    st, st_sh, _, _ = specs_lib.train_specs(cfg, SMALL_TRAIN, mesh, zero1=True)
    # shardings exist and match param tree structure
    assert jax.tree.structure(st_sh["opt"]["m"]) == jax.tree.structure(st["params"])
    leaves = jax.tree.leaves(st_sh["opt"]["m"],
                             is_leaf=lambda x: isinstance(x, NamedSharding))
    assert all(isinstance(s, NamedSharding) for s in leaves)


def test_kv_repeat_changes_cache_heads_only():
    cfg = configs.get_smoke_config("pixtral-12b").scaled(dtype=jnp.float32)
    lm1 = build_model(cfg)
    lm2 = build_model(cfg.scaled(kv_repeat=2))
    c1 = lm1.cache_specs(2, 16)["layers"]["k"].shape
    c2 = lm2.cache_specs(2, 16)["layers"]["k"].shape
    assert c2[-2] == 2 * c1[-2]  # kv head axis doubled
    # params unchanged
    import jax
    s1 = jax.tree.map(lambda s: s.shape, lm1.param_specs())
    s2 = jax.tree.map(lambda s: s.shape, lm2.param_specs())
    assert s1 == s2


def test_kv_repeat_preserves_decode_semantics():
    """kv_repeat is a layout change: decode logits must be unchanged."""
    import numpy as np
    from repro.models import common

    cfg = configs.get_smoke_config("deepseek-67b").scaled(dtype=jnp.float32)
    lm1 = build_model(cfg)
    lm2 = build_model(cfg.scaled(kv_repeat=2))
    params = common.materialize(lm1.param_specs(), jax.random.PRNGKey(0),
                                jnp.float32)
    tok = jnp.ones((2, 1), jnp.int32)

    def decode3(lm):
        cache = common.materialize(lm.cache_specs(2, 8), jax.random.PRNGKey(0),
                                   jnp.float32)
        cache = jax.tree.map(jnp.zeros_like, cache)
        outs = []
        for _ in range(3):
            lg, cache = jax.jit(lm.decode_step)(params, cache, tok)
            outs.append(np.asarray(lg))
        return np.stack(outs)

    np.testing.assert_allclose(decode3(lm1), decode3(lm2), rtol=2e-4, atol=2e-4)


def test_moe_pad_experts_preserves_routing():
    """Padded experts must never receive tokens (−inf router bias)."""
    import numpy as np
    from repro.models import common, moe

    cfg = configs.get_smoke_config("qwen2-moe-a2.7b").scaled(
        dtype=jnp.float32, moe_pad_experts=16)  # smoke has 8 routed
    lm = build_model(cfg)
    params = common.materialize(lm.param_specs(), jax.random.PRNGKey(0),
                                jnp.float32)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 17), 0,
                                          cfg.vocab_size)}
    logits, _ = jax.jit(lm.forward)(params, batch)
    assert np.all(np.isfinite(np.asarray(logits)))
    # routing check at the layer level
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 8, cfg.d_model))
    lp = jax.tree.map(lambda a: a[0], params["layers"])
    r_logits = jnp.einsum("nd,de->ne", x.reshape(-1, cfg.d_model),
                          lp["mlp"]["router"])
    pad_bias = jnp.where(jnp.arange(16) < cfg.n_routed, 0.0, -1e30)
    probs = jax.nn.softmax(r_logits + pad_bias[None], axis=-1)
    _, top_e = jax.lax.top_k(probs, cfg.top_k)
    assert int(jnp.max(top_e)) < cfg.n_routed


def test_accounting_hlo_consistency_small():
    """Analytic flops ≈ trip-corrected HLO expectations on a tiny dense
    model: the layer-scan while trip count must equal n_layers."""
    cfg = configs.get_smoke_config("olmo-1b").scaled(dtype=jnp.float32)
    lm = build_model(cfg)
    mesh = mesh_lib.make_host_mesh(1, 1)
    with mesh:
        st, st_sh, b, b_sh = specs_lib.train_specs(cfg, SMALL_TRAIN, mesh)
        step = make_train_step(lm, opt_lib.AdamWConfig(), remat="none")
        compiled = jax.jit(step).lower(st, b).compile()
    comps, entry = hlo_parse.parse_computations(compiled.as_text())
    trips = [t[3] for t in hlo_parse.while_trips(comps)]
    assert cfg.n_layers in trips

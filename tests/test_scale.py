"""Scale campaign machinery (DESIGN.md §14): lane-blocked violation
kernel parity (bitwise vs the jnp oracle), the slab entry + kernel-backed
sharded probe, donated async snapshots, the multi-process mesh entry, and
the campaign's memory-model cube-root law."""

import os
import subprocess
import sys
import tempfile
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from repro.core import metrics_device, problems
from repro.core.sharded_dykstra import ShardedSolver
from repro.kernels.metric_project import ops as kops
from repro.kernels.metric_project.violation import (
    max_triangle_violation_pallas,
    max_triangle_violation_slab_pallas,
)
from repro.launch import mesh as mesh_lib
from repro.train import checkpoint as ckpt_lib


def _sym(n, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, n))
    x = np.abs(x + x.T).astype(np.float32)
    np.fill_diagonal(x, 0.0)
    return jnp.asarray(x)


def _mesh1():
    return Mesh(np.array(jax.devices()[:1]), ("solver",))


def _problem(n, seed=0):
    rng = np.random.default_rng(seed)
    d = np.triu(rng.uniform(0, 1, (n, n)), k=1)
    return problems.metric_nearness_l2(d)


# --------------------------------------------- lane-blocked kernel parity
# npad spans >= 3 column blocks in every case (the tentpole's VMEM
# geometry); bitwise equality because max is association-free.
@pytest.mark.parametrize(
    "n,block,block_r,block_c",
    [
        (50, 8, 16, 16),  # npad=64: 4 column blocks, non-multiple n
        (97, 4, 32, 32),  # npad=128: 4 column blocks
        (33, 8, 8, 8),  # npad=40: 5 column blocks
        (64, 16, 16, 16),  # exact multiple: no padding at all
        (40, 8, 16, 24),  # block_c != block_r (lcm padding)
    ],
)
def test_lane_blocked_kernel_bitwise_vs_jnp(n, block, block_r, block_c):
    xs = _sym(n, seed=n)
    want = metrics_device.triangle_violation(xs)
    got = max_triangle_violation_pallas(
        xs, block=block, block_r=block_r, block_c=block_c
    )
    assert float(want) == float(got)


def test_lane_blocked_matches_full_width():
    """block_c=None (the pre-§14 single full-width column block) and the
    lane-blocked grid agree bitwise on the same matrix."""
    xs = _sym(45, seed=1)
    full = max_triangle_violation_pallas(xs, block=8, block_r=16)
    laned = max_triangle_violation_pallas(xs, block=8, block_r=16, block_c=8)
    assert float(full) == float(laned)


def test_lane_blocked_kernel_ghost_padding():
    """Ghost-padded instance (n_live < n): the kernel masks every triangle
    touching an index >= n_live, matching the jnp oracle bitwise."""
    n, live = 41, 29
    x = _sym(n, seed=3)
    xs = metrics_device.symmetrize(metrics_device.live_pair_mask(n, live), x)
    want = metrics_device.triangle_violation(xs, n_live=live)
    got = max_triangle_violation_pallas(
        xs, block=8, block_r=16, block_c=16, n_live=live
    )
    assert float(want) == float(got)


def test_ops_triangle_violation_threads_block_c():
    xs = _sym(26, seed=5)
    want = metrics_device.triangle_violation(xs)
    assert float(kops.triangle_violation(xs, block_c=8)) == float(want)
    assert float(kops.triangle_violation(xs)) == float(want)


# ------------------------------------------------------------- slab entry
def test_slab_partition_covers_full_reduction():
    """Contiguous apex slabs (including a zero-padded tail slab) pmax to
    exactly the full-matrix reduction — the sharded probe's algebra."""
    n, m = 40, 16  # 3 slabs: [0,16), [16,32), [32,48) with 8 padding rows
    xs = _sym(n, seed=8)
    vs = []
    for k in range(3):
        sl = xs[k * m:(k + 1) * m]
        if sl.shape[0] < m:
            sl = jnp.pad(sl, ((0, m - sl.shape[0]), (0, 0)))
        vs.append(
            max_triangle_violation_slab_pallas(
                sl, jnp.int32(k * m), xs, block=8, block_r=16, block_c=16
            )
        )
    want = metrics_device.triangle_violation(xs)
    assert float(jnp.max(jnp.stack(vs))) == float(want)


def test_slab_entry_rejects_unaligned_rows():
    xs = _sym(20, seed=2)
    with pytest.raises(AssertionError, match="multiple of the apex block"):
        max_triangle_violation_slab_pallas(xs[:10], jnp.int32(0), xs, block=8)


# ------------------------------------------- kernel-backed sharded probe
def test_sharded_kernel_probe_matches_jnp_p1():
    xs = _sym(37, seed=4)
    want = metrics_device.triangle_violation(xs)
    got = metrics_device.triangle_violation_sharded_kernel(
        xs, _mesh1(), block=8, block_r=16, block_c=16
    )
    assert float(want) == float(got)
    got_live = metrics_device.triangle_violation_sharded_kernel(
        xs, _mesh1(), n_live=20
    )
    assert float(got_live) == float(
        metrics_device.triangle_violation(xs, n_live=20)
    )


def test_sharded_solver_use_kernel_routes_probe():
    """use_kernel flips the sharded stopping probe to the Pallas slab
    kernel; run_until must land on the identical certificate and pass
    count (the probes are bitwise-equal)."""
    p = _problem(18, seed=6)
    a = ShardedSolver(p, _mesh1(), num_buckets=3, use_kernel=True,
                      probe_block_c=16)
    b = ShardedSolver(p, _mesh1(), num_buckets=3, use_kernel=False)
    _, ia = a.run_until(tol=1e-3, max_passes=30, check_every=5)
    _, ib = b.run_until(tol=1e-3, max_passes=30, check_every=5)
    assert float(ia["max_violation"]) == float(ib["max_violation"])
    assert int(ia["passes"]) == int(ib["passes"])
    assert bool(ia["converged"]) and bool(ib["converged"])


# ------------------------------------------ jnp apex-block padding guard
def test_apex_block_clamped_and_guarded():
    """apex_block > n no longer sweeps phantom blocks (clamped to n), and
    every blocking agrees with every other bitwise."""
    xs = _sym(23, seed=9)
    base = metrics_device.triangle_violation(xs, apex_block=1)
    for ab in (4, 7, 16, 23, 64, 1000):
        assert float(metrics_device.triangle_violation(xs, apex_block=ab)) \
            == float(base)


def test_sharded_jnp_probe_n_live():
    xs = _sym(21, seed=10)
    want = metrics_device.triangle_violation(xs, n_live=15)
    got = metrics_device.triangle_violation_sharded(
        xs, _mesh1(), n_live=15
    )
    assert float(want) == float(got)


# --------------------------------------------------- donated snapshots
def test_snapshot_device_copy_is_independent():
    tree = {"x": jnp.arange(6.0), "y": [jnp.ones((2, 2))]}
    live, snap = ckpt_lib.snapshot_device(tree)
    assert live is tree
    np.testing.assert_array_equal(np.asarray(snap["x"]), np.arange(6.0))
    # donate path (a no-op alias copy on CPU backends) still round-trips
    live2, snap2 = ckpt_lib.snapshot_device(tree, donate=True)
    np.testing.assert_array_equal(
        np.asarray(live2["x"]), np.asarray(snap2["x"])
    )


def test_save_async_donate_roundtrip():
    tree = {"x": jnp.arange(12.0).reshape(3, 4), "n": jnp.int32(7)}
    d = tempfile.mkdtemp()
    th, live = ckpt_lib.save_async(d, 5, tree, donate=True)
    th.join()
    ckpt_lib.wait_pending()
    got, manifest = ckpt_lib.restore(d, tree)
    assert manifest["step"] == 5
    np.testing.assert_array_equal(
        np.asarray(got["x"]), np.arange(12.0).reshape(3, 4)
    )
    # the returned live tree stays usable after the writer finished
    assert float(jnp.sum(live["x"])) == 66.0


def test_maybe_save_donate_idiom():
    tree = {"x": jnp.ones(4)}
    d = tempfile.mkdtemp()
    mgr = ckpt_lib.CheckpointManager(d, every=10)
    handle, tree = mgr.maybe_save(3, tree, donate=True)  # off cadence
    assert handle is None
    handle, tree = mgr.maybe_save(10, tree, donate=True)
    assert handle is not None
    ckpt_lib.wait_pending()
    _, manifest = ckpt_lib.restore(d, tree)
    assert manifest["step"] == 10
    with pytest.raises(ValueError, match="asynchronous"):
        mgr.maybe_save(20, tree, donate=True, asynchronous=False)


# -------------------------------------------------- multi-process mesh
def test_initialize_distributed_single_process_noop():
    assert mesh_lib.initialize_distributed() is False
    assert mesh_lib.initialize_distributed(num_processes=1) is False


def test_make_global_solver_mesh():
    mesh = mesh_lib.make_global_solver_mesh()
    assert mesh.axis_names == ("solver",)
    assert mesh.devices.size == len(jax.devices())
    with pytest.raises(RuntimeError, match="global list"):
        mesh_lib.make_global_solver_mesh(len(jax.devices()) + 1)


def test_device_memory_bytes_reports():
    keep = jnp.ones((64, 64))  # ensure something is live
    total, source = mesh_lib.device_memory_bytes()
    assert source in ("device_stats", "live_arrays")
    assert total >= keep.nbytes


# ------------------------------------------------ campaign memory model
def test_feasible_ladder_cube_root_law():
    """The acceptance bar's scaling: the 8-device ladder tops out at
    >= 2x the single-device largest-n for both campaign budgets (the
    dual-slab bytes grow ~n^3, so largest-n ~ (p*B)^(1/3))."""
    from benchmarks import scale_campaign as sc

    for budget in (sc.SMOKE_BUDGET_MB, sc.FULL_BUDGET_MB):
        l1 = sc.feasible_ladder(1, budget)
        l8 = sc.feasible_ladder(8, budget)
        assert l1 and l8
        assert l8[-1] >= 2 * l1[-1], (budget, l1[-1], l8[-1])
    # the smoke cap keeps the CI leg bounded
    assert sc.feasible_ladder(8, 1e9, cap=sc.SMOKE_CAP)[-1] <= sc.SMOKE_CAP


# ------------------------------------------------- 8-device subprocess
_PROBE8_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np, jax, jax.numpy as jnp
    from jax.sharding import Mesh
    from repro.core import metrics_device, problems
    from repro.core.sharded_dykstra import ShardedSolver

    assert len(jax.devices()) == 8
    mesh = Mesh(np.array(jax.devices()), ("solver",))
    rng = np.random.default_rng(11)
    n = 26
    x = rng.normal(size=(n, n))
    xs = jnp.asarray(np.abs(x + x.T).astype(np.float32))
    want = metrics_device.triangle_violation(xs)
    got = metrics_device.triangle_violation_sharded_kernel(
        xs, mesh, block=4, block_r=8, block_c=8)
    assert float(want) == float(got), (float(want), float(got))

    d = np.triu(rng.uniform(0, 1, (n, n)), k=1)
    p = problems.metric_nearness_l2(d)
    a = ShardedSolver(p, mesh, num_buckets=3, use_kernel=True,
                      probe_block_c=8)
    b = ShardedSolver(p, mesh, num_buckets=3, use_kernel=False)
    _, ia = a.run_until(tol=1e-3, max_passes=40, check_every=5)
    _, ib = b.run_until(tol=1e-3, max_passes=40, check_every=5)
    assert float(ia["max_violation"]) == float(ib["max_violation"])
    assert int(ia["passes"]) == int(ib["passes"])
    print("PROBE8_OK")
    """
)


@pytest.mark.multidevice
def test_kernel_probe_8_devices_subprocess():
    """True 8-device run: the kernel-backed sharded probe (contiguous
    apex slabs + pmax) equals the jnp oracle bitwise, and use_kernel
    run_until lands on the jnp route's exact certificate."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run(
        [sys.executable, "-c", _PROBE8_SCRIPT],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    assert "PROBE8_OK" in out.stdout


@pytest.mark.multidevice
def test_mesh_entry_8_devices_subprocess():
    """The multi-process mesh entry end to end on 8 forced host devices:
    global mesh line + a converged sharded solve certificate."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.mesh",
         "--local-device-count", "8", "--n", "16", "--use-kernel"],
        capture_output=True, text=True, env=env, timeout=600,
        cwd=os.path.join(os.path.dirname(__file__), ".."),
    )
    assert out.returncode == 0, out.stderr[-3000:]
    assert "global_devices=8" in out.stdout
    assert "converged=True" in out.stdout

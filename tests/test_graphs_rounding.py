"""Graph construction + LP rounding behaviour."""

import numpy as np
import pytest

from repro.core import problems, rounding
from repro.core.parallel_dykstra import ParallelSolver
from repro.graphs import generators, io, jaccard


def test_jaccard_properties():
    adj, _ = generators.planted_partition(30, seed=1)
    j = jaccard.jaccard_index(adj)
    assert np.all(j >= 0) and np.all(j <= 1)
    assert np.allclose(j, j.T)
    assert np.all(np.diag(j) == 0)


def test_signed_instance_nonzero_weights_and_signs():
    adj = generators.small_world(40, seed=2)
    dissim, w = jaccard.signed_instance(adj)
    n = adj.shape[0]
    iu = np.triu_indices(n, 1)
    assert np.all(w[iu] > 0)  # paper: every pair gets nonzero weight
    assert set(np.unique(dissim[iu])) <= {0.0, 1.0}


def test_edgelist_roundtrip(tmp_path):
    adj = generators.collaboration_like(25, seed=3)
    p = tmp_path / "g.txt"
    io.save_edgelist(adj, str(p))
    back = io.load_edgelist(str(p))
    assert back.shape == adj.shape
    assert np.array_equal(back, adj)


def test_pivot_round_respects_lp_geometry():
    # x encoding 2 perfect clusters → rounding must recover them
    n = 10
    labels_true = np.array([0] * 5 + [1] * 5)
    x = np.where(labels_true[:, None] == labels_true[None, :], 0.0, 1.0)
    x = np.triu(x, 1)
    lab = rounding.pivot_round(x, seed=0)
    same = lab[:, None] == lab[None, :]
    true_same = labels_true[:, None] == labels_true[None, :]
    assert np.array_equal(same, true_same)


def test_end_to_end_planted_partition_recovery():
    """Full pipeline on an easy SBM: LP solve + rounding should recover the
    planted clusters and the certificate ratio should be close to 1."""
    adj, truth = generators.planted_partition(
        24, clusters=3, p_in=0.9, p_out=0.02, seed=5
    )
    dissim, w = jaccard.signed_instance(adj)
    prob = problems.correlation_clustering_lp(dissim, w, eps=0.05)
    st = ParallelSolver(prob, bucket_diagonals=4).run(passes=150)
    x = np.asarray(st.x, np.float64)
    cert = rounding.certificate(x, dissim, w, trials=8)
    # at optimality the LP certificate is ~1.0 on easy instances
    assert cert["approx_ratio_certificate"] < 1.5
    # cluster agreement (up to relabeling): pairwise same/diff agreement rate.
    # Note the CC objective may legitimately merge weakly-separated planted
    # clusters (here it prefers 2 of the 3), so we require 0.8, not 1.0.
    lab = cert["labels"]
    same = lab[:, None] == lab[None, :]
    tsame = truth[:, None] == truth[None, :]
    iu = np.triu_indices(len(lab), 1)
    agreement = np.mean(same[iu] == tsame[iu])
    assert agreement > 0.8

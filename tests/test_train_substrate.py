"""Optimizer, checkpointing, data pipeline, compression, train loop."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import common
from repro.models.model import build_model
from repro.train import checkpoint as ckpt
from repro.train import compression
from repro.train import data as data_lib
from repro.train import optimizer as opt
from repro.train.train_step import make_train_step


def test_adamw_reduces_quadratic_loss():
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = opt.init_opt_state(params)
    cfg = opt.AdamWConfig(peak_lr=0.3, warmup_steps=2, total_steps=100,
                          weight_decay=0.0)
    loss = lambda p: jnp.sum(p["w"] ** 2)
    for _ in range(60):
        grads = jax.grad(loss)(params)
        params, state, _ = opt.adamw_update(cfg, params, grads, state)
    assert float(loss(params)) < 0.2


def test_cosine_schedule_shape():
    cfg = opt.AdamWConfig(peak_lr=1.0, warmup_steps=10, total_steps=100)
    lrs = [float(opt.cosine_schedule(cfg, jnp.asarray(s))) for s in (0, 5, 10, 55, 100)]
    assert lrs[0] == 0.0 and abs(lrs[2] - 1.0) < 1e-6
    assert lrs[1] < lrs[2] and lrs[3] < lrs[2] and lrs[4] < 0.01


def test_checkpoint_roundtrip_and_retention(tmp_path):
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
            "b": {"c": jnp.ones((4,), jnp.int32)}}
    mgr = ckpt.CheckpointManager(str(tmp_path), keep=2, every=1)
    for step in (1, 2, 3, 4):
        mgr.maybe_save(step, jax.tree.map(lambda x: x * step, tree),
                       asynchronous=False)
    assert ckpt.latest_step(str(tmp_path)) == 4
    restored, manifest = ckpt.restore(str(tmp_path), tree)
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(tree["a"]) * 4)
    # retention: only 2 newest kept
    kept = [d for d in os.listdir(tmp_path) if d.startswith("step_")]
    assert len(kept) == 2


def test_checkpoint_atomicity_no_tmp_left(tmp_path):
    tree = {"x": jnp.zeros((3,))}
    ckpt.save(str(tmp_path), 7, tree)
    assert not any(d.endswith(".tmp") for d in os.listdir(tmp_path))


def test_data_determinism_and_shapes():
    cfg = data_lib.DataConfig(vocab_size=100, seq_len=16, global_batch=4, seed=3)
    ds = data_lib.make_dataset(cfg)
    b1, b2 = ds.batch(5), ds.batch(5)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]), np.asarray(b2["tokens"]))
    assert b1["tokens"].shape == (4, 17)
    assert int(b1["tokens"].max()) < 100
    b3 = ds.batch(6)
    assert not np.array_equal(np.asarray(b1["tokens"]), np.asarray(b3["tokens"]))


def test_compression_roundtrip_error_bounded():
    g = {"w": jnp.asarray(np.random.default_rng(0).normal(size=(64, 64)), jnp.float32)}
    for method, tol in (("bf16", 0.01), ("int8", 0.02)):
        out = compression.compress_decompress(g, method)
        err = float(jnp.max(jnp.abs(out["w"] - g["w"])))
        assert err < tol, (method, err)


@pytest.mark.parametrize("kwargs", [
    {},
    {"grad_compression": "int8"},
    {"microbatch": 2},
    {"remat": "full"},
])
def test_train_step_loss_decreases(kwargs):
    cfg = configs.get_smoke_config("olmo-1b").scaled(dtype=jnp.float32)
    lm = build_model(cfg)
    params = common.materialize(lm.param_specs(), jax.random.PRNGKey(0), jnp.float32)
    state = {"params": params, "opt": opt.init_opt_state(params)}
    ocfg = opt.AdamWConfig(peak_lr=3e-3, warmup_steps=2, total_steps=50)
    step = jax.jit(make_train_step(lm, ocfg, remat=kwargs.pop("remat", "none"),
                                   **kwargs))
    ds = data_lib.make_dataset(data_lib.DataConfig(
        vocab_size=cfg.vocab_size, seq_len=32, global_batch=4, seed=0))
    losses = []
    for t in range(30):
        state, m = step(state, ds.batch(t))
        losses.append(float(m["loss"]))
    assert np.isfinite(losses).all()
    # int8 gradient compression converges slightly slower in 30 smoke steps
    # (seeded decrease ≈ 0.186 vs ≈ 0.25+ uncompressed) — the assertion is
    # "loss decreases meaningfully", so the margin accommodates it.
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.15, losses[:3] + losses[-3:]


def test_train_launcher_resume(tmp_path):
    """Kill/restart fault-tolerance: run 6 steps, 'crash', resume to 12 —
    loss trajectory must continue (checkpoint + deterministic data)."""
    from repro.launch import train as train_launcher

    args = ["--arch", "olmo-1b", "--smoke", "--steps", "6", "--batch", "2",
            "--seq", "16", "--ckpt-dir", str(tmp_path), "--ckpt-every", "3",
            "--log-every", "100"]
    l1 = train_launcher.main(args)
    args12 = [a if a != "6" else "12" for a in args]
    l2 = train_launcher.main(args12)  # resumes from step 6
    assert len(l2) == 6  # only the new steps ran
    full = train_launcher.main(
        ["--arch", "olmo-1b", "--smoke", "--steps", "12", "--batch", "2",
         "--seq", "16", "--log-every", "100"])
    # resumed trajectory ends near the uninterrupted one
    assert abs(l2[-1] - full[-1]) < 0.15

"""Scale campaign: largest-n solved per device count → BENCH_scale.json.

The ROADMAP's named success artifact for the paper's headline-scale item
(2.9e12 triangle constraints at n ≈ 2.6e4, arXiv 1901.10084). Per device
count p the campaign walks the n ladder upward until the **modeled
per-device dual-slab footprint** crosses the budget — 3·C(n,3) f32 duals
sharded p ways is the state that actually scales with the mesh
(DESIGN.md §14); the replicated (n, n) planes are identical at every p —
and for each feasible n records:

  * amortized per-pass time of the fused sharded runner (warm),
  * one warm kernel-backed stopping-probe evaluation (the lane-blocked
    Pallas slab kernel + pmax routed by ``use_kernel``),
  * peak live device bytes (``launch.mesh.device_memory_bytes``),
  * the (viol, gap) certificate of a ``run_until`` solve,
  * the donated-snapshot overlap: wall time of a blocking host-transfer
    ``save`` vs the caller-visible dispatch of ``save_async(donate=True)``
    (the difference is solve time reclaimed per checkpoint).

Cube-root law: the budget binds at 3·C(n,3)·4/p ≈ n³·2/p bytes, so
largest-n grows like (p·B)^(1/3) — doubling largest-n needs 8× the
devices, which is exactly the 1 → 8 device leg asserted in CI and the
acceptance bar (largest-n at p=8 ≥ 2× p=1).

One subprocess per device count (jax locks the device count at backend
init; same pattern as fig6_cores). Modes:

  * ``run()`` / ``--smoke``: KB-scale budget, ladder capped at 256 —
    seconds per count, safe for the CI benchmark-smoke leg.
  * ``--full`` (or env REPRO_SCALE_FULL=1): the checked-in artifact's
    budget (2 MB/device → largest-n 96/128/192 at p=1/4/8).

Writes BENCH_scale.json (repo root) and prints one ``BENCH_scale`` row
per (p, n) plus a ``certificate`` line per device count — the CI scale
leg greps both.
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import textwrap

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

LADDER = (16, 24, 32, 48, 64, 96, 128, 160, 192, 256, 320, 384, 512)
DEFAULT_COUNTS = (1, 4, 8)
SMOKE_BUDGET_MB = 0.032  # → largest-n 24/32/48 at p=1/4/8
FULL_BUDGET_MB = 2.0  # → largest-n 96/128/192 at p=1/4/8
SMOKE_CAP = 256  # ladder cap of the CI smoke leg


def dual_slab_bytes(n: int, itemsize: int = 4) -> int:
    """Sharded solver state that scales with n: 3·C(n,3) schedule-native
    triangle duals (DESIGN.md §3). Slab padding and the replicated (n,n)
    planes are excluded — the model ranks n per device count, it does not
    predict the allocator's peak."""
    return 3 * (n * (n - 1) * (n - 2) // 6) * itemsize


def feasible_ladder(p: int, budget_mb: float, ladder=LADDER,
                    cap: int | None = None) -> list[int]:
    """The ladder prefix whose per-device dual-slab bytes fit the budget."""
    out = []
    for n in ladder:
        if cap is not None and n > cap:
            break
        if dual_slab_bytes(n) / p > budget_mb * 1e6:
            break
        out.append(n)
    return out


_WORKER = textwrap.dedent("""
    import json, os, sys, tempfile, time
    cfg = json.loads(sys.argv[1])
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count=%d" % cfg["devices"]
    )
    import numpy as np
    import jax
    from repro.core import problems
    from repro.core.sharded_dykstra import ShardedSolver
    from repro.launch import mesh as mesh_lib
    from repro.train import checkpoint as ckpt_lib

    mesh = mesh_lib.make_global_solver_mesh()
    p = mesh.devices.size
    assert p == cfg["devices"], (p, cfg["devices"])

    for n in cfg["ladder"]:
        rng = np.random.default_rng(7)
        d = rng.random((n, n))
        d = (d + d.T) / 2
        np.fill_diagonal(d, 0)
        prob = problems.metric_nearness_l2(d)
        solver = ShardedSolver(
            prob, mesh, num_buckets=cfg["buckets"], use_kernel=True,
            probe_block_c=cfg["block_c"],
        )
        # warm the SAME multi-pass program the timing runs (the fused
        # runner compiles one scan per pass count)
        st = solver.run(passes=cfg["timed_passes"])
        jax.block_until_ready(st.x)
        t0 = time.perf_counter()
        st = solver.run(st, passes=cfg["timed_passes"])
        jax.block_until_ready(st.x)
        pass_ms = (time.perf_counter() - t0) * 1e3 / cfg["timed_passes"]
        probe = solver._probe_fn()
        jax.block_until_ready(probe(st))
        t0 = time.perf_counter()
        jax.block_until_ready(probe(st))
        probe_ms = (time.perf_counter() - t0) * 1e3
        st, info = solver.run_until(
            st, tol=cfg["tol"], max_passes=cfg["max_passes"],
            check_every=cfg["check_every"], stop_rule=cfg["stop_rule"],
        )
        mem_b, mem_src = mesh_lib.device_memory_bytes()
        tmp = tempfile.mkdtemp()
        # warm the snapshot program (jit traces once per state shape) so
        # the timed dispatch measures the steady-state caller cost
        th, st = ckpt_lib.save_async(tmp, 0, st, donate=True)
        th.join()
        ckpt_lib.wait_pending()
        t0 = time.perf_counter()
        ckpt_lib.save(tmp, 1, st)
        block_ms = (time.perf_counter() - t0) * 1e3
        t0 = time.perf_counter()
        th, st = ckpt_lib.save_async(tmp, 2, st, donate=True)
        dispatch_ms = (time.perf_counter() - t0) * 1e3
        th.join()
        ckpt_lib.wait_pending()
        print("ROW " + json.dumps(dict(
            devices=p, n=n,
            pass_ms=round(pass_ms, 3), probe_ms=round(probe_ms, 3),
            peak_live_bytes=int(mem_b), mem_source=mem_src,
            dual_slab_bytes_per_device=cfg["model_bytes"][str(n)],
            viol=float(info["max_violation"]),
            gap=float(info["duality_gap"]),
            converged=bool(info["converged"]), passes=int(info["passes"]),
            snapshot_block_ms=round(block_ms, 3),
            snapshot_dispatch_ms=round(dispatch_ms, 3),
        )), flush=True)
    print("WORKER_DONE", flush=True)
""")


def _campaign(counts, budget_mb, cap, *, buckets=3, block_c=None,
              tol=2e-3, max_passes=200, check_every=10,
              stop_rule="rel_gap", timed_passes=3, timeout=2400):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env.pop("XLA_FLAGS", None)  # each worker pins its own device count
    rows = []
    for p in counts:
        ladder = feasible_ladder(p, budget_mb, cap=cap)
        if not ladder:
            rows.append(dict(devices=p, error="empty ladder", ladder=[]))
            continue
        cfg = dict(
            devices=p, ladder=ladder, buckets=buckets, block_c=block_c,
            tol=tol, max_passes=max_passes, check_every=check_every,
            stop_rule=stop_rule, timed_passes=timed_passes,
            model_bytes={str(n): dual_slab_bytes(n) // p for n in ladder},
        )
        out = subprocess.run(
            [sys.executable, "-c", _WORKER, json.dumps(cfg)],
            capture_output=True, text=True, env=env, cwd=ROOT,
            timeout=timeout,
        )
        if out.returncode != 0 or "WORKER_DONE" not in out.stdout:
            rows.append(dict(
                devices=p, error=(out.stderr or out.stdout)[-500:],
                ladder=[],
            ))
            continue
        per_n = [
            json.loads(line[len("ROW "):])
            for line in out.stdout.splitlines()
            if line.startswith("ROW ")
        ]
        top = per_n[-1]
        rows.append(dict(
            devices=p, largest_n=top["n"], pass_ms=top["pass_ms"],
            probe_ms=top["probe_ms"],
            peak_live_bytes=top["peak_live_bytes"],
            viol=top["viol"], gap=top["gap"], converged=top["converged"],
            snapshot_block_ms=top["snapshot_block_ms"],
            snapshot_dispatch_ms=top["snapshot_dispatch_ms"],
            ladder=per_n,
        ))
    return rows


def _report(rows, mode, budget_mb, json_path):
    for row in rows:
        if "error" in row:
            print(f"BENCH_scale p={row['devices']} FAILED {row['error']}")
            continue
        for r in row["ladder"]:
            print(
                f"BENCH_scale p={r['devices']} n={r['n']} "
                f"pass_ms={r['pass_ms']:.1f} probe_ms={r['probe_ms']:.1f} "
                f"peak_mb={r['peak_live_bytes'] / 1e6:.1f} "
                f"snapshot_block_ms={r['snapshot_block_ms']:.1f} "
                f"snapshot_dispatch_ms={r['snapshot_dispatch_ms']:.1f}"
            )
        print(
            f"certificate p={row['devices']} largest_n={row['largest_n']} "
            f"viol={row['viol']:.3e} gap={row['gap']:.3e} "
            f"converged={row['converged']}"
        )
    doc = dict(mode=mode, budget_mb=budget_mb, ladder=list(LADDER),
               rows=rows)
    with open(json_path, "w") as fh:
        json.dump(doc, fh, indent=1)
    print(f"wrote {json_path}")
    return doc


def run() -> list[dict]:
    """benchmarks.run registry entry: the smoke campaign (full with env
    REPRO_SCALE_FULL=1), BENCH_scale.json written as a side effect."""
    full = os.environ.get("REPRO_SCALE_FULL") == "1"
    budget = FULL_BUDGET_MB if full else SMOKE_BUDGET_MB
    cap = None if full else SMOKE_CAP
    rows = _campaign(DEFAULT_COUNTS, budget, cap)
    _report(rows, "full" if full else "smoke", budget,
            os.path.join(ROOT, "BENCH_scale.json"))
    out = []
    for row in rows:
        if "error" in row:
            out.append(dict(name=f"scale/p{row['devices']}", us_per_call=-1,
                            derived="FAILED " + row["error"][:200]))
            continue
        out.append(dict(
            name=f"scale/p{row['devices']}",
            us_per_call=row["pass_ms"] * 1e3,
            derived=(
                f"largest_n={row['largest_n']} "
                f"probe_ms={row['probe_ms']:.1f} "
                f"peak_mb={row['peak_live_bytes'] / 1e6:.1f} "
                f"converged={row['converged']} "
                f"snapshot_overlap_ms="
                f"{row['snapshot_block_ms'] - row['snapshot_dispatch_ms']:.1f}"
            ),
        ))
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="KB-scale budget, ladder capped (CI leg)")
    ap.add_argument("--full", action="store_true",
                    help="the checked-in artifact's budget")
    ap.add_argument("--budget-mb", type=float, default=None,
                    help="override the per-device dual-slab budget")
    ap.add_argument("--counts", default=None,
                    help="comma-separated device counts (default 1,4,8)")
    ap.add_argument("--json", default=os.path.join(ROOT, "BENCH_scale.json"))
    ap.add_argument("--max-passes", type=int, default=200)
    ap.add_argument("--tol", type=float, default=2e-3)
    args = ap.parse_args(argv)
    if args.full or os.environ.get("REPRO_SCALE_FULL") == "1":
        mode, budget, cap = "full", FULL_BUDGET_MB, None
    else:
        mode, budget, cap = "smoke", SMOKE_BUDGET_MB, SMOKE_CAP
    if args.budget_mb is not None:
        budget = args.budget_mb
    counts = (
        tuple(int(c) for c in args.counts.split(","))
        if args.counts else DEFAULT_COUNTS
    )
    rows = _campaign(counts, budget, cap, tol=args.tol,
                     max_passes=args.max_passes)
    _report(rows, mode, budget, args.json)
    return 0 if all("error" not in r for r in rows) else 1


if __name__ == "__main__":
    raise SystemExit(main())

"""Benchmark harness — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only substr]

Prints ``name,us_per_call,derived`` CSV. Mapping to the paper:
  table1_speedup   → Table I   (fixed-pass serial vs parallel)
  fig6_cores       → Fig. 6    (processor-count sweep, subprocesses)
  fig7_tilesize    → Fig. 7    (tile/bucket-size sweep)
  ordering_effect  → §IV.D     (constraint-order vs convergence)
  kernel_sweep     → §III.C    (Pallas tile kernel)
  roofline_table   → EXPERIMENTS.md §Roofline (dry-run aggregation)
"""

from __future__ import annotations

import argparse
import sys
import traceback

from benchmarks import (
    fig6_cores,
    fig7_tilesize,
    kernel_sweep,
    ordering_effect,
    roofline_table,
    table1_speedup,
)

MODULES = [
    ("table1_speedup", table1_speedup),
    ("fig7_tilesize", fig7_tilesize),
    ("ordering_effect", ordering_effect),
    ("kernel_sweep", kernel_sweep),
    ("fig6_cores", fig6_cores),
    ("roofline_table", roofline_table),
]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    args = ap.parse_args(argv)
    print("name,us_per_call,derived")
    failed = 0
    for name, mod in MODULES:
        if args.only and args.only not in name:
            continue
        try:
            for row in mod.run():
                derived = str(row.get("derived", "")).replace(",", ";")
                print(f"{row['name']},{row['us_per_call']:.1f},{derived}")
        except Exception:  # noqa: BLE001
            failed += 1
            print(f"{name},-1,EXCEPTION")
            traceback.print_exc()
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())

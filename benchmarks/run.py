"""Benchmark harness — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only substr] [--json OUT]

Prints ``name,us_per_call,derived`` CSV; ``--json OUT`` additionally writes
a machine-readable ``{name: us_per_call}`` map (plus a ``derived`` section)
so the perf trajectory is comparable across PRs — by convention the file is
checked in as ``BENCH_solver.json``. Mapping to the paper:
  table1_speedup   → Table I   (fixed-pass serial vs parallel)
  fig6_cores       → Fig. 6    (processor-count sweep, subprocesses)
  fig7_tilesize    → Fig. 7    (tile/bucket-size sweep)
  ordering_effect  → §IV.D     (constraint-order vs convergence)
  kernel_sweep     → §III.C    (Pallas tile kernel)
  convergence_probe→ DESIGN.md §7 (host vs device metrics, solve-to-tol)
  serve_throughput → DESIGN.md §8 (batched vs sequential solve service;
                     also writes BENCH_serve.json)
  sharded_runtime  → DESIGN.md §9 (sharded fused scan vs host-looped
                     baseline, per pass)
  sparsify_decay   → DESIGN.md §13 (Project-and-Forget active-set decay:
                     pass time and active fraction vs the dense baseline)
  roofline_table   → EXPERIMENTS.md §Roofline (dry-run aggregation;
                     REPRO_ROOFLINE_DRYRUN=1 compiles the smallest cell)
  scale_campaign   → DESIGN.md §14 (largest-n per device count; smoke
                     budget by default, REPRO_SCALE_FULL=1 for the
                     checked-in BENCH_scale.json budget)
"""

from __future__ import annotations

import argparse
import json
import sys
import traceback

from benchmarks import (
    convergence_probe,
    fig6_cores,
    fig7_tilesize,
    kernel_sweep,
    ordering_effect,
    roofline_table,
    scale_campaign,
    serve_throughput,
    sharded_runtime,
    sparsify_decay,
    table1_speedup,
)

MODULES = [
    ("table1_speedup", table1_speedup),
    ("fig7_tilesize", fig7_tilesize),
    ("ordering_effect", ordering_effect),
    ("kernel_sweep", kernel_sweep),
    ("convergence_probe", convergence_probe),
    ("serve_throughput", serve_throughput),
    ("sharded_runtime", sharded_runtime),
    ("sparsify_decay", sparsify_decay),
    ("fig6_cores", fig6_cores),
    ("scale_campaign", scale_campaign),
    ("roofline_table", roofline_table),
]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--json", default=None, metavar="OUT",
                    help="write {name: us_per_call} JSON (BENCH_solver.json)")
    args = ap.parse_args(argv)
    print("name,us_per_call,derived")
    failed = 0
    results: dict[str, float] = {}
    derived_map: dict[str, str] = {}
    for name, mod in MODULES:
        if args.only and args.only not in name:
            continue
        try:
            for row in mod.run():
                derived = str(row.get("derived", "")).replace(",", ";")
                print(f"{row['name']},{row['us_per_call']:.1f},{derived}")
                results[row["name"]] = round(float(row["us_per_call"]), 1)
                if derived:
                    derived_map[row["name"]] = derived
        except Exception:  # noqa: BLE001
            failed += 1
            print(f"{name},-1,EXCEPTION")
            traceback.print_exc()
    if args.json:
        with open(args.json, "w") as fh:
            json.dump(
                {"us_per_call": results, "derived": derived_map},
                fh, indent=2, sort_keys=True,
            )
            fh.write("\n")
        print(f"wrote {args.json}", file=sys.stderr)
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())

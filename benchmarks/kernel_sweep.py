"""Pallas kernel micro-benchmarks.

Three generations of the metric-projection sweep:
  * legacy unfolded ``sweep_pallas`` (one diagonal, six dual buffers) with
    a block_c sweep — the VMEM tile, paper Fig. 7's knob at kernel level;
  * ``ops.diagonal_sweep_slab`` — the folded schedule-native contract the
    sharded/legacy solvers actually call (duals as one (3, T, C) slab,
    in-place aliased);
  * ``ops.fused_bucket_pass`` — the whole-bucket fused-pass megakernel
    (DESIGN.md §4/§10), timed against its jnp reference on a real bucket;
  * the gen-3 **batched** path — one megakernel call per bucket covering
    a whole (B, ...) serve batch (DESIGN.md §10), timed per pass against
    the vmapped jnp fused reference it replaced as
    ``BatchedSolver``'s kernel path.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.metric_project import ops, ref
from repro.kernels.metric_project.metric_project import sweep_pallas

T, C = 64, 512
BLOCKS = (32, 128, 256)
FUSED_N = 32


def _slab_inputs(rng):
    """Folded slab-contract inputs: every lane packs two segments
    head-to-tail (s1 + s2 = T, all steps active)."""
    mk = lambda *s: jnp.asarray(rng.uniform(0, 1, s), jnp.float32)
    s1 = rng.integers(1, T, size=(C,))
    seg = jnp.asarray(np.arange(T)[:, None] >= s1[None, :])
    active = jnp.ones((T, C), bool)
    return (mk(T, C), mk(T, C), mk(2, C), mk(3, T, C),
            mk(T, C) + 0.5, mk(T, C) + 0.5, mk(2, C) + 0.5, active, seg)


def _fused_bucket_case():
    """A real staged bucket at n = FUSED_N for the megakernel benchmark."""
    from repro.core import problems
    from repro.core.parallel_dykstra import ParallelSolver

    rng = np.random.default_rng(2)
    d = np.triu(rng.uniform(0, 1, (FUSED_N, FUSED_N)), k=1)
    solver = ParallelSolver(problems.metric_nearness_l2(d),
                            bucket_diagonals=2)
    st = solver.run(passes=1)
    return solver.staged_buckets[0], st.x, st.yd[0]


def run() -> list[dict]:
    rng = np.random.default_rng(0)
    mk = lambda *s: jnp.asarray(rng.uniform(0, 1, s), jnp.float32)
    args = (mk(T, C), mk(T, C), mk(C), mk(T, C), mk(T, C), mk(T, C),
            mk(T, C) + 0.5, mk(T, C) + 0.5, mk(C) + 0.5,
            jnp.ones((T, C), bool))
    rows = []
    ref_out = ref.sweep_ref(*args, 1.0)

    jref = jax.jit(lambda *a: ref.sweep_ref(*a, 1.0))
    jref(*args)[0].block_until_ready()
    t0 = time.perf_counter()
    for _ in range(10):
        jref(*args)[0].block_until_ready()
    t_ref = (time.perf_counter() - t0) / 10
    rows.append(dict(name="kernel/ref_jnp", us_per_call=t_ref * 1e6,
                     derived=f"T={T} C={C}"))

    for bc in BLOCKS:
        out = sweep_pallas(*args, 1.0, block_c=bc, interpret=True)
        err = max(float(np.abs(np.asarray(a) - np.asarray(b)).max())
                  for a, b in zip(ref_out, out))
        t0 = time.perf_counter()
        sweep_pallas(*args, 1.0, block_c=bc, interpret=True)[0].block_until_ready()
        dt = time.perf_counter() - t0
        rows.append(dict(
            name=f"kernel/pallas_bc{bc}", us_per_call=dt * 1e6,
            derived=f"interpret-mode err={err:.1e} "
                    f"(TPU target: VMEM/block={12 * T * bc * 4 / 1024:.0f}KiB)",
        ))

    # --- folded slab contract: what the sharded/legacy solvers call.
    sargs = _slab_inputs(rng)
    slab_ref = ref.sweep_ref_slab(*sargs, 1.0)
    out = ops.diagonal_sweep_slab(*sargs, 1.0)  # compile + warm the jit cache
    err = max(float(np.abs(np.asarray(a) - np.asarray(b)).max())
              for a, b in zip(slab_ref, out))
    t0 = time.perf_counter()
    ops.diagonal_sweep_slab(*sargs, 1.0)[0].block_until_ready()
    dt = time.perf_counter() - t0
    rows.append(dict(
        name="kernel/slab_folded", us_per_call=dt * 1e6,
        derived=f"interpret-mode err={err:.1e} folded 2-carry in-place duals",
    ))

    # --- fused-pass megakernel on a real staged bucket.
    from repro.kernels.metric_project.ref import fused_bucket_pass_ref

    bucket, x, yslab = _fused_bucket_case()
    fx, fy = fused_bucket_pass_ref(x, yslab, bucket)
    kx, ky = ops.fused_bucket_pass(x, yslab, bucket)  # compile + warm
    err = float(np.abs(np.asarray(fx) - np.asarray(kx)).max())
    D = yslab.shape[0]
    t0 = time.perf_counter()
    ops.fused_bucket_pass(x, yslab, bucket)[0].block_until_ready()
    dt = time.perf_counter() - t0
    rows.append(dict(
        name="kernel/fused_bucket", us_per_call=dt * 1e6,
        derived=f"interpret-mode x_err={err:.1e} n={FUSED_N} "
                f"diagonals={D} launches_replaced={D}",
    ))

    # --- gen-3 batched megakernel vs the vmapped jnp reference it
    # replaced: one full batch pass (triangle sweeps + pair step) of a
    # real B-instance serve bucket through each engine. Two shapes: the
    # original B=4 n=24 micro case, and the serve-shaped B=8 n=96 bucket
    # the sustained-load benchmark runs at (DESIGN.md §12), where the
    # larger triangles give the megakernel real work to amortize its
    # launch overhead against.
    from repro.core import problems as probs_lib
    from repro.serve import batching as bk, buckets as bkts

    rng2 = np.random.default_rng(7)
    for B, BN, nbuckets, reps in ((4, 24, 3, 10), (8, 96, 6, 3)):
        insts = []
        for b in range(B):
            nb = BN - 2 * (b % 2)
            dm = np.triu(rng2.uniform(0, 1, (nb, nb)), k=1)
            insts.append(probs_lib.metric_nearness_l2(dm))
        fam = bkts.family_of(insts[0], np.float32)
        jsolver = bk.BatchedSolver(BN, B, fam, num_buckets=nbuckets)
        ksolver = bk.BatchedSolver(BN, B, fam, num_buckets=nbuckets,
                                   use_kernel=True)
        inst = jsolver.stack(insts)
        st = jsolver.init_state(inst)
        aux = jax.vmap(jsolver._aux_one)(inst.w, inst.n_real)
        jpass = jax.jit(lambda s: jax.vmap(jsolver._pass_one,
                                           in_axes=(0, 0, 0))(s, inst, aux))
        kpass = jax.jit(lambda s: ksolver._pass_batch(s, inst, aux))
        sj, sk = jpass(st), kpass(st)  # compile + warm both engines
        err = float(np.abs(np.asarray(sj.x) - np.asarray(sk.x)).max())

        def best_of(f, reps=reps, rounds=3):
            best = np.inf
            for _ in range(rounds):
                t0 = time.perf_counter()
                for _ in range(reps):
                    f(st).x.block_until_ready()
                best = min(best, (time.perf_counter() - t0) / reps)
            return best

        t_j, t_k = best_of(jpass), best_of(kpass)
        suffix = f"_B{B}n{BN}" if BN != 24 else ""
        rows.append(dict(
            name=f"kernel/batched_vmap_ref{suffix}", us_per_call=t_j * 1e6,
            derived=f"B={B} bucket_n={BN} vmapped jnp fused pass",
        ))
        rows.append(dict(
            name=f"kernel/batched_gen3{suffix}", us_per_call=t_k * 1e6,
            derived=f"B={B} bucket_n={BN} one megakernel call per bucket "
                    f"x_err={err:.1e} speedup_vs_vmap={t_j / t_k:.2f}x",
        ))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)

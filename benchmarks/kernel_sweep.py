"""Pallas kernel micro-benchmarks.

Three generations of the metric-projection sweep:
  * legacy unfolded ``sweep_pallas`` (one diagonal, six dual buffers) with
    a block_c sweep — the VMEM tile, paper Fig. 7's knob at kernel level;
  * ``ops.diagonal_sweep_slab`` — the folded schedule-native contract the
    sharded/legacy solvers actually call (duals as one (3, T, C) slab,
    in-place aliased);
  * ``ops.fused_bucket_pass`` — the whole-bucket fused-pass megakernel
    (DESIGN.md §4), timed against its jnp reference on a real bucket.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.metric_project import ops, ref
from repro.kernels.metric_project.metric_project import sweep_pallas

T, C = 64, 512
BLOCKS = (32, 128, 256)
FUSED_N = 32


def _slab_inputs(rng):
    """Folded slab-contract inputs: every lane packs two segments
    head-to-tail (s1 + s2 = T, all steps active)."""
    mk = lambda *s: jnp.asarray(rng.uniform(0, 1, s), jnp.float32)
    s1 = rng.integers(1, T, size=(C,))
    seg = jnp.asarray(np.arange(T)[:, None] >= s1[None, :])
    active = jnp.ones((T, C), bool)
    return (mk(T, C), mk(T, C), mk(2, C), mk(3, T, C),
            mk(T, C) + 0.5, mk(T, C) + 0.5, mk(2, C) + 0.5, active, seg)


def _fused_bucket_case():
    """A real staged bucket at n = FUSED_N for the megakernel benchmark."""
    from repro.core import problems
    from repro.core.parallel_dykstra import ParallelSolver

    rng = np.random.default_rng(2)
    d = np.triu(rng.uniform(0, 1, (FUSED_N, FUSED_N)), k=1)
    solver = ParallelSolver(problems.metric_nearness_l2(d),
                            bucket_diagonals=2)
    st = solver.run(passes=1)
    return solver.staged_buckets[0], st.x, st.yd[0]


def run() -> list[dict]:
    rng = np.random.default_rng(0)
    mk = lambda *s: jnp.asarray(rng.uniform(0, 1, s), jnp.float32)
    args = (mk(T, C), mk(T, C), mk(C), mk(T, C), mk(T, C), mk(T, C),
            mk(T, C) + 0.5, mk(T, C) + 0.5, mk(C) + 0.5,
            jnp.ones((T, C), bool))
    rows = []
    ref_out = ref.sweep_ref(*args, 1.0)

    jref = jax.jit(lambda *a: ref.sweep_ref(*a, 1.0))
    jref(*args)[0].block_until_ready()
    t0 = time.perf_counter()
    for _ in range(10):
        jref(*args)[0].block_until_ready()
    t_ref = (time.perf_counter() - t0) / 10
    rows.append(dict(name="kernel/ref_jnp", us_per_call=t_ref * 1e6,
                     derived=f"T={T} C={C}"))

    for bc in BLOCKS:
        out = sweep_pallas(*args, 1.0, block_c=bc, interpret=True)
        err = max(float(np.abs(np.asarray(a) - np.asarray(b)).max())
                  for a, b in zip(ref_out, out))
        t0 = time.perf_counter()
        sweep_pallas(*args, 1.0, block_c=bc, interpret=True)[0].block_until_ready()
        dt = time.perf_counter() - t0
        rows.append(dict(
            name=f"kernel/pallas_bc{bc}", us_per_call=dt * 1e6,
            derived=f"interpret-mode err={err:.1e} "
                    f"(TPU target: VMEM/block={12 * T * bc * 4 / 1024:.0f}KiB)",
        ))

    # --- folded slab contract: what the sharded/legacy solvers call.
    sargs = _slab_inputs(rng)
    slab_ref = ref.sweep_ref_slab(*sargs, 1.0)
    out = ops.diagonal_sweep_slab(*sargs, 1.0)  # compile + warm the jit cache
    err = max(float(np.abs(np.asarray(a) - np.asarray(b)).max())
              for a, b in zip(slab_ref, out))
    t0 = time.perf_counter()
    ops.diagonal_sweep_slab(*sargs, 1.0)[0].block_until_ready()
    dt = time.perf_counter() - t0
    rows.append(dict(
        name="kernel/slab_folded", us_per_call=dt * 1e6,
        derived=f"interpret-mode err={err:.1e} folded 2-carry in-place duals",
    ))

    # --- fused-pass megakernel on a real staged bucket.
    from repro.kernels.metric_project.ref import fused_bucket_pass_ref

    bucket, x, yslab = _fused_bucket_case()
    fx, fy = fused_bucket_pass_ref(x, yslab, bucket)
    kx, ky = ops.fused_bucket_pass(x, yslab, bucket)  # compile + warm
    err = float(np.abs(np.asarray(fx) - np.asarray(kx)).max())
    D = yslab.shape[0]
    t0 = time.perf_counter()
    ops.fused_bucket_pass(x, yslab, bucket)[0].block_until_ready()
    dt = time.perf_counter() - t0
    rows.append(dict(
        name="kernel/fused_bucket", us_per_call=dt * 1e6,
        derived=f"interpret-mode x_err={err:.1e} n={FUSED_N} "
                f"diagonals={D} launches_replaced={D}",
    ))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)

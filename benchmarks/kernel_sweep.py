"""Pallas kernel micro-benchmark: diagonal sweep, ref-vs-kernel agreement and
block_c sweep (the VMEM tile — paper Fig. 7's knob at the kernel level)."""

from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.kernels.metric_project import ref
from repro.kernels.metric_project.metric_project import sweep_pallas

T, C = 64, 512
BLOCKS = (32, 128, 256)


def run() -> list[dict]:
    rng = np.random.default_rng(0)
    mk = lambda *s: jnp.asarray(rng.uniform(0, 1, s), jnp.float32)
    args = (mk(T, C), mk(T, C), mk(C), mk(T, C), mk(T, C), mk(T, C),
            mk(T, C) + 0.5, mk(T, C) + 0.5, mk(C) + 0.5,
            jnp.ones((T, C), bool))
    rows = []
    ref_out = ref.sweep_ref(*args, 1.0)

    import jax
    jref = jax.jit(lambda *a: ref.sweep_ref(*a, 1.0))
    jref(*args)[0].block_until_ready()
    t0 = time.perf_counter()
    for _ in range(10):
        jref(*args)[0].block_until_ready()
    t_ref = (time.perf_counter() - t0) / 10
    rows.append(dict(name="kernel/ref_jnp", us_per_call=t_ref * 1e6,
                     derived=f"T={T} C={C}"))

    for bc in BLOCKS:
        out = sweep_pallas(*args, 1.0, block_c=bc, interpret=True)
        err = max(float(np.abs(np.asarray(a) - np.asarray(b)).max())
                  for a, b in zip(ref_out, out))
        t0 = time.perf_counter()
        sweep_pallas(*args, 1.0, block_c=bc, interpret=True)[0].block_until_ready()
        dt = time.perf_counter() - t0
        rows.append(dict(
            name=f"kernel/pallas_bc{bc}", us_per_call=dt * 1e6,
            derived=f"interpret-mode err={err:.1e} "
                    f"(TPU target: VMEM/block={12 * T * bc * 4 / 1024:.0f}KiB)",
        ))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)

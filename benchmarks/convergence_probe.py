"""Convergence-monitoring cost (DESIGN.md §7).

Three probe flavours on the same n=96 state, then end-to-end
solve-to-tolerance:

  convergence/host-report    — the float64 numpy oracle (`solver.metrics`):
                               full host transfer + blocked apex loop.
  convergence/device-report  — the device engine (`solver.device_metrics`):
                               one jitted program, one scalar sync.
  convergence/inloop-probe   — marginal cost of the stopping-pair probe
                               *inside* the run_until while_loop, per pass
                               (run_until at check_every=1 minus the plain
                               fused runner).
  convergence/solve-to-tol   — wall-clock of a full n=96 CC-LP solve to
                               tolerance: the PR-2 host-driven chunk loop
                               (chunked `run` + host metrics per chunk)
                               vs one `run_until` device program.
"""

from __future__ import annotations

import time

import jax

from repro.core import problems
from repro.core.parallel_dykstra import ParallelSolver
from repro.graphs import generators, jaccard

N = 96
EPS = 0.05
# Stopping pair tolerance for the e2e row: Dykstra closes the duality gap
# slowly on CC-LPs, so full 1e-4 convergence is thousands of passes; 2.0
# stops both drivers at the same mid-solve chunk (~60 passes) — enough to
# compare the loop drivers end to end without a multi-minute benchmark.
TOL = 2.0
CHUNK = 10
MAX_PASSES = 120


def _cc_instance(n: int, seed: int = 0):
    adj, _ = generators.planted_partition(n, seed=seed)
    dissim, weights = jaccard.signed_instance(adj)
    return problems.correlation_clustering_lp(dissim, weights, eps=EPS)


def _time(fn, reps: int) -> float:
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return (time.perf_counter() - t0) / reps


def run() -> list[dict]:
    prob = _cc_instance(N)
    solver = ParallelSolver(prob, bucket_diagonals=6)
    st = solver.run(passes=5)
    jax.block_until_ready(st.x)

    # --- host oracle report (includes the device→host transfer it needs)
    t_host = _time(lambda: solver.metrics(st), 3)

    # --- device engine report
    solver.device_metrics(st)  # compile
    t_dev = _time(lambda: solver.device_metrics(st), 10)

    # --- marginal in-loop probe cost per pass: run_until probing every
    # pass (tol=0 → never stops) vs the plain fused multi-pass runner.
    P = 10
    solver.run(st, passes=P)  # compile the P-pass runner
    t_plain = _time(lambda: jax.block_until_ready(solver.run(st, passes=P).x), 2) / P
    tgt = int(st.passes) + P
    solver.run_until(st, tol=0.0, max_passes=tgt, check_every=1)  # compile
    t_until = _time(
        lambda: jax.block_until_ready(
            solver.run_until(st, tol=0.0, max_passes=tgt, check_every=1)[0].x
        ), 2,
    ) / P
    probe_per_pass = max(t_until - t_plain, 0.0)

    rows = [
        dict(name="convergence/host-report",
             us_per_call=t_host * 1e6,
             derived=f"n={N} float64 oracle (transfer + blocked apex loop)"),
        dict(name="convergence/device-report",
             us_per_call=t_dev * 1e6,
             derived=f"n={N} speedup_vs_host={t_host / t_dev:.1f}x "
                     "one jitted program; one scalar sync"),
        dict(name="convergence/inloop-probe",
             us_per_call=probe_per_pass * 1e6,
             derived=f"marginal stopping-pair cost per pass inside "
                     f"run_until (vs {t_host * 1e6:.0f}us host report); "
                     f"plain_pass={t_plain * 1e3:.1f}ms"),
    ]

    # --- end-to-end solve to tolerance: host-driven chunk loop (PR-2
    # protocol: chunked run + full host metrics per chunk) vs run_until.
    loop_solver = ParallelSolver(prob, bucket_diagonals=6)
    loop_solver.run(passes=CHUNK)  # compile the chunk runner

    def host_loop():
        s = loop_solver.init_state()
        done = 0
        while done < MAX_PASSES:
            s = loop_solver.run(s, passes=CHUNK)
            done += CHUNK
            m = loop_solver.metrics(s)
            if m["max_violation"] < TOL and abs(m["duality_gap"]) < TOL:
                break
        return s, done

    t0 = time.perf_counter()
    _, host_passes = host_loop()
    t_loop = time.perf_counter() - t0

    until_solver = ParallelSolver(prob, bucket_diagonals=6)
    until_solver.run_until(
        until_solver.init_state(), tol=TOL, max_passes=CHUNK,
        check_every=CHUNK,
    )  # compile the while_loop runner
    t0 = time.perf_counter()
    _, info = until_solver.run_until(
        tol=TOL, max_passes=MAX_PASSES, check_every=CHUNK
    )
    t_until_e2e = time.perf_counter() - t0

    rows.append(
        dict(name="convergence/solve-to-tol",
             us_per_call=t_until_e2e * 1e6,
             derived=f"n={N} CC-LP tol={TOL} run_until={t_until_e2e:.2f}s "
                     f"passes={info['passes']} converged={info['converged']} "
                     f"vs host_loop={t_loop:.2f}s ({host_passes} passes) "
                     f"speedup={t_loop / t_until_e2e:.2f}x")
    )
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)

"""Paper Fig. 7 analogue: tile-size sweep.

The paper sweeps the b×b cache tile. Our TPU analogue is the Pallas kernel's
lane-block size ``block_c`` (VMEM tile over the sets of a diagonal). We time
the kernel (interpret mode on CPU — relative block overheads still visible)
and, more portably, the pure-jnp solver with different diagonal bucket
granularities, which controls the padding waste exactly like tile choice
controls cache waste in the paper.
"""

from __future__ import annotations

import time

import jax
import numpy as np

from repro.core import problems
from repro.core.parallel_dykstra import ParallelSolver

N = 48
PASSES = 4
BUCKETS = (1, 2, 4, 8, 16, 32)


def run() -> list[dict]:
    rng = np.random.default_rng(0)
    d = np.triu(rng.uniform(0, 1, (N, N)), k=1)
    prob = problems.metric_nearness_l2(d)
    rows = []
    base = None
    ref_x = None
    for b in BUCKETS:
        solver = ParallelSolver(prob, bucket_diagonals=b)
        st = solver.run(passes=PASSES)  # compiles the P-pass fused runner
        jax.block_until_ready(st.x)
        t0 = time.perf_counter()
        st = solver.run(st, passes=PASSES)
        jax.block_until_ready(st.x)
        dt = time.perf_counter() - t0
        x = np.asarray(st.x)
        if ref_x is None:
            ref_x = x
            base = dt
        err = float(np.abs(x - ref_x).max())
        # padded-work model: Σ_bucket D_b × T_b × Cl_b folded lane-steps vs
        # Σ real triplets, straight from the ScheduleLayout slab shapes
        # (slab_shape = (procs, D, 3, T, Cl); one lane-step = 3 duals).
        waste = sum(
            bl.slab_size / 3 for bl in solver.layout.buckets
        ) / (N * (N - 1) * (N - 2) / 6)
        rows.append(dict(
            name=f"fig7/buckets{b}",
            us_per_call=dt / PASSES * 1e6,
            derived=f"rel_time={dt/base:.2f} padded_work={waste:.1f}x "
                    f"agreement={err:.0e}",
        ))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)

"""Sharded fused runtime vs the host-looped baseline (DESIGN.md §9).

Two per-pass rows on the same n=96 CC-LP over the in-process solver mesh
(every visible device; 1 on the CPU CI container — the 8-device parity is
pinned by tests/test_sharded.py in a subprocess):

  sharded/host-loop-pass — ``fused=False``: the PR-1-style baseline
                           (runtime weight division in the per-device
                           sweep, one jitted dispatch + host sync per
                           pass).
  sharded/fused-pass     — ``fused=True`` (default): staged projection
                           gains in the sweep and ``run(passes=P)`` as
                           ONE jitted ``lax.scan`` of shard_map passes.

Acceptance criterion (ISSUE 5): fused ≥ 1.5x per pass. The two paths run
different (equally exact) sweep math, so the in-bench parity check is a
tolerance comparison, not bitwise.
"""

from __future__ import annotations

import time

import jax
import numpy as np

from repro.core.sharded_dykstra import ShardedSolver
from repro.launch import mesh as mesh_lib

from benchmarks.convergence_probe import _cc_instance

N = 96
PASSES = 10
BUCKETS = 6


def run() -> list[dict]:
    prob = _cc_instance(N)
    mesh = mesh_lib.make_solver_mesh()
    p = mesh.devices.size

    base = ShardedSolver(prob, mesh, num_buckets=BUCKETS, fused=False)
    st0 = base.init_state()
    jax.block_until_ready(base.run(st0, passes=1).x)  # compile
    t0 = time.perf_counter()
    st_base = base.run(st0, passes=PASSES)
    jax.block_until_ready(st_base.x)
    t_loop = (time.perf_counter() - t0) / PASSES

    fused = ShardedSolver(prob, mesh, num_buckets=BUCKETS)
    stf0 = fused.init_state()
    jax.block_until_ready(fused.run(stf0, passes=PASSES).x)  # compile runner
    t0 = time.perf_counter()
    st_fused = fused.run(stf0, passes=PASSES)
    jax.block_until_ready(st_fused.x)
    t_fused = (time.perf_counter() - t0) / PASSES

    dx = float(np.max(np.abs(np.asarray(st_fused.x) - np.asarray(st_base.x))))
    return [
        dict(name="sharded/host-loop-pass",
             us_per_call=t_loop * 1e6,
             derived=f"n={N} p={p} legacy sweep; one dispatch per pass"),
        dict(name="sharded/fused-pass",
             us_per_call=t_fused * 1e6,
             derived=f"n={N} p={p} speedup_vs_host_loop="
                     f"{t_loop / t_fused:.2f}x (criterion >=1.5x) "
                     f"one scan program for {PASSES} passes; "
                     f"parity max|dx|={dx:.1e}"),
    ]


if __name__ == "__main__":
    for r in run():
        print(r)

"""Aggregate results/dryrun/*.json into the §Roofline table (markdown + CSV
rows for benchmarks.run).

Self-contained in ``--json`` runs: when no dry-run reports exist, setting
``REPRO_ROOFLINE_DRYRUN=1`` compiles the smallest (arch × shape) cell in a
subprocess (the dryrun forces its own host device count, so it cannot run
in-process after jax initializes) and aggregates it; otherwise the module
emits one clean ``roofline/skipped`` row carrying the reason — never a
dangling "go run this" instruction with a -1 sentinel.
"""

from __future__ import annotations

import glob
import json
import os
import subprocess
import sys

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")

#: the cheapest dry-run cell — what REPRO_ROOFLINE_DRYRUN=1 compiles.
_SMOKE_CELL = ("whisper-base", "train_4k")


def _dryrun_smoke() -> bool:
    """Compile the smallest dry-run cell into RESULTS (subprocess: the
    dryrun must lock the host device count before jax init). Returns
    True if the run produced reports."""
    env = dict(os.environ, PYTHONPATH=os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src")]
        + os.environ.get("PYTHONPATH", "").split(os.pathsep)
    ))
    arch, shape = _SMOKE_CELL
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
         "--shape", shape, "--mesh", "single", "--out", RESULTS],
        env=env, capture_output=True, text=True, timeout=1200,
    )
    if proc.returncode != 0:
        print(f"roofline dryrun smoke failed:\n{proc.stderr[-2000:]}",
              file=sys.stderr)
    return proc.returncode == 0 and bool(load_reports())


def load_reports(pattern="*.json"):
    reps = []
    for f in sorted(glob.glob(os.path.join(RESULTS, pattern))):
        with open(f) as fh:
            reps.append(json.load(fh))
    return reps


def markdown_table(reps, mesh="16x16") -> str:
    lines = [
        "| arch | shape | bottleneck | t_comp (s) | t_mem (s) | t_coll (s) | "
        "useful/HLO | roofline frac |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in reps:
        if r.get("skipped") or r.get("mesh") != mesh or r.get("tag"):
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['bottleneck']} "
            f"| {r['t_compute']:.2e} | {r['t_memory']:.2e} "
            f"| {r['t_collective']:.2e} | {r['useful_flops_fraction']:.2f} "
            f"| {r['roofline_fraction']:.3f} |"
        )
    skipped = [r for r in reps if r.get("skipped") and r.get("mesh") in (mesh, "single")]
    for r in skipped:
        lines.append(f"| {r['arch']} | {r['shape']} | — skipped: {r['reason']} | | | | | |")
    return "\n".join(lines)


def run() -> list[dict]:
    if not load_reports() and os.environ.get("REPRO_ROOFLINE_DRYRUN"):
        _dryrun_smoke()
    reps = [r for r in load_reports() if not r.get("tag")]
    rows = []
    done = [r for r in reps if not r.get("skipped")]
    for r in done:
        rows.append(dict(
            name=f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}",
            us_per_call=max(r["t_compute"], r["t_memory"], r["t_collective"]) * 1e6,
            derived=f"bottleneck={r['bottleneck']} "
                    f"frac={r['roofline_fraction']:.3f} "
                    f"useful={r['useful_flops_fraction']:.2f}",
        ))
    if not rows:
        rows.append(dict(
            name="roofline/skipped", us_per_call=0.0,
            derived="skipped: no results/dryrun reports in this checkout "
                    "(LLM-scale dry-run; set REPRO_ROOFLINE_DRYRUN=1 to "
                    f"compile the {_SMOKE_CELL[0]}/{_SMOKE_CELL[1]} cell "
                    "inline)",
        ))
    return rows


if __name__ == "__main__":
    print(markdown_table(load_reports()))

"""Aggregate results/dryrun/*.json into the §Roofline table (markdown + CSV
rows for benchmarks.run)."""

from __future__ import annotations

import glob
import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")


def load_reports(pattern="*.json"):
    reps = []
    for f in sorted(glob.glob(os.path.join(RESULTS, pattern))):
        with open(f) as fh:
            reps.append(json.load(fh))
    return reps


def markdown_table(reps, mesh="16x16") -> str:
    lines = [
        "| arch | shape | bottleneck | t_comp (s) | t_mem (s) | t_coll (s) | "
        "useful/HLO | roofline frac |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in reps:
        if r.get("skipped") or r.get("mesh") != mesh or r.get("tag"):
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['bottleneck']} "
            f"| {r['t_compute']:.2e} | {r['t_memory']:.2e} "
            f"| {r['t_collective']:.2e} | {r['useful_flops_fraction']:.2f} "
            f"| {r['roofline_fraction']:.3f} |"
        )
    skipped = [r for r in reps if r.get("skipped") and r.get("mesh") in (mesh, "single")]
    for r in skipped:
        lines.append(f"| {r['arch']} | {r['shape']} | — skipped: {r['reason']} | | | | | |")
    return "\n".join(lines)


def run() -> list[dict]:
    reps = [r for r in load_reports() if not r.get("tag")]
    rows = []
    done = [r for r in reps if not r.get("skipped")]
    for r in done:
        rows.append(dict(
            name=f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}",
            us_per_call=max(r["t_compute"], r["t_memory"], r["t_collective"]) * 1e6,
            derived=f"bottleneck={r['bottleneck']} "
                    f"frac={r['roofline_fraction']:.3f} "
                    f"useful={r['useful_flops_fraction']:.2f}",
        ))
    if not rows:
        rows.append(dict(name="roofline/missing", us_per_call=-1,
                         derived="run: python -m repro.launch.dryrun"))
    return rows


if __name__ == "__main__":
    print(markdown_table(load_reports()))

"""Paper Table I analogue: time a FIXED number of Dykstra passes, serial vs
the parallel conflict-free schedule, on several graph instances.

The paper compares 1 core vs 8/16/32 cores (Julia threads). Here the serial
baseline is the scalar-loop oracle (core/dykstra.py — the '1 core' method)
and the parallel method is the vectorized diagonal-sweep solver (the TPU
adaptation). Same constraint count, same visit order, fixed pass count —
exactly the paper's §IV.D measurement protocol.
"""

from __future__ import annotations

import time

import jax
import numpy as np

from repro.core import dykstra, problems
from repro.core.parallel_dykstra import ParallelSolver
from repro.graphs import generators, jaccard

GRAPHS = [
    ("ws-small", lambda: generators.small_world(40, seed=0)),     # 'power'-like
    ("ba-small", lambda: generators.collaboration_like(40, seed=1)),  # 'ca-*'-like
    ("ba-medium", lambda: generators.collaboration_like(64, seed=2)),
]
PASSES = 5
LAYOUT_N = 96  # dense-vs-schedule-native dual layout comparison size
LAYOUT_PASSES = 3


def dual_layout_rows(n: int = LAYOUT_N, passes: int = LAYOUT_PASSES) -> list[dict]:
    """Perf-trajectory rows for the solver refactors, same schedule, same
    bucket count, fixed passes:

      dense   — legacy dense (n, n, n) ytri path (benchmarks/dense_baseline)
      native  — PR-1 schedule-native duals, per-diagonal staging + one host
                dispatch per pass (``ParallelSolver(fused=False)``)
      fused   — fused-pass execution (DESIGN.md §4): static staging slabs +
                single multi-pass ``lax.scan`` runner.
    """
    from benchmarks.dense_baseline import DenseYtriBaseline
    from repro.core import schedule as sched

    rng = np.random.default_rng(0)
    dis = np.triu(rng.uniform(0, 1, (n, n)), k=1)
    prob = problems.metric_nearness_l2(dis)

    dense = DenseYtriBaseline(prob, bucket_diagonals=6)
    carry = dense.run(passes=1)  # compile warmup
    t0 = time.perf_counter()
    carry = dense.run(carry, passes=passes)
    jax.block_until_ready(carry)
    t_dense = (time.perf_counter() - t0) / passes

    native = ParallelSolver(prob, bucket_diagonals=6, fused=False)
    st = native.run(passes=1)  # compile warmup
    t0 = time.perf_counter()
    st = native.run(st, passes=passes)
    jax.block_until_ready(st.x)
    t_native = (time.perf_counter() - t0) / passes

    fused = ParallelSolver(prob, bucket_diagonals=6)
    st = fused.run(passes=passes)  # compiles the P-pass fused runner
    jax.block_until_ready(st.x)
    t0 = time.perf_counter()
    st = fused.run(st, passes=passes)
    jax.block_until_ready(st.x)
    t_fused = (time.perf_counter() - t0) / passes

    # same fixed-pass iterate ⇒ identical X up to float error
    x_dense = np.asarray(dense.run(dense.init_state(), passes=2)[0])
    x_native = np.asarray(native.run(native.init_state(), passes=2).x)
    x_fused = np.asarray(fused.run(fused.init_state(), passes=2).x)
    err = float(np.abs(x_dense - x_native).max())
    err_fused = float(np.abs(x_dense - x_fused).max())

    dense_floats = n ** 3
    slab_floats = sum(bl.slab_size for bl in native.layout.buckets)
    real = 3 * sched.n_triplets(n)
    return [
        dict(name=f"table1/dual-layout-dense-n{n}",
             us_per_call=t_dense * 1e6,
             derived=f"dual_floats={dense_floats} per_pass={t_dense:.3f}s"),
        dict(name=f"table1/dual-layout-native-n{n}",
             us_per_call=t_native * 1e6,
             derived=f"dual_floats={slab_floats} ideal={real} "
                     f"speedup={t_dense / t_native:.2f}x "
                     f"mem_ratio={slab_floats / dense_floats:.2f} "
                     f"agreement={err:.1e}"),
        dict(name=f"table1/fused-pass-n{n}",
             us_per_call=t_fused * 1e6,
             derived=f"speedup_vs_native={t_native / t_fused:.2f}x "
                     f"speedup_vs_dense={t_dense / t_fused:.2f}x "
                     f"per_pass={t_fused:.3f}s agreement={err_fused:.1e}"),
    ]


def run() -> list[dict]:
    rows = []
    for name, gen in GRAPHS:
        adj = gen()
        n = adj.shape[0]
        dissim, w = jaccard.signed_instance(adj)
        prob = problems.correlation_clustering_lp(dissim, w, eps=0.05)
        ncon = 3 * n * (n - 1) * (n - 2) // 6

        t0 = time.perf_counter()
        st = dykstra.init_state(prob)
        for _ in range(PASSES):
            dykstra.run_pass(prob, st, order="schedule")
        t_serial = time.perf_counter() - t0

        solver = ParallelSolver(prob, bucket_diagonals=6)
        state = solver.run(passes=PASSES)  # compiles the P-pass fused runner
        jax.block_until_ready(state.x)
        t0 = time.perf_counter()
        jax.block_until_ready(solver.run(state, passes=PASSES).x)
        t_par = time.perf_counter() - t0

        # verify both computed the same thing (fixed passes ⇒ same iterate)
        st2 = dykstra.init_state(prob)
        for _ in range(PASSES + 1):
            dykstra.run_pass(prob, st2, order="schedule")
        x_par = np.asarray(solver.run(solver.init_state(), passes=PASSES + 1).x)
        err = float(np.abs(x_par - st2.x).max())

        rows.append(dict(
            name=f"table1/{name}", n=n, constraints=ncon,
            us_per_call=t_par / PASSES * 1e6,
            derived=f"speedup={t_serial / t_par:.1f}x serial={t_serial:.1f}s "
                    f"parallel={t_par:.2f}s agreement={err:.1e}",
        ))
    rows.extend(dual_layout_rows())
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)

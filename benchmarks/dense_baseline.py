"""Legacy dense-ytri solver — benchmark baseline only.

This reproduces the pre-schedule-native dual storage that ``ParallelSolver``
used before DESIGN.md §3: triangle duals in a dense ``(n, n, n)`` tensor,
re-gathered and re-scattered with random-access 3D indexing on every
diagonal (six gather/scatter pairs per diagonal). It exists so
``table1_speedup.py`` can report the dense-vs-schedule-native delta, and is
deliberately NOT part of the production package — no production code may
allocate an (n, n, n) dual tensor.

Supports the metric-nearness problem family (no pair/box constraints),
which is all the layout benchmark needs.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import schedule as sched
from repro.core.problems import MetricQP
from repro.kernels.metric_project import ref as kref

__all__ = ["DenseYtriBaseline"]


def _gather(arr, idx, fill):
    return arr.at[idx].get(mode="fill", fill_value=fill)


def _scatter_add(arr, idx, delta):
    return arr.at[idx].add(delta, mode="drop", unique_indices=True)


class DenseYtriBaseline:
    """Fixed-pass runner with dense (n, n, n) triangle duals (the old way)."""

    def __init__(self, problem: MetricQP, dtype=jnp.float32,
                 bucket_diagonals: int = 1):
        assert not problem.has_f and problem.box is None, (
            "baseline supports the plain metric-nearness family only"
        )
        self.p = problem
        self.n = problem.n
        self.dtype = dtype
        self._w = jnp.asarray(problem.w, dtype)
        s = sched.build_schedule(self.n)
        import numpy as np

        groups = np.array_split(np.arange(s.num_diagonals),
                                max(1, bucket_diagonals))
        self._buckets = []
        for g in groups:
            if len(g) == 0:
                continue
            T = int(s.max_t[g].max())
            if T <= 0:
                continue
            self._buckets.append(dict(
                i=jnp.asarray(s.diag_i[g], jnp.int32),
                k=jnp.asarray(s.diag_k[g], jnp.int32),
                sizes=jnp.asarray(
                    np.where(s.set_mask[g], s.diag_k[g] - s.diag_i[g] - 1, 0),
                    jnp.int32),
                T=T,
            ))
        self._pass_fn = jax.jit(self._one_pass)

    def init_state(self):
        n = self.n
        return (jnp.asarray(self.p.x0(), self.dtype),
                jnp.zeros((n, n, n), self.dtype))

    def _diagonal_body(self, carry, diag, T: int):
        x, ytri = carry
        i_vec, k_vec, sizes = diag["i"], diag["k"], diag["sizes"]
        C = i_vec.shape[0]
        eps = float(self.p.eps)
        t_idx = jnp.arange(T, dtype=jnp.int32)
        J = i_vec[None, :] + 1 + t_idx[:, None]
        iN = jnp.broadcast_to(i_vec[None, :], (T, C))
        kN = jnp.broadcast_to(k_vec[None, :], (T, C))
        active = (t_idx[:, None] < sizes[None, :]) & (i_vec[None, :] >= 0)
        rowb = _gather(x, (iN, J), 0.0)
        colb = _gather(x, (J, kN), 0.0)
        xik = _gather(x, (i_vec, k_vec), 0.0)
        # the traffic under test: three 3D gathers + three 3D scatters of
        # randomly-strided (T, C) index sets, every diagonal, every pass
        y0 = _gather(ytri, (iN, J, kN), 0.0)
        y1 = _gather(ytri, (iN, kN, J), 0.0)
        y2 = _gather(ytri, (J, kN, iN), 0.0)
        w_row = _gather(self._w, (iN, J), 1.0)
        w_col = _gather(self._w, (J, kN), 1.0)
        w_ik = _gather(self._w, (i_vec, k_vec), 1.0)
        nrow, ncol, nxik, n0, n1, n2 = kref.sweep_ref(
            rowb, colb, xik, y0, y1, y2, w_row, w_col, w_ik, active, eps
        )
        x = _scatter_add(x, (iN, J), jnp.where(active, nrow - rowb, 0))
        x = _scatter_add(x, (J, kN), jnp.where(active, ncol - colb, 0))
        any_active = active.any(axis=0)
        x = _scatter_add(x, (i_vec, k_vec), jnp.where(any_active, nxik - xik, 0))
        ytri = _scatter_add(ytri, (iN, J, kN), jnp.where(active, n0 - y0, 0))
        ytri = _scatter_add(ytri, (iN, kN, J), jnp.where(active, n1 - y1, 0))
        ytri = _scatter_add(ytri, (J, kN, iN), jnp.where(active, n2 - y2, 0))
        return (x, ytri), None

    def _one_pass(self, carry):
        for b in self._buckets:
            body = functools.partial(self._diagonal_body, T=b["T"])
            carry, _ = jax.lax.scan(
                body, carry, dict(i=b["i"], k=b["k"], sizes=b["sizes"])
            )
        return carry

    def run(self, carry=None, passes: int = 1):
        c = carry if carry is not None else self.init_state()
        for _ in range(passes):
            c = self._pass_fn(c)
        return c

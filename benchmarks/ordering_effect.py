"""Paper §IV.D: the effect of constraint reordering on convergence.

Dykstra converges under any fixed ordering; the paper observes the iteration
count to a fixed tolerance varies between the serial ('lex') and parallel
('schedule') orders, in either direction depending on the instance. We
measure passes-to-tolerance for both orders on several instances.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import convergence, dykstra, problems

TOL = 1e-4
MAX_PASSES = 120


def _passes_to_tol(prob, order):
    st = dykstra.init_state(prob)
    for k in range(1, MAX_PASSES + 1):
        dykstra.run_pass(prob, st, order=order)
        if convergence.max_violation(prob, st.x, st.f) <= TOL:
            return k
    return MAX_PASSES + 1


def run() -> list[dict]:
    rows = []
    rng = np.random.default_rng(0)
    for trial in range(3):
        n = 14
        # binary CC-style dissimilarities create abundant triangle violations
        d = np.triu((rng.uniform(0, 1, (n, n)) > 0.4).astype(float), k=1)
        prob = problems.metric_nearness_l2(d)
        t0 = time.perf_counter()
        k_lex = _passes_to_tol(prob, "lex")
        k_sched = _passes_to_tol(prob, "schedule")
        dt = time.perf_counter() - t0
        rows.append(dict(
            name=f"ordering/inst{trial}",
            us_per_call=dt * 1e6 / (k_lex + k_sched),
            derived=f"passes_to_{TOL}: lex={k_lex} schedule={k_sched}",
        ))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)

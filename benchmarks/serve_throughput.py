"""Serve-layer throughput: batched vs sequential solves (DESIGN.md §8).

The serving claim: for streams of same-bucket instances, one vmapped
batched runner beats per-instance solves because (a) the batch shares ONE
compiled executable — `ParallelSolver` bakes each instance's weights into
the trace as constants, so a stream of new instances pays a fresh XLA
compile *per instance*, while `BatchedSolver` takes (W, c, d) as runtime
operands — and (b) the batch fills the accelerator with one dispatch per
solve instead of B.

Protocol (acceptance: >= 3x):

  * workload: B=8 independent n=96 CC-LP instances (planted partition +
    Jaccard signing, seeds 0..7), solved to the same stopping pair.
  * sequential baseline: 8 fresh `ParallelSolver.run_until` solves, each
    timed **including its compile** — that compile is intrinsic to the
    per-instance architecture (every new weight matrix retraces).
  * batched: one warm `BatchedSolver.run_until` (compile amortized across
    the stream and reported separately), per-instance results
    parity-checked against the sequential solves.

Writes BENCH_serve.json; also registered in benchmarks.run.
"""

from __future__ import annotations

import json
import time

import jax
import numpy as np

from repro.core import problems
from repro.core.parallel_dykstra import ParallelSolver
from repro.graphs import generators, jaccard
from repro.serve import buckets as bk
from repro.serve.batching import BatchedSolver

N = 96
B = 8
EPS = 0.05
# Same mid-solve stopping pair as benchmarks/convergence_probe.py: full
# 1e-4 convergence is thousands of passes on CC-LPs; 2.0 stops every
# driver at the same chunk (~60 passes) — enough to compare end to end.
TOL = 2.0
CHUNK = 10
MAX_PASSES = 120


def _instances():
    out = []
    for seed in range(B):
        adj, _ = generators.planted_partition(N, seed=seed)
        dissim, weights = jaccard.signed_instance(adj)
        out.append(problems.correlation_clustering_lp(dissim, weights, eps=EPS))
    return out


def run() -> list[dict]:
    probs = _instances()
    kw = dict(tol=TOL, max_passes=MAX_PASSES, check_every=CHUNK)

    # --- sequential baseline: fresh solver (=> fresh compile) per instance
    t0 = time.perf_counter()
    solo_states, solo_passes = [], []
    for p in probs:
        solver = ParallelSolver(p, bucket_diagonals=6)
        st, info = solver.run_until(**kw)
        jax.block_until_ready(st.x)
        solo_states.append(np.asarray(st.x))
        solo_passes.append(info["passes"])
    t_seq = time.perf_counter() - t0
    seq_ips = B / t_seq

    # --- batched: one executable for the whole stream. Warm runs are
    # timed best-of-2 (same protocol for both batch engines): a ~8s
    # single-shot wanders ±5% with machine load, which is the size of the
    # effect the kernel-vs-vmapped comparison below is after.
    def best_of(run, rounds=2):
        best = np.inf
        for _ in range(rounds):
            t0 = time.perf_counter()
            out = run()
            jax.block_until_ready(out[0].x)
            best = min(best, time.perf_counter() - t0)
        return best, out

    fam = bk.family_of(probs[0], np.float32)
    bs = BatchedSolver(N, batch=B, family=fam, num_buckets=6)
    inst = bs.stack(probs)
    t0 = time.perf_counter()
    st, _ = bs.run_until(inst, **kw)
    jax.block_until_ready(st.x)
    t_compile_and_first = time.perf_counter() - t0
    t_batched, (st, info) = best_of(lambda: bs.run_until(inst, **kw))
    bat_ips = B / t_batched
    t_compile = t_compile_and_first - t_batched

    # --- batched on the gen-3 megakernel path (DESIGN.md §10): same
    # stream, same executable-sharing story, but every bucket's triangle
    # sweeps run as ONE pallas_call covering the whole batch.
    ks = BatchedSolver(N, batch=B, family=fam, num_buckets=6,
                       use_kernel=True)
    stk, _ = ks.run_until(inst, **kw)  # compile + warm
    jax.block_until_ready(stk.x)
    t_kernel, (stk, _) = best_of(lambda: ks.run_until(inst, **kw))
    k_ips = B / t_kernel
    kernel_dx = float(np.abs(np.asarray(stk.x) - np.asarray(st.x)).max())
    assert kernel_dx == 0.0, (
        f"kernel/vmapped batch paths diverged: {kernel_dx}"
    )

    # --- per-instance parity vs the sequential solves (float32 run; the
    # float64 1e-10 contract is pinned by tests/test_serve.py)
    xb = np.asarray(st.x)
    max_dx = max(
        float(np.abs(xb[i] - solo_states[i]).max()) for i in range(B)
    )
    pass_delta = max(
        abs(int(info["passes"][i]) - solo_passes[i]) for i in range(B)
    )
    assert max_dx < 1e-3, f"batched/solo iterates diverged: {max_dx}"
    assert pass_delta == 0, (
        f"stop passes diverged: {list(info['passes'])} vs {solo_passes}"
    )

    ratio = bat_ips / seq_ips
    rows = [
        dict(
            name="serve/sequential-8x-n96",
            us_per_call=t_seq / B * 1e6,
            derived=(
                f"n={N} B={B} tol={TOL} {t_seq:.1f}s total "
                f"({seq_ips:.3f} inst/s; per-instance compile included — "
                f"each new W retraces) passes={solo_passes[0]}"
            ),
        ),
        dict(
            name="serve/batched-B8-n96",
            us_per_call=t_batched / B * 1e6,
            derived=(
                f"n={N} B={B} tol={TOL} {t_batched:.1f}s/batch "
                f"({bat_ips:.3f} inst/s) throughput_ratio={ratio:.2f}x "
                f"(criterion >=3x) parity_max_dx={max_dx:.1e} "
                f"pass_delta={pass_delta}"
            ),
        ),
        dict(
            name="serve/batched-kernel-B8-n96",
            us_per_call=t_kernel / B * 1e6,
            derived=(
                f"gen-3 megakernel batch path (one pallas_call per "
                f"bucket per pass, DESIGN.md §10): {t_kernel:.1f}s/batch "
                f"({k_ips:.3f} inst/s) vs_vmapped="
                f"{t_batched / t_kernel:.2f}x bitwise_dx={kernel_dx:.1e}"
            ),
        ),
        dict(
            name="serve/batched-compile",
            us_per_call=t_compile * 1e6,
            derived=(
                f"one-time executable build for the (n={N}, B={B}, CC) "
                f"bucket; amortized across every later batch"
            ),
        ),
    ]
    payload = {
        "us_per_call": {r["name"]: round(float(r["us_per_call"]), 1)
                        for r in rows},
        "derived": {r["name"]: r["derived"] for r in rows},
        "throughput": {
            "sequential_ips": round(seq_ips, 4),
            "batched_ips": round(bat_ips, 4),
            "ratio": round(ratio, 2),
            "kernel_ips": round(k_ips, 4),
            "kernel_vs_vmapped": round(t_batched / t_kernel, 2),
        },
    }
    with open("BENCH_serve.json", "w") as fh:
        json.dump(payload, fh, indent=2, sort_keys=True)
        fh.write("\n")
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)

"""Serve-layer throughput: batched vs sequential solves (DESIGN.md §8),
plus the sustained-load drain-vs-continuous comparison (DESIGN.md §12).

The serving claim: for streams of same-bucket instances, one vmapped
batched runner beats per-instance solves because (a) the batch shares ONE
compiled executable — `ParallelSolver` bakes each instance's weights into
the trace as constants, so a stream of new instances pays a fresh XLA
compile *per instance*, while `BatchedSolver` takes (W, c, d) as runtime
operands — and (b) the batch fills the accelerator with one dispatch per
solve instead of B.

Protocol (acceptance: >= 3x):

  * workload: B=8 independent n=96 CC-LP instances (planted partition +
    Jaccard signing, seeds 0..7), solved to the same stopping pair.
  * sequential baseline: 8 fresh `ParallelSolver.run_until` solves, each
    timed **including its compile** — that compile is intrinsic to the
    per-instance architecture (every new weight matrix retraces).
  * batched: one warm `BatchedSolver.run_until` (compile amortized across
    the stream and reported separately), per-instance results
    parity-checked against the sequential solves.

Sustained-load protocol (acceptance: continuous occupancy >= 0.9 and
>= 1.3x drain inst/s, bitwise-equal per-instance results):

  * workload: a Poisson stream of 32 mixed-difficulty CC-LP instances
    (clean / sharp / noisy planted partitions, sizes 48..96, all bucketed
    to n=96 B=8) whose convergence spans ~10..160 passes — the
    heterogeneity that makes whole-batch draining wasteful. The stream is
    load-test shaped (ramp / sustain / cool-down): every drain group of 8
    consecutive arrivals contains at least one cap-length instance (so
    each drain batch runs at the cap while its converged slots idle),
    long jobs are front/mid-loaded, and the stream ends in a descending
    backfill so the finite stream drains without stranding slots behind
    one late straggler.
  * drain mode: the scheduler dispatches full batches and each batch runs
    until its SLOWEST slot stops; converged slots idle.
  * continuous mode: the background worker steps bounded chunks, retires
    converged slots at chunk boundaries and refills them from the queue
    (weights are runtime operands — refill never recompiles), so the
    batch stays full while the queue is non-empty.

Writes BENCH_serve.json; also registered in benchmarks.run.
"""

from __future__ import annotations

import json
import time

import jax
import numpy as np

from repro.core import problems
from repro.core.parallel_dykstra import ParallelSolver
from repro.graphs import generators, jaccard
from repro.serve import buckets as bk
from repro.serve.batching import BatchedSolver
from repro.serve.scheduler import BatchScheduler

N = 96
B = 8
EPS = 0.05
# Same mid-solve stopping pair as benchmarks/convergence_probe.py: full
# 1e-4 convergence is thousands of passes on CC-LPs; 2.0 stops every
# driver at the same chunk (~60 passes) — enough to compare end to end.
TOL = 2.0
CHUNK = 10
MAX_PASSES = 120


def _instances():
    out = []
    for seed in range(B):
        adj, _ = generators.planted_partition(N, seed=seed)
        dissim, weights = jaccard.signed_instance(adj)
        out.append(problems.correlation_clustering_lp(dissim, weights, eps=EPS))
    return out


# --- sustained load (DESIGN.md §12) ---------------------------------------
S_TOL = 1e-3
S_MAX_PASSES = 160
S_RATE = 4.0  # Poisson arrivals, instances/sec (arrivals outpace service)
#: (p_in, p_out) difficulty tiers: clean partitions converge in ~10
#: passes (1 chunk), sharp in ~40 (4 chunks; a few run to the 160 cap),
#: noisy in ~70-160 (7-16 chunks).
S_TIERS = ((1.0, 0.0), (0.95, 0.01), (0.7, 0.05))
#: The stream, in arrival order: (tier, n, seed). Convergence pass
#: counts are deterministic per spec (bitwise-reproducible solves), so
#: the stream is load-test shaped rather than shuffled per run: every
#: group of 8 consecutive arrivals (= one drain-mode batch) contains a
#: cap-length instance (each drain batch runs at the cap while its
#: converged slots idle), long jobs sit early/mid-stream, and the tail
#: descends (13, 12, 9, 4 chunks) so the last arrivals finish together
#: instead of one straggler holding 7 idle slots through its whole cap.
S_SPECS = (
    (1, 48, 19), (1, 56, 22), (2, 56, 14), (1, 64, 1),
    (1, 88, 4), (2, 64, 17), (1, 96, 16), (0, 96, 24),
    (1, 64, 25), (2, 72, 29), (0, 64, 9), (0, 72, 21),
    (2, 80, 2), (0, 56, 6), (0, 96, 0), (0, 96, 15),
    (1, 80, 10), (2, 48, 11), (0, 48, 3), (2, 88, 20),
    (0, 56, 30), (1, 96, 31), (0, 48, 27), (1, 96, 7),
    (1, 88, 28), (2, 96, 23), (0, 88, 12), (0, 80, 18),
    (2, 72, 5), (2, 80, 26), (2, 96, 8), (1, 72, 13),
)
S_STREAM = len(S_SPECS)


def _stream_problems():
    out = []
    for tier, n, seed in S_SPECS:
        p_in, p_out = S_TIERS[tier]
        adj, _ = generators.planted_partition(n, seed=seed, p_in=p_in,
                                              p_out=p_out)
        dissim, weights = jaccard.signed_instance(adj)
        out.append(problems.correlation_clustering_lp(dissim, weights, eps=EPS))
    return out


def _drive(mode: str, probs) -> dict:
    """One sustained-load run: Poisson-submit the stream into a scheduler
    in ``mode``, drain, and report throughput / occupancy / latency."""
    sched = BatchScheduler(
        ladder=(N,), batch=B, tol=S_TOL, max_passes=S_MAX_PASSES,
        check_every=CHUNK, mode=mode,
    )
    sched.warmup(bk.family_of(probs[0], np.float32))
    rng = np.random.default_rng(0)  # same arrival sequence for both modes
    t0 = time.perf_counter()
    for i, p in enumerate(probs):
        time.sleep(rng.exponential(1.0 / S_RATE))
        sched.submit(p, tag=i)
    res = sched.drain()
    wall = time.perf_counter() - t0
    stats = sched.stats()
    sched.close()
    lat = np.sort([res[i]["latency_s"] for i in range(len(probs))])
    return dict(
        results=res,
        wall=wall,
        ips=len(probs) / wall,
        occupancy=float(stats["occupancy"]),
        chunks=stats["chunks_run"],
        refills=stats["refills"],
        p50=float(lat[int(0.50 * (len(lat) - 1))]),
        p99=float(lat[int(0.99 * (len(lat) - 1))]),
    )


def _sustained() -> tuple[list[dict], dict]:
    probs = _stream_problems()
    drain = _drive("drain", probs)
    cont = _drive("continuous", probs)

    # Per-slot freeze at chunk boundaries guarantees continuous mode is a
    # re-batching of the SAME per-instance trajectories (DESIGN.md §12):
    # every instance must land bitwise equal to its drain-mode result.
    max_dx = 0.0
    for i in range(S_STREAM):
        rd, rc = drain["results"][i], cont["results"][i]
        assert rd["passes"] == rc["passes"], (
            f"instance {i}: drain stopped at {rd['passes']} passes, "
            f"continuous at {rc['passes']}"
        )
        dx = float(np.abs(rd["x_pad"] - rc["x_pad"]).max())
        max_dx = max(max_dx, dx)
    assert max_dx == 0.0, f"continuous/drain iterates diverged: {max_dx}"

    ratio = cont["ips"] / drain["ips"]
    assert cont["occupancy"] >= 0.9, (
        f"continuous occupancy {cont['occupancy']:.3f} < 0.9"
    )
    assert ratio >= 1.3, (
        f"continuous/drain throughput ratio {ratio:.2f} < 1.3"
    )
    rows = [
        dict(
            name="serve/sustained-drain-B8-n96",
            us_per_call=drain["wall"] / S_STREAM * 1e6,
            derived=(
                f"Poisson stream rate={S_RATE}/s x{S_STREAM} mixed-difficulty "
                f"instances; whole-batch drain: {drain['ips']:.3f} inst/s "
                f"p50={drain['p50']:.1f}s p99={drain['p99']:.1f}s "
                f"occupancy={drain['occupancy']:.2f}"
            ),
        ),
        dict(
            name="serve/sustained-continuous-B8-n96",
            us_per_call=cont["wall"] / S_STREAM * 1e6,
            derived=(
                f"slot-level continuous batching: {cont['ips']:.3f} inst/s "
                f"({ratio:.2f}x drain; criterion >=1.3x) "
                f"p50={cont['p50']:.1f}s p99={cont['p99']:.1f}s "
                f"occupancy={cont['occupancy']:.2f} (criterion >=0.9) "
                f"refills={cont['refills']} chunks={cont['chunks']} "
                f"bitwise_dx={max_dx:.1e}"
            ),
        ),
    ]
    payload = {
        "sustained": {
            "stream": S_STREAM,
            "arrival_rate": S_RATE,
            "drain_ips": round(drain["ips"], 4),
            "continuous_ips": round(cont["ips"], 4),
            "ratio": round(ratio, 2),
            "drain_occupancy": round(drain["occupancy"], 3),
            "continuous_occupancy": round(cont["occupancy"], 3),
            "drain_p50_s": round(drain["p50"], 2),
            "drain_p99_s": round(drain["p99"], 2),
            "continuous_p50_s": round(cont["p50"], 2),
            "continuous_p99_s": round(cont["p99"], 2),
            "refills": cont["refills"],
            "chunks_run": cont["chunks"],
            "bitwise_max_dx": max_dx,
        },
    }
    return rows, payload


def run() -> list[dict]:
    probs = _instances()
    kw = dict(tol=TOL, max_passes=MAX_PASSES, check_every=CHUNK)

    # --- sequential baseline: fresh solver (=> fresh compile) per instance
    t0 = time.perf_counter()
    solo_states, solo_passes = [], []
    for p in probs:
        solver = ParallelSolver(p, bucket_diagonals=6)
        st, info = solver.run_until(**kw)
        jax.block_until_ready(st.x)
        solo_states.append(np.asarray(st.x))
        solo_passes.append(info["passes"])
    t_seq = time.perf_counter() - t0
    seq_ips = B / t_seq

    # --- batched: one executable for the whole stream. Warm runs are
    # timed best-of-2 (same protocol for both batch engines): a ~8s
    # single-shot wanders ±5% with machine load, which is the size of the
    # effect the kernel-vs-vmapped comparison below is after.
    def best_of(run, rounds=2):
        best = np.inf
        for _ in range(rounds):
            t0 = time.perf_counter()
            out = run()
            jax.block_until_ready(out[0].x)
            best = min(best, time.perf_counter() - t0)
        return best, out

    fam = bk.family_of(probs[0], np.float32)
    bs = BatchedSolver(N, batch=B, family=fam, num_buckets=6)
    inst = bs.stack(probs)
    t0 = time.perf_counter()
    st, _ = bs.run_until(inst, **kw)
    jax.block_until_ready(st.x)
    t_compile_and_first = time.perf_counter() - t0
    t_batched, (st, info) = best_of(lambda: bs.run_until(inst, **kw))
    bat_ips = B / t_batched
    t_compile = t_compile_and_first - t_batched

    # --- batched on the gen-3 megakernel path (DESIGN.md §10): same
    # stream, same executable-sharing story, but every bucket's triangle
    # sweeps run as ONE pallas_call covering the whole batch.
    ks = BatchedSolver(N, batch=B, family=fam, num_buckets=6,
                       use_kernel=True)
    stk, _ = ks.run_until(inst, **kw)  # compile + warm
    jax.block_until_ready(stk.x)
    t_kernel, (stk, _) = best_of(lambda: ks.run_until(inst, **kw))
    k_ips = B / t_kernel
    kernel_dx = float(np.abs(np.asarray(stk.x) - np.asarray(st.x)).max())
    assert kernel_dx == 0.0, (
        f"kernel/vmapped batch paths diverged: {kernel_dx}"
    )

    # --- per-instance parity vs the sequential solves (float32 run; the
    # float64 1e-10 contract is pinned by tests/test_serve.py)
    xb = np.asarray(st.x)
    max_dx = max(
        float(np.abs(xb[i] - solo_states[i]).max()) for i in range(B)
    )
    pass_delta = max(
        abs(int(info["passes"][i]) - solo_passes[i]) for i in range(B)
    )
    assert max_dx < 1e-3, f"batched/solo iterates diverged: {max_dx}"
    assert pass_delta == 0, (
        f"stop passes diverged: {list(info['passes'])} vs {solo_passes}"
    )

    ratio = bat_ips / seq_ips
    rows = [
        dict(
            name="serve/sequential-8x-n96",
            us_per_call=t_seq / B * 1e6,
            derived=(
                f"n={N} B={B} tol={TOL} {t_seq:.1f}s total "
                f"({seq_ips:.3f} inst/s; per-instance compile included — "
                f"each new W retraces) passes={solo_passes[0]}"
            ),
        ),
        dict(
            name="serve/batched-B8-n96",
            us_per_call=t_batched / B * 1e6,
            derived=(
                f"n={N} B={B} tol={TOL} {t_batched:.1f}s/batch "
                f"({bat_ips:.3f} inst/s) throughput_ratio={ratio:.2f}x "
                f"(criterion >=3x) parity_max_dx={max_dx:.1e} "
                f"pass_delta={pass_delta}"
            ),
        ),
        dict(
            name="serve/batched-kernel-B8-n96",
            us_per_call=t_kernel / B * 1e6,
            derived=(
                f"gen-3 megakernel batch path (one pallas_call per "
                f"bucket per pass, DESIGN.md §10): {t_kernel:.1f}s/batch "
                f"({k_ips:.3f} inst/s) vs_vmapped="
                f"{t_batched / t_kernel:.2f}x bitwise_dx={kernel_dx:.1e}"
            ),
        ),
        dict(
            name="serve/batched-compile",
            us_per_call=t_compile * 1e6,
            derived=(
                f"one-time executable build for the (n={N}, B={B}, CC) "
                f"bucket; amortized across every later batch"
            ),
        ),
    ]
    sustained_rows, sustained_payload = _sustained()
    rows += sustained_rows
    payload = {
        "us_per_call": {r["name"]: round(float(r["us_per_call"]), 1)
                        for r in rows},
        "derived": {r["name"]: r["derived"] for r in rows},
        "throughput": {
            "sequential_ips": round(seq_ips, 4),
            "batched_ips": round(bat_ips, 4),
            "ratio": round(ratio, 2),
            "kernel_ips": round(k_ips, 4),
            "kernel_vs_vmapped": round(t_batched / t_kernel, 2),
        },
        **sustained_payload,
    }
    with open("BENCH_serve.json", "w") as fh:
        json.dump(payload, fh, indent=2, sort_keys=True)
        fh.write("\n")
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)

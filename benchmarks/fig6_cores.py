"""Paper Fig. 6 analogue: fixed work, varying processor count.

The paper sweeps cores on ca-HepPh. We sweep host-device count for the
sharded solver (subprocess per count — jax locks the device count at init).
On this 1-core container the wall-clock cannot show real scaling, so the
derived metric also reports the collective/compute split that governs
scaling on a real mesh (one n² psum per diagonal; per-device work n³/p).
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap
import time

N = 40
PASSES = 3
COUNTS = (1, 2, 4, 8)

_SCRIPT = textwrap.dedent("""
    import os, sys, json, time
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=%d"
    import numpy as np, jax
    from jax.sharding import Mesh
    from repro.core import problems
    from repro.core.sharded_dykstra import ShardedSolver
    from repro.graphs import generators, jaccard

    adj = generators.collaboration_like(%d, seed=1)
    dissim, w = jaccard.signed_instance(adj)
    prob = problems.correlation_clustering_lp(dissim, w, eps=0.05)
    mesh = Mesh(np.array(jax.devices()), ("solver",))
    solver = ShardedSolver(prob, mesh, num_buckets=4)
    st = solver.run(passes=1)  # warmup/compile
    t0 = time.time()
    solver.run(st, passes=%d)
    dt = time.time() - t0
    m = solver.metrics(solver.run(st, passes=1))
    print(json.dumps({"p": len(jax.devices()), "seconds": dt,
                      "viol": m["max_violation"]}))
""")


def run() -> list[dict]:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    rows = []
    base = None
    for p in COUNTS:
        out = subprocess.run(
            [sys.executable, "-c", _SCRIPT % (p, N, PASSES)],
            capture_output=True, text=True, env=env, timeout=900,
        )
        if out.returncode != 0:
            rows.append(dict(name=f"fig6/p{p}", us_per_call=-1,
                             derived="FAILED " + out.stderr[-200:]))
            continue
        d = json.loads(out.stdout.strip().splitlines()[-1])
        if base is None:
            base = d["seconds"]
        rows.append(dict(
            name=f"fig6/p{p}",
            us_per_call=d["seconds"] / PASSES * 1e6,
            derived=f"rel_time={d['seconds']/base:.2f} (1 host core; "
                    f"per-device work ∝ n³/p, psum ∝ n² per diagonal)",
        ))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)

"""Project-and-Forget sparsification decay (DESIGN.md §13).

Three rows on the n=96 planted-partition CC-LP:

  sparsify/full-pass-n96  — one masked fused pass over the FULL slabs
                            (active fraction 1.0; the dense baseline).
  sparsify/final-pass-n96 — the same pass over the compacted slabs the
                            solve ends on. Acceptance (ISSUE 9): ≥ 1.3x
                            faster than the full pass, with the final
                            active fraction < 0.5.
  sparsify/solve-n96      — the whole sparse solve (forget/revive every
                            FORGET_EVERY passes, compaction every
                            COMPACT_EVERY rounds); derived carries the
                            active-fraction trajectory endpoints.

Both pass timings run the SAME cached jitted pass (slabs are operands),
warm, after the solve — so the comparison is pure slab-size effect, free
of compile noise.
"""

from __future__ import annotations

import time

import jax
import numpy as np

from repro.sparse import SparseSolver

from benchmarks.convergence_probe import _cc_instance

N = 96
BUCKETS = 6
FORGET_EVERY = 10
FORGET_TOL = 1e-6  # f32 run: catch near-zero duals, not only exact zeros
COMPACT_EVERY = 3
MAX_PASSES = 120
TOL = 1e-4
REPS = 5


def _time_pass(fn, st, slabs) -> float:
    jax.block_until_ready(fn(st, slabs).x)  # compile/warm this shape
    t0 = time.perf_counter()
    for _ in range(REPS):
        out = fn(st, slabs)
    jax.block_until_ready(out.x)
    return (time.perf_counter() - t0) / REPS


def run() -> list[dict]:
    prob = _cc_instance(N)
    solver = SparseSolver(
        prob, bucket_diagonals=BUCKETS, forget_every=FORGET_EVERY,
        forget_tol=FORGET_TOL, compact_every=COMPACT_EVERY,
    )
    full_slabs = solver.active_slabs  # reference survives compaction
    st0 = solver.init_state()
    fn = solver._masked_pass_fn()
    t_full = _time_pass(fn, st0, full_slabs)

    t0 = time.perf_counter()
    st, info = solver.run_until(st0, tol=TOL, max_passes=MAX_PASSES)
    t_solve = time.perf_counter() - t0

    t_final = _time_pass(fn, st, solver.active_slabs)
    traj = np.asarray(info["active_trajectory"])
    af = float(info["active_fraction"])
    return [
        dict(name="sparsify/full-pass-n96",
             us_per_call=t_full * 1e6,
             derived=f"n={N} active_frac=1.000 (dense baseline)"),
        dict(name="sparsify/final-pass-n96",
             us_per_call=t_final * 1e6,
             derived=f"n={N} active_frac={af:.3f} (criterion <0.5) "
                     f"speedup_vs_full={t_full / t_final:.2f}x "
                     f"(criterion >=1.3x) "
                     f"compactions={info['compactions']}"),
        dict(name="sparsify/solve-n96",
             us_per_call=t_solve * 1e6,
             derived=f"passes={info['passes']} rounds={info['rounds']} "
                     f"converged={info['converged']} "
                     f"viol={info['max_violation']:.1e} "
                     f"af_decay={traj[0]:.3f}->{traj[-1]:.3f} "
                     f"over {len(traj)} forget rounds"),
    ]


if __name__ == "__main__":
    for r in run():
        print(r)

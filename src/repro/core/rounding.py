"""Rounding the CC LP relaxation to a clustering.

Implements the classic pivot/ball rounding used by LP-based approximation
algorithms for correlation clustering (Charikar et al. [10], Chawla et al.
[11]): repeatedly pick an unclustered pivot and cluster every unclustered
node within LP distance < radius of it. The LP objective lower-bounds the
optimal CC cost, so ``cc_cost(rounded) / lp_objective`` is a per-instance
approximation certificate.
"""

from __future__ import annotations

import numpy as np

__all__ = ["pivot_round", "cc_cost", "certificate"]


def pivot_round(
    x: np.ndarray, radius: float = 0.5, seed: int = 0, pivots: str = "random"
) -> np.ndarray:
    """Ball rounding of an LP point x (n, n upper triangle of distances).

    Returns integer cluster labels (n,).
    """
    n = x.shape[0]
    xs = np.triu(x, 1)
    xs = xs + xs.T
    rng = np.random.default_rng(seed)
    order = rng.permutation(n) if pivots == "random" else np.arange(n)
    labels = -np.ones(n, dtype=np.int64)
    next_label = 0
    for v in order:
        if labels[v] >= 0:
            continue
        ball = (labels < 0) & (xs[v] < radius)
        ball[v] = True
        labels[ball] = next_label
        next_label += 1
    return labels


def cc_cost(labels: np.ndarray, dissim: np.ndarray, weights: np.ndarray) -> float:
    """Weighted CC mistakes of a clustering (paper eq. (2)):
    positive pair (dissim=0) cut, or negative pair (dissim=1) joined."""
    n = len(labels)
    iu = np.triu_indices(n, 1)
    same = labels[iu[0]] == labels[iu[1]]
    pos_mistake = (dissim[iu] == 0) & ~same
    neg_mistake = (dissim[iu] == 1) & same
    return float(np.sum(weights[iu] * (pos_mistake | neg_mistake)))


def certificate(
    x: np.ndarray, dissim: np.ndarray, weights: np.ndarray, seed: int = 0,
    trials: int = 5,
) -> dict:
    """Round several times, return best clustering + approximation ratio
    certificate (LP objective is a lower bound on OPT)."""
    lp_lb = float(np.sum(weights[np.triu_indices(len(x), 1)]
                         * np.abs(x - dissim)[np.triu_indices(len(x), 1)]))
    best, best_cost = None, np.inf
    for s in range(trials):
        lab = pivot_round(x, seed=seed + s)
        c = cc_cost(lab, dissim, weights)
        if c < best_cost:
            best, best_cost = lab, c
    return {
        "labels": best,
        "cc_cost": best_cost,
        "lp_lower_bound": lp_lb,
        "approx_ratio_certificate": best_cost / max(lp_lb, 1e-12),
        "num_clusters": int(len(np.unique(best))),
    }

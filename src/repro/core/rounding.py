"""Rounding the CC LP relaxation to a clustering.

Implements the classic pivot/ball rounding used by LP-based approximation
algorithms for correlation clustering (Charikar et al. [10], Chawla et al.
[11]): repeatedly pick an unclustered pivot and cluster every unclustered
node within LP distance < radius of it. The LP objective lower-bounds the
optimal CC cost, so ``cc_cost(rounded) / lp_objective`` is a per-instance
approximation certificate.

Two implementations share the algorithm:

  * the numpy originals (``pivot_round``, ``cc_cost``, ``certificate``) —
    the host oracle, and the path the single-solve launcher uses;
  * jnp twins (``pivot_round_device``, ``cc_cost_device``) for the serve
    pipeline (DESIGN.md §8): pure, jit-safe, ``vmap``-able over instances
    AND over rounding trials, with the pivot order passed in as an
    explicit array (``pivot_orders`` derives the same permutations the
    numpy path draws from a seed) so host and device rounding are
    comparable element-for-element. Ghost padding is honoured via
    ``n_real``: ghost nodes never pivot, never join a ball, and come back
    labelled -1.
"""

from __future__ import annotations

import numpy as np

__all__ = [
    "cc_cost",
    "cc_cost_device",
    "certificate",
    "pivot_orders",
    "pivot_round",
    "pivot_round_device",
]


def pivot_round(
    x: np.ndarray,
    radius: float = 0.5,
    seed: int = 0,
    pivots: str = "random",
    order: np.ndarray | None = None,
) -> np.ndarray:
    """Ball rounding of an LP point x (n, n upper triangle of distances).

    ``order`` overrides the pivot sequence (the device twin takes the
    same array, which is how the parity tests align the two paths).
    Returns integer cluster labels (n,).
    """
    n = x.shape[0]
    xs = np.triu(x, 1)
    xs = xs + xs.T
    if order is None:
        rng = np.random.default_rng(seed)
        order = rng.permutation(n) if pivots == "random" else np.arange(n)
    labels = -np.ones(n, dtype=np.int64)
    next_label = 0
    for v in order:
        if labels[v] >= 0:
            continue
        ball = (labels < 0) & (xs[v] < radius)
        ball[v] = True
        labels[ball] = next_label
        next_label += 1
    return labels


def pivot_orders(n: int, seed: int = 0, trials: int = 1) -> np.ndarray:
    """(trials, n) pivot permutations — the exact sequence the numpy
    ``certificate`` loop draws: trial t uses ``default_rng(seed + t)``."""
    return np.stack(
        [np.random.default_rng(seed + t).permutation(n) for t in range(trials)]
    )


def pivot_round_device(x, order, radius: float = 0.5, n_real=None):
    """jnp twin of :func:`pivot_round` (same labels, given the same order).

    Args:
      x: (n, n) iterate, strict upper triangle meaningful.
      order: (n,) int32 pivot permutation (see :func:`pivot_orders`).
      n_real: live-point count under ghost padding (int or traced
        scalar); ghost nodes v >= n_real are pre-assigned the sentinel
        -1 so they never pivot and never join a ball.

    Pure and jit-safe; vmap over a leading instance axis and/or a trials
    axis of ``order``. Returns (n,) int32 labels; ghosts stay -1 (real
    labels are contiguous and start at 0, exactly like the numpy path).
    """
    import jax.numpy as jnp
    from jax import lax

    n = x.shape[0]
    order = jnp.asarray(order, jnp.int32)
    xs = jnp.triu(jnp.asarray(x), 1)
    xs = xs + xs.T
    idx = jnp.arange(n, dtype=jnp.int32)
    live = idx < (n if n_real is None else n_real)
    # -1 = unassigned (live), -2 = ghost; final ghost labels report -1.
    labels0 = jnp.where(live, jnp.int32(-1), jnp.int32(-2))

    def body(t, carry):
        labels, next_label = carry
        v = order[t]
        unassigned = labels == -1
        take = unassigned[v]
        ball = unassigned & (xs[v] < radius)
        ball = ball.at[v].set(unassigned[v])
        labels = jnp.where(take & ball, next_label, labels)
        return labels, next_label + take.astype(jnp.int32)

    labels, _ = lax.fori_loop(0, n, body, (labels0, jnp.int32(0)))
    return jnp.where(labels == -2, jnp.int32(-1), labels)


def cc_cost_device(labels, dissim, weights, mask):
    """jnp twin of :func:`cc_cost` over an explicit live-pair ``mask``
    (the §8 ghost-aware upper triangle). Elementwise, so it vmaps over
    (instances, trials) stacks of labels."""
    import jax.numpy as jnp

    same = labels[:, None] == labels[None, :]
    pos_mistake = (dissim == 0) & ~same
    neg_mistake = (dissim == 1) & same
    bad = pos_mistake | neg_mistake
    return jnp.sum(jnp.where(mask & bad, weights, 0.0))


def cc_cost(labels: np.ndarray, dissim: np.ndarray, weights: np.ndarray) -> float:
    """Weighted CC mistakes of a clustering (paper eq. (2)):
    positive pair (dissim=0) cut, or negative pair (dissim=1) joined."""
    n = len(labels)
    iu = np.triu_indices(n, 1)
    same = labels[iu[0]] == labels[iu[1]]
    pos_mistake = (dissim[iu] == 0) & ~same
    neg_mistake = (dissim[iu] == 1) & same
    return float(np.sum(weights[iu] * (pos_mistake | neg_mistake)))


def certificate(
    x: np.ndarray, dissim: np.ndarray, weights: np.ndarray, seed: int = 0,
    trials: int = 5,
) -> dict:
    """Round several times, return best clustering + approximation ratio
    certificate (LP objective is a lower bound on OPT)."""
    lp_lb = float(np.sum(weights[np.triu_indices(len(x), 1)]
                         * np.abs(x - dissim)[np.triu_indices(len(x), 1)]))
    best, best_cost = None, np.inf
    for s in range(trials):
        lab = pivot_round(x, seed=seed + s)
        c = cc_cost(lab, dissim, weights)
        if c < best_cost:
            best, best_cost = lab, c
    return {
        "labels": best,
        "cc_cost": best_cost,
        "lp_lower_bound": lp_lb,
        "approx_ratio_certificate": best_cost / max(lp_lb, 1e-12),
        "num_clusters": int(len(np.unique(best))),
    }

"""Vectorized parallel Dykstra solver (single device).

TPU-native adaptation of the paper's parallel execution schedule: instead of
p threads sweeping the sets ``S_{i,k}`` of a diagonal, the *whole diagonal* is
vectorized — one lane per set — and the sequential middle-index loop becomes a
``lax.scan`` carrying ``x_ik``. The paper's conflict-freedom theorem
(any two triplets from different sets on a diagonal share at most one index)
guarantees every gather/scatter below touches disjoint cells across lanes, so
scatters are exact merges with ``unique_indices=True`` — the JAX analogue of
"no locks" (paper §III.A; DESIGN.md §3).

Data layout per diagonal ("schedule layout"): lanes are *folded* — lane c
packs up to two sets of the diagonal head-to-tail (DESIGN.md §3), segment A
``(i, k)`` for steps t < sizes, then partner segment B ``(i2, k2)``. The
touched entries of X are

    rowb[t, c] = x[i_c(t), j(t)]  (contiguous row slice of X — VMEM friendly)
    colb[t, c] = x[j(t),  k_c(t)] (contiguous column slice)
    xikp[s, c] = x[i, k]          (the sequential carry, one per segment)

Triangle duals are **schedule-native** (DESIGN.md §3): they live permanently
in per-bucket slabs ``(D, 3, T, C)`` addressed by the scan step index — the
slab slice for a diagonal is pure slicing, never a gather. Only the X
row/column/carry slices above are gathered, and those are contiguous. Dual
memory is exactly ``3·C(n, 3)`` floats plus bucket padding — there is no
dense (n, n, n) tensor anywhere in this solver. Use ``duals_to_dense`` /
``dense_to_duals`` to convert to the serial oracle's dense convention.

The inner sweep (``sweep_ref`` in kernels/metric_project/ref.py) is a pure
function of these buffers; ``use_kernel=True`` swaps in the Pallas TPU kernel
(which updates the dual blocks in place in VMEM via input/output aliasing).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import schedule as sched
from repro.core.problems import MetricQP

__all__ = ["ParallelState", "ParallelSolver", "folded_geometry"]


def folded_geometry(i1, k1, s1, i2, k2, s2, T: int):
    """(T, C) index/mask arrays for folded lanes (DESIGN.md §3).

    Lane c sweeps set (i1, k1) for steps t < s1 (segment A), then partner
    set (i2, k2) at local step t - s1 (segment B). All inputs are (C,)
    int32 with -1/-0 padding. Returns (J, iN, kN, active, seg) — the single
    source of the segment-selection math shared by both solvers; the
    conflict-free exactness argument requires every call site to agree on
    it bit-for-bit.
    """
    C = i1.shape[0]
    t_idx = jnp.arange(T, dtype=jnp.int32)
    seg = t_idx[:, None] >= s1[None, :]  # (T, C) — True in segment B
    tB = t_idx[:, None] - s1[None, :]
    J = jnp.where(seg, i2[None, :] + 1 + tB, i1[None, :] + 1 + t_idx[:, None])
    iN = jnp.where(seg, jnp.broadcast_to(i2[None, :], (T, C)),
                   jnp.broadcast_to(i1[None, :], (T, C)))
    kN = jnp.where(seg, jnp.broadcast_to(k2[None, :], (T, C)),
                   jnp.broadcast_to(k1[None, :], (T, C)))
    active = jnp.where(
        seg,
        (tB < s2[None, :]) & (i2[None, :] >= 0),
        (t_idx[:, None] < s1[None, :]) & (i1[None, :] >= 0),
    )
    return J, iN, kN, active, seg


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class ParallelState:
    x: jax.Array  # (n, n) upper triangle
    f: jax.Array | None
    yd: list[jax.Array]  # per bucket: (D_b, 3, T_b, C_b) schedule-native duals
    ypair: jax.Array | None  # (2, n, n)
    ybox: jax.Array | None  # (2, n, n)
    passes: jax.Array  # scalar int32


def _gather(arr, idx_tuple, fill):
    return arr.at[idx_tuple].get(mode="fill", fill_value=fill)


def _scatter_add(arr, idx_tuple, delta):
    # Conflict-free by the paper's theorem; OOB (padding) rows are dropped.
    return arr.at[idx_tuple].add(delta, mode="drop", unique_indices=True)


class ParallelSolver:
    """Vectorized Dykstra for one MetricQP on a single device.

    Args:
      problem: the MetricQP instance.
      dtype: compute dtype (float32 default; float64 if x64 enabled).
      use_kernel: use the Pallas diagonal-sweep kernel (interpret=True on CPU)
        instead of the pure-jnp reference sweep.
      bucket_diagonals: group diagonals into T-size buckets to cut padding
        waste (beyond-paper optimization; see EXPERIMENTS.md §Solver-perf).
    """

    def __init__(
        self,
        problem: MetricQP,
        dtype=jnp.float32,
        use_kernel: bool = False,
        bucket_diagonals: int = 1,
        pad_sets_to: int | None = None,
    ):
        self.p = problem
        self.n = problem.n
        self.dtype = dtype
        self.use_kernel = use_kernel
        self.bucket_diagonals = max(1, int(bucket_diagonals))
        self.layout = sched.build_layout(
            self.n,
            num_buckets=self.bucket_diagonals,
            procs=1,
            pad_sets_to=pad_sets_to,
        )
        self._w = jnp.asarray(problem.w, dtype)
        self._d = jnp.asarray(problem.d, dtype)
        self._wf = (
            jnp.asarray(problem.w_f, dtype) if problem.has_f else None
        )
        # Device-resident work arrays; procs=1 → drop the unit device axis.
        # Lanes are folded (schedule.py): each lane holds segment-A set
        # (i, k) then segment-B set (i2, k2) head-to-tail.
        self._buckets = [
            dict(
                i=jnp.asarray(bl.i[0], jnp.int32),
                k=jnp.asarray(bl.k[0], jnp.int32),
                s=jnp.asarray(bl.sizes[0], jnp.int32),
                i2=jnp.asarray(bl.i2[0], jnp.int32),
                k2=jnp.asarray(bl.k2[0], jnp.int32),
                s2=jnp.asarray(bl.sizes2[0], jnp.int32),
                T=bl.T,
            )
            for bl in self.layout.buckets
        ]
        self._pass_fn = jax.jit(self._one_pass)

    # ------------------------------------------------------------------ init
    def init_state(self) -> ParallelState:
        n, dt = self.n, self.dtype
        p = self.p
        return ParallelState(
            x=jnp.asarray(p.x0(), dt),
            f=jnp.asarray(p.f0(), dt) if p.has_f else None,
            yd=self._zero_duals(),
            ypair=jnp.zeros((2, n, n), dt) if p.has_f else None,
            ybox=jnp.zeros((2, n, n), dt) if p.box is not None else None,
            passes=jnp.zeros((), jnp.int32),
        )

    def _zero_duals(self) -> list[jax.Array]:
        # slab_shape is (1, D, 3, T, C); the solver stores (D, 3, T, C).
        return [
            jnp.zeros(bl.slab_shape[1:], self.dtype) for bl in self.layout.buckets
        ]

    # ----------------------------------------------------- dual conversions
    def duals_to_dense(self, st: ParallelState) -> np.ndarray:
        """Schedule-native duals → dense ``ytri[a, b, c]`` (DESIGN.md §2)."""
        return sched.duals_to_dense(self.layout, st.yd)

    def dense_to_duals(self, ytri: np.ndarray) -> list[jax.Array]:
        """Dense ``ytri`` → state slabs (e.g. to resume from the oracle)."""
        slabs = sched.dense_to_duals(self.layout, ytri, np.float64)
        return [
            jnp.asarray(s.reshape(s.shape[1:]), self.dtype) for s in slabs
        ]

    # ------------------------------------------------------------- one pass
    def _sweep_fn(self):
        if self.use_kernel:
            from repro.kernels.metric_project import ops as kops

            return kops.diagonal_sweep_slab
        from repro.kernels.metric_project import ref as kref

        return kref.sweep_ref_slab

    def _diagonal_body(self, x, diag, T: int):
        """Process one diagonal: gather the contiguous X row/column slices,
        run the sequential-in-j sweep vectorized over folded lanes, scatter
        exact X deltas. Duals arrive as this diagonal's slab slice from the
        scan and are replaced wholesale — no dual gather/scatter exists."""
        i1, k1, s1 = diag["i"], diag["k"], diag["s"]
        i2, k2, s2 = diag["i2"], diag["k2"], diag["s2"]
        yslab = diag["y"]
        eps = float(self.p.eps)
        J, iN, kN, active, seg = folded_geometry(i1, k1, s1, i2, k2, s2, T)

        rowb = _gather(x, (iN, J), 0.0)
        colb = _gather(x, (J, kN), 0.0)
        xikp = jnp.stack(
            [_gather(x, (i1, k1), 0.0), _gather(x, (i2, k2), 0.0)]
        )
        w_row = _gather(self._w, (iN, J), 1.0)
        w_col = _gather(self._w, (J, kN), 1.0)
        w_ikp = jnp.stack(
            [_gather(self._w, (i1, k1), 1.0), _gather(self._w, (i2, k2), 1.0)]
        )

        sweep = self._sweep_fn()
        nrow, ncol, nxikp, new_yslab = sweep(
            rowb, colb, xikp, yslab, w_row, w_col, w_ikp, active, seg, eps
        )

        x = _scatter_add(x, (iN, J), jnp.where(active, nrow - rowb, 0))
        x = _scatter_add(x, (J, kN), jnp.where(active, ncol - colb, 0))
        x = _scatter_add(
            x, (i1, k1), jnp.where(s1 > 0, nxikp[0] - xikp[0], 0)
        )
        x = _scatter_add(
            x, (i2, k2), jnp.where(s2 > 0, nxikp[1] - xikp[1], 0)
        )
        return x, new_yslab

    def _pair_step(self, x, f, ypair):
        """Both pair constraints, all pairs at once (conflict-free family)."""
        p, eps = self.p, float(self.p.eps)
        w, wf, d = self._w, self._wf, self._d
        iw_x, iw_f = 1.0 / w, 1.0 / wf
        denom = iw_x + iw_f
        # x - f <= d
        xv = x + ypair[0] * iw_x / eps
        fv = f - ypair[0] * iw_f / eps
        theta = eps * jnp.maximum(xv - fv - d, 0.0) / denom
        x = xv - theta * iw_x / eps
        f = fv + theta * iw_f / eps
        y0 = theta
        # -x - f <= -d
        xv = x - ypair[1] * iw_x / eps
        fv = f - ypair[1] * iw_f / eps
        theta = eps * jnp.maximum(d - xv - fv, 0.0) / denom
        x = xv + theta * iw_x / eps
        f = fv + theta * iw_f / eps
        return x, f, jnp.stack([y0, theta])

    def _box_step(self, x, ybox):
        p, eps = self.p, float(self.p.eps)
        lo, hi = p.box
        iw_x = 1.0 / self._w
        xv = x + ybox[0] * iw_x / eps
        theta_hi = eps * jnp.maximum(xv - hi, 0.0) / iw_x
        x = xv - theta_hi * iw_x / eps
        xv = x - ybox[1] * iw_x / eps
        theta_lo = eps * jnp.maximum(lo - xv, 0.0) / iw_x
        x = xv + theta_lo * iw_x / eps
        return x, jnp.stack([theta_hi, theta_lo])

    def _one_pass(self, st: ParallelState) -> ParallelState:
        x = st.x
        new_yd = []
        for b, yb in zip(self._buckets, st.yd):
            body = functools.partial(self._diagonal_body, T=b["T"])
            xs = {key: b[key] for key in ("i", "k", "s", "i2", "k2", "s2")}
            x, nyb = jax.lax.scan(body, x, xs | {"y": yb})
            new_yd.append(nyb)
        f, ypair, ybox = st.f, st.ypair, st.ybox
        mask = jnp.triu(jnp.ones((self.n, self.n), bool), k=1)
        if self.p.has_f:
            x2, f2, ypair = self._pair_step(x, f, ypair)
            x = jnp.where(mask, x2, x)
            f = jnp.where(mask, f2, f)
            ypair = jnp.where(mask[None], ypair, 0)
        if self.p.box is not None:
            x2, ybox = self._box_step(x, ybox)
            x = jnp.where(mask, x2, x)
            ybox = jnp.where(mask[None], ybox, 0)
        return ParallelState(x, f, new_yd, ypair, ybox, st.passes + 1)

    # ------------------------------------------------------------------ API
    def run(self, state: ParallelState | None = None, passes: int = 1) -> ParallelState:
        st = state if state is not None else self.init_state()
        for _ in range(passes):
            st = self._pass_fn(st)
        return st

    def metrics(self, st: ParallelState, include_duals: bool = False) -> dict[str, Any]:
        from repro.core import convergence

        class _Np:
            x = np.asarray(st.x, np.float64)
            f = np.asarray(st.f, np.float64) if st.f is not None else None
            ypair = np.asarray(st.ypair, np.float64) if st.ypair is not None else None
            ybox = np.asarray(st.ybox, np.float64) if st.ybox is not None else None
            passes = int(st.passes)

        ytri = self.duals_to_dense(st) if include_duals else None
        return convergence.report(self.p, _Np(), ytri=ytri)

"""Vectorized parallel Dykstra solver (single device).

TPU-native adaptation of the paper's parallel execution schedule: instead of
p threads sweeping the sets ``S_{i,k}`` of a diagonal, the *whole diagonal* is
vectorized — one lane per set — and the sequential middle-index loop becomes a
``lax.scan`` carrying ``x_ik``. The paper's conflict-freedom theorem
(any two triplets from different sets on a diagonal share at most one index)
guarantees every gather/scatter below touches disjoint cells across lanes, so
scatters are exact merges with ``unique_indices=True`` — the JAX analogue of
"no locks" (paper §III.A; DESIGN.md §3).

Data layout per diagonal ("schedule layout"): lanes are *folded* — lane c
packs up to two sets of the diagonal head-to-tail (DESIGN.md §3), segment A
``(i, k)`` for steps t < sizes, then partner segment B ``(i2, k2)``. The
touched entries of X are

    rowb[t, c] = x[i_c(t), j(t)]  (contiguous row slice of X — VMEM friendly)
    colb[t, c] = x[j(t),  k_c(t)] (contiguous column slice)
    xikp[s, c] = x[i, k]          (the sequential carry, one per segment)

Triangle duals are **schedule-native** (DESIGN.md §3): they live permanently
in per-bucket slabs ``(D, 3, T, C)`` addressed by the scan step index — the
slab slice for a diagonal is pure slicing, never a gather. Only the X
row/column/carry slices above are gathered, and those are contiguous. Dual
memory is exactly ``3·C(n, 3)`` floats plus bucket padding — there is no
dense (n, n, n) tensor anywhere in this solver. Use ``duals_to_dense`` /
``dense_to_duals`` to convert to the serial oracle's dense convention.

**Fused-pass execution** (DESIGN.md §4, the default): everything above that
never changes across passes — folded geometry, step masks, gathered weight
buffers — is precomputed once by ``core/schedule.py::build_static_stage``
into per-bucket slabs addressed by the scan step index, the per-diagonal
sweep is the staged ``fused_bucket_pass_ref`` (or, with ``use_kernel=True``,
one whole-bucket Pallas megakernel per bucket instead of one kernel launch
per diagonal), and ``run(passes=P)`` executes all P passes (pair/box steps
included) as a single jitted ``lax.scan`` with a periodic convergence probe
— a full solve is one device program, not ~2n·P of them.

``fused=False`` keeps the PR-1 path (per-diagonal geometry recompute +
weight re-gather, one host dispatch per pass) as a benchmark baseline.

Pair/box steps, host/device metrics, dual conversions and the
``run_until`` solve-to-tolerance runtime are inherited from
``core/engine.py::SolverRuntime`` (the device-resident convergence
engine, DESIGN.md §7) and shared with the sharded solver.
"""

from __future__ import annotations

import dataclasses
import functools
import warnings

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import metrics_device, schedule as sched
from repro.core.engine import SolverRuntime
from repro.core.problems import MetricQP

__all__ = ["ParallelState", "ParallelSolver", "folded_geometry"]


def folded_geometry(i1, k1, s1, i2, k2, s2, T: int):
    """(T, C) index/mask arrays for folded lanes (DESIGN.md §3).

    Lane c sweeps set (i1, k1) for steps t < s1 (segment A), then partner
    set (i2, k2) at local step t - s1 (segment B). All inputs are (C,)
    int32 with -1/-0 padding. Returns (J, iN, kN, active, seg) — the single
    source of the segment-selection math shared by both solvers; the
    conflict-free exactness argument requires every call site to agree on
    it bit-for-bit.
    """
    C = i1.shape[0]
    t_idx = jnp.arange(T, dtype=jnp.int32)
    seg = t_idx[:, None] >= s1[None, :]  # (T, C) — True in segment B
    tB = t_idx[:, None] - s1[None, :]
    J = jnp.where(seg, i2[None, :] + 1 + tB, i1[None, :] + 1 + t_idx[:, None])
    iN = jnp.where(seg, jnp.broadcast_to(i2[None, :], (T, C)),
                   jnp.broadcast_to(i1[None, :], (T, C)))
    kN = jnp.where(seg, jnp.broadcast_to(k2[None, :], (T, C)),
                   jnp.broadcast_to(k1[None, :], (T, C)))
    active = jnp.where(
        seg,
        (tB < s2[None, :]) & (i2[None, :] >= 0),
        (t_idx[:, None] < s1[None, :]) & (i1[None, :] >= 0),
    )
    return J, iN, kN, active, seg


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class ParallelState:
    x: jax.Array  # (n, n) upper triangle
    f: jax.Array | None
    yd: list[jax.Array]  # per bucket: (D_b, 3, T_b, C_b) schedule-native duals
    ypair: jax.Array | None  # (2, n, n)
    ybox: jax.Array | None  # (2, n, n)
    passes: jax.Array  # scalar int32


def _gather(arr, idx_tuple, fill):
    return arr.at[idx_tuple].get(mode="fill", fill_value=fill)


def _scatter_add(arr, idx_tuple, delta):
    # Conflict-free by the paper's theorem; OOB (padding) rows are dropped.
    return arr.at[idx_tuple].add(delta, mode="drop", unique_indices=True)


class ParallelSolver(SolverRuntime):
    """Vectorized Dykstra for one MetricQP on a single device.

    Args:
      problem: the MetricQP instance.
      dtype: compute dtype (float32 default; float64 if x64 enabled).
      use_kernel: use the Pallas whole-bucket megakernel (interpret=True on
        CPU) instead of the pure-jnp fused reference; with ``fused=False``,
        the first-generation per-diagonal kernel.
      bucket_diagonals: group diagonals into T-size buckets to cut padding
        waste (beyond-paper optimization; see EXPERIMENTS.md §Solver-perf).
      fused: fused-pass execution (DESIGN.md §4, default) — static staging
        slabs, whole-bucket sweeps, and a single multi-pass scan runner.
        False keeps the PR-1 per-diagonal/per-pass path as a baseline.
      probe_every: evaluate the runner's convergence probe every this many
        passes (``last_residuals`` holds -1.0 at skipped passes).
      sweep_unroll: unroll factor of the inner sequential-in-j scan
        (amortizes loop overhead; 4 is a good CPU/TPU default).
      n_real: live-point count when the problem is ghost-padded to a
        serving bucket (DESIGN.md §8): only indices < n_real are real.
        Every triangle touching a ghost index is masked out of the
        staged ``act`` slabs (a set S_{i,k} is ghost iff its largest
        index k >= n_real, so whole sets drop at once), the pair/box
        steps and the convergence engine run under the live-pair mask,
        and ghost cells of X/F/duals stay exactly at their init values —
        the padded solve IS the n_real solve on the padded schedule.
    """

    def __init__(
        self,
        problem: MetricQP,
        dtype=jnp.float32,
        use_kernel: bool = False,
        bucket_diagonals: int = 1,
        pad_sets_to: int | None = None,
        fused: bool = True,
        probe_every: int = 1,
        sweep_unroll: int = 4,
        n_real: int | None = None,
    ):
        self.p = problem
        self.n = problem.n
        self.n_real = self.n if n_real is None else int(n_real)
        if not 0 <= self.n_real <= self.n:
            raise ValueError(f"n_real={n_real} outside [0, {self.n}]")
        self.dtype = dtype
        self.use_kernel = use_kernel
        self.fused = fused
        self.probe_every = max(1, int(probe_every))
        self.sweep_unroll = max(1, int(sweep_unroll))
        self.bucket_diagonals = max(1, int(bucket_diagonals))
        self.layout = sched.build_layout(
            self.n,
            num_buckets=self.bucket_diagonals,
            procs=1,
            pad_sets_to=pad_sets_to,
        )
        self._w = jnp.asarray(problem.w, dtype)
        self._d = jnp.asarray(problem.d, dtype)
        self._wf = (
            jnp.asarray(problem.w_f, dtype) if problem.has_f else None
        )
        self._mask = metrics_device.live_pair_mask(
            self.n, self.n_real if self.n_real < self.n else None
        )
        self._buckets = self._stage_buckets()
        self._pass_fn = jax.jit(self._one_pass)

    def _stage_buckets(self) -> list[dict]:
        """Device-resident per-bucket work arrays (procs=1 → unit device
        axis dropped). Lane tables (i/k/s/...) drive the legacy path and
        the carry gathers; the staged geometry/mask/gain slabs
        (DESIGN.md §4) — everything the fused pass needs beyond X and the
        duals — are built only when fused execution is on (the legacy
        path re-derives them at runtime and must not pay their memory)."""
        buckets = [
            dict(
                i=jnp.asarray(bl.i[0], jnp.int32),
                k=jnp.asarray(bl.k[0], jnp.int32),
                s=jnp.asarray(bl.sizes[0], jnp.int32),
                i2=jnp.asarray(bl.i2[0], jnp.int32),
                k2=jnp.asarray(bl.k2[0], jnp.int32),
                s2=jnp.asarray(bl.sizes2[0], jnp.int32),
                T=bl.T,
            )
            for bl in self.layout.buckets
        ]
        if not self.fused:
            return buckets
        npdt = np.dtype(self.dtype)
        one = npdt.type(1.0)
        epsc = npdt.type(self.p.eps)
        stage = sched.build_static_stage(self.layout, self.p.w, npdt)
        for b, sb in zip(buckets, stage):
            # Ghost padding (DESIGN.md §8): a triplet is real iff its
            # largest index kN < n_real, so the staged step mask drops
            # every ghost set wholesale — ghost duals/X cells are simply
            # never visited (the structural fixed-point argument).
            act = sb.active[0]
            if self.n_real < self.n:
                act = act & (sb.kN[0] < self.n_real)
            # Projection gains: g = (1/w)/eps, staged so the inner step
            # never divides; dinv = 1/(sum of the triplet's three gains)
            # makes theta a single multiply (ref.py::fused_step).
            g_row = (one / sb.w_row[0]) / epsc
            g_col = (one / sb.w_col[0]) / epsc
            g_ikp = (one / sb.w_ikp[0]) / epsc  # (D, 2, Cl)
            g_sel = np.where(
                sb.seg[0], g_ikp[:, 1][:, None, :], g_ikp[:, 0][:, None, :]
            ).astype(npdt)
            dinv = (one / (g_row + g_sel + g_col)).astype(npdt)
            b.update(
                J=jnp.asarray(sb.J[0]),
                iN=jnp.asarray(sb.iN[0]),
                kN=jnp.asarray(sb.kN[0]),
                act=jnp.asarray(act),
                seg=jnp.asarray(sb.seg[0]),
                g_row=jnp.asarray(g_row),
                g_col=jnp.asarray(g_col),
                g_sel=jnp.asarray(g_sel),
                dinv=jnp.asarray(dinv),
            )
        return buckets

    @property
    def staged_buckets(self) -> list[dict]:
        """Public view of the per-bucket staged work arrays, in schedule
        order. Each dict carries the lane tables ``i/k/s/i2/k2/s2`` and
        ``T``; with ``fused=True`` also the DESIGN.md §4 staging slabs
        (``J/iN/kN/act/seg`` geometry + ``g_row/g_col/g_sel/dinv`` gains)
        in the exact contract ``ops.fused_bucket_pass`` consumes. External
        callers (benchmarks, tooling) use this instead of solver privates."""
        return self._buckets

    # ------------------------------------------------------------------ init
    def init_state(self) -> ParallelState:
        n, dt = self.n, self.dtype
        p = self.p
        return ParallelState(
            x=jnp.asarray(p.x0(), dt),
            f=jnp.asarray(p.f0(), dt) if p.has_f else None,
            yd=self._zero_duals(),
            ypair=jnp.zeros((2, n, n), dt) if p.has_f else None,
            ybox=jnp.zeros((2, n, n), dt) if p.box is not None else None,
            passes=jnp.zeros((), jnp.int32),
        )

    def _zero_duals(self) -> list[jax.Array]:
        # slab_shape is (1, D, 3, T, C); the solver stores (D, 3, T, C).
        return [
            jnp.zeros(bl.slab_shape[1:], self.dtype) for bl in self.layout.buckets
        ]

    # ----------------------------------------------------- engine hooks
    # Dual conversions, pair/box steps, metrics and run_until live on
    # SolverRuntime (core/engine.py); this solver only customizes device
    # placement and the kernel-backed violation probe.
    def _slab_state_shape(self, slab: np.ndarray) -> tuple[int, ...]:
        return slab.shape[1:]  # drop the unit procs axis

    def _triangle_violation(self, x):
        # Ghost triangles are masked inside the kernel (``n_live``), so
        # padded serve instances take the same probe as full solves.
        if self.use_kernel:
            from repro.kernels.metric_project import ops as kops

            return kops.triangle_violation(
                metrics_device.symmetrize(self._dprob.mask, x),
                n_live=None if self.n_real >= self.n else self.n_real,
            )
        return super()._triangle_violation(x)

    # ------------------------------------------------------------- one pass
    def _sweep_fn(self):
        if self.use_kernel:
            # Gen-1 per-diagonal kernel is test-oracle-only since PR 6;
            # the kernel-backed legacy body would silently mix kernel
            # generations, so fall back loudly to the jnp sweep.
            warnings.warn(
                "use_kernel=True with fused=False has no kernel path: the "
                "gen-1 per-diagonal kernel is demoted to test-oracle "
                "status; running the jnp reference sweep instead. Use "
                "fused=True (default) for the gen-3 megakernel.",
                stacklevel=3,
            )
        from repro.kernels.metric_project import ref as kref

        return kref.sweep_ref_slab

    def _diagonal_body(self, x, diag, T: int):
        """Legacy (``fused=False``) diagonal body: re-derives the folded
        geometry and re-gathers the weight slices on every diagonal of
        every pass. Kept as the PR-1 benchmark baseline; the fused path
        replaces all of this with static staging slabs."""
        i1, k1, s1 = diag["i"], diag["k"], diag["s"]
        i2, k2, s2 = diag["i2"], diag["k2"], diag["s2"]
        yslab = diag["y"]
        eps = float(self.p.eps)
        J, iN, kN, active, seg = folded_geometry(i1, k1, s1, i2, k2, s2, T)
        if self.n_real < self.n:  # ghost sets masked out (DESIGN.md §8)
            active = active & (kN < self.n_real)

        rowb = _gather(x, (iN, J), 0.0)
        colb = _gather(x, (J, kN), 0.0)
        xikp = jnp.stack(
            [_gather(x, (i1, k1), 0.0), _gather(x, (i2, k2), 0.0)]
        )
        w_row = _gather(self._w, (iN, J), 1.0)
        w_col = _gather(self._w, (J, kN), 1.0)
        w_ikp = jnp.stack(
            [_gather(self._w, (i1, k1), 1.0), _gather(self._w, (i2, k2), 1.0)]
        )

        sweep = self._sweep_fn()
        nrow, ncol, nxikp, new_yslab = sweep(
            rowb, colb, xikp, yslab, w_row, w_col, w_ikp, active, seg, eps
        )

        x = _scatter_add(x, (iN, J), jnp.where(active, nrow - rowb, 0))
        x = _scatter_add(x, (J, kN), jnp.where(active, ncol - colb, 0))
        x = _scatter_add(
            x, (i1, k1), jnp.where(s1 > 0, nxikp[0] - xikp[0], 0)
        )
        x = _scatter_add(
            x, (i2, k2), jnp.where(s2 > 0, nxikp[1] - xikp[1], 0)
        )
        return x, new_yslab

    def _triangle_sweeps(self, x, yd: list[jax.Array]):
        """All triangle constraints of one pass: one fused bucket program
        per bucket (default), or the legacy per-diagonal scan."""
        new_yd = []
        if self.fused and self.use_kernel:
            from repro.kernels.metric_project import ops as kops

            for b, yb in zip(self._buckets, yd):
                x, nyb = kops.fused_bucket_pass(
                    x, yb, b, unroll=self.sweep_unroll
                )
                new_yd.append(nyb)
        elif self.fused:
            from repro.kernels.metric_project import ref as kref

            for b, yb in zip(self._buckets, yd):
                x, nyb = kref.fused_bucket_pass_ref(
                    x, yb, b, unroll=self.sweep_unroll
                )
                new_yd.append(nyb)
        else:
            for b, yb in zip(self._buckets, yd):
                body = functools.partial(self._diagonal_body, T=b["T"])
                xs = {key: b[key] for key in ("i", "k", "s", "i2", "k2", "s2")}
                x, nyb = jax.lax.scan(body, x, xs | {"y": yb})
                new_yd.append(nyb)
        return x, new_yd

    def _one_pass(self, st: ParallelState) -> ParallelState:
        x, new_yd = self._triangle_sweeps(st.x, st.yd)
        f, ypair, ybox = st.f, st.ypair, st.ybox
        mask = self._mask
        if self.p.has_f:
            x2, f2, ypair = self._pair_step(x, f, ypair)
            x = jnp.where(mask, x2, x)
            f = jnp.where(mask, f2, f)
            ypair = jnp.where(mask[None], ypair, 0)
        if self.p.box is not None:
            x2, ybox = self._box_step(x, ybox)
            x = jnp.where(mask, x2, x)
            ybox = jnp.where(mask[None], ybox, 0)
        return ParallelState(x, f, new_yd, ypair, ybox, st.passes + 1)

    # ------------------------------------------------------ multi-pass run
    # ``run(passes=P)`` — one jitted lax.scan over passes with the
    # periodic ||Δx||_inf probe — is inherited from SolverRuntime
    # (``_multi_pass_fn``); ``fused=False`` host-loops ``_pass_fn``.

"""Vectorized parallel Dykstra solver (single device).

TPU-native adaptation of the paper's parallel execution schedule: instead of
p threads sweeping the sets ``S_{i,k}`` of a diagonal, the *whole diagonal* is
vectorized — one lane per set — and the sequential middle-index loop becomes a
``lax.scan`` carrying ``x_ik``. The paper's conflict-freedom theorem
(any two triplets from different sets on a diagonal share at most one index)
guarantees every gather/scatter below touches disjoint cells across lanes, so
scatters are exact merges with ``unique_indices=True`` — the JAX analogue of
"no locks" (DESIGN.md §2).

Data layout per diagonal ("schedule layout"): for sets with smallest indices
``i_vec`` (C,) and largest ``k_vec`` (C,), middle index j at step t is
``J[t, c] = i_vec[c] + 1 + t``. The touched entries of X are

    rowb[t, c] = x[i_c, j]     (contiguous row slice of X — VMEM friendly)
    colb[t, c] = x[j,  k_c]    (contiguous column slice)
    xik[c]     = x[i_c, k_c]   (the sequential carry)

and the three triangle duals of triplet (i, j, k) live at
``ytri[i, j, k], ytri[i, k, j], ytri[j, k, i]`` (see DESIGN.md).

The inner sweep (``sweep_ref`` in kernels/metric_project/ref.py) is a pure
function of these buffers; ``use_kernel=True`` swaps in the Pallas TPU kernel.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import schedule as sched
from repro.core.problems import MetricQP

__all__ = ["ParallelState", "ParallelSolver"]


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class ParallelState:
    x: jax.Array  # (n, n) upper triangle
    f: jax.Array | None
    ytri: jax.Array  # (n, n, n)
    ypair: jax.Array | None  # (2, n, n)
    ybox: jax.Array | None  # (2, n, n)
    passes: jax.Array  # scalar int32


def _gather(arr, idx_tuple, fill):
    return arr.at[idx_tuple].get(mode="fill", fill_value=fill)


def _scatter_add(arr, idx_tuple, delta):
    # Conflict-free by the paper's theorem; OOB (padding) rows are dropped.
    return arr.at[idx_tuple].add(delta, mode="drop", unique_indices=True)


class ParallelSolver:
    """Vectorized Dykstra for one MetricQP on a single device.

    Args:
      problem: the MetricQP instance.
      dtype: compute dtype (float32 default; float64 if x64 enabled).
      use_kernel: use the Pallas diagonal-sweep kernel (interpret=True on CPU)
        instead of the pure-jnp reference sweep.
      bucket_diagonals: group diagonals into T-size buckets to cut padding
        waste (beyond-paper optimization; see EXPERIMENTS.md §Solver-perf).
    """

    def __init__(
        self,
        problem: MetricQP,
        dtype=jnp.float32,
        use_kernel: bool = False,
        bucket_diagonals: int = 1,
        pad_sets_to: int | None = None,
    ):
        self.p = problem
        self.n = problem.n
        self.dtype = dtype
        self.use_kernel = use_kernel
        self.schedule = sched.build_schedule(self.n, pad_sets_to=pad_sets_to)
        self.bucket_diagonals = max(1, int(bucket_diagonals))
        self._w = jnp.asarray(problem.w, dtype)
        self._d = jnp.asarray(problem.d, dtype)
        self._wf = (
            jnp.asarray(problem.w_f, dtype) if problem.has_f else None
        )
        self._buckets = self._make_buckets()
        self._pass_fn = jax.jit(self._one_pass)

    # ------------------------------------------------------------------ init
    def init_state(self) -> ParallelState:
        n, dt = self.n, self.dtype
        p = self.p
        return ParallelState(
            x=jnp.asarray(p.x0(), dt),
            f=jnp.asarray(p.f0(), dt) if p.has_f else None,
            ytri=jnp.zeros((n, n, n), dt),
            ypair=jnp.zeros((2, n, n), dt) if p.has_f else None,
            ybox=jnp.zeros((2, n, n), dt) if p.box is not None else None,
            passes=jnp.zeros((), jnp.int32),
        )

    # ------------------------------------------------------- schedule buckets
    def _make_buckets(self):
        """Group diagonals by max_t so each scan pads to its bucket's T.

        bucket_diagonals=1 → a single scan padded to the global T (paper-
        faithful baseline). Larger values split into roughly log-spaced
        T buckets, reducing padded work from ~n^3 to ~n^3/6 asymptotically.
        """
        s = self.schedule
        if s.num_diagonals == 0:
            return []
        # Contiguous split preserves the schedule's diagonal order exactly, so
        # the solver visits constraints in the same order as the serial oracle
        # regardless of bucket count (diagonal T is monotone within each loop
        # family, so contiguous runs already have near-uniform T).
        groups = np.array_split(np.arange(s.num_diagonals), self.bucket_diagonals)
        buckets = []
        for g in groups:
            if len(g) == 0:
                continue
            T = int(s.max_t[g].max())
            if T <= 0:
                continue
            buckets.append(
                dict(
                    diag_i=jnp.asarray(s.diag_i[g], jnp.int32),
                    diag_k=jnp.asarray(s.diag_k[g], jnp.int32),
                    sizes=jnp.asarray(
                        np.where(s.set_mask[g], s.diag_k[g] - s.diag_i[g] - 1, 0),
                        jnp.int32,
                    ),
                    T=T,
                )
            )
        return buckets

    # ------------------------------------------------------------- one pass
    def _sweep_fn(self):
        if self.use_kernel:
            from repro.kernels.metric_project import ops as kops

            return kops.diagonal_sweep
        from repro.kernels.metric_project import ref as kref

        return kref.sweep_ref

    def _diagonal_body(self, carry, diag, T: int):
        """Process one diagonal: gather schedule-layout buffers, run the
        sequential-in-j sweep vectorized over sets, scatter exact deltas."""
        x, ytri = carry
        i_vec, k_vec, sizes = diag["i"], diag["k"], diag["sizes"]
        C = i_vec.shape[0]
        eps = float(self.p.eps)
        t_idx = jnp.arange(T, dtype=jnp.int32)
        J = i_vec[None, :] + 1 + t_idx[:, None]  # (T, C)
        iN = jnp.broadcast_to(i_vec[None, :], (T, C))
        kN = jnp.broadcast_to(k_vec[None, :], (T, C))
        active = (t_idx[:, None] < sizes[None, :]) & (i_vec[None, :] >= 0)

        rowb = _gather(x, (iN, J), 0.0)
        colb = _gather(x, (J, kN), 0.0)
        xik = _gather(x, (i_vec, k_vec), 0.0)
        y0 = _gather(ytri, (iN, J, kN), 0.0)
        y1 = _gather(ytri, (iN, kN, J), 0.0)
        y2 = _gather(ytri, (J, kN, iN), 0.0)
        w_row = _gather(self._w, (iN, J), 1.0)
        w_col = _gather(self._w, (J, kN), 1.0)
        w_ik = _gather(self._w, (i_vec, k_vec), 1.0)

        sweep = self._sweep_fn()
        nrow, ncol, nxik, n0, n1, n2 = sweep(
            rowb, colb, xik, y0, y1, y2, w_row, w_col, w_ik, active, eps
        )

        x = _scatter_add(x, (iN, J), jnp.where(active, nrow - rowb, 0))
        x = _scatter_add(x, (J, kN), jnp.where(active, ncol - colb, 0))
        any_active = active.any(axis=0)
        x = _scatter_add(x, (i_vec, k_vec), jnp.where(any_active, nxik - xik, 0))
        ytri = _scatter_add(ytri, (iN, J, kN), jnp.where(active, n0 - y0, 0))
        ytri = _scatter_add(ytri, (iN, kN, J), jnp.where(active, n1 - y1, 0))
        ytri = _scatter_add(ytri, (J, kN, iN), jnp.where(active, n2 - y2, 0))
        return (x, ytri), None

    def _pair_step(self, x, f, ypair):
        """Both pair constraints, all pairs at once (conflict-free family)."""
        p, eps = self.p, float(self.p.eps)
        w, wf, d = self._w, self._wf, self._d
        iw_x, iw_f = 1.0 / w, 1.0 / wf
        denom = iw_x + iw_f
        # x - f <= d
        xv = x + ypair[0] * iw_x / eps
        fv = f - ypair[0] * iw_f / eps
        theta = eps * jnp.maximum(xv - fv - d, 0.0) / denom
        x = xv - theta * iw_x / eps
        f = fv + theta * iw_f / eps
        y0 = theta
        # -x - f <= -d
        xv = x - ypair[1] * iw_x / eps
        fv = f - ypair[1] * iw_f / eps
        theta = eps * jnp.maximum(d - xv - fv, 0.0) / denom
        x = xv + theta * iw_x / eps
        f = fv + theta * iw_f / eps
        return x, f, jnp.stack([y0, theta])

    def _box_step(self, x, ybox):
        p, eps = self.p, float(self.p.eps)
        lo, hi = p.box
        iw_x = 1.0 / self._w
        xv = x + ybox[0] * iw_x / eps
        theta_hi = eps * jnp.maximum(xv - hi, 0.0) / iw_x
        x = xv - theta_hi * iw_x / eps
        xv = x - ybox[1] * iw_x / eps
        theta_lo = eps * jnp.maximum(lo - xv, 0.0) / iw_x
        x = xv + theta_lo * iw_x / eps
        return x, jnp.stack([theta_hi, theta_lo])

    def _one_pass(self, st: ParallelState) -> ParallelState:
        x, ytri = st.x, st.ytri
        for b in self._buckets:
            T = b["T"]
            body = functools.partial(self._diagonal_body, T=T)
            (x, ytri), _ = jax.lax.scan(
                body,
                (x, ytri),
                dict(i=b["diag_i"], k=b["diag_k"], sizes=b["sizes"]),
            )
        f, ypair, ybox = st.f, st.ypair, st.ybox
        mask = jnp.triu(jnp.ones((self.n, self.n), bool), k=1)
        if self.p.has_f:
            x2, f2, ypair = self._pair_step(x, f, ypair)
            x = jnp.where(mask, x2, x)
            f = jnp.where(mask, f2, f)
            ypair = jnp.where(mask[None], ypair, 0)
        if self.p.box is not None:
            x2, ybox = self._box_step(x, ybox)
            x = jnp.where(mask, x2, x)
            ybox = jnp.where(mask[None], ybox, 0)
        return ParallelState(x, f, ytri, ypair, ybox, st.passes + 1)

    # ------------------------------------------------------------------ API
    def run(self, state: ParallelState | None = None, passes: int = 1) -> ParallelState:
        st = state if state is not None else self.init_state()
        for _ in range(passes):
            st = self._pass_fn(st)
        return st

    def metrics(self, st: ParallelState) -> dict[str, Any]:
        from repro.core import convergence

        class _Np:
            x = np.asarray(st.x, np.float64)
            f = np.asarray(st.f, np.float64) if st.f is not None else None
            ypair = np.asarray(st.ypair, np.float64) if st.ypair is not None else None
            ybox = np.asarray(st.ybox, np.float64) if st.ybox is not None else None
            passes = int(st.passes)

        return convergence.report(self.p, _Np())

"""Convergence metrics for metric-constrained QPs.

Duality gap (DESIGN.md §1): Dykstra maintains the invariant
``v = v0 - (1/eps) W^{-1} A'y`` with y >= 0, hence ``c + A'y = -eps W v`` and

    dual objective  = -b'y - (eps/2) v'Wv
    primal objective =  c'v + (eps/2) v'Wv
    gap              =  c'v + eps v'Wv + b'y.

Triangle constraints have b = 0; pair constraints contribute ±d_ab; box
constraints contribute hi / -lo. The gap is valid as an optimality certificate
once v is (nearly) feasible, so we report (gap, max violation) together —
exactly the stopping pair used in [37].

This module is the **host float64 oracle**: every scalar here is also
computed on device by `core/metrics_device.py` (the convergence engine,
DESIGN.md §7), which is property-tested against this file to 1e-10.
Production solve loops use the device engine; this path serves tests,
diagnostics, and ad-hoc analysis.
"""

from __future__ import annotations

import numpy as np

from repro.core.problems import MetricQP

__all__ = ["max_violation", "duality_gap", "report", "triangle_dual_stats"]


def _upper(n: int):
    return np.triu_indices(n, k=1)


def max_violation(
    p: MetricQP,
    x: np.ndarray,
    f: np.ndarray | None = None,
    *,
    apex_block: int = 4,
) -> float:
    """Max violation over every constraint family. O(n^3), blocked.

    The triangle family is reduced over *blocks* of apexes — one
    preallocated (B, n, n) slack buffer reused across blocks — instead of
    a Python loop over all n apexes (the slowest part of a metrics report
    at n >= 256). Same per-apex expression and fp association as the
    historical loop, so the result is bit-identical; small blocks win
    because the reduction is memory-bound and the buffer must stay
    cache-resident.
    """
    n = p.n
    xs = np.where(np.triu(np.ones((n, n), bool), 1), x, 0.0)
    xs = xs + xs.T  # symmetric view for easy triplet algebra
    # max over (a,b,c): x_ab - x_ac - x_bc, a<b, c != a,b.
    viol = 0.0
    ar = np.arange(n)
    buf = np.empty((min(apex_block, n), n, n), dtype=xs.dtype)
    for c0 in range(0, n, apex_block):
        cs = ar[c0 : c0 + apex_block]
        bi = np.arange(len(cs))
        slack = buf[: len(cs)]
        xb = xs[cs]  # (B, n); row c == column c by symmetry
        # slack[ci, a, b] = xs[a, b] - (xs[a, c] + xs[c, b])
        np.add(xb[:, :, None], xb[:, None, :], out=slack)
        np.subtract(xs[None, :, :], slack, out=slack)
        slack[:, ar, ar] = -np.inf  # a == b
        slack[bi, cs, :] = -np.inf  # a == c
        slack[bi, :, cs] = -np.inf  # b == c
        viol = max(viol, float(slack.max()))
    if p.has_f and f is not None:
        iu = _upper(n)
        viol = max(viol, float(np.max(np.abs(x[iu] - p.d[iu]) - f[iu], initial=-np.inf)))
    if p.box is not None:
        lo, hi = p.box
        iu = _upper(n)
        viol = max(viol, float(np.max(x[iu] - hi, initial=-np.inf)))
        viol = max(viol, float(np.max(lo - x[iu], initial=-np.inf)))
    return max(viol, 0.0)


def duality_gap(
    p: MetricQP,
    x: np.ndarray,
    f: np.ndarray | None,
    ytri_bsum: float,
    ypair: np.ndarray | None,
    ybox: np.ndarray | None,
) -> float:
    """gap = c'v + eps v'Wv + b'y.

    ``ytri_bsum`` is Σ b_i y_i over triangle constraints = 0 always (b=0); the
    argument exists so sharded solvers can pass a precomputed value without
    materializing duals on the host.
    """
    n = p.n
    iu = _upper(n)
    val = float(np.sum(p.c_x[iu] * x[iu] + p.eps * p.w[iu] * x[iu] ** 2))
    by = float(ytri_bsum)
    if p.has_f:
        val += float(np.sum(p.c_f[iu] * f[iu] + p.eps * p.w_f[iu] * f[iu] ** 2))
        # pair 0: x - f <= d  (b=+d); pair 1: -x - f <= -d  (b=-d)
        by += float(np.sum(p.d[iu] * ypair[0][iu]) - np.sum(p.d[iu] * ypair[1][iu]))
    if p.box is not None:
        lo, hi = p.box
        by += float(hi * np.sum(ybox[0][iu]) - lo * np.sum(ybox[1][iu]))
    return val + by


def triangle_dual_stats(ytri: np.ndarray) -> dict:
    """Summary stats of the triangle duals in the dense DESIGN.md §2 layout.

    Solvers store duals schedule-natively (DESIGN.md §3); they convert via
    ``duals_to_dense`` before calling this, so the stats are layout-agnostic.
    ``dual_min`` certifies Dykstra's θ ≥ 0 invariant (up to float error);
    ``active_constraints`` counts triangle constraints currently tight.
    """
    y = np.asarray(ytri, np.float64)
    return {
        "dual_min": float(y.min(initial=0.0)),
        "dual_max": float(y.max(initial=0.0)),
        "dual_l1": float(np.abs(y).sum()),
        "active_constraints": int(np.count_nonzero(y)),
    }


def report(p: MetricQP, st, ytri: np.ndarray | None = None) -> dict:
    """Metric bundle for logging: QP obj, LP obj, gap, max violation.

    ``ytri`` (dense (n, n, n), via the solver's ``duals_to_dense``) is
    optional — converting schedule-native duals costs an O(n^3) host pass, so
    callers opt in when they want dual-side diagnostics.
    """
    ypair = getattr(st, "ypair", None)
    ybox = getattr(st, "ybox", None)
    out = {
        "passes": int(getattr(st, "passes", 0)),
        "qp_objective": p.qp_objective(st.x, st.f),
        "lp_objective": p.lp_objective(st.x),
        "duality_gap": duality_gap(p, st.x, st.f, 0.0, ypair, ybox),
        "max_violation": max_violation(p, st.x, st.f),
    }
    if ytri is not None:
        out.update(triangle_dual_stats(ytri))
    return out

"""Conflict-free parallel execution schedule for metric constraints.

Implements the paper's triplet enumeration (Fig. 1/2): ordered triplets
``T = {(i, j, k) : 0 <= i < j < k < n}`` (0-based here) are grouped into sets

    S_{i,k} = {(i, j, k) : i < j < k},   nonempty iff k >= i + 2,

and the sets are swept along anti-diagonals of the (i, k) grid. Any two
triplets taken from *different* sets on the same diagonal share at most one
index, so their projection updates touch disjoint variables of X — they can be
executed simultaneously without locks (paper §III.A-B).

Two diagonal families cover the grid exactly once (paper Fig. 1):
  family 1: fix x = 0, z = n-1 .. 2:       sets S_{x+c, z-c}, c = 0..floor((z-x-2)/2)
  family 2: fix z = n-1, x = 1 .. n-3:     sets S_{x+c, z-c}, c = 0..floor((z-x-2)/2)

(The paper is 1-based; we use 0-based indices throughout.)

The schedule is *static*: it depends only on n, so it is precomputed in numpy
and baked into jitted solvers as constant index arrays.
"""

from __future__ import annotations

import dataclasses
import functools

import numpy as np

__all__ = [
    "Diagonal",
    "Schedule",
    "build_schedule",
    "diagonal_list",
    "enumerate_triplets",
    "device_assignment",
    "n_triplets",
]


def n_triplets(n: int) -> int:
    """|T| = C(n, 3)."""
    return n * (n - 1) * (n - 2) // 6


@dataclasses.dataclass(frozen=True)
class Diagonal:
    """One anti-diagonal of S_{i,k} sets; all sets are mutually conflict-free.

    Attributes:
      i: (C,) smallest index of each set on the diagonal.
      k: (C,) largest index of each set (i + 2 <= k).
      sizes: (C,) number of middle indices j per set (= k - i - 1).
    """

    i: np.ndarray
    k: np.ndarray

    @property
    def sizes(self) -> np.ndarray:
        return self.k - self.i - 1

    @property
    def num_sets(self) -> int:
        return int(self.i.shape[0])

    @property
    def max_size(self) -> int:
        return int(self.sizes.max()) if self.num_sets else 0

    @property
    def num_triplets(self) -> int:
        return int(self.sizes.sum())


def diagonal_list(n: int) -> list[Diagonal]:
    """All diagonals of the two double loops in paper Fig. 1 (0-based)."""
    if n < 3:
        return []
    diags: list[Diagonal] = []

    def make(x: int, z: int) -> Diagonal:
        g = (z - x - 2) // 2
        c = np.arange(g + 1, dtype=np.int64)
        return Diagonal(i=x + c, k=z - c)

    # Family 1: x = 0, z = n-1 down to 2.
    for z in range(n - 1, 1, -1):
        if z - 0 >= 2:
            diags.append(make(0, z))
    # Family 2: z = n-1, x = 1 .. n-3.
    for x in range(1, n - 2):
        diags.append(make(x, n - 1))
    return diags


def enumerate_triplets(n: int) -> np.ndarray:
    """All triplets in schedule order, shape (C(n,3), 3). Test/debug helper."""
    rows = []
    for d in diagonal_list(n):
        for i, k in zip(d.i, d.k):
            for j in range(i + 1, k):
                rows.append((i, j, k))
    out = np.asarray(rows, dtype=np.int64).reshape(-1, 3)
    return out


def device_assignment(num_sets: int, p: int) -> np.ndarray:
    """Paper Fig. 3: the r-th set on a diagonal goes to processor r mod p."""
    return np.arange(num_sets, dtype=np.int64) % p


@dataclasses.dataclass(frozen=True)
class Schedule:
    """Padded, array-form schedule for vectorized execution.

    All diagonals are stacked and padded to a common width so a single
    ``lax.scan`` can sweep them. ``bucket`` groups diagonals of similar length
    to bound padding waste (beyond-paper optimization; see EXPERIMENTS.md).

    Attributes:
      n: problem size.
      diag_i: (D, Cmax) int32, padded with -1.
      diag_k: (D, Cmax) int32, padded with -1.
      set_mask: (D, Cmax) bool, True where a real set exists.
      max_t: (D,) int32 — max j-steps needed on each diagonal.
      t_max: global max j-steps (int).
    """

    n: int
    diag_i: np.ndarray
    diag_k: np.ndarray
    set_mask: np.ndarray
    max_t: np.ndarray

    @property
    def num_diagonals(self) -> int:
        return int(self.diag_i.shape[0])

    @property
    def max_sets(self) -> int:
        return int(self.diag_i.shape[1])

    @property
    def t_max(self) -> int:
        return int(self.max_t.max()) if self.num_diagonals else 0


@functools.lru_cache(maxsize=32)
def build_schedule(n: int, pad_sets_to: int | None = None) -> Schedule:
    """Build the padded array schedule for size-n problems.

    Args:
      n: number of points.
      pad_sets_to: optionally round the set dimension up to a multiple
        (e.g. 128 for TPU lane alignment).
    """
    diags = diagonal_list(n)
    if not diags:
        z = np.zeros((0, 0), dtype=np.int64)
        return Schedule(n, z, z, z.astype(bool), np.zeros((0,), np.int64))
    cmax = max(d.num_sets for d in diags)
    if pad_sets_to:
        cmax = ((cmax + pad_sets_to - 1) // pad_sets_to) * pad_sets_to
    D = len(diags)
    diag_i = np.full((D, cmax), -1, dtype=np.int64)
    diag_k = np.full((D, cmax), -1, dtype=np.int64)
    set_mask = np.zeros((D, cmax), dtype=bool)
    max_t = np.zeros((D,), dtype=np.int64)
    for r, d in enumerate(diags):
        C = d.num_sets
        diag_i[r, :C] = d.i
        diag_k[r, :C] = d.k
        set_mask[r, :C] = True
        max_t[r] = d.max_size
    return Schedule(n, diag_i, diag_k, set_mask, max_t)


def validate_conflict_free(d: Diagonal) -> bool:
    """Brute-force check: any two triplets from different sets of this diagonal
    share at most one index (paper §III.A). Used in tests."""
    for a in range(d.num_sets):
        for b in range(a + 1, d.num_sets):
            ia, ka = int(d.i[a]), int(d.k[a])
            ib, kb = int(d.i[b]), int(d.k[b])
            for ja in range(ia + 1, ka):
                for jb in range(ib + 1, kb):
                    shared = len({ia, ja, ka} & {ib, jb, kb})
                    if shared > 1:
                        return False
    return True

"""Conflict-free parallel execution schedule for metric constraints.

Implements the paper's triplet enumeration (Fig. 1/2): ordered triplets
``T = {(i, j, k) : 0 <= i < j < k < n}`` (0-based here) are grouped into sets

    S_{i,k} = {(i, j, k) : i < j < k},   nonempty iff k >= i + 2,

and the sets are swept along anti-diagonals of the (i, k) grid. Any two
triplets taken from *different* sets on the same diagonal share at most one
index, so their projection updates touch disjoint variables of X — they can be
executed simultaneously without locks (paper §III.A-B).

Two diagonal families cover the grid exactly once (paper Fig. 1):
  family 1: fix x = 0, z = n-1 .. 2:       sets S_{x+c, z-c}, c = 0..floor((z-x-2)/2)
  family 2: fix z = n-1, x = 1 .. n-3:     sets S_{x+c, z-c}, c = 0..floor((z-x-2)/2)

(The paper is 1-based; we use 0-based indices throughout.)

The schedule is *static*: it depends only on n, so it is precomputed in numpy
and baked into jitted solvers as constant index arrays.
"""

from __future__ import annotations

import dataclasses
import functools

import numpy as np

__all__ = [
    "BucketLayout",
    "Diagonal",
    "Schedule",
    "ScheduleLayout",
    "StageBucket",
    "build_layout",
    "build_schedule",
    "build_static_stage",
    "compose_slab_permutation",
    "dense_to_duals",
    "diagonal_list",
    "duals_to_dense",
    "enumerate_triplets",
    "folded_geometry_np",
    "device_assignment",
    "n_triplets",
    "slab_valid_masks",
]


def n_triplets(n: int) -> int:
    """|T| = C(n, 3)."""
    return n * (n - 1) * (n - 2) // 6


@dataclasses.dataclass(frozen=True)
class Diagonal:
    """One anti-diagonal of S_{i,k} sets; all sets are mutually conflict-free.

    Attributes:
      i: (C,) smallest index of each set on the diagonal.
      k: (C,) largest index of each set (i + 2 <= k).
      sizes: (C,) number of middle indices j per set (= k - i - 1).
    """

    i: np.ndarray
    k: np.ndarray

    @property
    def sizes(self) -> np.ndarray:
        return self.k - self.i - 1

    @property
    def num_sets(self) -> int:
        return int(self.i.shape[0])

    @property
    def max_size(self) -> int:
        return int(self.sizes.max()) if self.num_sets else 0

    @property
    def num_triplets(self) -> int:
        return int(self.sizes.sum())


def diagonal_list(n: int) -> list[Diagonal]:
    """All diagonals of the two double loops in paper Fig. 1 (0-based)."""
    if n < 3:
        return []
    diags: list[Diagonal] = []

    def make(x: int, z: int) -> Diagonal:
        g = (z - x - 2) // 2
        c = np.arange(g + 1, dtype=np.int64)
        return Diagonal(i=x + c, k=z - c)

    # Family 1: x = 0, z = n-1 down to 2.
    for z in range(n - 1, 1, -1):
        if z - 0 >= 2:
            diags.append(make(0, z))
    # Family 2: z = n-1, x = 1 .. n-3.
    for x in range(1, n - 2):
        diags.append(make(x, n - 1))
    return diags


def enumerate_triplets(n: int) -> np.ndarray:
    """All triplets in schedule order, shape (C(n,3), 3). Test/debug helper."""
    rows = []
    for d in diagonal_list(n):
        for i, k in zip(d.i, d.k):
            for j in range(i + 1, k):
                rows.append((i, j, k))
    out = np.asarray(rows, dtype=np.int64).reshape(-1, 3)
    return out


def device_assignment(num_sets: int, p: int) -> np.ndarray:
    """Paper Fig. 3: the r-th set on a diagonal goes to processor r mod p."""
    return np.arange(num_sets, dtype=np.int64) % p


@dataclasses.dataclass(frozen=True)
class Schedule:
    """Padded, array-form schedule for vectorized execution.

    All diagonals are stacked and padded to a common width so a single
    ``lax.scan`` can sweep them. ``bucket`` groups diagonals of similar length
    to bound padding waste (beyond-paper optimization; see EXPERIMENTS.md).

    Attributes:
      n: problem size.
      diag_i: (D, Cmax) int32, padded with -1.
      diag_k: (D, Cmax) int32, padded with -1.
      set_mask: (D, Cmax) bool, True where a real set exists.
      max_t: (D,) int32 — max j-steps needed on each diagonal.
      t_max: global max j-steps (int).
    """

    n: int
    diag_i: np.ndarray
    diag_k: np.ndarray
    set_mask: np.ndarray
    max_t: np.ndarray

    @property
    def num_diagonals(self) -> int:
        return int(self.diag_i.shape[0])

    @property
    def max_sets(self) -> int:
        return int(self.diag_i.shape[1])

    @property
    def t_max(self) -> int:
        return int(self.max_t.max()) if self.num_diagonals else 0


@functools.lru_cache(maxsize=32)
def build_schedule(n: int, pad_sets_to: int | None = None) -> Schedule:
    """Build the padded array schedule for size-n problems.

    Args:
      n: number of points.
      pad_sets_to: optionally round the set dimension up to a multiple
        (e.g. 128 for TPU lane alignment).
    """
    diags = diagonal_list(n)
    if not diags:
        z = np.zeros((0, 0), dtype=np.int64)
        return Schedule(n, z, z, z.astype(bool), np.zeros((0,), np.int64))
    cmax = max(d.num_sets for d in diags)
    if pad_sets_to:
        cmax = ((cmax + pad_sets_to - 1) // pad_sets_to) * pad_sets_to
    D = len(diags)
    diag_i = np.full((D, cmax), -1, dtype=np.int64)
    diag_k = np.full((D, cmax), -1, dtype=np.int64)
    set_mask = np.zeros((D, cmax), dtype=bool)
    max_t = np.zeros((D,), dtype=np.int64)
    for r, d in enumerate(diags):
        C = d.num_sets
        diag_i[r, :C] = d.i
        diag_k[r, :C] = d.k
        set_mask[r, :C] = True
        max_t[r] = d.max_size
    return Schedule(n, diag_i, diag_k, set_mask, max_t)


# --------------------------------------------------------------------------
# Schedule-native dual layout (DESIGN.md §3)
#
# Triangle duals never live in a dense (n, n, n) tensor inside the solvers.
# They are stored in "schedule layout": one slab per diagonal bucket, shaped
#
#     (procs, D, 3, T, Cl)
#
# where D diagonals are scanned in schedule order, T is the bucket's max
# lane height, Cl the per-device lane count, and axis 2 indexes the three
# constraints of a triplet (0: long (i,j) apex k, 1: long (i,k) apex j,
# 2: long (j,k) apex i). The slab slice for one diagonal is addressed by the
# ``lax.scan`` step index directly — no gather, no scatter. ``procs`` is the
# device count (1 for the single-device solver); lane f of a diagonal maps to
# (device f % procs, slot f // procs), the paper's Fig. 3 assignment.
#
# **Lane folding**: the sets of a diagonal have sizes s, s-2, s-4, ... — a
# rectangular (T, C) layout would waste ~half its area on the triangular
# profile. Since sets on one diagonal are mutually conflict-free, processing
# them in any interleaving is exact, so lane f packs TWO sets: segment A is
# set f (the f-th largest) for steps t < sizes_A, segment B is set C-1-f for
# the remaining steps. Paired sizes sum to a constant, so lanes have
# near-uniform height, slab area ≈ the true dual count 3·C(n, 3) (padding
# factor ~1.0–1.6 depending on bucketing vs the dense tensor's fixed ~2.1×),
# and per-lane work is balanced — strictly better than the unfolded Fig. 3
# deal on both memory and skew.
#
# ``BucketLayout`` carries precomputed flat conversion maps between this
# layout and the dense ``ytri[a, b, c]`` convention of the serial oracle
# (DESIGN.md §2), so solvers can import/export duals exactly.
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class BucketLayout:
    """Layout metadata for one contiguous bucket of diagonals.

    All work arrays are (procs, D, Cl) int32; i/k padded with -1, sizes
    with 0. Segment A of lane (dev, r, slot) is the set (i, k) visited for
    steps t in [0, sizes); segment B is the set (i2, k2) visited for steps
    t in [sizes, sizes + sizes2). Unpaired lanes have i2 = -1, sizes2 = 0.

    Attributes:
      diag_ids: (D,) global diagonal indices in schedule order.
      i, k, sizes: segment-A set per lane; ``sizes = k - i - 1``.
      i2, k2, sizes2: segment-B (folded partner) set per lane.
      T: max lane height (sizes + sizes2) over the bucket's diagonals.
      slab_shape: (procs, D, 3, T, Cl) — the dual slab for this bucket.
      slab_index: (M,) int64 flat indices into the slab, one per real dual.
      dense_index: 3×(M,) int64 arrays (a, b, c) — matching dense positions.
    """

    diag_ids: np.ndarray
    i: np.ndarray
    k: np.ndarray
    sizes: np.ndarray
    i2: np.ndarray
    k2: np.ndarray
    sizes2: np.ndarray
    T: int
    slab_shape: tuple[int, ...]
    slab_index: np.ndarray
    dense_index: tuple[np.ndarray, np.ndarray, np.ndarray]

    @property
    def procs(self) -> int:
        return int(self.slab_shape[0])

    @property
    def num_diagonals(self) -> int:
        return int(self.slab_shape[1])

    @property
    def lanes(self) -> int:
        return int(self.slab_shape[4])

    @property
    def slab_size(self) -> int:
        return int(np.prod(self.slab_shape))

    @property
    def num_duals(self) -> int:
        """Real (non-padding) dual entries in this bucket."""
        return int(self.slab_index.shape[0])


@dataclasses.dataclass(frozen=True)
class ScheduleLayout:
    """Full schedule-native dual layout: an ordered tuple of buckets.

    The buckets partition the diagonal list contiguously (schedule order is
    preserved), so sweeping bucket 0..B-1 visits constraints in exactly the
    serial oracle's "schedule" order. Total real duals = 3·C(n, 3).
    """

    n: int
    procs: int
    buckets: tuple[BucketLayout, ...]

    @property
    def num_duals(self) -> int:
        return sum(b.num_duals for b in self.buckets)

    def slab_shapes(self) -> list[tuple[int, ...]]:
        return [b.slab_shape for b in self.buckets]


@functools.lru_cache(maxsize=32)
def build_layout(
    n: int,
    num_buckets: int = 1,
    procs: int = 1,
    pad_sets_to: int | None = None,
) -> ScheduleLayout:
    """Build the schedule-native dual layout for size-n problems.

    Args:
      n: number of points.
      num_buckets: contiguous diagonal buckets (bounds scan padding waste).
      procs: device count; lanes are dealt round-robin (paper Fig. 3).
      pad_sets_to: round the lane dimension up to a multiple (TPU alignment).
    """
    diags = diagonal_list(n)
    if not diags:
        return ScheduleLayout(n, procs, ())
    groups = np.array_split(np.arange(len(diags)), max(1, int(num_buckets)))
    buckets: list[BucketLayout] = []
    for g in groups:
        if len(g) == 0:
            continue
        ds = [diags[r] for r in g]
        D = len(ds)
        # Fold: lane f = (set f, set C-1-f); the middle set of an odd
        # diagonal rides alone. Paired sizes sum to a constant, so lane
        # heights are near-uniform (see module comment).
        folds = []
        for d in ds:
            C = d.num_sets
            F = (C + 1) // 2
            cA = np.arange(F)
            cB = C - 1 - cA
            iA, kA = d.i[cA], d.k[cA]
            iB = np.where(cB > cA, d.i[cB], -1)
            kB = np.where(cB > cA, d.k[cB], -1)
            folds.append((iA, kA, iB, kB))
        heights = [
            int(((kA - iA - 1) + np.where(iB >= 0, kB - iB - 1, 0)).max())
            for iA, kA, iB, kB in folds
        ]
        T = max(heights)
        Cl = max(-(-len(f[0]) // procs) for f in folds)
        if pad_sets_to:
            Cl = ((Cl + pad_sets_to - 1) // pad_sets_to) * pad_sets_to
        arrs = {
            name: np.full((procs, D, Cl), -1, dtype=np.int32)
            for name in ("i", "k", "i2", "k2")
        }
        for r, (iA, kA, iB, kB) in enumerate(folds):
            f = np.arange(len(iA))
            dev, slot = f % procs, f // procs
            arrs["i"][dev, r, slot] = iA
            arrs["k"][dev, r, slot] = kA
            arrs["i2"][dev, r, slot] = iB
            arrs["k2"][dev, r, slot] = kB
        s_arr = np.where(arrs["i"] >= 0, arrs["k"] - arrs["i"] - 1, 0).astype(np.int32)
        s2_arr = np.where(arrs["i2"] >= 0, arrs["k2"] - arrs["i2"] - 1, 0).astype(np.int32)
        slab_shape = (procs, D, 3, T, Cl)
        # Conversion maps: every real (dev, diag, t, lane) cell, three duals.
        shape4 = (procs, D, T, Cl)
        tt = np.broadcast_to(
            np.arange(T, dtype=np.int32)[None, None, :, None], shape4
        )
        s1b = np.broadcast_to(s_arr[:, :, None, :], shape4)
        s2b = np.broadcast_to(s2_arr[:, :, None, :], shape4)
        seg_entries = []
        for seg, (i_name, k_name) in enumerate((("i", "k"), ("i2", "k2"))):
            ib = np.broadcast_to(arrs[i_name][:, :, None, :], shape4)
            kb = np.broadcast_to(arrs[k_name][:, :, None, :], shape4)
            if seg == 0:
                valid = (ib >= 0) & (tt < s1b)
                toff = tt
            else:
                valid = (ib >= 0) & (tt >= s1b) & (tt < s1b + s2b)
                toff = tt - s1b
            dev, dg, tv, ln = (a.astype(np.int64) for a in np.nonzero(valid))
            iv = ib[valid].astype(np.int64)
            kv = kb[valid].astype(np.int64)
            jv = iv + 1 + toff[valid].astype(np.int64)
            seg_entries.append((dev, dg, tv, ln, iv, jv, kv))
        flat = []
        dense_a, dense_b, dense_c = [], [], []
        for dev, dg, tv, ln, iv, jv, kv in seg_entries:
            for m, (a, b, c) in enumerate(
                ((iv, jv, kv), (iv, kv, jv), (jv, kv, iv))
            ):
                flat.append(
                    np.ravel_multi_index(
                        (dev, dg, np.full_like(dev, m), tv, ln), slab_shape
                    )
                )
                dense_a.append(a)
                dense_b.append(b)
                dense_c.append(c)
        buckets.append(
            BucketLayout(
                diag_ids=np.asarray(g, dtype=np.int64),
                i=arrs["i"],
                k=arrs["k"],
                sizes=s_arr,
                i2=arrs["i2"],
                k2=arrs["k2"],
                sizes2=s2_arr,
                T=T,
                slab_shape=slab_shape,
                slab_index=np.concatenate(flat),
                dense_index=(
                    np.concatenate(dense_a),
                    np.concatenate(dense_b),
                    np.concatenate(dense_c),
                ),
            )
        )
    return ScheduleLayout(n, procs, tuple(buckets))


def duals_to_dense(layout: ScheduleLayout, slabs) -> np.ndarray:
    """Schedule-layout dual slabs → dense ``ytri[a, b, c]`` (DESIGN.md §2).

    ``slabs`` is one array per bucket; any shape that flattens to
    ``prod(bucket.slab_shape)`` is accepted (solvers may drop a unit procs
    axis). Returns float64 (n, n, n).
    """
    n = layout.n
    ytri = np.zeros((n, n, n), dtype=np.float64)
    for bl, slab in zip(layout.buckets, slabs):
        flat = np.asarray(slab, dtype=np.float64).reshape(-1)
        if flat.shape[0] != bl.slab_size:
            raise ValueError(
                f"slab has {flat.shape[0]} elements, layout expects {bl.slab_size}"
            )
        ytri[bl.dense_index] = flat[bl.slab_index]
    return ytri


def dense_to_duals(
    layout: ScheduleLayout, ytri: np.ndarray, dtype=np.float32
) -> list[np.ndarray]:
    """Dense ``ytri[a, b, c]`` → schedule-layout slabs (inverse of
    :func:`duals_to_dense`; padding cells are zero)."""
    out = []
    for bl in layout.buckets:
        flat = np.zeros(bl.slab_size, dtype=dtype)
        flat[bl.slab_index] = ytri[bl.dense_index].astype(dtype)
        out.append(flat.reshape(bl.slab_shape))
    return out


def slab_valid_masks(
    layout: ScheduleLayout, n_real: int | None = None
) -> list[np.ndarray]:
    """Per-bucket bool masks marking the real (non-padding) dual cells.

    Shape matches ``slab_shape``. Slab-native reductions (the device
    convergence engine's ``triangle_dual_stats``) mask with these: under
    fused execution (DESIGN.md §4) the padding cells of a dual slab carry
    don't-care values and must never enter a reduction.

    ``n_real`` makes the masks **ghost-aware** (DESIGN.md §8): on a
    ghost-padded problem the cells of every triangle set touching an
    index >= n_real are additionally dropped — those sets are masked out
    of the staged ``act`` slabs, so their dual cells also carry
    don't-care values under fused execution. A set ``S_{i,k}`` is ghost
    iff its largest index ``kN >= n_real`` (i < j < k), the same
    predicate the staging applies.
    """
    out = []
    for bl in layout.buckets:
        m = np.zeros(bl.slab_size, dtype=bool)
        m[bl.slab_index] = True
        m = m.reshape(bl.slab_shape)
        if n_real is not None:
            _, _, kN, _, _ = folded_geometry_np(
                bl.i, bl.k, bl.sizes, bl.i2, bl.k2, bl.sizes2, bl.T
            )  # (procs, D, T, Cl)
            m = m & (kN[:, :, None, :, :] < int(n_real))
        out.append(m)
    return out


@functools.lru_cache(maxsize=16)
def compose_slab_permutation(
    n: int, num_buckets: int, p_old: int, p_new: int
) -> tuple[np.ndarray, np.ndarray, int, int]:
    """Direct slab→slab permutation between two device counts.

    Composes the two layouts' dense conversion maps *symbolically*: every
    real dual has a unique dense key (a, b, c), so sorting both layouts'
    (key, flat slab position) tables by key aligns old and new positions
    one-to-one — the dense (n, n, n) tensor itself is never materialized
    (that round-trip survives only as the test oracle,
    ``elastic.reshard_duals_dense``).

    Returns ``(src, dst, old_size, new_size)``: flat positions into the
    bucket-concatenated old/new slab vectors such that
    ``new_flat[dst] = old_flat[src]`` (padding cells stay zero).
    """
    old = build_layout(n, num_buckets=num_buckets, procs=p_old)
    new = build_layout(n, num_buckets=num_buckets, procs=p_new)

    def flat_table(layout: ScheduleLayout):
        keys, pos, off = [], [], 0
        for bl in layout.buckets:
            a, b, c = bl.dense_index
            keys.append((a * n + b) * n + c)
            pos.append(bl.slab_index + off)
            off += bl.slab_size
        if not keys:
            return np.zeros(0, np.int64), np.zeros(0, np.int64), 0
        return np.concatenate(keys), np.concatenate(pos), off

    k_old, p_old_flat, size_old = flat_table(old)
    k_new, p_new_flat, size_new = flat_table(new)
    so = np.argsort(k_old, kind="stable")
    sn = np.argsort(k_new, kind="stable")
    if not np.array_equal(k_old[so], k_new[sn]):
        raise AssertionError("layouts enumerate different constraint sets")
    return p_old_flat[so], p_new_flat[sn], size_old, size_new


# --------------------------------------------------------------------------
# Static staging (DESIGN.md §4)
#
# Everything a pass touches besides X and the duals is a pure function of
# (n, num_buckets, procs) and the constant weight matrix W: the folded
# per-step geometry (J / iN / kN index tables), the active/seg masks, and
# the gathered weight slices w_row / w_col / w_ikp. Before fused-pass
# execution these were re-derived (or re-gathered from HBM) inside every
# ``lax.scan`` step of every pass — pure waste, since they never change.
# ``build_static_stage`` precomputes them once, in numpy, as per-bucket
# slabs laid out exactly like the dual slabs:
#
#     J, iN, kN        (procs, D, T, Cl) int32   per-step triplet indices
#     active, seg      (procs, D, T, Cl) bool    step masks
#     w_row, w_col     (procs, D, T, Cl) dtype   W[iN, J], W[J, kN]
#     w_ikp            (procs, D, 2, Cl) dtype   W[i, k] per segment
#
# The per-diagonal slice of each slab is addressed by the scan step index —
# the same zero-gather discipline as the dual storage (§3). The geometry
# must agree **bit-for-bit** with ``parallel_dykstra.folded_geometry`` (the
# jnp implementation used by data-dependent paths such as the sharded
# solver's packed delta exchange); ``folded_geometry_np`` is its numpy twin
# and tests/test_fused_pass.py pins the equivalence property.
# --------------------------------------------------------------------------


def folded_geometry_np(i1, k1, s1, i2, k2, s2, T: int):
    """Numpy twin of ``parallel_dykstra.folded_geometry``.

    Inputs are int arrays of shape (..., C) (any leading batch dims, e.g.
    (procs, D, Cl)); returns (J, iN, kN, active, seg) of shape (..., T, C)
    with int32/bool dtypes, bit-identical to the jnp implementation.
    """
    i1, k1, s1, i2, k2, s2 = (
        np.asarray(a, np.int32) for a in (i1, k1, s1, i2, k2, s2)
    )
    ax = i1.ndim - 1
    e = lambda a: np.expand_dims(a, ax)  # (..., 1, C)
    t = np.arange(T, dtype=np.int32).reshape((1,) * ax + (T, 1))
    seg = t >= e(s1)  # (..., T, C) — True in segment B
    tB = t - e(s1)
    J = np.where(seg, e(i2) + 1 + tB, e(i1) + 1 + t).astype(np.int32)
    shape = J.shape
    iN = np.where(seg, np.broadcast_to(e(i2), shape),
                  np.broadcast_to(e(i1), shape)).astype(np.int32)
    kN = np.where(seg, np.broadcast_to(e(k2), shape),
                  np.broadcast_to(e(k1), shape)).astype(np.int32)
    active = np.where(
        seg,
        (tB < e(s2)) & (e(i2) >= 0),
        (t < e(s1)) & (e(i1) >= 0),
    )
    return J, iN, kN, active, seg


@dataclasses.dataclass(frozen=True)
class StageBucket:
    """Precomputed static staging slabs for one bucket (DESIGN.md §4).

    All arrays carry the leading ``procs`` axis of the layout; the
    single-device solver drops it, the sharded solver shards it.

    Attributes:
      J, iN, kN: (procs, D, T, Cl) int32 — per-step middle index ``j`` and
        the segment-selected ``(i, k)`` of each folded lane.
      active: (procs, D, T, Cl) bool — True where a real triplet is visited.
      seg: (procs, D, T, Cl) bool — True while the lane sweeps segment B.
      w_row, w_col: (procs, D, T, Cl) — W[iN, J] / W[J, kN], out-of-bounds
        cells filled with 1.0 (matching ``x.at[].get(mode="fill")``).
      w_ikp: (procs, D, 2, Cl) — W[i, k] of segments A and B.
    """

    J: np.ndarray
    iN: np.ndarray
    kN: np.ndarray
    active: np.ndarray
    seg: np.ndarray
    w_row: np.ndarray
    w_col: np.ndarray
    w_ikp: np.ndarray


def build_static_stage(
    layout: ScheduleLayout, w: np.ndarray, dtype=np.float32
) -> list[StageBucket]:
    """Precompute the pass-invariant staging slabs for every bucket.

    Args:
      layout: the schedule-native dual layout (``build_layout``).
      w: (n, n) weight matrix of the problem.
      dtype: dtype of the staged weight slabs (the solver compute dtype).

    Unlike the legacy per-diagonal gathers (``w.at[idx].get(mode="fill")``,
    whose negative padding indices *wrap* into the zero lower triangle and
    poison masked lanes with ``1/w = inf``), every cell a **masked** step
    would read — padding lanes, out-of-range middle indices, lower-triangle
    wraps — is staged as 1.0, so no inf/nan from padding ever enters the
    fused pipeline. Active steps always read W verbatim (the geometry
    guarantees valid upper-triangle indices there), so X and every real
    dual are unaffected bit-for-bit — including problems whose real
    weights contain zeros, which keep the serial oracle's ``1/w = inf``
    semantics.
    """
    n = layout.n
    dtype = np.dtype(dtype)
    w = np.asarray(w, dtype)

    def gather(rows, cols, live, fill):
        """W[rows, cols] where ``live``; ``fill`` at masked cells."""
        fill = dtype.type(fill)
        r = np.clip(rows, 0, n - 1)
        c = np.clip(cols, 0, n - 1)
        return np.where(live, w[r, c], fill).astype(dtype)

    out = []
    for bl in layout.buckets:
        J, iN, kN, active, seg = folded_geometry_np(
            bl.i, bl.k, bl.sizes, bl.i2, bl.k2, bl.sizes2, bl.T
        )
        # A lane's (i, k) carry weight is live iff the segment exists.
        w_ikp = np.stack(
            [gather(bl.i, bl.k, bl.i >= 0, 1.0),
             gather(bl.i2, bl.k2, bl.i2 >= 0, 1.0)], axis=-2
        )  # (procs, D, 2, Cl)
        out.append(
            StageBucket(
                J=J,
                iN=iN,
                kN=kN,
                active=active,
                seg=seg,
                w_row=gather(iN, J, active, 1.0),
                w_col=gather(J, kN, active, 1.0),
                w_ikp=w_ikp,
            )
        )
    return out


def validate_conflict_free(d: Diagonal) -> bool:
    """Brute-force check: any two triplets from different sets of this diagonal
    share at most one index (paper §III.A). Used in tests."""
    for a in range(d.num_sets):
        for b in range(a + 1, d.num_sets):
            ia, ka = int(d.i[a]), int(d.k[a])
            ib, kb = int(d.i[b]), int(d.k[b])
            for ja in range(ia + 1, ka):
                for jb in range(ib + 1, kb):
                    shared = len({ia, ja, ka} & {ib, jb, kb})
                    if shared > 1:
                        return False
    return True

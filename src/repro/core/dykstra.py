"""Serial Dykstra's method for metric-constrained QPs (paper Algorithm 1).

Pure-numpy scalar-loop implementation. This is the *oracle* for the
vectorized/parallel solvers and the "1 core" baseline of the paper's Table I.

Constraint visitation order within one pass:
  1. all triangle constraints, in a configurable triplet order
     ("lex": (i,j,k) lexicographic as in the serial method of [37];
      "schedule": the paper's conflict-free diagonal order),
     visiting for each triplet the three constraints
     (long=(i,j), apex=k), (long=(i,k), apex=j), (long=(j,k), apex=i);
  2. pair constraints  x-d <= f  and  d-x <= f  (if the problem has f);
  3. box constraints  x <= hi, -x <= -lo  (if the problem has a box).

Dual-variable layout matches DESIGN.md §2: ``ytri[a, b, c]`` is the dual of
"x_ab <= x_ac + x_bc" (a < b, apex c). Pair/box duals are (n, n) matrices.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import schedule as sched
from repro.core.problems import MetricQP

__all__ = ["DykstraState", "init_state", "run_pass", "solve_serial"]


@dataclasses.dataclass
class DykstraState:
    x: np.ndarray  # (n, n) upper triangle
    f: np.ndarray | None  # (n, n) or None
    ytri: np.ndarray  # (n, n, n) triangle duals
    ypair: np.ndarray | None  # (2, n, n): [0]=x-d<=f, [1]=d-x<=f
    ybox: np.ndarray | None  # (2, n, n): [0]=x<=hi, [1]=-x<=-lo
    passes: int = 0


def init_state(p: MetricQP) -> DykstraState:
    n = p.n
    return DykstraState(
        x=p.x0(),
        f=p.f0(),
        ytri=np.zeros((n, n, n), dtype=np.float64),
        ypair=np.zeros((2, n, n), dtype=np.float64) if p.has_f else None,
        ybox=np.zeros((2, n, n), dtype=np.float64) if p.box is not None else None,
    )


def _triangle_step(p: MetricQP, st: DykstraState, a: int, b: int, c: int) -> None:
    """One Dykstra visit to constraint x_ab <= x_ac + x_bc (a<b, apex c)."""
    x, w, eps = st.x, p.w, p.eps
    ac = (min(a, c), max(a, c))
    bc = (min(b, c), max(b, c))
    iw_ab = 1.0 / w[a, b]
    iw_ac = 1.0 / w[ac]
    iw_bc = 1.0 / w[bc]
    y = st.ytri[a, b, c]
    # Correction: x += y * (1/eps) W^{-1} a_row   (a_row = +1@ab, -1@ac, -1@bc)
    if y != 0.0:
        x[a, b] += y * iw_ab / eps
        x[ac] -= y * iw_ac / eps
        x[bc] -= y * iw_bc / eps
    # Projection.
    delta = x[a, b] - x[ac] - x[bc]
    if delta > 0.0:
        theta = eps * delta / (iw_ab + iw_ac + iw_bc)
        x[a, b] -= theta * iw_ab / eps
        x[ac] += theta * iw_ac / eps
        x[bc] += theta * iw_bc / eps
        st.ytri[a, b, c] = theta
    else:
        st.ytri[a, b, c] = 0.0


def _pair_steps(p: MetricQP, st: DykstraState) -> None:
    """Visit the two pair constraints of every pair (vector-serial is exact:
    distinct pairs touch distinct variables, so visiting them 'at once' is the
    same as serially — the embarrassingly-parallel family)."""
    n, eps = p.n, p.eps
    iu = np.triu_indices(n, k=1)
    x, f = st.x, st.f
    iw_x = 1.0 / p.w[iu]
    iw_f = 1.0 / p.w_f[iu]
    denom = iw_x + iw_f
    # Constraint 0: x - f <= d   (row: +1@x, -1@f)
    y = st.ypair[0][iu]
    xv = x[iu] + y * iw_x / eps
    fv = f[iu] - y * iw_f / eps
    delta = xv - fv - p.d[iu]
    theta = eps * np.maximum(delta, 0.0) / denom
    x[iu] = xv - theta * iw_x / eps
    f[iu] = fv + theta * iw_f / eps
    st.ypair[0][iu] = theta
    # Constraint 1: -x - f <= -d  (row: -1@x, -1@f)
    y = st.ypair[1][iu]
    xv = x[iu] - y * iw_x / eps
    fv = f[iu] - y * iw_f / eps
    delta = p.d[iu] - xv - fv
    theta = eps * np.maximum(delta, 0.0) / denom
    x[iu] = xv + theta * iw_x / eps
    f[iu] = fv + theta * iw_f / eps
    st.ypair[1][iu] = theta


def _box_steps(p: MetricQP, st: DykstraState) -> None:
    n, eps = p.n, p.eps
    lo, hi = p.box
    iu = np.triu_indices(n, k=1)
    x = st.x
    iw_x = 1.0 / p.w[iu]
    # x <= hi
    y = st.ybox[0][iu]
    xv = x[iu] + y * iw_x / eps
    theta = eps * np.maximum(xv - hi, 0.0) / iw_x
    x[iu] = xv - theta * iw_x / eps
    st.ybox[0][iu] = theta
    # -x <= -lo
    y = st.ybox[1][iu]
    xv = x[iu] - y * iw_x / eps
    theta = eps * np.maximum(lo - xv, 0.0) / iw_x
    x[iu] = xv + theta * iw_x / eps
    st.ybox[1][iu] = theta


def triplet_order(n: int, order: str) -> np.ndarray:
    """(T, 3) triplets in the requested visitation order."""
    if order == "schedule":
        return sched.enumerate_triplets(n)
    if order == "lex":
        rows = [
            (i, j, k)
            for i in range(n)
            for j in range(i + 1, n)
            for k in range(j + 1, n)
        ]
        return np.asarray(rows, dtype=np.int64).reshape(-1, 3)
    raise ValueError(f"unknown order {order!r}")


def run_pass(p: MetricQP, st: DykstraState, order: str = "schedule") -> DykstraState:
    """One full pass through every constraint."""
    for i, j, k in triplet_order(p.n, order):
        _triangle_step(p, st, i, j, k)  # long (i,j), apex k
        _triangle_step(p, st, i, k, j)  # long (i,k), apex j
        _triangle_step(p, st, j, k, i)  # long (j,k), apex i
    if p.has_f:
        _pair_steps(p, st)
    if p.box is not None:
        _box_steps(p, st)
    st.passes += 1
    return st


def solve_serial(
    p: MetricQP,
    max_passes: int = 50,
    order: str = "schedule",
    tol: float = 0.0,
) -> DykstraState:
    """Run Dykstra for a fixed number of passes (paper §IV.D compares fixed
    iteration counts) or until max triangle violation <= tol."""
    from repro.core import convergence

    st = init_state(p)
    for _ in range(max_passes):
        run_pass(p, st, order=order)
        if tol > 0.0 and convergence.max_violation(p, st.x, st.f) <= tol:
            break
    return st

"""Device-resident convergence metrics (DESIGN.md §7).

The stopping pair of the paper — (max constraint violation, duality gap) —
was previously computed by `core/convergence.py`: host-side numpy with a
Python loop over apexes, fed by `np.asarray(state.x)` host transfers. That
is fine as a float64 *oracle*, but at production scale the monitor must
live on device with the pass kernel (Veldt et al. and Project-and-Forget
both fold convergence monitoring into the solver loop). This module is the
jnp twin: every function here is pure, jit-safe, and allocates nothing
bigger than one apex block — in particular the duality gap and the
triangle-dual stats are computed **directly from schedule-native dual
slabs** (DESIGN.md §3); nothing ever densifies to (n, n, n).

Numerical contract, pinned by tests/test_engine.py: with float64 inputs
every scalar matches `convergence.report` to 1e-10 — the device engine
reorganizes the reductions (blocked apexes, masked whole-matrix sums), it
never changes the math. Where fp association matters (the triangle slack),
the expression mirrors the host oracle term-for-term.

`DeviceProblem` is the device-resident constant set of a `MetricQP`
(weights, costs, the triu mask); solvers build one per instance and close
over it in their jitted metric programs, so metrics never re-upload
problem data.
"""

from __future__ import annotations

import dataclasses
import inspect

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.problems import MetricQP

try:  # jax >= 0.5 exposes shard_map at the top level
    _shard_map = jax.shard_map
except AttributeError:  # jax 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map

# Replication-check kwarg of shard_map (renamed check_rep -> check_vma
# across jax versions). The kernel-backed sharded probe must disable it:
# pallas_call carries no replication rule, same as the sharded sweep.
_CHECK_KW = (
    "check_vma"
    if "check_vma" in inspect.signature(_shard_map).parameters
    else "check_rep"
)

__all__ = [
    "DeviceProblem",
    "duality_gap",
    "live_pair_mask",
    "max_violation",
    "qp_objective",
    "lp_objective",
    "symmetrize",
    "triangle_dual_stats",
    "triangle_violation",
    "triangle_violation_sharded",
    "triangle_violation_sharded_kernel",
]


@dataclasses.dataclass(frozen=True)
class DeviceProblem:
    """Device-resident constants of one MetricQP (compute dtype).

    Plain (non-pytree) dataclass: solvers hold one instance and *close
    over* it inside their jitted metric programs, so the arrays are baked
    in as constants exactly like the staged schedule slabs. The batched
    serve engine instead constructs instances *inside* a vmapped trace —
    every array field (including ``mask``) then carries a leading-axis
    tracer and ``n_real`` is a traced per-instance scalar; all consumers
    below only index/compare these fields, so both uses share one code
    path.

    ``n_real``: number of live points. Indices >= n_real are *ghost*
    padding (DESIGN.md §8): their pairs are excluded from ``mask`` and
    their triangles from the violation reduction. None means all n live.
    """

    n: int
    eps: float
    has_f: bool
    box: tuple[float, float] | None
    mask: jax.Array  # (n, n) bool strict upper triangle (live pairs only)
    d: jax.Array
    w: jax.Array
    c_x: jax.Array
    w_f: jax.Array | None
    c_f: jax.Array | None
    n_real: int | jax.Array | None = None

    @classmethod
    def from_qp(cls, p: MetricQP, dtype, n_real: int | None = None) -> "DeviceProblem":
        asd = lambda a: None if a is None else jnp.asarray(a, dtype)
        return cls(
            n=p.n,
            eps=float(p.eps),
            has_f=bool(p.has_f),
            box=None if p.box is None else (float(p.box[0]), float(p.box[1])),
            mask=live_pair_mask(p.n, n_real),
            d=asd(p.d),
            w=asd(p.w),
            c_x=asd(p.c_x),
            w_f=asd(p.w_f),
            c_f=asd(p.c_f),
            n_real=n_real,
        )


def live_pair_mask(n: int, n_real=None):
    """Strict-upper-triangle mask restricted to live (non-ghost) pairs.

    ``n_real`` may be a python int or a traced scalar (the batched engine
    vmaps it over instances); None means every index is live.
    """
    m = jnp.triu(jnp.ones((n, n), bool), k=1)
    if n_real is None:
        return m
    live = jnp.arange(n, dtype=jnp.int32) < n_real
    return m & live[:, None] & live[None, :]


def symmetrize(mask, x):
    """Strict-upper-triangle iterate → full symmetric matrix (the view the
    apex-blocked triangle reduction and the Pallas kernel both consume)."""
    xs = jnp.where(mask, x, 0.0)
    return xs + xs.T


def _apex_block_max(xs, cs, n_live=None, *, padded: bool = True):
    """Max triangle slack over one block of apexes.

    ``xs`` is the (n, n) symmetric iterate, ``cs`` (B,) int32 apex indices
    (>= n marks padding). For apex c the slack matrix is
    ``xs[a, b] - (xs[a, c] + xs[c, b])`` — the exact expression (and fp
    association) of the host oracle ``convergence.max_violation``; cells
    with a == b, a == c, b == c and padding apexes are masked to -inf.
    ``n_live`` (int or traced scalar) additionally masks every triangle
    touching a ghost index >= n_live (DESIGN.md §8): ghost x cells are 0,
    so e.g. a ghost apex would report the *false* slack x_ab - 0 - 0.

    Padding contract: ``padded=False`` asserts every ``cs`` entry is a
    real apex (< n) and skips both the index clamp and the liveness term
    of the mask — every interior block of an exactly-divisible sweep takes
    this branch; only tail/dealt blocks that may run past n pay for the
    clamp (``triangle_violation`` decides per sweep, the sharded dealing
    always pads so it always passes True).
    """
    n = xs.shape[0]
    a = jnp.arange(n, dtype=jnp.int32)
    if padded:
        live = cs < n
        c = jnp.minimum(cs, n - 1)
    else:
        c = cs
    xb = xs[c]  # (B, n); row c == column c by symmetry
    slack = xs[None, :, :] - (xb[:, :, None] + xb[:, None, :])
    ok = (
        (a[None, :, None] != a[None, None, :])
        & (c[:, None, None] != a[None, :, None])
        & (c[:, None, None] != a[None, None, :])
    )
    if padded:
        ok = ok & live[:, None, None]
    if n_live is not None:
        la = a < n_live
        ok = ok & (c[:, None, None] < n_live) & la[None, :, None] & la[None, None, :]
    return jnp.max(jnp.where(ok, slack, -jnp.inf))


def triangle_violation(xs, *, apex_block: int = 16, n_live=None):
    """Max violation over the triangle family, blocked over apexes.

    ``lax.map`` sweeps apex blocks sequentially so peak memory is one
    (B, n, n) slack block, never the O(n^3) tensor. Returns -inf for
    n < 3 (no triangles); callers floor the combined violation at 0.
    ``n_live`` restricts the reduction to triangles of the first n_live
    indices (ghost padding, DESIGN.md §8).

    Padding contract (guarded below): ``apex_block`` is clamped to n, so
    the swept index table ``nb·apex_block`` overshoots n by *strictly
    less than one block* — the only padding apexes are the tail of the
    last block, masked -inf inside ``_apex_block_max``. Without the clamp
    a large ``apex_block`` at large non-multiple n would silently sweep
    whole blocks of clamped phantom apexes (index min(c, n-1) — masked,
    but each one a full (B, n, n) slack block of wasted work). When n
    divides evenly there is no padding at all and the per-block reduction
    skips the clamp + liveness masking entirely.
    """
    n = xs.shape[0]
    apex_block = max(1, min(int(apex_block), max(n, 1)))
    nb = max(1, -(-n // apex_block))
    assert nb * apex_block - n < apex_block, (n, apex_block, nb)
    padded = nb * apex_block != n
    cs = jnp.arange(nb * apex_block, dtype=jnp.int32).reshape(nb, apex_block)
    per_block = jax.lax.map(
        lambda c: _apex_block_max(xs, c, n_live, padded=padded), cs
    )
    return jnp.max(per_block)


def triangle_violation_sharded(xs, mesh, axis: str = "solver",
                               *, apex_block: int = 8, n_live=None):
    """Multi-device triangle violation: apex blocks are dealt round-robin
    over the mesh axis, each device reduces its share with the same blocked
    kernel, and one ``pmax`` merges the partial maxima — the monitor's
    analogue of the solvers' per-diagonal psum. ``xs`` is replicated.
    The dealt table is padded to the device count, so blocks may run
    arbitrarily far past n (every padding apex masks to -inf)."""
    from jax.sharding import PartitionSpec as P

    n = xs.shape[0]
    p = mesh.devices.size
    apex_block = max(1, min(int(apex_block), max(n, 1)))
    nb = max(1, -(-n // apex_block))
    nb = -(-nb // p) * p  # pad block count to the device count
    cs = jnp.arange(nb * apex_block, dtype=jnp.int32).reshape(
        p, nb // p, apex_block
    )

    def local(xs_rep, blocks):
        blocks = blocks[0]  # drop the unit device axis
        v = jax.lax.map(lambda c: _apex_block_max(xs_rep, c, n_live), blocks)
        return jax.lax.pmax(jnp.max(v), axis)

    return _shard_map(
        local, mesh=mesh, in_specs=(P(), P(axis)), out_specs=P()
    )(xs, cs)


def triangle_violation_sharded_kernel(xs, mesh, axis: str = "solver",
                                      *, block: int = 8, block_r: int = 128,
                                      block_c: int | None = None,
                                      n_live: int | None = None,
                                      interpret: bool | None = None):
    """Kernel-backed multi-device triangle violation (DESIGN.md §14): the
    lane-blocked Pallas slab kernel composed with the apex-dealing
    ``shard_map`` + ``pmax`` of the jnp path above.

    The apex rows are dealt as **contiguous block-aligned slabs**: device
    k reduces apexes [k·m, (k+1)·m) from its (m, npad) shard of the
    row-padded iterate, drawing (a, b) tiles from the replicated ``xs``
    inside the kernel's (apex, column, row) grid — so per-device VMEM per
    grid step is (A + R)·block_c + A·R floats regardless of n, and the
    only cross-device traffic is the final scalar ``pmax``. Contiguous
    (not round-robin) dealing keeps every padding apex at the global tail
    with index >= n, which the kernel masks exactly like grid padding.
    Bitwise-equal to ``triangle_violation`` (max is association-free).

    ``n_live`` is the ghost-padding contract (static int here — the
    sharded solver's shapes are static). ``interpret`` defaults to
    "not on TPU".
    """
    from jax.sharding import PartitionSpec as P

    from repro.kernels.metric_project.violation import (
        max_triangle_violation_slab_pallas,
    )

    n = xs.shape[0]
    p = mesh.devices.size
    m = -(-n // (p * block)) * block  # block-aligned apex rows per device
    xa = jnp.pad(xs, ((0, p * m - n), (0, 0)))
    live = n if n_live is None else int(min(n_live, n))
    interp = (jax.default_backend() != "tpu") if interpret is None else interpret

    def local(xs_rep, xa_shard):
        off = jax.lax.axis_index(axis).astype(jnp.int32) * m
        v = max_triangle_violation_slab_pallas(
            xa_shard, off, xs_rep, block=block, block_r=block_r,
            block_c=block_c, interpret=interp, n_live=live,
        )
        return jax.lax.pmax(v, axis)

    return _shard_map(
        local, mesh=mesh, in_specs=(P(), P(axis)), out_specs=P(),
        **{_CHECK_KW: False},
    )(xs, xa)


def max_violation(dp: DeviceProblem, x, f=None, *, tri=None):
    """Max violation over every constraint family (device scalar).

    ``tri`` optionally injects a precomputed triangle-family violation
    (the sharded psum-max or the Pallas kernel); by default the blocked
    jnp reduction runs on the replicated iterate.
    """
    if tri is None:
        tri = triangle_violation(symmetrize(dp.mask, x), n_live=dp.n_real)
    viol = tri
    ninf = -jnp.inf
    if dp.has_f and f is not None:
        pairv = jnp.where(dp.mask, jnp.abs(x - dp.d) - f, ninf)
        viol = jnp.maximum(viol, jnp.max(pairv))
    if dp.box is not None:
        lo, hi = dp.box
        viol = jnp.maximum(viol, jnp.max(jnp.where(dp.mask, x - hi, ninf)))
        viol = jnp.maximum(viol, jnp.max(jnp.where(dp.mask, lo - x, ninf)))
    return jnp.maximum(viol, 0.0)


def qp_objective(dp: DeviceProblem, x, f=None):
    """c'v + (eps/2) v'Wv over the upper triangle (MetricQP.qp_objective)."""
    m = dp.mask
    val = jnp.sum(jnp.where(m, dp.c_x * x + 0.5 * dp.eps * dp.w * x * x, 0.0))
    if dp.has_f:
        val = val + jnp.sum(
            jnp.where(m, dp.c_f * f + 0.5 * dp.eps * dp.w_f * f * f, 0.0)
        )
    return val


def lp_objective(dp: DeviceProblem, x):
    """Σ w |x - d| over the upper triangle (MetricQP.lp_objective)."""
    return jnp.sum(jnp.where(dp.mask, dp.w * jnp.abs(x - dp.d), 0.0))


def duality_gap(dp: DeviceProblem, x, f, ypair, ybox):
    """gap = c'v + eps v'Wv + b'y, from the Dykstra dual invariant
    (DESIGN.md §1). Triangle constraints have b = 0 — their b'y term is
    zero *by construction*, which is exactly why the gap never needs the
    triangle duals, dense or slab-native. Pair/box terms come from the
    (2, n, n) dual matrices.
    """
    m = dp.mask
    val = jnp.sum(jnp.where(m, dp.c_x * x + dp.eps * dp.w * x * x, 0.0))
    if dp.has_f:
        val = val + jnp.sum(
            jnp.where(m, dp.c_f * f + dp.eps * dp.w_f * f * f, 0.0)
        )
        # pair 0: x - f <= d  (b = +d); pair 1: -x - f <= -d  (b = -d)
        val = val + jnp.sum(jnp.where(m, dp.d * ypair[0], 0.0))
        val = val - jnp.sum(jnp.where(m, dp.d * ypair[1], 0.0))
    if dp.box is not None:
        lo, hi = dp.box
        val = val + hi * jnp.sum(jnp.where(m, ybox[0], 0.0))
        val = val - lo * jnp.sum(jnp.where(m, ybox[1], 0.0))
    return val


def triangle_dual_stats(yd, valid_masks):
    """Summary stats of schedule-native triangle dual slabs, reduced
    slab-native — the dense (n, n, n) tensor is never formed.

    ``valid_masks`` (schedule.slab_valid_masks) marks real dual cells;
    padding cells carry don't-care values under fused execution
    (DESIGN.md §4) and must not leak into the reductions. On
    ghost-padded problems pass the ghost-aware masks
    (``slab_valid_masks(layout, n_real)``) — ghost-set cells are
    don't-care too; the masks may also be traced (the batched engine
    builds them per instance from a traced ``n_real``). Matches
    ``convergence.triangle_dual_stats(duals_to_dense(...))`` exactly: the
    dense tensor's structural zeros floor dual_min at 0 and cap dual_max
    from below at 0, so the slab-native min/max fold a 0 in.
    """
    zero = jnp.zeros((), yd[0].dtype if yd else jnp.float32)
    # 3·C(n, 3) real duals pass int32 range at n ≈ 1626 — count in int64
    # where available (exact counts at that scale require x64).
    cnt_dt = jnp.int64 if jax.config.jax_enable_x64 else jnp.int32
    dual_min, dual_max, l1, active = zero, zero, zero, jnp.zeros((), cnt_dt)
    for y, v in zip(yd, valid_masks):
        v = v.reshape(y.shape)
        dual_min = jnp.minimum(dual_min, jnp.min(jnp.where(v, y, jnp.inf)))
        dual_max = jnp.maximum(dual_max, jnp.max(jnp.where(v, y, -jnp.inf)))
        l1 = l1 + jnp.sum(jnp.where(v, jnp.abs(y), 0.0))
        active = active + jnp.sum(jnp.where(v, y != 0, False), dtype=cnt_dt)
    return {
        "dual_min": dual_min,
        "dual_max": dual_max,
        "dual_l1": l1,
        "active_constraints": active,
    }

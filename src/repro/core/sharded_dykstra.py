"""Multi-device parallel Dykstra via shard_map (the distributed solver).

Maps the paper's multithreaded execution model onto a TPU/CPU device mesh:

  * **Set assignment** (paper Fig. 3): the r-th set on each diagonal goes to
    device ``r mod p``. We materialize this as per-device work arrays of shape
    ``(p, D, Cl)`` (Cl = ceil(Cmax/p)) so the shard_map simply splits axis 0.
  * **Per-device dual arrays** (paper §III.D): every triplet is visited by the
    same device in the same order each pass, so its three duals live in a
    *schedule-native* slab ``(p, D, 3, T, Cl)`` sharded on axis 0 — the exact
    analogue of the paper's per-processor arrays; duals never travel. The
    layout (and its dense conversion maps) is built centrally by
    ``core/schedule.py::build_layout`` and shared with the single-device
    solver (DESIGN.md §3).
  * **Shared-memory X → replicated X + exact delta merge**: each device holds
    a replica of X and updates only the entries of its own sets. Because the
    schedule is conflict-free, per-device deltas are supported on *disjoint*
    cells, so one ``psum`` per diagonal merges them exactly (not an average —
    this is why the paper's schedule parallelizes Dykstra where the
    averaging-based parallel Dykstra of Iusem & De Pierro fails).

The pair/box constraint families are O(n^2), conflict-free across pairs, and
executed replicated (identical on every device; no communication).

Collective cost: one (n, n) psum per diagonal, ~2n psums per pass. The
per-device compute is O(n^3 / p) — the solver becomes compute-bound once
n / p is large, which is the trillion-constraint regime the paper targets
(see EXPERIMENTS.md §Dry-run for the 512-chip memory/collective analysis).

Pair/box steps, host/device metrics, dual conversions and the
``run_until`` solve-to-tolerance runtime are inherited from
``core/engine.py::SolverRuntime`` (DESIGN.md §7); this module only adds
the sharded specifics — a psum-max violation probe whose apex blocks are
dealt over the mesh axis, and sharded placement of imported dual slabs.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

try:  # jax >= 0.5 exposes shard_map at the top level
    shard_map = jax.shard_map
except AttributeError:  # jax 0.4.x
    from jax.experimental.shard_map import shard_map

# The replication-check kwarg was renamed check_rep -> check_vma in newer
# jax; pick whichever the selected shard_map accepts.
import inspect as _inspect

_CHECK_KW = (
    "check_vma"
    if "check_vma" in _inspect.signature(shard_map).parameters
    else "check_rep"
)

from repro.core import metrics_device, schedule as sched
from repro.core.engine import SolverRuntime
from repro.core.parallel_dykstra import folded_geometry
from repro.core.problems import MetricQP

__all__ = ["ShardedSolver", "ShardedState"]

AXIS = "solver"


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class ShardedState:
    x: jax.Array  # (n, n), replicated
    f: jax.Array | None  # (n, n), replicated
    yd: list[jax.Array]  # per bucket: (p, D_b, 3, T_b, Cl_b), sharded axis 0
    ypair: jax.Array | None  # (2, n, n), replicated
    ybox: jax.Array | None
    passes: jax.Array


class ShardedSolver(SolverRuntime):
    """Distributed Dykstra over a 1-D device mesh.

    Args:
      problem: MetricQP instance.
      mesh: a jax Mesh with a single axis named "solver" (built by
        launch/mesh.py for production; tests pass small host meshes).
      num_buckets: diagonal buckets (contiguous, order preserving).
      use_kernel: route the inner sweep through the Pallas kernel.
    """

    def __init__(
        self,
        problem: MetricQP,
        mesh: Mesh,
        dtype=jnp.float32,
        num_buckets: int = 4,
        use_kernel: bool = False,
        delta_mode: str = "psum",
    ):
        """delta_mode:
          "psum"   — paper-faithful shared-memory emulation: one (n, n)
                     delta all-reduce per diagonal.
          "packed" — beyond-paper (§Perf H3): all_gather only the TOUCHED
                     row/column segments in schedule layout — the payload is
                     the actual update support (~2·C·T values per diagonal)
                     instead of the full n² matrix.
        """
        assert mesh.axis_names == (AXIS,), mesh.axis_names
        assert delta_mode in ("psum", "packed"), delta_mode
        self.p = problem
        self.n = problem.n
        self.mesh = mesh
        self.dtype = dtype
        self.nproc = mesh.devices.size
        self.use_kernel = use_kernel
        self.delta_mode = delta_mode
        self.num_buckets = num_buckets
        # Schedule-native dual layout, shared with ParallelSolver and the
        # elastic re-sharder (DESIGN.md §3).
        self.layout = sched.build_layout(
            self.n, num_buckets=num_buckets, procs=self.nproc
        )
        self._w = jnp.asarray(problem.w, dtype)
        self._d = jnp.asarray(problem.d, dtype)
        self._wf = jnp.asarray(problem.w_f, dtype) if problem.has_f else None
        self._mask = jnp.triu(jnp.ones((self.n, self.n), bool), k=1)
        # Static staging (DESIGN.md §4): folded geometry, step masks and
        # gathered weight slabs are pass-invariant — precomputed once and
        # sharded on the device axis like the dual slabs, so the per-device
        # scan body below does no index math and no weight gathers.
        stage = sched.build_static_stage(self.layout, problem.w, np.dtype(dtype))
        shard = NamedSharding(mesh, P(AXIS))
        put = lambda a: jax.device_put(jnp.asarray(a), shard)
        self._work_dev = [
            {
                key: put(getattr(bl, key))
                for key in ("i", "k", "sizes", "i2", "k2", "sizes2")
            }
            | {
                "J": put(sb.J),
                "iN": put(sb.iN),
                "kN": put(sb.kN),
                "act": put(sb.active),
                "seg": put(sb.seg),
                "w_row": put(sb.w_row),
                "w_col": put(sb.w_col),
                "w_ikp": put(sb.w_ikp),
                "T": bl.T,
            }
            for bl, sb in zip(self.layout.buckets, stage)
        ]
        self._pass_fn = jax.jit(self._one_pass)

    # ------------------------------------------------------------------ state
    def init_state(self) -> ShardedState:
        n, dt, prob = self.n, self.dtype, self.p
        shard = NamedSharding(self.mesh, P(AXIS))
        rep = NamedSharding(self.mesh, P())
        yd = [
            jax.device_put(jnp.zeros(bl.slab_shape, dt), shard)
            for bl in self.layout.buckets
        ]
        return ShardedState(
            x=jax.device_put(jnp.asarray(prob.x0(), dt), rep),
            f=jax.device_put(jnp.asarray(prob.f0(), dt), rep) if prob.has_f else None,
            yd=yd,
            ypair=jnp.zeros((2, n, n), dt) if prob.has_f else None,
            ybox=jnp.zeros((2, n, n), dt) if prob.box is not None else None,
            passes=jnp.zeros((), jnp.int32),
        )

    # ------------------------------------------------------------- the pass
    def _sweep_fn(self):
        if self.use_kernel:
            from repro.kernels.metric_project import ops as kops

            return kops.diagonal_sweep_slab
        from repro.kernels.metric_project import ref as kref

        return kref.sweep_ref_slab

    def _device_bucket(self, x, yd_b, work, T: int):
        """Runs on ONE device (inside shard_map): sweep its assigned folded
        lanes of every diagonal in this bucket, psum-merging X deltas per
        diagonal. ``work`` is the bucket's sharded work-array dict: lane
        tables plus the static staging slabs (geometry, masks, weights) —
        nothing is re-derived or re-gathered per diagonal."""
        eps = float(self.p.eps)
        sweep = self._sweep_fn()
        # shard_map keeps the device axis with local extent 1 — drop it.
        yd_b = yd_b[0]
        work = {key: val[0] for key, val in work.items()}

        def diag_body(x, inp):
            w, yslab = inp  # per-diagonal slices of work arrays + dual slab
            i1, k1, s1 = w["i"], w["k"], w["sizes"]
            i2, k2, s2 = w["i2"], w["k2"], w["sizes2"]
            J, iN, kN = w["J"], w["iN"], w["kN"]
            active, seg = w["act"], w["seg"]
            get = lambda a, idx, fill: a.at[idx].get(mode="fill", fill_value=fill)
            rowb = get(x, (iN, J), 0.0)
            colb = get(x, (J, kN), 0.0)
            xikp = jnp.stack([get(x, (i1, k1), 0.0), get(x, (i2, k2), 0.0)])
            # per-device duals: schedule-native slab (paper §III.D) — pure
            # slicing, no gather/transpose, because this device always
            # re-visits the same slots in the same order.
            nrow, ncol, nxikp, new_yslab = sweep(
                rowb, colb, xikp, yslab, w["w_row"], w["w_col"], w["w_ikp"],
                active, seg, eps
            )
            add = lambda a, idx, v: a.at[idx].add(
                v, mode="drop", unique_indices=True
            )
            d_row = jnp.where(active, nrow - rowb, 0)
            d_col = jnp.where(active, ncol - colb, 0)
            d_ik1 = jnp.where(s1 > 0, nxikp[0] - xikp[0], 0)
            d_ik2 = jnp.where(s2 > 0, nxikp[1] - xikp[1], 0)
            if self.delta_mode == "psum":
                delta = jnp.zeros_like(x)
                delta = add(delta, (iN, J), d_row)
                delta = add(delta, (J, kN), d_col)
                delta = add(delta, (i1, k1), d_ik1)
                delta = add(delta, (i2, k2), d_ik2)
                # conflict-free ⇒ exact merge (disjoint supports), no average
                x = x + jax.lax.psum(delta, AXIS)
            else:
                # §Perf H3: exchange only the TOUCHED segments in schedule
                # layout — payload per diagonal is p·(2·T·Cl + 7·Cl) floats
                # (the update support) instead of the n² matrix. Each device
                # owns a distinct slot of the compact buffer, so the psum is
                # an exact merge; conflict-freedom makes the post-merge
                # scatter exact too.
                T_, Cl_ = d_row.shape
                rank = jax.lax.axis_index(AXIS)
                p_ = self.nproc
                pack = jnp.zeros((2 * T_ + 7, p_, Cl_), d_row.dtype)
                asf = lambda a: a[None].astype(d_row.dtype)
                mine = jnp.concatenate(
                    [d_row, d_col, d_ik1[None], d_ik2[None],
                     asf(i1), asf(k1), asf(i2), asf(k2), asf(s1)], axis=0
                )  # (2T+7, Cl)
                pack = jax.lax.dynamic_update_slice(
                    pack, mine[:, None, :], (0, rank, 0)
                )
                pack = jax.lax.psum(pack, AXIS)  # invariant, compact payload
                # every device reconstructs all p lane groups: flatten the
                # (p, Cl) lane tables and reuse the shared folded geometry
                g_row = jnp.moveaxis(pack[:T_], 1, 0)        # (p, T, Cl)
                g_col = jnp.moveaxis(pack[T_:2 * T_], 1, 0)
                g_ik1 = pack[2 * T_]                         # (p, Cl)
                g_ik2 = pack[2 * T_ + 1]
                gint = lambda r: pack[2 * T_ + r].astype(jnp.int32).reshape(-1)
                gJ, gi, gk, _, _ = folded_geometry(
                    gint(2), gint(3), gint(6), gint(4), gint(5),
                    jnp.where(gint(4) >= 0, gint(5) - gint(4) - 1, 0), T_,
                )  # (T, p·Cl) each
                to3 = lambda a: jnp.moveaxis(a.reshape(T_, p_, Cl_), 1, 0)
                gi, gk, gJ = to3(gi), to3(gk), to3(gJ)       # (p, T, Cl)
                g_i1 = pack[2 * T_ + 2].astype(jnp.int32)
                g_k1 = pack[2 * T_ + 3].astype(jnp.int32)
                g_i2 = pack[2 * T_ + 4].astype(jnp.int32)
                g_k2 = pack[2 * T_ + 5].astype(jnp.int32)
                # padding lanes (i = -1) carry zero deltas; their indices may
                # alias real cells after clamping, so no unique_indices here
                gadd = lambda a, idx, v: a.at[idx].add(v, mode="drop")
                x = gadd(x, (gi, gJ), g_row)
                x = gadd(x, (gJ, gk), g_col)
                x = gadd(x, (g_i1, g_k1), g_ik1)
                x = gadd(x, (g_i2, g_k2), g_ik2)
            return x, new_yslab

        x, new_yd = jax.lax.scan(diag_body, x, (work, yd_b))
        return x, new_yd[None]  # restore the local device axis for out_specs

    def _one_pass(self, st: ShardedState) -> ShardedState:
        x = st.x
        new_yd = []
        for b, work in zip(st.yd, self._work_dev):
            fn = functools.partial(self._device_bucket, T=work["T"])
            arrays = {key: val for key, val in work.items() if key != "T"}
            x, yb = shard_map(
                fn,
                mesh=self.mesh,
                in_specs=(P(), P(AXIS), P(AXIS)),
                out_specs=(P(), P(AXIS)),
                # pallas_call has no replication rule; the per-diagonal psum
                # makes x replicated by construction.
                **{_CHECK_KW: not self.use_kernel},
            )(x, b, arrays)
            new_yd.append(yb)
        f, ypair, ybox = st.f, st.ypair, st.ybox
        mask = self._mask
        if self.p.has_f:
            x2, f2, ypair = self._pair_step(x, f, ypair)
            x = jnp.where(mask, x2, x)
            f = jnp.where(mask, f2, f)
            ypair = jnp.where(mask[None], ypair, 0)
        if self.p.box is not None:
            x2, ybox = self._box_step(x, ybox)
            x = jnp.where(mask, x2, x)
            ybox = jnp.where(mask[None], ybox, 0)
        return ShardedState(x, f, new_yd, ypair, ybox, st.passes + 1)

    # ------------------------------------------------------------------ API
    def run(self, state: ShardedState | None = None, passes: int = 1) -> ShardedState:
        st = state if state is not None else self.init_state()
        for _ in range(passes):
            st = self._pass_fn(st)
        return st

    # ----------------------------------------------------- engine hooks
    # Dual conversions, pair/box steps, metrics and run_until live on
    # SolverRuntime (core/engine.py); this solver customizes device
    # placement of imported slabs and shards the violation probe.
    def _put_slab(self, slab: np.ndarray):
        shard = NamedSharding(self.mesh, P(AXIS))
        return jax.device_put(jnp.asarray(slab, self.dtype), shard)

    def _triangle_violation(self, x):
        """Apex blocks dealt over the mesh, partial maxima psum-maxed —
        the probe's compute scales O(n^3 / p) like the pass itself."""
        return metrics_device.triangle_violation_sharded(
            metrics_device.symmetrize(self._dprob.mask, x), self.mesh, AXIS
        )

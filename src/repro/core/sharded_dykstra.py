"""Multi-device parallel Dykstra via shard_map (the distributed solver).

Maps the paper's multithreaded execution model onto a TPU/CPU device mesh:

  * **Set assignment** (paper Fig. 3): the r-th set on each diagonal goes to
    device ``r mod p``. We materialize this as per-device work arrays of shape
    ``(p, D, Cl)`` (Cl = ceil(Cmax/p)) so the shard_map simply splits axis 0.
  * **Per-device dual arrays** (paper §III.D): every triplet is visited by the
    same device in the same order each pass, so its three duals live in a
    *schedule-layout* slab ``(p, D, Cl, T, 3)`` sharded on axis 0 — the exact
    analogue of the paper's per-processor arrays; duals never travel.
  * **Shared-memory X → replicated X + exact delta merge**: each device holds
    a replica of X and updates only the entries of its own sets. Because the
    schedule is conflict-free, per-device deltas are supported on *disjoint*
    cells, so one ``psum`` per diagonal merges them exactly (not an average —
    this is why the paper's schedule parallelizes Dykstra where the
    averaging-based parallel Dykstra of Iusem & De Pierro fails).

The pair/box constraint families are O(n^2), conflict-free across pairs, and
executed replicated (identical on every device; no communication).

Collective cost: one (n, n) psum per diagonal, ~2n psums per pass. The
per-device compute is O(n^3 / p) — the solver becomes compute-bound once
n / p is large, which is the trillion-constraint regime the paper targets
(see EXPERIMENTS.md §Dry-run for the 512-chip memory/collective analysis).
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import schedule as sched
from repro.core.problems import MetricQP

__all__ = ["ShardedSolver", "ShardedState"]

AXIS = "solver"


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class ShardedState:
    x: jax.Array  # (n, n), replicated
    f: jax.Array | None  # (n, n), replicated
    yd: list[jax.Array]  # per bucket: (p, D_b, Cl_b, T_b, 3), sharded axis 0
    ypair: jax.Array | None  # (2, n, n), replicated
    ybox: jax.Array | None
    passes: jax.Array


def _bucket_work(n: int, p: int, num_buckets: int):
    """Precompute per-device work arrays per bucket.

    Returns a list of dicts with numpy arrays:
      i, k, sizes: (p, D_b, Cl) int32  (padded with -1 / 0)
      T: int — max middle-index steps in this bucket.
    """
    diags = sched.diagonal_list(n)
    groups = np.array_split(np.arange(len(diags)), num_buckets)
    buckets = []
    for g in groups:
        if len(g) == 0:
            continue
        ds = [diags[r] for r in g]
        T = max(d.max_size for d in ds)
        Cl = max(-(-d.num_sets // p) for d in ds)
        D_b = len(ds)
        i_arr = np.full((p, D_b, Cl), -1, dtype=np.int32)
        k_arr = np.full((p, D_b, Cl), -1, dtype=np.int32)
        s_arr = np.zeros((p, D_b, Cl), dtype=np.int32)
        for r, d in enumerate(ds):
            for c in range(d.num_sets):
                dev = c % p  # paper Fig. 3 assignment
                slot = c // p
                i_arr[dev, r, slot] = d.i[c]
                k_arr[dev, r, slot] = d.k[c]
                s_arr[dev, r, slot] = d.k[c] - d.i[c] - 1
        buckets.append(dict(i=i_arr, k=k_arr, sizes=s_arr, T=T, D=D_b, Cl=Cl))
    return buckets


class ShardedSolver:
    """Distributed Dykstra over a 1-D device mesh.

    Args:
      problem: MetricQP instance.
      mesh: a jax Mesh with a single axis named "solver" (built by
        launch/mesh.py for production; tests pass small host meshes).
      num_buckets: diagonal buckets (contiguous, order preserving).
      use_kernel: route the inner sweep through the Pallas kernel.
    """

    def __init__(
        self,
        problem: MetricQP,
        mesh: Mesh,
        dtype=jnp.float32,
        num_buckets: int = 4,
        use_kernel: bool = False,
        delta_mode: str = "psum",
    ):
        """delta_mode:
          "psum"   — paper-faithful shared-memory emulation: one (n, n)
                     delta all-reduce per diagonal.
          "packed" — beyond-paper (§Perf H3): all_gather only the TOUCHED
                     row/column segments in schedule layout — the payload is
                     the actual update support (~2·C·T values per diagonal)
                     instead of the full n² matrix.
        """
        assert mesh.axis_names == (AXIS,), mesh.axis_names
        assert delta_mode in ("psum", "packed"), delta_mode
        self.p = problem
        self.n = problem.n
        self.mesh = mesh
        self.dtype = dtype
        self.nproc = mesh.devices.size
        self.use_kernel = use_kernel
        self.delta_mode = delta_mode
        self.work = _bucket_work(self.n, self.nproc, num_buckets)
        self._w = jnp.asarray(problem.w, dtype)
        self._d = jnp.asarray(problem.d, dtype)
        self._wf = jnp.asarray(problem.w_f, dtype) if problem.has_f else None
        self._work_dev = [
            {
                key: jax.device_put(
                    jnp.asarray(b[key]), NamedSharding(mesh, P(AXIS))
                )
                for key in ("i", "k", "sizes")
            }
            | {"T": b["T"]}
            for b in self.work
        ]
        self._pass_fn = jax.jit(self._one_pass)

    # ------------------------------------------------------------------ state
    def init_state(self) -> ShardedState:
        n, dt, prob = self.n, self.dtype, self.p
        shard = NamedSharding(self.mesh, P(AXIS))
        rep = NamedSharding(self.mesh, P())
        yd = [
            jax.device_put(
                jnp.zeros((self.nproc, b["D"], b["Cl"], b["T"], 3), dt), shard
            )
            for b in self.work
        ]
        return ShardedState(
            x=jax.device_put(jnp.asarray(prob.x0(), dt), rep),
            f=jax.device_put(jnp.asarray(prob.f0(), dt), rep) if prob.has_f else None,
            yd=yd,
            ypair=jnp.zeros((2, n, n), dt) if prob.has_f else None,
            ybox=jnp.zeros((2, n, n), dt) if prob.box is not None else None,
            passes=jnp.zeros((), jnp.int32),
        )

    # ------------------------------------------------------------- the pass
    def _sweep_fn(self):
        if self.use_kernel:
            from repro.kernels.metric_project import ops as kops

            return kops.diagonal_sweep
        from repro.kernels.metric_project import ref as kref

        return kref.sweep_ref

    def _device_bucket(self, x, yd_b, i_b, k_b, s_b, T: int):
        """Runs on ONE device (inside shard_map): sweep its assigned sets of
        every diagonal in this bucket, psum-merging X deltas per diagonal."""
        n = self.n
        eps = float(self.p.eps)
        w = self._w
        sweep = self._sweep_fn()
        # shard_map keeps the device axis with local extent 1 — drop it.
        yd_b, i_b, k_b, s_b = yd_b[0], i_b[0], k_b[0], s_b[0]

        def diag_body(x, inp):
            i_vec, k_vec, sizes, yslab = inp  # (Cl,), (Cl,), (Cl,), (Cl, T, 3)
            C = i_vec.shape[0]
            t_idx = jnp.arange(T, dtype=jnp.int32)
            J = i_vec[None, :] + 1 + t_idx[:, None]
            iN = jnp.broadcast_to(i_vec[None, :], (T, C))
            kN = jnp.broadcast_to(k_vec[None, :], (T, C))
            active = (t_idx[:, None] < sizes[None, :]) & (i_vec[None, :] >= 0)
            get = lambda a, idx, fill: a.at[idx].get(mode="fill", fill_value=fill)
            rowb = get(x, (iN, J), 0.0)
            colb = get(x, (J, kN), 0.0)
            xik = get(x, (i_vec, k_vec), 0.0)
            # per-device duals: schedule layout (paper §III.D) — pure slicing,
            # no gather, because this device always re-visits the same slots.
            y0, y1, y2 = yslab[:, :, 0].T, yslab[:, :, 1].T, yslab[:, :, 2].T
            w_row = get(w, (iN, J), 1.0)
            w_col = get(w, (J, kN), 1.0)
            w_ik = get(w, (i_vec, k_vec), 1.0)
            nrow, ncol, nxik, n0, n1, n2 = sweep(
                rowb, colb, xik, y0, y1, y2, w_row, w_col, w_ik, active, eps
            )
            add = lambda a, idx, v: a.at[idx].add(
                v, mode="drop", unique_indices=True
            )
            d_row = jnp.where(active, nrow - rowb, 0)
            d_col = jnp.where(active, ncol - colb, 0)
            any_act = active.any(axis=0)
            d_ik = jnp.where(any_act, nxik - xik, 0)
            if self.delta_mode == "psum":
                delta = jnp.zeros_like(x)
                delta = add(delta, (iN, J), d_row)
                delta = add(delta, (J, kN), d_col)
                delta = add(delta, (i_vec, k_vec), d_ik)
                # conflict-free ⇒ exact merge (disjoint supports), no average
                x = x + jax.lax.psum(delta, AXIS)
            else:
                # §Perf H3: exchange only the TOUCHED segments in schedule
                # layout — payload per diagonal is p·(2·T·Cl + 3·Cl) floats
                # (the update support) instead of the n² matrix. Each device
                # owns a distinct slot of the compact buffer, so the psum is
                # an exact merge; conflict-freedom makes the post-merge
                # scatter exact too.
                T_, Cl_ = d_row.shape
                rank = jax.lax.axis_index(AXIS)
                p_ = self.nproc
                pack = jnp.zeros((2 * T_ + 3, p_, Cl_), d_row.dtype)
                mine = jnp.concatenate(
                    [d_row, d_col,
                     d_ik[None], i_vec[None].astype(d_row.dtype),
                     k_vec[None].astype(d_row.dtype)], axis=0
                )  # (2T+3, Cl)
                pack = jax.lax.dynamic_update_slice(
                    pack, mine[:, None, :], (0, rank, 0)
                )
                pack = jax.lax.psum(pack, AXIS)  # invariant, compact payload
                g_row = jnp.moveaxis(pack[:T_], 1, 0)        # (p, T, Cl)
                g_col = jnp.moveaxis(pack[T_:2 * T_], 1, 0)
                g_ik = pack[2 * T_]                          # (p, Cl)
                g_i = pack[2 * T_ + 1].astype(jnp.int32)
                g_k = pack[2 * T_ + 2].astype(jnp.int32)
                gi = jnp.broadcast_to(g_i[:, None, :], (p_, T_, Cl_))
                gk = jnp.broadcast_to(g_k[:, None, :], (p_, T_, Cl_))
                gJ = gi + 1 + jnp.arange(T_, dtype=jnp.int32)[None, :, None]
                # padding lanes (i = -1) carry zero deltas; their indices may
                # alias real cells after clamping, so no unique_indices here
                gadd = lambda a, idx, v: a.at[idx].add(v, mode="drop")
                x = gadd(x, (gi, gJ), g_row)
                x = gadd(x, (gJ, gk), g_col)
                x = gadd(x, (g_i, g_k), g_ik)
            new_yslab = jnp.stack([n0.T, n1.T, n2.T], axis=-1)
            return x, new_yslab

        x, new_yd = jax.lax.scan(diag_body, x, (i_b, k_b, s_b, yd_b))
        return x, new_yd[None]  # restore the local device axis for out_specs

    def _pair_step(self, x, f, ypair):
        eps = float(self.p.eps)
        w, wf, d = self._w, self._wf, self._d
        iw_x, iw_f = 1.0 / w, 1.0 / wf
        denom = iw_x + iw_f
        xv = x + ypair[0] * iw_x / eps
        fv = f - ypair[0] * iw_f / eps
        theta = eps * jnp.maximum(xv - fv - d, 0.0) / denom
        x, f, y0 = xv - theta * iw_x / eps, fv + theta * iw_f / eps, theta
        xv = x - ypair[1] * iw_x / eps
        fv = f - ypair[1] * iw_f / eps
        theta = eps * jnp.maximum(d - xv - fv, 0.0) / denom
        x, f = xv + theta * iw_x / eps, fv + theta * iw_f / eps
        return x, f, jnp.stack([y0, theta])

    def _box_step(self, x, ybox):
        eps = float(self.p.eps)
        lo, hi = self.p.box
        iw_x = 1.0 / self._w
        xv = x + ybox[0] * iw_x / eps
        th_hi = eps * jnp.maximum(xv - hi, 0.0) / iw_x
        x = xv - th_hi * iw_x / eps
        xv = x - ybox[1] * iw_x / eps
        th_lo = eps * jnp.maximum(lo - xv, 0.0) / iw_x
        x = xv + th_lo * iw_x / eps
        return x, jnp.stack([th_hi, th_lo])

    def _one_pass(self, st: ShardedState) -> ShardedState:
        x = st.x
        new_yd = []
        for b, work in zip(st.yd, self._work_dev):
            T = work["T"]
            fn = functools.partial(self._device_bucket, T=T)
            x, yb = jax.shard_map(
                fn,
                mesh=self.mesh,
                in_specs=(P(), P(AXIS), P(AXIS), P(AXIS), P(AXIS)),
                out_specs=(P(), P(AXIS)),
            )(x, b, work["i"], work["k"], work["sizes"])
            new_yd.append(yb)
        f, ypair, ybox = st.f, st.ypair, st.ybox
        mask = jnp.triu(jnp.ones((self.n, self.n), bool), k=1)
        if self.p.has_f:
            x2, f2, ypair = self._pair_step(x, f, ypair)
            x = jnp.where(mask, x2, x)
            f = jnp.where(mask, f2, f)
            ypair = jnp.where(mask[None], ypair, 0)
        if self.p.box is not None:
            x2, ybox = self._box_step(x, ybox)
            x = jnp.where(mask, x2, x)
            ybox = jnp.where(mask[None], ybox, 0)
        return ShardedState(x, f, new_yd, ypair, ybox, st.passes + 1)

    # ------------------------------------------------------------------ API
    def run(self, state: ShardedState | None = None, passes: int = 1) -> ShardedState:
        st = state if state is not None else self.init_state()
        for _ in range(passes):
            st = self._pass_fn(st)
        return st

    def duals_to_dense(self, st: ShardedState) -> np.ndarray:
        """Schedule-layout duals → dense ytri[a, b, c] (testing/metrics)."""
        n = self.n
        ytri = np.zeros((n, n, n), dtype=np.float64)
        for b, work in zip(st.yd, self.work):
            arr = np.asarray(b, np.float64)
            i_a, k_a, s_a = work["i"], work["k"], work["sizes"]
            p_, D_, Cl = i_a.shape
            for dev in range(p_):
                for r in range(D_):
                    for c in range(Cl):
                        i, k, sz = i_a[dev, r, c], k_a[dev, r, c], s_a[dev, r, c]
                        if i < 0:
                            continue
                        for t in range(sz):
                            j = i + 1 + t
                            ytri[i, j, k] = arr[dev, r, c, t, 0]
                            ytri[i, k, j] = arr[dev, r, c, t, 1]
                            ytri[j, k, i] = arr[dev, r, c, t, 2]
        return ytri

    def metrics(self, st: ShardedState) -> dict:
        from repro.core import convergence

        class _Np:
            x = np.asarray(st.x, np.float64)
            f = np.asarray(st.f, np.float64) if st.f is not None else None
            ypair = np.asarray(st.ypair, np.float64) if st.ypair is not None else None
            ybox = np.asarray(st.ybox, np.float64) if st.ybox is not None else None
            passes = int(st.passes)

        return convergence.report(self.p, _Np())

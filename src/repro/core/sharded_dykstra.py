"""Multi-device parallel Dykstra via shard_map (the distributed solver).

Maps the paper's multithreaded execution model onto a TPU/CPU device mesh:

  * **Set assignment** (paper Fig. 3): the r-th set on each diagonal goes to
    device ``r mod p``. We materialize this as per-device work arrays of shape
    ``(p, D, Cl)`` (Cl = ceil(Cmax/p)) so the shard_map simply splits axis 0.
  * **Per-device dual arrays** (paper §III.D): every triplet is visited by the
    same device in the same order each pass, so its three duals live in a
    *schedule-native* slab ``(p, D, 3, T, Cl)`` sharded on axis 0 — the exact
    analogue of the paper's per-processor arrays; duals never travel. The
    layout (and its dense conversion maps) is built centrally by
    ``core/schedule.py::build_layout`` and shared with the single-device
    solver (DESIGN.md §3).
  * **Shared-memory X → replicated X + exact delta merge**: each device holds
    a replica of X and updates only the entries of its own sets. Because the
    schedule is conflict-free, per-device deltas are supported on *disjoint*
    cells, so one ``psum`` per diagonal merges them exactly (not an average —
    this is why the paper's schedule parallelizes Dykstra where the
    averaging-based parallel Dykstra of Iusem & De Pierro fails).

The pair/box constraint families are O(n^2), conflict-free across pairs, and
executed replicated (identical on every device; no communication).

Collective cost: one (n, n) psum per diagonal, ~2n psums per pass. The
per-device compute is O(n^3 / p) — the solver becomes compute-bound once
n / p is large, which is the trillion-constraint regime the paper targets
(see EXPERIMENTS.md §Dry-run for the 512-chip memory/collective analysis).

**Fused-pass execution** (DESIGN.md §9, the default): the per-device sweep
consumes staged *projection gains* ``g = (1/w)/eps`` and ``dinv``
(`ref.fused_diag_sweep`, the same staged math as the single-device fused
path — no per-step division, no restore-selects, scan unroll), and
``run(passes=P)`` executes all P passes as ONE jitted ``lax.scan`` whose
body is the shard_map pass — one dispatch and one host sync for the whole
run instead of one per pass, with the periodic ``||Δx||_inf`` probe on
``last_residuals``. ``fused=False`` keeps the PR-1-style path (runtime
weight division in ``sweep_ref_slab``, one jitted dispatch per pass) as
the benchmark baseline.

Pair/box steps, host/device metrics, dual conversions and the ``run`` /
``run_until`` runtimes are inherited from
``core/engine.py::SolverRuntime`` (DESIGN.md §7/§9); this module only adds
the sharded specifics — a psum-max violation probe whose apex blocks are
dealt over the mesh axis, and sharded placement of imported dual slabs.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

try:  # jax >= 0.5 exposes shard_map at the top level
    shard_map = jax.shard_map
except AttributeError:  # jax 0.4.x
    from jax.experimental.shard_map import shard_map

# The replication-check kwarg was renamed check_rep -> check_vma in newer
# jax; pick whichever the selected shard_map accepts.
import inspect as _inspect

_CHECK_KW = (
    "check_vma"
    if "check_vma" in _inspect.signature(shard_map).parameters
    else "check_rep"
)

from repro.core import metrics_device, schedule as sched
from repro.core.engine import SolverRuntime
from repro.core.parallel_dykstra import folded_geometry
from repro.core.problems import MetricQP

__all__ = ["ShardedSolver", "ShardedState"]

AXIS = "solver"


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class ShardedState:
    x: jax.Array  # (n, n), replicated
    f: jax.Array | None  # (n, n), replicated
    yd: list[jax.Array]  # per bucket: (p, D_b, 3, T_b, Cl_b), sharded axis 0
    ypair: jax.Array | None  # (2, n, n), replicated
    ybox: jax.Array | None
    passes: jax.Array


class ShardedSolver(SolverRuntime):
    """Distributed Dykstra over a 1-D device mesh.

    Args:
      problem: MetricQP instance.
      mesh: a jax Mesh with a single axis named "solver" (built by
        launch/mesh.py for production; tests pass small host meshes).
      num_buckets: diagonal buckets (contiguous, order preserving).
      use_kernel: route the inner sweep through the Pallas kernel.
      fused: fused execution (DESIGN.md §9, default) — staged projection
        gains in the per-device sweep and the single-scan multi-pass
        runner. False keeps the legacy sweep + one dispatch per pass as
        the benchmark baseline.
      sweep_unroll: unroll factor of the inner sequential-in-j scan
        (fused path only).
      probe_every: evaluate the runner's convergence probe every this
        many passes (``last_residuals`` holds -1.0 at skipped passes).
      probe_block_c: lane block width of the kernel-backed violation
        probe (use_kernel=True; DESIGN.md §14). None = full width.
    """

    def __init__(
        self,
        problem: MetricQP,
        mesh: Mesh,
        dtype=jnp.float32,
        num_buckets: int = 4,
        use_kernel: bool = False,
        delta_mode: str = "psum",
        fused: bool = True,
        sweep_unroll: int = 4,
        probe_every: int = 1,
        probe_block_c: int | None = None,
    ):
        """delta_mode:
          "psum"   — paper-faithful shared-memory emulation: one (n, n)
                     delta all-reduce per diagonal.
          "packed" — beyond-paper (§Perf H3): all_gather only the TOUCHED
                     row/column segments in schedule layout — the payload is
                     the actual update support (~2·C·T values per diagonal)
                     instead of the full n² matrix.
        """
        assert mesh.axis_names == (AXIS,), mesh.axis_names
        assert delta_mode in ("psum", "packed"), delta_mode
        if use_kernel and delta_mode == "packed":
            raise ValueError(
                "use_kernel=True requires delta_mode='psum': the gen-3 "
                "megakernel emits the per-diagonal delta matrix directly "
                "(DESIGN.md §10); the packed compact exchange re-derives "
                "deltas host-side and has no kernel path."
            )
        if use_kernel and not fused:
            import warnings

            warnings.warn(
                "use_kernel=True with fused=False has no kernel path: the "
                "gen-1 per-diagonal kernel is demoted to test-oracle "
                "status (PR 6); running the legacy jnp sweep instead. Use "
                "fused=True (default) for the gen-3 megakernel.",
                stacklevel=2,
            )
        self.p = problem
        self.n = problem.n
        self.mesh = mesh
        self.dtype = dtype
        self.nproc = mesh.devices.size
        self.use_kernel = use_kernel
        self.delta_mode = delta_mode
        self.fused = fused
        self.sweep_unroll = max(1, int(sweep_unroll))
        self.probe_every = max(1, int(probe_every))
        # Lane (column) block of the kernel-backed violation probe
        # (use_kernel=True): None keeps one full-width column block; at
        # n ≫ 10³ pick a finite width so the per-device probe's VMEM per
        # grid step stays bounded (DESIGN.md §14).
        self.probe_block_c = (
            None if probe_block_c is None else int(probe_block_c)
        )
        self.num_buckets = num_buckets
        # Schedule-native dual layout, shared with ParallelSolver and the
        # elastic re-sharder (DESIGN.md §3).
        self.layout = sched.build_layout(
            self.n, num_buckets=num_buckets, procs=self.nproc
        )
        self._w = jnp.asarray(problem.w, dtype)
        self._d = jnp.asarray(problem.d, dtype)
        self._wf = jnp.asarray(problem.w_f, dtype) if problem.has_f else None
        self._mask = jnp.triu(jnp.ones((self.n, self.n), bool), k=1)
        # Static staging (DESIGN.md §4): folded geometry, step masks and
        # gathered weight slabs are pass-invariant — precomputed once and
        # sharded on the device axis like the dual slabs, so the per-device
        # scan body below does no index math and no weight gathers.
        npdt = np.dtype(dtype)
        stage = sched.build_static_stage(self.layout, problem.w, npdt)
        shard = NamedSharding(mesh, P(AXIS))
        put = lambda a: jax.device_put(jnp.asarray(a), shard)
        self._work_dev = []
        for bl, sb in zip(self.layout.buckets, stage):
            work = {
                key: put(getattr(bl, key))
                for key in ("i", "k", "sizes", "i2", "k2", "sizes2")
            } | {
                "J": put(sb.J),
                "iN": put(sb.iN),
                "kN": put(sb.kN),
                "act": put(sb.active),
                "seg": put(sb.seg),
                "T": bl.T,
            }
            if self._fused_sweep:
                # Projection gains (DESIGN.md §4), staged with the procs
                # axis and sharded like the dual slabs — the exact
                # expressions of ParallelSolver._stage_buckets, so the
                # per-step math is shared bit-for-bit with the
                # single-device fused path.
                one = npdt.type(1.0)
                epsc = npdt.type(problem.eps)
                g_row = (one / sb.w_row) / epsc
                g_col = (one / sb.w_col) / epsc
                g_ikp = (one / sb.w_ikp) / epsc  # (procs, D, 2, Cl)
                g_sel = np.where(
                    sb.seg,
                    g_ikp[:, :, 1][:, :, None, :],
                    g_ikp[:, :, 0][:, :, None, :],
                ).astype(npdt)
                dinv = (one / (g_row + g_sel + g_col)).astype(npdt)
                work |= {
                    "g_row": put(g_row),
                    "g_col": put(g_col),
                    "g_sel": put(g_sel),
                    "dinv": put(dinv),
                }
            else:
                work |= {
                    "w_row": put(sb.w_row),
                    "w_col": put(sb.w_col),
                    "w_ikp": put(sb.w_ikp),
                }
            self._work_dev.append(work)
        self._pass_fn = jax.jit(self._one_pass)

    # ------------------------------------------------------------------ state
    def init_state(self) -> ShardedState:
        n, dt, prob = self.n, self.dtype, self.p
        shard = NamedSharding(self.mesh, P(AXIS))
        rep = NamedSharding(self.mesh, P())
        yd = [
            jax.device_put(jnp.zeros(bl.slab_shape, dt), shard)
            for bl in self.layout.buckets
        ]
        return ShardedState(
            x=jax.device_put(jnp.asarray(prob.x0(), dt), rep),
            f=jax.device_put(jnp.asarray(prob.f0(), dt), rep) if prob.has_f else None,
            yd=yd,
            ypair=jnp.zeros((2, n, n), dt) if prob.has_f else None,
            ybox=jnp.zeros((2, n, n), dt) if prob.box is not None else None,
            passes=jnp.zeros((), jnp.int32),
        )

    # ------------------------------------------------------------- the pass
    @property
    def _fused_sweep(self) -> bool:
        """True when the per-device sweep runs on staged projection gains —
        the jnp ``ref.fused_diag_sweep`` body, or the gen-3 megakernel in
        delta-output mode when ``use_kernel`` (both consume the same
        staged gains; DESIGN.md §10). Only the legacy baseline
        (``fused=False``) keeps the runtime-weight slab contract."""
        return self.fused

    def _sweep_fn(self):
        # Legacy (fused=False) path only. The gen-1 per-diagonal kernel is
        # test-oracle-only since PR 6, so this is always the jnp sweep.
        from repro.kernels.metric_project import ref as kref

        return kref.sweep_ref_slab

    def _device_bucket(self, x, yd_b, work, T: int):
        """Runs on ONE device (inside shard_map): sweep its assigned folded
        lanes of every diagonal in this bucket, psum-merging X deltas per
        diagonal. ``work`` is the bucket's sharded work-array dict: lane
        tables plus the static staging slabs (geometry, masks, weights) —
        nothing is re-derived or re-gathered per diagonal."""
        eps = float(self.p.eps)
        fused = self._fused_sweep
        sweep = None if fused else self._sweep_fn()
        if fused and self.use_kernel:
            from repro.kernels.metric_project import ops as kops
        elif fused:
            from repro.kernels.metric_project import ref as kref
        # shard_map keeps the device axis with local extent 1 — drop it.
        yd_b = yd_b[0]
        work = {key: val[0] for key, val in work.items()}

        def diag_body(x, inp):
            w, yslab = inp  # per-diagonal slices of work arrays + dual slab
            i1, k1, s1 = w["i"], w["k"], w["sizes"]
            i2, k2, s2 = w["i2"], w["k2"], w["sizes2"]
            J, iN, kN = w["J"], w["iN"], w["kN"]
            active, seg = w["act"], w["seg"]
            if fused and self.use_kernel:
                # Gen-3 megakernel, delta-output mode (DESIGN.md §10): X
                # stays read-only and the kernel emits this device's
                # act-masked delta matrix directly — bitwise-equal to the
                # scatter construction below, so the psum merge is exact.
                delta, new_yslab = kops.fused_diag_pass_delta(
                    x, yslab,
                    jnp.stack([i1, k1, s1, i2, k2, s2]),
                    jnp.stack([J, iN, kN]),
                    w["g_row"], w["g_col"], w["g_sel"], w["dinv"],
                    active, seg, unroll=self.sweep_unroll,
                )
                return x + jax.lax.psum(delta, AXIS), new_yslab
            get = lambda a, idx, fill: a.at[idx].get(mode="fill", fill_value=fill)
            rowb = get(x, (iN, J), 0.0)
            colb = get(x, (J, kN), 0.0)
            xikp = jnp.stack([get(x, (i1, k1), 0.0), get(x, (i2, k2), 0.0)])
            # per-device duals: schedule-native slab (paper §III.D) — pure
            # slicing, no gather/transpose, because this device always
            # re-visits the same slots in the same order.
            if fused:
                # staged-gain sweep (DESIGN.md §4/§9): masked outputs are
                # don't-care — deltas are act-masked below and the dual
                # conversion maps / valid masks skip padding cells.
                nrow, ncol, nxikp, new_yslab = kref.fused_diag_sweep(
                    rowb, colb, xikp, yslab, w["g_row"], w["g_col"],
                    w["g_sel"], w["dinv"], active, seg,
                    unroll=self.sweep_unroll,
                )
            else:
                nrow, ncol, nxikp, new_yslab = sweep(
                    rowb, colb, xikp, yslab, w["w_row"], w["w_col"],
                    w["w_ikp"], active, seg, eps
                )
            add = lambda a, idx, v: a.at[idx].add(
                v, mode="drop", unique_indices=True
            )
            d_row = jnp.where(active, nrow - rowb, 0)
            d_col = jnp.where(active, ncol - colb, 0)
            d_ik1 = jnp.where(s1 > 0, nxikp[0] - xikp[0], 0)
            d_ik2 = jnp.where(s2 > 0, nxikp[1] - xikp[1], 0)
            if self.delta_mode == "psum":
                delta = jnp.zeros_like(x)
                delta = add(delta, (iN, J), d_row)
                delta = add(delta, (J, kN), d_col)
                delta = add(delta, (i1, k1), d_ik1)
                delta = add(delta, (i2, k2), d_ik2)
                # conflict-free ⇒ exact merge (disjoint supports), no average
                x = x + jax.lax.psum(delta, AXIS)
            else:
                # §Perf H3: exchange only the TOUCHED segments in schedule
                # layout — payload per diagonal is p·(2·T·Cl + 7·Cl) floats
                # (the update support) instead of the n² matrix. Each device
                # owns a distinct slot of the compact buffer, so the psum is
                # an exact merge; conflict-freedom makes the post-merge
                # scatter exact too.
                T_, Cl_ = d_row.shape
                rank = jax.lax.axis_index(AXIS)
                p_ = self.nproc
                pack = jnp.zeros((2 * T_ + 7, p_, Cl_), d_row.dtype)
                asf = lambda a: a[None].astype(d_row.dtype)
                mine = jnp.concatenate(
                    [d_row, d_col, d_ik1[None], d_ik2[None],
                     asf(i1), asf(k1), asf(i2), asf(k2), asf(s1)], axis=0
                )  # (2T+7, Cl)
                pack = jax.lax.dynamic_update_slice(
                    pack, mine[:, None, :], (0, rank, 0)
                )
                pack = jax.lax.psum(pack, AXIS)  # invariant, compact payload
                # every device reconstructs all p lane groups: flatten the
                # (p, Cl) lane tables and reuse the shared folded geometry
                g_row = jnp.moveaxis(pack[:T_], 1, 0)        # (p, T, Cl)
                g_col = jnp.moveaxis(pack[T_:2 * T_], 1, 0)
                g_ik1 = pack[2 * T_]                         # (p, Cl)
                g_ik2 = pack[2 * T_ + 1]
                gint = lambda r: pack[2 * T_ + r].astype(jnp.int32).reshape(-1)
                gJ, gi, gk, _, _ = folded_geometry(
                    gint(2), gint(3), gint(6), gint(4), gint(5),
                    jnp.where(gint(4) >= 0, gint(5) - gint(4) - 1, 0), T_,
                )  # (T, p·Cl) each
                to3 = lambda a: jnp.moveaxis(a.reshape(T_, p_, Cl_), 1, 0)
                gi, gk, gJ = to3(gi), to3(gk), to3(gJ)       # (p, T, Cl)
                g_i1 = pack[2 * T_ + 2].astype(jnp.int32)
                g_k1 = pack[2 * T_ + 3].astype(jnp.int32)
                g_i2 = pack[2 * T_ + 4].astype(jnp.int32)
                g_k2 = pack[2 * T_ + 5].astype(jnp.int32)
                # padding lanes (i = -1) carry zero deltas; their indices may
                # alias real cells after clamping, so no unique_indices here
                gadd = lambda a, idx, v: a.at[idx].add(v, mode="drop")
                x = gadd(x, (gi, gJ), g_row)
                x = gadd(x, (gJ, gk), g_col)
                x = gadd(x, (g_i1, g_k1), g_ik1)
                x = gadd(x, (g_i2, g_k2), g_ik2)
            return x, new_yslab

        x, new_yd = jax.lax.scan(diag_body, x, (work, yd_b))
        return x, new_yd[None]  # restore the local device axis for out_specs

    def _one_pass(self, st: ShardedState) -> ShardedState:
        x = st.x
        new_yd = []
        for b, work in zip(st.yd, self._work_dev):
            fn = functools.partial(self._device_bucket, T=work["T"])
            arrays = {key: val for key, val in work.items() if key != "T"}
            x, yb = shard_map(
                fn,
                mesh=self.mesh,
                in_specs=(P(), P(AXIS), P(AXIS)),
                out_specs=(P(), P(AXIS)),
                # pallas_call has no replication rule; the per-diagonal psum
                # makes x replicated by construction.
                **{_CHECK_KW: not self.use_kernel},
            )(x, b, arrays)
            new_yd.append(yb)
        f, ypair, ybox = st.f, st.ypair, st.ybox
        mask = self._mask
        if self.p.has_f:
            x2, f2, ypair = self._pair_step(x, f, ypair)
            x = jnp.where(mask, x2, x)
            f = jnp.where(mask, f2, f)
            ypair = jnp.where(mask[None], ypair, 0)
        if self.p.box is not None:
            x2, ybox = self._box_step(x, ybox)
            x = jnp.where(mask, x2, x)
            ybox = jnp.where(mask[None], ybox, 0)
        return ShardedState(x, f, new_yd, ypair, ybox, st.passes + 1)

    # ----------------------------------------------------- engine hooks
    # Dual conversions, pair/box steps, metrics, the fused multi-pass
    # ``run`` and ``run_until`` live on SolverRuntime (core/engine.py);
    # this solver customizes device placement of imported slabs and
    # shards the violation probe.
    def _put_slab(self, slab: np.ndarray):
        shard = NamedSharding(self.mesh, P(AXIS))
        return jax.device_put(jnp.asarray(slab, self.dtype), shard)

    def _triangle_violation(self, x):
        """Apex slabs dealt over the mesh, partial maxima pmax-merged —
        the probe's compute scales O(n^3 / p) like the pass itself.
        ``use_kernel`` routes the lane-blocked Pallas slab kernel per
        device (DESIGN.md §14) — this was the last loud jnp fallback on
        the sharded hot path; the jnp apex-blocked reduction stays as the
        default/oracle route. Both are bitwise-equal (max is
        association-free) and both honor ghost padding via ``n_live``."""
        xs = metrics_device.symmetrize(self._dprob.mask, x)
        if self.use_kernel:
            return metrics_device.triangle_violation_sharded_kernel(
                xs, self.mesh, AXIS,
                block_c=self.probe_block_c, n_live=self._dprob.n_real,
            )
        return metrics_device.triangle_violation_sharded(
            xs, self.mesh, AXIS, n_live=self._dprob.n_real
        )

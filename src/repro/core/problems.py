"""Metric-constrained optimization problem definitions.

All problems are instances of the ε-regularized QP (paper eq. (5))

    min  cᵀv + (ε/2) vᵀWv   s.t.  Av <= b,

where v stacks the pair distance variables ``x_ab`` (upper triangle of an n×n
matrix) and, for LP-derived problems, slack variables ``f_ab``. The constraint
families are:

  * triangle:  x_ab - x_ac - x_bc <= 0   for all triplets (the O(n^3) family,
    swept by the conflict-free parallel schedule),
  * pair (only when ``has_f``):  ±(x_ab - d_ab) - f_ab <= 0,
  * box (optional):  x_ab <= hi,  -x_ab <= -lo.

Supported instantiations:

  * ``metric_nearness_l2``: min Σ w_ab (x_ab - d_ab)^2 s.t. triangles.
    Pure QP — Dykstra solves it exactly for any ε (we fold it as
    c = -ε W d so the unconstrained optimum is X=D). Paper eq. (1), p=2.
  * ``metric_nearness_l1`` == ``correlation_clustering_lp``: the metric-
    constrained LP (paper eq. (3)) regularized per eq. (5): v=(x, f),
    c = (0, w), W = diag(w_x, w_f).
"""

from __future__ import annotations

import dataclasses

import numpy as np

__all__ = [
    "MetricQP",
    "metric_nearness_l2",
    "metric_nearness_l1",
    "correlation_clustering_lp",
]


def _upper_mask(n: int) -> np.ndarray:
    return np.triu(np.ones((n, n), dtype=bool), k=1)


@dataclasses.dataclass(frozen=True)
class MetricQP:
    """One metric-constrained regularized QP instance.

    Matrices are dense (n, n); only the strict upper triangle is meaningful.

    Attributes:
      n: number of points.
      d: (n, n) target dissimilarities (upper triangle).
      w: (n, n) positive weights for the x variables.
      eps: regularization ε (paper eq. (5)). For the pure-QP l2 problem the
        solution is independent of eps.
      has_f: whether slack variables f (and pair constraints) exist (LP mode).
      w_f: (n, n) weights for the f variables (only if has_f).
      c_x: (n, n) linear cost on x. l2 nearness: -eps*w*d. CC LP: 0.
      c_f: (n, n) linear cost on f (the LP objective weights), if has_f.
      box: optional (lo, hi) box constraints on x.
    """

    n: int
    d: np.ndarray
    w: np.ndarray
    eps: float
    has_f: bool
    c_x: np.ndarray
    w_f: np.ndarray | None = None
    c_f: np.ndarray | None = None
    box: tuple[float, float] | None = None

    # ---- initial iterate: v0 = -(1/eps) W^{-1} c (paper Alg. 1 line 3) ----
    def x0(self) -> np.ndarray:
        x = -self.c_x / (self.eps * self.w)
        return np.where(_upper_mask(self.n), x, 0.0)

    def f0(self) -> np.ndarray | None:
        if not self.has_f:
            return None
        f = -self.c_f / (self.eps * self.w_f)
        return np.where(_upper_mask(self.n), f, 0.0)

    # ---- objectives ----
    def qp_objective(self, x: np.ndarray, f: np.ndarray | None = None) -> float:
        """c'v + eps/2 v'Wv over the upper triangle."""
        m = _upper_mask(self.n)
        val = float(np.sum((self.c_x * x + 0.5 * self.eps * self.w * x * x)[m]))
        if self.has_f:
            assert f is not None
            val += float(
                np.sum((self.c_f * f + 0.5 * self.eps * self.w_f * f * f)[m])
            )
        return val

    def lp_objective(self, x: np.ndarray) -> float:
        """The underlying LP objective Σ w_ab |x_ab - d_ab| (CC / l1 nearness)."""
        m = _upper_mask(self.n)
        return float(np.sum((self.w * np.abs(x - self.d))[m]))


def metric_nearness_l2(
    d: np.ndarray, w: np.ndarray | None = None, eps: float = 1.0
) -> MetricQP:
    """l2 metric nearness: min Σ w (x-d)^2 s.t. triangle inequalities."""
    d = np.asarray(d, dtype=np.float64)
    n = d.shape[0]
    if w is None:
        w = np.ones((n, n), dtype=np.float64)
    w = np.asarray(w, dtype=np.float64)
    # min (eps/2) Σ w (x-d)^2  ⟺  c = -eps*w*d  (constant dropped).
    return MetricQP(
        n=n, d=d, w=w, eps=eps, has_f=False, c_x=-eps * w * d, box=None
    )


def metric_nearness_l1(
    d: np.ndarray,
    w: np.ndarray | None = None,
    eps: float = 0.01,
    box: tuple[float, float] | None = None,
) -> MetricQP:
    """l1 metric nearness / CC LP relaxation (paper eq. (3)), regularized.

    v = (x, f);  min Σ w f + (eps/2)(Σ w x² + Σ w f²)
    s.t. triangles on x, ±(x-d) <= f, optional box on x.

    Following [37], W = diag(w, w) and small eps approximates the LP.
    """
    d = np.asarray(d, dtype=np.float64)
    n = d.shape[0]
    if w is None:
        w = np.ones((n, n), dtype=np.float64)
    w = np.asarray(w, dtype=np.float64)
    return MetricQP(
        n=n,
        d=d,
        w=w,
        eps=eps,
        has_f=True,
        c_x=np.zeros((n, n), dtype=np.float64),
        w_f=w,
        c_f=w,
        box=box,
    )


def correlation_clustering_lp(
    dissim: np.ndarray,
    weights: np.ndarray | None = None,
    eps: float = 0.01,
) -> MetricQP:
    """CC LP relaxation: dissim[a,b] = 1 if (a,b) ∈ E⁻ else 0 (paper §II.A).

    Box [0, 1] is enforced so the rounded solution is a valid LP point.
    """
    return metric_nearness_l1(dissim, weights, eps=eps, box=(0.0, 1.0))

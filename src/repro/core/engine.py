"""Shared solver runtime: device-resident convergence engine (DESIGN.md §7).

`SolverRuntime` is the mixin both vectorized Dykstra solvers
(`ParallelSolver`, `ShardedSolver`) inherit. It owns every surface the two
previously duplicated — the pair/box constraint steps, the host metrics
report, the dense dual conversion — and adds the device-resident
convergence engine:

  * ``device_metrics(state)``  — the full (QP/LP objective, duality gap,
    max violation, optional slab-native dual stats) report as one jitted
    device program; nothing densifies, nothing loops on the host.
  * ``run_until(state, tol, max_passes, check_every)`` — a full
    solve-to-tolerance as a single jitted ``lax.while_loop``: each
    iteration runs ``check_every`` fused passes (a ``lax.scan`` over the
    subclass's ``_one_pass``) and evaluates the paper's stopping pair
    (max violation, |duality gap|) *on device*. The host is not consulted
    until the loop exits — zero host syncs per chunk, versus the one
    dispatch + one full host metrics report per chunk of the PR-2 loop.
  * ``run(state, passes)`` — the fused multi-pass runner (DESIGN.md §4/§9):
    all P passes as ONE jitted ``lax.scan`` over ``_one_pass`` with the
    periodic ``||Δx||_inf`` probe, shared verbatim by the single-device
    and the sharded solver (the scan body simply contains the subclass's
    shard_map pass when sharded). ``fused=False`` subclasses fall back to
    one jitted dispatch per pass — the benchmark baseline.

Subclass contract: provide ``p`` (MetricQP), ``n``, ``dtype``, ``layout``,
``_w``/``_d``/``_wf``/``_mask`` device constants, ``init_state()`` and
``_one_pass(state) -> state``; optionally ``fused`` / ``probe_every`` /
``_pass_fn`` (the runner knobs — defaults True / 1 / a fresh jit of
``_one_pass``), and overrides for ``_triangle_violation`` (the sharded
solver routes it through a psum-max, the kernel solver through the Pallas
apex-block kernel) and ``_put_slab`` (device placement of imported dual
slabs).

The float64 numpy path in `core/convergence.py` stays as the oracle the
engine is property-tested against (tests/test_engine.py, 1e-10).
"""

from __future__ import annotations

import dataclasses
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import metrics_device, schedule as sched

__all__ = [
    "STOP_RULES",
    "ChunkCarry",
    "SolverRuntime",
    "box_step",
    "chunk_terminal",
    "harvest_converged",
    "init_chunk_carry",
    "pair_step",
    "stop_converged",
]

#: Stopping rules for ``run_until`` (and the batched serve engine, which
#: applies the same rule per instance — DESIGN.md §8):
#:   absolute — the paper's pair: viol < tol and |gap| < tol.
#:   rel_gap  — viol < tol and |gap| <= tol * (1 + |qp objective|); the
#:              scale-free variant production workloads want when the
#:              objective magnitude varies across instances.
#:   plateau  — viol < tol and the qp objective moved less than
#:              tol * (1 + |obj|) since the previous convergence check:
#:              feasible and no longer making progress.
STOP_RULES = ("absolute", "rel_gap", "plateau")


def stop_converged(rule: str, tol, viol, gap, obj, prev_obj):
    """Elementwise convergence decision for one stop rule.

    All operands may be scalars (run_until) or (B,) arrays (the batched
    engine) — the expression is elementwise either way. ``prev_obj`` is
    the objective at the previous check (inf on the first: every rule
    then returns False, since viol is also still inf).
    """
    feas = viol < tol
    if rule == "absolute":
        return feas & (jnp.abs(gap) < tol)
    if rule == "rel_gap":
        return feas & (jnp.abs(gap) <= tol * (1.0 + jnp.abs(obj)))
    if rule == "plateau":
        return feas & (jnp.abs(obj - prev_obj) <= tol * (1.0 + jnp.abs(obj)))
    raise ValueError(f"unknown stop_rule {rule!r}; expected one of {STOP_RULES}")


# ------------------------------------------------------------------------
# Chunked-resume carry: the loop-invariant state of ONE convergence-check
# chunk, as a pytree. ``run_until`` (solo and batched) threads exactly this
# carry through its jitted ``lax.while_loop``; the continuous-batching
# serve loop (DESIGN.md §12) instead holds a live ``ChunkCarry`` across
# host round-trips and advances it one body-application at a time — the
# SAME body closure the while_loop runs, so a chunk boundary reached by
# the continuous loop is bitwise the chunk boundary drain-mode reaches.
# ------------------------------------------------------------------------
@jax.tree_util.register_dataclass
@dataclasses.dataclass
class ChunkCarry:
    """Everything a convergence chunk needs from the previous boundary.

    ``state`` is the subclass solver state (solo SolveState or serve
    BatchedState); every other leaf is per-instance — scalar in the solo
    runtime, length-B in the batched one. ``viol``/``gap``/``obj`` carry
    the previous check's stopping probe (inf before the first: the
    plateau baseline and the divergence guard's restore values),
    ``resbuf``/``k`` the chunk-boundary ``||Δx||_inf`` ring buffer and
    its per-instance write cursor, ``div`` the divergence-guard latch.
    """

    state: object
    done: jax.Array
    viol: jax.Array
    gap: jax.Array
    obj: jax.Array
    resbuf: jax.Array
    k: jax.Array
    div: jax.Array


def init_chunk_carry(state, batch: int, res_hist: int, dtype) -> ChunkCarry:
    """Fresh carry for a (B,)-instance chunk loop (B=1 collapses to the
    solo runtime's shape)."""
    inf = jnp.full((batch,), jnp.inf, dtype)
    return ChunkCarry(
        state=state,
        done=jnp.zeros((batch,), bool),
        viol=inf,
        gap=inf,
        obj=inf,
        resbuf=jnp.full((batch, res_hist), -1.0, dtype),
        k=jnp.zeros((batch,), jnp.int32),
        div=jnp.zeros((batch,), bool),
    )


def chunk_terminal(done, passes, max_passes):
    """Per-instance terminal predicate of the chunk loop — exactly the
    negation of the while_loop's live set, so a slot the continuous loop
    harvests is a slot drain-mode's loop would have exited for."""
    return done | (passes >= max_passes)


def harvest_converged(rule: str, tol, viol, gap, obj, done, div):
    """The ``converged`` vector ``run_until`` reports for a finished
    carry (host-side epilogue, numpy in / numpy out): the stop rule
    re-evaluated on the final probe OR the device-side ``done`` latch,
    never a diverged slot. Matches the batched ``run_until`` epilogue
    bit for bit so continuous-mode harvests agree with drain mode."""
    with np.errstate(invalid="ignore"):
        conv = np.asarray(
            stop_converged(
                rule, float(tol), viol, gap, obj, np.full_like(obj, np.inf)
            )
        )
    return (conv | np.asarray(done, bool)) & ~np.asarray(div, bool)


# ------------------------------------------------------------------------
# Pair/box constraint steps as pure functions. The runtime methods below
# close these over the solver's device constants; the batched serve engine
# (repro/serve/batching.py) instead vmaps them with per-instance (w, wf, d)
# operands — which is why the problem data are explicit arguments, not
# attributes.
# ------------------------------------------------------------------------
def pair_step(x, f, ypair, *, w, wf, d, eps):
    """Both pair constraints, all pairs at once (conflict-free family)."""
    iw_x, iw_f = 1.0 / w, 1.0 / wf
    denom = iw_x + iw_f
    # x - f <= d
    xv = x + ypair[0] * iw_x / eps
    fv = f - ypair[0] * iw_f / eps
    theta = eps * jnp.maximum(xv - fv - d, 0.0) / denom
    x = xv - theta * iw_x / eps
    f = fv + theta * iw_f / eps
    y0 = theta
    # -x - f <= -d
    xv = x - ypair[1] * iw_x / eps
    fv = f - ypair[1] * iw_f / eps
    theta = eps * jnp.maximum(d - xv - fv, 0.0) / denom
    x = xv + theta * iw_x / eps
    f = fv + theta * iw_f / eps
    return x, f, jnp.stack([y0, theta])


def box_step(x, ybox, *, w, lo, hi, eps):
    iw_x = 1.0 / w
    xv = x + ybox[0] * iw_x / eps
    theta_hi = eps * jnp.maximum(xv - hi, 0.0) / iw_x
    x = xv - theta_hi * iw_x / eps
    xv = x - ybox[1] * iw_x / eps
    theta_lo = eps * jnp.maximum(lo - xv, 0.0) / iw_x
    x = xv + theta_lo * iw_x / eps
    return x, jnp.stack([theta_hi, theta_lo])


class _HostView:
    """Host float64 snapshot of a solver state, in the shape
    ``convergence.report`` expects."""

    def __init__(self, st):
        asnp = lambda a: None if a is None else np.asarray(a, np.float64)
        self.x = asnp(st.x)
        self.f = asnp(st.f)
        self.ypair = asnp(st.ypair)
        self.ybox = asnp(st.ybox)
        self.passes = int(st.passes)


class SolverRuntime:
    """Runtime shared by the vectorized solvers (see module docstring)."""

    #: per-pass ``||x_{p+1} - x_p||_inf`` trajectory of the last fused
    #: ``run`` / the chunk-boundary trajectory of the last ``run_until``
    #: (-1.0 at passes the periodic probe skipped).
    last_residuals = None

    # ------------------------------------------------------ device constants
    @property
    def _n_real(self) -> int | None:
        """Live-point count when the problem is ghost-padded (DESIGN.md
        §8); None (all live) unless the subclass sets ``n_real``."""
        nr = getattr(self, "n_real", None)
        return None if nr is None or nr >= self.n else int(nr)

    @functools.cached_property
    def _dprob(self) -> metrics_device.DeviceProblem:
        return metrics_device.DeviceProblem.from_qp(
            self.p, self.dtype, n_real=self._n_real
        )

    @functools.cached_property
    def _dprob_wide(self) -> metrics_device.DeviceProblem:
        """Float64 twin of the constants for the stopping decision, when
        the process allows it (x64). With x64 off this is the compute
        dtype — the stopping pair then inherits that dtype's reduction
        noise (~1e-3 relative at f32/n≈100), so pick ``tol`` above it or
        enable x64 for tight tolerances."""
        if jax.config.jax_enable_x64 and self.dtype != jnp.float64:
            return metrics_device.DeviceProblem.from_qp(
                self.p, jnp.float64, n_real=self._n_real
            )
        return self._dprob

    @functools.cached_property
    def _slab_valid(self) -> list[jax.Array]:
        # Ghost-aware on padded problems (DESIGN.md §8): ghost sets are
        # never visited, so under fused execution their slab cells hold
        # don't-care values just like schedule padding — both are masked.
        return [
            jnp.asarray(m)
            for m in sched.slab_valid_masks(self.layout, self._n_real)
        ]

    @functools.cached_property
    def _engine_cache(self) -> dict:
        return {"report": {}, "until": {}, "probe": None}

    def _ensure_constants(self):
        """Materialize the cached device constants eagerly. Must run
        before any engine jit: a cached_property first touched *inside* a
        trace would capture (and leak) tracers instead of constants."""
        self._dprob, self._dprob_wide, self._slab_valid

    # ------------------------------------------- pair/box constraint families
    # O(n^2), conflict-free across pairs, executed replicated — identical in
    # both solvers. The math lives in the module-level pure functions
    # (vmap-safe; the batched serve engine calls them with per-instance
    # operands); these methods just close them over the device constants.
    def _pair_step(self, x, f, ypair):
        return pair_step(
            x, f, ypair, w=self._w, wf=self._wf, d=self._d,
            eps=float(self.p.eps),
        )

    def _box_step(self, x, ybox):
        lo, hi = self.p.box
        return box_step(
            x, ybox, w=self._w, lo=lo, hi=hi, eps=float(self.p.eps)
        )

    # --------------------------------------------------- dual conversions
    # Dense (n, n, n) is the *interchange* format only (DESIGN.md §2):
    # these are host-side diagnostics/test boundaries, never on any solve
    # or metrics hot path.
    def duals_to_dense(self, st) -> np.ndarray:
        """Schedule-native duals → dense ``ytri[a, b, c]`` (DESIGN.md §2).
        Diagnostics/tests only — the engine never calls this."""
        return sched.duals_to_dense(self.layout, st.yd)

    def _put_slab(self, slab: np.ndarray):
        """Device placement of one imported dual slab (subclass hook)."""
        return jnp.asarray(slab, self.dtype)

    def dense_to_duals(self, ytri: np.ndarray) -> list[jax.Array]:
        """Dense ``ytri`` → state slabs (e.g. to resume from the oracle)."""
        slabs = sched.dense_to_duals(self.layout, ytri, np.float64)
        return [self._put_slab(s.reshape(self._slab_state_shape(s))) for s in slabs]

    def _slab_state_shape(self, slab: np.ndarray) -> tuple[int, ...]:
        """Shape a converted slab takes inside the state pytree (the
        single-device solver drops the unit procs axis)."""
        return slab.shape

    # ----------------------------------------------------- device metrics
    def _triangle_violation(self, x):
        """Triangle-family max violation on device (subclasses override:
        psum-max when sharded, Pallas kernel when use_kernel).
        ``n_live`` masks ghost-apex triangles on padded problems — ghost
        x cells are 0, so an unmasked ghost apex would report the false
        slack x_ab - 0 - 0."""
        return metrics_device.triangle_violation(
            metrics_device.symmetrize(self._dprob.mask, x),
            n_live=self._dprob.n_real,
        )

    def _stopping_pair(self, st):
        """The paper's stopping pair (max violation, duality gap), traced
        on device — the while_loop probe and the metrics report share it.
        Reduced in float64 whenever x64 is enabled (the host loop's
        decision precision); see ``_dprob_wide`` for the f32 caveat."""
        dp = self._dprob_wide
        wd = dp.w.dtype
        up = lambda a: None if a is None else a.astype(wd)
        x, f = up(st.x), up(st.f)
        viol = metrics_device.max_violation(
            dp, x, f, tri=self._triangle_violation(x)
        )
        gap = metrics_device.duality_gap(dp, x, f, up(st.ypair), up(st.ybox))
        return viol, gap

    def _device_report(self, st, include_duals: bool):
        dp = self._dprob
        viol, gap = self._stopping_pair(st)
        out = {
            "passes": st.passes,
            "qp_objective": metrics_device.qp_objective(dp, st.x, st.f),
            "lp_objective": metrics_device.lp_objective(dp, st.x),
            "duality_gap": gap,
            "max_violation": viol,
        }
        if include_duals:
            out.update(
                metrics_device.triangle_dual_stats(st.yd, self._slab_valid)
            )
        return out

    def device_metrics(self, st, include_duals: bool = False) -> dict:
        """Full metrics bundle computed on device (one jitted program, one
        host sync). Same keys/semantics as the host ``metrics``; dual
        stats are reduced slab-native when requested — on ghost-padded
        problems under the ghost-aware valid masks, so they cover exactly
        the real (< n_real) triangle duals."""
        self._ensure_constants()
        cache = self._engine_cache["report"]
        key = bool(include_duals)
        fn = cache.get(key)
        if fn is None:
            fn = cache[key] = jax.jit(
                functools.partial(self._device_report, include_duals=key)
            )
        out = jax.device_get(fn(st))
        ints = ("passes", "active_constraints")
        return {k: (int(v) if k in ints else float(v)) for k, v in out.items()}

    def metrics(self, st, include_duals: bool = False) -> dict:
        """Host float64 oracle report (core/convergence.py). The device
        engine (``device_metrics``) is property-tested against this."""
        if self._n_real is not None:
            raise NotImplementedError(
                "the host oracle has no ghost-padding support; use "
                "device_metrics on padded solvers (DESIGN.md §8)"
            )
        from repro.core import convergence

        ytri = self.duals_to_dense(st) if include_duals else None
        return convergence.report(self.p, _HostView(st), ytri=ytri)

    def _wide_objective(self, st):
        """QP objective in the stopping-decision dtype (rel_gap/plateau
        operand; also the plateau rule's progress signal)."""
        dp = self._dprob_wide
        wd = dp.w.dtype
        up = lambda a: None if a is None else a.astype(wd)
        return metrics_device.qp_objective(dp, up(st.x), up(st.f))

    # ------------------------------------------------------ solve runtime
    def _multi_pass_fn(self, passes: int):
        """Jitted P-pass runner: a single ``lax.scan`` over passes (the
        subclass ``_one_pass``, pair/box steps included) — one dispatch
        and one host sync for the whole run. Emits the per-pass residual
        ``||x_{p+1} - x_p||_inf`` wherever the periodic probe fires
        (every ``probe_every`` passes; -1 elsewhere), the cheap
        convergence signal callers poll without leaving the device
        program. Shared by the single-device and sharded solvers
        (DESIGN.md §4/§9); cached per pass count."""
        cache = self._engine_cache.setdefault("runner", {})
        fn = cache.get(passes)
        if fn is None:
            probe = max(1, int(getattr(self, "probe_every", 1)))

            def multi(st):
                def body(carry, p):
                    st2 = self._one_pass(carry)
                    dt = st2.x.dtype
                    if probe == 1:
                        res = jnp.max(jnp.abs(st2.x - carry.x)).astype(dt)
                    else:
                        # lax.cond so skipped passes pay nothing for the
                        # O(n^2) reduction, not just discard its value.
                        res = jax.lax.cond(
                            (p + 1) % probe == 0,
                            lambda a, b: jnp.max(jnp.abs(a - b)).astype(dt),
                            lambda a, b: jnp.asarray(-1.0, dt),
                            st2.x, carry.x,
                        )
                    return st2, res

                return jax.lax.scan(
                    body, st, jnp.arange(passes, dtype=jnp.int32)
                )

            fn = cache[passes] = jax.jit(multi)
        return fn

    def run(self, state=None, passes: int = 1):
        """Run ``passes`` passes. With ``fused`` (the default) all P
        passes execute as one compiled program via ``_multi_pass_fn`` and
        the probe trajectory lands on ``last_residuals``; ``fused=False``
        host-loops one jitted dispatch per pass (benchmark baseline).
        Contract (pinned by tests): the P-pass scan produces bit-identical
        state to P single-pass runs; ``run(st, 0)`` is the identity."""
        st = state if state is not None else self.init_state()
        if passes <= 0:
            return st
        if not getattr(self, "fused", True):
            for _ in range(passes):
                st = self._pass_fn(st)
            return st
        st, self.last_residuals = self._multi_pass_fn(passes)(st)
        return st

    def _until_fn(self, check_every: int, stop_rule: str, res_hist: int):
        self._ensure_constants()
        cache = self._engine_cache["until"]
        key = (check_every, stop_rule, res_hist)
        fn = cache.get(key)
        if fn is None:

            def runner(st, tol, max_passes):
                # carry the stopping pair in its own (wide) dtype so the
                # on-device decision keeps the probe's full precision
                dt = self._dprob_wide.w.dtype

                def guarded(s):
                    # Per-pass cumulative cap: the final chunk runs only
                    # its real remainder (host k = min(chunk, remaining)
                    # semantics) with ONE compiled program per
                    # check_every — no specialized remainder runner.
                    return jax.lax.cond(
                        s.passes < max_passes, self._one_pass, lambda q: q, s
                    )

                def chunk(s):
                    s2, _ = jax.lax.scan(
                        lambda c, _: (guarded(c), None),
                        s, None, length=check_every,
                    )
                    return s2

                def cond(carry):
                    s, viol, gap, obj, prev_obj, _, _, div = carry
                    conv = stop_converged(stop_rule, tol, viol, gap, obj,
                                          prev_obj)
                    return (~div) & (~conv) & (s.passes < max_passes)

                def body(carry):
                    s, viol_p, gap_p, obj_prev, _, resbuf, k, div = carry
                    s2 = chunk(s)
                    viol, gap = self._stopping_pair(s2)
                    obj = self._wide_objective(s2)
                    res = jnp.max(jnp.abs(s2.x - s.x)).astype(dt)
                    # Divergence guard: isfinite of the residual probe is
                    # folded into the stopping decision — a NaN/Inf chunk
                    # flips ``div`` (the loop exits), restores the last
                    # finite chunk boundary, and keeps that boundary's
                    # stopping pair. Same device program, zero extra host
                    # syncs — versus scanning NaNs for the remaining
                    # max_passes and reporting garbage.
                    finite = (
                        jnp.isfinite(res)
                        & jnp.isfinite(viol)
                        & jnp.isfinite(gap)
                    )
                    sel = lambda a, b: jnp.where(finite, a, b)
                    s2 = jax.tree.map(sel, s2, s)
                    viol = sel(viol.astype(dt), viol_p)
                    gap = sel(gap.astype(dt), gap_p)
                    obj = sel(obj.astype(dt), obj_prev)
                    # ring buffer of the periodic ||Δx||_inf probe, one
                    # entry per executed chunk (ROADMAP: the fused
                    # runner's residual trajectory, threaded through the
                    # while_loop); a diverged chunk records inf.
                    resbuf = jax.lax.dynamic_update_index_in_dim(
                        resbuf, sel(res, jnp.asarray(jnp.inf, dt)),
                        k % res_hist, 0,
                    )
                    return (s2, viol, gap, obj, obj_prev, resbuf, k + 1,
                            div | ~finite)

                inf = jnp.asarray(jnp.inf, dt)
                resbuf0 = jnp.full((res_hist,), -1.0, dt)
                k0 = jnp.zeros((), jnp.int32)
                div0 = jnp.zeros((), bool)
                return jax.lax.while_loop(
                    cond, body, (st, inf, inf, inf, inf, resbuf0, k0, div0)
                )

            fn = cache[key] = jax.jit(runner)
        return fn

    def _probe_fn(self):
        self._ensure_constants()
        fn = self._engine_cache["probe"]
        if fn is None:
            fn = self._engine_cache["probe"] = jax.jit(self._stopping_pair)
        return fn

    def _objectives_fn(self):
        """Cached jit of the O(n^2) objectives alone — run_until reports
        them in info without re-running the O(n^3) violation reduction."""
        self._ensure_constants()
        fn = self._engine_cache.get("objectives")
        if fn is None:
            dp = self._dprob

            def obj(st):
                return (
                    metrics_device.qp_objective(dp, st.x, st.f),
                    metrics_device.lp_objective(dp, st.x),
                )

            fn = self._engine_cache["objectives"] = jax.jit(obj)
        return fn

    def _apply_entry_faults(self, faults, st):
        """Poll the ``chunk`` fault site once per ``run_until`` call (the
        host-visible chunk/window boundary). ``nan_poison`` poisons the
        live iterate — the on-device divergence guard must then stop the
        loop; ``straggler`` sleeps a deterministic beat. Duck-typed: any
        object with ``poll(site)`` works (serve.faults.FaultInjector)."""
        for spec in faults.poll("chunk"):
            if spec.kind == "nan_poison":
                st = dataclasses.replace(st, x=st.x * jnp.nan)
            elif spec.kind == "straggler":
                time.sleep(float(spec.payload.get("seconds", 0.001)))
        return st

    def run_until(
        self,
        state=None,
        *,
        tol: float = 1e-4,
        max_passes: int = 100,
        check_every: int = 10,
        stop_rule: str = "absolute",
        residual_history: int = 16,
        faults=None,
    ):
        """Solve to tolerance: run passes in chunks of ``check_every``
        until the ``stop_rule`` fires or the *cumulative* pass counter
        reaches ``max_passes``. Rules (module ``STOP_RULES``): the
        default ``absolute`` is the paper's pair (viol, |gap|) < tol;
        ``rel_gap`` scales the gap test by the objective magnitude;
        ``plateau`` stops when feasible and the objective stalls between
        checks. Every rule evaluates on device inside the loop.

        The whole chunk loop is one jitted ``lax.while_loop`` with an
        on-device stopping test — a solve is a single device program with
        zero host syncs per chunk (the PR-2 launcher paid one dispatch
        plus a full host-numpy metrics report per chunk). ``max_passes``
        is cumulative so resumed states (checkpoints) compose; inside the
        chunk scan every pass is guarded by the cumulative cap, so a
        final partial chunk runs exactly ``max_passes - passes`` real
        passes — the host loop's ``k = min(chunk, remaining)`` schedule
        pass-for-pass, without compiling a remainder-specialized runner.

        Returns ``(state, info)`` with info keys ``passes`` (cumulative),
        ``converged``, ``diverged``, ``max_violation``, ``duality_gap``,
        ``qp_objective``, ``lp_objective``, ``stop_rule`` and
        ``residuals`` — the chunk-boundary ``||Δx||_inf`` trajectory (the
        most recent ``residual_history`` chunks, oldest first), carried
        through the while_loop as a ring buffer and mirrored to
        ``self.last_residuals``. The stopping pair comes from the loop's
        own final probe and the objectives from one extra O(n^2) program,
        so callers never need a second full metrics pass.

        A non-finite residual probe (NaN poison, numerical blow-up) trips
        the on-device divergence guard: the loop exits at the first bad
        chunk with ``info["diverged"] = True`` and the state restored to
        the last finite chunk boundary, instead of scanning NaNs until
        ``max_passes``. ``faults`` (optional, duck-typed
        ``serve.faults.FaultInjector``) is polled once at entry — the
        ``chunk`` injection site (DESIGN.md §11).
        """
        st = state if state is not None else self.init_state()
        if faults is not None:
            st = self._apply_entry_faults(faults, st)
        check_every = max(1, int(check_every))
        residual_history = max(1, int(residual_history))
        if stop_rule not in STOP_RULES:
            raise ValueError(
                f"unknown stop_rule {stop_rule!r}; expected one of {STOP_RULES}"
            )
        max_passes = int(max_passes)
        tol = float(tol)

        def host(pair):
            v, g = jax.device_get(pair)
            return float(v), float(g)

        fn = self._until_fn(check_every, stop_rule, residual_history)
        st, viol, gap, obj, prev_obj, resbuf, k, div = fn(st, tol, max_passes)
        viol, gap = host((viol, gap))
        obj, prev_obj = host((obj, prev_obj))
        k = int(k)
        diverged = bool(jax.device_get(div))
        resbuf = np.asarray(jax.device_get(resbuf), np.float64)
        residuals = (
            resbuf[:k] if k <= residual_history
            else np.roll(resbuf, -(k % residual_history))
        )
        self.last_residuals = residuals
        qp, lp = (float(v) for v in jax.device_get(self._objectives_fn()(st)))
        if not np.isfinite(viol):
            # no chunk ran (state already at/over max_passes), or the
            # guard tripped on the very first chunk: probe the returned
            # state once so the caller still gets a real stopping pair.
            viol, gap = host(self._probe_fn()(st))
            obj = qp
        converged = not diverged and bool(
            stop_converged(stop_rule, tol, viol, gap, obj, prev_obj)
        )
        info = {
            "passes": int(st.passes),
            "converged": converged,
            "diverged": diverged,
            "max_violation": viol,
            "duality_gap": gap,
            "qp_objective": qp,
            "lp_objective": lp,
            "stop_rule": stop_rule,
            "residuals": residuals,
        }
        return st, info

"""Shared solver runtime: device-resident convergence engine (DESIGN.md §7).

`SolverRuntime` is the mixin both vectorized Dykstra solvers
(`ParallelSolver`, `ShardedSolver`) inherit. It owns every surface the two
previously duplicated — the pair/box constraint steps, the host metrics
report, the dense dual conversion — and adds the device-resident
convergence engine:

  * ``device_metrics(state)``  — the full (QP/LP objective, duality gap,
    max violation, optional slab-native dual stats) report as one jitted
    device program; nothing densifies, nothing loops on the host.
  * ``run_until(state, tol, max_passes, check_every)`` — a full
    solve-to-tolerance as a single jitted ``lax.while_loop``: each
    iteration runs ``check_every`` fused passes (a ``lax.scan`` over the
    subclass's ``_one_pass``) and evaluates the paper's stopping pair
    (max violation, |duality gap|) *on device*. The host is not consulted
    until the loop exits — zero host syncs per chunk, versus the one
    dispatch + one full host metrics report per chunk of the PR-2 loop.

Subclass contract: provide ``p`` (MetricQP), ``n``, ``dtype``, ``layout``,
``_w``/``_d``/``_wf``/``_mask`` device constants, ``init_state()`` and
``_one_pass(state) -> state``; optionally override ``_triangle_violation``
(the sharded solver routes it through a psum-max, the kernel solver
through the Pallas apex-block kernel) and ``_put_slab`` (device placement
of imported dual slabs).

The float64 numpy path in `core/convergence.py` stays as the oracle the
engine is property-tested against (tests/test_engine.py, 1e-10).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import metrics_device, schedule as sched

__all__ = ["SolverRuntime"]


class _HostView:
    """Host float64 snapshot of a solver state, in the shape
    ``convergence.report`` expects."""

    def __init__(self, st):
        asnp = lambda a: None if a is None else np.asarray(a, np.float64)
        self.x = asnp(st.x)
        self.f = asnp(st.f)
        self.ypair = asnp(st.ypair)
        self.ybox = asnp(st.ybox)
        self.passes = int(st.passes)


class SolverRuntime:
    """Runtime shared by the vectorized solvers (see module docstring)."""

    # ------------------------------------------------------ device constants
    @functools.cached_property
    def _dprob(self) -> metrics_device.DeviceProblem:
        return metrics_device.DeviceProblem.from_qp(self.p, self.dtype)

    @functools.cached_property
    def _dprob_wide(self) -> metrics_device.DeviceProblem:
        """Float64 twin of the constants for the stopping decision, when
        the process allows it (x64). With x64 off this is the compute
        dtype — the stopping pair then inherits that dtype's reduction
        noise (~1e-3 relative at f32/n≈100), so pick ``tol`` above it or
        enable x64 for tight tolerances."""
        if jax.config.jax_enable_x64 and self.dtype != jnp.float64:
            return metrics_device.DeviceProblem.from_qp(self.p, jnp.float64)
        return self._dprob

    @functools.cached_property
    def _slab_valid(self) -> list[jax.Array]:
        return [jnp.asarray(m) for m in sched.slab_valid_masks(self.layout)]

    @functools.cached_property
    def _engine_cache(self) -> dict:
        return {"report": {}, "until": {}, "probe": None}

    def _ensure_constants(self):
        """Materialize the cached device constants eagerly. Must run
        before any engine jit: a cached_property first touched *inside* a
        trace would capture (and leak) tracers instead of constants."""
        self._dprob, self._dprob_wide, self._slab_valid

    # ------------------------------------------- pair/box constraint families
    # O(n^2), conflict-free across pairs, executed replicated — identical in
    # both solvers, so the math lives here once.
    def _pair_step(self, x, f, ypair):
        """Both pair constraints, all pairs at once (conflict-free family)."""
        eps = float(self.p.eps)
        w, wf, d = self._w, self._wf, self._d
        iw_x, iw_f = 1.0 / w, 1.0 / wf
        denom = iw_x + iw_f
        # x - f <= d
        xv = x + ypair[0] * iw_x / eps
        fv = f - ypair[0] * iw_f / eps
        theta = eps * jnp.maximum(xv - fv - d, 0.0) / denom
        x = xv - theta * iw_x / eps
        f = fv + theta * iw_f / eps
        y0 = theta
        # -x - f <= -d
        xv = x - ypair[1] * iw_x / eps
        fv = f - ypair[1] * iw_f / eps
        theta = eps * jnp.maximum(d - xv - fv, 0.0) / denom
        x = xv + theta * iw_x / eps
        f = fv + theta * iw_f / eps
        return x, f, jnp.stack([y0, theta])

    def _box_step(self, x, ybox):
        eps = float(self.p.eps)
        lo, hi = self.p.box
        iw_x = 1.0 / self._w
        xv = x + ybox[0] * iw_x / eps
        theta_hi = eps * jnp.maximum(xv - hi, 0.0) / iw_x
        x = xv - theta_hi * iw_x / eps
        xv = x - ybox[1] * iw_x / eps
        theta_lo = eps * jnp.maximum(lo - xv, 0.0) / iw_x
        x = xv + theta_lo * iw_x / eps
        return x, jnp.stack([theta_hi, theta_lo])

    # --------------------------------------------------- dual conversions
    # Dense (n, n, n) is the *interchange* format only (DESIGN.md §2):
    # these are host-side diagnostics/test boundaries, never on any solve
    # or metrics hot path.
    def duals_to_dense(self, st) -> np.ndarray:
        """Schedule-native duals → dense ``ytri[a, b, c]`` (DESIGN.md §2).
        Diagnostics/tests only — the engine never calls this."""
        return sched.duals_to_dense(self.layout, st.yd)

    def _put_slab(self, slab: np.ndarray):
        """Device placement of one imported dual slab (subclass hook)."""
        return jnp.asarray(slab, self.dtype)

    def dense_to_duals(self, ytri: np.ndarray) -> list[jax.Array]:
        """Dense ``ytri`` → state slabs (e.g. to resume from the oracle)."""
        slabs = sched.dense_to_duals(self.layout, ytri, np.float64)
        return [self._put_slab(s.reshape(self._slab_state_shape(s))) for s in slabs]

    def _slab_state_shape(self, slab: np.ndarray) -> tuple[int, ...]:
        """Shape a converted slab takes inside the state pytree (the
        single-device solver drops the unit procs axis)."""
        return slab.shape

    # ----------------------------------------------------- device metrics
    def _triangle_violation(self, x):
        """Triangle-family max violation on device (subclasses override:
        psum-max when sharded, Pallas kernel when use_kernel)."""
        return metrics_device.triangle_violation(
            metrics_device.symmetrize(self._dprob.mask, x)
        )

    def _stopping_pair(self, st):
        """The paper's stopping pair (max violation, duality gap), traced
        on device — the while_loop probe and the metrics report share it.
        Reduced in float64 whenever x64 is enabled (the host loop's
        decision precision); see ``_dprob_wide`` for the f32 caveat."""
        dp = self._dprob_wide
        wd = dp.w.dtype
        up = lambda a: None if a is None else a.astype(wd)
        x, f = up(st.x), up(st.f)
        viol = metrics_device.max_violation(
            dp, x, f, tri=self._triangle_violation(x)
        )
        gap = metrics_device.duality_gap(dp, x, f, up(st.ypair), up(st.ybox))
        return viol, gap

    def _device_report(self, st, include_duals: bool):
        dp = self._dprob
        viol, gap = self._stopping_pair(st)
        out = {
            "passes": st.passes,
            "qp_objective": metrics_device.qp_objective(dp, st.x, st.f),
            "lp_objective": metrics_device.lp_objective(dp, st.x),
            "duality_gap": gap,
            "max_violation": viol,
        }
        if include_duals:
            out.update(
                metrics_device.triangle_dual_stats(st.yd, self._slab_valid)
            )
        return out

    def device_metrics(self, st, include_duals: bool = False) -> dict:
        """Full metrics bundle computed on device (one jitted program, one
        host sync). Same keys/semantics as the host ``metrics``; dual
        stats are reduced slab-native when requested."""
        self._ensure_constants()
        cache = self._engine_cache["report"]
        key = bool(include_duals)
        fn = cache.get(key)
        if fn is None:
            fn = cache[key] = jax.jit(
                functools.partial(self._device_report, include_duals=key)
            )
        out = jax.device_get(fn(st))
        ints = ("passes", "active_constraints")
        return {k: (int(v) if k in ints else float(v)) for k, v in out.items()}

    def metrics(self, st, include_duals: bool = False) -> dict:
        """Host float64 oracle report (core/convergence.py). The device
        engine (``device_metrics``) is property-tested against this."""
        from repro.core import convergence

        ytri = self.duals_to_dense(st) if include_duals else None
        return convergence.report(self.p, _HostView(st), ytri=ytri)

    # ------------------------------------------------------ solve runtime
    def _until_fn(self, check_every: int):
        self._ensure_constants()
        cache = self._engine_cache["until"]
        fn = cache.get(check_every)
        if fn is None:

            def runner(st, tol, max_passes):
                # carry the stopping pair in its own (wide) dtype so the
                # on-device decision keeps the probe's full precision
                dt = self._dprob_wide.w.dtype

                def guarded(s):
                    # Per-pass cumulative cap: the final chunk runs only
                    # its real remainder (host k = min(chunk, remaining)
                    # semantics) with ONE compiled program per
                    # check_every — no specialized remainder runner.
                    return jax.lax.cond(
                        s.passes < max_passes, self._one_pass, lambda q: q, s
                    )

                def chunk(s):
                    s2, _ = jax.lax.scan(
                        lambda c, _: (guarded(c), None),
                        s, None, length=check_every,
                    )
                    return s2

                def cond(carry):
                    s, viol, gap = carry
                    conv = (viol < tol) & (jnp.abs(gap) < tol)
                    return (~conv) & (s.passes < max_passes)

                def body(carry):
                    s, _, _ = carry
                    s = chunk(s)
                    viol, gap = self._stopping_pair(s)
                    return (s, viol.astype(dt), gap.astype(dt))

                inf = jnp.asarray(jnp.inf, dt)
                return jax.lax.while_loop(cond, body, (st, inf, inf))

            fn = cache[check_every] = jax.jit(runner)
        return fn

    def _probe_fn(self):
        self._ensure_constants()
        fn = self._engine_cache["probe"]
        if fn is None:
            fn = self._engine_cache["probe"] = jax.jit(self._stopping_pair)
        return fn

    def _objectives_fn(self):
        """Cached jit of the O(n^2) objectives alone — run_until reports
        them in info without re-running the O(n^3) violation reduction."""
        self._ensure_constants()
        fn = self._engine_cache.get("objectives")
        if fn is None:
            dp = self._dprob

            def obj(st):
                return (
                    metrics_device.qp_objective(dp, st.x, st.f),
                    metrics_device.lp_objective(dp, st.x),
                )

            fn = self._engine_cache["objectives"] = jax.jit(obj)
        return fn

    def run_until(
        self,
        state=None,
        *,
        tol: float = 1e-4,
        max_passes: int = 100,
        check_every: int = 10,
    ):
        """Solve to tolerance: run passes in chunks of ``check_every``
        until the stopping pair (max violation, |duality gap|) is below
        ``tol`` or the *cumulative* pass counter reaches ``max_passes``.

        The whole chunk loop is one jitted ``lax.while_loop`` with an
        on-device stopping test — a solve is a single device program with
        zero host syncs per chunk (the PR-2 launcher paid one dispatch
        plus a full host-numpy metrics report per chunk). ``max_passes``
        is cumulative so resumed states (checkpoints) compose; inside the
        chunk scan every pass is guarded by the cumulative cap, so a
        final partial chunk runs exactly ``max_passes - passes`` real
        passes — the host loop's ``k = min(chunk, remaining)`` schedule
        pass-for-pass, without compiling a remainder-specialized runner.

        Returns ``(state, info)`` with info keys ``passes`` (cumulative),
        ``converged``, ``max_violation``, ``duality_gap``,
        ``qp_objective``, ``lp_objective`` — the stopping pair comes from
        the loop's own final probe and the objectives from one extra
        O(n^2) program, so callers never need a second full metrics pass.
        """
        st = state if state is not None else self.init_state()
        check_every = max(1, int(check_every))
        max_passes = int(max_passes)
        tol = float(tol)

        def host(pair):
            v, g = jax.device_get(pair)
            return float(v), float(g)

        st, viol, gap = self._until_fn(check_every)(st, tol, max_passes)
        viol, gap = host((viol, gap))
        converged = viol < tol and abs(gap) < tol
        if not np.isfinite(viol):
            # no chunk ran (state already at/over max_passes): probe once
            # so the caller still gets a real stopping pair.
            viol, gap = host(self._probe_fn()(st))
            converged = viol < tol and abs(gap) < tol
        qp, lp = jax.device_get(self._objectives_fn()(st))
        info = {
            "passes": int(st.passes),
            "converged": bool(converged),
            "max_violation": viol,
            "duality_gap": gap,
            "qp_objective": float(qp),
            "lp_objective": float(lp),
        }
        return st, info

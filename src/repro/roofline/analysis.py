"""Three-term roofline analysis per compiled dry-run cell.

    compute    = FLOPs_per_device   / peak_FLOP/s
    memory     = HBM_bytes_per_device / HBM_bw
    collective = coll_bytes_per_device / link_bw

Sources:
  * collective bytes: optimized HLO text of the compiled artifact, with
    while-loop trip-count correction (roofline/hlo_parse.py) — XLA emits the
    per-device SPMD program, so these are per-device numbers;
  * FLOPs / HBM bytes: analytic accounting bound to the same shapes the
    compiled program binds (roofline/accounting.py) — XLA:CPU
    ``cost_analysis()`` counts loop bodies once and is reported raw alongside
    for transparency;
  * MODEL_FLOPS = 6·N·D (train) / 2·N_active·D (+cache attention, decode).

Hardware: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""

from __future__ import annotations

import dataclasses

from repro.roofline import hlo_parse

__all__ = ["RooflineReport", "analyze", "PEAK_FLOPS", "HBM_BW", "LINK_BW"]

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # bytes/s / chip
LINK_BW = 50e9  # bytes/s / link


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_device: float          # analytic
    bytes_per_device: float          # analytic HBM traffic
    coll_bytes_per_device: float     # HLO-parsed, trip-corrected
    coll_breakdown: dict
    model_flops: float               # useful flops, global
    raw_cost_analysis: dict
    peak_memory_per_device: float | None
    accounting: dict

    @property
    def t_compute(self) -> float:
        return self.flops_per_device / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.bytes_per_device / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes_per_device / LINK_BW

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(terms, key=terms.get)

    @property
    def useful_flops_fraction(self) -> float:
        total = self.flops_per_device * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def roofline_fraction(self) -> float:
        """(model_flops/chips/peak) / max(term): how close the *useful* work
        runs to the binding roofline — the headline §Perf number."""
        t_useful = self.model_flops / self.chips / PEAK_FLOPS
        bound = max(self.t_compute, self.t_memory, self.t_collective)
        return t_useful / bound if bound else 0.0

    def to_dict(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "coll_bytes_per_device": self.coll_bytes_per_device,
            "coll_breakdown": self.coll_breakdown,
            "model_flops": self.model_flops,
            "raw_cost_analysis": self.raw_cost_analysis,
            "peak_memory_per_device": self.peak_memory_per_device,
            "accounting": self.accounting,
            "t_compute": self.t_compute,
            "t_memory": self.t_memory,
            "t_collective": self.t_collective,
            "bottleneck": self.bottleneck,
            "useful_flops_fraction": self.useful_flops_fraction,
            "roofline_fraction": self.roofline_fraction,
        }


def analyze(
    arch: str,
    shape: str,
    mesh_name: str,
    chips: int,
    compiled,
    hlo_text: str,
    accounting: dict,
) -> RooflineReport:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    raw = {
        "flops": float(cost.get("flops", 0.0)),
        "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
        "note": "XLA:CPU counts while bodies once; see accounting",
    }
    coll = hlo_parse.collective_bytes(hlo_text)
    try:
        mem = compiled.memory_analysis()
        peak = getattr(mem, "temp_size_in_bytes", None)
        if peak is not None:
            peak = float(peak) + float(getattr(mem, "argument_size_in_bytes", 0) or 0)
    except Exception:
        peak = None
    return RooflineReport(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        flops_per_device=accounting["analytic_flops_per_device"],
        bytes_per_device=accounting["analytic_hbm_bytes_per_device"],
        coll_bytes_per_device=float(coll["total"]),
        coll_breakdown=coll,
        model_flops=accounting["model_flops"],
        raw_cost_analysis=raw,
        peak_memory_per_device=peak,
        accounting=accounting,
    )

"""Optimized-HLO text parsing: per-computation collective bytes with
while-loop trip-count multipliers.

XLA's ``cost_analysis()`` (and a naive text scan) counts a while-loop body
ONCE, but a scanned-layers transformer executes it n_layers times. We
recover true collective traffic by:
  1. splitting the module into computations,
  2. extracting every ``while`` op's (condition, body) computation names,
  3. reading the trip count from the loop bound constant in the condition,
  4. propagating multipliers through the call graph (nested loops multiply),
  5. summing collective result bytes × multiplier.
"""

from __future__ import annotations

import re

__all__ = ["collective_bytes", "parse_computations", "while_trips"]

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

COLL_KINDS = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "ragged-all-to-all",
)

_SHAPE_RE = re.compile(r"\b([a-z]+[0-9]*(?:e[0-9a-z]+)?)\[([0-9,]*)\]")
_HEADER_RE = re.compile(r"^(?:ENTRY\s+)?%([\w.\-]+)\s*\(.*\)\s*->")
# note: shape tuples contain /*index=N*/ comments, so match loosely on the
# attribute list rather than anchoring at '='
_WHILE_RE = re.compile(
    r" while\(.*condition=%?([\w.\-]+), body=%?([\w.\-]+)",
)
_CALL_RE = re.compile(r"(?:calls|to_apply|condition|body)=%?([\w.\-]+)")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_CONST_RE = re.compile(r"s32\[\]\s+constant\((\d+)\)")


def _shape_bytes_all(text: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(text):
        dt, dims = m.groups()
        nb = _DTYPE_BYTES.get(dt)
        if nb is None:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * nb
    return total


def parse_computations(txt: str) -> tuple[dict[str, list[str]], str]:
    """Returns ({computation_name: [instruction lines]}, entry_name)."""
    comps: dict[str, list[str]] = {}
    entry = None
    cur = None
    for line in txt.splitlines():
        if not line.strip():
            cur = None
            continue
        m = _HEADER_RE.match(line.strip())
        if m and line.rstrip().endswith("{"):
            cur = m.group(1)
            comps[cur] = []
            if line.lstrip().startswith("ENTRY"):
                entry = cur
            continue
        if line.startswith("}"):
            cur = None
            continue
        if cur is not None:
            comps[cur].append(line.strip())
    return comps, entry


def while_trips(comps: dict[str, list[str]]) -> list[tuple[str, str, str, int]]:
    """Every while op: (parent_comp, cond_comp, body_comp, trip_count)."""
    out = []
    for name, lines in comps.items():
        for line in lines:
            if " while(" not in line:
                continue
            m = _WHILE_RE.search(line)
            if not m:
                continue
            cond, body = m.groups()
            consts = [int(c) for c in _CONST_RE.findall("\n".join(comps.get(cond, [])))]
            trip = max(consts) if consts else 1
            out.append((name, cond, body, trip))
    return out


def _multipliers(comps, entry) -> dict[str, float]:
    whiles = while_trips(comps)
    body_of = {}
    for parent, cond, body, trip in whiles:
        body_of.setdefault(parent, []).append((cond, body, trip))
    mult = {entry: 1.0}
    work = [entry]
    seen = set()
    while work:
        cur = work.pop()
        if cur in seen or cur not in comps:
            continue
        seen.add(cur)
        m = mult.get(cur, 1.0)
        # while bodies get trip multiplier
        for cond, body, trip in body_of.get(cur, []):
            for target, factor in ((cond, 1.0), (body, float(trip))):
                mult[target] = max(mult.get(target, 0.0), m * factor)
                work.append(target)
        # other calls inherit the parent multiplier
        for line in comps[cur]:
            if " while(" in line:
                continue
            for callee in _CALL_RE.findall(line):
                mult[callee] = max(mult.get(callee, 0.0), m)
                work.append(callee)
            b = _BRANCH_RE.search(line)
            if b:
                for callee in re.findall(r"%?([\w.\-]+)", b.group(1)):
                    mult[callee] = max(mult.get(callee, 0.0), m)
                    work.append(callee)
    return mult


def collective_bytes(txt: str) -> dict[str, float]:
    """Trip-count-aware collective byte totals by kind (+ 'total')."""
    comps, entry = parse_computations(txt)
    if entry is None:
        return {k: 0.0 for k in COLL_KINDS} | {"total": 0.0}
    mult = _multipliers(comps, entry)
    out = {k: 0.0 for k in COLL_KINDS}
    for name, lines in comps.items():
        m = mult.get(name, 1.0)
        for line in lines:
            # result-side op only; skip async -done halves (count -start)
            mm = re.match(r"(?:ROOT\s+)?%[\w.\-]+\s*=\s*(.*)$", line)
            if not mm:
                continue
            rhs = mm.group(1)
            opm = re.match(r"((?:\([^)]*\))|(?:[\w\[\]{},]+))\s+([\w\-]+)\(", rhs)
            if not opm:
                continue
            shape_str, op = opm.groups()
            base = op[:-6] if op.endswith("-start") else op
            if op.endswith("-done") or base not in COLL_KINDS:
                continue
            out[base] += m * _shape_bytes_all(shape_str)
    out["total"] = sum(out[k] for k in COLL_KINDS)
    return out

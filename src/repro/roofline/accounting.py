"""Analytic FLOP / HBM-byte accounting per (arch × shape) cell.

Why analytic: XLA:CPU ``cost_analysis()`` counts while-loop bodies ONCE, so a
scanned-layers model is undercounted by ~n_layers×. We therefore derive the
roofline compute/memory terms from the model structure (the same shapes the
compiled dry-run binds), and report the raw cost_analysis numbers alongside
for transparency. Collective bytes DO come from the compiled HLO
(hlo_parse.py applies the trip-count correction there).

Conventions (standard MFU accounting):
  * matmul FLOPs = 2·m·n·k; backward = 2× forward for weights + 1× for
    activations → train = 3× forward ("6·N·D" for the dense part).
  * remat="dots" recomputes non-dot ops only — negligible FLOPs, counted 0;
    remat="full" adds +1× forward.
  * attention scores/AV: 2·2·B·S·S_k·H·hd (fwd), ×3 train.
  * mamba1 sequential scan: ~9 flops per (B, S, d_inner, d_state) element.
  * HBM bytes (train): params read + grads written + AdamW m/v read+write
    (f32) + activation traffic ≈ 2·(bytes of layer-boundary activations ×
    layers × 2 dtypes) — a documented lower-bound model.
"""

from __future__ import annotations

import dataclasses

from repro.configs.shapes import InputShape
from repro.models import common
from repro.models.common import ModelConfig
from repro.models.model import build_model

__all__ = ["cell_accounting"]


def _param_counts(cfg: ModelConfig) -> tuple[int, int]:
    """(total, active-per-token) parameters."""
    lm = build_model(cfg)
    total = common.count_params(lm.param_specs())
    active = total
    if cfg.moe:
        per_expert = 3 * cfg.d_model * cfg.moe_d_ff
        n_moe = cfg.n_layers - cfg.first_dense_layers
        e_eff = max(cfg.n_routed, cfg.moe_pad_experts or 0)
        active = total - n_moe * (e_eff - cfg.top_k) * per_expert
    return total, active


def _attn_flops_fwd(cfg: ModelConfig, B: int, Sq: int, Sk: int) -> float:
    """Scores + AV einsums over all layers with attention."""
    if cfg.family == "ssm":
        return 0.0
    if cfg.family == "hybrid":
        n_attn = (cfg.n_layers // cfg.hybrid_period)
        hd = cfg.hd
        H = cfg.n_heads
        return n_attn * 4.0 * B * Sq * Sk * H * hd
    if cfg.mla:
        hd = cfg.qk_nope_dim + cfg.qk_rope_dim + cfg.v_head_dim
        return cfg.n_layers * 2.0 * B * Sq * Sk * cfg.n_heads * hd
    hd = cfg.hd
    n = cfg.n_layers
    extra = 0.0
    if cfg.family == "encdec":
        # decoder self (Sq×Sq term passed in) + cross (Sq×enc) + encoder self
        extra = (
            cfg.n_layers * 4.0 * B * Sq * cfg.encoder_seq * cfg.n_heads * hd
            + cfg.encoder_layers * 4.0 * B * cfg.encoder_seq ** 2 * cfg.n_heads * hd
        )
    return n * 4.0 * B * Sq * Sk * cfg.n_heads * hd + extra


def _ssm_flops_fwd(cfg: ModelConfig, B: int, S: int) -> float:
    if cfg.ssm == "mamba1":
        # h = exp(dtA)·h + dtBx ; y = Σ h·C  → ~9 flops / (di × N) / step
        return cfg.n_layers * 9.0 * B * S * cfg.d_inner * cfg.d_state
    if cfg.ssm == "mamba2":
        H = cfg.d_inner // cfg.ssm_head_dim
        P, N, Q = cfg.ssm_head_dim, cfg.d_state, cfg.ssd_chunk
        Qe = min(Q, S)
        per_chunk = (
            2.0 * Qe * Qe * N * H          # C·Bᵀ
            + 2.0 * Qe * Qe * H * P        # L·X
            + 2.0 * Qe * H * P * N * 2     # states in/out
        )
        return cfg.n_layers * B * (S / Qe) * per_chunk
    return 0.0


def cell_accounting(cfg: ModelConfig, shape: InputShape, chips: int,
                    remat: str = "dots") -> dict:
    """Analytic global FLOPs + per-device HBM bytes for one cell."""
    total_p, active_p = _param_counts(cfg)
    B, S = shape.global_batch, shape.seq_len
    dtype_bytes = 2  # bf16 weights/activations

    if shape.kind in ("train", "prefill"):
        tokens = B * S
        dense_fwd = 2.0 * active_p * tokens
        attn_fwd = _attn_flops_fwd(cfg, B, S, S) / 2.0  # causal: half the S² window
        ssm_fwd = _ssm_flops_fwd(cfg, B, S)
        fwd = dense_fwd + attn_fwd + ssm_fwd
        if shape.kind == "prefill":
            flops = fwd
            hbm = (
                total_p * dtype_bytes  # weights read once
                + tokens * cfg.d_model * dtype_bytes * 2 * cfg.n_layers
            ) / chips
        else:
            mult = {"none": 3.0, "dots": 3.0, "dots_no_batch": 3.0, "full": 4.0}[remat]
            flops = fwd * mult
            act_bytes = tokens * cfg.d_model * dtype_bytes * 2 * cfg.n_layers
            opt_bytes = total_p * (4 + 4) * 2  # m,v f32 read+write
            hbm = (
                total_p * dtype_bytes * 3      # w read (fwd+bwd) + grad write
                + opt_bytes
                + 2.0 * act_bytes              # save + reread boundaries
            ) / chips
        model_flops = (6.0 if shape.kind == "train" else 2.0) * active_p * tokens
    else:  # decode: one token against an S-long cache
        tokens = B
        dense = 2.0 * active_p * tokens
        attn = _attn_flops_fwd(cfg, B, 1, S)
        ssm = _ssm_flops_fwd(cfg, B, 1)
        flops = dense + attn + ssm
        # decode HBM: weights + full KV/SSM cache read per step
        if cfg.family == "ssm":
            cache = cfg.n_layers * B * cfg.d_inner * cfg.d_state * dtype_bytes
        elif cfg.family == "hybrid":
            H = cfg.d_inner // cfg.ssm_head_dim
            cache = cfg.n_layers * B * H * cfg.ssm_head_dim * cfg.d_state * dtype_bytes
            n_attn = cfg.n_layers // cfg.hybrid_period
            cache += n_attn * 2 * B * S * cfg.n_kv_heads * cfg.hd * dtype_bytes
        elif cfg.mla:
            cache = cfg.n_layers * B * S * (cfg.kv_lora_rank + cfg.qk_rope_dim) * dtype_bytes
        else:
            cache = cfg.n_layers * 2 * B * S * cfg.n_kv_heads * cfg.hd * dtype_bytes
        hbm = (total_p * dtype_bytes + cache) / chips
        model_flops = 2.0 * active_p * tokens + attn

    return dict(
        total_params=total_p,
        active_params=active_p,
        analytic_flops_global=flops,
        analytic_flops_per_device=flops / chips,
        analytic_hbm_bytes_per_device=hbm,
        model_flops=model_flops,
    )

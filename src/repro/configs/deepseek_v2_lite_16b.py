"""deepseek-v2-lite-16b [arXiv:2405.04434]: 27L d=2048 16H, MLA with
kv_lora_rank=512 (rope 64 / nope 128 / v 128), MoE 64 routed top-6 +
2 shared (expert d_ff=1408), first layer dense (d_ff=10944),
vocab=102400. NOTE: the assignment line also says "160 routed", which
contradicts the published config; we follow the published 64 (DESIGN.md)."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b", family="moe", n_layers=27, d_model=2048,
    n_heads=16, n_kv_heads=16, d_ff=10944, vocab_size=102400,
    norm="rmsnorm", mlp="swiglu",
    mla=True, kv_lora_rank=512, qk_rope_dim=64, qk_nope_dim=128,
    v_head_dim=128,
    moe=True, n_routed=64, n_shared=2, top_k=6, moe_d_ff=1408,
    shared_d_ff=2816, first_dense_layers=1,
)

SMOKE = CONFIG.scaled(n_layers=3, d_model=64, n_heads=4, n_kv_heads=4,
                      d_ff=128, vocab_size=512, kv_lora_rank=32,
                      qk_rope_dim=8, qk_nope_dim=16, v_head_dim=16,
                      n_routed=8, n_shared=1, top_k=2, moe_d_ff=64,
                      shared_d_ff=64, first_dense_layers=1,
                      vocab_pad_multiple=64)

"""olmo-1b [arXiv:2402.00838; hf]: 16L d=2048 16H (kv=16) d_ff=8192
vocab=50304, non-parametric LayerNorm, SwiGLU, tied embeddings."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="olmo-1b", family="dense", n_layers=16, d_model=2048,
    n_heads=16, n_kv_heads=16, d_ff=8192, vocab_size=50304,
    norm="layernorm_np", mlp="swiglu", tie_embeddings=True,
)

SMOKE = CONFIG.scaled(n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
                      d_ff=128, vocab_size=512, vocab_pad_multiple=64)

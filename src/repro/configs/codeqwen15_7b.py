"""codeqwen1.5-7b [hf:Qwen/CodeQwen1.5-7B]: 32L d=4096 32H (kv=32... HF
config uses GQA kv=4 for CodeQwen; the assignment pins kv=32) d_ff=13440
vocab=92416, qwen1.5 arch (SwiGLU + RMSNorm). Attention QKV biases of
qwen1.5 are omitted (noted in DESIGN.md)."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="codeqwen1.5-7b", family="dense", n_layers=32, d_model=4096,
    n_heads=32, n_kv_heads=32, d_ff=13440, vocab_size=92416,
    norm="rmsnorm", mlp="swiglu",
)

SMOKE = CONFIG.scaled(n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
                      d_ff=96, vocab_size=512, vocab_pad_multiple=64)

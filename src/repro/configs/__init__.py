"""Architecture registry: ``--arch <id>`` → ModelConfig.

Ten assigned LM architectures + the paper's own solver config
(``metric-cc``, handled by launch/solve.py rather than the LM stack).
"""

from __future__ import annotations

import importlib

from repro.configs.shapes import SHAPES, InputShape

_ARCH_MODULES = {
    "gemma-7b": "gemma_7b",
    "olmo-1b": "olmo_1b",
    "codeqwen1.5-7b": "codeqwen15_7b",
    "deepseek-67b": "deepseek_67b",
    "pixtral-12b": "pixtral_12b",
    "zamba2-1.2b": "zamba2_1p2b",
    "whisper-base": "whisper_base",
    "qwen2-moe-a2.7b": "qwen2_moe_a2p7b",
    "deepseek-v2-lite-16b": "deepseek_v2_lite_16b",
    "falcon-mamba-7b": "falcon_mamba_7b",
}

ARCH_NAMES = tuple(_ARCH_MODULES)


def _module(name: str):
    if name not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {ARCH_NAMES}")
    return importlib.import_module(f"repro.configs.{_ARCH_MODULES[name]}")


def get_config(name: str):
    return _module(name).CONFIG


def get_smoke_config(name: str):
    return _module(name).SMOKE


def shape_applicable(cfg, shape: InputShape) -> tuple[bool, str]:
    """Is (arch × shape) a runnable cell? (False, reason) if skipped."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "full quadratic attention at 500k ctx (DESIGN.md skip)"
    return True, ""


def all_cells():
    """All (arch, shape) pairs with applicability flags — 40 cells."""
    out = []
    for a in ARCH_NAMES:
        cfg = get_config(a)
        for s in SHAPES.values():
            ok, why = shape_applicable(cfg, s)
            out.append((a, s.name, ok, why))
    return out

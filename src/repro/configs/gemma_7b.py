"""gemma-7b [arXiv:2403.08295; hf]: 28L d=3072 16H (GQA kv=16) d_ff=24576
vocab=256000, GeGLU, head_dim=256, tied embeddings."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="gemma-7b", family="dense", n_layers=28, d_model=3072,
    n_heads=16, n_kv_heads=16, head_dim=256, d_ff=24576, vocab_size=256000,
    norm="rmsnorm", mlp="geglu", tie_embeddings=True,
)

SMOKE = CONFIG.scaled(n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
                      head_dim=32, d_ff=128, vocab_size=512,
                      vocab_pad_multiple=64)

"""whisper-base [arXiv:2212.04356; unverified]: enc-dec, 6L encoder + 6L
decoder, d=512 8H d_ff=2048 vocab=51865. Conv audio frontend is a STUB —
input_specs() provides 1500 precomputed frame embeddings. LayerNorm is the
non-parametric variant (DESIGN.md simplification); GELU MLP; learned
decoder positions, sinusoidal encoder positions."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base", family="encdec", n_layers=6, d_model=512,
    n_heads=8, n_kv_heads=8, d_ff=2048, vocab_size=51865,
    norm="layernorm_np", mlp="gelu", encoder_layers=6, encoder_seq=1500,
)

SMOKE = CONFIG.scaled(n_layers=2, encoder_layers=2, d_model=64, n_heads=4,
                      n_kv_heads=4, d_ff=128, vocab_size=512, encoder_seq=16,
                      vocab_pad_multiple=64)

"""zamba2-1.2b [arXiv:2411.15242; hf]: 38 mamba2 layers d=2048, shared
attention block (32H kv=32) every 6 layers, d_ff=8192, vocab=32000,
ssm_state=64. The published per-invocation LoRA on the shared block is
omitted (DESIGN.md §Arch-applicability)."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b", family="hybrid", n_layers=38, d_model=2048,
    n_heads=32, n_kv_heads=32, d_ff=8192, vocab_size=32000,
    norm="rmsnorm", mlp="swiglu",
    ssm="mamba2", d_inner=4096, d_state=64, ssm_head_dim=64, conv_width=4,
    ssd_chunk=256, hybrid_period=6,
)

SMOKE = CONFIG.scaled(n_layers=5, d_model=64, n_heads=4, n_kv_heads=4,
                      d_ff=128, vocab_size=512, d_inner=128, d_state=16,
                      ssm_head_dim=32, ssd_chunk=8, hybrid_period=2,
                      vocab_pad_multiple=64)

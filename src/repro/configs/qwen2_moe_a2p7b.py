"""qwen2-moe-a2.7b [hf:Qwen/Qwen1.5-MoE-A2.7B]: 24L d=2048 16H (kv=16),
MoE with 60 routed experts top-4 (expert d_ff=1408) + shared expert
(d_ff=5632, the "4 shared" aggregate), vocab=151936. 60 experts do not
divide the 16-way model axis → expert weights fall back to TP over the
expert FFN dim (common.py divisibility rules)."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b", family="moe", n_layers=24, d_model=2048,
    n_heads=16, n_kv_heads=16, d_ff=1408, vocab_size=151936,
    norm="rmsnorm", mlp="swiglu",
    moe=True, n_routed=60, n_shared=4, top_k=4, moe_d_ff=1408,
    shared_d_ff=5632,
)

SMOKE = CONFIG.scaled(n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
                      d_ff=96, vocab_size=512, n_routed=8, n_shared=2,
                      top_k=2, moe_d_ff=96, shared_d_ff=192,
                      vocab_pad_multiple=64)

"""pixtral-12b [hf:mistralai/Pixtral-12B-2409; unverified]: 40L d=5120 32H
(GQA kv=8) d_ff=14336 vocab=131072. VLM: pixtral-ViT frontend is a STUB —
input_specs() provides precomputed patch embeddings prepended to text."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="pixtral-12b", family="vlm", n_layers=40, d_model=5120,
    n_heads=32, n_kv_heads=8, head_dim=128, d_ff=14336, vocab_size=131072,
    norm="rmsnorm", mlp="swiglu", num_patches=256,
)

SMOKE = CONFIG.scaled(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                      head_dim=16, d_ff=128, vocab_size=512, num_patches=8,
                      vocab_pad_multiple=64)

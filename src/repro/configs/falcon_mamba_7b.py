"""falcon-mamba-7b [arXiv:2410.05355; unverified]: 64L mamba1 blocks,
d=4096 (attn-free), d_inner=8192, ssm_state=16, conv width 4, dt_rank=256,
vocab=65024."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b", family="ssm", n_layers=64, d_model=4096,
    n_heads=1, n_kv_heads=1, d_ff=0, vocab_size=65024,
    norm="rmsnorm", mlp="swiglu",
    ssm="mamba1", d_inner=8192, d_state=16, conv_width=4, dt_rank=256,
)

SMOKE = CONFIG.scaled(n_layers=3, d_model=64, d_inner=128, d_state=8,
                      dt_rank=8, vocab_size=512, vocab_pad_multiple=64)

"""Assigned input shapes (one set, shared by all 10 LM architectures).

``decode_*`` / ``long_*`` lower serve_step (one new token against a KV/SSM
cache of seq_len); the others lower train_step. ``long_500k`` requires
sub-quadratic sequence mixing and is skipped for pure full-attention
architectures (see DESIGN.md §Arch-applicability).
"""

import dataclasses


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}

"""Shape bucketing for the batched solve service (DESIGN.md §8).

XLA compiles one executable per shape, so a serving layer must not present
it one shape per request. Incoming instances of any size ``n <= bucket_n``
are padded into a small ladder of canonical sizes (default 32/64/96/128)
by appending **ghost points**, and batches of ``B`` padded instances share
one compiled batched runner per ``(bucket_n, B, family)``.

Ghost contract (the §8 fixed-point argument):

  * Ghost problem data are inert: ``w = 1``, ``d = 0``, ``c_x = 0`` (and
    ``w_f = 1``, ``c_f = 0`` when the family has slacks), so the initial
    iterate ``x0 = -c_x/(eps w)`` (and ``f0``) is exactly 0 on every ghost
    cell and all staged projection gains stay finite.
  * Every constraint touching a ghost index is **structurally masked**: a
    triangle set ``S_{i,k}`` is ghost iff its largest index ``k >= n_real``
    (all of a set's triplets share k), so whole sets drop from the staged
    ``act`` masks at once; the pair/box families and the convergence
    metrics run under the live-pair mask (`metrics_device.live_pair_mask`).
  * Therefore ghost cells of X, F and every dual are *never read into an
    active step and never written*: they are fixed points of the padded
    pass by construction, and the padded solve IS the n_real solve on the
    padded schedule (pinned to 1e-10 by tests/test_serve.py).

``Family`` is the compile key beyond shape: (eps, has_f, box, dtype).
``SolverCache`` memoizes one ``BatchedSolver`` per (bucket_n, batch,
family) and counts hits/misses for the scheduler's occupancy report.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.problems import MetricQP

__all__ = [
    "DEFAULT_LADDER",
    "Family",
    "SolverCache",
    "ValidationError",
    "bucket_for",
    "family_of",
    "pad_problem",
    "route_for",
    "validate_problem",
]

DEFAULT_LADDER = (32, 64, 96, 128)


class ValidationError(ValueError):
    """Rejected at intake: the instance would poison a batch (non-finite
    or non-positive data) or cannot be stacked (wrong shapes)."""


def validate_problem(p: MetricQP) -> None:
    """Intake gate of the serve stack (DESIGN.md §11): reject instances
    whose data would propagate NaNs through a shared batch or break the
    stacked layout, *before* they cost a dispatch. Checks shapes against
    ``p.n``, finiteness of every operand's strict upper triangle (the
    only meaningful region), strict positivity of the weights (the
    projection gains divide by them), and a finite positive eps."""
    n = int(p.n)
    if n < 2:
        raise ValidationError(f"instance needs n >= 2 points, got n={n}")
    if not np.isfinite(p.eps) or p.eps <= 0:
        raise ValidationError(f"eps must be finite and > 0, got {p.eps}")
    fields = [("d", p.d), ("w", p.w), ("c_x", p.c_x)]
    if p.has_f:
        fields += [("w_f", p.w_f), ("c_f", p.c_f)]
    iu = np.triu_indices(n, k=1)
    for name, arr in fields:
        if arr is None:
            raise ValidationError(f"{name} is required (has_f={p.has_f})")
        a = np.asarray(arr)
        if a.shape != (n, n):
            raise ValidationError(
                f"{name} has shape {a.shape}, expected ({n}, {n})"
            )
        if not np.all(np.isfinite(a[iu])):
            raise ValidationError(
                f"{name} has non-finite entries on the upper triangle"
            )
    for name, arr in (("w", p.w), ("w_f", p.w_f)):
        if arr is not None and not np.all(np.asarray(arr)[iu] > 0):
            raise ValidationError(f"{name} must be strictly positive")
    if p.box is not None:
        lo, hi = p.box
        if not (np.isfinite(lo) and np.isfinite(hi) and lo <= hi):
            raise ValidationError(f"box {p.box} must be finite with lo <= hi")


def route_for(n: int, ladder=DEFAULT_LADDER) -> int | None:
    """Serving route of an n-point instance: the smallest ladder bucket
    that fits it, or ``None`` for an **above-ladder** instance — the
    scheduler then routes it to a dedicated ``ShardedSolver.run_until``
    slot (multi-device, native n, DESIGN.md §9) instead of a batch slot.
    """
    for b in sorted(ladder):
        if n <= b:
            return int(b)
    return None


def bucket_for(n: int, ladder=DEFAULT_LADDER) -> int:
    """Smallest ladder size that fits an n-point instance; raises for
    above-ladder sizes (use ``route_for`` when the sharded escape hatch
    should catch them instead)."""
    b = route_for(n, ladder)
    if b is None:
        raise ValueError(
            f"instance n={n} exceeds the largest serving bucket {max(ladder)}"
        )
    return b


@dataclasses.dataclass(frozen=True)
class Family:
    """Problem-family compile key: everything that changes the traced
    program besides (bucket_n, batch). Instances in one batch must agree
    on all of it; per-instance (w, d, c) data are runtime operands."""

    eps: float
    has_f: bool
    box: tuple[float, float] | None
    dtype: str = "float64"

    def __post_init__(self):
        object.__setattr__(self, "eps", float(self.eps))
        if self.box is not None:
            object.__setattr__(
                self, "box", (float(self.box[0]), float(self.box[1]))
            )


def family_of(p: MetricQP, dtype=np.float64) -> Family:
    return Family(
        eps=p.eps, has_f=p.has_f, box=p.box, dtype=np.dtype(dtype).name
    )


def pad_problem(p: MetricQP, bucket_n: int) -> MetricQP:
    """Ghost-pad a MetricQP to ``bucket_n`` points (see module docstring).

    The returned problem has the same family (eps/has_f/box) and inert
    ghost data; solve it with ``n_real = p.n`` (``ParallelSolver`` for a
    standalone padded solve, ``BatchedSolver`` for a batch slot).
    """
    if not 0 <= p.n <= bucket_n:
        raise ValueError(f"cannot pad n={p.n} into bucket_n={bucket_n}")

    def pad(a, fill):
        if a is None:
            return None
        out = np.full((bucket_n, bucket_n), fill, np.float64)
        out[: p.n, : p.n] = a
        return out

    return MetricQP(
        n=bucket_n,
        d=pad(p.d, 0.0),
        w=pad(p.w, 1.0),
        eps=p.eps,
        has_f=p.has_f,
        c_x=pad(p.c_x, 0.0),
        w_f=pad(p.w_f, 1.0),
        c_f=pad(p.c_f, 0.0),
        box=p.box,
    )


class SolverCache:
    """Compiled-solver cache: one BatchedSolver per (bucket_n, batch,
    family). The jitted runners hang off each solver (keyed by
    check_every/stop_rule), so a cache hit reuses the compiled batched
    while_loop outright — the compile cost a naive per-instance service
    would pay on every new weight matrix is paid once per bucket."""

    def __init__(self, num_buckets: int = 6, **solver_kwargs):
        self.num_buckets = num_buckets
        self.solver_kwargs = solver_kwargs
        self._cache: dict = {}
        self.hits = 0
        self.misses = 0

    def get(self, bucket_n: int, batch: int, family: Family):
        from repro.serve.batching import BatchedSolver

        key = (int(bucket_n), int(batch), family)
        solver = self._cache.get(key)
        if solver is None:
            self.misses += 1
            solver = self._cache[key] = BatchedSolver(
                bucket_n=bucket_n,
                batch=batch,
                family=family,
                num_buckets=self.num_buckets,
                **self.solver_kwargs,
            )
        else:
            self.hits += 1
        return solver

    def stats(self) -> dict:
        return {
            "entries": len(self._cache),
            "hits": self.hits,
            "misses": self.misses,
        }

"""End-to-end graph -> clustering serving pipeline (DESIGN.md §8/§9).

One callable, shared by the CLI below, the CI smoke legs and
``benchmarks/serve_throughput.py``:

    adjacency -> signed CC instance (graphs/jaccard.py)
              -> correlation_clustering_lp
              -> batched vmapped solve (scheduler + BatchedSolver) —
                 whole-batch drain mode or slot-level continuous
                 batching (``--mode continuous``, DESIGN.md §12), with
                 optional Poisson arrivals (``--arrival-rate``) —
                 OR, above the ladder's top rung, a dedicated
                 ShardedSolver.run_until slot at native n (§9 routing)
              -> batched device pivot rounding (rounding.pivot_round_device)
              -> labels + per-instance approximation certificates.

The solve never leaves the device between LP and labels: rounding runs on
the *padded* iterate under the ghost-aware live mask (one jitted program
per (bucket_n, trials), vmapped over rounding trials), so per-instance
shapes never recompile anything.

    PYTHONPATH=src python -m repro.serve.pipeline --sizes 18,22,26 --batch 4
"""

from __future__ import annotations

import argparse
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import metrics_device, problems, rounding
from repro.graphs import generators, jaccard
from repro.serve import buckets as bk
from repro.serve.scheduler import BatchScheduler

__all__ = ["cluster_graphs", "round_device_batch"]


@functools.lru_cache(maxsize=16)
def _round_fn(bucket_n: int, trials: int):
    """Jitted (padded) rounding program: vmap over trials, pick the
    cheapest clustering, report cost + LP lower bound."""

    def go(x, orders, dissim, weights, n_real):
        mask = metrics_device.live_pair_mask(bucket_n, n_real)
        labs = jax.vmap(
            lambda o: rounding.pivot_round_device(x, o, n_real=n_real)
        )(orders)  # (trials, bucket_n)
        costs = jax.vmap(
            lambda l: rounding.cc_cost_device(l, dissim, weights, mask)
        )(labs)
        best = jnp.argmin(costs)
        lp_lb = jnp.sum(
            jnp.where(mask, weights * jnp.abs(x - dissim), 0.0)
        )
        return labs[best], costs[best], lp_lb

    return jax.jit(go)


def round_device_batch(
    x_pad, dissim, weights, n_real: int, trials: int = 5, seed: int = 0
):
    """Device pivot rounding of one padded LP point; returns the numpy
    certificate dict of the best trial (same fields as
    ``rounding.certificate``). Pivot orders are permutations of the
    *padded* index range (ghosts skip themselves inside the kernel), so
    the jit cache keys on (bucket_n, trials) only."""
    bucket_n = x_pad.shape[0]
    orders = jnp.asarray(
        rounding.pivot_orders(bucket_n, seed=seed, trials=trials), jnp.int32
    )
    labels, cost, lp_lb = _round_fn(bucket_n, trials)(
        jnp.asarray(x_pad), orders, jnp.asarray(dissim),
        jnp.asarray(weights), n_real,
    )
    labels = np.asarray(labels)[:n_real]
    cost = float(cost)
    lp_lb = float(lp_lb)
    return {
        "labels": labels,
        "cc_cost": cost,
        "lp_lower_bound": lp_lb,
        "approx_ratio_certificate": cost / max(lp_lb, 1e-12),
        "num_clusters": int(len(np.unique(labels))),
    }


def cluster_graphs(
    adjs,
    *,
    ladder=bk.DEFAULT_LADDER,
    batch: int = 8,
    eps: float = 0.05,
    tol: float = 1e-3,
    max_passes: int = 200,
    check_every: int = 10,
    stop_rule: str = "absolute",
    trials: int = 5,
    seed: int = 0,
    dtype=np.float32,
    scheduler: BatchScheduler | None = None,
    use_kernel: bool = False,
    mode: str = "drain",
    arrival_rate: float | None = None,
):
    """Cluster a stream of graphs through the batched solve service.

    Args:
      adjs: iterable of (n, n) boolean adjacency matrices (any mix of
        sizes up to the ladder max).
      scheduler: optionally a pre-warmed ``BatchScheduler`` (shares its
        compile cache across calls); otherwise one is built from the
        solve arguments.
      mode: scheduler dispatch mode — ``"drain"`` micro-batching or
        ``"continuous"`` slot-level continuous batching (DESIGN.md §12).
      arrival_rate: if set, submissions follow a Poisson stream at this
        rate (instances/sec; seeded exponential inter-arrival sleeps)
        instead of arriving as one burst — the sustained-load shape the
        CI smoke leg drives through the continuous scheduler.

    Returns ``(results, stats)``: one dict per input graph — ``labels``,
    ``num_clusters``, ``cc_cost``, ``lp_lower_bound``,
    ``approx_ratio_certificate`` plus the solve telemetry (``passes``,
    ``converged``, ``max_violation``, ``duality_gap``, ``bucket_n``) —
    and the scheduler's throughput/occupancy/cache stats.
    """
    sched_ = scheduler
    if sched_ is None:
        sched_ = BatchScheduler(
            ladder=ladder, batch=batch, dtype=dtype,
            tol=tol, max_passes=max_passes, check_every=check_every,
            stop_rule=stop_rule, use_kernel=use_kernel, mode=mode,
        )
    rng = np.random.default_rng(seed)
    instances = []
    for g, adj in enumerate(adjs):
        if arrival_rate:
            time.sleep(rng.exponential(1.0 / float(arrival_rate)))
        dissim, weights = jaccard.signed_instance(np.asarray(adj))
        prob = problems.correlation_clustering_lp(dissim, weights, eps=eps)
        fut = sched_.submit(prob, tag=g)
        instances.append((fut.tag, prob, dissim, weights))
    solved = sched_.drain()

    results = []
    for tag, prob, dissim, weights in instances:
        r = solved[tag]
        if r.get("route") == "failed":
            # Dead-letter (validation reject, persistent dispatch fault,
            # diverged slot): surface the typed error per graph instead
            # of crashing the whole stream on a missing iterate.
            results.append(
                {
                    "graph": tag,
                    "n": prob.n,
                    "bucket_n": r["bucket_n"],
                    "route": "failed",
                    "error": r.get("error"),
                    "error_detail": r.get("error_detail"),
                    "passes": r.get("passes", 0),
                    "converged": False,
                }
            )
            continue
        n, bucket_n = prob.n, r["bucket_n"]
        # Above-ladder instances come back from the sharded route at
        # native n (bucket_n == n): the pad is a no-op and the ghost-aware
        # rounding degrades to plain device rounding — one code path.
        pad = lambda a: np.pad(a, ((0, bucket_n - n), (0, bucket_n - n)))
        cert = round_device_batch(
            r["x_pad"], pad(dissim), pad(weights), n,
            trials=trials, seed=seed,
        )
        results.append(
            {
                "graph": tag,
                "n": n,
                "bucket_n": bucket_n,
                "route": r.get("route", "batch"),
                "passes": r["passes"],
                "converged": r["converged"],
                "max_violation": r["max_violation"],
                "duality_gap": r["duality_gap"],
                "lp_objective": r["lp_objective"],
                **cert,
            }
        )
    return results, sched_.stats()


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--sizes", default="18,22,26",
                    help="comma-separated graph sizes")
    ap.add_argument("--kind", default="sbm", choices=["sbm", "ba", "ws"])
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--ladder", default="32,64,96,128")
    ap.add_argument("--eps", type=float, default=0.05)
    ap.add_argument("--tol", type=float, default=1e-3)
    ap.add_argument("--max-passes", type=int, default=200)
    ap.add_argument("--check-every", type=int, default=10)
    ap.add_argument("--stop-rule", default="absolute",
                    choices=["absolute", "rel_gap", "plateau"])
    ap.add_argument("--trials", type=int, default=5)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--use-kernel", action="store_true",
                    help="route solves through the gen-3 Pallas megakernel "
                         "(batched AND sharded paths; DESIGN.md §10)")
    ap.add_argument("--mode", default="drain",
                    choices=["drain", "continuous"],
                    help="dispatch mode: whole-batch micro-batching or "
                         "slot-level continuous batching (DESIGN.md §12)")
    ap.add_argument("--arrival-rate", type=float, default=None,
                    help="Poisson arrival rate (instances/sec); default: "
                         "submit everything as one burst")
    args = ap.parse_args(argv)

    sizes = [int(s) for s in args.sizes.split(",")]
    ladder = tuple(int(s) for s in args.ladder.split(","))
    adjs = generators.graph_batch(sizes, kind=args.kind, seed=args.seed)
    t0 = time.perf_counter()
    results, stats = cluster_graphs(
        adjs, ladder=ladder, batch=args.batch, eps=args.eps, tol=args.tol,
        max_passes=args.max_passes, check_every=args.check_every,
        stop_rule=args.stop_rule, trials=args.trials, seed=args.seed,
        use_kernel=args.use_kernel, mode=args.mode,
        arrival_rate=args.arrival_rate,
    )
    wall = time.perf_counter() - t0
    for r in results:
        if r["route"] == "failed":
            print(
                f"graph {r['graph']}: n={r['n']} route=failed "
                f"error={r['error']} ({r['error_detail']})"
            )
            continue
        print(
            f"graph {r['graph']}: n={r['n']} bucket={r['bucket_n']} "
            f"route={r['route']} "
            f"passes={r['passes']} converged={r['converged']} "
            f"clusters={r['num_clusters']} cost={r['cc_cost']:.3f} "
            f"lp_lb={r['lp_lower_bound']:.3f} "
            f"ratio={r['approx_ratio_certificate']:.3f}"
        )
    print(
        f"pipeline: instances={stats['instances_done']} "
        f"batches={stats['batches_run']} "
        f"occupancy={stats['occupancy']:.2f} "
        f"cache_misses={stats['compile_cache']['misses']} "
        f"instances/sec={stats['instances_done'] / wall:.3f} "
        f"(wall {wall:.1f}s, solve {stats['solve_time_s']:.1f}s)"
    )
    hwm = ",".join(
        f"{k}:{v}" for k, v in sorted(
            stats["queue_depth_hwm"].items(), key=lambda kv: str(kv[0])
        )
    )
    # terminal=K/N pins the §11 invariant the CI sustained-load leg
    # asserts: every submitted graph reached exactly one terminal result.
    print(
        f"serve: mode={stats['mode']} "
        f"refills={stats['refills']} chunks={stats['chunks_run']} "
        f"queue_hwm=[{hwm}] "
        f"dead_letters={stats['faults']['dead_letters']} "
        f"terminal={len(results)}/{len(sizes)}"
    )
    return results, stats


if __name__ == "__main__":
    main()

"""Micro-batching request scheduler for the batched solve service
(DESIGN.md §8/§9).

Requests (one MetricQP each, any size ``n``) are queued, routed to their
shape bucket, and dispatched as batches of up to ``batch`` instances. A
batch launches when its bucket has ``batch`` requests waiting (full) or
when the oldest waiting request has aged past ``deadline_s`` (a partial
batch padded with empty slots — latency wins over occupancy once the
deadline expires). ``drain()`` flushes everything regardless of age.

**Above-ladder instances** (n larger than the top rung) do not batch:
``submit`` routes them immediately to a dedicated
``ShardedSolver.run_until`` slot on the solver mesh (DESIGN.md §9) — the
same stop rule, the same result/certificate plumbing, results flagged
``route="sharded"``. Big instances bake their weights into the trace
(one compile each), which is the right trade at sizes where the solve
itself dwarfs the compile and batching would only serialize the mesh.

The scheduler owns a ``SolverCache``: the first batch of a
(bucket_n, batch, family) slot compiles the batched runner, every later
batch reuses it. ``warmup(family)`` pre-compiles the runner for every
configured ladder rung up front (an all-empty batch through the real
jitted while_loop, which exits at pass 0), so the first real batch of a
prewarmed slot dispatches warm. ``stats()`` reports the cache hit rate
and the warm/cold dispatch counts alongside throughput (instances/sec of
completed solves) and mean batch occupancy (real instances per slot),
the numbers the serve benchmark and CI smoke legs grep for.

**Fault tolerance** (DESIGN.md §11). ``submit`` never raises for a
solvable request and every accepted request reaches exactly one terminal
result:

  * intake validation (`buckets.validate_problem`) rejects poison
    (non-finite data, non-positive weights, bad shapes) into an
    immediate dead-letter result instead of a queue slot;
  * duplicate tags — user-supplied, or an auto tag colliding with a
    still-pending one — raise ``ValueError`` at submit, the one case
    that IS a caller bug: silently overwriting ``_results`` loses a
    previous request's answer;
  * each dispatch attempt runs under retry with capped exponential
    backoff (transient failures heal); a group that keeps failing is
    bisected to isolate the poison instance, whose singleton becomes a
    dead-letter result (``route="failed"``, typed ``error`` /
    ``error_detail``, original tag) while every healthy slot's result
    still lands;
  * a slot the batched engine flags ``diverged`` (NaN probe — the
    on-device guard froze it at its last finite iterate) dead-letters
    with ``error="diverged"`` rather than masquerading as a solve;
  * an optional ``faults`` injector (`serve.faults.FaultInjector`) is
    polled once per dispatch *attempt* — the deterministic chaos source
    the end-to-end tests replay from a seed.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import numpy as np

from repro.core.problems import MetricQP
from repro.serve import buckets as bk

__all__ = ["BatchScheduler", "SolveRequest"]


@dataclasses.dataclass
class SolveRequest:
    """One queued instance. ``tag`` is the caller's correlation key."""

    problem: MetricQP
    tag: Any = None
    t_submit: float = 0.0
    bucket_n: int = 0


class BatchScheduler:
    """Collect-up-to-B-or-deadline micro-batcher (see module docstring).

    Args:
      ladder: bucket sizes (sorted ascending is not required).
      batch: instance slots per batched solve.
      deadline_s: max age of the oldest queued request before a partial
        batch is dispatched anyway (0 = only ``drain`` flushes partials).
      cache: shared ``SolverCache`` (one per process is the right scope;
        pass your own to share compiled runners across schedulers).
      dtype: compute dtype of the batched solvers.
      sharded_mesh: mesh for the above-ladder sharded route (default: a
        1-D 'solver' mesh over every visible device, built lazily on the
        first big instance).
      sharded_num_buckets: diagonal buckets of the sharded solvers.
      prewarm: optionally a ``Family`` — ``warmup(prewarm)`` runs at
        construction, compiling the configured ladder before traffic.
      use_kernel: route BOTH dispatch paths through the gen-3 Pallas
        megakernel (DESIGN.md §10) — the batched route via
        ``BatchedSolver(use_kernel=True)``, the above-ladder route via
        ``ShardedSolver(use_kernel=True)``. Ignored when ``cache`` is
        passed explicitly (the cache's own solver kwargs win on the
        batched route).
      max_retries: dispatch attempts beyond the first before a group is
        bisected (transient-failure budget).
      backoff_s / backoff_cap_s: initial / maximum retry backoff; the
        delay doubles per retry and is served by ``sleep`` (injectable —
        tests pass a recording stub, so retry tests take zero wall
        time).
      faults: optional ``serve.faults.FaultInjector`` polled once per
        dispatch attempt (the ``dispatch`` injection site).
      solve_kwargs: forwarded to ``run_until`` on both routes (tol,
        max_passes, check_every, stop_rule).
    """

    def __init__(
        self,
        ladder=bk.DEFAULT_LADDER,
        batch: int = 8,
        deadline_s: float = 0.0,
        cache: bk.SolverCache | None = None,
        dtype=np.float32,
        clock: Callable[[], float] = time.monotonic,
        sharded_mesh=None,
        sharded_num_buckets: int = 6,
        prewarm: bk.Family | None = None,
        use_kernel: bool = False,
        max_retries: int = 2,
        backoff_s: float = 0.05,
        backoff_cap_s: float = 1.0,
        sleep: Callable[[float], None] = time.sleep,
        faults=None,
        **solve_kwargs,
    ):
        self.ladder = tuple(ladder)
        self.batch = int(batch)
        self.deadline_s = float(deadline_s)
        self.use_kernel = bool(use_kernel)
        self.cache = (
            cache
            if cache is not None
            else bk.SolverCache(use_kernel=self.use_kernel)
        )
        self.dtype = dtype
        self.clock = clock
        self.solve_kwargs = solve_kwargs
        self.sharded_num_buckets = int(sharded_num_buckets)
        self._mesh = sharded_mesh
        self.max_retries = max(0, int(max_retries))
        self.backoff_s = float(backoff_s)
        self.backoff_cap_s = float(backoff_cap_s)
        self._sleep = sleep
        self.faults = faults
        self._queues: dict[tuple[int, bk.Family], list[SolveRequest]] = {}
        self._results: dict[Any, dict] = {}
        self._pending_tags: set = set()
        self._seq = 0
        self._instances_done = 0
        self._batches_run = 0
        self._slots_run = 0
        self._solve_time = 0.0
        self._sharded_done = 0
        self._sharded_time = 0.0
        self._retries = 0
        self._dead_letters = 0
        self._validation_rejects = 0
        # compile-warmth bookkeeping: a dispatch is "warm" when its
        # (bucket_n, batch, family) runner was compiled before it —
        # by warmup() or by an earlier batch of the same slot.
        self._compiled: set = set()
        self._prewarmed: set = set()
        self._warm_dispatches = 0
        self._cold_dispatches = 0
        if prewarm is not None:
            self.warmup(prewarm)

    # ------------------------------------------------------------- intake
    def submit(self, problem: MetricQP, tag: Any = None) -> Any:
        """Queue one instance; returns its tag (auto-assigned if None).
        Full buckets dispatch immediately; **above-ladder** instances
        bypass the queue entirely and solve now on the sharded route.

        A duplicate tag (still pending, or already holding a result)
        raises ``ValueError`` — accepting it would silently overwrite
        the earlier request's result. Everything else terminates in a
        result: invalid problem data dead-letter at intake
        (``route="failed"``, ``error="validation"``), solver failures
        dead-letter after retry/bisection — ``submit`` itself never
        raises past intake."""
        if tag is None:
            tag, self._seq = f"req-{self._seq}", self._seq + 1
        if tag in self._pending_tags or tag in self._results:
            raise ValueError(
                f"duplicate tag {tag!r}: a request with this tag is "
                "already pending or has an unclaimed result"
            )
        req = SolveRequest(
            problem=problem,
            tag=tag,
            t_submit=self.clock(),
            bucket_n=problem.n,
        )
        try:
            bk.validate_problem(problem)
        except bk.ValidationError as e:
            self._validation_rejects += 1
            self._dead_letter(req, "validation", e)
            return tag
        bucket_n = bk.route_for(problem.n, self.ladder)
        self._pending_tags.add(tag)
        if bucket_n is None:
            self._dispatch_sharded(req)
            return tag
        req.bucket_n = bucket_n
        key = (req.bucket_n, bk.family_of(problem, self.dtype))
        self._queues.setdefault(key, []).append(req)
        if len(self._queues[key]) >= self.batch:
            self._dispatch(key)
        return tag

    # ------------------------------------------------------------- warmup
    def warmup(self, family: bk.Family, buckets=None) -> dict:
        """Pre-compile the batched runner for every ladder rung of one
        problem family (DESIGN.md §8): an all-empty batch is pushed
        through the REAL ``run_until`` with ``max_passes=0`` — the jitted
        while_loop compiles fully and exits at pass 0 — under exactly the
        solve kwargs real dispatches use, so the compile-cache key
        matches by construction. Later real batches of these slots
        dispatch warm. Returns ``{bucket_n: seconds}``.
        """
        timings = {}
        for bucket_n in sorted(set(int(b) for b in (buckets or self.ladder))):
            t0 = self.clock()
            solver = self.cache.get(bucket_n, self.batch, family)
            solver.run_until(
                solver.stack([]), **{**self.solve_kwargs, "max_passes": 0}
            )
            key = (bucket_n, self.batch, family)
            self._compiled.add(key)
            self._prewarmed.add(key)
            timings[bucket_n] = self.clock() - t0
        return timings

    @property
    def pending(self) -> int:
        return sum(len(q) for q in self._queues.values())

    def poll(self) -> None:
        """Dispatch every bucket whose oldest request is past deadline.
        With ``deadline_s == 0`` partial batches wait for ``drain()``
        (the documented contract); only full buckets dispatch eagerly."""
        if self.deadline_s <= 0:
            return
        now = self.clock()
        for key, q in list(self._queues.items()):
            if q and now - q[0].t_submit >= self.deadline_s:
                self._dispatch(key)

    def drain(self) -> dict[Any, dict]:
        """Flush all partial batches and return every finished result."""
        for key in list(self._queues):
            while self._queues.get(key):
                self._dispatch(key)
        return self.results()

    def results(self) -> dict[Any, dict]:
        return dict(self._results)

    # ------------------------------------------------------- fault handling
    def _dead_letter(self, req: SolveRequest, error: str, exc: Exception):
        """Terminal failure result: the request's tag still resolves, with
        a typed error instead of an iterate (DESIGN.md §11)."""
        self._dead_letters += 1
        self._pending_tags.discard(req.tag)
        self._results[req.tag] = {
            "x": None,
            "x_pad": None,
            "f": None,
            "n": req.problem.n,
            "bucket_n": req.bucket_n,
            "route": "failed",
            "error": error,
            "error_type": type(exc).__name__,
            "error_detail": str(exc),
            "passes": 0,
            "converged": False,
            "wait_s": max(0.0, self.clock() - req.t_submit),
            "solve_s": 0.0,
        }

    def _poll_faults(self, reqs: list[SolveRequest]) -> dict:
        """Poll the ``dispatch`` injection site once per solve attempt.
        Raises ``InjectedFault`` for a due dispatch_error (the retry loop
        then eats it like any real dispatch exception); returns a
        {tag: poisoned_problem} override map for due nan_poison specs —
        corruption past the intake gate, which must surface as a
        per-slot divergence, never as a batch loss."""
        if self.faults is None:
            return {}
        from repro.serve import faults as flt

        overrides: dict = {}
        tags = [r.tag for r in reqs]
        for spec in self.faults.poll("dispatch", tags=tags):
            if spec.kind == "dispatch_error":
                raise flt.InjectedFault(f"injected dispatch error ({spec.spec_str()})")
            if spec.kind == "nan_poison":
                tag = spec.payload.get("tag", tags[0])
                for r in reqs:
                    if r.tag == tag:
                        overrides[tag] = flt.poison_problem(r.problem)
            elif spec.kind == "straggler":
                self._sleep(float(spec.payload.get("seconds", 0.001)))
        return overrides

    def _with_retries(self, attempt: Callable[[], Any]) -> Any:
        """Run one dispatch attempt under retry with capped exponential
        backoff. Each retry re-polls the fault site (transient injected
        faults heal exactly like transient real ones)."""
        delay = self.backoff_s
        failures = 0
        while True:
            try:
                return attempt()
            except Exception:
                failures += 1
                if failures > self.max_retries:
                    raise
                self._retries += 1
                self._sleep(min(delay, self.backoff_cap_s))
                delay *= 2.0

    # ----------------------------------------------------------- dispatch
    def _dispatch(self, key) -> None:
        bucket_n, family = key
        q = self._queues.get(key, [])
        reqs, self._queues[key] = q[: self.batch], q[self.batch:]
        if not reqs:
            return
        ckey = (bucket_n, self.batch, family)
        if ckey in self._compiled:
            self._warm_dispatches += 1
        else:
            self._cold_dispatches += 1
            self._compiled.add(ckey)
        solver = self.cache.get(bucket_n, self.batch, family)
        self._solve_group(solver, bucket_n, reqs)

    def _solve_group(self, solver, bucket_n: int, reqs: list[SolveRequest]):
        """Solve a request group with retry; on persistent failure bisect
        to isolate the poison instance — healthy halves land normally,
        the failing singleton dead-letters. Worst case (one bad instance
        in a batch of B) costs O(log B) extra sub-batch solves, each a
        warm dispatch of the already-compiled runner."""
        try:
            out = self._with_retries(
                lambda: self._attempt_batch(solver, reqs)
            )
        except Exception as e:
            if len(reqs) == 1:
                self._dead_letter(
                    reqs[0],
                    "injected" if type(e).__name__ == "InjectedFault"
                    else "dispatch",
                    e,
                )
                return
            mid = len(reqs) // 2
            self._solve_group(solver, bucket_n, reqs[:mid])
            self._solve_group(solver, bucket_n, reqs[mid:])
            return
        self._land_batch(bucket_n, reqs, *out)

    def _attempt_batch(self, solver, reqs: list[SolveRequest]):
        """One solve attempt of a request group (the retry unit)."""
        overrides = self._poll_faults(reqs)
        inst = solver.stack(
            [overrides.get(r.tag, r.problem) for r in reqs]
        )
        t0 = self.clock()
        state, info = solver.run_until(inst, **self.solve_kwargs)
        x = np.asarray(state.x)  # one host copy; also blocks for the timing
        dt = self.clock() - t0
        return state, info, x, t0, dt

    def _land_batch(self, bucket_n, reqs, state, info, x, t0, dt) -> None:
        self._solve_time += dt
        self._batches_run += 1
        self._slots_run += self.batch
        f = None if state.f is None else np.asarray(state.f)
        diverged = info.get("diverged")
        for i, r in enumerate(reqs):
            if diverged is not None and bool(diverged[i]):
                # the on-device guard froze this slot at its last finite
                # iterate; its result is a typed failure, not a solve.
                self._dead_letter(
                    r, "diverged",
                    ArithmeticError(
                        "residual probe went non-finite; slot frozen at "
                        "its last finite iterate by the divergence guard"
                    ),
                )
                continue
            n = r.problem.n
            self._instances_done += 1
            self._pending_tags.discard(r.tag)
            self._results[r.tag] = {
                "x": x[i, :n, :n],
                "x_pad": x[i],  # padded iterate (ghost-aware device rounding)
                "f": None if f is None else f[i, :n, :n],
                "n": n,
                "bucket_n": bucket_n,
                "route": "batch",
                "passes": int(info["passes"][i]),
                "converged": bool(info["converged"][i]),
                "max_violation": float(info["max_violation"][i]),
                "duality_gap": float(info["duality_gap"][i]),
                "lp_objective": float(info["lp_objective"][i]),
                "qp_objective": float(info["qp_objective"][i]),
                "wait_s": max(0.0, t0 - r.t_submit),
                "solve_s": dt,
            }

    def _solver_mesh(self):
        if self._mesh is None:
            from repro.launch import mesh as mesh_lib

            self._mesh = mesh_lib.make_solver_mesh()
        return self._mesh

    def _dispatch_sharded(self, req: SolveRequest) -> None:
        """Above-ladder escape hatch (DESIGN.md §9): solve one instance at
        its NATIVE n with ``ShardedSolver.run_until`` on the solver mesh —
        same stop rule and info/certificate plumbing as a batch slot, no
        ghost padding (``x_pad`` is the native iterate, ``bucket_n = n``,
        so the pipeline's ghost-aware device rounding degrades to plain
        device rounding). Same failure contract too: retry with backoff,
        then a dead-letter result; a diverged solve dead-letters."""
        from repro.core.sharded_dykstra import ShardedSolver

        def attempt():
            overrides = self._poll_faults([req])
            solver = ShardedSolver(
                overrides.get(req.tag, req.problem), self._solver_mesh(),
                dtype=self.dtype,
                num_buckets=self.sharded_num_buckets,
                use_kernel=self.use_kernel,
            )
            t0 = self.clock()
            state, info = solver.run_until(**self.solve_kwargs)
            x = np.asarray(state.x)  # host copy; also blocks for the timing
            return state, info, x, t0

        try:
            state, info, x, t0 = self._with_retries(attempt)
        except Exception as e:
            self._dead_letter(
                req,
                "injected" if type(e).__name__ == "InjectedFault"
                else "dispatch",
                e,
            )
            return
        if info.get("diverged"):
            self._dead_letter(
                req, "diverged",
                ArithmeticError(
                    "residual probe went non-finite; sharded solve "
                    "stopped at its last finite chunk boundary"
                ),
            )
            return
        dt = self.clock() - t0
        self._solve_time += dt
        self._sharded_time += dt
        self._sharded_done += 1
        self._instances_done += 1
        self._pending_tags.discard(req.tag)
        n = req.problem.n
        self._results[req.tag] = {
            "x": x,
            "x_pad": x,
            "f": None if state.f is None else np.asarray(state.f),
            "n": n,
            "bucket_n": n,
            "route": "sharded",
            "passes": int(info["passes"]),
            "converged": bool(info["converged"]),
            "max_violation": float(info["max_violation"]),
            "duality_gap": float(info["duality_gap"]),
            "lp_objective": float(info["lp_objective"]),
            "qp_objective": float(info["qp_objective"]),
            "wait_s": max(0.0, t0 - req.t_submit),
            "solve_s": dt,
        }

    # -------------------------------------------------------------- stats
    def stats(self) -> dict:
        """Throughput / occupancy / compile-cache / warmth counters."""
        return {
            "instances_done": self._instances_done,
            "batches_run": self._batches_run,
            "pending": self.pending,
            "occupancy": (
                (self._instances_done - self._sharded_done) / self._slots_run
                if self._slots_run else 0.0
            ),
            "solve_time_s": self._solve_time,
            "throughput_ips": (
                self._instances_done / self._solve_time
                if self._solve_time > 0 else 0.0
            ),
            "sharded_done": self._sharded_done,
            "sharded_time_s": self._sharded_time,
            "compile_cache": self.cache.stats(),
            "prewarm": {
                "buckets": len(self._prewarmed),
                "warm_dispatches": self._warm_dispatches,
                "cold_dispatches": self._cold_dispatches,
            },
            "faults": {
                "retries": self._retries,
                "dead_letters": self._dead_letters,
                "validation_rejects": self._validation_rejects,
                "injected_fired": (
                    len(self.faults.fired) if self.faults is not None else 0
                ),
            },
        }

"""Micro-batching request scheduler for the batched solve service
(DESIGN.md §8).

Requests (one MetricQP each, any size ``n`` up to the ladder max) are
queued, routed to their shape bucket, and dispatched as batches of up to
``batch`` instances. A batch launches when its bucket has ``batch``
requests waiting (full) or when the oldest waiting request has aged past
``deadline_s`` (a partial batch padded with empty slots — latency wins
over occupancy once the deadline expires). ``drain()`` flushes everything
regardless of age.

The scheduler owns a ``SolverCache``: the first batch of a
(bucket_n, batch, family) slot compiles the batched runner, every later
batch reuses it — ``stats()`` reports the cache hit rate alongside
throughput (instances/sec of completed solves) and mean batch occupancy
(real instances per slot), the numbers the serve benchmark and CI smoke
leg grep for.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import numpy as np

from repro.core.problems import MetricQP
from repro.serve import buckets as bk

__all__ = ["BatchScheduler", "SolveRequest"]


@dataclasses.dataclass
class SolveRequest:
    """One queued instance. ``tag`` is the caller's correlation key."""

    problem: MetricQP
    tag: Any = None
    t_submit: float = 0.0
    bucket_n: int = 0


class BatchScheduler:
    """Collect-up-to-B-or-deadline micro-batcher (see module docstring).

    Args:
      ladder: bucket sizes (sorted ascending is not required).
      batch: instance slots per batched solve.
      deadline_s: max age of the oldest queued request before a partial
        batch is dispatched anyway (0 = only ``drain`` flushes partials).
      cache: shared ``SolverCache`` (one per process is the right scope;
        pass your own to share compiled runners across schedulers).
      dtype: compute dtype of the batched solvers.
      solve_kwargs: forwarded to ``BatchedSolver.run_until`` (tol,
        max_passes, check_every, stop_rule).
    """

    def __init__(
        self,
        ladder=bk.DEFAULT_LADDER,
        batch: int = 8,
        deadline_s: float = 0.0,
        cache: bk.SolverCache | None = None,
        dtype=np.float32,
        clock: Callable[[], float] = time.monotonic,
        **solve_kwargs,
    ):
        self.ladder = tuple(ladder)
        self.batch = int(batch)
        self.deadline_s = float(deadline_s)
        self.cache = cache if cache is not None else bk.SolverCache()
        self.dtype = dtype
        self.clock = clock
        self.solve_kwargs = solve_kwargs
        self._queues: dict[tuple[int, bk.Family], list[SolveRequest]] = {}
        self._results: dict[Any, dict] = {}
        self._instances_done = 0
        self._batches_run = 0
        self._slots_run = 0
        self._solve_time = 0.0

    # ------------------------------------------------------------- intake
    def submit(self, problem: MetricQP, tag: Any = None) -> Any:
        """Queue one instance; returns its tag (auto-assigned if None).
        Full buckets dispatch immediately."""
        if tag is None:
            tag = f"req-{self._instances_done + self.pending}"
        req = SolveRequest(
            problem=problem,
            tag=tag,
            t_submit=self.clock(),
            bucket_n=bk.bucket_for(problem.n, self.ladder),
        )
        key = (req.bucket_n, bk.family_of(problem, self.dtype))
        self._queues.setdefault(key, []).append(req)
        if len(self._queues[key]) >= self.batch:
            self._dispatch(key)
        return tag

    @property
    def pending(self) -> int:
        return sum(len(q) for q in self._queues.values())

    def poll(self) -> None:
        """Dispatch every bucket whose oldest request is past deadline.
        With ``deadline_s == 0`` partial batches wait for ``drain()``
        (the documented contract); only full buckets dispatch eagerly."""
        if self.deadline_s <= 0:
            return
        now = self.clock()
        for key, q in list(self._queues.items()):
            if q and now - q[0].t_submit >= self.deadline_s:
                self._dispatch(key)

    def drain(self) -> dict[Any, dict]:
        """Flush all partial batches and return every finished result."""
        for key in list(self._queues):
            while self._queues.get(key):
                self._dispatch(key)
        return self.results()

    def results(self) -> dict[Any, dict]:
        return dict(self._results)

    # ----------------------------------------------------------- dispatch
    def _dispatch(self, key) -> None:
        bucket_n, family = key
        q = self._queues.get(key, [])
        reqs, self._queues[key] = q[: self.batch], q[self.batch:]
        if not reqs:
            return
        solver = self.cache.get(bucket_n, self.batch, family)
        inst = solver.stack([r.problem for r in reqs])
        t0 = self.clock()
        state, info = solver.run_until(inst, **self.solve_kwargs)
        x = np.asarray(state.x)  # one host copy; also blocks for the timing
        dt = self.clock() - t0
        self._solve_time += dt
        self._batches_run += 1
        self._slots_run += self.batch
        self._instances_done += len(reqs)
        f = None if state.f is None else np.asarray(state.f)
        for i, r in enumerate(reqs):
            n = r.problem.n
            self._results[r.tag] = {
                "x": x[i, :n, :n],
                "x_pad": x[i],  # padded iterate (ghost-aware device rounding)
                "f": None if f is None else f[i, :n, :n],
                "n": n,
                "bucket_n": bucket_n,
                "passes": int(info["passes"][i]),
                "converged": bool(info["converged"][i]),
                "max_violation": float(info["max_violation"][i]),
                "duality_gap": float(info["duality_gap"][i]),
                "lp_objective": float(info["lp_objective"][i]),
                "qp_objective": float(info["qp_objective"][i]),
                "wait_s": max(0.0, t0 - r.t_submit),
                "solve_s": dt,
            }

    # -------------------------------------------------------------- stats
    def stats(self) -> dict:
        """Throughput / occupancy / compile-cache counters."""
        return {
            "instances_done": self._instances_done,
            "batches_run": self._batches_run,
            "pending": self.pending,
            "occupancy": (
                self._instances_done / self._slots_run
                if self._slots_run else 0.0
            ),
            "solve_time_s": self._solve_time,
            "throughput_ips": (
                self._instances_done / self._solve_time
                if self._solve_time > 0 else 0.0
            ),
            "compile_cache": self.cache.stats(),
        }

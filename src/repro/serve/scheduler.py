"""Async micro-batching / continuous-batching request scheduler for the
batched solve service (DESIGN.md §8/§9/§12).

``submit`` never blocks on a solve: it validates, routes, and returns a
``ServeFuture`` immediately (the future compares and hashes as its tag,
so tag-keyed code keeps working). Background worker threads — one per
(bucket_n, family) slot, plus one for the above-ladder sharded route —
own every dispatch. ``results()`` / ``drain()`` are the sync points: they
wait for all in-flight work to land, then return the result map (queued
partial batches in drain mode are NOT in flight — only ``drain()`` or a
``poll()`` past the deadline flushes them, the pre-async contract).

Two dispatch modes for the bucketed route:

  * ``mode="drain"`` — classic micro-batching: requests queue per
    (bucket_n, family); a full batch (or an aged partial) is handed to
    that slot's worker as one ``run_until`` job; the whole batch lands
    when its slowest instance stops.
  * ``mode="continuous"`` — slot-level continuous batching
    (DESIGN.md §12): the worker owns a long-lived
    ``batching.ContinuousBatcher`` and loops chunk by chunk; at every
    chunk boundary it retires converged/diverged slots and refills the
    freed slots from its queue (weights are runtime operands — a refill
    never recompiles), so a mixed-age batch keeps every slot busy
    instead of waiting for the batch's slowest instance. Per-slot
    freeze semantics make each instance's result bitwise identical to
    its drain-mode solve.

**Above-ladder instances** (n larger than the top rung) do not batch:
they are handed to the dedicated sharded worker, which solves each at
its NATIVE n with ``ShardedSolver.run_until`` on the solver mesh
(DESIGN.md §9) — same stop rule, same result/certificate plumbing,
results flagged ``route="sharded"``, delivered through the same future.

The scheduler owns a ``SolverCache``: the first batch of a
(bucket_n, batch, family) slot compiles the batched runner, every later
batch reuses it. ``warmup(family)`` pre-compiles the runner for every
configured ladder rung up front (in continuous mode, the chunk stepper
and the refill merge too), so the first real batch of a prewarmed slot
dispatches warm. ``stats()`` reports the cache hit rate, warm/cold
dispatch counts, throughput, slot occupancy, per-bucket queue-depth
high-water marks, and refill/chunk counters — the numbers the serve
benchmark and CI smoke legs grep for.

**Fault tolerance** (DESIGN.md §11). ``submit`` never raises for a
solvable request and every accepted request reaches exactly one terminal
result; every fault site now fires under the worker that owns the
dispatch:

  * intake validation (`buckets.validate_problem`) rejects poison
    (non-finite data, non-positive weights, bad shapes) into an
    immediate dead-letter result instead of a queue slot;
  * duplicate tags — user-supplied, or an auto tag colliding with a
    still-pending one — raise ``ValueError`` at submit, the one case
    that IS a caller bug: silently overwriting ``_results`` loses a
    previous request's answer;
  * each dispatch attempt runs under retry with capped exponential
    backoff (transient failures heal); in drain mode a group that keeps
    failing is bisected to isolate the poison instance; in continuous
    mode the admission of each request is its own retry unit, so a
    poison admission dead-letters alone without any bisection;
  * a slot the batched engine flags ``diverged`` (NaN probe — the
    on-device guard froze it at its last finite iterate) dead-letters
    with ``error="diverged"`` rather than masquerading as a solve — in
    continuous mode it retires at the next chunk boundary while its
    co-resident slots keep sweeping unperturbed;
  * an optional ``faults`` injector (`serve.faults.FaultInjector`) is
    polled once per dispatch *attempt* — under the worker thread — the
    deterministic chaos source the end-to-end tests replay from a seed.
"""

from __future__ import annotations

import dataclasses
import queue as queue_mod
import threading
import time
from typing import Any, Callable

import numpy as np

from repro.core.problems import MetricQP
from repro.serve import buckets as bk

__all__ = ["BatchScheduler", "ServeFuture", "SolveRequest"]

#: Sentinel handed to a worker queue to stop the thread (close()).
_SHUTDOWN = object()


class ServeFuture:
    """Handle for one submitted request, resolved with the request's
    terminal result dict (solved OR dead-letter — exactly one of the
    two, the §11 invariant).

    Compares and hashes as its ``tag``, so code written against the old
    tag-returning ``submit`` — ``results()[submit(p)]``, set/dict
    membership — keeps working unchanged.
    """

    __slots__ = ("tag", "_event", "_result")

    def __init__(self, tag):
        self.tag = tag
        self._event = threading.Event()
        self._result = None

    def _resolve(self, result: dict) -> None:
        self._result = result
        self._event.set()

    def done(self) -> bool:
        return self._event.is_set()

    def result(self, timeout: float | None = None) -> dict:
        """Block until the terminal result lands (or ``timeout``)."""
        if not self._event.wait(timeout):
            raise TimeoutError(
                f"request {self.tag!r} not finished within {timeout}s"
            )
        return self._result

    def __eq__(self, other):
        if isinstance(other, ServeFuture):
            return other.tag == self.tag
        return other == self.tag

    def __hash__(self):
        return hash(self.tag)

    def __repr__(self):
        return f"ServeFuture({self.tag!r}, done={self.done()})"


@dataclasses.dataclass
class SolveRequest:
    """One queued instance. ``tag`` is the caller's correlation key;
    ``in_flight`` flips when the request is handed to a worker (the
    ``results()`` barrier counts exactly these)."""

    problem: MetricQP
    tag: Any = None
    t_submit: float = 0.0
    bucket_n: int = 0
    in_flight: bool = False


class BatchScheduler:
    """Async collect-up-to-B-or-deadline micro-batcher with an optional
    continuous-batching dispatch loop (see module docstring).

    Args:
      ladder: bucket sizes (sorted ascending is not required).
      batch: instance slots per batched solve.
      mode: ``"drain"`` (whole-batch dispatch) or ``"continuous"``
        (slot-level refill at chunk boundaries, DESIGN.md §12).
      deadline_s: drain mode — max age of the oldest queued request
        before a partial batch is dispatched anyway (0 = only ``drain``
        flushes partials). Continuous mode admits requests to free slots
        immediately, so the deadline never applies.
      cache: shared ``SolverCache`` (one per process is the right scope;
        pass your own to share compiled runners across schedulers).
      dtype: compute dtype of the batched solvers.
      sharded_mesh: mesh for the above-ladder sharded route (default: a
        1-D 'solver' mesh over every visible device, built lazily on the
        first big instance).
      sharded_num_buckets: diagonal buckets of the sharded solvers.
      prewarm: optionally a ``Family`` — ``warmup(prewarm)`` runs at
        construction, compiling the configured ladder before traffic.
      use_kernel: route BOTH dispatch paths through the gen-3 Pallas
        megakernel (DESIGN.md §10) — the batched route via
        ``BatchedSolver(use_kernel=True)``, the above-ladder route via
        ``ShardedSolver(use_kernel=True)``. Ignored when ``cache`` is
        passed explicitly (the cache's own solver kwargs win on the
        batched route).
      max_retries: dispatch attempts beyond the first before a group is
        bisected / an admission dead-letters (transient-failure budget).
      backoff_s / backoff_cap_s: initial / maximum retry backoff; the
        delay doubles per retry and is served by ``sleep`` (injectable —
        tests pass a recording stub, so retry tests take zero wall
        time).
      faults: optional ``serve.faults.FaultInjector`` polled once per
        dispatch attempt (the ``dispatch`` injection site), under the
        worker thread that owns the dispatch.
      solve_kwargs: forwarded to ``run_until`` on both routes (tol,
        max_passes, check_every, stop_rule, residual_history).
    """

    def __init__(
        self,
        ladder=bk.DEFAULT_LADDER,
        batch: int = 8,
        deadline_s: float = 0.0,
        cache: bk.SolverCache | None = None,
        dtype=np.float32,
        clock: Callable[[], float] = time.monotonic,
        sharded_mesh=None,
        sharded_num_buckets: int = 6,
        prewarm: bk.Family | None = None,
        use_kernel: bool = False,
        max_retries: int = 2,
        backoff_s: float = 0.05,
        backoff_cap_s: float = 1.0,
        sleep: Callable[[float], None] = time.sleep,
        faults=None,
        mode: str = "drain",
        **solve_kwargs,
    ):
        if mode not in ("drain", "continuous"):
            raise ValueError(
                f"unknown mode {mode!r}; expected 'drain' or 'continuous'"
            )
        self.ladder = tuple(ladder)
        self.batch = int(batch)
        self.mode = mode
        self.deadline_s = float(deadline_s)
        self.use_kernel = bool(use_kernel)
        self.cache = (
            cache
            if cache is not None
            else bk.SolverCache(use_kernel=self.use_kernel)
        )
        self.dtype = dtype
        self.clock = clock
        self.solve_kwargs = solve_kwargs
        self.sharded_num_buckets = int(sharded_num_buckets)
        self._mesh = sharded_mesh
        self.max_retries = max(0, int(max_retries))
        self.backoff_s = float(backoff_s)
        self.backoff_cap_s = float(backoff_cap_s)
        self._sleep = sleep
        self.faults = faults
        # All mutable scheduler state below is guarded by _lock; _flush
        # is notified whenever an in-flight request reaches its terminal
        # result (the results()/stats() barrier).
        self._lock = threading.RLock()
        self._flush = threading.Condition(self._lock)
        self._in_flight = 0
        self._queues: dict[tuple[int, bk.Family], list[SolveRequest]] = {}
        self._results: dict[Any, dict] = {}
        self._futures: dict[Any, ServeFuture] = {}
        self._pending_tags: set = set()
        self._seq = 0
        self._instances_done = 0
        self._batches_run = 0
        self._slots_run = 0
        self._solve_time = 0.0
        self._sharded_done = 0
        self._sharded_time = 0.0
        self._retries = 0
        self._dead_letters = 0
        self._validation_rejects = 0
        self._queue_hwm: dict = {}
        # per-bucket dual-sparsity accumulator: bucket_n -> [sum of
        # active-constraint fractions, slots sampled] (DESIGN.md §13 —
        # the signal the sparsifier acts on, surfaced per landed slot).
        self._dual_sparsity: dict = {}
        self._refills = 0
        self._chunks_run = 0
        self._occupied_chunks = 0
        # worker threads: one per (bucket_n, family) slot + one sharded,
        # created lazily on the first request that routes to them.
        self._workers: dict = {}
        self._sharded_q = None
        self._closed = False
        # compile-warmth bookkeeping: a dispatch is "warm" when its
        # (bucket_n, batch, family) runner was compiled before it —
        # by warmup() or by an earlier batch of the same slot.
        self._compiled: set = set()
        self._prewarmed: set = set()
        self._warm_dispatches = 0
        self._cold_dispatches = 0
        if prewarm is not None:
            self.warmup(prewarm)

    # ------------------------------------------------------------- intake
    def submit(self, problem: MetricQP, tag: Any = None) -> ServeFuture:
        """Queue one instance; returns its ``ServeFuture`` immediately —
        never blocks on a solve, bucketed or sharded. The future (which
        compares as its tag) resolves with the terminal result.

        A duplicate tag (still pending, or already holding a result)
        raises ``ValueError`` — accepting it would silently overwrite
        the earlier request's result. Everything else terminates in a
        result: invalid problem data dead-letter at intake
        (``route="failed"``, ``error="validation"``), solver failures
        dead-letter after retry/bisection under the worker — ``submit``
        itself never raises past intake."""
        with self._lock:
            if self._closed:
                raise RuntimeError("scheduler is closed")
            if tag is None:
                tag, self._seq = f"req-{self._seq}", self._seq + 1
            if tag in self._pending_tags or tag in self._results:
                raise ValueError(
                    f"duplicate tag {tag!r}: a request with this tag is "
                    "already pending or has an unclaimed result"
                )
            fut = self._futures[tag] = ServeFuture(tag)
            req = SolveRequest(
                problem=problem,
                tag=tag,
                t_submit=self.clock(),
                bucket_n=problem.n,
            )
            try:
                bk.validate_problem(problem)
            except bk.ValidationError as e:
                self._validation_rejects += 1
                self._dead_letter(req, "validation", e)
                return fut
            bucket_n = bk.route_for(problem.n, self.ladder)
            self._pending_tags.add(tag)
            if bucket_n is None:
                self._hand_to_sharded(req)
                return fut
            req.bucket_n = bucket_n
            key = (req.bucket_n, bk.family_of(problem, self.dtype))
            if self.mode == "continuous":
                self._hand_to_continuous(key, req)
                return fut
            self._queues.setdefault(key, []).append(req)
            self._note_depth(req.bucket_n, len(self._queues[key]))
            if len(self._queues[key]) >= self.batch:
                self._dispatch(key)
        return fut

    def future(self, tag) -> ServeFuture:
        """The future of a submitted request (KeyError if unknown)."""
        with self._lock:
            return self._futures[tag]

    def _note_depth(self, bucket, depth: int) -> None:
        if depth > self._queue_hwm.get(bucket, 0):
            self._queue_hwm[bucket] = depth

    # ------------------------------------------------------------- warmup
    def warmup(self, family: bk.Family, buckets=None) -> dict:
        """Pre-compile the batched runner for every ladder rung of one
        problem family (DESIGN.md §8): an all-empty batch is pushed
        through the REAL ``run_until`` with ``max_passes=0`` — the jitted
        while_loop compiles fully and exits at pass 0 — under exactly the
        solve kwargs real dispatches use, so the compile-cache key
        matches by construction. In continuous mode the chunk stepper
        and the refill merge compile too (one empty chunk + one
        empty-mask refill through the real jitted programs). Later real
        batches of these slots dispatch warm. Returns
        ``{bucket_n: seconds}``.
        """
        from repro.serve.batching import ContinuousBatcher

        timings = {}
        for bucket_n in sorted(set(int(b) for b in (buckets or self.ladder))):
            t0 = self.clock()
            with self._lock:
                solver = self.cache.get(bucket_n, self.batch, family)
            solver.run_until(
                solver.stack([]), **{**self.solve_kwargs, "max_passes": 0}
            )
            if self.mode == "continuous":
                import jax.numpy as jnp

                cb = ContinuousBatcher(solver, **self.solve_kwargs)
                cb.step()  # compiles the chunk stepper (both cond arms)
                solver._refill_fn()(
                    cb.carry, cb.inst, solver.stack([]),
                    jnp.asarray(np.zeros((self.batch,), bool)),
                )
            key = (bucket_n, self.batch, family)
            with self._lock:
                self._compiled.add(key)
                self._prewarmed.add(key)
            timings[bucket_n] = self.clock() - t0
        return timings

    @property
    def pending(self) -> int:
        """Requests accepted but not yet handed to a solve — drain-mode
        queue depth plus continuous-mode not-yet-admitted depth. In-
        flight work is NOT pending (it no longer needs poll/drain)."""
        with self._lock:
            n = sum(len(q) for q in self._queues.values())
            for w in self._workers.values():
                if w.get("kind") == "continuous":
                    n += w["queue"].qsize()
            return n

    def poll(self) -> None:
        """Drain mode: dispatch every bucket whose oldest request is past
        deadline. With ``deadline_s == 0`` partial batches wait for
        ``drain()`` (the documented contract); only full buckets dispatch
        eagerly. Continuous mode admits eagerly — poll is a no-op."""
        if self.deadline_s <= 0 or self.mode == "continuous":
            return
        with self._lock:
            now = self.clock()
            for key, q in list(self._queues.items()):
                if q and now - q[0].t_submit >= self.deadline_s:
                    self._dispatch(key)

    def drain(self) -> dict[Any, dict]:
        """Flush all partial batches, wait for every in-flight request to
        land, and return every finished result."""
        with self._lock:
            for key in list(self._queues):
                while self._queues.get(key):
                    self._dispatch(key)
        return self.results()

    def results(self) -> dict[Any, dict]:
        """Terminal results so far, as ``{tag: result}`` — a sync point:
        waits for all in-flight work to land first (queued partials in
        drain mode are not in flight; ``drain()`` flushes those)."""
        with self._flush:
            self._flush.wait_for(lambda: self._in_flight == 0)
            return dict(self._results)

    def close(self) -> None:
        """Stop every worker thread (idempotent; in-flight work finishes
        first — workers see the sentinel after their current item)."""
        with self._lock:
            if self._closed:
                return
            self._closed = True
            workers = list(self._workers.values())
        for w in workers:
            w["queue"].put(_SHUTDOWN)
        for w in workers:
            w["thread"].join(timeout=60.0)

    # ------------------------------------------------------- fault handling
    def _dead_letter(self, req: SolveRequest, error: str, exc: Exception):
        """Terminal failure result: the request's tag still resolves, with
        a typed error instead of an iterate (DESIGN.md §11)."""
        now = self.clock()
        result = {
            "x": None,
            "x_pad": None,
            "f": None,
            "n": req.problem.n,
            "bucket_n": req.bucket_n,
            "route": "failed",
            "error": error,
            "error_type": type(exc).__name__,
            "error_detail": str(exc),
            "passes": 0,
            "converged": False,
            "wait_s": max(0.0, now - req.t_submit),
            "solve_s": 0.0,
            "latency_s": max(0.0, now - req.t_submit),
        }
        with self._flush:
            self._dead_letters += 1
            self._finish(req, result)

    def _finish(self, req: SolveRequest, result: dict) -> None:
        """Land one terminal result (caller holds the lock): resolve the
        future, retire the tag, release the results() barrier."""
        self._results[req.tag] = result
        self._pending_tags.discard(req.tag)
        fut = self._futures.get(req.tag)
        if fut is not None:
            fut._resolve(result)
        if req.in_flight:
            req.in_flight = False
            self._in_flight -= 1
            self._flush.notify_all()

    def _poll_faults(self, reqs: list[SolveRequest]) -> dict:
        """Poll the ``dispatch`` injection site once per solve attempt —
        from the worker thread that owns the dispatch. Raises
        ``InjectedFault`` for a due dispatch_error (the retry loop then
        eats it like any real dispatch exception); returns a
        {tag: poisoned_problem} override map for due nan_poison specs —
        corruption past the intake gate, which must surface as a
        per-slot divergence, never as a batch loss."""
        if self.faults is None:
            return {}
        from repro.serve import faults as flt

        overrides: dict = {}
        tags = [r.tag for r in reqs]
        for spec in self.faults.poll("dispatch", tags=tags):
            if spec.kind == "dispatch_error":
                raise flt.InjectedFault(f"injected dispatch error ({spec.spec_str()})")
            if spec.kind == "nan_poison":
                tag = spec.payload.get("tag", tags[0])
                for r in reqs:
                    if r.tag == tag:
                        overrides[tag] = flt.poison_problem(r.problem)
            elif spec.kind == "straggler":
                self._sleep(float(spec.payload.get("seconds", 0.001)))
        return overrides

    def _with_retries(self, attempt: Callable[[], Any]) -> Any:
        """Run one dispatch attempt under retry with capped exponential
        backoff. Each retry re-polls the fault site (transient injected
        faults heal exactly like transient real ones)."""
        delay = self.backoff_s
        failures = 0
        while True:
            try:
                return attempt()
            except Exception:
                failures += 1
                if failures > self.max_retries:
                    raise
                with self._lock:
                    self._retries += 1
                self._sleep(min(delay, self.backoff_cap_s))
                delay *= 2.0

    # ------------------------------------------------------ worker plumbing
    def _spawn_worker(self, name: str, kind: str, target, key=None) -> dict:
        q: queue_mod.Queue = queue_mod.Queue()
        worker = {"queue": q, "kind": kind, "key": key}
        t = threading.Thread(
            target=target, args=(q,), name=name, daemon=True
        )
        worker["thread"] = t
        t.start()
        return worker

    def _hand_to_sharded(self, req: SolveRequest) -> None:
        """Hand an above-ladder request to the background sharded worker
        (caller holds the lock) — the caller never blocks on the solve."""
        if "sharded" not in self._workers:
            self._workers["sharded"] = self._spawn_worker(
                "serve-sharded", "sharded", self._sharded_worker
            )
        req.in_flight = True
        self._in_flight += 1
        w = self._workers["sharded"]
        w["queue"].put(req)
        self._note_depth("sharded", w["queue"].qsize())

    def _hand_to_continuous(self, key, req: SolveRequest) -> None:
        """Hand a bucket request to its slot's continuous worker (caller
        holds the lock)."""
        if key not in self._workers:
            self._workers[key] = self._spawn_worker(
                f"serve-cont-{key[0]}", "continuous",
                lambda q, k=key: self._continuous_worker(k, q), key=key,
            )
        req.in_flight = True
        self._in_flight += 1
        w = self._workers[key]
        w["queue"].put(req)
        self._note_depth(key[0], w["queue"].qsize())

    # ------------------------------------------------- drain-mode dispatch
    def _dispatch(self, key) -> None:
        """Pop a batch off one bucket queue and hand it to the slot's
        worker (caller holds the lock)."""
        bucket_n, family = key
        q = self._queues.get(key, [])
        reqs, self._queues[key] = q[: self.batch], q[self.batch:]
        if not reqs:
            return
        ckey = (bucket_n, self.batch, family)
        if ckey in self._compiled:
            self._warm_dispatches += 1
        else:
            self._cold_dispatches += 1
            self._compiled.add(ckey)
        if key not in self._workers:
            self._workers[key] = self._spawn_worker(
                f"serve-batch-{bucket_n}", "drain",
                lambda jq, k=key: self._batch_worker(k, jq), key=key,
            )
        for r in reqs:
            r.in_flight = True
        self._in_flight += len(reqs)
        self._workers[key]["queue"].put(reqs)

    def _batch_worker(self, key, jobs: queue_mod.Queue) -> None:
        """Drain-mode worker loop for one (bucket_n, family) slot: each
        job is one request group, solved with the retry/bisect/dead-
        letter ladder. A worker crash never strands a request — the
        catch-all dead-letters the whole group (terminal-result
        invariant)."""
        bucket_n, family = key
        while True:
            item = jobs.get()
            if item is _SHUTDOWN:
                return
            reqs = item
            try:
                with self._lock:
                    solver = self.cache.get(bucket_n, self.batch, family)
                self._solve_group(solver, bucket_n, reqs)
            except BaseException as e:  # defensive: never strand a tag
                for r in reqs:
                    if r.tag not in self._results:
                        self._dead_letter(r, "dispatch", e)

    def _solve_group(self, solver, bucket_n: int, reqs: list[SolveRequest]):
        """Solve a request group with retry; on persistent failure bisect
        to isolate the poison instance — healthy halves land normally,
        the failing singleton dead-letters. Worst case (one bad instance
        in a batch of B) costs O(log B) extra sub-batch solves, each a
        warm dispatch of the already-compiled runner."""
        try:
            out = self._with_retries(
                lambda: self._attempt_batch(solver, reqs)
            )
        except Exception as e:
            if len(reqs) == 1:
                self._dead_letter(
                    reqs[0],
                    "injected" if type(e).__name__ == "InjectedFault"
                    else "dispatch",
                    e,
                )
                return
            mid = len(reqs) // 2
            self._solve_group(solver, bucket_n, reqs[:mid])
            self._solve_group(solver, bucket_n, reqs[mid:])
            return
        self._land_batch(bucket_n, reqs, *out)

    def _attempt_batch(self, solver, reqs: list[SolveRequest]):
        """One solve attempt of a request group (the retry unit)."""
        overrides = self._poll_faults(reqs)
        inst = solver.stack(
            [overrides.get(r.tag, r.problem) for r in reqs]
        )
        t0 = self.clock()
        state, info = solver.run_until(inst, **self.solve_kwargs)
        x = np.asarray(state.x)  # one host copy; also blocks for the timing
        dt = self.clock() - t0
        dstats = solver.dual_stats(state, inst)
        return state, info, x, t0, dt, dstats

    @staticmethod
    def _dual_fraction(active_count: float, n: int) -> float:
        """Active-constraint fraction of one slot: nonzero triangle duals
        over the instance's 3·C(n, 3) real constraints (n < 3 has none)."""
        total = n * (n - 1) * (n - 2) // 2  # 3 * C(n, 3)
        return float(active_count) / total if total else 0.0

    def _record_dual_sparsity(self, bucket_n: int, fracs) -> None:
        acc = self._dual_sparsity.setdefault(bucket_n, [0.0, 0])
        for f in fracs:
            acc[0] += f
            acc[1] += 1

    def _land_batch(self, bucket_n, reqs, state, info, x, t0, dt,
                    dstats) -> None:
        f = None if state.f is None else np.asarray(state.f)
        diverged = info.get("diverged")
        with self._flush:
            self._solve_time += dt
            self._batches_run += 1
            self._slots_run += self.batch
            self._record_dual_sparsity(bucket_n, [
                self._dual_fraction(
                    dstats["active_constraints"][i], r.problem.n
                )
                for i, r in enumerate(reqs)
            ])
            for i, r in enumerate(reqs):
                if diverged is not None and bool(diverged[i]):
                    # the on-device guard froze this slot at its last
                    # finite iterate; its result is a typed failure, not
                    # a solve.
                    self._dead_letters += 1
                    self._finish(r, self._diverged_result(r, bucket_n))
                    continue
                n = r.problem.n
                self._instances_done += 1
                now = self.clock()
                self._finish(r, {
                    "x": x[i, :n, :n],
                    "x_pad": x[i],  # padded iterate (ghost-aware rounding)
                    "f": None if f is None else f[i, :n, :n],
                    "n": n,
                    "bucket_n": bucket_n,
                    "route": "batch",
                    "passes": int(info["passes"][i]),
                    "converged": bool(info["converged"][i]),
                    "max_violation": float(info["max_violation"][i]),
                    "duality_gap": float(info["duality_gap"][i]),
                    "lp_objective": float(info["lp_objective"][i]),
                    "qp_objective": float(info["qp_objective"][i]),
                    "wait_s": max(0.0, t0 - r.t_submit),
                    "solve_s": dt,
                    "latency_s": max(0.0, now - r.t_submit),
                })

    def _diverged_result(self, req: SolveRequest, bucket_n: int) -> dict:
        now = self.clock()
        exc = ArithmeticError(
            "residual probe went non-finite; slot frozen at "
            "its last finite iterate by the divergence guard"
        )
        return {
            "x": None,
            "x_pad": None,
            "f": None,
            "n": req.problem.n,
            "bucket_n": bucket_n,
            "route": "failed",
            "error": "diverged",
            "error_type": type(exc).__name__,
            "error_detail": str(exc),
            "passes": 0,
            "converged": False,
            "wait_s": max(0.0, now - req.t_submit),
            "solve_s": 0.0,
            "latency_s": max(0.0, now - req.t_submit),
        }

    # ------------------------------------------- continuous-mode dispatch
    def _continuous_worker(self, key, q: queue_mod.Queue) -> None:
        """Continuous-batching worker loop for one (bucket_n, family)
        slot (DESIGN.md §12): a long-lived ``ContinuousBatcher`` sweeps a
        mixed-age batch chunk by chunk; freed slots refill from the queue
        at every chunk boundary. Admission is the per-request fault/retry
        unit; a chunk that keeps failing dead-letters the live slots and
        restarts the batcher with a fresh carry (terminal-result
        invariant)."""
        from repro.serve.batching import ContinuousBatcher

        bucket_n, family = key
        with self._lock:
            solver = self.cache.get(bucket_n, self.batch, family)
            ckey = (bucket_n, self.batch, family)
            if ckey in self._compiled:
                self._warm_dispatches += 1
            else:
                self._cold_dispatches += 1
                self._compiled.add(ckey)
        batcher = ContinuousBatcher(solver, **self.solve_kwargs)
        live_reqs: dict = {}  # tag -> (SolveRequest, t_admit)
        item = None
        while True:
            if item is None and not batcher.live:
                item = q.get()  # idle: block for traffic (or shutdown)
            if item is _SHUTDOWN:
                return
            # ---- refill every free slot from the queue
            assignments = []
            free = batcher.free_slots()
            while free:
                if item is None:
                    try:
                        item = q.get_nowait()
                    except queue_mod.Empty:
                        break
                    if item is _SHUTDOWN:
                        break
                req, item = item, None
                problem = self._admit_request(req)
                if problem is None:
                    continue  # dead-lettered at admission
                slot = free.pop(0)
                assignments.append((slot, problem, req.tag))
                live_reqs[req.tag] = (req, self.clock())
            try:
                batcher.admit(assignments)
                if not batcher.live:
                    continue
                # ---- one convergence chunk + harvest, under retry
                t0 = self.clock()
                self._with_retries(batcher.step)
                dt = self.clock() - t0
                with self._lock:
                    self._solve_time += dt
                    self._chunks_run += 1
                    self._occupied_chunks += batcher.occupied
                    self._refills = max(self._refills, 0)
                harvested = batcher.harvest()
            except BaseException as e:
                # the chunk itself keeps failing (device error, injected
                # chaos past the retry budget): every live slot dead-
                # letters and the batcher restarts with a fresh carry.
                for tag in list(batcher.tags):
                    if tag is None or tag not in live_reqs:
                        continue
                    req, _ = live_reqs.pop(tag)
                    self._dead_letter(
                        req,
                        "injected" if type(e).__name__ == "InjectedFault"
                        else "dispatch",
                        e,
                    )
                batcher = ContinuousBatcher(solver, **self.solve_kwargs)
                continue
            with self._lock:
                self._refills += len(assignments)
            if harvested:
                dstats = batcher.solver.dual_stats(
                    batcher.carry.state, batcher.inst
                )
                with self._flush:
                    self._record_dual_sparsity(bucket_n, [
                        self._dual_fraction(
                            dstats["active_constraints"][slot], info["n"]
                        )
                        for slot, _, _, _, info in harvested
                    ])
            for slot, tag, x_row, f_row, info in harvested:
                req, t_admit = live_reqs.pop(tag)
                self._land_slot(req, bucket_n, x_row, f_row, info, t_admit)

    def _admit_request(self, req: SolveRequest):
        """Admission gate of one request into a freed slot: the dispatch
        fault site + retry ladder, per request (continuous mode's retry
        unit — no bisection needed, a poison admission fails alone).
        Returns the (possibly nan_poison-overridden) problem, or None
        after dead-lettering."""

        def attempt():
            overrides = self._poll_faults([req])
            return overrides.get(req.tag, req.problem)

        try:
            return self._with_retries(attempt)
        except Exception as e:
            self._dead_letter(
                req,
                "injected" if type(e).__name__ == "InjectedFault"
                else "dispatch",
                e,
            )
            return None

    def _land_slot(self, req, bucket_n, x_row, f_row, info, t_admit):
        """Land one harvested slot (continuous mode): same result dict as
        a drain-mode batch slot, with per-request wait/solve split at its
        own admission time."""
        if info["diverged"]:
            with self._flush:
                self._dead_letters += 1
                self._finish(req, self._diverged_result(req, bucket_n))
            return
        n = req.problem.n
        now = self.clock()
        with self._flush:
            self._instances_done += 1
            self._slots_run += 1
            self._finish(req, {
                "x": x_row[:n, :n],
                "x_pad": x_row,
                "f": None if f_row is None else f_row[:n, :n],
                "n": n,
                "bucket_n": bucket_n,
                "route": "batch",
                "passes": info["passes"],
                "converged": info["converged"],
                "max_violation": info["max_violation"],
                "duality_gap": info["duality_gap"],
                "lp_objective": info["lp_objective"],
                "qp_objective": info["qp_objective"],
                "wait_s": max(0.0, t_admit - req.t_submit),
                "solve_s": max(0.0, now - t_admit),
                "latency_s": max(0.0, now - req.t_submit),
            })

    # -------------------------------------------------- sharded dispatch
    def _sharded_worker(self, jobs: queue_mod.Queue) -> None:
        """Above-ladder worker loop: one request per job, solved at its
        NATIVE n with ``ShardedSolver.run_until`` (DESIGN.md §9) — the
        route that used to block the caller inside ``submit`` and now
        runs behind the same future plumbing as every bucket slot."""
        while True:
            item = jobs.get()
            if item is _SHUTDOWN:
                return
            try:
                self._dispatch_sharded(item)
            except BaseException as e:  # defensive: never strand a tag
                if item.tag not in self._results:
                    self._dead_letter(item, "dispatch", e)

    def _dispatch_sharded(self, req: SolveRequest) -> None:
        """Solve one above-ladder instance (in the sharded worker): same
        stop rule and info/certificate plumbing as a batch slot, no ghost
        padding (``x_pad`` is the native iterate, ``bucket_n = n``, so
        the pipeline's ghost-aware device rounding degrades to plain
        device rounding). Same failure contract too: retry with backoff,
        then a dead-letter result; a diverged solve dead-letters."""
        from repro.core.sharded_dykstra import ShardedSolver

        def attempt():
            overrides = self._poll_faults([req])
            solver = ShardedSolver(
                overrides.get(req.tag, req.problem), self._solver_mesh(),
                dtype=self.dtype,
                num_buckets=self.sharded_num_buckets,
                use_kernel=self.use_kernel,
            )
            t0 = self.clock()
            state, info = solver.run_until(**self._sharded_kwargs())
            x = np.asarray(state.x)  # host copy; also blocks for the timing
            return state, info, x, t0

        try:
            state, info, x, t0 = self._with_retries(attempt)
        except Exception as e:
            self._dead_letter(
                req,
                "injected" if type(e).__name__ == "InjectedFault"
                else "dispatch",
                e,
            )
            return
        if info.get("diverged"):
            self._dead_letter(
                req, "diverged",
                ArithmeticError(
                    "residual probe went non-finite; sharded solve "
                    "stopped at its last finite chunk boundary"
                ),
            )
            return
        dt = self.clock() - t0
        n = req.problem.n
        now = self.clock()
        with self._flush:
            self._solve_time += dt
            self._sharded_time += dt
            self._sharded_done += 1
            self._instances_done += 1
            self._finish(req, {
                "x": x,
                "x_pad": x,
                "f": None if state.f is None else np.asarray(state.f),
                "n": n,
                "bucket_n": n,
                "route": "sharded",
                "passes": int(info["passes"]),
                "converged": bool(info["converged"]),
                "max_violation": float(info["max_violation"]),
                "duality_gap": float(info["duality_gap"]),
                "lp_objective": float(info["lp_objective"]),
                "qp_objective": float(info["qp_objective"]),
                "wait_s": max(0.0, t0 - req.t_submit),
                "solve_s": dt,
                "latency_s": max(0.0, now - req.t_submit),
            })

    def _sharded_kwargs(self) -> dict:
        """run_until kwargs for the sharded route — the batched solver's
        residual_history knob does not exist there."""
        kw = dict(self.solve_kwargs)
        kw.pop("residual_history", None)
        return kw

    def _solver_mesh(self):
        with self._lock:
            if self._mesh is None:
                from repro.launch import mesh as mesh_lib

                self._mesh = mesh_lib.make_solver_mesh()
            return self._mesh

    # -------------------------------------------------------------- stats
    def stats(self) -> dict:
        """Throughput / occupancy / queue / compile-cache / warmth
        counters. A sync point like ``results()``: waits for in-flight
        work, so the numbers describe completed dispatches.

        ``occupancy`` is mode-dependent: drain mode reports real
        instances per dispatched batch slot (how full the batches were);
        continuous mode reports occupied slots per swept chunk slot (how
        busy the long-lived batch stayed under refill — the sustained-
        load benchmark's headline). ``queue_depth_hwm`` is the per-bucket
        high-water mark of the waiting queue depth (key "sharded" for the
        above-ladder queue); ``refills`` counts slot admissions by the
        continuous loop, ``chunks_run`` its chunk steps.
        ``dual_sparsity`` maps bucket_n → mean active-constraint fraction
        (``BatchedSolver.dual_stats`` nonzero triangle duals over the
        instance's 3·C(n,3)) across landed slots — the signal the
        Project-and-Forget sparsifier acts on (DESIGN.md §13), and a
        capacity-planning proxy for how constrained a bucket's traffic
        runs."""
        with self._flush:
            self._flush.wait_for(lambda: self._in_flight == 0)
            if self.mode == "continuous":
                occupancy = (
                    self._occupied_chunks / (self._chunks_run * self.batch)
                    if self._chunks_run else 0.0
                )
            else:
                occupancy = (
                    (self._instances_done - self._sharded_done)
                    / self._slots_run
                    if self._slots_run else 0.0
                )
            return {
                "mode": self.mode,
                "instances_done": self._instances_done,
                "batches_run": self._batches_run,
                "pending": self.pending,
                "occupancy": occupancy,
                "solve_time_s": self._solve_time,
                "throughput_ips": (
                    self._instances_done / self._solve_time
                    if self._solve_time > 0 else 0.0
                ),
                "sharded_done": self._sharded_done,
                "sharded_time_s": self._sharded_time,
                "queue_depth_hwm": dict(self._queue_hwm),
                "dual_sparsity": {
                    b: acc[0] / acc[1]
                    for b, acc in sorted(self._dual_sparsity.items())
                    if acc[1]
                },
                "refills": self._refills,
                "chunks_run": self._chunks_run,
                "compile_cache": self.cache.stats(),
                "prewarm": {
                    "buckets": len(self._prewarmed),
                    "warm_dispatches": self._warm_dispatches,
                    "cold_dispatches": self._cold_dispatches,
                },
                "faults": {
                    "retries": self._retries,
                    "dead_letters": self._dead_letters,
                    "validation_rejects": self._validation_rejects,
                    "injected_fired": (
                        len(self.faults.fired)
                        if self.faults is not None else 0
                    ),
                },
            }

"""Micro-batching request scheduler for the batched solve service
(DESIGN.md §8/§9).

Requests (one MetricQP each, any size ``n``) are queued, routed to their
shape bucket, and dispatched as batches of up to ``batch`` instances. A
batch launches when its bucket has ``batch`` requests waiting (full) or
when the oldest waiting request has aged past ``deadline_s`` (a partial
batch padded with empty slots — latency wins over occupancy once the
deadline expires). ``drain()`` flushes everything regardless of age.

**Above-ladder instances** (n larger than the top rung) do not batch:
``submit`` routes them immediately to a dedicated
``ShardedSolver.run_until`` slot on the solver mesh (DESIGN.md §9) — the
same stop rule, the same result/certificate plumbing, results flagged
``route="sharded"``. Big instances bake their weights into the trace
(one compile each), which is the right trade at sizes where the solve
itself dwarfs the compile and batching would only serialize the mesh.

The scheduler owns a ``SolverCache``: the first batch of a
(bucket_n, batch, family) slot compiles the batched runner, every later
batch reuses it. ``warmup(family)`` pre-compiles the runner for every
configured ladder rung up front (an all-empty batch through the real
jitted while_loop, which exits at pass 0), so the first real batch of a
prewarmed slot dispatches warm. ``stats()`` reports the cache hit rate
and the warm/cold dispatch counts alongside throughput (instances/sec of
completed solves) and mean batch occupancy (real instances per slot),
the numbers the serve benchmark and CI smoke legs grep for.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import numpy as np

from repro.core.problems import MetricQP
from repro.serve import buckets as bk

__all__ = ["BatchScheduler", "SolveRequest"]


@dataclasses.dataclass
class SolveRequest:
    """One queued instance. ``tag`` is the caller's correlation key."""

    problem: MetricQP
    tag: Any = None
    t_submit: float = 0.0
    bucket_n: int = 0


class BatchScheduler:
    """Collect-up-to-B-or-deadline micro-batcher (see module docstring).

    Args:
      ladder: bucket sizes (sorted ascending is not required).
      batch: instance slots per batched solve.
      deadline_s: max age of the oldest queued request before a partial
        batch is dispatched anyway (0 = only ``drain`` flushes partials).
      cache: shared ``SolverCache`` (one per process is the right scope;
        pass your own to share compiled runners across schedulers).
      dtype: compute dtype of the batched solvers.
      sharded_mesh: mesh for the above-ladder sharded route (default: a
        1-D 'solver' mesh over every visible device, built lazily on the
        first big instance).
      sharded_num_buckets: diagonal buckets of the sharded solvers.
      prewarm: optionally a ``Family`` — ``warmup(prewarm)`` runs at
        construction, compiling the configured ladder before traffic.
      use_kernel: route BOTH dispatch paths through the gen-3 Pallas
        megakernel (DESIGN.md §10) — the batched route via
        ``BatchedSolver(use_kernel=True)``, the above-ladder route via
        ``ShardedSolver(use_kernel=True)``. Ignored when ``cache`` is
        passed explicitly (the cache's own solver kwargs win on the
        batched route).
      solve_kwargs: forwarded to ``run_until`` on both routes (tol,
        max_passes, check_every, stop_rule).
    """

    def __init__(
        self,
        ladder=bk.DEFAULT_LADDER,
        batch: int = 8,
        deadline_s: float = 0.0,
        cache: bk.SolverCache | None = None,
        dtype=np.float32,
        clock: Callable[[], float] = time.monotonic,
        sharded_mesh=None,
        sharded_num_buckets: int = 6,
        prewarm: bk.Family | None = None,
        use_kernel: bool = False,
        **solve_kwargs,
    ):
        self.ladder = tuple(ladder)
        self.batch = int(batch)
        self.deadline_s = float(deadline_s)
        self.use_kernel = bool(use_kernel)
        self.cache = (
            cache
            if cache is not None
            else bk.SolverCache(use_kernel=self.use_kernel)
        )
        self.dtype = dtype
        self.clock = clock
        self.solve_kwargs = solve_kwargs
        self.sharded_num_buckets = int(sharded_num_buckets)
        self._mesh = sharded_mesh
        self._queues: dict[tuple[int, bk.Family], list[SolveRequest]] = {}
        self._results: dict[Any, dict] = {}
        self._instances_done = 0
        self._batches_run = 0
        self._slots_run = 0
        self._solve_time = 0.0
        self._sharded_done = 0
        self._sharded_time = 0.0
        # compile-warmth bookkeeping: a dispatch is "warm" when its
        # (bucket_n, batch, family) runner was compiled before it —
        # by warmup() or by an earlier batch of the same slot.
        self._compiled: set = set()
        self._prewarmed: set = set()
        self._warm_dispatches = 0
        self._cold_dispatches = 0
        if prewarm is not None:
            self.warmup(prewarm)

    # ------------------------------------------------------------- intake
    def submit(self, problem: MetricQP, tag: Any = None) -> Any:
        """Queue one instance; returns its tag (auto-assigned if None).
        Full buckets dispatch immediately; **above-ladder** instances
        bypass the queue entirely and solve now on the sharded route."""
        if tag is None:
            tag = f"req-{self._instances_done + self.pending}"
        bucket_n = bk.route_for(problem.n, self.ladder)
        req = SolveRequest(
            problem=problem,
            tag=tag,
            t_submit=self.clock(),
            bucket_n=problem.n if bucket_n is None else bucket_n,
        )
        if bucket_n is None:
            self._dispatch_sharded(req)
            return tag
        key = (req.bucket_n, bk.family_of(problem, self.dtype))
        self._queues.setdefault(key, []).append(req)
        if len(self._queues[key]) >= self.batch:
            self._dispatch(key)
        return tag

    # ------------------------------------------------------------- warmup
    def warmup(self, family: bk.Family, buckets=None) -> dict:
        """Pre-compile the batched runner for every ladder rung of one
        problem family (DESIGN.md §8): an all-empty batch is pushed
        through the REAL ``run_until`` with ``max_passes=0`` — the jitted
        while_loop compiles fully and exits at pass 0 — under exactly the
        solve kwargs real dispatches use, so the compile-cache key
        matches by construction. Later real batches of these slots
        dispatch warm. Returns ``{bucket_n: seconds}``.
        """
        timings = {}
        for bucket_n in sorted(set(int(b) for b in (buckets or self.ladder))):
            t0 = self.clock()
            solver = self.cache.get(bucket_n, self.batch, family)
            solver.run_until(
                solver.stack([]), **{**self.solve_kwargs, "max_passes": 0}
            )
            key = (bucket_n, self.batch, family)
            self._compiled.add(key)
            self._prewarmed.add(key)
            timings[bucket_n] = self.clock() - t0
        return timings

    @property
    def pending(self) -> int:
        return sum(len(q) for q in self._queues.values())

    def poll(self) -> None:
        """Dispatch every bucket whose oldest request is past deadline.
        With ``deadline_s == 0`` partial batches wait for ``drain()``
        (the documented contract); only full buckets dispatch eagerly."""
        if self.deadline_s <= 0:
            return
        now = self.clock()
        for key, q in list(self._queues.items()):
            if q and now - q[0].t_submit >= self.deadline_s:
                self._dispatch(key)

    def drain(self) -> dict[Any, dict]:
        """Flush all partial batches and return every finished result."""
        for key in list(self._queues):
            while self._queues.get(key):
                self._dispatch(key)
        return self.results()

    def results(self) -> dict[Any, dict]:
        return dict(self._results)

    # ----------------------------------------------------------- dispatch
    def _dispatch(self, key) -> None:
        bucket_n, family = key
        q = self._queues.get(key, [])
        reqs, self._queues[key] = q[: self.batch], q[self.batch:]
        if not reqs:
            return
        ckey = (bucket_n, self.batch, family)
        if ckey in self._compiled:
            self._warm_dispatches += 1
        else:
            self._cold_dispatches += 1
            self._compiled.add(ckey)
        solver = self.cache.get(bucket_n, self.batch, family)
        inst = solver.stack([r.problem for r in reqs])
        t0 = self.clock()
        state, info = solver.run_until(inst, **self.solve_kwargs)
        x = np.asarray(state.x)  # one host copy; also blocks for the timing
        dt = self.clock() - t0
        self._solve_time += dt
        self._batches_run += 1
        self._slots_run += self.batch
        self._instances_done += len(reqs)
        f = None if state.f is None else np.asarray(state.f)
        for i, r in enumerate(reqs):
            n = r.problem.n
            self._results[r.tag] = {
                "x": x[i, :n, :n],
                "x_pad": x[i],  # padded iterate (ghost-aware device rounding)
                "f": None if f is None else f[i, :n, :n],
                "n": n,
                "bucket_n": bucket_n,
                "route": "batch",
                "passes": int(info["passes"][i]),
                "converged": bool(info["converged"][i]),
                "max_violation": float(info["max_violation"][i]),
                "duality_gap": float(info["duality_gap"][i]),
                "lp_objective": float(info["lp_objective"][i]),
                "qp_objective": float(info["qp_objective"][i]),
                "wait_s": max(0.0, t0 - r.t_submit),
                "solve_s": dt,
            }

    def _solver_mesh(self):
        if self._mesh is None:
            from repro.launch import mesh as mesh_lib

            self._mesh = mesh_lib.make_solver_mesh()
        return self._mesh

    def _dispatch_sharded(self, req: SolveRequest) -> None:
        """Above-ladder escape hatch (DESIGN.md §9): solve one instance at
        its NATIVE n with ``ShardedSolver.run_until`` on the solver mesh —
        same stop rule and info/certificate plumbing as a batch slot, no
        ghost padding (``x_pad`` is the native iterate, ``bucket_n = n``,
        so the pipeline's ghost-aware device rounding degrades to plain
        device rounding)."""
        from repro.core.sharded_dykstra import ShardedSolver

        solver = ShardedSolver(
            req.problem, self._solver_mesh(), dtype=self.dtype,
            num_buckets=self.sharded_num_buckets,
            use_kernel=self.use_kernel,
        )
        t0 = self.clock()
        state, info = solver.run_until(**self.solve_kwargs)
        x = np.asarray(state.x)  # one host copy; also blocks for the timing
        dt = self.clock() - t0
        self._solve_time += dt
        self._sharded_time += dt
        self._sharded_done += 1
        self._instances_done += 1
        n = req.problem.n
        self._results[req.tag] = {
            "x": x,
            "x_pad": x,
            "f": None if state.f is None else np.asarray(state.f),
            "n": n,
            "bucket_n": n,
            "route": "sharded",
            "passes": int(info["passes"]),
            "converged": bool(info["converged"]),
            "max_violation": float(info["max_violation"]),
            "duality_gap": float(info["duality_gap"]),
            "lp_objective": float(info["lp_objective"]),
            "qp_objective": float(info["qp_objective"]),
            "wait_s": max(0.0, t0 - req.t_submit),
            "solve_s": dt,
        }

    # -------------------------------------------------------------- stats
    def stats(self) -> dict:
        """Throughput / occupancy / compile-cache / warmth counters."""
        return {
            "instances_done": self._instances_done,
            "batches_run": self._batches_run,
            "pending": self.pending,
            "occupancy": (
                (self._instances_done - self._sharded_done) / self._slots_run
                if self._slots_run else 0.0
            ),
            "solve_time_s": self._solve_time,
            "throughput_ips": (
                self._instances_done / self._solve_time
                if self._solve_time > 0 else 0.0
            ),
            "sharded_done": self._sharded_done,
            "sharded_time_s": self._sharded_time,
            "compile_cache": self.cache.stats(),
            "prewarm": {
                "buckets": len(self._prewarmed),
                "warm_dispatches": self._warm_dispatches,
                "cold_dispatches": self._cold_dispatches,
            },
        }

"""Deterministic fault injection for the serve/solve runtime (DESIGN.md §11).

At the paper's headline scale (2.9e12 constraints) a solve runs for hours
across many devices; the failure model of `launch/elastic.py` only earns
its keep if every handling path — retry, batch isolation, divergence
guard, checkpoint walk-back, device-loss degradation — is exercised on
demand, deterministically, in CI. This module is the chaos source:

  * ``FaultSpec``     — one fault: kind × trigger site × fire-at-count
    (plus an optional payload, e.g. the poisoned request tag or the
    survivor device count).
  * ``FaultPlan``     — an immutable set of specs; built explicitly,
    parsed from a compact CLI string (``kind@site:at[:k=v,...]`` joined
    with ``;``), or drawn deterministically from a seed
    (``FaultPlan.seeded``) — the same seed always replays the same
    faults at the same counts.
  * ``FaultInjector`` — the runtime half: each hook site polls it once
    per event (``poll(site)`` advances that site's counter and returns
    the specs due now); what fired is recorded on ``injector.fired`` so
    a chaos test can assert the plan actually executed.

Hook sites (each polled by the layer that owns it):

  ``dispatch``      — ``BatchScheduler`` polls once per dispatch
                      *attempt* (so a retry advances the counter and a
                      transient fault heals). Kinds: ``dispatch_error``
                      (raise ``InjectedFault``), ``nan_poison`` (poison
                      one request's problem data past intake
                      validation), ``straggler`` (deterministic sleep).
  ``chunk``         — ``SolverRuntime.run_until`` polls once per
                      invocation (the host-visible chunk/window
                      boundary). Kinds: ``nan_poison`` (poison the live
                      iterate — the divergence guard must catch it on
                      device), ``straggler``.
  ``ckpt_save``     — ``train/checkpoint.save`` polls once per save,
                      after the staging write and before the atomic
                      commit. Kinds: ``ckpt_truncate`` / ``ckpt_corrupt``
                      (damage the staged arrays so the *committed*
                      checkpoint is corrupt — restore must detect it via
                      checksums and walk back), ``kill`` (``os._exit``
                      mid-save: the commit never happens, the previous
                      checkpoint must survive).
  ``ckpt_restore``  — ``train/checkpoint.restore`` polls once per
                      attempted step. Kind: ``ckpt_corrupt`` (report the
                      step corrupt without touching the bytes — a pure
                      read-path fault).
  ``mesh``          — the solve launcher polls once per ``run_until``
                      window when sharded. Kind: ``device_loss``
                      (payload ``p`` = survivor device count): the
                      launcher degrades to the survivor mesh via
                      ``elastic.degrade_solver`` and resumes.

Specs with a ``tag`` payload are *persistent*: they fire on every poll
whose ``tags`` context contains that tag once the counter reaches
``at`` — this is how one poisoned request keeps failing every retry
until bisection isolates it into a dead-letter result.

The module depends only on numpy/stdlib so every layer (core engine,
train checkpointing, launchers) can consume an injector duck-typed,
without importing the serve package at module scope.
"""

from __future__ import annotations

import dataclasses
import threading

import numpy as np

__all__ = [
    "KIND_SITES",
    "SITES",
    "FaultInjector",
    "FaultPlan",
    "FaultSpec",
    "InjectedFault",
    "parse_spec",
    "poison_problem",
]

#: Hook sites, in dispatch order of a typical serve/solve stack.
SITES = ("dispatch", "chunk", "ckpt_save", "ckpt_restore", "mesh")

#: Which sites each fault kind may fire at (also the seeded-plan domain).
KIND_SITES = {
    "dispatch_error": ("dispatch",),
    "nan_poison": ("dispatch", "chunk"),
    "straggler": ("dispatch", "chunk"),
    "ckpt_truncate": ("ckpt_save",),
    "ckpt_corrupt": ("ckpt_save", "ckpt_restore"),
    "device_loss": ("mesh",),
    "kill": ("ckpt_save",),
}

KINDS = tuple(KIND_SITES)


class InjectedFault(RuntimeError):
    """A deterministic injected failure (never raised by real faults)."""


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One fault: ``kind`` fires at ``site`` when that site's event
    counter reaches ``at``. ``payload`` carries kind-specific knobs
    (``tag`` makes the spec persistent and context-matched; ``p`` is
    the survivor device count of ``device_loss``; ``seconds`` the
    straggler sleep; ``fraction`` the truncation point)."""

    kind: str
    site: str
    at: int = 0
    payload: dict = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        if self.kind not in KIND_SITES:
            raise ValueError(f"unknown fault kind {self.kind!r}; one of {KINDS}")
        if self.site not in KIND_SITES[self.kind]:
            raise ValueError(
                f"kind {self.kind!r} cannot fire at site {self.site!r}; "
                f"allowed: {KIND_SITES[self.kind]}"
            )
        if self.at < 0:
            raise ValueError(f"fire-at count must be >= 0, got {self.at}")

    def spec_str(self) -> str:
        """Inverse of ``parse_spec``."""
        s = f"{self.kind}@{self.site}:{self.at}"
        if self.payload:
            s += ":" + ",".join(f"{k}={v}" for k, v in self.payload.items())
        return s


def _cast(v: str):
    for t in (int, float):
        try:
            return t(v)
        except ValueError:
            continue
    return v


def parse_spec(text: str) -> FaultSpec:
    """Parse one ``kind@site:at[:k=v,...]`` spec (the CLI grammar)."""
    head, _, rest = text.strip().partition("@")
    if not rest:
        raise ValueError(f"bad fault spec {text!r}: expected kind@site:at[:k=v,...]")
    parts = rest.split(":")
    site = parts[0]
    at = int(parts[1]) if len(parts) > 1 and parts[1] else 0
    payload = {}
    if len(parts) > 2 and parts[2]:
        for kv in parts[2].split(","):
            k, _, v = kv.partition("=")
            payload[k.strip()] = _cast(v.strip())
    return FaultSpec(kind=head.strip(), site=site.strip(), at=at, payload=payload)


class FaultPlan:
    """An immutable, replayable set of ``FaultSpec``s."""

    def __init__(self, specs=()):
        self.specs = tuple(
            s if isinstance(s, FaultSpec) else parse_spec(s) for s in specs
        )

    def __len__(self):
        return len(self.specs)

    def __iter__(self):
        return iter(self.specs)

    def __eq__(self, other):
        return isinstance(other, FaultPlan) and self.specs == other.specs

    def __add__(self, other: "FaultPlan") -> "FaultPlan":
        return FaultPlan(self.specs + tuple(other))

    def __repr__(self):
        return f"FaultPlan({'; '.join(s.spec_str() for s in self.specs)})"

    @classmethod
    def parse(cls, text: str) -> "FaultPlan":
        """Parse a ``;``-joined list of specs (the ``--inject`` CLI arg)."""
        return cls(
            parse_spec(tok) for tok in text.split(";") if tok.strip()
        )

    @classmethod
    def seeded(
        cls,
        seed: int,
        n_faults: int = 3,
        horizon: int = 6,
        kinds=None,
        sites=None,
    ) -> "FaultPlan":
        """Draw a deterministic random plan: same seed ⇒ same faults at
        the same counts, so any chaos failure replays exactly.

        ``kill`` is excluded by default (it terminates the host
        process); opt in via ``kinds``. ``horizon`` bounds the fire-at
        counts, so size it to the number of events the harness will
        actually generate per site.
        """
        rng = np.random.default_rng(seed)
        kinds = tuple(kinds) if kinds is not None else tuple(
            k for k in KINDS if k != "kill"
        )
        domain = [
            (k, s)
            for k in kinds
            for s in KIND_SITES[k]
            if sites is None or s in sites
        ]
        if not domain:
            raise ValueError("no (kind, site) pairs in the seeded domain")
        defaults = {
            "straggler": {"seconds": 0.001},
            "device_loss": {},
            "ckpt_truncate": {"fraction": 0.5},
        }
        specs = []
        for _ in range(int(n_faults)):
            kind, site = domain[int(rng.integers(len(domain)))]
            specs.append(
                FaultSpec(
                    kind=kind,
                    site=site,
                    at=int(rng.integers(max(1, horizon))),
                    payload=dict(defaults.get(kind, {})),
                )
            )
        return cls(specs)


class FaultInjector:
    """Runtime side of a ``FaultPlan``: per-site event counters plus the
    fired log. Each hook site calls ``poll(site)`` exactly once per
    event; the matching specs (counter specs at ``at == count``,
    tag-matched specs persistently once ``count >= at``) come back for
    the caller to act on."""

    def __init__(self, plan: FaultPlan | str | None = None):
        if isinstance(plan, str):
            plan = FaultPlan.parse(plan)
        self.plan = plan if plan is not None else FaultPlan()
        self._counts: dict[str, int] = {}
        # poll() now fires under the serve scheduler's worker threads
        # (DESIGN.md §12) — counter advance + fired-log append must stay
        # atomic per event for the replay log to be a replay.
        self._lock = threading.Lock()
        #: (site, count, spec) triples, in firing order — the replay log.
        self.fired: list[tuple[str, int, FaultSpec]] = []

    def count(self, site: str) -> int:
        return self._counts.get(site, 0)

    def poll(self, site: str, tags=()) -> list[FaultSpec]:
        """Advance ``site``'s event counter; return the specs due now."""
        if site not in SITES:
            raise ValueError(f"unknown fault site {site!r}; one of {SITES}")
        with self._lock:
            return self._poll_locked(site, tags)

    def _poll_locked(self, site: str, tags) -> list[FaultSpec]:
        c = self._counts.get(site, 0)
        self._counts[site] = c + 1
        tags = tuple(tags)
        due = []
        for spec in self.plan.specs:
            if spec.site != site:
                continue
            tag = spec.payload.get("tag")
            if tag is not None:
                if c >= spec.at and tag in tags:
                    due.append(spec)
            elif spec.at == c:
                due.append(spec)
        for spec in due:
            self.fired.append((site, c, spec))
        return due

    def log(self) -> list[tuple[str, int, str]]:
        """Compact fired log: (site, count, kind)."""
        return [(site, c, spec.kind) for site, c, spec in self.fired]


def poison_problem(p):
    """NaN-poison one cell of a MetricQP's linear cost — past intake
    validation, the poison the batch runtime must isolate: the slot's
    ``x0`` is NaN, its residual probe is NaN at the first check, and the
    per-slot divergence guard dead-letters it while healthy slots land."""
    c = np.array(p.c_x, np.float64)
    c[0, min(1, p.n - 1)] = np.nan
    return dataclasses.replace(p, c_x=c)

# Serving layer (DESIGN.md §8/§12): many independent moderate-n
# instances batched onto one accelerator. buckets.py owns the shape
# ladder + ghost padding + intake validation + compiled-solver cache,
# batching.py the vmapped multi-instance engine (per-slot divergence
# guard, drain-mode while_loop, and the ContinuousBatcher chunk/refill
# runtime), scheduler.py the async service front-end (submit -> future,
# background dispatch workers, drain micro-batching or slot-level
# continuous batching; retry / bisect-isolate / dead-letter hardening,
# DESIGN.md §11), pipeline.py the end-to-end graph -> clustering
# scenario (optional Poisson arrival streams), faults.py the seeded
# deterministic fault-injection plans the chaos tests replay.

# Serving layer (DESIGN.md §8): many independent moderate-n instances
# batched onto one accelerator. buckets.py owns the shape ladder + ghost
# padding + intake validation + compiled-solver cache, batching.py the
# vmapped multi-instance engine (with the per-slot divergence guard),
# scheduler.py the micro-batching request queue (retry / bisect-isolate /
# dead-letter hardening, DESIGN.md §11), pipeline.py the end-to-end
# graph -> clustering scenario, faults.py the seeded deterministic
# fault-injection plans the chaos tests replay.

# Serving layer (DESIGN.md §8): many independent moderate-n instances
# batched onto one accelerator. buckets.py owns the shape ladder + ghost
# padding + compiled-solver cache, batching.py the vmapped multi-instance
# engine, scheduler.py the micro-batching request queue, pipeline.py the
# end-to-end graph -> clustering scenario.

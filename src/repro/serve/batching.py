"""Vmapped multi-instance solve engine (DESIGN.md §8).

``BatchedSolver`` runs B independent MetricQP instances of one shape
bucket as a *single* device program. The single-instance machinery is
reused wholesale — the staged fused pass (`ref.fused_bucket_pass_ref`),
the pair/box steps (`engine.pair_step` / `engine.box_step`), the stopping
metrics (`metrics_device`) — but where `ParallelSolver` bakes its problem
data into the trace as constants, the batched engine splits every
per-pass input into

  * **shared statics** (one copy per bucket shape, traced as constants):
    the schedule layout, folded geometry / step-mask / seg slabs, lane
    tables — pure functions of ``bucket_n`` alone;
  * **per-instance operands** (stacked with a leading B axis, passed as
    runtime arguments): ``(w, d, c)`` problem data, the staged projection
    gains derived from w on device, the live-pair mask and ghost count
    ``n_real`` — so a new batch of weight matrices NEVER recompiles.

``run_until`` is the batched twin of the engine's solve-to-tolerance
runtime: one jitted ``lax.while_loop`` whose body runs ``check_every``
vmapped passes and evaluates the per-instance stopping rule
(`engine.stop_converged`) as a (B,) vector on device. Converged instances
**freeze**: their slots are select-restored after every chunk (a no-op in
lock-step vmap execution), so stragglers keep sweeping while finished
instances hold their stopped state and pass counter — exactly the state a
standalone `ParallelSolver.run_until` of the same padded instance stops
at, pinned to 1e-10 by tests/test_serve.py.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import engine, metrics_device, schedule as sched
from repro.core.problems import MetricQP
from repro.kernels.metric_project import ref as kref
from repro.serve.buckets import Family, family_of, pad_problem

__all__ = [
    "BatchedSolver",
    "BatchedState",
    "ContinuousBatcher",
    "InstanceBatch",
    "stack_instances",
]


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class BatchedState:
    """State of B stacked instances: leading axis of every leaf is the
    batch slot. ``passes`` is per instance (slots freeze independently)."""

    x: jax.Array  # (B, n, n)
    f: jax.Array | None
    yd: list[jax.Array]  # per bucket: (B, D, 3, T, Cl)
    ypair: jax.Array | None  # (B, 2, n, n)
    ybox: jax.Array | None
    passes: jax.Array  # (B,) int32


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class InstanceBatch:
    """Per-instance problem data, stacked: the runtime operands of the
    batched runner (a new batch never recompiles). ``n_real[b] = 0``
    marks an empty slot (all-ghost; converges at the first check)."""

    w: jax.Array  # (B, n, n)
    d: jax.Array
    c_x: jax.Array
    w_f: jax.Array | None
    c_f: jax.Array | None
    n_real: jax.Array  # (B,) int32

    @property
    def batch(self) -> int:
        return int(self.w.shape[0])


def stack_instances(
    problems: list[MetricQP | None],
    bucket_n: int,
    family: Family,
    dtype,
) -> InstanceBatch:
    """Ghost-pad each problem to ``bucket_n`` and stack the batch.

    ``None`` entries become empty slots (n_real = 0, inert data). Every
    real problem must match ``family`` — eps/has_f/box are compile-time
    constants of the batched program.
    """
    n = bucket_n
    zeros = np.zeros((n, n), np.float64)
    ones = np.ones((n, n), np.float64)
    ws, ds, cxs, wfs, cfs, n_real = [], [], [], [], [], []
    for p in problems:
        if p is None:
            ws.append(ones)
            ds.append(zeros)
            cxs.append(zeros)
            wfs.append(ones)
            cfs.append(zeros)
            n_real.append(0)
            continue
        got = family_of(p, dtype)
        if got != family:
            raise ValueError(
                f"instance family {got} does not match batch family {family}"
            )
        pp = pad_problem(p, bucket_n)
        ws.append(pp.w)
        ds.append(pp.d)
        cxs.append(pp.c_x)
        wfs.append(pp.w_f if pp.w_f is not None else ones)
        cfs.append(pp.c_f if pp.c_f is not None else zeros)
        n_real.append(p.n)
    stack = lambda xs: jnp.asarray(np.stack(xs), dtype)
    return InstanceBatch(
        w=stack(ws),
        d=stack(ds),
        c_x=stack(cxs),
        w_f=stack(wfs) if family.has_f else None,
        c_f=stack(cfs) if family.has_f else None,
        n_real=jnp.asarray(np.asarray(n_real, np.int32)),
    )


def _freeze(done, old, new):
    """Select-restore frozen slots across a whole state pytree."""

    def sel(a, b):
        if a is None:
            return None
        d = done.reshape(done.shape + (1,) * (a.ndim - 1))
        return jnp.where(d, a, b)

    return jax.tree_util.tree_map(sel, old, new)


class BatchedSolver:
    """Vmapped fused-pass Dykstra for one (bucket_n, batch, family) slot
    of the serving ladder (see module docstring and DESIGN.md §8).

    Args:
      bucket_n: canonical padded instance size of this bucket.
      batch: number of instance slots B.
      family: problem family (eps/has_f/box/dtype) — the compile key.
      num_buckets: diagonal buckets of the schedule (same knob as
        ``ParallelSolver.bucket_diagonals``).
      sweep_unroll: inner-scan unroll of the fused sweep.
      use_kernel: route the triangle sweeps through the gen-3 Pallas
        megakernel — the whole (B, ...) bucket runs as ONE ``pallas_call``
        per bucket per pass (DESIGN.md §10), bitwise-equal per instance
        to the vmapped jnp fused reference. Gains/masks stay runtime
        operands either way, so new batches never recompile.
    """

    def __init__(
        self,
        bucket_n: int,
        batch: int,
        family: Family,
        num_buckets: int = 6,
        sweep_unroll: int = 4,
        use_kernel: bool = False,
    ):
        self.bucket_n = self.n = int(bucket_n)
        self.batch = int(batch)
        self.family = family
        self.dtype = jnp.dtype(family.dtype)
        self.sweep_unroll = max(1, int(sweep_unroll))
        self.use_kernel = bool(use_kernel)
        self.num_buckets = max(1, int(num_buckets))
        self.layout = sched.build_layout(
            self.n, num_buckets=self.num_buckets, procs=1
        )
        # Shared statics: lane tables + folded geometry/masks (weight
        # slabs of the ones-stage are discarded — weights are operands).
        stage = sched.build_static_stage(
            self.layout, np.ones((self.n, self.n)), np.dtype(self.dtype)
        )
        self._geo = [
            dict(
                i=jnp.asarray(bl.i[0], jnp.int32),
                k=jnp.asarray(bl.k[0], jnp.int32),
                s=jnp.asarray(bl.sizes[0], jnp.int32),
                i2=jnp.asarray(bl.i2[0], jnp.int32),
                k2=jnp.asarray(bl.k2[0], jnp.int32),
                s2=jnp.asarray(bl.sizes2[0], jnp.int32),
                J=jnp.asarray(sb.J[0]),
                iN=jnp.asarray(sb.iN[0]),
                kN=jnp.asarray(sb.kN[0]),
                seg=jnp.asarray(sb.seg[0]),
            )
            for bl, sb in zip(self.layout.buckets, stage)
        ]
        self._act0 = [jnp.asarray(sb.active[0]) for sb in stage]
        self._runner_cache: dict = {}
        self._fn_cache: dict = {}
        #: (B, R) chunk-boundary ||Δx||_inf trajectories of the last
        #: run_until (oldest first per row, -1.0 where fewer chunks ran).
        self.last_residuals = None

    # ------------------------------------------------------------ plumbing
    @property
    def _wide_dtype(self):
        if jax.config.jax_enable_x64 and self.dtype != jnp.float64:
            return jnp.float64
        return self.dtype

    def stack(self, problems: list[MetricQP | None]) -> InstanceBatch:
        """Pad + stack a list of instances into this solver's slots."""
        if len(problems) > self.batch:
            raise ValueError(f"{len(problems)} instances > batch {self.batch}")
        problems = list(problems) + [None] * (self.batch - len(problems))
        return stack_instances(problems, self.n, self.family, self.dtype)

    def _init_expr(self, inst: InstanceBatch) -> BatchedState:
        """The init-state expression (traceable; shared by ``init_state``
        and the jitted slot-refill merge, so a refilled slot restarts
        from bitwise the state a fresh drain-mode batch would give it)."""
        mask_all = jnp.triu(jnp.ones((self.n, self.n), bool), k=1)
        eps = self.family.eps
        x0 = jnp.where(mask_all, -inst.c_x / (eps * inst.w), 0.0)
        f0 = None
        if self.family.has_f:
            f0 = jnp.where(mask_all, -inst.c_f / (eps * inst.w_f), 0.0)
        B, n, dt = self.batch, self.n, self.dtype
        return BatchedState(
            x=x0.astype(dt),
            f=None if f0 is None else f0.astype(dt),
            yd=[
                jnp.zeros((B,) + bl.slab_shape[1:], dt)
                for bl in self.layout.buckets
            ],
            ypair=(
                jnp.zeros((B, 2, n, n), dt)
                if self.family.has_f else None
            ),
            ybox=(
                jnp.zeros((B, 2, n, n), dt)
                if self.family.box is not None else None
            ),
            passes=jnp.zeros((self.batch,), jnp.int32),
        )

    def init_state(self, inst: InstanceBatch) -> BatchedState:
        fn = self._fn_cache.get("init")
        if fn is None:
            fn = self._fn_cache["init"] = jax.jit(self._init_expr)
        return fn(inst)

    # ------------------------------------------------- per-instance pieces
    def _aux_one(self, w, n_real):
        """Staged per-instance operands: projection gains gathered from
        this instance's W on device, ghost-masked step masks, live-pair
        mask. Mirrors ``ParallelSolver._stage_buckets`` expression-for-
        expression so batched == standalone bit-for-bit."""
        dt = self.dtype
        one = jnp.asarray(1.0, dt)
        eps = jnp.asarray(self.family.eps, dt)
        gains = []
        for geo, act0 in zip(self._geo, self._act0):
            gather = lambda r, c: w.at[r, c].get(mode="fill", fill_value=1.0)
            w_row = jnp.where(act0, gather(geo["iN"], geo["J"]), one)
            w_col = jnp.where(act0, gather(geo["J"], geo["kN"]), one)
            w_ikp = jnp.stack(
                [
                    jnp.where(geo["i"] >= 0, gather(geo["i"], geo["k"]), one),
                    jnp.where(geo["i2"] >= 0, gather(geo["i2"], geo["k2"]), one),
                ],
                axis=1,
            )  # (D, 2, Cl)
            g_row = (one / w_row) / eps
            g_col = (one / w_col) / eps
            g_ikp = (one / w_ikp) / eps
            g_sel = jnp.where(
                geo["seg"], g_ikp[:, 1][:, None, :], g_ikp[:, 0][:, None, :]
            )
            dinv = one / (g_row + g_sel + g_col)
            gains.append(
                dict(
                    act=act0 & (geo["kN"] < n_real),
                    g_row=g_row,
                    g_col=g_col,
                    g_sel=g_sel,
                    dinv=dinv,
                )
            )
        return dict(
            gains=gains,
            mask=metrics_device.live_pair_mask(self.n, n_real),
        )

    def _pairbox_one(self, x, f, ypair, ybox, inst1, aux):
        """Pair/box projections of one instance under its live-pair mask
        (shared by the vmapped-ref and kernel batch passes)."""
        mask = aux["mask"]
        eps = self.family.eps
        if self.family.has_f:
            x2, f2, ypair = engine.pair_step(
                x, f, ypair, w=inst1.w, wf=inst1.w_f, d=inst1.d, eps=eps
            )
            x = jnp.where(mask, x2, x)
            f = jnp.where(mask, f2, f)
            ypair = jnp.where(mask[None], ypair, 0)
        if self.family.box is not None:
            lo, hi = self.family.box
            x2, ybox = engine.box_step(
                x, ybox, w=inst1.w, lo=lo, hi=hi, eps=eps
            )
            x = jnp.where(mask, x2, x)
            ybox = jnp.where(mask[None], ybox, 0)
        return x, f, ypair, ybox

    def _pass_one(self, st, inst1, aux):
        """One fused pass of a single instance (vmapped by the runner)."""
        x, yd = st.x, st.yd
        new_yd = []
        for geo, g, yb in zip(self._geo, aux["gains"], yd):
            x, nyb = kref.fused_bucket_pass_ref(
                x, yb, geo | g, unroll=self.sweep_unroll
            )
            new_yd.append(nyb)
        x, f, ypair, ybox = self._pairbox_one(
            x, st.f, st.ypair, st.ybox, inst1, aux
        )
        return BatchedState(x, f, new_yd, ypair, ybox, st.passes + 1)

    def _pass_batch(self, st, inst, aux):
        """One fused pass of the WHOLE batch: per bucket, one gen-3
        megakernel call covers all B instances (the leading instance grid
        axis of DESIGN.md §10) — bitwise-equal to ``vmap(_pass_one)``.
        ``aux`` is the vmapped ``_aux_one`` output (leading B axis on
        every gain/mask leaf)."""
        from repro.kernels.metric_project import ops as kops

        x, yd = st.x, st.yd
        new_yd = []
        for geo, g, yb in zip(self._geo, aux["gains"], yd):
            x, nyb = kops.fused_bucket_pass_batched(
                x, yb, geo, g, unroll=self.sweep_unroll
            )
            new_yd.append(nyb)
        x, f, ypair, ybox = jax.vmap(self._pairbox_one)(
            x, st.f, st.ypair, st.ybox, inst, aux
        )
        return BatchedState(x, f, new_yd, ypair, ybox, st.passes + 1)

    def _dprob_one(self, inst1, mask, n_real, dtype):
        up = lambda a: None if a is None else a.astype(dtype)
        return metrics_device.DeviceProblem(
            n=self.n,
            eps=self.family.eps,
            has_f=self.family.has_f,
            box=self.family.box,
            mask=mask,
            d=up(inst1.d),
            w=up(inst1.w),
            c_x=up(inst1.c_x),
            w_f=up(inst1.w_f),
            c_f=up(inst1.c_f),
            n_real=n_real,
        )

    def _probe_one(self, st, inst1, aux, n_real):
        """(viol, gap, obj) of one instance in the wide dtype — the same
        reductions as ``SolverRuntime._stopping_pair`` and
        ``_wide_objective``."""
        wd = self._wide_dtype
        dp = self._dprob_one(inst1, aux["mask"], n_real, wd)
        up = lambda a: None if a is None else a.astype(wd)
        x, f = up(st.x), up(st.f)
        viol = metrics_device.max_violation(dp, x, f)
        gap = metrics_device.duality_gap(dp, x, f, up(st.ypair), up(st.ybox))
        obj = metrics_device.qp_objective(dp, x, f)
        return viol, gap, obj

    # ------------------------------------------------------------ runners
    def _loop_pieces(self, check_every: int, stop_rule: str, res_hist: int):
        """Build the chunk loop's ``(cond, body)`` closure factory.

        ``make(inst, tol, max_passes)`` returns the predicate and body of
        ONE convergence-check chunk over an ``engine.ChunkCarry`` — the
        exact while_loop pieces ``run_until`` jits, also exposed one
        body-application at a time through ``_chunk_fn`` for the
        continuous-batching serve loop (DESIGN.md §12). Sharing the
        closure is what makes continuous-mode chunk boundaries bitwise
        identical to drain-mode ones.
        """

        def make(inst, tol, max_passes):
                dt = self._wide_dtype
                aux = jax.vmap(self._aux_one)(inst.w, inst.n_real)

                def chunk_guarded(st1, inst1, aux1):
                    # Exact host k = min(chunk, remaining) semantics for a
                    # partial final chunk — the engine's per-pass guard.
                    # Under vmap the cond lowers to a select that
                    # materializes BOTH branches' state every pass (~4x a
                    # plain pass), so the runner only takes this chunk
                    # when some live slot would overshoot max_passes.
                    def guarded(s):
                        return jax.lax.cond(
                            s.passes < max_passes,
                            lambda q: self._pass_one(q, inst1, aux1),
                            lambda q: q,
                            s,
                        )

                    s2, _ = jax.lax.scan(
                        lambda c, _: (guarded(c), None),
                        st1, None, length=check_every,
                    )
                    return s2

                def chunk_plain(st1, inst1, aux1):
                    s2, _ = jax.lax.scan(
                        lambda c, _: (self._pass_one(c, inst1, aux1), None),
                        st1, None, length=check_every,
                    )
                    return s2

                def kchunk_plain(st1):
                    s2, _ = jax.lax.scan(
                        lambda c, _: (self._pass_batch(c, inst, aux), None),
                        st1, None, length=check_every,
                    )
                    return s2

                def kchunk_guarded(st1):
                    # Batch-level twin of chunk_guarded: the vmapped
                    # per-instance cond lowers to a per-slot select, so
                    # freezing at-limit slots after a full batch pass is
                    # bit-identical.
                    def step(c, _):
                        c2 = self._pass_batch(c, inst, aux)
                        return _freeze(c.passes >= max_passes, c, c2), None

                    s2, _ = jax.lax.scan(
                        step, st1, None, length=check_every
                    )
                    return s2

                if self.use_kernel:
                    run_plain, run_guarded = kchunk_plain, kchunk_guarded
                else:
                    vchunk_guarded = jax.vmap(chunk_guarded)
                    vchunk_plain = jax.vmap(chunk_plain)
                    run_plain = lambda q: vchunk_plain(q, inst, aux)
                    run_guarded = lambda q: vchunk_guarded(q, inst, aux)
                vprobe = jax.vmap(self._probe_one)

                def cond(carry):
                    return jnp.any(
                        ~carry.done & (carry.state.passes < max_passes)
                    )

                def body(carry):
                    # carry's obj is the previous check's objective — the
                    # plateau rule's progress baseline.
                    s, done = carry.state, carry.done
                    viol_p, gap_p, obj_prev = carry.viol, carry.gap, carry.obj
                    resbuf, k, div = carry.resbuf, carry.k, carry.div
                    # Scalar predicate -> a true XLA branch: the fast
                    # unguarded chunk whenever no live slot can cross
                    # max_passes inside it (frozen slots are restored by
                    # the select below, so their overshoot is harmless).
                    safe = jnp.all(
                        done | (s.passes + check_every <= max_passes)
                    )
                    s2 = jax.lax.cond(safe, run_plain, run_guarded, s)
                    s2 = _freeze(done, s, s2)
                    # (B, R) ring buffer of the chunk-boundary ||Δx||_inf
                    # probe — the solo runtime's residual trajectory, one
                    # row per instance. A slot records only the chunks it
                    # was live for (its write cursor freezes with it), so
                    # row i IS the trajectory solo run_until would export
                    # for instance i.
                    B = self.batch
                    res = jnp.max(
                        jnp.abs(s2.x - s.x).reshape(B, -1), axis=1
                    ).astype(dt)
                    viol, gap, obj = vprobe(s2, inst, aux, inst.n_real)
                    viol, gap, obj = (
                        viol.astype(dt), gap.astype(dt), obj.astype(dt)
                    )
                    # Per-slot divergence guard (the solo engine's,
                    # vectorized): a slot whose probe goes non-finite is
                    # restored to its last finite chunk boundary and
                    # frozen — a NaN-poisoned instance stops costing
                    # passes after one chunk while healthy slots keep
                    # sweeping. In fault-free runs every select below is
                    # an identity, preserving batched==solo bitwise
                    # parity.
                    bad = (~done) & ~(
                        jnp.isfinite(res)
                        & jnp.isfinite(viol)
                        & jnp.isfinite(gap)
                    )
                    s2 = _freeze(bad, s, s2)
                    viol = jnp.where(bad, viol_p, viol)
                    gap = jnp.where(bad, gap_p, gap)
                    obj = jnp.where(bad, obj_prev, obj)
                    live = (~done) & (s.passes < max_passes)
                    slot = jax.lax.broadcasted_iota(
                        jnp.int32, (B, res_hist), 1
                    )
                    write = live[:, None] & (
                        slot == (k % res_hist)[:, None]
                    )
                    rec = jnp.where(bad, jnp.asarray(jnp.inf, dt), res)
                    resbuf = jnp.where(write, rec[:, None], resbuf)
                    k = k + live.astype(jnp.int32)
                    div = div | bad
                    done = done | bad | engine.stop_converged(
                        stop_rule, tol, viol, gap, obj, obj_prev
                    )
                    return engine.ChunkCarry(
                        s2, done, viol, gap, obj, resbuf, k, div
                    )

                return cond, body

        return make

    def _until_fn(self, check_every: int, stop_rule: str,
                  res_hist: int = 16):
        key = (check_every, stop_rule, res_hist)
        fn = self._runner_cache.get(key)
        if fn is None:
            make = self._loop_pieces(check_every, stop_rule, res_hist)

            def runner(st, inst, tol, max_passes):
                cond, body = make(inst, tol, max_passes)
                carry = engine.init_chunk_carry(
                    st, self.batch, res_hist, self._wide_dtype
                )
                return jax.lax.while_loop(cond, body, carry)

            fn = self._runner_cache[key] = jax.jit(runner)
        return fn

    def _chunk_fn(self, check_every: int, stop_rule: str,
                  res_hist: int = 16):
        """One body-application of the chunk loop, jitted: the
        continuous-batching stepper. Identity when no slot is live (the
        while_loop's exit condition), otherwise exactly one chunk —
        ``check_every`` passes + probe + freeze/divergence/stop updates —
        so interleaving refills at chunk boundaries never perturbs
        co-resident slots (each slot's trajectory depends only on its own
        operands under the vmapped/kernel pass)."""
        key = ("chunk", check_every, stop_rule, res_hist)
        fn = self._fn_cache.get(key)
        if fn is None:
            make = self._loop_pieces(check_every, stop_rule, res_hist)

            def step(carry, inst, tol, max_passes):
                cond, body = make(inst, tol, max_passes)
                return jax.lax.cond(
                    cond(carry), body, lambda c: c, carry
                )

            fn = self._fn_cache[key] = jax.jit(step)
        return fn

    def start_carry(self, inst: InstanceBatch, state=None,
                    residual_history: int = 16) -> engine.ChunkCarry:
        """Fresh chunk-loop carry over ``state`` (default: the batch's
        init state) — the continuous loop's entry point."""
        st = state if state is not None else self.init_state(inst)
        return engine.init_chunk_carry(
            st, self.batch, max(1, int(residual_history)), self._wide_dtype
        )

    def _refill_fn(self):
        """Jitted slot refill: merge ``new_inst`` rows into ``inst`` and
        reset the carry's state/bookkeeping at ``mask`` rows — the new
        slots restart from exactly the init state + fresh carry drain
        mode would give them, while untouched rows pass through bitwise
        (every select is an identity off-mask). Operands only — a refill
        NEVER recompiles."""
        fn = self._fn_cache.get("refill")
        if fn is None:

            def refill(carry, inst, new_inst, mask):
                inst2 = jax.tree_util.tree_map(
                    lambda old, new: jnp.where(
                        mask.reshape(mask.shape + (1,) * (new.ndim - 1)),
                        new, old,
                    ),
                    inst, new_inst,
                )
                st0 = self._init_expr(inst2)
                st = _freeze(mask, st0, carry.state)
                dt = self._wide_dtype
                inf = jnp.asarray(jnp.inf, dt)
                sel = lambda a, b: jnp.where(mask, a, b)
                return engine.ChunkCarry(
                    state=st,
                    done=sel(jnp.zeros_like(carry.done), carry.done),
                    viol=sel(inf, carry.viol),
                    gap=sel(inf, carry.gap),
                    obj=sel(inf, carry.obj),
                    resbuf=jnp.where(
                        mask[:, None], jnp.asarray(-1.0, dt), carry.resbuf
                    ),
                    k=sel(jnp.zeros_like(carry.k), carry.k),
                    div=sel(jnp.zeros_like(carry.div), carry.div),
                ), inst2

            fn = self._fn_cache["refill"] = jax.jit(refill)
        return fn

    def dual_stats(self, st: BatchedState, inst: InstanceBatch) -> dict:
        """Per-instance triangle dual stats (min/max/l1/active count),
        reduced slab-native under **ghost-aware** valid masks: the
        structural padding mask of the shared layout AND'd with each
        instance's ``kN < n_real`` set predicate (a traced per-instance
        scalar — one compiled program serves every batch). Ghost and
        padding cells hold don't-care values under fused execution and
        never enter the reductions. Returns length-B numpy arrays, keys
        as ``metrics_device.triangle_dual_stats``."""
        fn = self._fn_cache.get("dual_stats")
        if fn is None:
            valid0 = [
                jnp.asarray(m[0])
                for m in sched.slab_valid_masks(self.layout)
            ]

            def one(yd1, n_real):
                masks = [
                    v & (geo["kN"][:, None, :, :] < n_real)
                    for v, geo in zip(valid0, self._geo)
                ]
                return metrics_device.triangle_dual_stats(yd1, masks)

            fn = self._fn_cache["dual_stats"] = jax.jit(jax.vmap(one))
        out = jax.device_get(fn(st.yd, inst.n_real))
        return {k: np.asarray(v) for k, v in out.items()}

    def _objectives_fn(self):
        fn = self._fn_cache.get("objectives")
        if fn is None:

            def obj_one(st, inst1, n_real):
                mask = metrics_device.live_pair_mask(self.n, n_real)
                dp = self._dprob_one(inst1, mask, n_real, self._wide_dtype)
                up = lambda a: None if a is None else a.astype(self._wide_dtype)
                return (
                    metrics_device.qp_objective(dp, up(st.x), up(st.f)),
                    metrics_device.lp_objective(dp, up(st.x)),
                )

            fn = self._fn_cache["objectives"] = jax.jit(
                jax.vmap(obj_one)
            )
        return fn

    def run_until(
        self,
        inst: InstanceBatch,
        state: BatchedState | None = None,
        *,
        tol: float = 1e-4,
        max_passes: int = 100,
        check_every: int = 10,
        stop_rule: str = "absolute",
        residual_history: int = 16,
    ):
        """Solve all B instances to tolerance inside ONE jitted
        while_loop with per-instance device-side stopping (see module
        docstring). Semantics per instance are exactly
        ``SolverRuntime.run_until`` — same chunking, same cumulative
        ``max_passes`` guard, same ``stop_rule`` decision — evaluated as
        (B,) vectors; converged slots freeze while stragglers sweep.

        Returns ``(state, info)`` where every info value is a length-B
        numpy array (``passes``, ``converged``, ``diverged``,
        ``max_violation``, ``duality_gap``, ``qp_objective``,
        ``lp_objective``), plus
        ``residuals`` — the (B, R) chunk-boundary ``||Δx||_inf``
        trajectory ring buffer (R = ``residual_history``): row i holds
        the most recent R chunk residuals of instance i oldest-first
        (-1.0 where fewer chunks ran — a slot's cursor freezes with it),
        exactly the trajectory the solo runtime exports; mirrored to
        ``self.last_residuals``.

        A slot whose residual probe goes non-finite trips the per-slot
        divergence guard: it is restored to its last finite chunk
        boundary and frozen (``diverged[b] = True``, ``converged[b] =
        False``) while healthy slots keep sweeping — one poisoned
        instance never costs the batch its remaining passes.
        """
        if stop_rule not in engine.STOP_RULES:
            raise ValueError(
                f"unknown stop_rule {stop_rule!r}; "
                f"expected one of {engine.STOP_RULES}"
            )
        st = state if state is not None else self.init_state(inst)
        check_every = max(1, int(check_every))
        residual_history = max(1, int(residual_history))
        fn = self._until_fn(check_every, stop_rule, residual_history)
        out = fn(st, inst, float(tol), int(max_passes))
        st, done, viol, gap, obj, resbuf, kcnt, div = (
            out.state, out.done, out.viol, out.gap, out.obj,
            out.resbuf, out.k, out.div,
        )
        div = np.asarray(jax.device_get(div), bool)
        viol, gap, obj = (
            np.asarray(jax.device_get(v), np.float64) for v in (viol, gap, obj)
        )
        qp, lp = (
            np.asarray(jax.device_get(v), np.float64)
            for v in self._objectives_fn()(st, inst, inst.n_real)
        )
        if not np.all(np.isfinite(viol)):
            # no chunk ran (some slot already at/over max_passes), or a
            # slot diverged on its very first chunk (its carried pair is
            # still inf): probe once so callers get a real stopping
            # vector — NaN for slots whose restored state is itself
            # poisoned, which stop_converged below treats as False.
            probe = self._fn_cache.get("probe")
            if probe is None:
                probe = self._fn_cache["probe"] = jax.jit(
                    jax.vmap(self._probe_one)
                )
            aux = jax.vmap(self._aux_one)(inst.w, inst.n_real)
            viol, gap, obj = (
                np.asarray(jax.device_get(v), np.float64)
                for v in probe(st, inst, aux, inst.n_real)
            )
        with np.errstate(invalid="ignore"):
            converged = (
                np.asarray(
                    engine.stop_converged(
                        stop_rule, float(tol), viol, gap, obj,
                        np.full_like(obj, np.inf),
                    )
                )
                | np.asarray(jax.device_get(done))
            ) & ~div
        resbuf = np.asarray(jax.device_get(resbuf), np.float64)
        kcnt = np.asarray(jax.device_get(kcnt), np.int64)
        residuals = np.array(
            [
                row if k <= residual_history
                else np.roll(row, -(k % residual_history))
                for row, k in zip(resbuf, kcnt)
            ]
        )
        self.last_residuals = residuals
        info = {
            "passes": np.asarray(jax.device_get(st.passes), np.int64),
            "converged": np.asarray(converged, bool),
            "diverged": div,
            "max_violation": viol,
            "duality_gap": gap,
            "qp_objective": qp,
            "lp_objective": lp,
            "stop_rule": stop_rule,
            "residuals": residuals,
        }
        return st, info


class ContinuousBatcher:
    """Slot-level continuous batching over one ``BatchedSolver``
    (DESIGN.md §12): a long-lived chunk-loop carry whose slots retire and
    refill independently at chunk boundaries, instead of a whole batch
    waiting for its slowest instance.

    The loop contract is the drain-mode one taken apart: ``step()`` is
    one body-application of the SAME jitted chunk closure ``run_until``
    while_loops (``BatchedSolver._chunk_fn``); ``harvest()`` pops slots
    the while_loop's exit condition would have released
    (``engine.chunk_terminal``) and reproduces ``run_until``'s host
    epilogue per slot; ``admit()`` resets freed slots to exactly the init
    state + fresh carry a drain-mode batch would give the new instance
    (``BatchedSolver._refill_fn`` — weights are runtime operands, so a
    refill never recompiles). Because each slot's trajectory depends only
    on its own operands under the vmapped/kernel pass, and a slot's
    stopping checks land at multiples of ``check_every`` from its OWN
    pass 0, every instance's harvested ``x``/``passes`` are bitwise what
    the same instance gets in a drain-mode batch — the mixed-age
    extension of the §8 batched==solo pin, pinned by
    tests/test_continuous.py.

    Host-side bookkeeping only lives here (which tag occupies which
    slot); all math is the solver's. Not thread-safe: one owner (the
    scheduler's per-bucket worker) drives it.
    """

    def __init__(
        self,
        solver: BatchedSolver,
        *,
        tol: float = 1e-4,
        max_passes: int = 100,
        check_every: int = 10,
        stop_rule: str = "absolute",
        residual_history: int = 16,
    ):
        if stop_rule not in engine.STOP_RULES:
            raise ValueError(
                f"unknown stop_rule {stop_rule!r}; "
                f"expected one of {engine.STOP_RULES}"
            )
        self.solver = solver
        self.tol = float(tol)
        self.max_passes = int(max_passes)
        self.check_every = max(1, int(check_every))
        self.stop_rule = stop_rule
        self.residual_history = max(1, int(residual_history))
        #: slot -> tag of the occupying instance (None = free).
        self.tags: list = [None] * solver.batch
        self._n_real: dict = {}  # tag -> native n of its problem
        self.inst = solver.stack([])
        self.carry = solver.start_carry(
            self.inst, residual_history=self.residual_history
        )
        self.chunks_run = 0
        self.refills = 0
        #: sum over chunks of occupied slots — occupancy numerator.
        self.occupied_chunks = 0

    # ---------------------------------------------------------- occupancy
    def free_slots(self) -> list[int]:
        return [b for b, t in enumerate(self.tags) if t is None]

    @property
    def occupied(self) -> int:
        return sum(t is not None for t in self.tags)

    @property
    def live(self) -> bool:
        return self.occupied > 0

    # ------------------------------------------------------------- refill
    def admit(self, assignments: list) -> None:
        """Fill freed slots: ``assignments`` is ``[(slot, problem, tag)]``
        (each slot currently free). One jitted refill merges every row at
        once; co-resident rows pass through bitwise."""
        if not assignments:
            return
        B = self.solver.batch
        probs: list = [None] * B
        mask = np.zeros((B,), bool)
        for slot, problem, tag in assignments:
            if self.tags[slot] is not None:
                raise ValueError(f"slot {slot} is occupied by {self.tags[slot]!r}")
            probs[slot] = problem
            mask[slot] = True
        new_inst = stack_instances(
            probs, self.solver.n, self.solver.family, self.solver.dtype
        )
        self.carry, self.inst = self.solver._refill_fn()(
            self.carry, self.inst, new_inst, jnp.asarray(mask)
        )
        for slot, problem, tag in assignments:
            self.tags[slot] = tag
            self._n_real[tag] = problem.n
            self.refills += 1

    # --------------------------------------------------------------- step
    def step(self) -> None:
        """Advance the live slots one convergence chunk (identity when
        no slot is live — the while_loop's exit condition)."""
        fn = self.solver._chunk_fn(
            self.check_every, self.stop_rule, self.residual_history
        )
        self.carry = fn(
            self.carry, self.inst, self.tol, self.max_passes
        )
        self.chunks_run += 1
        self.occupied_chunks += self.occupied

    # ------------------------------------------------------------ harvest
    def harvest(self) -> list:
        """Pop every occupied terminal slot: returns
        ``[(slot, tag, x_row, f_row, info)]`` with ``info`` exactly the
        per-instance ``run_until`` report (passes / converged / diverged /
        stopping pair / objectives / residual trajectory). Freed slots
        are immediately admittable."""
        c = self.carry
        done = np.asarray(jax.device_get(c.done), bool)
        passes = np.asarray(jax.device_get(c.state.passes), np.int64)
        term = np.asarray(
            engine.chunk_terminal(done, passes, self.max_passes), bool
        )
        slots = [
            b for b, t in enumerate(self.tags)
            if t is not None and term[b]
        ]
        if not slots:
            return []
        st, inst, solver = c.state, self.inst, self.solver
        x = np.asarray(jax.device_get(st.x))
        f = None if st.f is None else np.asarray(jax.device_get(st.f))
        div = np.asarray(jax.device_get(c.div), bool)
        viol, gap, obj = (
            np.asarray(jax.device_get(v), np.float64)
            for v in (c.viol, c.gap, c.obj)
        )
        qp, lp = (
            np.asarray(jax.device_get(v), np.float64)
            for v in solver._objectives_fn()(st, inst, inst.n_real)
        )
        if not np.all(np.isfinite(viol[slots])):
            # drain-mode's epilogue fallback, per slot: a slot that never
            # completed a finite chunk (diverged on its first, or
            # max_passes=0) still gets a real stopping probe — NaN when
            # the restored state is itself poisoned, which the stop rule
            # treats as not-converged.
            probe = solver._fn_cache.get("probe")
            if probe is None:
                probe = solver._fn_cache["probe"] = jax.jit(
                    jax.vmap(solver._probe_one)
                )
            aux = jax.vmap(solver._aux_one)(inst.w, inst.n_real)
            pv, pg, po = (
                np.asarray(jax.device_get(v), np.float64)
                for v in probe(st, inst, aux, inst.n_real)
            )
            bad = ~np.isfinite(viol)
            viol = np.where(bad, pv, viol)
            gap = np.where(bad, pg, gap)
            obj = np.where(bad, po, obj)
        resbuf = np.asarray(jax.device_get(c.resbuf), np.float64)
        kcnt = np.asarray(jax.device_get(c.k), np.int64)
        R = self.residual_history
        out = []
        for b in slots:
            tag = self.tags[b]
            n = self._n_real.pop(tag)
            conv = bool(
                engine.harvest_converged(
                    self.stop_rule, self.tol,
                    viol[b: b + 1], gap[b: b + 1], obj[b: b + 1],
                    done[b: b + 1], div[b: b + 1],
                )[0]
            )
            row = resbuf[b]
            residuals = (
                row if kcnt[b] <= R else np.roll(row, -(kcnt[b] % R))
            )
            info = {
                "passes": int(passes[b]),
                "converged": conv,
                "diverged": bool(div[b]),
                "max_violation": float(viol[b]),
                "duality_gap": float(gap[b]),
                "qp_objective": float(qp[b]),
                "lp_objective": float(lp[b]),
                "stop_rule": self.stop_rule,
                "residuals": residuals,
                "n": n,
            }
            out.append((
                b, tag, x[b],
                None if f is None else f[b],
                info,
            ))
            self.tags[b] = None
        # Park the freed rows: a slot harvested at the pass cap has
        # done=False, passes==max_passes, and if it stays empty (queue
        # drained) it would flip the chunk loop's ``safe`` predicate and
        # route EVERY later chunk through the guarded per-pass-cond body
        # (~4x a plain chunk). Latching done=True freezes the row (same
        # freeze a converged slot gets — bitwise inert for co-residents)
        # and keeps the plain path; refill resets done at re-admitted
        # rows, so a parked slot is indistinguishable from a fresh one.
        park = self.solver._fn_cache.get("park")
        if park is None:
            park = self.solver._fn_cache["park"] = jax.jit(jnp.logical_or)
        freed = np.zeros((self.solver.batch,), bool)
        freed[slots] = True
        self.carry = dataclasses.replace(
            self.carry, done=park(self.carry.done, jnp.asarray(freed))
        )
        return out

"""Jitted wrapper for the fused pair/box projection kernel."""

from __future__ import annotations

import jax

from repro.kernels.pair_project.pair_project import pair_box_pallas

__all__ = ["pair_box_project"]


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def pair_box_project(x, f, d, w_x, w_f, y0, y1, yhi, ylo, mask, eps,
                     lo=0.0, hi=1.0, has_box=True, block=(128, 128)):
    return pair_box_pallas(
        x, f, d, w_x, w_f, y0, y1, yhi, ylo, mask, eps,
        lo=lo, hi=hi, has_box=has_box, block=block,
        interpret=not _on_tpu(),
    )

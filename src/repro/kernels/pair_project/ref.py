"""Pure-jnp oracle for the pair/box projection kernel.

One Dykstra visit to the four O(n²) constraint families of the CC LP
(paper eq. (3)), fully parallel across pairs:

    x - f <= d,   -x - f <= -d,   x <= hi,   -x <= -lo

Inputs/outputs are whole matrices (any shape); masked entries pass through.
"""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["pair_box_ref"]


def pair_box_ref(x, f, d, w_x, w_f, y0, y1, yhi, ylo, mask, eps, lo, hi,
                 has_box=True):
    dt = x.dtype
    eps = jnp.asarray(eps, dt)
    iw_x, iw_f = 1.0 / w_x, 1.0 / w_f
    denom = iw_x + iw_f
    # pair 0: x - f <= d
    xv = x + y0 * iw_x / eps
    fv = f - y0 * iw_f / eps
    th = eps * jnp.maximum(xv - fv - d, 0.0) / denom
    x1 = xv - th * iw_x / eps
    f1 = fv + th * iw_f / eps
    n0 = th
    # pair 1: -x - f <= -d
    xv = x1 - y1 * iw_x / eps
    fv = f1 - y1 * iw_f / eps
    th = eps * jnp.maximum(d - xv - fv, 0.0) / denom
    x1 = xv + th * iw_x / eps
    f1 = fv + th * iw_f / eps
    n1 = th
    if has_box:
        # box hi: x <= hi
        xv = x1 + yhi * iw_x / eps
        th_hi = eps * jnp.maximum(xv - hi, 0.0) / iw_x
        x1 = xv - th_hi * iw_x / eps
        # box lo: -x <= -lo
        xv = x1 - ylo * iw_x / eps
        th_lo = eps * jnp.maximum(lo - xv, 0.0) / iw_x
        x1 = xv + th_lo * iw_x / eps
    else:
        th_hi, th_lo = yhi, ylo
    out = lambda new, old: jnp.where(mask, new, old)
    return (out(x1, x), out(f1, f), out(n0, y0), out(n1, y1),
            out(th_hi, yhi), out(th_lo, ylo))

"""Pallas TPU kernel: fused pair + box Dykstra projections.

The O(n²) constraint families are embarrassingly parallel across pairs —
pure VPU work. Fusing all four visits into one kernel makes the pass read
(x, f, duals, weights) from HBM exactly once instead of four times; on the
bandwidth-bound pair step that is a 4× HBM-traffic reduction (this family is
memory-bound: ~30 flops vs 40 bytes per pair).

Grid tiles the (n, n) matrices in (block_r, block_c) VMEM blocks.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.pair_project.ref import pair_box_ref

__all__ = ["pair_box_pallas"]


def _kernel(x_ref, f_ref, d_ref, wx_ref, wf_ref, y0_ref, y1_ref, yhi_ref,
            ylo_ref, m_ref, ox_ref, of_ref, o0_ref, o1_ref, ohi_ref, olo_ref,
            *, eps: float, lo: float, hi: float, has_box: bool):
    out = pair_box_ref(
        x_ref[...], f_ref[...], d_ref[...], wx_ref[...], wf_ref[...],
        y0_ref[...], y1_ref[...], yhi_ref[...], ylo_ref[...],
        m_ref[...] != 0, eps, lo, hi, has_box,
    )
    for ref, val in zip((ox_ref, of_ref, o0_ref, o1_ref, ohi_ref, olo_ref), out):
        ref[...] = val


def pair_box_pallas(x, f, d, w_x, w_f, y0, y1, yhi, ylo, mask, eps,
                    lo=0.0, hi=1.0, has_box=True,
                    block=(128, 128), interpret=True):
    n0, n1 = x.shape
    br = min(block[0], n0)
    bc = min(block[1], n1)
    pr = -(-n0 // br) * br
    pc = -(-n1 // bc) * bc

    def pad(a, fill):
        if a.shape == (pr, pc):
            return a
        return jnp.pad(a, ((0, pr - n0), (0, pc - n1)), constant_values=fill)

    args = [pad(x, 0), pad(f, 0), pad(d, 0), pad(w_x, 1), pad(w_f, 1),
            pad(y0, 0), pad(y1, 0), pad(yhi, 0), pad(ylo, 0),
            pad(mask.astype(jnp.int8), 0)]
    spec = pl.BlockSpec((br, bc), lambda i, j: (i, j))
    grid = (pr // br, pc // bc)
    kernel = functools.partial(_kernel, eps=float(eps), lo=float(lo),
                               hi=float(hi), has_box=has_box)
    outs = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[spec] * 10,
        out_specs=[spec] * 6,
        out_shape=[jax.ShapeDtypeStruct((pr, pc), x.dtype)] * 6,
        interpret=interpret,
    )(*args)
    return tuple(o[:n0, :n1] for o in outs)

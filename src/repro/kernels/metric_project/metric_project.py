"""Pallas TPU kernel: conflict-free diagonal sweep for metric projections.

TPU adaptation of the paper's tiled triplet assignment (§III.C): the sets
``S_{i,k}`` of one conflict-free diagonal are mapped to VPU *lanes* (last dim,
blocks of ``block_c``); the sequential middle-index loop j = i+1..k-1 runs as a
``fori_loop`` over the sublane dimension with the shared ``x_ik`` carried in
registers. The buffers staged into VMEM are exactly the contiguous row/column
slices of X the paper's b×b×b cache cubes target — HBM→VMEM blocking replaces
L1/L2 cache blocking.

Grid: (num_c_blocks,). Block shapes: (T, block_c) for all (T, C) buffers and
(1, block_c) for the carries. VMEM footprint ≈ 12 · T · block_c · 4 bytes
(e.g. T=1024, block_c=128 → 6 MiB), within the ~16 MiB v5e VMEM budget; for
larger T the host splits the sweep (see ops.py).

``block_c`` is the tunable *tile size* — the analogue of the paper's Fig. 7
tile-size sweep, benchmarked in benchmarks/fig7_tilesize.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.metric_project.ref import triplet_visit

__all__ = ["sweep_pallas"]


def _sweep_kernel(
    rowb_ref,
    colb_ref,
    xik_ref,
    y0_ref,
    y1_ref,
    y2_ref,
    wrow_ref,
    wcol_ref,
    wik_ref,
    act_ref,
    orow_ref,
    ocol_ref,
    oxik_ref,
    o0_ref,
    o1_ref,
    o2_ref,
    *,
    eps: float,
    T: int,
):
    dt = rowb_ref.dtype
    eps = jnp.asarray(eps, dt)
    iw_ik = 1.0 / wik_ref[...]  # (1, Cb)

    def body(t, xik):
        sl = (pl.ds(t, 1), slice(None))
        xij = pl.load(rowb_ref, sl)
        xjk = pl.load(colb_ref, sl)
        v0 = pl.load(y0_ref, sl)
        v1 = pl.load(y1_ref, sl)
        v2 = pl.load(y2_ref, sl)
        act = pl.load(act_ref, sl) != 0
        iwij = 1.0 / pl.load(wrow_ref, sl)
        iwjk = 1.0 / pl.load(wcol_ref, sl)
        nij, nik, njk, t0, t1, t2 = triplet_visit(
            xij, xik, xjk, v0, v1, v2, iwij, iw_ik, iwjk, eps
        )
        pl.store(orow_ref, sl, jnp.where(act, nij, xij))
        pl.store(ocol_ref, sl, jnp.where(act, njk, xjk))
        pl.store(o0_ref, sl, jnp.where(act, t0, v0))
        pl.store(o1_ref, sl, jnp.where(act, t1, v1))
        pl.store(o2_ref, sl, jnp.where(act, t2, v2))
        return jnp.where(act, nik, xik)

    xik = jax.lax.fori_loop(0, T, body, xik_ref[...])
    oxik_ref[...] = xik


def sweep_pallas(
    rowb,
    colb,
    xik,
    y0,
    y1,
    y2,
    w_row,
    w_col,
    w_ik,
    active,
    eps,
    *,
    block_c: int = 128,
    interpret: bool = True,
):
    """Pallas diagonal sweep. Same contract as ref.sweep_ref.

    Shapes: (T, C) buffers; (C,) for xik / w_ik. C is padded to a multiple of
    ``block_c`` here; padding lanes carry active=False.
    """
    T, C = rowb.shape
    dt = rowb.dtype
    Cp = -(-C // block_c) * block_c

    def padc(a, fill):
        if a.shape[-1] == Cp:
            return a
        pad = [(0, 0)] * (a.ndim - 1) + [(0, Cp - C)]
        return jnp.pad(a, pad, constant_values=fill)

    rowb_, colb_ = padc(rowb, 0), padc(colb, 0)
    y0_, y1_, y2_ = padc(y0, 0), padc(y1, 0), padc(y2, 0)
    wrow_, wcol_ = padc(w_row, 1), padc(w_col, 1)
    xik_ = padc(xik[None, :], 0)
    wik_ = padc(w_ik[None, :], 1)
    act_ = padc(active.astype(jnp.int8), 0)

    tc_spec = pl.BlockSpec((T, block_c), lambda c: (0, c))
    c_spec = pl.BlockSpec((1, block_c), lambda c: (0, c))
    grid = (Cp // block_c,)
    kernel = functools.partial(_sweep_kernel, eps=float(eps), T=T)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            tc_spec, tc_spec, c_spec, tc_spec, tc_spec, tc_spec,
            tc_spec, tc_spec, c_spec, tc_spec,
        ],
        out_specs=[tc_spec, tc_spec, c_spec, tc_spec, tc_spec, tc_spec],
        out_shape=[
            jax.ShapeDtypeStruct((T, Cp), dt),
            jax.ShapeDtypeStruct((T, Cp), dt),
            jax.ShapeDtypeStruct((1, Cp), dt),
            jax.ShapeDtypeStruct((T, Cp), dt),
            jax.ShapeDtypeStruct((T, Cp), dt),
            jax.ShapeDtypeStruct((T, Cp), dt),
        ],
        interpret=interpret,
    )(rowb_, colb_, xik_, y0_, y1_, y2_, wrow_, wcol_, wik_, act_)
    nrow, ncol, nxik, n0, n1, n2 = out
    return (
        nrow[:, :C],
        ncol[:, :C],
        nxik[0, :C],
        n0[:, :C],
        n1[:, :C],
        n2[:, :C],
    )

"""Pallas TPU kernel: conflict-free diagonal sweep for metric projections.

TPU adaptation of the paper's tiled triplet assignment (§III.C): the sets
``S_{i,k}`` of one conflict-free diagonal are mapped to VPU *lanes* (last dim,
blocks of ``block_c``); the sequential middle-index loop runs as a
``fori_loop`` over the sublane dimension with the shared ``x_ik`` carried in
registers. Lanes are *folded* (core/schedule.py): each packs up to two sets
head-to-tail, with ``seg`` selecting which of the two ``x_ik`` carries is
live at step t — this evens out lane heights so the staged buffers carry
almost no padding. The buffers staged into VMEM are exactly the contiguous
row/column slices of X the paper's b×b×b cache cubes target — HBM→VMEM
blocking replaces L1/L2 cache blocking.

Grid: (num_c_blocks,). Block shapes: (T, block_c) for all (T, C) buffers and
(2, block_c) for the carries. VMEM footprint ≈ 13 · T · block_c · 4 bytes
(e.g. T=1024, block_c=128 → 6.5 MiB), within the ~16 MiB v5e VMEM budget; for
larger T the host splits the sweep (see ops.py).

With ``in_place=True`` the three dual blocks are aliased input→output
(``input_output_aliases``), so the schedule-native dual slabs are updated in
their own buffers rather than round-tripped as separate outputs.

``block_c`` is the tunable *tile size* — the analogue of the paper's Fig. 7
tile-size sweep, benchmarked in benchmarks/fig7_tilesize.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.metric_project.ref import triplet_visit

__all__ = ["sweep_pallas", "sweep_pallas_folded"]


def _sweep_kernel(
    rowb_ref,
    colb_ref,
    xikp_ref,
    y0_ref,
    y1_ref,
    y2_ref,
    wrow_ref,
    wcol_ref,
    wikp_ref,
    act_ref,
    seg_ref,
    orow_ref,
    ocol_ref,
    oxikp_ref,
    o0_ref,
    o1_ref,
    o2_ref,
    *,
    eps: float,
    T: int,
):
    dt = rowb_ref.dtype
    eps = jnp.asarray(eps, dt)
    iw_a = 1.0 / wikp_ref[0:1, :]  # (1, Cb)
    iw_b = 1.0 / wikp_ref[1:2, :]

    def body(t, carry):
        xa, xb = carry
        sl = (pl.ds(t, 1), slice(None))
        xij = pl.load(rowb_ref, sl)
        xjk = pl.load(colb_ref, sl)
        v0 = pl.load(y0_ref, sl)
        v1 = pl.load(y1_ref, sl)
        v2 = pl.load(y2_ref, sl)
        act = pl.load(act_ref, sl) != 0
        sg = pl.load(seg_ref, sl) != 0
        iwij = 1.0 / pl.load(wrow_ref, sl)
        iwjk = 1.0 / pl.load(wcol_ref, sl)
        xc = jnp.where(sg, xb, xa)
        iw_ik = jnp.where(sg, iw_b, iw_a)
        nij, nik, njk, t0, t1, t2 = triplet_visit(
            xij, xc, xjk, v0, v1, v2, iwij, iw_ik, iwjk, eps
        )
        pl.store(orow_ref, sl, jnp.where(act, nij, xij))
        pl.store(ocol_ref, sl, jnp.where(act, njk, xjk))
        pl.store(o0_ref, sl, jnp.where(act, t0, v0))
        pl.store(o1_ref, sl, jnp.where(act, t1, v1))
        pl.store(o2_ref, sl, jnp.where(act, t2, v2))
        nik = jnp.where(act, nik, xc)
        return jnp.where(sg, xa, nik), jnp.where(sg, nik, xb)

    xa, xb = jax.lax.fori_loop(
        0, T, body, (xikp_ref[0:1, :], xikp_ref[1:2, :])
    )
    oxikp_ref[0:1, :] = xa
    oxikp_ref[1:2, :] = xb


def sweep_pallas_folded(
    rowb,
    colb,
    xikp,
    y0,
    y1,
    y2,
    w_row,
    w_col,
    w_ikp,
    active,
    seg,
    eps,
    *,
    block_c: int = 128,
    interpret: bool = True,
    in_place: bool = False,
):
    """Pallas folded diagonal sweep. Same contract as ref.sweep_ref_folded.

    Shapes: (T, C) buffers; (2, C) for xikp / w_ikp; (T, C) bool seg. C is
    padded to a multiple of ``block_c`` here; padding lanes carry
    active=False.

    ``in_place=True`` aliases the three dual inputs to the three dual outputs
    (``input_output_aliases``), so the kernel updates the dual blocks in
    their VMEM/HBM buffers instead of round-tripping through separate
    outputs — the schedule-native storage never needs the pre-sweep dual
    values again (DESIGN.md §3). Only enable under jit (XLA inserts copies if
    the donated inputs have other uses; eager callers would see their arrays
    deleted).
    """
    T, C = rowb.shape
    dt = rowb.dtype
    Cp = -(-C // block_c) * block_c

    def padc(a, fill):
        if a.shape[-1] == Cp:
            return a
        pad = [(0, 0)] * (a.ndim - 1) + [(0, Cp - C)]
        return jnp.pad(a, pad, constant_values=fill)

    rowb_, colb_ = padc(rowb, 0), padc(colb, 0)
    y0_, y1_, y2_ = padc(y0, 0), padc(y1, 0), padc(y2, 0)
    wrow_, wcol_ = padc(w_row, 1), padc(w_col, 1)
    xikp_ = padc(xikp, 0)
    wikp_ = padc(w_ikp, 1)
    act_ = padc(active.astype(jnp.int8), 0)
    seg_ = padc(seg.astype(jnp.int8), 0)

    tc_spec = pl.BlockSpec((T, block_c), lambda c: (0, c))
    p_spec = pl.BlockSpec((2, block_c), lambda c: (0, c))
    grid = (Cp // block_c,)
    kernel = functools.partial(_sweep_kernel, eps=float(eps), T=T)
    # Dual buffers y0/y1/y2 (inputs 3..5) alias outputs o0/o1/o2 (3..5):
    # their pre-sweep values are dead after the kernel, so the blocks are
    # overwritten in place rather than allocated as fresh outputs.
    aliases = {3: 3, 4: 4, 5: 5} if in_place else {}
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            tc_spec, tc_spec, p_spec, tc_spec, tc_spec, tc_spec,
            tc_spec, tc_spec, p_spec, tc_spec, tc_spec,
        ],
        out_specs=[tc_spec, tc_spec, p_spec, tc_spec, tc_spec, tc_spec],
        input_output_aliases=aliases,
        out_shape=[
            jax.ShapeDtypeStruct((T, Cp), dt),
            jax.ShapeDtypeStruct((T, Cp), dt),
            jax.ShapeDtypeStruct((2, Cp), dt),
            jax.ShapeDtypeStruct((T, Cp), dt),
            jax.ShapeDtypeStruct((T, Cp), dt),
            jax.ShapeDtypeStruct((T, Cp), dt),
        ],
        interpret=interpret,
    )(rowb_, colb_, xikp_, y0_, y1_, y2_, wrow_, wcol_, wikp_, act_, seg_)
    nrow, ncol, nxikp, n0, n1, n2 = out
    return (
        nrow[:, :C],
        ncol[:, :C],
        nxikp[:, :C],
        n0[:, :C],
        n1[:, :C],
        n2[:, :C],
    )


def sweep_pallas(
    rowb,
    colb,
    xik,
    y0,
    y1,
    y2,
    w_row,
    w_col,
    w_ik,
    active,
    eps,
    *,
    block_c: int = 128,
    interpret: bool = True,
    in_place: bool = False,
):
    """Unfolded Pallas diagonal sweep. Same contract as ref.sweep_ref:
    (T, C) buffers, (C,) xik / w_ik — a folded sweep with an empty B
    segment. Kept as the kernel's oracle-validated entry point."""
    xikp = jnp.stack([xik, jnp.zeros_like(xik)])
    w_ikp = jnp.stack([w_ik, jnp.ones_like(w_ik)])
    seg = jnp.zeros_like(active)
    nrow, ncol, nxikp, n0, n1, n2 = sweep_pallas_folded(
        rowb, colb, xikp, y0, y1, y2, w_row, w_col, w_ikp, active, seg, eps,
        block_c=block_c, interpret=interpret, in_place=in_place,
    )
    return nrow, ncol, nxikp[0], n0, n1, n2

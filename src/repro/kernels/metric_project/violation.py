"""Pallas kernel: max triangle-inequality violation, lane-blocked 3-D grid.

The convergence engine's hot probe (DESIGN.md §7/§14). The triangle family
has C(n, 3) constraints but the violation reduction only ever needs one
(apex block, row block, column block) tile in flight: for apexes ``c``,
long-edge rows ``a`` and columns ``b`` the slack tensor is

    slack[c, a, b] = xs[a, b] - (xs[a, c] + xs[c, b])

with xs the symmetrized iterate. Grid = (apex blocks, column blocks,
row blocks), row-major, so for a fixed apex block the column blocks sweep
and within each column step the row blocks stream:

  * the **apex tile** ``xs[c0:c0+A, b0:b0+C]`` maps to a block indexed by
    the (apex, column) program ids — resident across the whole inner row
    sweep of its column step;
  * the **row tiles** ``xs[r0:r0+R, b0:b0+C]`` map to a block indexed by
    the (row, column) ids — Pallas's grid pipeline double-buffers this
    DMA, so the next row tile streams HBM→VMEM while the current one
    reduces (the kernel-level analogue of the §4 megakernel's staging);
  * ``xs[a, c]`` comes from the **apex-transpose tile**
    ``xa[c0:c0+A, r0:r0+R]`` (row c equals column c by symmetry), a third
    small (A, R) operand — under lane blocking the apex columns generally
    live outside the current column block, so the PR-5 trick of slicing
    them out of the full-width row slab no longer applies;
  * a (1, 1) SMEM accumulator carries the running max across the
    sequential TPU grid — race-free, init at step (0, 0, 0); a (1, 1)
    SMEM *input* carries the apex-index offset of slab calls (below).

This is the piece that makes the device-resident stopping rule work at
n ≫ 10³: VMEM per step is ≈ (A + R)·block_c + A·R floats of x tiles plus
the (A, R, block_c) slack tile — **never** a full-width (·, npad) slab.
The PR-5 kernel streamed full-width row slabs, which caps out once
npad·(A + R + A·R) floats outgrow VMEM (n ≈ 10⁴ at the defaults); with
``block_c`` the budget is independent of n. At A = 8, R = 128, C = 512
f32 the tiles hold ~0.3 MB and the slack ~2.1 MB per step — pick
``block_c ≈ VMEM / (4·A·block_r)``.

``block_c=None`` (the default) keeps a single full-width column block —
identical tiling to the PR-5 kernel, the right call at n ≲ 2·10³.

**Slab entry** (``max_triangle_violation_slab_pallas``): the sharded
probe deals contiguous apex-row slabs over the mesh (DESIGN.md §14), so
each device reduces only the apexes ``offset + i`` whose rows it holds in
``xa`` while drawing (a, b) from the replicated full matrix. The solo
entry is the slab entry with ``xa = xs`` and offset 0 — one kernel body
serves both; a pmax over devices merges the partial maxima exactly
because max is association-free.

The masked slack expression matches ``metrics_device._apex_block_max``
term-for-term (and the host oracle's fp association), so kernel vs jnp
parity is exact for the max at any blocking.

On CPU (this container) the kernel runs in interpret mode; the grid is
executed sequentially there too, so the accumulator contract holds.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = [
    "max_triangle_violation_pallas",
    "max_triangle_violation_slab_pallas",
]


def _viol_kernel(off_ref, xa_ref, xat_ref, xr_ref, o_ref, *, n: int,
                 block_a: int, block_r: int, block_c: int):
    a_id = pl.program_id(0)
    c_id = pl.program_id(1)
    r_id = pl.program_id(2)
    r0 = r_id * block_r
    b0 = c_id * block_c
    apex = xa_ref[...]   # (A, C): xs[c, b] tile of this apex/column block
    rowc = xat_ref[...]  # (A, R): xs[c, a] == xs[a, c] by symmetry
    rows = xr_ref[...]   # (R, C): xs[a, b] tile of this row/column block
    slack = rows[None, :, :] - (
        rowc[:, :, None] + apex[:, None, :]
    )  # (A, R, C)
    # Global indices: apexes are offset by the slab origin (0 for the solo
    # entry; rank * rows_per_device under the sharded dealing) — slab
    # padding rows then carry indices >= n and mask out like grid padding.
    ci = (
        jax.lax.broadcasted_iota(jnp.int32, slack.shape, 0)
        + off_ref[0, 0] + a_id * block_a
    )
    ai = jax.lax.broadcasted_iota(jnp.int32, slack.shape, 1) + r0
    bi = jax.lax.broadcasted_iota(jnp.int32, slack.shape, 2) + b0
    ok = (
        (ai != bi) & (ci != ai) & (ci != bi)
        & (ai < n) & (bi < n) & (ci < n)
    )
    m = jnp.max(jnp.where(ok, slack, -jnp.inf))

    first = (a_id == 0) & (c_id == 0) & (r_id == 0)

    @pl.when(first)
    def _init():
        o_ref[0, 0] = m

    @pl.when(jnp.logical_not(first))
    def _accum():
        o_ref[0, 0] = jnp.maximum(o_ref[0, 0], m)


def _viol_call(xa, off, xp, *, live: int, block_a: int, block_r: int,
               block_c: int, interpret: bool):
    """One pallas_call over the (apex, column, row) grid. ``xa`` is the
    (m, npad) apex-row slab (m % block_a == 0), ``xp`` the (npad, npad)
    padded symmetric matrix, ``off`` a (1, 1) int32 apex-index offset."""
    m, npad = xa.shape
    assert m % block_a == 0 and npad % block_r == 0 and npad % block_c == 0
    return pl.pallas_call(
        functools.partial(
            _viol_kernel, n=live, block_a=block_a, block_r=block_r,
            block_c=block_c,
        ),
        grid=(m // block_a, npad // block_c, npad // block_r),
        in_specs=[
            # apex offset: one SMEM scalar, shared by every grid step
            pl.BlockSpec(memory_space=pltpu.SMEM),
            # apex tile: constant across the inner row sweep
            pl.BlockSpec((block_a, block_c), lambda a, c, r: (a, c)),
            # apex-transpose tile: xs[c, a] for the xs[a, c] term
            pl.BlockSpec((block_a, block_r), lambda a, c, r: (a, r)),
            # row tiles: streamed, double-buffered by the grid pipeline
            pl.BlockSpec((block_r, block_c), lambda a, c, r: (r, c)),
        ],
        out_specs=pl.BlockSpec(memory_space=pltpu.SMEM),
        out_shape=jax.ShapeDtypeStruct((1, 1), xa.dtype),
        interpret=interpret,
    )(off, xa, xa, xp)[0, 0]


def _resolve_blocks(n: int, block: int, block_r: int, block_c: int | None):
    """Clamp the streamed block sizes to the block-aligned matrix width
    and compute the common padding step. A ``block_r``/``block_c`` above
    the aligned width would only inflate npad (lcm padding) and the
    per-step slack tile — at small n the whole matrix is one block
    anyway, which is exactly the regime where residency is fine."""
    npad_a = -(-max(n, block) // block) * block
    block_r = min(block_r, npad_a)
    if block_c is not None:
        block_c = min(int(block_c), npad_a)
        step = math.lcm(block, block_r, block_c)
    else:
        step = math.lcm(block, block_r)
    npad = -(-max(n, step) // step) * step
    return block_r, (npad if block_c is None else block_c), npad


@functools.partial(
    jax.jit,
    static_argnames=("block", "block_r", "block_c", "interpret", "n_live"),
)
def max_triangle_violation_pallas(xs, *, block: int = 8,
                                  block_r: int = 128,
                                  block_c: int | None = None,
                                  interpret: bool = True,
                                  n_live: int | None = None):
    """Max triangle slack of the symmetric iterate ``xs`` ((n, n), as built
    by ``metrics_device.symmetrize``). ``block`` is the apex-block height,
    ``block_r`` the streamed row-block height, ``block_c`` the lane
    (column) block width — None keeps one full-width column block (see
    module docstring for the VMEM budget each choice buys).
    ``n_live`` restricts the reduction to triangles with every index
    < n_live — the ghost-padding contract (DESIGN.md §8), identical to
    slicing xs[:n_live, :n_live] first but without a copy. Returns a
    scalar; -inf when fewer than 3 live points. Drop-in for
    ``metrics_device.triangle_violation``."""
    n = xs.shape[0]
    live = n if n_live is None else min(int(n_live), n)
    block_r, bc, npad = _resolve_blocks(n, block, block_r, block_c)
    xp = jnp.pad(xs, ((0, npad - n), (0, npad - n)))
    return _viol_call(
        xp, jnp.zeros((1, 1), jnp.int32), xp,
        live=live, block_a=block, block_r=block_r, block_c=bc,
        interpret=interpret,
    )


@functools.partial(
    jax.jit,
    static_argnames=("block", "block_r", "block_c", "interpret", "n_live"),
)
def max_triangle_violation_slab_pallas(xa, offset, xs, *, block: int = 8,
                                       block_r: int = 128,
                                       block_c: int | None = None,
                                       interpret: bool = True,
                                       n_live: int | None = None):
    """Partial triangle-slack max over one contiguous apex-row slab — the
    per-device body of the kernel-backed sharded probe (DESIGN.md §14).

    ``xa`` ((m, n), m a multiple of ``block``) holds rows
    ``xs[offset : offset + m]`` of the symmetric iterate; ``offset`` is a
    (traced) int32 scalar. The reduction covers exactly the triangles
    whose apex index ``c = offset + i`` is < n_live (slab rows past the
    matrix carry indices >= n and mask out), with (a, b) drawn from the
    full replicated ``xs`` — so a ``pmax`` over contiguous slabs dealt
    across a mesh equals the solo entry exactly (max is
    association-free). Returns -inf for an all-padding slab."""
    m, n = xa.shape
    assert xs.shape == (n, n), (xa.shape, xs.shape)
    assert m % block == 0, (
        f"apex slab rows ({m}) must be a multiple of the apex block "
        f"({block}); deal block-aligned slabs"
    )
    live = n if n_live is None else min(int(n_live), n)
    block_r, bc, npad = _resolve_blocks(n, block, block_r, block_c)
    xp = jnp.pad(xs, ((0, npad - n), (0, npad - n)))
    xap = jnp.pad(xa, ((0, 0), (0, npad - n)))
    off = jnp.reshape(offset, (1, 1)).astype(jnp.int32)
    return _viol_call(
        xap, off, xp,
        live=live, block_a=block, block_r=block_r, block_c=bc,
        interpret=interpret,
    )

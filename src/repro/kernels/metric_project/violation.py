"""Pallas kernel: max triangle-inequality violation, 2-D blocked grid.

The convergence engine's hot probe (DESIGN.md §7). The triangle family has
C(n, 3) constraints but the violation reduction only ever needs one
(apex block, row block) tile in flight: for apexes ``c`` and long-edge
rows ``a`` the slack tensor is

    slack[c, a, b] = xs[a, b] - (xs[a, c] + xs[c, b])

with xs the symmetrized iterate. Grid = (apex blocks, row blocks),
row-major, so for a fixed apex block the row blocks stream while the apex
block stays put:

  * the **apex rows** ``xs[c0:c0+A, :]`` map to a block indexed by the
    apex program id only — fetched once per apex block, resident across
    the whole inner row sweep;
  * the **row blocks** ``xs[r0:r0+R, :]`` map to a block indexed by the
    row program id — Pallas's grid pipeline double-buffers this DMA, so
    the next row block streams HBM→VMEM while the current one reduces
    (the kernel-level analogue of the §4 megakernel's staging);
  * ``xs[a, c]`` is a column slice of the *row* block at dynamic offset
    c0 — no third fetch;
  * a (1, 1) SMEM accumulator carries the running max across the
    sequential TPU grid — race-free, init at step (0, 0).

This is what makes the device-resident stopping rule work at n ≫ 10³:
VMEM per step is ≈ (A + R) · npad floats (the two row slabs) plus the
(A, R, npad) slack tile, **never** a resident (npad, npad) matrix — the
PR-3 kernel kept all of xs in VMEM and capped out around n ≈ 2000 (16 MB
f32). The slack tile dominates, so A·R must shrink as n grows: at
n = 10⁴ f32, A = 8 with R = 8 holds ~0.64 MB of x slabs + ~2.6 MB of
slack per step (R = 128 would need ~41 MB — pick R ≈ VMEM/(4·A·npad)).

The masked slack expression matches ``metrics_device._apex_block_max``
term-for-term (and the host oracle's fp association), so kernel vs jnp
parity is exact for the max (max is association-free).

On CPU (this container) the kernel runs in interpret mode; the grid is
executed sequentially there too, so the accumulator contract holds.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["max_triangle_violation_pallas"]


def _viol_kernel(xa_ref, xr_ref, o_ref, *, n: int, block_a: int,
                 block_r: int):
    a_id = pl.program_id(0)
    r_id = pl.program_id(1)
    npad = xa_ref.shape[1]
    c0 = a_id * block_a
    r0 = r_id * block_r
    apex = xa_ref[...]  # (A, npad): xs[c, b] rows of this apex block
    rows = xr_ref[...]  # (R, npad): xs[a, b] rows of this row block
    # xs[a, c]: column slice of the row block at the apex offset — row c
    # equals column c by symmetry, so no third operand is fetched.
    rowc = pl.load(xr_ref, (slice(None), pl.ds(c0, block_a)))  # (R, A)
    slack = rows[None, :, :] - (
        jnp.swapaxes(rowc, 0, 1)[:, :, None] + apex[:, None, :]
    )  # (A, R, npad)
    ai = jax.lax.broadcasted_iota(jnp.int32, slack.shape, 1) + r0
    bi = jax.lax.broadcasted_iota(jnp.int32, slack.shape, 2)
    ci = jax.lax.broadcasted_iota(jnp.int32, slack.shape, 0) + c0
    ok = (
        (ai != bi) & (ci != ai) & (ci != bi)
        & (ai < n) & (bi < n) & (ci < n)
    )
    m = jnp.max(jnp.where(ok, slack, -jnp.inf))

    first = (a_id == 0) & (r_id == 0)

    @pl.when(first)
    def _init():
        o_ref[0, 0] = m

    @pl.when(jnp.logical_not(first))
    def _accum():
        o_ref[0, 0] = jnp.maximum(o_ref[0, 0], m)


@functools.partial(
    jax.jit, static_argnames=("block", "block_r", "interpret", "n_live")
)
def max_triangle_violation_pallas(xs, *, block: int = 8,
                                  block_r: int = 128,
                                  interpret: bool = True,
                                  n_live: int | None = None):
    """Max triangle slack of the symmetric iterate ``xs`` ((n, n), as built
    by ``metrics_device.symmetrize``). ``block`` is the apex-block height,
    ``block_r`` the streamed row-block height (see module docstring).
    ``n_live`` restricts the reduction to triangles with every index
    < n_live — the ghost-padding contract (DESIGN.md §8), identical to
    slicing xs[:n_live, :n_live] first but without a copy. Returns a
    scalar; -inf when fewer than 3 live points. Drop-in for
    ``metrics_device.triangle_violation``."""
    n = xs.shape[0]
    live = n if n_live is None else min(int(n_live), n)
    # Never stream more rows than the block-aligned matrix holds: a
    # block_r above that would only inflate npad (lcm padding) and the
    # per-step slack tile — at n <= block_r the whole matrix is one row
    # block anyway, which is exactly the small-n regime where residency
    # is fine.
    npad_a = -(-max(n, block) // block) * block
    block_r = min(block_r, npad_a)
    step = math.lcm(block, block_r)
    npad = -(-max(n, step) // step) * step
    xp = jnp.pad(xs, ((0, npad - n), (0, npad - n)))
    out = pl.pallas_call(
        functools.partial(
            _viol_kernel, n=live, block_a=block, block_r=block_r
        ),
        grid=(npad // block, npad // block_r),
        in_specs=[
            # apex rows: constant across the inner row sweep
            pl.BlockSpec((block, npad), lambda a, r: (a, 0)),
            # row blocks: streamed, double-buffered by the grid pipeline
            pl.BlockSpec((block_r, npad), lambda a, r: (r, 0)),
        ],
        out_specs=pl.BlockSpec(memory_space=pltpu.SMEM),
        out_shape=jax.ShapeDtypeStruct((1, 1), xs.dtype),
        interpret=interpret,
    )(xp, xp)
    return out[0, 0]

"""Pallas kernel: max triangle-inequality violation, blocked over apexes.

The convergence engine's hot probe (DESIGN.md §7). The triangle family has
C(n, 3) constraints but the violation reduction only ever needs one apex
block in flight: for a block of apexes ``c`` the slack tensor is

    slack[c, a, b] = xs[a, b] - (xs[a, c] + xs[c, b])

with xs the symmetrized iterate. Grid = apex blocks; xs maps to a
constant-index block (resident in VMEM across the whole grid, like the
megakernel's X), each step reduces its (B, n, n) slack block to a scalar,
and a (1, 1) SMEM accumulator carries the running max across grid steps —
TPU grids are sequential, so the accumulation is race-free.

The masked slack expression matches ``metrics_device._apex_block_max``
term-for-term (and the host oracle's fp association), so kernel vs jnp
parity is exact for the max (max is association-free).

VMEM per step ≈ (B + 1) · npad² floats: n = 96, B = 8, f32 → ~0.35 MiB.
On CPU (this container) the kernel runs in interpret mode.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["max_triangle_violation_pallas"]


def _viol_kernel(x_ref, o_ref, *, n: int, block: int):
    b = pl.program_id(0)
    npad = x_ref.shape[0]
    c0 = b * block
    xs = x_ref[...]
    xb = pl.load(x_ref, (pl.ds(c0, block), slice(None)))  # (B, npad)
    slack = xs[None, :, :] - (xb[:, :, None] + xb[:, None, :])
    ai = jax.lax.broadcasted_iota(jnp.int32, (block, npad, npad), 1)
    bi = jax.lax.broadcasted_iota(jnp.int32, (block, npad, npad), 2)
    ci = jax.lax.broadcasted_iota(jnp.int32, (block, npad, npad), 0) + c0
    ok = (
        (ai != bi) & (ci != ai) & (ci != bi)
        & (ai < n) & (bi < n) & (ci < n)
    )
    m = jnp.max(jnp.where(ok, slack, -jnp.inf))

    @pl.when(b == 0)
    def _init():
        o_ref[0, 0] = m

    @pl.when(b > 0)
    def _accum():
        o_ref[0, 0] = jnp.maximum(o_ref[0, 0], m)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def max_triangle_violation_pallas(xs, *, block: int = 8, interpret: bool = True):
    """Max triangle slack of the symmetric iterate ``xs`` ((n, n), as built
    by ``metrics_device.symmetrize``). Returns a scalar; -inf when n < 3.
    Drop-in for ``metrics_device.triangle_violation``."""
    n = xs.shape[0]
    npad = -(-max(n, block) // block) * block
    xp = jnp.pad(xs, ((0, npad - n), (0, npad - n)))
    out = pl.pallas_call(
        functools.partial(_viol_kernel, n=n, block=block),
        grid=(npad // block,),
        in_specs=[pl.BlockSpec((npad, npad), lambda b: (0, 0))],
        out_specs=pl.BlockSpec(memory_space=pltpu.SMEM),
        out_shape=jax.ShapeDtypeStruct((1, 1), xs.dtype),
        interpret=interpret,
    )(xp)
    return out[0, 0]

"""Jitted public wrapper for the metric-projection diagonal sweep.

On TPU, ``interpret=False`` compiles the Mosaic kernel; on CPU (this
container) the kernel body executes in interpret mode, which is how it is
validated against ``ref.sweep_ref`` in tests/test_kernels.py.
"""

from __future__ import annotations

import functools

import jax

from repro.kernels.metric_project.metric_project import sweep_pallas

__all__ = ["diagonal_sweep", "set_default_block_c"]

_DEFAULT_BLOCK_C = 128


def set_default_block_c(block_c: int) -> None:
    """Set the lane-tile size (paper Fig. 7 'tile size' analogue)."""
    global _DEFAULT_BLOCK_C
    _DEFAULT_BLOCK_C = int(block_c)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("block_c",))
def _sweep_jit(rowb, colb, xik, y0, y1, y2, w_row, w_col, w_ik, active, eps,
               block_c):
    return sweep_pallas(
        rowb, colb, xik, y0, y1, y2, w_row, w_col, w_ik, active, eps,
        block_c=block_c, interpret=not _on_tpu(),
    )


def diagonal_sweep(rowb, colb, xik, y0, y1, y2, w_row, w_col, w_ik, active,
                   eps, block_c: int | None = None):
    """Drop-in replacement for ref.sweep_ref backed by the Pallas kernel."""
    bc = block_c or _DEFAULT_BLOCK_C
    return sweep_pallas(
        rowb, colb, xik, y0, y1, y2, w_row, w_col, w_ik, active, eps,
        block_c=bc, interpret=not _on_tpu(),
    )

"""Jitted public wrappers for the metric-projection diagonal sweep.

On TPU, ``interpret=False`` compiles the Mosaic kernel; on CPU (this
container) the kernel body executes in interpret mode, which is how it is
validated against ``ref.sweep_ref`` in tests/test_kernels.py.

Entry points:
  * ``diagonal_sweep``       — six-buffer unfolded contract (matches
    ref.sweep_ref); kept for kernel validation and external callers.
  * ``diagonal_sweep_slab``  — schedule-native folded contract (matches
    ref.sweep_ref_slab): duals as one (3, T, C) slab, two x_ik carries per
    folded lane, dual blocks updated in place in the kernel via
    input/output aliasing (DESIGN.md §3). Used by the sharded solver and
    the legacy (``fused=False``) single-device path.
  * ``fused_bucket_pass``    — whole-bucket megakernel (matches
    ref.fused_bucket_pass_ref): one pallas_call per bucket per pass, X
    resident in VMEM across diagonals, duals and X aliased in place
    (DESIGN.md §4). This is what ``ParallelSolver`` calls by default.

All route through ``jax.jit``-cached wrappers so repeated sweeps of the
same shape never retrace.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.metric_project.fused_pass import fused_bucket_pass_pallas
from repro.kernels.metric_project.metric_project import (
    sweep_pallas,
    sweep_pallas_folded,
)
from repro.kernels.metric_project.violation import max_triangle_violation_pallas

__all__ = [
    "diagonal_sweep",
    "diagonal_sweep_slab",
    "fused_bucket_pass",
    "set_default_block_c",
    "triangle_violation",
]

_DEFAULT_BLOCK_C = 128


def set_default_block_c(block_c: int) -> None:
    """Set the lane-tile size (paper Fig. 7 'tile size' analogue)."""
    global _DEFAULT_BLOCK_C
    _DEFAULT_BLOCK_C = int(block_c)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


# eps is static: sweep_pallas bakes it into the kernel body as a python
# float (it is a problem constant, so this never causes retracing).
@functools.partial(jax.jit, static_argnames=("eps", "block_c", "interpret"))
def _sweep_jit(rowb, colb, xik, y0, y1, y2, w_row, w_col, w_ik, active, eps,
               block_c, interpret):
    return sweep_pallas(
        rowb, colb, xik, y0, y1, y2, w_row, w_col, w_ik, active, eps,
        block_c=block_c, interpret=interpret,
    )


@functools.partial(jax.jit, static_argnames=("eps", "block_c", "interpret"))
def _sweep_folded_jit(rowb, colb, xikp, yslab, w_row, w_col, w_ikp, active,
                      seg, eps, block_c, interpret):
    # in_place is safe here: under jit, XLA copies any donated dual buffer
    # that is still live in the caller; fresh buffers are updated in place.
    nrow, ncol, nxikp, n0, n1, n2 = sweep_pallas_folded(
        rowb, colb, xikp, yslab[0], yslab[1], yslab[2],
        w_row, w_col, w_ikp, active, seg, eps,
        block_c=block_c, interpret=interpret, in_place=True,
    )
    return nrow, ncol, nxikp, jnp.stack([n0, n1, n2])


def diagonal_sweep(rowb, colb, xik, y0, y1, y2, w_row, w_col, w_ik, active,
                   eps, block_c: int | None = None):
    """Drop-in replacement for ref.sweep_ref backed by the Pallas kernel."""
    bc = block_c or _DEFAULT_BLOCK_C
    return _sweep_jit(
        rowb, colb, xik, y0, y1, y2, w_row, w_col, w_ik, active,
        eps=float(eps), block_c=bc, interpret=not _on_tpu(),
    )


def diagonal_sweep_slab(rowb, colb, xikp, yslab, w_row, w_col, w_ikp, active,
                        seg, eps, block_c: int | None = None):
    """Drop-in replacement for ref.sweep_ref_slab backed by the Pallas
    kernel. ``yslab`` is the (3, T, C) schedule-native dual slab; the three
    (T, C) planes are contiguous slices, aliased in place inside the kernel.
    """
    bc = block_c or _DEFAULT_BLOCK_C
    return _sweep_folded_jit(
        rowb, colb, xikp, yslab, w_row, w_col, w_ikp, active, seg,
        eps=float(eps), block_c=bc, interpret=not _on_tpu(),
    )


@functools.partial(jax.jit, static_argnames=("block_c", "interpret"))
def _fused_pass_jit(x, yslab, lanes, g_row, g_col, g_sel, dinv, act, seg,
                    block_c, interpret):
    # in_place is safe here for both X and the dual slab: under jit, XLA
    # copies any donated buffer that is still live in the caller.
    return fused_bucket_pass_pallas(
        x, yslab, lanes, g_row, g_col, g_sel, dinv, act, seg,
        block_c=block_c, interpret=interpret, in_place=True,
    )


def triangle_violation(xs, block: int = 8, block_r: int = 128):
    """Max triangle slack of the symmetric iterate (the convergence
    engine's probe; DESIGN.md §7) backed by the 2-D-grid Pallas kernel
    (apex blocks × streamed row blocks — works at n ≫ 10³ without a
    VMEM-resident (n, n) matrix); drop-in for
    ``metrics_device.triangle_violation``."""
    return max_triangle_violation_pallas(
        xs, block=block, block_r=block_r, interpret=not _on_tpu()
    )


def fused_bucket_pass(x, yslab, bucket, block_c: int | None = None):
    """Whole-bucket fused pass backed by the Pallas megakernel; drop-in for
    ``ref.fused_bucket_pass_ref``. ``bucket`` is a staged bucket dict
    (``ParallelSolver.staged_buckets``): lane tables i/k/s/i2/k2/s2, gains
    g_row/g_col/g_sel/dinv, masks act/seg."""
    bc = block_c or _DEFAULT_BLOCK_C
    lanes = jnp.stack(
        [bucket[key] for key in ("i", "k", "s", "i2", "k2", "s2")]
    )
    return _fused_pass_jit(
        x, yslab, lanes, bucket["g_row"], bucket["g_col"], bucket["g_sel"],
        bucket["dinv"], bucket["act"], bucket["seg"],
        block_c=bc, interpret=not _on_tpu(),
    )

"""Jitted public wrappers for the metric-projection sweep kernels.

On TPU, ``interpret=False`` compiles the Mosaic kernel; on CPU (this
container) kernels execute in interpret mode, which is how they are
validated against the jnp references in tests.

Production entry points — all three route the gen-3 megakernel
(``fused_pass.py``, DESIGN.md §10), one compiled program per bucket
shape with per-instance data as runtime operands:

  * ``fused_bucket_pass``         — solo path (``ParallelSolver``): one
    instance lifted to a unit batch axis.
  * ``fused_bucket_pass_batched`` — serve batch path (``BatchedSolver``):
    a whole (B, ...) bucket in ONE ``pallas_call``; new instances or
    batches never recompile (gains/masks are operands).
  * ``fused_diag_pass_delta``     — sharded path (``ShardedSolver``): one
    diagonal per call in delta-output mode — the kernel returns the
    act-masked update deltas scattered into zeros, exactly the per-device
    delta matrix the solver psum-merges per diagonal.

Test-oracle / benchmark-only entry points (first-generation per-diagonal
kernel, ``metric_project.py`` — demoted from production routing in PR 6):

  * ``diagonal_sweep``      — six-buffer unfolded contract (matches
    ref.sweep_ref); kernel-validation oracle (tests/test_kernels.py).
  * ``diagonal_sweep_slab`` — schedule-native folded contract (matches
    ref.sweep_ref_slab); kept for the kernel_sweep benchmark baseline and
    the gen-1-vs-gen-3 parity test. No solver routes it anymore.

All route through ``jax.jit``-cached wrappers so repeated sweeps of the
same shape never retrace.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.metric_project.fused_pass import fused_bucket_pass_pallas
from repro.kernels.metric_project.metric_project import (
    sweep_pallas,
    sweep_pallas_folded,
)
from repro.kernels.metric_project.violation import max_triangle_violation_pallas

__all__ = [
    "diagonal_sweep",
    "diagonal_sweep_slab",
    "fused_bucket_pass",
    "fused_bucket_pass_batched",
    "fused_diag_pass_delta",
    "set_default_block_c",
    "triangle_violation",
]

_DEFAULT_BLOCK_C = 128


def set_default_block_c(block_c: int) -> None:
    """Set the lane-tile size (paper Fig. 7 'tile size' analogue)."""
    global _DEFAULT_BLOCK_C
    _DEFAULT_BLOCK_C = int(block_c)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _kernel_mode() -> str:
    """Gen-3 staging engine: the per-lane DMA body on real TPUs, the
    vmapped vector body under interpret execution (DESIGN.md §10)."""
    return "dma" if _on_tpu() else "vector"


# eps is static: sweep_pallas bakes it into the kernel body as a python
# float (it is a problem constant, so this never causes retracing).
@functools.partial(jax.jit, static_argnames=("eps", "block_c", "interpret"))
def _sweep_jit(rowb, colb, xik, y0, y1, y2, w_row, w_col, w_ik, active, eps,
               block_c, interpret):
    return sweep_pallas(
        rowb, colb, xik, y0, y1, y2, w_row, w_col, w_ik, active, eps,
        block_c=block_c, interpret=interpret,
    )


@functools.partial(jax.jit, static_argnames=("eps", "block_c", "interpret"))
def _sweep_folded_jit(rowb, colb, xikp, yslab, w_row, w_col, w_ikp, active,
                      seg, eps, block_c, interpret):
    # in_place is safe here: under jit, XLA copies any donated dual buffer
    # that is still live in the caller; fresh buffers are updated in place.
    nrow, ncol, nxikp, n0, n1, n2 = sweep_pallas_folded(
        rowb, colb, xikp, yslab[0], yslab[1], yslab[2],
        w_row, w_col, w_ikp, active, seg, eps,
        block_c=block_c, interpret=interpret, in_place=True,
    )
    return nrow, ncol, nxikp, jnp.stack([n0, n1, n2])


def diagonal_sweep(rowb, colb, xik, y0, y1, y2, w_row, w_col, w_ik, active,
                   eps, block_c: int | None = None):
    """Gen-1 kernel, unfolded contract — TEST ORACLE ONLY (validated
    against ref.sweep_ref in tests/test_kernels.py; no production path
    routes it)."""
    bc = block_c or _DEFAULT_BLOCK_C
    return _sweep_jit(
        rowb, colb, xik, y0, y1, y2, w_row, w_col, w_ik, active,
        eps=float(eps), block_c=bc, interpret=not _on_tpu(),
    )


def diagonal_sweep_slab(rowb, colb, xikp, yslab, w_row, w_col, w_ikp, active,
                        seg, eps, block_c: int | None = None):
    """Gen-1 kernel, schedule-native folded contract — TEST ORACLE /
    BENCHMARK BASELINE ONLY (the kernel_sweep benchmark and the
    gen-1-vs-gen-3 parity test; no solver routes it since PR 6)."""
    bc = block_c or _DEFAULT_BLOCK_C
    return _sweep_folded_jit(
        rowb, colb, xikp, yslab, w_row, w_col, w_ikp, active, seg,
        eps=float(eps), block_c=bc, interpret=not _on_tpu(),
    )


@functools.partial(
    jax.jit,
    static_argnames=("block_c", "interpret", "mode", "unroll", "out_delta"),
    inline=True,
)
def _fused_pass_jit(x, yslab, lanes, g_row, g_col, g_sel, dinv, act, seg,
                    geom, block_c, interpret, mode, unroll, out_delta):
    # in_place is safe here for both X and the dual slab: under jit, XLA
    # copies any donated buffer that is still live in the caller. All
    # per-instance data are operands, so every solo/batched/sharded call
    # of one bucket shape hits this one cache entry — zero recompiles
    # across instances (the §10 contract, pinned by tests).
    # inline=True: when a runner jits a whole pass/chunk around this call
    # (BatchedSolver chunks, ShardedSolver passes), the bucket program is
    # inlined into the enclosing jaxpr instead of staying an opaque pjit
    # call — XLA then fuses across bucket boundaries, which is worth ~5%
    # per chunked pass; top-level calls still hit this cache as before.
    return fused_bucket_pass_pallas(
        x, yslab, lanes, g_row, g_col, g_sel, dinv, act, seg, geom,
        block_c=block_c, interpret=interpret, in_place=True, mode=mode,
        unroll=unroll, out_delta=out_delta,
    )


def triangle_violation(xs, block: int = 8, block_r: int = 128,
                       block_c: int | None = None,
                       n_live: int | None = None):
    """Max triangle slack of the symmetric iterate (the convergence
    engine's probe; DESIGN.md §7) backed by the lane-blocked 3-D-grid
    Pallas kernel (apex blocks × column blocks × streamed row blocks —
    works at n ≫ 10³ without a VMEM-resident (n, n) matrix); drop-in for
    ``metrics_device.triangle_violation``. ``block_c`` is the lane
    (column) block width: None keeps one full-width column block (the
    pre-§14 tiling, right at n ≲ 2·10³); at larger n pick
    ``block_c ≈ VMEM / (4·block·block_r)`` so the per-step slack tile
    stays resident (DESIGN.md §14). ``n_live`` restricts the reduction
    to triangles whose indices are all < n_live — the ghost-padding
    contract (DESIGN.md §8), so padded serve instances run the kernel
    probe too instead of falling back to jnp."""
    return max_triangle_violation_pallas(
        xs, block=block, block_r=block_r,
        block_c=None if block_c is None else int(block_c),
        interpret=not _on_tpu(),
        n_live=None if n_live is None else int(n_live),
    )


def fused_bucket_pass(x, yslab, bucket, block_c: int | None = None,
                      unroll: int = 4):
    """Whole-bucket fused pass backed by the gen-3 megakernel (solo path);
    drop-in for ``ref.fused_bucket_pass_ref``. ``bucket`` is a staged
    bucket dict (``ParallelSolver.staged_buckets``): lane tables
    i/k/s/i2/k2/s2, geometry J/iN/kN, gains g_row/g_col/g_sel/dinv, masks
    act/seg. The instance is lifted to a unit batch axis, so it shares the
    batched path's compiled program."""
    bc = block_c or _DEFAULT_BLOCK_C
    lanes = jnp.stack(
        [bucket[key] for key in ("i", "k", "s", "i2", "k2", "s2")]
    )
    geom = jnp.stack([bucket["J"], bucket["iN"], bucket["kN"]])
    one = lambda a: a[None]
    nx, ny = _fused_pass_jit(
        x[None], yslab[None], lanes,
        one(bucket["g_row"]), one(bucket["g_col"]), one(bucket["g_sel"]),
        one(bucket["dinv"]), one(bucket["act"]), bucket["seg"], geom,
        block_c=bc, interpret=not _on_tpu(), mode=_kernel_mode(),
        unroll=int(unroll), out_delta=False,
    )
    return nx[0], ny[0]


def fused_bucket_pass_batched(x, yslab, geo, gains,
                              block_c: int | None = None, unroll: int = 4):
    """Whole-bucket fused pass of a B-instance serve batch in ONE
    ``pallas_call`` (DESIGN.md §10). ``geo`` holds the bucket's shared
    statics (lane tables ``i/k/s/i2/k2/s2``, geometry ``J/iN/kN``, the
    ``seg`` mask — pure functions of the bucket shape); ``gains`` the
    per-instance operands stacked with a leading B axis
    (``g_row/g_col/g_sel/dinv`` and the ghost-aware ``act`` mask, as
    built by ``BatchedSolver._aux_one``). Per instance the result matches
    ``ref.fused_bucket_pass_ref`` bitwise on every live cell.

    Args:
      x: (B, n, n) iterates.  yslab: (B, D, 3, T, C) dual slabs.

    Returns (new_x, new_yslab).
    """
    bc = block_c or _DEFAULT_BLOCK_C
    lanes = jnp.stack([geo[key] for key in ("i", "k", "s", "i2", "k2", "s2")])
    geom = jnp.stack([geo["J"], geo["iN"], geo["kN"]])
    return _fused_pass_jit(
        x, yslab, lanes, gains["g_row"], gains["g_col"], gains["g_sel"],
        gains["dinv"], gains["act"], geo["seg"], geom,
        block_c=bc, interpret=not _on_tpu(), mode=_kernel_mode(),
        unroll=int(unroll), out_delta=False,
    )


def fused_diag_pass_delta(x, yslab, lanes, geom, g_row, g_col, g_sel, dinv,
                          act, seg, block_c: int | None = None,
                          unroll: int = 4):
    """One diagonal through the gen-3 megakernel in delta-output mode —
    the sharded solver's per-device sweep (DESIGN.md §10): X is read-only
    and the returned matrix holds the act-masked update deltas scattered
    into zeros, bitwise-equal to the jnp fused path's per-diagonal delta
    (``x_new = x + psum(delta)`` merges exactly; conflict-freedom makes
    the supports disjoint across devices).

    Args:
      x: (n, n) replicated iterate.  yslab: (3, T, C) this diagonal's
      dual slab.  lanes: (6, C) int32 lane tables.  geom: (3, T, C) int32
      folded geometry (J, iN, kN).  g_*/dinv/act/seg: (T, C) staged
      gains and masks.

    Returns (delta, new_yslab) — (n, n) and (3, T, C).
    """
    bc = block_c or _DEFAULT_BLOCK_C
    two = lambda a: a[None, None]
    dx, ny = _fused_pass_jit(
        x[None], yslab[None, None], lanes[:, None],
        two(g_row), two(g_col), two(g_sel), two(dinv), two(act), seg[None],
        geom[:, None],
        block_c=bc, interpret=not _on_tpu(), mode=_kernel_mode(),
        unroll=int(unroll), out_delta=True,
    )
    return dx[0], ny[0, 0]

"""Pure-jnp oracle for the diagonal-sweep kernel.

``sweep_ref`` performs, for every set lane c (one ``S_{i,k}`` set on a
conflict-free diagonal), the *sequential* Dykstra visit over middle indices
j = i+1 .. k-1, three triangle constraints per (i, j, k) triplet, carrying the
shared variable ``x_ik``. All buffers are in "schedule layout" (T, C):

  rowb[t, c] = x[i_c, j(t)]        colb[t, c] = x[j(t), k_c]
  y0 = dual(long (i,j), apex k)    y1 = dual(long (i,k), apex j)
  y2 = dual(long (j,k), apex i)

Returns updated buffers; y := theta per Dykstra (theta = 0 when satisfied).
Padding lanes / steps are masked by ``active`` and returned unchanged.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["sweep_ref", "triplet_visit"]


def triplet_visit(xij, xik, xjk, y0, y1, y2, iwij, iwik, iwjk, eps):
    """The three sequential Dykstra constraint visits of one triplet.

    Elementwise over any shape; this is the paper's Algorithm 1 body
    specialized to the three metric constraints of (i, j, k). Shared by the
    jnp reference and the Pallas kernel so the math lives in one place.
    """
    denom = iwij + iwik + iwjk
    # --- constraint 0: x_ij <= x_ik + x_jk  (long (i,j), apex k)
    xij = xij + y0 * iwij / eps
    xik = xik - y0 * iwik / eps
    xjk = xjk - y0 * iwjk / eps
    th0 = eps * jnp.maximum(xij - xik - xjk, 0.0) / denom
    xij = xij - th0 * iwij / eps
    xik = xik + th0 * iwik / eps
    xjk = xjk + th0 * iwjk / eps
    # --- constraint 1: x_ik <= x_ij + x_jk  (long (i,k), apex j)
    xik = xik + y1 * iwik / eps
    xij = xij - y1 * iwij / eps
    xjk = xjk - y1 * iwjk / eps
    th1 = eps * jnp.maximum(xik - xij - xjk, 0.0) / denom
    xik = xik - th1 * iwik / eps
    xij = xij + th1 * iwij / eps
    xjk = xjk + th1 * iwjk / eps
    # --- constraint 2: x_jk <= x_ij + x_ik  (long (j,k), apex i)
    xjk = xjk + y2 * iwjk / eps
    xij = xij - y2 * iwij / eps
    xik = xik - y2 * iwik / eps
    th2 = eps * jnp.maximum(xjk - xij - xik, 0.0) / denom
    xjk = xjk - th2 * iwjk / eps
    xij = xij + th2 * iwij / eps
    xik = xik + th2 * iwik / eps
    return xij, xik, xjk, th0, th1, th2


def sweep_ref(rowb, colb, xik, y0, y1, y2, w_row, w_col, w_ik, active, eps):
    """Reference sweep. Shapes: (T, C) buffers, (C,) xik / w_ik.

    Returns (new_rowb, new_colb, new_xik, new_y0, new_y1, new_y2).
    """
    dt = rowb.dtype
    eps = jnp.asarray(eps, dt)
    iw_ik = 1.0 / w_ik.astype(dt)

    def step(carry, inp):
        xik_c = carry
        xij, xjk, v0, v1, v2, wij, wjk, act = inp
        iwij = 1.0 / wij
        iwjk = 1.0 / wjk
        nij, nik, njk, t0, t1, t2 = triplet_visit(
            xij, xik_c, xjk, v0, v1, v2, iwij, iw_ik, iwjk, eps
        )
        new_xik = jnp.where(act, nik, xik_c)
        out = (
            jnp.where(act, nij, xij),
            jnp.where(act, njk, xjk),
            jnp.where(act, t0, v0),
            jnp.where(act, t1, v1),
            jnp.where(act, t2, v2),
        )
        return new_xik, out

    new_xik, (nrow, ncol, n0, n1, n2) = jax.lax.scan(
        step, xik.astype(dt), (rowb, colb, y0, y1, y2, w_row, w_col, active)
    )
    return nrow, ncol, new_xik, n0, n1, n2

"""Pure-jnp oracle for the diagonal-sweep kernel.

``sweep_ref_folded`` performs, for every *folded* lane c (up to two
``S_{i,k}`` sets of one conflict-free diagonal packed head-to-tail — see
core/schedule.py lane folding), the *sequential* Dykstra visit over middle
indices, three triangle constraints per (i, j, k) triplet, carrying the
shared variables ``x_ik`` of both segments. All buffers are in "schedule
layout" (T, C):

  rowb[t, c] = x[i_c(t), j(t)]     colb[t, c] = x[j(t), k_c(t)]
  y0 = dual(long (i,j), apex k)    y1 = dual(long (i,k), apex j)
  y2 = dual(long (j,k), apex i)
  seg[t, c]  = False while t runs over segment A, True over segment B
  xikp[s, c] = x[i, k] carry of segment s;  w_ikp likewise

Returns updated buffers; y := theta per Dykstra (theta = 0 when satisfied).
Padding lanes / steps are masked by ``active`` and returned unchanged.

``sweep_ref`` keeps the original unfolded six-buffer contract (a folded
sweep with an empty B segment) — it is the oracle the Pallas kernel is
validated against in tests/test_kernels.py.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["sweep_ref", "sweep_ref_folded", "sweep_ref_slab", "triplet_visit"]


def triplet_visit(xij, xik, xjk, y0, y1, y2, iwij, iwik, iwjk, eps):
    """The three sequential Dykstra constraint visits of one triplet.

    Elementwise over any shape; this is the paper's Algorithm 1 body
    specialized to the three metric constraints of (i, j, k). Shared by the
    jnp reference and the Pallas kernel so the math lives in one place.
    """
    denom = iwij + iwik + iwjk
    # --- constraint 0: x_ij <= x_ik + x_jk  (long (i,j), apex k)
    xij = xij + y0 * iwij / eps
    xik = xik - y0 * iwik / eps
    xjk = xjk - y0 * iwjk / eps
    th0 = eps * jnp.maximum(xij - xik - xjk, 0.0) / denom
    xij = xij - th0 * iwij / eps
    xik = xik + th0 * iwik / eps
    xjk = xjk + th0 * iwjk / eps
    # --- constraint 1: x_ik <= x_ij + x_jk  (long (i,k), apex j)
    xik = xik + y1 * iwik / eps
    xij = xij - y1 * iwij / eps
    xjk = xjk - y1 * iwjk / eps
    th1 = eps * jnp.maximum(xik - xij - xjk, 0.0) / denom
    xik = xik - th1 * iwik / eps
    xij = xij + th1 * iwij / eps
    xjk = xjk + th1 * iwjk / eps
    # --- constraint 2: x_jk <= x_ij + x_ik  (long (j,k), apex i)
    xjk = xjk + y2 * iwjk / eps
    xij = xij - y2 * iwij / eps
    xik = xik - y2 * iwik / eps
    th2 = eps * jnp.maximum(xjk - xij - xik, 0.0) / denom
    xjk = xjk - th2 * iwjk / eps
    xij = xij + th2 * iwij / eps
    xik = xik + th2 * iwik / eps
    return xij, xik, xjk, th0, th1, th2


def sweep_ref_folded(rowb, colb, xikp, y0, y1, y2, w_row, w_col, w_ikp,
                     active, seg, eps):
    """Folded reference sweep. Shapes: (T, C) buffers, (2, C) xikp / w_ikp,
    (T, C) bool seg selecting the B segment.

    Returns (new_rowb, new_colb, new_xikp, new_y0, new_y1, new_y2).
    """
    dt = rowb.dtype
    eps = jnp.asarray(eps, dt)
    iw_a = 1.0 / w_ikp[0].astype(dt)
    iw_b = 1.0 / w_ikp[1].astype(dt)

    def step(carry, inp):
        xa, xb = carry
        xij, xjk, v0, v1, v2, wij, wjk, act, sg = inp
        iwij = 1.0 / wij
        iwjk = 1.0 / wjk
        xc = jnp.where(sg, xb, xa)
        iw_ik = jnp.where(sg, iw_b, iw_a)
        nij, nik, njk, t0, t1, t2 = triplet_visit(
            xij, xc, xjk, v0, v1, v2, iwij, iw_ik, iwjk, eps
        )
        nik = jnp.where(act, nik, xc)
        new_xa = jnp.where(sg, xa, nik)
        new_xb = jnp.where(sg, nik, xb)
        out = (
            jnp.where(act, nij, xij),
            jnp.where(act, njk, xjk),
            jnp.where(act, t0, v0),
            jnp.where(act, t1, v1),
            jnp.where(act, t2, v2),
        )
        return (new_xa, new_xb), out

    (new_xa, new_xb), (nrow, ncol, n0, n1, n2) = jax.lax.scan(
        step,
        (xikp[0].astype(dt), xikp[1].astype(dt)),
        (rowb, colb, y0, y1, y2, w_row, w_col, active, seg),
    )
    return nrow, ncol, jnp.stack([new_xa, new_xb]), n0, n1, n2


def sweep_ref(rowb, colb, xik, y0, y1, y2, w_row, w_col, w_ik, active, eps):
    """Unfolded reference sweep (original contract): one set per lane.

    Shapes: (T, C) buffers, (C,) xik / w_ik. A folded sweep whose B segment
    is empty. Returns (new_rowb, new_colb, new_xik, new_y0, new_y1, new_y2).
    """
    xikp = jnp.stack([xik, jnp.zeros_like(xik)])
    w_ikp = jnp.stack([w_ik, jnp.ones_like(w_ik)])
    seg = jnp.zeros_like(active)
    nrow, ncol, nxikp, n0, n1, n2 = sweep_ref_folded(
        rowb, colb, xikp, y0, y1, y2, w_row, w_col, w_ikp, active, seg, eps
    )
    return nrow, ncol, nxikp[0], n0, n1, n2


def sweep_ref_slab(rowb, colb, xikp, yslab, w_row, w_col, w_ikp, active,
                   seg, eps):
    """Schedule-native (slab) contract: duals arrive as one ``(3, T, C)``
    slab (DESIGN.md §3) and are returned the same way. This is the sweep
    entry point the solvers use."""
    nrow, ncol, nxikp, n0, n1, n2 = sweep_ref_folded(
        rowb, colb, xikp, yslab[0], yslab[1], yslab[2],
        w_row, w_col, w_ikp, active, seg, eps,
    )
    return nrow, ncol, nxikp, jnp.stack([n0, n1, n2])

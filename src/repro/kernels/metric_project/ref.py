"""Pure-jnp oracle for the diagonal-sweep kernel.

``sweep_ref_folded`` performs, for every *folded* lane c (up to two
``S_{i,k}`` sets of one conflict-free diagonal packed head-to-tail — see
core/schedule.py lane folding), the *sequential* Dykstra visit over middle
indices, three triangle constraints per (i, j, k) triplet, carrying the
shared variables ``x_ik`` of both segments. All buffers are in "schedule
layout" (T, C):

  rowb[t, c] = x[i_c(t), j(t)]     colb[t, c] = x[j(t), k_c(t)]
  y0 = dual(long (i,j), apex k)    y1 = dual(long (i,k), apex j)
  y2 = dual(long (j,k), apex i)
  seg[t, c]  = False while t runs over segment A, True over segment B
  xikp[s, c] = x[i, k] carry of segment s;  w_ikp likewise

Returns updated buffers; y := theta per Dykstra (theta = 0 when satisfied).
Padding lanes / steps are masked by ``active`` and returned unchanged.

``sweep_ref`` keeps the original unfolded six-buffer contract (a folded
sweep with an empty B segment) — it is the oracle the Pallas kernel is
validated against in tests/test_kernels.py.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "fused_bucket_pass_ref",
    "fused_diag_sweep",
    "fused_step",
    "sweep_ref",
    "sweep_ref_folded",
    "sweep_ref_slab",
    "triplet_visit",
]


def triplet_visit(xij, xik, xjk, y0, y1, y2, iwij, iwik, iwjk, eps):
    """The three sequential Dykstra constraint visits of one triplet.

    Elementwise over any shape; this is the paper's Algorithm 1 body
    specialized to the three metric constraints of (i, j, k). Shared by the
    jnp reference and the Pallas kernel so the math lives in one place.
    """
    denom = iwij + iwik + iwjk
    # --- constraint 0: x_ij <= x_ik + x_jk  (long (i,j), apex k)
    xij = xij + y0 * iwij / eps
    xik = xik - y0 * iwik / eps
    xjk = xjk - y0 * iwjk / eps
    th0 = eps * jnp.maximum(xij - xik - xjk, 0.0) / denom
    xij = xij - th0 * iwij / eps
    xik = xik + th0 * iwik / eps
    xjk = xjk + th0 * iwjk / eps
    # --- constraint 1: x_ik <= x_ij + x_jk  (long (i,k), apex j)
    xik = xik + y1 * iwik / eps
    xij = xij - y1 * iwij / eps
    xjk = xjk - y1 * iwjk / eps
    th1 = eps * jnp.maximum(xik - xij - xjk, 0.0) / denom
    xik = xik - th1 * iwik / eps
    xij = xij + th1 * iwij / eps
    xjk = xjk + th1 * iwjk / eps
    # --- constraint 2: x_jk <= x_ij + x_ik  (long (j,k), apex i)
    xjk = xjk + y2 * iwjk / eps
    xij = xij - y2 * iwij / eps
    xik = xik - y2 * iwik / eps
    th2 = eps * jnp.maximum(xjk - xij - xik, 0.0) / denom
    xjk = xjk - th2 * iwjk / eps
    xij = xij + th2 * iwij / eps
    xik = xik + th2 * iwik / eps
    return xij, xik, xjk, th0, th1, th2


def sweep_ref_folded(rowb, colb, xikp, y0, y1, y2, w_row, w_col, w_ikp,
                     active, seg, eps):
    """Folded reference sweep. Shapes: (T, C) buffers, (2, C) xikp / w_ikp,
    (T, C) bool seg selecting the B segment.

    Returns (new_rowb, new_colb, new_xikp, new_y0, new_y1, new_y2).
    """
    dt = rowb.dtype
    eps = jnp.asarray(eps, dt)
    iw_a = 1.0 / w_ikp[0].astype(dt)
    iw_b = 1.0 / w_ikp[1].astype(dt)

    def step(carry, inp):
        xa, xb = carry
        xij, xjk, v0, v1, v2, wij, wjk, act, sg = inp
        iwij = 1.0 / wij
        iwjk = 1.0 / wjk
        xc = jnp.where(sg, xb, xa)
        iw_ik = jnp.where(sg, iw_b, iw_a)
        nij, nik, njk, t0, t1, t2 = triplet_visit(
            xij, xc, xjk, v0, v1, v2, iwij, iw_ik, iwjk, eps
        )
        nik = jnp.where(act, nik, xc)
        new_xa = jnp.where(sg, xa, nik)
        new_xb = jnp.where(sg, nik, xb)
        out = (
            jnp.where(act, nij, xij),
            jnp.where(act, njk, xjk),
            jnp.where(act, t0, v0),
            jnp.where(act, t1, v1),
            jnp.where(act, t2, v2),
        )
        return (new_xa, new_xb), out

    (new_xa, new_xb), (nrow, ncol, n0, n1, n2) = jax.lax.scan(
        step,
        (xikp[0].astype(dt), xikp[1].astype(dt)),
        (rowb, colb, y0, y1, y2, w_row, w_col, active, seg),
    )
    return nrow, ncol, jnp.stack([new_xa, new_xb]), n0, n1, n2


def sweep_ref(rowb, colb, xik, y0, y1, y2, w_row, w_col, w_ik, active, eps):
    """Unfolded reference sweep (original contract): one set per lane.

    Shapes: (T, C) buffers, (C,) xik / w_ik. A folded sweep whose B segment
    is empty. Returns (new_rowb, new_colb, new_xik, new_y0, new_y1, new_y2).
    """
    xikp = jnp.stack([xik, jnp.zeros_like(xik)])
    w_ikp = jnp.stack([w_ik, jnp.ones_like(w_ik)])
    seg = jnp.zeros_like(active)
    nrow, ncol, nxikp, n0, n1, n2 = sweep_ref_folded(
        rowb, colb, xikp, y0, y1, y2, w_row, w_col, w_ikp, active, seg, eps
    )
    return nrow, ncol, nxikp[0], n0, n1, n2


def sweep_ref_slab(rowb, colb, xikp, yslab, w_row, w_col, w_ikp, active,
                   seg, eps):
    """Schedule-native (slab) contract: duals arrive as one ``(3, T, C)``
    slab (DESIGN.md §3) and are returned the same way. This is the sweep
    entry point the sharded solver uses."""
    nrow, ncol, nxikp, n0, n1, n2 = sweep_ref_folded(
        rowb, colb, xikp, yslab[0], yslab[1], yslab[2],
        w_row, w_col, w_ikp, active, seg, eps,
    )
    return nrow, ncol, nxikp, jnp.stack([n0, n1, n2])


# ---------------------------------------------------------------------------
# Fused-pass execution (DESIGN.md §4)
#
# The fused pass consumes *static staging* slabs (core/schedule.py::
# build_static_stage): the folded geometry tables, the step masks, and the
# constraint weights pre-divided into "projection gains"
#
#     g_* = (1/w_*) / eps        dinv = 1/(g_row + g_sel + g_col)
#
# so the inner step body spends no ops on index math, weight gathers, or
# the repeated /eps rescaling of Algorithm 1 — the dual value written back
# is still exactly Dykstra's theta (th = eps * delta / sum(1/w), to fp
# association). ``fused_step`` is the single source of the per-step math:
# the jnp reference scan below and the Pallas megakernel's fori body both
# call it, which is what makes kernel-vs-reference parity exact op-for-op.
#
# Unlike ``sweep_ref_folded``, outputs at masked (padding) steps are NOT
# restored to their inputs: masked row/col/dual cells carry don't-care
# values. Correctness does not depend on them — X deltas are act-masked at
# scatter time and the dual layout's dense-conversion maps skip padding
# cells — and dropping the five restore-selects per step is part of the
# fused pass's speedup. The two x_ik carries stay masked (they are live
# state across steps).
# ---------------------------------------------------------------------------


def fused_step(xij, xc, xjk, y0, y1, y2, g_ij, g_ik, g_jk, dinv):
    """The three sequential constraint visits of one triplet, in staged
    "gain" form. Elementwise over any shape; shared by the fused jnp
    reference and the Pallas megakernel (same op sequence → exact parity).

    Returns (nij, nik, njk, th0, th1, th2); th values equal
    ``triplet_visit``'s duals up to fp association.
    """
    # --- constraint 0: x_ij <= x_ik + x_jk  (long (i,j), apex k)
    xij = xij + y0 * g_ij
    xc = xc - y0 * g_ik
    xjk = xjk - y0 * g_jk
    th0 = jnp.maximum(xij - xc - xjk, 0.0) * dinv
    xij = xij - th0 * g_ij
    xc = xc + th0 * g_ik
    xjk = xjk + th0 * g_jk
    # --- constraint 1: x_ik <= x_ij + x_jk  (long (i,k), apex j)
    xc = xc + y1 * g_ik
    xij = xij - y1 * g_ij
    xjk = xjk - y1 * g_jk
    th1 = jnp.maximum(xc - xij - xjk, 0.0) * dinv
    xc = xc - th1 * g_ik
    xij = xij + th1 * g_ij
    xjk = xjk + th1 * g_jk
    # --- constraint 2: x_jk <= x_ij + x_ik  (long (j,k), apex i)
    xjk = xjk + y2 * g_jk
    xij = xij - y2 * g_ij
    xc = xc - y2 * g_ik
    th2 = jnp.maximum(xjk - xij - xc, 0.0) * dinv
    xjk = xjk - th2 * g_jk
    xij = xij + th2 * g_ij
    xc = xc + th2 * g_ik
    return xij, xc, xjk, th0, th1, th2


def fused_diag_sweep(rowb, colb, xikp, yslab, g_row, g_col, g_sel, dinv,
                     active, seg, *, unroll: int = 4):
    """Sequential-in-j sweep of one diagonal on staged buffers.

    Shapes: (T, C) for rowb/colb/g_row/g_col/g_sel/dinv/active/seg,
    (2, C) xikp, (3, T, C) yslab. Returns (nrow, ncol, nxikp, nyslab);
    masked cells of nrow/ncol/nyslab are don't-care (see module comment).
    """

    def step(carry, inp):
        xa, xb = carry
        xij, xjk, y0, y1, y2, gij, gjk, gik, dv, act, sg = inp
        xc = jnp.where(sg, xb, xa)
        nij, nik, njk, t0, t1, t2 = fused_step(
            xij, xc, xjk, y0, y1, y2, gij, gik, gjk, dv
        )
        nik = jnp.where(act, nik, xc)
        return (
            (jnp.where(sg, xa, nik), jnp.where(sg, nik, xb)),
            (nij, njk, t0, t1, t2),
        )

    (xa, xb), (nrow, ncol, n0, n1, n2) = jax.lax.scan(
        step,
        (xikp[0], xikp[1]),
        (rowb, colb, yslab[0], yslab[1], yslab[2],
         g_row, g_col, g_sel, dinv, active, seg),
        unroll=unroll,
    )
    return nrow, ncol, jnp.stack([xa, xb]), jnp.stack([n0, n1, n2])


def fused_bucket_pass_ref(x, yslab, stage, *, unroll: int = 4):
    """One whole-bucket fused pass, pure jnp — the megakernel's oracle.

    Args:
      x: (n, n) iterate.
      yslab: (D, 3, T, C) schedule-native dual slab of this bucket.
      stage: dict of staged arrays for the bucket — per-diagonal lane
        tables ``i/k/s/i2/k2/s2`` (D, C), geometry ``J/iN/kN`` (D, T, C),
        masks ``act/seg`` (D, T, C), gains ``g_row/g_col/g_sel/dinv``
        (D, T, C) — see ``ParallelSolver.staged_buckets``.

    Returns (new_x, new_yslab). Only the X row/column/carry slices are
    gathered (contiguous); the duals and every constant are pure slicing
    via the scan step index.
    """

    def body(x, inp):
        J, iN, kN, act = inp["J"], inp["iN"], inp["kN"], inp["act"]
        i1, k1, i2, k2 = inp["i"], inp["k"], inp["i2"], inp["k2"]
        rowb = x.at[iN, J].get(mode="fill", fill_value=0.0)
        colb = x.at[J, kN].get(mode="fill", fill_value=0.0)
        xikp = jnp.stack([
            x.at[i1, k1].get(mode="fill", fill_value=0.0),
            x.at[i2, k2].get(mode="fill", fill_value=0.0),
        ])
        nrow, ncol, nxikp, ny = fused_diag_sweep(
            rowb, colb, xikp, inp["y"], inp["g_row"], inp["g_col"],
            inp["g_sel"], inp["dinv"], act, inp["seg"], unroll=unroll,
        )
        add = lambda a, idx, v: a.at[idx].add(
            v, mode="drop", unique_indices=True
        )
        x = add(x, (iN, J), jnp.where(act, nrow - rowb, 0))
        x = add(x, (J, kN), jnp.where(act, ncol - colb, 0))
        x = add(x, (i1, k1), jnp.where(inp["s"] > 0, nxikp[0] - xikp[0], 0))
        x = add(x, (i2, k2), jnp.where(inp["s2"] > 0, nxikp[1] - xikp[1], 0))
        return x, ny

    xs = {key: stage[key]
          for key in ("i", "k", "s", "i2", "k2", "s2", "J", "iN", "kN",
                      "act", "seg", "g_row", "g_col", "g_sel", "dinv")}
    return jax.lax.scan(body, x, xs | {"y": yslab})

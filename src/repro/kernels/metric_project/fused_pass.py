"""Pallas TPU megakernel: one whole-bucket fused pass per ``pallas_call``.

Second-generation kernel (DESIGN.md §4). The first-generation
``metric_project.py`` kernel sweeps ONE diagonal per launch, so a pass costs
~2n launches and re-stages the X row/column slices from HBM every time. Here
the grid is (diagonals × lane blocks) over an entire bucket and:

  * **X is resident in VMEM across diagonals**: the (padded) iterate maps to
    a constant-index output block, so Pallas keeps it on-chip for the whole
    grid; it is written back to HBM once per bucket. The input X is aliased
    to it (``input_output_aliases``) and copied on the first grid step.
  * **In-kernel dynamic-slice gather/scatter**: each folded lane's row slice
    ``x[i, i+1 : i+1+T]``, column slice ``x[i+1 : i+1+T, k]`` and carry
    ``x[i, k]`` are staged into scratch with per-lane dynamic slices driven
    by the **scalar-prefetched** lane tables (i/k/s of both segments, SMEM).
    After the sweep, act-masked *deltas* are added back cell-by-lane; because
    deltas are exactly zero outside a lane's active cells, overlapping fixed-
    length windows (padding tails over other lanes' cells) add 0.0 — the
    sequential read-modify-write inside one grid step is exact without locks,
    the in-kernel restatement of the paper's conflict-freedom argument.
  * **Duals never round-trip**: the (D, 3, T, C) slab maps one diagonal
    block per grid step, aliased input→output, written in place.
  * The per-step math is ``ref.fused_step`` — the same function the jnp
    fused reference scans — so kernel-vs-reference parity is op-for-op.

Grid order is row-major, diagonals outermost: all lane blocks of diagonal d
complete before d+1 starts, preserving the schedule's sequential-by-diagonal
semantics while lanes within a diagonal are free to interleave (conflict-
free, paper §III.A).

VMEM budget per grid step ≈ (n+T)² · 4 (resident X) + 9·T·block_c · 4
(dual + gain + mask blocks) + 6·T·block_c · 4 (scratch). At n = 96,
T = 47, block_c = 128: ~0.4 MiB + ~2.9 MiB — comfortably inside a ~16 MiB
v5e VMEM budget; for larger n the bucket's lane dimension is the tile knob.

On CPU (this container) the kernel runs in interpret mode, where it is
validated against the fused jnp reference; the per-lane staging loops and
(1, T) ↔ (T, 1) relayouts are Mosaic-expressible but would deserve a
double-buffered DMA treatment on real hardware before production use.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.metric_project.ref import fused_step

__all__ = ["fused_bucket_pass_pallas"]


def _fused_kernel(
    lanes_ref,  # (6, D, Cp) int32 scalar-prefetch: i1, k1, s1, i2, k2, s2
    x_ref,      # (np, np) resident iterate (input copy)
    y_ref,      # (1, 3, T, Cb) dual block of this (diagonal, lane block)
    grow_ref,   # (1, T, Cb) staged gains (DESIGN.md §4)
    gcol_ref,
    gsel_ref,
    dinv_ref,
    act_ref,    # (1, T, Cb) int8 masks
    seg_ref,
    ox_ref,     # (np, np) resident iterate (working buffer)
    oy_ref,     # (1, 3, T, Cb)
    rowS,       # (Cb, 2T) scratch: folded row slices, then row deltas
    colS,       # (Cb, 2T) scratch: folded col slices, then col deltas
    dR,         # (T, Cb) scratch: act-masked row deltas (sweep layout)
    dC,         # (T, Cb) scratch: act-masked col deltas
    *,
    T: int,
    block_c: int,
):
    d = pl.program_id(0)
    cb = pl.program_id(1)
    # Constant index components must match the int32 traced starts even
    # under jax_enable_x64 (python ints would promote to int64).
    i32 = lambda v: jnp.asarray(v, jnp.int32)

    @pl.when((d == 0) & (cb == 0))
    def _init_x():
        ox_ref[...] = x_ref[...]

    dt = x_ref.dtype
    col0 = cb * block_c

    def lane_scalars(c):
        i1 = lanes_ref[0, d, col0 + c]
        k1 = lanes_ref[1, d, col0 + c]
        s1 = lanes_ref[2, d, col0 + c]
        i2 = lanes_ref[3, d, col0 + c]
        k2 = lanes_ref[4, d, col0 + c]
        s2 = lanes_ref[5, d, col0 + c]
        # Padding lanes carry -1; clamp to cell (0, 0) / row 0 — their
        # deltas are exactly zero, so the clamped windows only ever add 0.
        r1 = jnp.maximum(i1, 0)
        q1 = jnp.maximum(k1, 0)
        r2 = jnp.maximum(i2, 0)
        q2 = jnp.maximum(k2, 0)
        return s1, s2, r1, q1, r2, q2

    # ---- gather: stage folded row/col slices of X and the two carries.
    # Lane c, segment A occupies folded steps [0, s1) (slices from (i1, k1)),
    # segment B is appended at [s1, s1 + s2) — writing the fixed-length-T
    # segment-B slice at dynamic offset s1 performs the fold in-place.
    def stage(c, xik):
        c = i32(c)
        s1, s2, r1, q1, r2, q2 = lane_scalars(c)
        rowA = pl.load(ox_ref, (pl.ds(r1, 1), pl.ds(r1 + 1, T)))
        pl.store(rowS, (pl.ds(c, 1), pl.ds(i32(0), T)), rowA)
        rowB = pl.load(ox_ref, (pl.ds(r2, 1), pl.ds(r2 + 1, T)))
        pl.store(rowS, (pl.ds(c, 1), pl.ds(s1, T)), rowB)
        colA = pl.load(ox_ref, (pl.ds(r1 + 1, T), pl.ds(q1, 1)))
        pl.store(colS, (pl.ds(c, 1), pl.ds(i32(0), T)), colA.reshape(1, T))
        colB = pl.load(ox_ref, (pl.ds(r2 + 1, T), pl.ds(q2, 1)))
        pl.store(colS, (pl.ds(c, 1), pl.ds(s1, T)), colB.reshape(1, T))
        xa = pl.load(ox_ref, (pl.ds(r1, 1), pl.ds(q1, 1)))
        xb = pl.load(ox_ref, (pl.ds(r2, 1), pl.ds(q2, 1)))
        return jax.lax.dynamic_update_slice(
            xik, jnp.concatenate([xa, xb], axis=0), (i32(0), c)
        )

    xik0 = jax.lax.fori_loop(
        0, block_c, stage, jnp.zeros((2, block_c), dt)
    )

    # ---- sweep: sequential in t, vectorized over the lane block.
    rowb = rowS[...][:, :T].T  # (T, Cb)
    colb = colS[...][:, :T].T
    yv = y_ref[0]              # (3, T, Cb); preloaded so the aliased
    grow = grow_ref[0]         # output writes below can never shadow reads
    gcol = gcol_ref[0]
    gsel = gsel_ref[0]
    dinv = dinv_ref[0]
    actv = act_ref[0] != 0
    segv = seg_ref[0] != 0

    def body(t, carry):
        t = i32(t)
        xa, xb = carry  # (1, Cb) — the two folded x_ik carries
        row = lambda a: jax.lax.dynamic_slice(a, (t, i32(0)), (1, block_c))
        yrow = lambda m: jax.lax.dynamic_slice(
            yv, (i32(m), t, i32(0)), (1, 1, block_c)
        ).reshape(1, block_c)
        xij, xjk = row(rowb), row(colb)
        act, sg = row(actv), row(segv)
        xc = jnp.where(sg, xb, xa)
        nij, nik, njk, t0, t1, t2 = fused_step(
            xij, xc, xjk, yrow(0), yrow(1), yrow(2),
            row(grow), row(gsel), row(gcol), row(dinv),
        )
        for m, th in ((0, t0), (1, t1), (2, t2)):
            pl.store(
                oy_ref,
                (pl.ds(i32(0), 1), pl.ds(i32(m), 1), pl.ds(t, 1),
                 pl.ds(i32(0), block_c)),
                th.reshape(1, 1, 1, block_c),
            )
        pl.store(dR, (pl.ds(t, 1), pl.ds(i32(0), block_c)),
                 jnp.where(act, nij - xij, 0.0))
        pl.store(dC, (pl.ds(t, 1), pl.ds(i32(0), block_c)),
                 jnp.where(act, njk - xjk, 0.0))
        nik = jnp.where(act, nik, xc)
        return jnp.where(sg, xa, nik), jnp.where(sg, nik, xb)

    xa, xb = jax.lax.fori_loop(0, T, body, (xik0[0:1, :], xik0[1:2, :]))

    # ---- scatter: act-masked deltas, unfolded by the same dynamic offsets.
    # Reuse the staging scratch in folded lane-major layout; the upper T
    # columns are zero so segment-B windows read zeros beyond their extent.
    zer = jnp.zeros((block_c, T), dt)
    rowS[...] = jnp.concatenate([dR[...].T, zer], axis=1)
    colS[...] = jnp.concatenate([dC[...].T, zer], axis=1)
    tvec = jax.lax.broadcasted_iota(jnp.int32, (1, T), 1)

    def scatter(c, _):
        c = i32(c)
        s1, s2, r1, q1, r2, q2 = lane_scalars(c)

        def add(rows, cols, delta):
            cur = pl.load(ox_ref, (rows, cols))
            pl.store(ox_ref, (rows, cols), cur + delta)

        dA = pl.load(rowS, (pl.ds(c, 1), pl.ds(i32(0), T)))
        add(pl.ds(r1, 1), pl.ds(r1 + 1, T), jnp.where(tvec < s1, dA, 0.0))
        dB = pl.load(rowS, (pl.ds(c, 1), pl.ds(s1, T)))
        add(pl.ds(r2, 1), pl.ds(r2 + 1, T), dB)
        cA = pl.load(colS, (pl.ds(c, 1), pl.ds(i32(0), T)))
        cA = jnp.where(tvec < s1, cA, 0.0).reshape(T, 1)
        add(pl.ds(r1 + 1, T), pl.ds(q1, 1), cA)
        cB = pl.load(colS, (pl.ds(c, 1), pl.ds(s1, T))).reshape(T, 1)
        add(pl.ds(r2 + 1, T), pl.ds(q2, 1), cB)
        lane = lambda a, s: jax.lax.dynamic_slice(a, (i32(s), c), (1, 1))
        da = lane(xa, 0) - lane(xik0, 0)
        add(pl.ds(r1, 1), pl.ds(q1, 1), jnp.where(s1 > 0, da, 0.0))
        db = lane(xb, 0) - lane(xik0, 1)
        add(pl.ds(r2, 1), pl.ds(q2, 1), jnp.where(s2 > 0, db, 0.0))
        return 0

    jax.lax.fori_loop(0, block_c, scatter, 0)


def fused_bucket_pass_pallas(
    x,
    yslab,
    lanes,
    g_row,
    g_col,
    g_sel,
    dinv,
    act,
    seg,
    *,
    block_c: int = 128,
    interpret: bool = True,
    in_place: bool = False,
):
    """One fused pass over a whole bucket; matches ``ref.fused_bucket_pass_ref``.

    Args:
      x: (n, n) iterate.
      yslab: (D, 3, T, C) schedule-native dual slab.
      lanes: (6, D, C) int32 — i1, k1, s1, i2, k2, s2 lane tables
        (scalar-prefetched into SMEM).
      g_row/g_col/g_sel/dinv: (D, T, C) staged gains.
      act/seg: (D, T, C) bool step masks.
      in_place: alias X and the dual slab input→output (enable under jit
        only, like the first-generation kernel).

    Returns (new_x, new_yslab).
    """
    n = x.shape[0]
    D, _, T, C = yslab.shape
    dt = x.dtype
    bc = min(block_c, max(8, -(-C // 8) * 8))
    Cp = -(-C // bc) * bc

    def padc(a, fill):
        if a.shape[-1] == Cp:
            return a
        pad = [(0, 0)] * (a.ndim - 1) + [(0, Cp - C)]
        return jnp.pad(a, pad, constant_values=fill)

    # Pad X so every fixed-length-T slice window stays in bounds; the pad
    # region only ever receives exact zeros.
    np_ = n + T + 1
    xp = jnp.pad(x, ((0, np_ - n), (0, np_ - n)))
    lanes_p = jnp.concatenate(
        [padc(lanes[:2], -1), padc(lanes[2:3], 0),
         padc(lanes[3:5], -1), padc(lanes[5:6], 0)], axis=0
    )
    y_p = padc(yslab, 0)
    g_row_p, g_col_p = padc(g_row, 1.0), padc(g_col, 1.0)
    g_sel_p, dinv_p = padc(g_sel, 1.0), padc(dinv, 1.0)
    act_p = padc(act.astype(jnp.int8), 0)
    seg_p = padc(seg.astype(jnp.int8), 0)

    x_spec = pl.BlockSpec((np_, np_), lambda d, c, s: (0, 0))
    y_spec = pl.BlockSpec((1, 3, T, bc), lambda d, c, s: (d, 0, 0, c))
    tc_spec = pl.BlockSpec((1, T, bc), lambda d, c, s: (d, 0, c))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(D, Cp // bc),
        in_specs=[x_spec, y_spec] + [tc_spec] * 6,
        out_specs=[x_spec, y_spec],
        scratch_shapes=[
            pltpu.VMEM((bc, 2 * T), dt),
            pltpu.VMEM((bc, 2 * T), dt),
            pltpu.VMEM((T, bc), dt),
            pltpu.VMEM((T, bc), dt),
        ],
    )
    # Operand indices include the scalar-prefetch arg (index 0): X is
    # operand 1, the dual slab operand 2.
    aliases = {1: 0, 2: 1} if in_place else {}
    kernel = functools.partial(_fused_kernel, T=T, block_c=bc)
    nx, ny = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((np_, np_), dt),
            jax.ShapeDtypeStruct((D, 3, T, Cp), dt),
        ],
        input_output_aliases=aliases,
        interpret=interpret,
    )(lanes_p, xp, y_p, g_row_p, g_col_p, g_sel_p, dinv_p, act_p, seg_p)
    return nx[:n, :n], ny[..., :C]

"""Pallas TPU megakernel, third generation: one batch- and shard-aware
fused-pass kernel behind every sweep path (DESIGN.md §10).

The second-generation kernel (DESIGN.md §4, superseded) fused a whole
bucket into one ``pallas_call`` but baked the staged projection gains and
act masks into the trace as constants and served exactly one instance per
launch. Gen-3 changes the contract, not the math:

  * **Leading instance grid axis**: the grid is ``(B, D, lane blocks)`` —
    a whole serve bucket of B padded instances runs as ONE ``pallas_call``.
  * **Weights as runtime operands**: the staged gains ``g_row / g_col /
    g_sel / dinv`` and the per-instance (ghost-aware) ``act`` masks arrive
    with a leading batch axis as ordinary operands, never trace constants —
    new instances/batches NEVER trigger recompilation (the §8
    weights-as-operands re-partitioning applied to the kernel itself).
    Only the lane tables, the ``seg`` masks and the folded geometry — pure
    functions of the bucket shape — stay shared.
  * **Delta-output mode** (``out_delta=True``, single diagonal): instead of
    updating X in place the kernel scatters the act-masked deltas into a
    zero buffer — exactly the per-device delta matrix the sharded solver
    psum-merges per diagonal (bitwise-equal to the jnp fused path's
    scatter, because both scatter the same ``where(act, new - old, 0)``
    values into zeros).

Two staging engines implement the same contract:

  * ``mode="dma"`` (TPU production): the gen-2 per-lane body — X resident
    in VMEM per instance via a constant-index output block, per-lane
    dynamic-slice gather/scatter driven by the scalar-prefetched lane
    tables, zero-delta-tail exactness (the in-kernel restatement of the
    paper's conflict-freedom argument, §III.A). The batch axis is squeezed
    out of every BlockSpec (``None`` leading block dim), so the body is
    the gen-2 body verbatim; instance b's X is fetched at grid step
    (b, 0, 0) and written back once per instance.
  * ``mode="vector"`` (CPU / interpret default): per instance, one
    ``lax.scan`` over the bucket's diagonals of the jnp fused reference's
    per-diagonal body — gather, ``ref.fused_diag_sweep``, scatter —
    vmapped over B, using the folded-geometry operand. When the lane axis
    fits one block (every production bucket) this dispatches XLA-native
    (``_vector_bucket_pass``): the pallas grid would be a single step
    whose interpret wrapper only adds whole-buffer copies around the
    identical body, so the batched kernel path costs what the vmapped
    reference costs. The multi-block fallback keeps the pallas grid (one
    diagonal per step); interpret mode executes kernels as traced jnp,
    where the dma engine's per-lane ``fori_loop`` staging is
    dispatch-bound (~20x slower than the vectorized gathers).

VMEM budget (dma mode, per grid step): (n+T+1)^2 * 4 (one instance's
resident X) + 9*T*block_c * 4 (dual + gain + mask blocks) + 6*T*block_c
* 4 (scratch) — identical to gen-2, because the batch axis contributes
nothing resident: at n = 96, T = 47, block_c = 128 that is ~0.4 MiB +
~2.9 MiB, comfortably inside a ~16 MiB v5e VMEM budget for any B. The
vector engine holds B*(n+T+1)^2 floats and is CPU-only by construction.

Exactness note shared by both engines: every scatter outside a lane's
active cells adds an exact 0.0 (act-masked deltas; carry deltas guarded
by ``sizes > 0``), so overlapping windows / wrapped padding indices only
ever add zeros — and X cells are never -0.0 (they start at +0.0 and only
accumulate sums), so zero-adds are bitwise no-ops.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.metric_project.ref import fused_diag_sweep, fused_step

__all__ = ["fused_bucket_pass_pallas"]


def _fused_kernel_dma(
    lanes_ref,  # (6, D, Cp) int32 scalar-prefetch: i1, k1, s1, i2, k2, s2
    x_ref,      # (np, np) this instance's iterate (batch axis squeezed)
    y_ref,      # (1, 3, T, Cb) dual block of this (instance, diagonal, block)
    grow_ref,   # (1, T, Cb) per-instance staged gains (runtime operands)
    gcol_ref,
    gsel_ref,
    dinv_ref,
    act_ref,    # (1, T, Cb) int8 per-instance (ghost-aware) step mask
    seg_ref,    # (1, T, Cb) int8 shared segment mask
    ox_ref,     # (np, np) resident working buffer: X, or the delta matrix
    oy_ref,     # (1, 3, T, Cb)
    rowS,       # (Cb, 2T) scratch: folded row slices, then row deltas
    colS,       # (Cb, 2T) scratch: folded col slices, then col deltas
    dR,         # (T, Cb) scratch: act-masked row deltas (sweep layout)
    dC,         # (T, Cb) scratch: act-masked col deltas
    *,
    T: int,
    block_c: int,
    out_delta: bool,
):
    d = pl.program_id(1)
    cb = pl.program_id(2)
    # Constant index components must match the int32 traced starts even
    # under jax_enable_x64 (python ints would promote to int64).
    i32 = lambda v: jnp.asarray(v, jnp.int32)

    # First grid step of every instance: x_ref/ox_ref map fresh blocks
    # whenever the batch index advances, so this fires once per instance.
    @pl.when((d == 0) & (cb == 0))
    def _init_x():
        ox_ref[...] = (
            jnp.zeros(ox_ref.shape, ox_ref.dtype) if out_delta
            else x_ref[...]
        )

    # Delta mode reads the pristine X (single diagonal: every gather
    # precedes every scatter semantically); in-place mode reads the
    # resident buffer, which carries earlier diagonals' updates.
    src_ref = x_ref if out_delta else ox_ref
    dt = x_ref.dtype
    col0 = cb * block_c

    def lane_scalars(c):
        i1 = lanes_ref[0, d, col0 + c]
        k1 = lanes_ref[1, d, col0 + c]
        s1 = lanes_ref[2, d, col0 + c]
        i2 = lanes_ref[3, d, col0 + c]
        k2 = lanes_ref[4, d, col0 + c]
        s2 = lanes_ref[5, d, col0 + c]
        # Padding lanes carry -1; clamp to cell (0, 0) / row 0 — their
        # deltas are exactly zero, so the clamped windows only ever add 0.
        r1 = jnp.maximum(i1, 0)
        q1 = jnp.maximum(k1, 0)
        r2 = jnp.maximum(i2, 0)
        q2 = jnp.maximum(k2, 0)
        return s1, s2, r1, q1, r2, q2

    # ---- gather: stage folded row/col slices of X and the two carries.
    # Lane c, segment A occupies folded steps [0, s1) (slices from (i1, k1)),
    # segment B is appended at [s1, s1 + s2) — writing the fixed-length-T
    # segment-B slice at dynamic offset s1 performs the fold in-place.
    def stage(c, xik):
        c = i32(c)
        s1, s2, r1, q1, r2, q2 = lane_scalars(c)
        rowA = pl.load(src_ref, (pl.ds(r1, 1), pl.ds(r1 + 1, T)))
        pl.store(rowS, (pl.ds(c, 1), pl.ds(i32(0), T)), rowA)
        rowB = pl.load(src_ref, (pl.ds(r2, 1), pl.ds(r2 + 1, T)))
        pl.store(rowS, (pl.ds(c, 1), pl.ds(s1, T)), rowB)
        colA = pl.load(src_ref, (pl.ds(r1 + 1, T), pl.ds(q1, 1)))
        pl.store(colS, (pl.ds(c, 1), pl.ds(i32(0), T)), colA.reshape(1, T))
        colB = pl.load(src_ref, (pl.ds(r2 + 1, T), pl.ds(q2, 1)))
        pl.store(colS, (pl.ds(c, 1), pl.ds(s1, T)), colB.reshape(1, T))
        xa = pl.load(src_ref, (pl.ds(r1, 1), pl.ds(q1, 1)))
        xb = pl.load(src_ref, (pl.ds(r2, 1), pl.ds(q2, 1)))
        return jax.lax.dynamic_update_slice(
            xik, jnp.concatenate([xa, xb], axis=0), (i32(0), c)
        )

    xik0 = jax.lax.fori_loop(
        0, block_c, stage, jnp.zeros((2, block_c), dt)
    )

    # ---- sweep: sequential in t, vectorized over the lane block.
    rowb = rowS[...][:, :T].T  # (T, Cb)
    colb = colS[...][:, :T].T
    yv = y_ref[0]              # (3, T, Cb); preloaded so the aliased
    grow = grow_ref[0]         # output writes below can never shadow reads
    gcol = gcol_ref[0]
    gsel = gsel_ref[0]
    dinv = dinv_ref[0]
    actv = act_ref[0] != 0
    segv = seg_ref[0] != 0

    def body(t, carry):
        t = i32(t)
        xa, xb = carry  # (1, Cb) — the two folded x_ik carries
        row = lambda a: jax.lax.dynamic_slice(a, (t, i32(0)), (1, block_c))
        yrow = lambda m: jax.lax.dynamic_slice(
            yv, (i32(m), t, i32(0)), (1, 1, block_c)
        ).reshape(1, block_c)
        xij, xjk = row(rowb), row(colb)
        act, sg = row(actv), row(segv)
        xc = jnp.where(sg, xb, xa)
        nij, nik, njk, t0, t1, t2 = fused_step(
            xij, xc, xjk, yrow(0), yrow(1), yrow(2),
            row(grow), row(gsel), row(gcol), row(dinv),
        )
        for m, th in ((0, t0), (1, t1), (2, t2)):
            pl.store(
                oy_ref,
                (pl.ds(i32(0), 1), pl.ds(i32(m), 1), pl.ds(t, 1),
                 pl.ds(i32(0), block_c)),
                th.reshape(1, 1, 1, block_c),
            )
        pl.store(dR, (pl.ds(t, 1), pl.ds(i32(0), block_c)),
                 jnp.where(act, nij - xij, 0.0))
        pl.store(dC, (pl.ds(t, 1), pl.ds(i32(0), block_c)),
                 jnp.where(act, njk - xjk, 0.0))
        nik = jnp.where(act, nik, xc)
        return jnp.where(sg, xa, nik), jnp.where(sg, nik, xb)

    xa, xb = jax.lax.fori_loop(0, T, body, (xik0[0:1, :], xik0[1:2, :]))

    # ---- scatter: act-masked deltas, unfolded by the same dynamic offsets.
    # Reuse the staging scratch in folded lane-major layout; the upper T
    # columns are zero so segment-B windows read zeros beyond their extent.
    zer = jnp.zeros((block_c, T), dt)
    rowS[...] = jnp.concatenate([dR[...].T, zer], axis=1)
    colS[...] = jnp.concatenate([dC[...].T, zer], axis=1)
    tvec = jax.lax.broadcasted_iota(jnp.int32, (1, T), 1)

    def scatter(c, _):
        c = i32(c)
        s1, s2, r1, q1, r2, q2 = lane_scalars(c)

        def add(rows, cols, delta):
            cur = pl.load(ox_ref, (rows, cols))
            pl.store(ox_ref, (rows, cols), cur + delta)

        dA = pl.load(rowS, (pl.ds(c, 1), pl.ds(i32(0), T)))
        add(pl.ds(r1, 1), pl.ds(r1 + 1, T), jnp.where(tvec < s1, dA, 0.0))
        dB = pl.load(rowS, (pl.ds(c, 1), pl.ds(s1, T)))
        add(pl.ds(r2, 1), pl.ds(r2 + 1, T), dB)
        cA = pl.load(colS, (pl.ds(c, 1), pl.ds(i32(0), T)))
        cA = jnp.where(tvec < s1, cA, 0.0).reshape(T, 1)
        add(pl.ds(r1 + 1, T), pl.ds(q1, 1), cA)
        cB = pl.load(colS, (pl.ds(c, 1), pl.ds(s1, T))).reshape(T, 1)
        add(pl.ds(r2 + 1, T), pl.ds(q2, 1), cB)
        lane = lambda a, s: jax.lax.dynamic_slice(a, (i32(s), c), (1, 1))
        da = lane(xa, 0) - lane(xik0, 0)
        add(pl.ds(r1, 1), pl.ds(q1, 1), jnp.where(s1 > 0, da, 0.0))
        db = lane(xb, 0) - lane(xik0, 1)
        add(pl.ds(r2, 1), pl.ds(q2, 1), jnp.where(s2 > 0, db, 0.0))
        return 0

    jax.lax.fori_loop(0, block_c, scatter, 0)


def _diag_one(xb, outb, lane, geo, seg_d, yb, gr, gc, gs, dv, ab, unroll):
    """One diagonal of one instance — the vector engine's unit of work.

    Mirror of ``ref.fused_bucket_pass_ref``'s per-diagonal body: same
    gathers, same staged sweep, same act-masked scatter. ``xb`` is the
    gather source, ``outb`` the scatter target (the same values in
    in-place mode; zeros in delta mode)."""
    i1, k1, s1, i2, k2, s2 = lane
    J, iN, kN = geo
    rowb = xb.at[iN, J].get(mode="fill", fill_value=0.0)
    colb = xb.at[J, kN].get(mode="fill", fill_value=0.0)
    xikp = jnp.stack([
        xb.at[i1, k1].get(mode="fill", fill_value=0.0),
        xb.at[i2, k2].get(mode="fill", fill_value=0.0),
    ])
    nrow, ncol, nxikp, ny = fused_diag_sweep(
        rowb, colb, xikp, yb, gr, gc, gs, dv, ab, seg_d, unroll=unroll
    )
    add = lambda a, idx, v: a.at[idx].add(
        v, mode="drop", unique_indices=True
    )
    outb = add(outb, (iN, J), jnp.where(ab, nrow - rowb, 0))
    outb = add(outb, (J, kN), jnp.where(ab, ncol - colb, 0))
    outb = add(outb, (i1, k1), jnp.where(s1 > 0, nxikp[0] - xikp[0], 0))
    outb = add(outb, (i2, k2), jnp.where(s2 > 0, nxikp[1] - xikp[1], 0))
    return outb, ny


def _vector_diag_body(xv, out, lane, geo, segv, yv, grow, gcol, gsel,
                      dinv, actv, unroll):
    """One diagonal of the vector engine, vmapped over the batch."""
    one = lambda xb, outb, yb, gr, gc, gs, dv, ab: _diag_one(
        xb, outb, lane, geo, segv, yb, gr, gc, gs, dv, ab, unroll
    )
    return jax.vmap(one)(xv, out, yv, grow, gcol, gsel, dinv, actv)


def _vector_bucket_pass(x, yslab, lanes, g_row, g_col, g_sel, dinv, act,
                        seg, geom, *, unroll, out_delta):
    """XLA-native execution of the vector engine: per instance, one
    ``lax.scan`` over the bucket's diagonals, vmapped over the batch —
    the exact program structure the jnp fused reference compiles to, so
    the batched kernel path costs what the vmapped reference costs.

    This is the single-lane-block CPU dispatch of
    ``fused_bucket_pass_pallas``: with one lane block the pallas grid
    would be a single step whose interpret-mode wrapper contributes only
    whole-buffer block copies around this same body, so the wrapper is
    skipped. The pallas grid path remains the dma engine's contract (and
    the multi-block vector fallback); results are bitwise identical."""
    segs = seg != 0
    acts = act != 0
    D = yslab.shape[1]
    idx = jnp.arange(D, dtype=jnp.int32)
    at = lambda a, ax, d: jax.lax.dynamic_index_in_dim(
        a, d, ax, keepdims=False
    )

    def one(xb, yb, gr, gc, gs, dv, ab):
        def diag(carry, d):
            xc, out = carry
            out2, ny = _diag_one(
                xc, out, at(lanes, 1, d), at(geom, 1, d), at(segs, 0, d),
                at(yb, 0, d), at(gr, 0, d), at(gc, 0, d), at(gs, 0, d),
                at(dv, 0, d), at(ab, 0, d), unroll,
            )
            # Delta mode gathers from the pristine X every diagonal
            # (D == 1 by contract); in-place mode threads the iterate.
            return (xc if out_delta else out2, out2), ny

        out0 = jnp.zeros_like(xb) if out_delta else xb
        (_, nx), ny = jax.lax.scan(diag, (xb, out0), idx)
        return nx, ny

    return jax.vmap(one)(x, yslab, g_row, g_col, g_sel, dinv, acts)


def _fused_kernel_vector(
    lanes_ref,  # (6, D, Cp) int32 scalar-prefetch lane tables
    x_ref,      # (B, np, np) whole padded batch (resident)
    y_ref,      # (B, 1, 3, T, Cb)
    grow_ref,   # (B, 1, T, Cb) per-instance staged gains
    gcol_ref,
    gsel_ref,
    dinv_ref,
    act_ref,    # (B, 1, T, Cb) int8 per-instance step mask
    seg_ref,    # (1, T, Cb) int8 shared segment mask
    geom_ref,   # (3, 1, T, Cb) int32 folded geometry: J, iN, kN
    ox_ref,     # (B, np, np) working buffer: X, or the delta matrices
    oy_ref,     # (B, 1, 3, T, Cb)
    *,
    T: int,
    block_c: int,
    unroll: int,
    out_delta: bool,
):
    d = pl.program_id(1)
    cb = pl.program_id(2)

    @pl.when((d == 0) & (cb == 0))
    def _init_x():
        ox_ref[...] = (
            jnp.zeros(ox_ref.shape, ox_ref.dtype) if out_delta
            else x_ref[...]
        )

    col0 = cb * block_c
    lane = jax.lax.dynamic_slice(
        lanes_ref[...], (jnp.int32(0), d, col0), (6, 1, block_c)
    ).reshape(6, block_c)
    xv = x_ref[...] if out_delta else ox_ref[...]
    base = ox_ref[...] if out_delta else xv
    nxv, ny = _vector_diag_body(
        xv, base, lane, geom_ref[...][:, 0], seg_ref[0] != 0,
        y_ref[...][:, 0], grow_ref[...][:, 0], gcol_ref[...][:, 0],
        gsel_ref[...][:, 0], dinv_ref[...][:, 0], act_ref[...][:, 0] != 0,
        unroll,
    )
    ox_ref[...] = nxv
    oy_ref[...] = ny[:, None]


def fused_bucket_pass_pallas(
    x,
    yslab,
    lanes,
    g_row,
    g_col,
    g_sel,
    dinv,
    act,
    seg,
    geom,
    *,
    block_c: int = 128,
    interpret: bool = True,
    in_place: bool = False,
    mode: str = "vector",
    unroll: int = 4,
    out_delta: bool = False,
):
    """One fused pass over a whole bucket of B instances; per instance it
    matches ``ref.fused_bucket_pass_ref`` bitwise on every live cell.

    Args:
      x: (B, n, n) iterates.
      yslab: (B, D, 3, T, C) schedule-native dual slabs.
      lanes: (6, D, C) int32 — i1, k1, s1, i2, k2, s2 lane tables, shared
        across the batch (scalar-prefetched into SMEM).
      g_row/g_col/g_sel/dinv: (B, D, T, C) per-instance staged gains —
        runtime operands, never trace constants.
      act: (B, D, T, C) per-instance (ghost-aware) step masks.
      seg: (D, T, C) shared segment mask.
      geom: (3, D, T, C) int32 folded geometry (J, iN, kN) — consumed by
        the vector engine; ignored (and not shipped) in dma mode.
      mode: "dma" (TPU per-lane engine) or "vector" (CPU/interpret
        vmapped engine). Same contract, same results.
      unroll: inner-scan unroll of the vector engine's staged sweep.
      in_place: alias X and the dual slab input→output (enable under jit
        only, like the earlier generations).
      out_delta: return the act-masked update deltas scattered into zeros
        instead of the updated X (requires D == 1 — the sharded solver's
        per-diagonal psum contract). X is read-only; duals still update.

    Returns (new_x, new_yslab) — (B, n, n) and (B, D, 3, T, C); new_x is
    the delta matrix batch when ``out_delta``.
    """
    if mode not in ("dma", "vector"):
        raise ValueError(f"unknown megakernel mode {mode!r}")
    B, n, _ = x.shape
    _, D, _, T, C = yslab.shape
    if out_delta and D != 1:
        raise ValueError("out_delta requires a single-diagonal call (D=1)")
    if mode == "vector" and block_c >= C:
        # Single lane block: dispatch the vector engine XLA-native (see
        # _vector_bucket_pass) — the pallas wrapper would add only
        # whole-buffer copies around the identical body.
        return _vector_bucket_pass(
            x, yslab, lanes, g_row, g_col, g_sel, dinv, act, seg,
            geom.astype(jnp.int32), unroll=unroll, out_delta=out_delta,
        )
    dt = x.dtype
    if mode == "vector":
        # The vector engine gathers/scatters by index with fill/drop
        # semantics (like the jnp ref), so neither the lane axis nor X
        # needs padding — pad-free keeps the multi-block CPU path close
        # to the ref's cost.
        bc = block_c
    else:
        bc = min(block_c, max(8, -(-C // 8) * 8))
    Cp = -(-C // bc) * bc

    def padc(a, fill):
        if a.shape[-1] == Cp:
            return a
        pad = [(0, 0)] * (a.ndim - 1) + [(0, Cp - C)]
        return jnp.pad(a, pad, constant_values=fill)

    # dma mode pads X so every fixed-length-T slice window stays in
    # bounds (the pad region only ever receives exact zeros); the vector
    # engine runs on the unpadded iterate.
    np_ = n if mode == "vector" else n + T + 1
    xp = x if np_ == n else jnp.pad(x, ((0, 0), (0, np_ - n), (0, np_ - n)))
    lanes_p = jnp.concatenate(
        [padc(lanes[:2], -1), padc(lanes[2:3], 0),
         padc(lanes[3:5], -1), padc(lanes[5:6], 0)], axis=0
    )
    y_p = padc(yslab, 0)
    g_row_p, g_col_p = padc(g_row, 1.0), padc(g_col, 1.0)
    g_sel_p, dinv_p = padc(g_sel, 1.0), padc(dinv, 1.0)
    # int8 masks are a TPU operand-dtype requirement; the vector engine
    # ships the bools straight through (the cast is a slab-sized pass
    # per call that the CPU path doesn't need).
    mask_dt = jnp.int8 if mode == "dma" else act.dtype
    act_p = padc(act.astype(mask_dt), 0)
    seg_p = padc(seg.astype(mask_dt), 0)

    grid = (B if mode == "dma" else 1, D, Cp // bc)
    if mode == "dma":
        # Batch axis squeezed out of every per-instance BlockSpec: the
        # kernel body sees gen-2 shapes, one instance at a time.
        x_spec = pl.BlockSpec((None, np_, np_), lambda b, d, c, s: (b, 0, 0))
        y_spec = pl.BlockSpec(
            (None, 1, 3, T, bc), lambda b, d, c, s: (b, d, 0, 0, c)
        )
        tc_spec = pl.BlockSpec(
            (None, 1, T, bc), lambda b, d, c, s: (b, d, 0, c)
        )
        seg_spec = pl.BlockSpec((1, T, bc), lambda b, d, c, s: (d, 0, c))
        in_specs = [x_spec, y_spec] + [tc_spec] * 5 + [seg_spec]
        operands = (xp, y_p, g_row_p, g_col_p, g_sel_p, dinv_p, act_p, seg_p)
        out_specs = [x_spec, y_spec]
        out_shape = [
            jax.ShapeDtypeStruct((B, np_, np_), dt),
            jax.ShapeDtypeStruct((B, D, 3, T, Cp), dt),
        ]
        scratch = [
            pltpu.VMEM((bc, 2 * T), dt),
            pltpu.VMEM((bc, 2 * T), dt),
            pltpu.VMEM((T, bc), dt),
            pltpu.VMEM((T, bc), dt),
        ]
        kernel = functools.partial(
            _fused_kernel_dma, T=T, block_c=bc, out_delta=out_delta
        )
    else:
        geom_p = padc(geom.astype(jnp.int32), -1)
        x_spec = pl.BlockSpec(
            (B, np_, np_), lambda b, d, c, s: (0, 0, 0)
        )
        y_spec = pl.BlockSpec(
            (B, 1, 3, T, bc), lambda b, d, c, s: (0, d, 0, 0, c)
        )
        tc_spec = pl.BlockSpec(
            (B, 1, T, bc), lambda b, d, c, s: (0, d, 0, c)
        )
        seg_spec = pl.BlockSpec((1, T, bc), lambda b, d, c, s: (d, 0, c))
        geo_spec = pl.BlockSpec(
            (3, 1, T, bc), lambda b, d, c, s: (0, d, 0, c)
        )
        vkernel = _fused_kernel_vector
        in_specs = (
            [x_spec, y_spec] + [tc_spec] * 5 + [seg_spec, geo_spec]
        )
        operands = (
            xp, y_p, g_row_p, g_col_p, g_sel_p, dinv_p, act_p, seg_p, geom_p
        )
        out_specs = [x_spec, y_spec]
        out_shape = [
            jax.ShapeDtypeStruct((B, np_, np_), dt),
            jax.ShapeDtypeStruct((B, D, 3, T, Cp), dt),
        ]
        scratch = []
        kernel = functools.partial(
            vkernel, T=T, block_c=bc, unroll=unroll,
            out_delta=out_delta,
        )

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        scratch_shapes=scratch,
    )
    # Operand indices include the scalar-prefetch arg (index 0): X is
    # operand 1, the dual slab operand 2. Delta mode must keep X intact
    # (it is re-read by the caller's psum merge), so only duals alias.
    if not in_place:
        aliases = {}
    elif out_delta:
        aliases = {2: 1}
    else:
        aliases = {1: 0, 2: 1}
    nx, ny = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=out_shape,
        input_output_aliases=aliases,
        interpret=interpret,
    )(lanes_p, *operands)
    return nx[:, :n, :n], ny[..., :C]

"""Selective state-space layers: Mamba-1 (falcon-mamba) and Mamba-2 / SSD
(zamba2), TPU-adapted.

Mamba-1 is computed with a sequential ``lax.scan`` over time carrying the
(B, d_inner, d_state) state — the memory-minimal formulation (the CUDA
selective-scan kernel has no TPU analogue; the scan is the jax-native
equivalent).

Mamba-2 uses the *chunked SSD* formulation: the sequence is split into
chunks of ``ssd_chunk``; within a chunk the output is an attention-like
einsum (MXU work), across chunks a short sequential scan carries the
(B, heads, head_dim, d_state) state. This is the TPU-idiomatic mapping of
the SSD algorithm — 16 sequential steps instead of 4096 at train_4k.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig, ParamSpec
from repro.models.layers import rmsnorm_apply

__all__ = [
    "mamba1_specs", "mamba1_apply", "mamba1_decode", "mamba1_cache_specs",
    "mamba2_specs", "mamba2_apply", "mamba2_decode", "mamba2_cache_specs",
]


# ---------------------------------------------------------------------------
# shared: causal depthwise conv1d over (B, S, C) with width W
# ---------------------------------------------------------------------------


def _causal_conv(x, kernel, bias, conv_state=None):
    """x: (B,S,C), kernel: (W,C), bias: (C,). conv_state: (B,W-1,C) or None.
    Returns (y, new_state)."""
    W = kernel.shape[0]
    if conv_state is None:
        pad = jnp.zeros((x.shape[0], W - 1, x.shape[2]), x.dtype)
    else:
        pad = conv_state
    xp = jnp.concatenate([pad, x], axis=1)  # (B, S+W-1, C)
    y = sum(
        xp[:, w : w + x.shape[1], :] * kernel[w][None, None, :] for w in range(W)
    )
    new_state = xp[:, -(W - 1):, :] if W > 1 else pad
    return y + bias[None, None, :], new_state


# ---------------------------------------------------------------------------
# Mamba-1
# ---------------------------------------------------------------------------


def mamba1_specs(cfg: ModelConfig) -> dict:
    d, di, N, W = cfg.d_model, cfg.d_inner, cfg.d_state, cfg.conv_width
    R = cfg.dt_rank or max(1, d // 16)
    return {
        "in_proj": ParamSpec((d, 2 * di), ("embed", "inner")),
        "conv_w": ParamSpec((W, di), ("conv", "inner"), scale=0.5),
        "conv_b": ParamSpec((di,), ("inner",), init="zeros"),
        "x_proj": ParamSpec((di, R + 2 * N), ("inner", None)),
        "dt_proj": ParamSpec((R, di), (None, "inner")),
        "dt_bias": ParamSpec((di,), ("inner",), init="ones", scale=0.0),
        "A_log": ParamSpec((di, N), ("inner", "state"), init="ones"),
        "D": ParamSpec((di,), ("inner",), init="ones"),
        "out_proj": ParamSpec((di, d), ("inner", "embed")),
    }


def _mamba1_core(cfg, p, x1c, dt, B_, C_, h0):
    """Sequential selective scan. x1c: (B,S,di), dt: (B,S,di),
    B_/C_: (B,S,N), h0: (B,di,N). Returns (y (B,S,di), hT)."""
    A = -jnp.exp(p["A_log"].astype(jnp.float32))  # (di, N)

    def step(h, inp):
        xt, dtt, bt, ct = inp  # (B,di), (B,di), (B,N), (B,N)
        da = jnp.exp(dtt[..., None] * A[None])  # (B,di,N)
        h = da * h + (dtt * xt)[..., None] * bt[:, None, :]
        y = jnp.einsum("bdn,bn->bd", h, ct)
        return h, y

    xs = (
        jnp.moveaxis(x1c.astype(jnp.float32), 1, 0),
        jnp.moveaxis(dt, 1, 0),
        jnp.moveaxis(B_.astype(jnp.float32), 1, 0),
        jnp.moveaxis(C_.astype(jnp.float32), 1, 0),
    )
    hT, ys = jax.lax.scan(step, h0, xs)
    y = jnp.moveaxis(ys, 0, 1)  # (B,S,di)
    y = y + p["D"].astype(jnp.float32)[None, None, :] * x1c.astype(jnp.float32)
    return y.astype(x1c.dtype), hT


def _mamba1_pre(cfg, p, x, conv_state=None):
    di, N = cfg.d_inner, cfg.d_state
    R = cfg.dt_rank or max(1, cfg.d_model // 16)
    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    x1, z = xz[..., :di], xz[..., di:]
    x1c, new_conv = _causal_conv(x1, p["conv_w"], p["conv_b"], conv_state)
    x1c = jax.nn.silu(x1c)
    xdbc = jnp.einsum("bsi,ie->bse", x1c, p["x_proj"])
    dt_r, B_, C_ = xdbc[..., :R], xdbc[..., R : R + N], xdbc[..., R + N :]
    dt = jax.nn.softplus(
        jnp.einsum("bsr,ri->bsi", dt_r, p["dt_proj"]).astype(jnp.float32)
        + p["dt_bias"].astype(jnp.float32)[None, None]
    )
    return x1c, z, dt, B_, C_, new_conv


def mamba1_apply(cfg: ModelConfig, p, x):
    """Training forward: x (B,S,D) → (B,S,D)."""
    B = x.shape[0]
    x1c, z, dt, B_, C_, _ = _mamba1_pre(cfg, p, x)
    h0 = jnp.zeros((B, cfg.d_inner, cfg.d_state), jnp.float32)
    y, _ = _mamba1_core(cfg, p, x1c, dt, B_, C_, h0)
    y = y * jax.nn.silu(z)
    return jnp.einsum("bsi,id->bsd", y, p["out_proj"])


def mamba1_decode(cfg: ModelConfig, p, x, cache):
    """Single-step decode: x (B,1,D), cache {conv:(B,W-1,di), ssm:(B,di,N)}."""
    x1c, z, dt, B_, C_, new_conv = _mamba1_pre(cfg, p, x, cache["conv"])
    y, hT = _mamba1_core(cfg, p, x1c, dt, B_, C_, cache["ssm"].astype(jnp.float32))
    y = y * jax.nn.silu(z)
    out = jnp.einsum("bsi,id->bsd", y, p["out_proj"])
    return out, {"conv": new_conv, "ssm": hT.astype(cache["ssm"].dtype)}


def mamba1_cache_specs(cfg: ModelConfig, batch: int) -> dict:
    return {
        "conv": ParamSpec((batch, cfg.conv_width - 1, cfg.d_inner), ("batch", "conv", "inner"), init="zeros"),
        "ssm": ParamSpec((batch, cfg.d_inner, cfg.d_state), ("batch", "inner", "state"), init="zeros"),
    }


# ---------------------------------------------------------------------------
# Mamba-2 (SSD, chunked)
# ---------------------------------------------------------------------------


def _m2_dims(cfg: ModelConfig):
    H = cfg.d_inner // cfg.ssm_head_dim
    return H, cfg.ssm_head_dim, cfg.d_state


def mamba2_specs(cfg: ModelConfig) -> dict:
    d, di, W = cfg.d_model, cfg.d_inner, cfg.conv_width
    H, Pd, N = _m2_dims(cfg)
    conv_dim = di + 2 * N  # conv over (x, B, C)
    return {
        "in_proj": ParamSpec((d, 2 * di + 2 * N + H), ("embed", "inner")),
        "conv_w": ParamSpec((W, conv_dim), ("conv", "inner"), scale=0.5),
        "conv_b": ParamSpec((conv_dim,), ("inner",), init="zeros"),
        "A_log": ParamSpec((H,), (None,), init="ones"),
        "D": ParamSpec((H,), (None,), init="ones"),
        "dt_bias": ParamSpec((H,), (None,), init="zeros"),
        "norm": ParamSpec((di,), ("inner",), init="ones"),
        "out_proj": ParamSpec((di, d), ("inner", "embed")),
    }


def _m2_pre(cfg, p, x, conv_state=None):
    di, N = cfg.d_inner, cfg.d_state
    H, Pd, _ = _m2_dims(cfg)
    proj = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    z = proj[..., :di]
    xbc = proj[..., di : 2 * di + 2 * N]
    dt_raw = proj[..., 2 * di + 2 * N :]  # (B,S,H)
    xbc_c, new_conv = _causal_conv(xbc, p["conv_w"], p["conv_b"], conv_state)
    xbc_c = jax.nn.silu(xbc_c)
    x1 = xbc_c[..., :di]
    B_ = xbc_c[..., di : di + N]
    C_ = xbc_c[..., di + N :]
    dt = jax.nn.softplus(
        dt_raw.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32)[None, None]
    )  # (B,S,H)
    B, S = x.shape[0], x.shape[1]
    xh = x1.reshape(B, S, H, Pd)
    return xh, z, dt, B_, C_, new_conv


def _ssd_chunked(cfg, p, xh, dt, B_, C_, h0):
    """Chunked SSD. xh: (B,S,H,P), dt: (B,S,H), B_/C_: (B,S,N),
    h0: (B,H,P,N). Returns (y (B,S,H,P), hT)."""
    Bb, S, H, Pd = xh.shape
    N = B_.shape[-1]
    Q = min(cfg.ssd_chunk, S)
    assert S % Q == 0, (S, Q)
    nc = S // Q
    A = -jnp.exp(p["A_log"].astype(jnp.float32))  # (H,)

    a = dt * A[None, None, :]  # (B,S,H), negative
    ac = a.reshape(Bb, nc, Q, H)
    xc = (xh * dt[..., None]).reshape(Bb, nc, Q, H, Pd).astype(jnp.float32)
    Bc = B_.reshape(Bb, nc, Q, N).astype(jnp.float32)
    Cc = C_.reshape(Bb, nc, Q, N).astype(jnp.float32)

    cum = jnp.cumsum(ac, axis=2)  # (B,nc,Q,H)
    # intra-chunk causal decay matrix L[i,j] = exp(cum_i - cum_j), i >= j
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # (B,nc,Q,Q,H)
    causal = jnp.tril(jnp.ones((Q, Q), bool))
    L = jnp.where(causal[None, None, :, :, None], jnp.exp(diff), 0.0)
    y_diag = jnp.einsum("bcin,bcjn,bcijh,bcjhp->bcihp", Cc, Bc, L, xc)

    # per-chunk end state contribution and inter-chunk scan
    decay_out = jnp.exp(cum[:, :, -1:, :] - cum)  # (B,nc,Q,H)
    states = jnp.einsum("bcjn,bcjh,bcjhp->bchpn", Bc, decay_out, xc)
    chunk_decay = jnp.exp(cum[:, :, -1, :])  # (B,nc,H)

    def step(h, inp):
        st, dec = inp  # (B,H,P,N), (B,H)
        h_new = dec[:, :, None, None] * h + st
        return h_new, h  # emit state *entering* the chunk

    hT, h_prev = jax.lax.scan(
        step, h0, (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0))
    )
    h_prev = jnp.moveaxis(h_prev, 0, 1)  # (B,nc,H,P,N)
    decay_in = jnp.exp(cum)  # (B,nc,Q,H)
    y_off = jnp.einsum("bcin,bchpn,bcih->bcihp", Cc, h_prev, decay_in)

    y = (y_diag + y_off).reshape(Bb, S, H, Pd)
    y = y + p["D"].astype(jnp.float32)[None, None, :, None] * xh.astype(jnp.float32)
    return y.astype(xh.dtype), hT


def mamba2_apply(cfg: ModelConfig, p, x):
    Bb = x.shape[0]
    H, Pd, N = _m2_dims(cfg)
    xh, z, dt, B_, C_, _ = _m2_pre(cfg, p, x)
    h0 = jnp.zeros((Bb, H, Pd, N), jnp.float32)
    y, _ = _ssd_chunked(cfg, p, xh, dt, B_, C_, h0)
    y = y.reshape(x.shape[0], x.shape[1], cfg.d_inner)
    y = rmsnorm_apply({"scale": p["norm"]}, y * jax.nn.silu(z))
    return jnp.einsum("bsi,id->bsd", y, p["out_proj"])


def mamba2_decode(cfg: ModelConfig, p, x, cache):
    """x (B,1,D), cache {conv:(B,W-1,conv_dim), ssm:(B,H,P,N)}."""
    H, Pd, N = _m2_dims(cfg)
    xh, z, dt, B_, C_, new_conv = _m2_pre(cfg, p, x, cache["conv"])
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    da = jnp.exp(dt[:, 0, :] * A[None])  # (B,H)
    h = cache["ssm"].astype(jnp.float32)
    xd = (xh[:, 0] * dt[:, 0, :, None]).astype(jnp.float32)  # (B,H,P)
    h = da[:, :, None, None] * h + xd[..., None] * B_[:, 0, None, None, :].astype(jnp.float32)
    y = jnp.einsum("bhpn,bn->bhp", h, C_[:, 0].astype(jnp.float32))
    y = y + p["D"].astype(jnp.float32)[None, :, None] * xh[:, 0].astype(jnp.float32)
    y = y.reshape(x.shape[0], 1, cfg.d_inner).astype(x.dtype)
    y = rmsnorm_apply({"scale": p["norm"]}, y * jax.nn.silu(z))
    out = jnp.einsum("bsi,id->bsd", y, p["out_proj"])
    return out, {"conv": new_conv, "ssm": h.astype(cache["ssm"].dtype)}


def mamba2_cache_specs(cfg: ModelConfig, batch: int) -> dict:
    H, Pd, N = _m2_dims(cfg)
    conv_dim = cfg.d_inner + 2 * N
    return {
        "conv": ParamSpec((batch, cfg.conv_width - 1, conv_dim), ("batch", "conv", "inner"), init="zeros"),
        "ssm": ParamSpec((batch, H, Pd, N), ("batch", "heads", None, "state"), init="zeros"),
    }

"""Model configuration + parameter/sharding machinery.

One ``ModelConfig`` covers all 10 assigned architectures (dense / MoE / MLA /
SSM / hybrid / enc-dec / VLM-backbone). Parameters are built as a pytree of
``ParamSpec`` (shape + logical axes + init), materialized either as real
arrays (smoke tests, training) or as ShapeDtypeStructs (the dry-run — no
allocation).

Sharding is *rule based*: every parameter axis carries a logical name
('vocab', 'heads', 'ff', 'experts', 'embed', ...); ``logical_to_spec`` maps
logical names to mesh axes, sharding an axis ONLY when its size is divisible
by the mesh axis — otherwise it falls back to replication (e.g. whisper's
8 heads on a 16-way model axis, qwen2-moe's 60 experts). This keeps every
(arch × mesh) cell compilable without per-arch special cases.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# ---------------------------------------------------------------------------
# Config
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int | None = None  # default d_model // n_heads
    norm: str = "rmsnorm"  # rmsnorm | layernorm_np (non-parametric, olmo)
    mlp: str = "swiglu"  # swiglu | geglu | gelu
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    max_seq_len: int = 524288
    # --- MoE ---
    moe: bool = False
    n_routed: int = 0
    n_shared: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    shared_d_ff: int = 0
    capacity_factor: float = 1.25
    first_dense_layers: int = 0  # deepseek-v2: layer 0 is dense
    # --- MLA (deepseek-v2) ---
    mla: bool = False
    kv_lora_rank: int = 0
    qk_rope_dim: int = 64
    qk_nope_dim: int = 128
    v_head_dim: int = 128
    # --- SSM ---
    ssm: str | None = None  # mamba1 | mamba2
    d_inner: int = 0
    d_state: int = 16
    conv_width: int = 4
    dt_rank: int = 0
    ssm_head_dim: int = 64  # mamba2
    ssd_chunk: int = 256  # mamba2 chunked scan
    # --- hybrid (zamba2): shared attention block every k mamba layers ---
    hybrid_period: int = 0
    # --- enc-dec (whisper) ---
    encoder_layers: int = 0
    encoder_seq: int = 1500  # stub audio frontend frames
    # --- vlm (pixtral): stub patch embeddings prepended ---
    num_patches: int = 0
    # --- numerics / padding ---
    dtype: Any = jnp.bfloat16
    vocab_pad_multiple: int = 2048
    # --- performance variants (EXPERIMENTS.md §Perf) ---
    kv_repeat: int = 1        # replicate KV heads to the TP width so the
                              # decode cache shards instead of replicating
    moe_pad_experts: int = 0  # pad routed experts up (e.g. 60→64) for EP
    moe_ep: bool = False      # constrain dispatch buffers to the model axis
    moe_ep_cap_sharded: bool = False  # additionally shard buffer capacity over data
    seq_parallel_acts: bool = False  # Megatron-SP style activation sharding

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_multiple
        return -(-self.vocab_size // m) * m

    @property
    def attn_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for the long_500k shape (SSM state instead of full KV)."""
        return self.family in ("ssm", "hybrid")

    def scaled(self, **kw) -> "ModelConfig":
        """Reduced copy for smoke tests."""
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Parameter specs
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: tuple[int, ...]
    axes: tuple[str | None, ...]  # logical axis names
    init: str = "normal"  # normal | zeros | ones | scaled
    scale: float = 1.0

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def is_param_spec(x) -> bool:
    return isinstance(x, ParamSpec)


# Logical-axis → mesh-axis rules. 'model' is tensor/expert parallelism;
# 'batch' covers (pod, data). None = replicated.
DEFAULT_RULES: dict[str, str | tuple[str, ...] | None] = {
    "vocab": "model",
    "embed": None,
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "ff": "model",
    "experts": "model",
    "expert_ff": "model",  # fallback TP inside experts (used when experts
    # don't divide the mesh axis — see logical_to_spec)
    "inner": "model",  # mamba d_inner
    "state": None,
    "conv": None,
    "lora": None,
    "layers": None,
    "batch": ("pod", "data"),
    "seq": None,
    "kv_seq": None,
}


def _mesh_axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        return int(np.prod([mesh.shape[a] for a in axis if a in mesh.shape]))
    return int(mesh.shape.get(axis, 1))


def _present(mesh: Mesh, axis):
    """Restrict a rule's mesh axes to those present in this mesh."""
    if axis is None:
        return None
    if isinstance(axis, tuple):
        keep = tuple(a for a in axis if a in mesh.shape)
        return keep if keep else None
    return axis if axis in mesh.shape else None


def logical_to_spec(
    axes: tuple[str | None, ...],
    shape: tuple[int, ...],
    mesh: Mesh,
    rules: dict | None = None,
) -> P:
    """Map logical axes to a PartitionSpec, with divisibility fallback.

    An axis is sharded only if its size divides evenly over the mapped mesh
    axes AND those mesh axes are not already used by an earlier dimension of
    the same parameter.
    """
    rules = rules or DEFAULT_RULES
    used: set[str] = set()
    out = []
    for name, size in zip(axes, shape):
        mesh_axis = _present(mesh, rules.get(name)) if name else None
        if mesh_axis is None:
            out.append(None)
            continue
        flat = mesh_axis if isinstance(mesh_axis, tuple) else (mesh_axis,)
        if any(a in used for a in flat):
            out.append(None)
            continue
        if size % _mesh_axis_size(mesh, mesh_axis) != 0:
            out.append(None)  # divisibility fallback → replicate
            continue
        used.update(flat)
        out.append(mesh_axis)
    # PartitionSpec trailing Nones are fine
    return P(*out)


def tree_specs(params_tree, mesh: Mesh, rules=None):
    """ParamSpec tree → PartitionSpec tree."""
    return jax.tree.map(
        lambda s: logical_to_spec(s.axes, s.shape, mesh, rules),
        params_tree,
        is_leaf=is_param_spec,
    )


def tree_shardings(params_tree, mesh: Mesh, rules=None):
    return jax.tree.map(
        lambda spec: NamedSharding(mesh, spec),
        tree_specs(params_tree, mesh, rules),
        is_leaf=lambda x: isinstance(x, P),
    )


def tree_shape_structs(params_tree, dtype):
    """ParamSpec tree → ShapeDtypeStruct tree (dry-run: no allocation)."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, dtype),
        params_tree,
        is_leaf=is_param_spec,
    )


def materialize(params_tree, rng: jax.Array, dtype):
    """ParamSpec tree → real initialized arrays (smoke tests / training)."""
    leaves, treedef = jax.tree.flatten(params_tree, is_leaf=is_param_spec)
    keys = jax.random.split(rng, len(leaves))
    arrs = []
    for spec, key in zip(leaves, keys):
        if spec.init == "zeros":
            a = jnp.zeros(spec.shape, dtype)
        elif spec.init == "ones":
            a = jnp.ones(spec.shape, dtype)
        else:
            fan_in = spec.shape[0] if spec.shape else 1
            std = spec.scale / math.sqrt(max(fan_in, 1))
            a = (jax.random.normal(key, spec.shape, jnp.float32) * std).astype(dtype)
        arrs.append(a)
    return jax.tree.unflatten(treedef, arrs)


def count_params(params_tree) -> int:
    leaves = jax.tree.leaves(params_tree, is_leaf=is_param_spec)
    return sum(int(np.prod(s.shape)) for s in leaves)

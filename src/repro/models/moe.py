"""Mixture-of-Experts layer with sort-based capacity dispatch.

Dispatch avoids the O(tokens × experts × capacity) one-hot tensor of the
classic Mesh-TF formulation (prohibitive at 1M tokens): tokens are routed
top-k, sorted by expert id, position-ranked within their expert group, and
scattered into an (E, capacity, d) buffer — O(tokens·k·d) memory. Batched
expert FFNs then run as one (E, cap, d) × (E, d, f) einsum that shards over
the 'experts' axis (EP) when E divides the model axis, else over 'expert_ff'
(TP inside experts — the qwen2-moe 60-expert fallback).

Tokens over capacity are dropped (standard capacity-factor semantics); their
contribution is the shared-expert path only.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig, ParamSpec
from repro.models import layers

__all__ = ["moe_specs", "moe_apply"]


def n_routed_eff(cfg: ModelConfig) -> int:
    """Routed expert count after optional padding (§Perf H2: 60→64 lets the
    expert axis shard over a 16-way model axis instead of falling back)."""
    return max(cfg.n_routed, cfg.moe_pad_experts or 0)


def moe_specs(cfg: ModelConfig) -> dict:
    d, e, f = cfg.d_model, n_routed_eff(cfg), cfg.moe_d_ff
    out = {
        "router": ParamSpec((d, e), ("embed", None)),
        "w_gate": ParamSpec((e, d, f), ("experts", "embed", "expert_ff")),
        "w_up": ParamSpec((e, d, f), ("experts", "embed", "expert_ff")),
        "w_down": ParamSpec((e, f, d), ("experts", "expert_ff", "embed")),
    }
    if cfg.n_shared > 0:
        shared_ff = cfg.shared_d_ff or cfg.n_shared * cfg.moe_d_ff
        out["shared"] = layers.mlp_specs(cfg, d_ff=shared_ff)
    return out


def _capacity(n_tokens: int, cfg: ModelConfig) -> int:
    cap = int(n_tokens * cfg.top_k * cfg.capacity_factor / cfg.n_routed) + 1
    return max(8, -(-cap // 8) * 8)


def _ep_constraint(x, cfg: ModelConfig):
    """§Perf H2: pin dispatch buffers to the expert-parallel layout so XLA
    moves tokens (all-to-all) instead of all-reducing whole buffers."""
    if not cfg.moe_ep:
        return x
    from jax.sharding import PartitionSpec as P

    if cfg.moe_ep_cap_sharded and x.ndim >= 2:
        spec = P("model", "data", *([None] * (x.ndim - 2)))
    else:
        spec = P("model", *([None] * (x.ndim - 1)))
    return jax.lax.with_sharding_constraint(x, spec)


def moe_apply(cfg: ModelConfig, p, x):
    """x: (B, S, D) → (B, S, D). Returns (out, aux) with load-balance loss."""
    B, S, D = x.shape
    N = B * S
    E, K = n_routed_eff(cfg), cfg.top_k
    xf = x.reshape(N, D)

    logits = jnp.einsum("nd,de->ne", xf.astype(jnp.float32), p["router"].astype(jnp.float32))
    if E > cfg.n_routed:  # mask padded (dummy) experts out of routing
        pad_bias = jnp.where(jnp.arange(E) < cfg.n_routed, 0.0, -1e30)
        logits = logits + pad_bias[None, :]
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, K)  # (N, K)
    top_w = top_w / jnp.sum(top_w, axis=-1, keepdims=True)

    # ---- sort-based position-in-expert ranking ----
    slot_e = top_e.reshape(-1)  # (N*K,)
    order = jnp.argsort(slot_e, stable=True)
    ranks = jnp.zeros((N * K,), jnp.int32).at[order].set(
        jnp.arange(N * K, dtype=jnp.int32)
    )
    counts = jnp.zeros((E,), jnp.int32).at[slot_e].add(1)
    starts = jnp.cumsum(counts) - counts
    pos = ranks - starts[slot_e]  # position within expert group

    cap = _capacity(N, cfg)
    tok_idx = jnp.repeat(jnp.arange(N, dtype=jnp.int32), K)
    in_range = pos < cap
    # scatter tokens into (E, cap, D); over-capacity slots dropped via mode
    buf = jnp.zeros((E, cap, D), x.dtype)
    safe_pos = jnp.where(in_range, pos, cap)  # OOB → dropped by mode="drop"
    buf = buf.at[slot_e, safe_pos].add(xf[tok_idx], mode="drop")
    buf = _ep_constraint(buf, cfg)

    # ---- batched expert FFN (EP over 'experts' or TP over 'expert_ff') ----
    act = jax.nn.silu if cfg.mlp in ("swiglu",) else jax.nn.gelu
    g = act(jnp.einsum("ecd,edf->ecf", buf, p["w_gate"]))
    u = jnp.einsum("ecd,edf->ecf", buf, p["w_up"])
    out_buf = jnp.einsum("ecf,efd->ecd", g * u, p["w_down"])
    out_buf = _ep_constraint(out_buf, cfg)

    # ---- gather back + weighted combine ----
    gathered = out_buf.at[slot_e, safe_pos].get(mode="fill", fill_value=0)
    gathered = gathered.reshape(N, K, D)
    routed = jnp.einsum("nkd,nk->nd", gathered, top_w.astype(x.dtype))

    out = routed
    if cfg.n_shared > 0:
        out = out + layers.mlp_apply(cfg, p["shared"], xf[None])[0]

    # load-balance auxiliary loss (Switch-style): E * Σ_e f_e · P_e
    frac_tokens = counts.astype(jnp.float32) / (N * K)
    frac_probs = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(frac_tokens * frac_probs)
    return out.reshape(B, S, D), aux

"""Model assembly: decoder-only (dense/MoE/MLA), SSM, hybrid, enc-dec, VLM.

All stacks scan over layers (stacked parameters, small HLO) and expose a
uniform API used by train/serve/dry-run:

    lm = build_model(cfg)
    specs  = lm.param_specs()                    # ParamSpec pytree
    logits, aux = lm.forward(params, batch)      # teacher-forced
    loss   = lm.loss(params, batch)
    cache  = lm.cache_specs(batch_size, max_seq) # decode state
    logits, cache = lm.decode_step(params, cache, tokens)

Batches are dicts: {"tokens": (B, S+1) int32} plus modality extras
("frames" for whisper, "patches" for pixtral — precomputed stub embeddings).
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import moe as M
from repro.models import ssm as S
from repro.models.common import ModelConfig, ParamSpec

__all__ = ["build_model", "LanguageModel"]


def _xent(logits, labels, vocab_size):
    """Mean cross entropy in f32; labels < 0 are masked."""
    lf = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(lf, axis=-1)
    ll = jnp.take_along_axis(lf, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
    mask = labels >= 0
    nll = (logz - ll) * mask
    return jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1)


class LanguageModel:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # ---------------------------------------------------------------- specs
    def _decoder_layer_specs(self) -> dict:
        cfg = self.cfg
        out = {"norm1": L.norm_specs(cfg), "norm2": L.norm_specs(cfg)}
        if cfg.ssm == "mamba1":
            return {"norm1": L.norm_specs(cfg), "mixer": S.mamba1_specs(cfg)}
        if cfg.ssm == "mamba2":
            return {"norm1": L.norm_specs(cfg), "mixer": S.mamba2_specs(cfg)}
        out["attn"] = L.mla_specs(cfg) if cfg.mla else L.attention_specs(cfg)
        out["mlp"] = M.moe_specs(cfg) if cfg.moe else L.mlp_specs(cfg)
        return out

    def _dense_layer_specs(self, d_ff: int) -> dict:
        cfg = self.cfg
        return {
            "norm1": L.norm_specs(cfg),
            "norm2": L.norm_specs(cfg),
            "attn": L.mla_specs(cfg) if cfg.mla else L.attention_specs(cfg),
            "mlp": L.mlp_specs(cfg, d_ff=d_ff),
        }

    def param_specs(self) -> dict:
        cfg = self.cfg
        out: dict[str, Any] = {"embed": L.embed_specs(cfg)}
        n_scanned = cfg.n_layers - cfg.first_dense_layers
        layer = self._decoder_layer_specs()
        out["layers"] = jax.tree.map(
            lambda s: ParamSpec((n_scanned,) + s.shape, ("layers",) + s.axes,
                                init=s.init, scale=s.scale),
            layer,
            is_leaf=lambda x: isinstance(x, ParamSpec),
        )
        if cfg.first_dense_layers:
            out["pre_layers"] = [
                self._dense_layer_specs(cfg.d_ff)
                for _ in range(cfg.first_dense_layers)
            ]
        if cfg.hybrid_period:
            out["shared_block"] = {
                "norm1": L.norm_specs(cfg),
                "norm2": L.norm_specs(cfg),
                "attn": L.attention_specs(cfg),
                "mlp": L.mlp_specs(cfg),
            }
        if cfg.family == "encdec":
            enc_layer = {
                "norm1": L.norm_specs(cfg),
                "norm2": L.norm_specs(cfg),
                "attn": L.attention_specs(cfg),
                "mlp": L.mlp_specs(cfg),
            }
            out["enc_layers"] = jax.tree.map(
                lambda s: ParamSpec((cfg.encoder_layers,) + s.shape,
                                    ("layers",) + s.axes, init=s.init, scale=s.scale),
                enc_layer,
                is_leaf=lambda x: isinstance(x, ParamSpec),
            )
            out["enc_norm"] = L.norm_specs(cfg)
            # decoder layers get a cross-attention block
            cross = {
                "norm3": L.norm_specs(cfg),
                "xattn": L.attention_specs(cfg),
            }
            out["cross"] = jax.tree.map(
                lambda s: ParamSpec((cfg.n_layers,) + s.shape,
                                    ("layers",) + s.axes, init=s.init, scale=s.scale),
                cross,
                is_leaf=lambda x: isinstance(x, ParamSpec),
            )
            # learned decoder positions; sized for the largest decode shape
            # (32k) — whisper's real 448 ceiling is noted in DESIGN.md
            out["dec_pos"] = ParamSpec((32768, cfg.d_model), ("seq", "embed"), scale=0.02)
        out["final_norm"] = L.norm_specs(cfg)
        return out

    # -------------------------------------------------------------- forward
    def _mixer(self, p, h, positions, cache=None, decode=False):
        cfg = self.cfg
        if cfg.ssm == "mamba1":
            if decode:
                return S.mamba1_decode(cfg, p, h, cache)
            return S.mamba1_apply(cfg, p, h), None
        if cfg.ssm == "mamba2":
            if decode:
                return S.mamba2_decode(cfg, p, h, cache)
            return S.mamba2_apply(cfg, p, h), None
        if cfg.mla:
            return L.mla_apply(cfg, p, h, positions, kv_cache=cache)
        return L.attention_apply(cfg, p, h, positions, kv_cache=cache)

    def _layer(self, p, h, positions, aux, cache=None, decode=False):
        cfg = self.cfg
        y, new_cache = self._mixer(
            p["mixer"] if "mixer" in p else p["attn"],
            L.norm_apply(cfg, p["norm1"], h),
            positions,
            cache=cache,
            decode=decode,
        )
        h = h + y
        if "mlp" in p:
            hn = L.norm_apply(cfg, p["norm2"], h)
            if "router" in p["mlp"]:  # MoE layer (pre_layers stay dense)
                y, a = M.moe_apply(cfg, p["mlp"], hn)
                aux = aux + a
            else:
                y = L.mlp_apply(cfg, p["mlp"], hn)
            h = h + y
        return h, aux, new_cache

    def _shared_block(self, p, h, positions):
        cfg = self.cfg
        y, _ = L.attention_apply(cfg, p["attn"], L.norm_apply(cfg, p["norm1"], h), positions)
        h = h + y
        h = h + L.mlp_apply(cfg, p["mlp"], L.norm_apply(cfg, p["norm2"], h))
        return h

    def _hybrid_groups(self):
        """(n_groups, remainder) for the zamba-style shared-block schedule."""
        cfg = self.cfg
        n = cfg.n_layers - cfg.first_dense_layers
        g = n // cfg.hybrid_period
        return g, n - g * cfg.hybrid_period

    def _decoder_stack(self, params, h, positions, remat_policy=None):
        cfg = self.cfg
        aux0 = jnp.zeros((), jnp.float32)
        for lp in params.get("pre_layers", []):
            h, aux0, _ = self._layer(lp, h, positions, aux0)

        def body(carry, lp):
            h, aux = carry
            h, aux, _ = self._layer(lp, h, positions, aux)
            return (h, aux), None

        fn = jax.checkpoint(body, policy=remat_policy) if remat_policy else body
        carry = (h, aux0)
        if cfg.hybrid_period:
            # zamba2: the SAME shared-weight attention block runs after every
            # `period` mamba layers (per-invocation state differs, weights
            # are shared — the Zamba parameter-reuse trick).
            period = cfg.hybrid_period
            n_groups, rem = self._hybrid_groups()
            for g in range(n_groups):
                sl = jax.tree.map(
                    lambda a: a[g * period : (g + 1) * period], params["layers"]
                )
                carry, _ = jax.lax.scan(fn, carry, sl)
                h, aux = carry
                h = self._shared_block(params["shared_block"], h, positions)
                carry = (h, aux)
            if rem:
                sl = jax.tree.map(lambda a: a[n_groups * period :], params["layers"])
                carry, _ = jax.lax.scan(fn, carry, sl)
        else:
            carry, _ = jax.lax.scan(fn, carry, params["layers"])
        return carry

    def _encode(self, params, frames):
        cfg = self.cfg
        B, T, D = frames.shape
        pos = jnp.arange(T)[None, :]
        half = D // 2
        freqs = 10000.0 ** (-jnp.arange(half, dtype=jnp.float32) / half)
        ang = pos[..., None] * freqs
        pe = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(frames.dtype)
        h = frames + pe

        def body(h, lp):
            y, _ = L.attention_apply(
                cfg, lp["attn"], L.norm_apply(cfg, lp["norm1"], h),
                pos, causal=False, use_rope=False,
            )
            h = h + y
            h = h + L.mlp_apply(cfg, lp["mlp"], L.norm_apply(cfg, lp["norm2"], h))
            return h, None

        h, _ = jax.lax.scan(body, h, params["enc_layers"])
        return L.norm_apply(cfg, params["enc_norm"], h)

    def forward(self, params, batch, remat_policy=None):
        cfg = self.cfg
        tokens = batch["tokens"]
        inputs, labels = tokens[:, :-1], tokens[:, 1:]
        B, Si = inputs.shape
        h = L.embed_apply(cfg, params["embed"], inputs)

        if cfg.family == "encdec":
            enc = self._encode(params, batch["frames"])
            h = h + params["dec_pos"][None, :Si, :].astype(h.dtype)
            pos = jnp.broadcast_to(jnp.arange(Si)[None], (B, Si))

            def body(carry, xs):
                hh, aux = carry
                lp, cp = xs
                hh, aux, _ = self._layer(lp, hh, pos, aux)
                y, _ = L.attention_apply(
                    cfg, cp["xattn"], L.norm_apply(cfg, cp["norm3"], hh),
                    pos, xkv=enc, causal=False, use_rope=False,
                )
                hh = hh + y
                return (hh, aux), None

            fn = jax.checkpoint(body, policy=remat_policy) if remat_policy else body
            (h, aux), _ = jax.lax.scan(
                fn, (h, jnp.zeros((), jnp.float32)), (params["layers"], params["cross"])
            )
        else:
            if cfg.family == "vlm":
                patches = batch["patches"].astype(h.dtype)
                h = jnp.concatenate([patches, h], axis=1)
            Sh = h.shape[1]
            pos = jnp.broadcast_to(jnp.arange(Sh)[None], (B, Sh))
            h, aux = self._decoder_stack(params, h, pos, remat_policy)
            if cfg.family == "vlm":
                h = h[:, -Si:, :]

        h = L.norm_apply(cfg, params["final_norm"], h)
        logits = L.unembed_apply(cfg, params["embed"], h)
        return logits, {"aux_loss": aux if cfg.moe else jnp.zeros((), jnp.float32),
                        "labels": labels}

    def loss(self, params, batch, remat_policy=None):
        logits, extra = self.forward(params, batch, remat_policy)
        ce = _xent(logits, extra["labels"], self.cfg.vocab_size)
        return ce + 0.01 * extra["aux_loss"]

    # --------------------------------------------------------------- decode
    def cache_specs(self, batch: int, max_seq: int) -> dict:
        cfg = self.cfg
        if cfg.ssm == "mamba1":
            per_layer = S.mamba1_cache_specs(cfg, batch)
        elif cfg.ssm == "mamba2":
            per_layer = S.mamba2_cache_specs(cfg, batch)
        elif cfg.mla:
            per_layer = L.mla_cache_specs(cfg, batch, max_seq)
        else:
            per_layer = L.attention_cache_specs(cfg, batch, max_seq)
        n_scanned = cfg.n_layers - cfg.first_dense_layers
        out = {
            "layers": jax.tree.map(
                lambda s: ParamSpec((n_scanned,) + s.shape, ("layers",) + s.axes,
                                    init="zeros"),
                per_layer,
                is_leaf=lambda x: isinstance(x, ParamSpec),
            ),
            "pos": ParamSpec((), (), init="zeros"),
        }
        if cfg.first_dense_layers:
            pre = (L.mla_cache_specs(cfg, batch, max_seq) if cfg.mla
                   else L.attention_cache_specs(cfg, batch, max_seq))
            out["pre_layers"] = [pre for _ in range(cfg.first_dense_layers)]
        if cfg.hybrid_period:
            n_groups, _ = self._hybrid_groups()
            shared = L.attention_cache_specs(cfg, batch, max_seq)
            out["shared"] = jax.tree.map(
                lambda s: ParamSpec((n_groups,) + s.shape, ("layers",) + s.axes,
                                    init="zeros"),
                shared,
                is_leaf=lambda x: isinstance(x, ParamSpec),
            )
        if cfg.family == "encdec":
            out["enc_out"] = ParamSpec((batch, cfg.encoder_seq, cfg.d_model),
                                       ("batch", "seq", "embed"), init="zeros")
        return out

    def decode_step(self, params, cache, tokens):
        """One decode step. tokens: (B, 1) int32. Returns (logits, cache)."""
        cfg = self.cfg
        B = tokens.shape[0]
        pos_scalar = cache["pos"].astype(jnp.int32)
        positions = pos_scalar[None, None] + jnp.zeros((B, 1), jnp.int32)
        h = L.embed_apply(cfg, params["embed"], tokens)
        new_cache = dict(cache)

        if cfg.family == "encdec":
            h = h + jax.lax.dynamic_slice_in_dim(
                params["dec_pos"], pos_scalar, 1, axis=0
            )[None].astype(h.dtype)

        if "pre_layers" in params:
            new_pre = []
            for lp, lc in zip(params["pre_layers"], cache["pre_layers"]):
                c = dict(lc, pos=pos_scalar)
                h, _, c2 = self._layer(lp, h, positions, jnp.zeros(()), cache=c, decode=True)
                c2.pop("pos", None)
                new_pre.append(c2)
            new_cache["pre_layers"] = new_pre

        enc = cache.get("enc_out")

        def body(carry, xs):
            h = carry
            if cfg.family == "encdec":
                lp, cp, lc = xs
            else:
                (lp, lc), cp = xs, None
            if cfg.ssm is None:
                lc = dict(lc, pos=pos_scalar)
            h, _, c2 = self._layer(lp, h, positions, jnp.zeros(()), cache=lc, decode=True)
            if cfg.ssm is None:
                c2.pop("pos", None)
            if cfg.family == "encdec":
                y, _ = L.attention_apply(
                    cfg, cp["xattn"], L.norm_apply(cfg, cp["norm3"], h),
                    positions, xkv=enc, causal=False, use_rope=False,
                )
                h = h + y
            return h, c2

        if cfg.hybrid_period:
            # zamba2: the shared block fires after every `period` layers with
            # its OWN per-invocation KV cache (weights shared, state not).
            period = cfg.hybrid_period
            n_groups, rem = self._hybrid_groups()
            cache_slices, shared_slices = [], []
            for g in range(n_groups):
                sl_p = jax.tree.map(
                    lambda a: a[g * period : (g + 1) * period], params["layers"]
                )
                sl_c = jax.tree.map(
                    lambda a: a[g * period : (g + 1) * period], cache["layers"]
                )
                h, c2 = jax.lax.scan(body, h, (sl_p, sl_c))
                cache_slices.append(c2)
                sb = params["shared_block"]
                sc_in = jax.tree.map(lambda a: a[g], cache["shared"])
                y, sc = L.attention_apply(
                    cfg, sb["attn"], L.norm_apply(cfg, sb["norm1"], h),
                    positions, kv_cache=dict(sc_in, pos=pos_scalar),
                )
                h = h + y
                h = h + L.mlp_apply(cfg, sb["mlp"], L.norm_apply(cfg, sb["norm2"], h))
                sc.pop("pos", None)
                shared_slices.append(sc)
            if rem:
                sl_p = jax.tree.map(lambda a: a[n_groups * period :], params["layers"])
                sl_c = jax.tree.map(lambda a: a[n_groups * period :], cache["layers"])
                h, c2 = jax.lax.scan(body, h, (sl_p, sl_c))
                cache_slices.append(c2)
            new_cache["layers"] = jax.tree.map(
                lambda *xs: jnp.concatenate(xs, axis=0), *cache_slices
            )
            new_cache["shared"] = jax.tree.map(
                lambda *xs: jnp.stack(xs, axis=0), *shared_slices
            )
        else:
            if cfg.family == "encdec":
                xs = (params["layers"], params["cross"], cache["layers"])
            else:
                xs = (params["layers"], cache["layers"])
            h, lcache_new = jax.lax.scan(body, h, xs)
            new_cache["layers"] = lcache_new

        h = L.norm_apply(cfg, params["final_norm"], h)
        logits = L.unembed_apply(cfg, params["embed"], h)
        new_cache["pos"] = (pos_scalar + 1).astype(cache["pos"].dtype)
        return logits, new_cache


def build_model(cfg: ModelConfig) -> LanguageModel:
    return LanguageModel(cfg)

"""Core transformer layers: norms, RoPE, GQA/MQA attention, MLA attention,
gated MLPs. Functional style — params are subtrees built by ``*_specs`` and
applied by ``*_apply``. Everything is einsum-based (MXU-friendly) and written
to lower cleanly under pjit with the logical sharding rules in common.py.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig, ParamSpec

# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rmsnorm_specs(d: int) -> dict:
    return {"scale": ParamSpec((d,), ("embed",), init="ones")}


def rmsnorm_apply(p, x, eps: float = 1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


def layernorm_np_apply(x, eps: float = 1e-5):
    """Non-parametric LayerNorm (OLMo)."""
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + eps)).astype(x.dtype)


def norm_specs(cfg: ModelConfig) -> dict:
    return rmsnorm_specs(cfg.d_model) if cfg.norm == "rmsnorm" else {}


def norm_apply(cfg: ModelConfig, p, x):
    if cfg.norm == "rmsnorm":
        return rmsnorm_apply(p, x)
    return layernorm_np_apply(x)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_apply(x, positions, theta: float):
    """x: (..., S, H, hd) with positions (..., S)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# GQA / MQA attention
# ---------------------------------------------------------------------------


def attention_specs(cfg: ModelConfig) -> dict:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    return {
        "wq": ParamSpec((d, h, hd), ("embed", "heads", "head_dim")),
        "wk": ParamSpec((d, kv, hd), ("embed", "kv_heads", "head_dim")),
        "wv": ParamSpec((d, kv, hd), ("embed", "kv_heads", "head_dim")),
        "wo": ParamSpec((h, hd, d), ("heads", "head_dim", "embed")),
    }


# Query-chunk size for long sequences: bounds the live score tensor to
# (B, H, Q_CHUNK, Sk) instead of (B, H, Sq, Sk) — 32k×32k scores would blow
# the 16 GB HBM budget, 1k×32k fits easily. Flash-style streaming over KV is
# not needed because Sk·Q_CHUNK blocks already fit; chunking only the query
# side keeps a single softmax per row (numerically identical to the dense
# computation, important for tests).
Q_CHUNK = 1024


def _sdpa_block(q, k, v, causal, q_offset, scale):
    """One dense block. q: (B,Q,KV,G,hd), k/v: (B,Sk,KV,hd). f32 math."""
    scores = jnp.einsum("bqkgh,bskh->bkgqs", q, k) * scale
    if causal:
        Sk, Q = k.shape[1], q.shape[1]
        mask = jnp.arange(Sk)[None, :] <= (jnp.arange(Q)[:, None] + q_offset)
        scores = jnp.where(mask[None, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bkgqs,bskh->bqkgh", probs, v)


def _sdpa(q, k, v, dtype, *, causal: bool, q_offset=0):
    """Grouped-query attention with lazy masks and query chunking.

    q: (B,Sq,H,hd), k/v: (B,Sk,KV,hd). The causal mask is
    ``j <= i + q_offset`` (q_offset = cache position at decode), computed
    per block — never materialized at (Sq, Sk).
    """
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    vd = v.shape[-1]  # may differ from hd (MLA: q/k have nope+rope, v has dv)
    scale = 1.0 / math.sqrt(hd)
    qf = q.reshape(B, Sq, KV, G, hd).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    if Sq <= Q_CHUNK or Sq % Q_CHUNK != 0:
        out = _sdpa_block(qf, kf, vf, causal, q_offset, scale)
        return out.reshape(B, Sq, H, vd).astype(dtype)

    n_blocks = Sq // Q_CHUNK

    def body(_, blk):
        qb, off = blk
        return None, _sdpa_block(qb, kf, vf, causal, off, scale)

    qb = jnp.moveaxis(
        qf.reshape(B, n_blocks, Q_CHUNK, KV, G, hd), 1, 0
    )
    offs = q_offset + jnp.arange(n_blocks) * Q_CHUNK
    _, outs = jax.lax.scan(body, None, (qb, offs))
    out = jnp.moveaxis(outs, 0, 1)
    return out.reshape(B, Sq, H, vd).astype(dtype)


def attention_apply(
    cfg: ModelConfig,
    p,
    x,
    positions,
    *,
    causal: bool = True,
    kv_cache=None,  # dict(k=(B,Smax,KV,hd), v=..., pos=scalar) for decode
    xkv=None,  # cross-attention inputs (whisper decoder)
    use_rope: bool = True,
):
    B, S, D = x.shape
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    src = xkv if xkv is not None else x
    k = jnp.einsum("bsd,dhk->bshk", src, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", src, p["wv"])
    if use_rope:
        q = rope_apply(q, positions, cfg.rope_theta)
        if xkv is None:
            k = rope_apply(k, positions, cfg.rope_theta)
    if cfg.kv_repeat > 1:
        # §Perf H1: replicate KV heads up to the TP width so the decode cache
        # shards over 'kv_heads' instead of being replicated per model rank
        k = jnp.repeat(k, cfg.kv_repeat, axis=2)
        v = jnp.repeat(v, cfg.kv_repeat, axis=2)

    if kv_cache is not None:
        pos = kv_cache["pos"]
        ck = jax.lax.dynamic_update_slice(kv_cache["k"], k, (0, pos, 0, 0))
        cv = jax.lax.dynamic_update_slice(kv_cache["v"], v, (0, pos, 0, 0))
        out = _sdpa(q, ck, cv, x.dtype, causal=True, q_offset=pos)
        new_cache = {"k": ck, "v": cv, "pos": pos + S}
    else:
        out = _sdpa(q, k, v, x.dtype, causal=causal, q_offset=0)
        new_cache = None
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return y, new_cache


def attention_cache_specs(cfg: ModelConfig, batch: int, max_seq: int) -> dict:
    kv, hd = cfg.n_kv_heads * cfg.kv_repeat, cfg.hd
    return {
        "k": ParamSpec((batch, max_seq, kv, hd), ("batch", "kv_seq", "kv_heads", "head_dim"), init="zeros"),
        "v": ParamSpec((batch, max_seq, kv, hd), ("batch", "kv_seq", "kv_heads", "head_dim"), init="zeros"),
    }


# ---------------------------------------------------------------------------
# MLA attention (DeepSeek-V2): low-rank compressed KV + decoupled RoPE key
# ---------------------------------------------------------------------------


def mla_specs(cfg: ModelConfig) -> dict:
    d, h = cfg.d_model, cfg.n_heads
    r, dr, dn, dv = cfg.kv_lora_rank, cfg.qk_rope_dim, cfg.qk_nope_dim, cfg.v_head_dim
    return {
        "wq": ParamSpec((d, h, dn + dr), ("embed", "heads", "head_dim")),
        "w_dkv": ParamSpec((d, r + dr), ("embed", "lora")),
        "w_uk": ParamSpec((r, h, dn), ("lora", "heads", "head_dim")),
        "w_uv": ParamSpec((r, h, dv), ("lora", "heads", "head_dim")),
        "wo": ParamSpec((h, dv, d), ("heads", "head_dim", "embed")),
        "kv_norm": ParamSpec((r,), ("lora",), init="ones"),
    }


def mla_apply(cfg: ModelConfig, p, x, positions, *, kv_cache=None):
    """kv_cache for decode: dict(ckv=(B,Smax,r), krope=(B,Smax,dr), pos)."""
    B, S, D = x.shape
    h = cfg.n_heads
    r, dr, dn = cfg.kv_lora_rank, cfg.qk_rope_dim, cfg.qk_nope_dim
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = rope_apply(q_rope, positions, cfg.rope_theta)

    dkv = jnp.einsum("bsd,dr->bsr", x, p["w_dkv"])
    ckv, k_rope = dkv[..., :r], dkv[..., r:]
    ckv = rmsnorm_apply({"scale": p["kv_norm"]}, ckv)
    k_rope = rope_apply(k_rope[:, :, None, :], positions, cfg.rope_theta)[:, :, 0]

    if kv_cache is not None:
        pos = kv_cache["pos"]
        ckv = jax.lax.dynamic_update_slice(kv_cache["ckv"], ckv, (0, pos, 0))
        k_rope = jax.lax.dynamic_update_slice(
            kv_cache["krope"], k_rope, (0, pos, 0)
        )
        new_cache = {"ckv": ckv, "krope": k_rope, "pos": pos + S}
        q_offset = pos
    else:
        new_cache = None
        q_offset = 0

    # up-project compressed cache to per-head K (nope ‖ shared-rope) and V,
    # then reuse the chunked GQA kernel (KV == H, G == 1). The absorbed-matmul
    # decode variant (attend in compressed space) is a recorded perf follow-up.
    k_nope = jnp.einsum("bsr,rhk->bshk", ckv, p["w_uk"])
    k_cat = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], k_nope.shape[:3] + (dr,))],
        axis=-1,
    )
    vv = jnp.einsum("bsr,rhk->bshk", ckv, p["w_uv"])
    q_cat = jnp.concatenate([q_nope, q_rope], axis=-1)
    # _sdpa scales by 1/sqrt(last_dim) == 1/sqrt(dn+dr) — the MLA scale.
    out = _sdpa(q_cat, k_cat, vv, x.dtype, causal=True, q_offset=q_offset)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return y, new_cache


def mla_cache_specs(cfg: ModelConfig, batch: int, max_seq: int) -> dict:
    return {
        "ckv": ParamSpec((batch, max_seq, cfg.kv_lora_rank), ("batch", "kv_seq", "lora"), init="zeros"),
        "krope": ParamSpec((batch, max_seq, cfg.qk_rope_dim), ("batch", "kv_seq", None), init="zeros"),
    }


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def mlp_specs(cfg: ModelConfig, d_ff: int | None = None) -> dict:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    if cfg.mlp in ("swiglu", "geglu"):
        return {
            "w_gate": ParamSpec((d, f), ("embed", "ff")),
            "w_up": ParamSpec((d, f), ("embed", "ff")),
            "w_down": ParamSpec((f, d), ("ff", "embed")),
        }
    return {
        "w_up": ParamSpec((d, f), ("embed", "ff")),
        "w_down": ParamSpec((f, d), ("ff", "embed")),
    }


def mlp_apply(cfg: ModelConfig, p, x):
    if cfg.mlp in ("swiglu", "geglu"):
        act = jax.nn.silu if cfg.mlp == "swiglu" else jax.nn.gelu
        g = act(jnp.einsum("bsd,df->bsf", x, p["w_gate"]))
        u = jnp.einsum("bsd,df->bsf", x, p["w_up"])
        return jnp.einsum("bsf,fd->bsd", g * u, p["w_down"])
    h = jax.nn.gelu(jnp.einsum("bsd,df->bsf", x, p["w_up"]))
    return jnp.einsum("bsf,fd->bsd", h, p["w_down"])


# ---------------------------------------------------------------------------
# Embedding / unembedding (padded vocab with masked logits)
# ---------------------------------------------------------------------------


def embed_specs(cfg: ModelConfig) -> dict:
    out = {"tok": ParamSpec((cfg.padded_vocab, cfg.d_model), ("vocab", "embed"), scale=1.0)}
    if not cfg.tie_embeddings:
        out["head"] = ParamSpec((cfg.d_model, cfg.padded_vocab), ("embed", "vocab"))
    return out


def embed_apply(cfg: ModelConfig, p, tokens):
    return jnp.take(p["tok"], tokens, axis=0)


def unembed_apply(cfg: ModelConfig, p, x):
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x, p["tok"])
    else:
        logits = jnp.einsum("bsd,dv->bsv", x, p["head"])
    # mask padded vocab entries
    if cfg.padded_vocab > cfg.vocab_size:
        neg = jnp.full((cfg.padded_vocab - cfg.vocab_size,), -1e30, logits.dtype)
        bias = jnp.concatenate([jnp.zeros((cfg.vocab_size,), logits.dtype), neg])
        logits = logits + bias
    return logits

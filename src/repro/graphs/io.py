"""Edge-list IO for real graphs (SNAP / SuiteSparse format)."""

from __future__ import annotations

import numpy as np

__all__ = ["load_edgelist", "save_edgelist"]


def load_edgelist(path: str) -> np.ndarray:
    """Load an undirected edge list (whitespace separated, # comments) into a
    dense boolean adjacency matrix with compacted node ids."""
    src, dst = [], []
    with open(path) as fh:
        for line in fh:
            line = line.strip()
            if not line or line.startswith(("#", "%")):
                continue
            parts = line.split()
            src.append(int(parts[0]))
            dst.append(int(parts[1]))
    ids = sorted(set(src) | set(dst))
    remap = {v: t for t, v in enumerate(ids)}
    n = len(ids)
    adj = np.zeros((n, n), dtype=bool)
    for a, b in zip(src, dst):
        if a == b:
            continue
        adj[remap[a], remap[b]] = True
        adj[remap[b], remap[a]] = True
    return adj


def save_edgelist(adj: np.ndarray, path: str) -> None:
    iu = np.triu_indices(adj.shape[0], 1)
    with open(path, "w") as fh:
        fh.write("# undirected edge list\n")
        for a, b in zip(*iu):
            if adj[a, b]:
                fh.write(f"{a} {b}\n")

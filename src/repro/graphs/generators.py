"""Graph sources for correlation-clustering instances.

The paper's experiments use five real graphs (SuiteSparse `power`, SNAP
ca-GrQc/HepTh/HepPh/AstroPh). Offline we substitute generators with matching
statistics families: small-world (power is a Watts–Strogatz-like grid) and
scale-free collaboration-style graphs; plus planted-partition graphs so
rounding quality can be validated against ground truth.
"""

from __future__ import annotations

import networkx as nx
import numpy as np

__all__ = [
    "small_world",
    "collaboration_like",
    "planted_partition",
    "graph_batch",
    "largest_component_adjacency",
]


def largest_component_adjacency(g: nx.Graph) -> np.ndarray:
    """Adjacency (bool) of the largest connected component (paper §IV.B)."""
    nodes = max(nx.connected_components(g), key=len)
    sub = g.subgraph(nodes)
    return nx.to_numpy_array(sub, dtype=np.float64) > 0


def small_world(n: int, k: int = 4, p: float = 0.1, seed: int = 0) -> np.ndarray:
    """Watts–Strogatz stand-in for the `power` grid graph."""
    g = nx.watts_strogatz_graph(n, k, p, seed=seed)
    return largest_component_adjacency(g)


def collaboration_like(n: int, m: int = 3, seed: int = 0) -> np.ndarray:
    """Barabási–Albert stand-in for the SNAP ca-* collaboration networks."""
    g = nx.barabasi_albert_graph(n, m, seed=seed)
    return largest_component_adjacency(g)


def graph_batch(
    ns, kind: str = "sbm", seed: int = 0
) -> list[np.ndarray]:
    """A stream of independent graphs (mixed sizes) for the batched solve
    service — one adjacency per requested size, seeds decorrelated."""
    out = []
    for g, n in enumerate(ns):
        s = seed + 1000 * g
        if kind == "ba":
            out.append(collaboration_like(n, seed=s))
        elif kind == "ws":
            out.append(small_world(n, seed=s))
        else:
            out.append(planted_partition(n, seed=s)[0])
    return out


def planted_partition(
    n: int, clusters: int = 3, p_in: float = 0.7, p_out: float = 0.05, seed: int = 0
) -> tuple[np.ndarray, np.ndarray]:
    """SBM with known ground-truth labels (for rounding-quality tests)."""
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, clusters, size=n)
    u = rng.uniform(size=(n, n))
    same = labels[:, None] == labels[None, :]
    adj = np.where(same, u < p_in, u < p_out)
    adj = np.triu(adj, 1)
    adj = adj | adj.T
    return adj, labels

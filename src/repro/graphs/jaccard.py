"""Correlation-clustering instance construction (paper §IV.B).

Follows Wang et al. [40] with the modification of [37]: given an unsigned
graph G, compute the Jaccard index J_ab between every pair of nodes, map it
through a non-linear function to a signed score, and offset by ±eps so every
pair has a nonzero weight and a sign. The output is a *dense* CC instance:

    dissim[a, b] = 1 if the pair is "negative" (should be cut) else 0
    weights[a, b] = |signed score|  (>0 everywhere)

which is exactly the (d, w) input of the metric-constrained LP (paper eq. 3).
"""

from __future__ import annotations

import numpy as np

__all__ = ["jaccard_index", "signed_instance"]


def jaccard_index(adj: np.ndarray) -> np.ndarray:
    """Dense pairwise Jaccard index of neighborhoods (including self-loops so
    adjacent nodes with no common neighbor still score > 0)."""
    a = adj.astype(np.float64)
    np.fill_diagonal(a, 1.0)  # closed neighborhoods
    inter = a @ a.T
    deg = a.sum(axis=1)
    union = deg[:, None] + deg[None, :] - inter
    with np.errstate(divide="ignore", invalid="ignore"):
        j = np.where(union > 0, inter / union, 0.0)
    np.fill_diagonal(j, 0.0)
    return j


def signed_instance(
    adj: np.ndarray, delta: float = 0.05, offset_eps: float = 0.01
) -> tuple[np.ndarray, np.ndarray]:
    """Wang et al. non-linear signing: s_ab = log((1+J-δ)/(1-J+δ)),
    then offset by ±offset_eps so all weights are nonzero.

    Returns (dissim, weights): dissim ∈ {0,1}, weights > 0, both (n, n) with
    meaningful strict upper triangle.
    """
    j = jaccard_index(adj)
    s = np.log((1.0 + j - delta) / (1.0 - j + delta))
    s = s + np.where(s >= 0, offset_eps, -offset_eps)
    n = adj.shape[0]
    iu = np.triu(np.ones((n, n), bool), 1)
    dissim = np.where(iu & (s < 0), 1.0, 0.0)
    weights = np.where(iu, np.abs(s), 1.0)
    weights = np.maximum(weights, 1e-6)
    return dissim, weights

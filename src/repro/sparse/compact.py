"""Round-boundary slab compaction for the active-set subsystem
(DESIGN.md §13).

Masking a forgotten cell makes it free in *math* but not in *time*: the
fused pass still sweeps the full (D, T, Cl) slab and spends a lane step on
every masked cell. Compaction repacks only the ACTIVE cells of a bucket
into a smaller slab so the per-pass wall clock actually decays with the
active fraction — the Project-and-Forget payoff.

Why repacking is exact (the same argument as the original lane fold):

  * Removing a masked step is a structural no-op — the sweep restores the
    ``x_ik`` carry at masked steps (``ref.fused_diag_sweep``) and act-masks
    every X delta at scatter time, so deleting the step changes nothing.
  * Any two sets on one diagonal share at most one index (the paper's
    conflict-freedom theorem), so re-pairing the surviving sets into new
    folded lanes keeps every gather/scatter disjoint across lanes — the
    fold assignment is arbitrary, only the *within-set* j order matters,
    and that order is preserved verbatim.
  * Dead diagonals drop from the scan; live diagonals keep their relative
    (schedule) order.

The one structural difference from the full layout: a compacted set's
middle indices are an arbitrary subset of ``i+1 .. k-1``, so the relation
``J = i + 1 + t`` no longer holds — the compact ``J`` table is an explicit
gathered index list, which the fused pass supports natively (``stage["J"]``
is already a per-step operand). ``T'``/``Cl'`` round up to ``pad_to`` so
recompaction produces a small ladder of distinct slab shapes (bounded
recompiles of the shape-keyed jitted runner).

``CompactPlan`` records the cell map full-slab ↔ compact-slab per bucket,
used to move dual slabs and active masks across a recompaction and to
expand compact duals back to the full layout (``duals_to_dense``, oracle
pinning).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import schedule as sched

__all__ = ["BucketPlan", "CompactPlan", "build_compact_slabs"]


def _round_up(v: int, m: int) -> int:
    return max(m, ((v + m - 1) // m) * m)


@dataclasses.dataclass(frozen=True)
class BucketPlan:
    """Cell map of one bucket across a compaction.

    ``src`` indexes the FULL slab (layout coordinates d/t/c), ``dst`` the
    compact slab; entry m of each is the same constraint triplet. The 3
    dual values of a cell ride along axis 1 of either slab.
    """

    src: tuple[np.ndarray, np.ndarray, np.ndarray]
    dst: tuple[np.ndarray, np.ndarray, np.ndarray]
    full_shape: tuple[int, ...]  # (D, 3, T, Cl)
    comp_shape: tuple[int, ...]  # (D', 3, T', Cl')

    @property
    def num_active(self) -> int:
        return int(self.src[0].shape[0])

    def compact_duals(self, y_full: np.ndarray) -> np.ndarray:
        sd, st, sc = self.src
        dd, dt, dc = self.dst
        out = np.zeros(self.comp_shape, y_full.dtype)
        out[dd, :, dt, dc] = y_full[sd, :, st, sc]
        return out

    def expand_duals(self, y_comp: np.ndarray) -> np.ndarray:
        sd, st, sc = self.src
        dd, dt, dc = self.dst
        out = np.zeros(self.full_shape, y_comp.dtype)
        out[sd, :, st, sc] = y_comp[dd, :, dt, dc]
        return out

    def compact_mask(self, m_full: np.ndarray) -> np.ndarray:
        sd, st, sc = self.src
        dd, dt, dc = self.dst
        out = np.zeros(
            (self.comp_shape[0],) + self.comp_shape[2:], bool
        )
        out[dd, dt, dc] = m_full[sd, st, sc]
        return out

    def expand_mask(self, m_comp: np.ndarray) -> np.ndarray:
        sd, st, sc = self.src
        dd, dt, dc = self.dst
        out = np.zeros(
            (self.full_shape[0],) + self.full_shape[2:], bool
        )
        out[sd, st, sc] = m_comp[dd, dt, dc]
        return out


@dataclasses.dataclass(frozen=True)
class CompactPlan:
    buckets: tuple[BucketPlan, ...]

    @property
    def num_active(self) -> int:
        return sum(b.num_active for b in self.buckets)


def _compact_bucket(bl: sched.BucketLayout, active: np.ndarray,
                    pad_to: int):
    """Repack one bucket's active cells. ``active`` is (D, T, Cl) bool in
    full layout coordinates. Returns (lane tables dict, J table, plan
    pieces) in numpy; empty buckets collapse to a zero-diagonal slab."""
    i1, k1 = bl.i[0], bl.k[0]
    i2, k2 = bl.i2[0], bl.k2[0]
    s1, s2 = bl.sizes[0], bl.sizes2[0]
    D, Cl = i1.shape
    J, _, _, act_full, _ = sched.folded_geometry_np(
        i1, k1, s1, i2, k2, s2, bl.T
    )
    active = active & act_full  # never exceed the structural cells

    # --- collect surviving sets per diagonal: (i, k, js, src_t, src_c)
    diag_sets: list[tuple[int, list]] = []
    for d in range(D):
        sets = []
        for c in range(Cl):
            if i1[d, c] >= 0:
                ts = np.nonzero(active[d, : s1[d, c], c])[0]
                if ts.size:
                    sets.append(
                        (int(i1[d, c]), int(k1[d, c]), J[d, ts, c], ts, c)
                    )
            if i2[d, c] >= 0:
                lo = int(s1[d, c])
                ts = lo + np.nonzero(
                    active[d, lo: lo + int(s2[d, c]), c]
                )[0]
                if ts.size:
                    sets.append(
                        (int(i2[d, c]), int(k2[d, c]), J[d, ts, c], ts, c)
                    )
        if sets:
            # Fold: sort by count desc, pair f with S-1-f — near-uniform
            # lane heights, exactly the build_layout folding policy.
            sets.sort(key=lambda s: -s[2].size)
            diag_sets.append((d, sets))

    if not diag_sets:
        Dp, Tp, Clp = 0, pad_to, pad_to
        lanes = {
            name: np.full((Dp, Clp), -1, np.int32)
            for name in ("i", "k", "i2", "k2")
        }
        lanes["s"] = np.zeros((Dp, Clp), np.int32)
        lanes["s2"] = np.zeros((Dp, Clp), np.int32)
        return (
            lanes, np.zeros((Dp, Tp, Clp), np.int32),
            ([], []), (Dp, 3, Tp, Clp),
        )

    Dp = len(diag_sets)
    Tp = _round_up(
        max(
            max(
                sets[f][2].size
                + (sets[len(sets) - 1 - f][2].size
                   if len(sets) - 1 - f > f else 0)
                for f in range((len(sets) + 1) // 2)
            )
            for _, sets in diag_sets
        ),
        pad_to,
    )
    Clp = _round_up(
        max((len(sets) + 1) // 2 for _, sets in diag_sets), pad_to
    )
    lanes = {
        name: np.full((Dp, Clp), -1, np.int32)
        for name in ("i", "k", "i2", "k2")
    }
    lanes["s"] = np.zeros((Dp, Clp), np.int32)
    lanes["s2"] = np.zeros((Dp, Clp), np.int32)
    Jp = np.zeros((Dp, Tp, Clp), np.int32)
    src: list[tuple[int, np.ndarray, int]] = []
    dst: list[tuple[int, np.ndarray, int]] = []
    for dd, (d, sets) in enumerate(diag_sets):
        S = len(sets)
        for f in range((S + 1) // 2):
            ia, ka, js, ts, c = sets[f]
            na = js.size
            lanes["i"][dd, f], lanes["k"][dd, f] = ia, ka
            lanes["s"][dd, f] = na
            Jp[dd, :na, f] = js
            src.append((d, ts, c))
            dst.append((dd, np.arange(na), f))
            g = S - 1 - f
            if g > f:
                ib, kb, js2, ts2, c2 = sets[g]
                nb = js2.size
                lanes["i2"][dd, f], lanes["k2"][dd, f] = ib, kb
                lanes["s2"][dd, f] = nb
                Jp[dd, na: na + nb, f] = js2
                src.append((d, ts2, c2))
                dst.append((dd, na + np.arange(nb), f))
    return lanes, Jp, (src, dst), (Dp, 3, Tp, Clp)


def build_compact_slabs(
    layout: sched.ScheduleLayout,
    active_full: list[np.ndarray],
    w: np.ndarray,
    eps: float,
    dtype,
    pad_to: int = 8,
):
    """Compact slab staging for the given per-bucket active sets.

    Args:
      layout: the FULL schedule layout (procs=1).
      active_full: per bucket, (D, T, Cl) bool of cells to keep.
      w, eps: problem weight matrix / slack scale — regathered into the
        staged projection gains exactly as ``ParallelSolver._stage_buckets``
        (masked cells sanitized to gain 1, matching build_static_stage).
      dtype: compute dtype of the gain slabs.
      pad_to: round T'/Cl' up to this multiple (bounds distinct shapes).

    Returns ``(slabs, plan)``: per-bucket numpy staging dicts in the
    fused-pass operand contract (lane tables i/k/s/i2/k2/s2, geometry
    J/iN/kN, masks valid/seg, gains g_row/g_col/g_sel/dinv) and the
    ``CompactPlan`` mapping cells back to the full layout.
    """
    npdt = np.dtype(dtype)
    one = npdt.type(1.0)
    epsc = npdt.type(eps)
    n = layout.n
    w = np.asarray(w, npdt)

    def wgather(rows, cols, live):
        r = np.clip(rows, 0, n - 1)
        c = np.clip(cols, 0, n - 1)
        return np.where(live, w[r, c], one).astype(npdt)

    slabs, plans = [], []
    for bl, am in zip(layout.buckets, active_full):
        lanes, Jp, (src, dst), comp_shape = _compact_bucket(
            bl, np.asarray(am, bool), pad_to
        )
        Dp, _, Tp, Clp = comp_shape
        # Geometry from the compacted lane tables — identical semantics
        # to folded_geometry_np except J, which is the explicit gathered
        # middle-index list (the compacted set is a j-subset).
        Jg, iN, kN, act, seg = sched.folded_geometry_np(
            lanes["i"], lanes["k"], lanes["s"],
            lanes["i2"], lanes["k2"], lanes["s2"], Tp,
        )
        J = np.where(act, Jp, Jg).astype(np.int32)
        g_row = (one / wgather(iN, J, act)) / epsc
        g_col = (one / wgather(J, kN, act)) / epsc
        g_a = (one / wgather(lanes["i"], lanes["k"], lanes["i"] >= 0)) / epsc
        g_b = (one / wgather(lanes["i2"], lanes["k2"],
                             lanes["i2"] >= 0)) / epsc
        g_sel = np.where(seg, g_b[:, None, :], g_a[:, None, :]).astype(npdt)
        dinv = (one / (g_row + g_sel + g_col)).astype(npdt)
        slabs.append(dict(
            i=lanes["i"], k=lanes["k"], s=lanes["s"],
            i2=lanes["i2"], k2=lanes["k2"], s2=lanes["s2"],
            J=J, iN=iN, kN=kN, valid=act, seg=seg,
            g_row=g_row.astype(npdt), g_col=g_col.astype(npdt),
            g_sel=g_sel, dinv=dinv,
        ))

        def flat(parts):
            if not parts:
                z = np.zeros(0, np.int64)
                return z, z.copy(), z.copy()
            ds = np.concatenate(
                [np.full(ts.size, d, np.int64) for d, ts, _ in parts]
            )
            tv = np.concatenate([ts.astype(np.int64) for _, ts, _ in parts])
            cs = np.concatenate(
                [np.full(ts.size, c, np.int64) for _, ts, c in parts]
            )
            return ds, tv, cs
        plans.append(BucketPlan(
            src=flat(src), dst=flat(dst),
            full_shape=bl.slab_shape[1:], comp_shape=comp_shape,
        ))
    return slabs, CompactPlan(tuple(plans))

"""Project-and-Forget active-set solver (DESIGN.md §13).

``SparseSolver`` wraps the fused-pass solver in the outer loop of
*Project and Forget* (arXiv 2005.03853): project over the currently
active triangle constraints, **forget** constraints whose duals sit at
zero, and **revive** forgotten constraints the iterate has started to
violate. Dykstra's dual at a strictly satisfied constraint is exactly
0.0 (theta = max(slack, 0) · dinv), so with the default
``forget_tol = 0.0`` the forget step drops precisely the constraints the
current iterate renders inactive — and re-projecting a revived
constraint from y = 0 is bitwise the step the full solver would take, so
sparsification changes *which* constraints are visited, never the math
of a visit.

Mechanism (all on device, inside one jitted ``lax.while_loop``):

  * **Active masks** ride in the state pytree as per-bucket boolean
    slabs composed into the fused pass as the runtime ``act`` operand —
    the same mechanism that makes ghost cells structural fixed points
    (DESIGN.md §8), just dynamic. Mask flips are data, never a
    recompile. The one fused-pass caveat: masked dual *outputs* are
    don't-care (ref.py module comment), so the sparse pass re-zeroes
    masked dual cells — making a forgotten cell a true bitwise fixed
    point (x untouched by masked scatters, y pinned at 0.0).
  * **Forget step**, every ``forget_every`` passes: cells with
    ``max|y| <= forget_tol`` leave the active mask and their duals are
    zeroed.
  * **Revival probe**: the slab-native form of the 2-D violation
    kernel's reduction — the per-cell triangle slacks recomputed from
    the same row/column/carry gathers the sweep uses; any valid cell
    violated beyond ``revive_tol`` (default ``0.5 · tol``, so nothing a
    certificate would flag can stay forgotten) re-enters the active set
    with y = 0.
  * **Certificate soundness**: the stopping pair is the engine's global
    probe over ALL triangles (``_stopping_pair`` reads only X), so
    ``converged`` means the *full-constraint* certificate holds — the
    active set is an execution detail, never a weaker stopping test.

``compact_every`` additionally repacks the slabs at round boundaries
(``sparse/compact.py``) so pass wall-time follows the active fraction
down; each compaction re-probes the FULL geometry and re-admits any
violated forgotten cell before it is dropped from the slab, so no
constraint is ever starved of revival.

Solo-device mode only: batched/sharded sparsification is stubbed with
clear errors (see ``SparseSolver.batched`` / ``.sharded``).
"""

from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import schedule as sched
from repro.core.engine import stop_converged
from repro.core.parallel_dykstra import ParallelSolver, ParallelState
from repro.sparse.compact import build_compact_slabs

__all__ = ["SparseSolver", "SparseState"]

#: fused-pass operand keys forwarded from a staged slab dict (``act`` is
#: supplied at runtime from the state's active mask).
_STAGE_KEYS = ("i", "k", "s", "i2", "k2", "s2", "J", "iN", "kN", "seg",
               "g_row", "g_col", "g_sel", "dinv")


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class SparseState:
    """ParallelState plus the per-bucket active masks (runtime operands:
    they live in the state pytree, so flipping them is pure data flow)."""

    x: jax.Array
    f: jax.Array | None
    yd: list[jax.Array]  # per bucket (D, 3, T, C) — current slab shapes
    ypair: jax.Array | None
    ybox: jax.Array | None
    passes: jax.Array
    amask: list[jax.Array]  # per bucket (D, T, C) bool


class SparseSolver(ParallelSolver):
    """Active-set (Project-and-Forget) solver for one MetricQP.

    Args (beyond ParallelSolver's):
      forget_every: passes between forget/revive steps (the outer-loop
        round length; also the convergence-check cadence).
      forget_tol: drop a constraint when ``max|y| <= forget_tol``. The
        default 0.0 catches exactly Dykstra's inactive-constraint zeros.
      revive_tol: re-admit a forgotten constraint violated beyond this;
        None derives ``0.5 * tol`` per run_until call — strictly inside
        the certificate tolerance, so convergence stays full-constraint
        sound.
      compact_every: forget rounds between slab compactions (0 = never
        compact; masks alone already skip the *math*, compaction also
        skips the *time*).
      compact_pad: round compacted slab dims up to this multiple —
        bounds the ladder of distinct shapes the jitted runner sees.
    """

    def __init__(
        self,
        problem,
        *,
        forget_every: int = 10,
        forget_tol: float = 0.0,
        revive_tol: float | None = None,
        compact_every: int = 0,
        compact_pad: int = 8,
        **kwargs,
    ):
        if kwargs.get("use_kernel"):
            raise NotImplementedError(
                "SparseSolver drives the fused jnp sweep; the megakernel "
                "takes its act mask as a traced operand too, but the "
                "kernel route is not wired into the sparse runner yet "
                "(ROADMAP). Drop use_kernel=True."
            )
        if kwargs.get("fused") is False:
            raise NotImplementedError(
                "SparseSolver requires fused execution: the legacy "
                "per-diagonal path has no staged act slab to mask."
            )
        kwargs["fused"] = True
        super().__init__(problem, **kwargs)
        self.forget_every = max(1, int(forget_every))
        self.forget_tol = float(forget_tol)
        self.revive_tol = None if revive_tol is None else float(revive_tol)
        self.compact_every = max(0, int(compact_every))
        self.compact_pad = max(1, int(compact_pad))
        # Current slab operands: start as the full staged buckets (with
        # the static mask under the "valid" key — the ceiling no active
        # mask may exceed); compaction swaps in smaller slabs + a plan
        # mapping them back to full layout coordinates.
        self._slabs = [
            {k: b[k] for k in _STAGE_KEYS} | {"valid": b["act"]}
            for b in self._buckets
        ]
        self._plan = None
        #: active-fraction denominator: real (non-padding, non-ghost)
        #: triplet cells across all buckets — fixed across compactions.
        self._total_cells = sum(
            int(np.asarray(b["act"]).sum()) for b in self._buckets
        )

    # ------------------------------------------------------------- state
    def init_state(self) -> SparseState:
        base: ParallelState = super().init_state()
        yd = [
            jnp.zeros(
                sl["dinv"].shape[:1] + (3,) + sl["dinv"].shape[1:],
                self.dtype,
            )
            for sl in self._slabs
        ]
        return SparseState(
            x=base.x, f=base.f, yd=yd, ypair=base.ypair, ybox=base.ybox,
            passes=base.passes, amask=[sl["valid"] for sl in self._slabs],
        )

    @property
    def active_slabs(self) -> list[dict]:
        """The slab operands the sparse pass currently runs over (full
        staging until the first compaction). Benchmarks hold a reference
        across a compaction to time old-vs-new pass configurations."""
        return self._slabs

    def active_fraction(self, st: SparseState) -> float:
        """Fraction of real triangle-constraint cells currently active."""
        live = sum(int(np.asarray(m).sum()) for m in st.amask)
        return live / max(1, self._total_cells)

    # ------------------------------------------------------ sparse pass
    def _sparse_pass(self, st: SparseState, slabs) -> SparseState:
        """One full pass over the ACTIVE constraints: the fused bucket
        sweeps with the state's masks as the act operand (+ the dual
        re-zero that pins masked cells at 0.0), then the pair/box steps
        — which stay dense: they are O(n^2) and always tight."""
        from repro.kernels.metric_project import ref as kref

        x = st.x
        new_yd = []
        for sl, yb, am in zip(slabs, st.yd, st.amask):
            stage = {k: sl[k] for k in _STAGE_KEYS} | {"act": am}
            x, nyb = kref.fused_bucket_pass_ref(
                x, yb, stage, unroll=self.sweep_unroll
            )
            # Masked dual outputs are don't-care in the fused pass; pin
            # them to 0.0 so forgotten cells are bitwise fixed points
            # and the forget/revive algebra below sees clean zeros.
            new_yd.append(jnp.where(am[:, None], nyb, 0.0))
        f, ypair, ybox = st.f, st.ypair, st.ybox
        mask = self._mask
        if self.p.has_f:
            x2, f2, ypair = self._pair_step(x, f, ypair)
            x = jnp.where(mask, x2, x)
            f = jnp.where(mask, f2, f)
            ypair = jnp.where(mask[None], ypair, 0)
        if self.p.box is not None:
            x2, ybox = self._box_step(x, ybox)
            x = jnp.where(mask, x2, x)
            ybox = jnp.where(mask[None], ybox, 0)
        return SparseState(x, f, new_yd, ypair, ybox, st.passes + 1,
                           st.amask)

    def _one_pass(self, st):  # pragma: no cover - guard
        raise NotImplementedError(
            "SparseSolver has no fixed-slab _one_pass: the pass takes "
            "the active slabs as operands (they change shape under "
            "compaction). Use run() / run_until()."
        )

    def _masked_pass_fn(self):
        """Cached jit of one sparse pass with the slabs as operands (so
        a post-compaction call retraces on the new shapes instead of
        replaying a stale closure)."""
        fn = self._engine_cache.get("sparse_pass")
        if fn is None:
            fn = self._engine_cache["sparse_pass"] = jax.jit(
                self._sparse_pass
            )
        return fn

    def run(self, state=None, passes: int = 1):
        """``passes`` masked passes, NO forget/revive — the projection
        inner loop alone (tests pin it bitwise against a masked full
        pass; the decay benchmark times it on warm slabs)."""
        self._ensure_constants()
        st = state if state is not None else self.init_state()
        fn = self._masked_pass_fn()
        for _ in range(passes):
            st = fn(st, self._slabs)
        return st

    # ------------------------------------------------- forget / revive
    @staticmethod
    def _bucket_slack(x, sl):
        """Per-cell max triangle slack, from the sweep's own gathers:
        rowb = x_ij (long (i,j)), colb = x_jk, carry cell = x_ik. The
        three constraint forms match ref.py::fused_step exactly — this
        is the 2-D violation kernel's reduction kept slab-shaped instead
        of max-reduced. Padding cells gather fill 0.0 and are masked by
        the caller (``valid``)."""
        rowb = x.at[sl["iN"], sl["J"]].get(mode="fill", fill_value=0.0)
        colb = x.at[sl["J"], sl["kN"]].get(mode="fill", fill_value=0.0)
        xa = x.at[sl["i"], sl["k"]].get(mode="fill", fill_value=0.0)
        xb = x.at[sl["i2"], sl["k2"]].get(mode="fill", fill_value=0.0)
        xc = jnp.where(sl["seg"], xb[:, None, :], xa[:, None, :])
        return jnp.maximum(
            jnp.maximum(rowb - xc - colb, xc - rowb - colb),
            colb - rowb - xc,
        )

    def _forget_revive_bucket(self, x, yb, am, sl, ftol, rtol):
        """One bucket's forget + revive decision. Active cells whose
        duals all sit within ``ftol`` of zero are forgotten; valid cells
        violated beyond ``rtol`` are (re)activated with y = 0. The new
        mask stays within ``valid`` by induction (am ⊆ valid, viol is
        valid-masked)."""
        small = jnp.max(jnp.abs(yb), axis=1) <= ftol
        viol = sl["valid"] & (self._bucket_slack(x, sl) > rtol)
        new_am = (am & ~small) | viol
        # Survivors keep their duals; forgotten cells zero, revived
        # cells were already pinned at zero by the sparse pass.
        ny = jnp.where((new_am & am)[:, None], yb, 0.0)
        return new_am, ny

    def _forget_revive(self, st: SparseState, slabs, ftol, rtol):
        new_am, new_yd = [], []
        for yb, am, sl in zip(st.yd, st.amask, slabs):
            na, ny = self._forget_revive_bucket(st.x, yb, am, sl, ftol,
                                                rtol)
            new_am.append(na)
            new_yd.append(ny)
        return dataclasses.replace(st, yd=new_yd, amask=new_am)

    # --------------------------------------------------- sparse runner
    def _sparse_until_fn(self, stop_rule: str, res_hist: int):
        """Jitted outer loop: ``lax.while_loop`` whose body is one
        forget round — ``forget_every`` guarded sparse passes, the
        forget/revive step, then the engine's global stopping probe,
        divergence guard and residual/active-fraction rings. The slabs
        are operands, so each compaction shape retraces once and is
        cached (the ``compact_pad`` ladder bounds the count)."""
        self._ensure_constants()
        cache = self._engine_cache.setdefault("sparse_until", {})
        key = (self.forget_every, stop_rule, res_hist)
        fn = cache.get(key)
        if fn is None:
            forget_every = self.forget_every
            total = float(max(1, self._total_cells))

            def runner(st, slabs, tol, max_passes, ftol, rtol):
                dt = self._dprob_wide.w.dtype

                def guarded(s):
                    return jax.lax.cond(
                        s.passes < max_passes,
                        lambda q: self._sparse_pass(q, slabs),
                        lambda q: q, s,
                    )

                def round_(s):
                    s2, _ = jax.lax.scan(
                        lambda c, _: (guarded(c), None),
                        s, None, length=forget_every,
                    )
                    return self._forget_revive(s2, slabs, ftol, rtol)

                def cond(carry):
                    s, viol, gap, obj, prev_obj, _, _, _, div = carry
                    conv = stop_converged(stop_rule, tol, viol, gap, obj,
                                          prev_obj)
                    return (~div) & (~conv) & (s.passes < max_passes)

                def body(carry):
                    (s, viol_p, gap_p, obj_prev, _, resbuf, afbuf, k,
                     div) = carry
                    s2 = round_(s)
                    viol, gap = self._stopping_pair(s2)
                    obj = self._wide_objective(s2)
                    res = jnp.max(jnp.abs(s2.x - s.x)).astype(dt)
                    finite = (
                        jnp.isfinite(res)
                        & jnp.isfinite(viol)
                        & jnp.isfinite(gap)
                    )
                    sel = lambda a, b: jnp.where(finite, a, b)
                    s2 = jax.tree.map(sel, s2, s)
                    viol = sel(viol.astype(dt), viol_p)
                    gap = sel(gap.astype(dt), gap_p)
                    obj = sel(obj.astype(dt), obj_prev)
                    resbuf = jax.lax.dynamic_update_index_in_dim(
                        resbuf, sel(res, jnp.asarray(jnp.inf, dt)),
                        k % res_hist, 0,
                    )
                    af = (
                        sum(jnp.sum(m) for m in s2.amask).astype(dt)
                        / total
                    )
                    afbuf = jax.lax.dynamic_update_index_in_dim(
                        afbuf, af, k % res_hist, 0
                    )
                    return (s2, viol, gap, obj, obj_prev, resbuf, afbuf,
                            k + 1, div | ~finite)

                inf = jnp.asarray(jnp.inf, dt)
                resbuf0 = jnp.full((res_hist,), -1.0, dt)
                afbuf0 = jnp.full((res_hist,), -1.0, dt)
                k0 = jnp.zeros((), jnp.int32)
                div0 = jnp.zeros((), bool)
                return jax.lax.while_loop(
                    cond, body,
                    (st, inf, inf, inf, inf, resbuf0, afbuf0, k0, div0),
                )

            fn = cache[key] = jax.jit(runner)
        return fn

    # ------------------------------------------------------ compaction
    def _full_slack_fn(self):
        """Cached jit of the revival probe over the FULL staged geometry
        (constant shapes — compiles once, regardless of how the active
        slabs have been compacted)."""
        fn = self._engine_cache.get("sparse_full_probe")
        if fn is None:
            buckets = self._buckets

            def probe(x):
                return [self._bucket_slack(x, b) for b in buckets]

            fn = self._engine_cache["sparse_full_probe"] = jax.jit(probe)
        return fn

    def _expand_to_full(self, st: SparseState):
        """Host views of (active masks, dual slabs) in full layout
        coordinates, undoing the current compaction plan."""
        ams = [np.asarray(m) for m in st.amask]
        yds = [np.asarray(y) for y in st.yd]
        if self._plan is None:
            return ams, yds
        ams = [pb.expand_mask(m) for pb, m in zip(self._plan.buckets, ams)]
        yds = [pb.expand_duals(y) for pb, y in zip(self._plan.buckets, yds)]
        return ams, yds

    def _recompact(self, st: SparseState, rtol: float) -> SparseState:
        """Round-boundary compaction: re-probe the FULL geometry (so
        cells absent from the current slabs get their revival chance —
        no constraint starves), keep active ∪ violated, rebuild compact
        slabs, and carry duals/masks across. Every kept cell enters the
        new slabs active; the next forget round re-drops any that come
        back slack."""
        ams, yds = self._expand_to_full(st)
        slacks = jax.device_get(self._full_slack_fn()(st.x))
        keep = [
            am | (np.asarray(b["act"]) & (sl > rtol))
            for am, b, sl in zip(ams, self._buckets, slacks)
        ]
        slabs_np, plan = build_compact_slabs(
            self.layout, keep, self.p.w, self.p.eps, self.dtype,
            pad_to=self.compact_pad,
        )
        self._slabs = [
            {k: jnp.asarray(v) for k, v in sl.items()} for sl in slabs_np
        ]
        self._plan = plan
        yd = [
            jnp.asarray(pb.compact_duals(y), self.dtype)
            for pb, y in zip(plan.buckets, yds)
        ]
        return dataclasses.replace(
            st, yd=yd, amask=[sl["valid"] for sl in self._slabs]
        )

    # ------------------------------------------------- dual conversion
    def duals_to_dense(self, st) -> np.ndarray:
        """Dense interchange duals; expands compacted slabs back to the
        full layout first (uses the solver's CURRENT plan — pass states
        from the same compaction epoch)."""
        yd = st.yd
        if self._plan is not None:
            yd = [
                pb.expand_duals(np.asarray(y))
                for pb, y in zip(self._plan.buckets, yd)
            ]
        return sched.duals_to_dense(self.layout, yd)

    # ------------------------------------------------------- run_until
    def run_until(
        self,
        state=None,
        *,
        tol: float = 1e-4,
        max_passes: int = 100,
        check_every: int | None = None,
        stop_rule: str = "absolute",
        residual_history: int = 16,
        faults=None,
    ):
        """Solve to tolerance under active-set sparsification.

        The convergence check rides the forget cadence (one check per
        ``forget_every`` passes; ``check_every`` is accepted for engine
        API compatibility and ignored). The stopping pair is the global
        full-constraint probe, so ``info["converged"]`` carries exactly
        the same certificate as the dense engine's. Extra info keys:
        ``active_fraction`` (final), ``active_trajectory`` (one entry
        per forget round, oldest first, capped at ``residual_history``
        per compaction window), ``rounds`` (forget rounds executed),
        ``compactions``, and ``round_stats`` — per compaction window
        ``(wall seconds, passes run, active fraction at exit)``.
        """
        self._ensure_constants()
        st = state if state is not None else self.init_state()
        if faults is not None:
            st = self._apply_entry_faults(faults, st)
        if stop_rule not in ("absolute", "rel_gap", "plateau"):
            raise ValueError(f"unknown stop_rule {stop_rule!r}")
        max_passes = int(max_passes)
        tol = float(tol)
        ftol = self.forget_tol
        rtol = self.revive_tol if self.revive_tol is not None else 0.5 * tol
        res_hist = max(1, int(residual_history))
        win = (
            self.forget_every * self.compact_every
            if self.compact_every else None
        )
        fn = self._sparse_until_fn(stop_rule, res_hist)

        def trim(buf, k):
            buf = np.asarray(jax.device_get(buf), np.float64)
            return buf[:k] if k <= res_hist else np.roll(buf, -(k % res_hist))

        done = int(jax.device_get(st.passes))
        residuals: list[np.ndarray] = []
        af_traj: list[np.ndarray] = []
        round_stats: list[tuple[float, int, float]] = []
        rounds = 0
        compactions = 0
        while True:
            cap = max_passes if win is None else min(max_passes, done + win)
            t0 = time.perf_counter()
            (st, viol, gap, obj, prev_obj, resbuf, afbuf, k, div) = fn(
                st, self._slabs, tol, cap, ftol, rtol
            )
            jax.block_until_ready(st.x)
            dt_win = time.perf_counter() - t0
            viol, gap, obj, prev_obj = (
                float(v) for v in jax.device_get((viol, gap, obj, prev_obj))
            )
            k = int(k)
            diverged = bool(jax.device_get(div))
            new_done = int(jax.device_get(st.passes))
            rounds += k
            if k:
                residuals.append(trim(resbuf, k))
                af_traj.append(trim(afbuf, k))
            af_now = self.active_fraction(st)
            round_stats.append((dt_win, new_done - done, af_now))
            done = new_done
            if not np.isfinite(viol):
                viol, gap = (
                    float(v)
                    for v in jax.device_get(self._probe_fn()(st))
                )
                obj = float(
                    jax.device_get(self._objectives_fn()(st)[0])
                )
            converged = not diverged and bool(
                stop_converged(stop_rule, tol, viol, gap, obj, prev_obj)
            )
            if converged or diverged or done >= max_passes:
                break
            if win is not None:
                st = self._recompact(st, rtol)
                compactions += 1
        qp, lp = (
            float(v) for v in jax.device_get(self._objectives_fn()(st))
        )
        res = (
            np.concatenate(residuals)[-res_hist:]
            if residuals else np.zeros(0)
        )
        self.last_residuals = res
        info = {
            "passes": done,
            "converged": converged,
            "diverged": diverged,
            "max_violation": viol,
            "duality_gap": gap,
            "qp_objective": qp,
            "lp_objective": lp,
            "stop_rule": stop_rule,
            "residuals": res,
            "active_fraction": self.active_fraction(st),
            "active_trajectory": (
                np.concatenate(af_traj) if af_traj else np.zeros(0)
            ),
            "rounds": rounds,
            "compactions": compactions,
            "round_stats": round_stats,
        }
        return st, info

    # ------------------------------------------------ runtime-mode stubs
    @classmethod
    def batched(cls, *args, **kwargs):
        raise NotImplementedError(
            "batched sparse serve is not wired up yet: the active masks "
            "are per-instance state the (B,)-stacked engine does not "
            "carry. Use serve.batching.BatchedSolver (dense) or solo "
            "SparseSolver; ROADMAP tracks the batched hook."
        )

    @classmethod
    def sharded(cls, *args, **kwargs):
        raise NotImplementedError(
            "sharded sparse solves are not wired up yet: compaction "
            "rebalances lanes across the procs axis and needs a "
            "resharding story (DESIGN.md §13). Use core.sharded."
            "ShardedSolver (dense) or solo SparseSolver."
        )

"""Project-and-Forget active-set sparsification (DESIGN.md §13).

Wraps the fused-pass solver in a project → forget → revive outer loop:
constraints whose Dykstra duals sit at zero are dropped from the active
set (and, with ``compact_every``, physically repacked out of the slabs),
violated forgotten constraints are revived, and convergence is certified
against the FULL constraint set via the engine's global stopping probe.
"""

from repro.sparse.compact import BucketPlan, CompactPlan, build_compact_slabs
from repro.sparse.solver import SparseSolver, SparseState

__all__ = [
    "BucketPlan",
    "CompactPlan",
    "SparseSolver",
    "SparseState",
    "build_compact_slabs",
]

"""Solver launcher: the paper's application as a first-class framework job.

    PYTHONPATH=src python -m repro.launch.solve --graph ba --n 60 \
        --passes 100 --ckpt-dir /tmp/cc_ckpt

Builds a CC instance (generator or edge-list file), solves the metric-
constrained LP with the parallel conflict-free schedule (multi-device when
devices exist), checkpoints (X, F, duals, pass counter) every ``--ckpt-every``
passes and auto-resumes — the solver analogue of launch/train.py.

Solve-to-tolerance runs on the device-resident convergence engine
(DESIGN.md §7): each checkpoint window is ONE ``run_until`` device program —
a jitted ``lax.while_loop`` of ``--chunk``-pass chunks with the stopping
pair (max violation, |duality gap|) tested on device — so the host is
consulted once per window, not once per chunk. Checkpoint ``extra``
carries the device metrics of the saved state.

Fault drills (DESIGN.md §11): ``--inject "kind@site:at[:k=v,..];.."`` or
``--fault-seed N`` arm a deterministic ``FaultInjector`` threaded through
every layer this launcher touches — checkpoint save/restore (corruption
walks back to the newest intact step at resume), the run_until chunk
boundary (NaN poison trips the divergence guard), and, when ``--sharded``,
the mesh site: an injected ``device_loss`` at a window boundary reshards
the live duals onto the survivor mesh (``elastic.degrade_solver``) and
the solve continues — printing ``degraded p=P->Q, resumed at pass K``,
the line the CI chaos leg pins.
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.core import problems, rounding
from repro.core.parallel_dykstra import ParallelSolver
from repro.core.sharded_dykstra import ShardedSolver
from repro.graphs import generators, io as gio, jaccard
from repro.launch import elastic, mesh as mesh_lib
from repro.train import checkpoint as ckpt_lib


def build_injector(args):
    """Arm the deterministic fault plan from --inject / --fault-seed
    (None when neither is given — the fault-free fast path)."""
    if not args.inject and args.fault_seed is None:
        return None
    from repro.serve import faults as flt

    plan = flt.FaultPlan.parse(args.inject) if args.inject else flt.FaultPlan()
    if args.fault_seed is not None:
        plan = plan + flt.FaultPlan.seeded(args.fault_seed)
    return flt.FaultInjector(plan)


def build_instance(args):
    if args.edgelist:
        adj = gio.load_edgelist(args.edgelist)
    elif args.graph == "ba":
        adj = generators.collaboration_like(args.n, seed=args.seed)
    elif args.graph == "ws":
        adj = generators.small_world(args.n, seed=args.seed)
    else:
        adj, _ = generators.planted_partition(args.n, seed=args.seed)
    dissim, weights = jaccard.signed_instance(adj)
    return dissim, weights


def run_serve(args):
    """--serve: a stream of generated instances through the batched
    solve service (drain or continuous mode), reporting the scheduler's
    occupancy / queue high-water / refill telemetry (DESIGN.md §12)."""
    from repro.serve.scheduler import BatchScheduler

    sizes = [int(s) for s in args.serve.split(",")]
    ladder = tuple(int(s) for s in args.serve_ladder.split(","))
    sched = BatchScheduler(
        ladder=ladder, batch=args.serve_batch, tol=args.tol,
        max_passes=args.passes, check_every=args.chunk,
        stop_rule=args.stop_rule, use_kernel=args.use_kernel,
        mode=args.serve_mode, faults=build_injector(args),
    )
    t0 = time.time()
    for i, n in enumerate(sizes):
        adj, _ = generators.planted_partition(n, seed=args.seed + i)
        dissim, weights = jaccard.signed_instance(adj)
        sched.submit(
            problems.correlation_clustering_lp(dissim, weights, eps=args.eps),
            tag=i,
        )
    results = sched.drain()
    wall = time.time() - t0
    for i, n in enumerate(sizes):
        r = results[i]
        if r.get("route") == "failed":
            print(f"serve {i}: n={n} route=failed error={r.get('error')}")
            continue
        print(f"serve {i}: n={n} bucket={r['bucket_n']} route={r['route']} "
              f"passes={r['passes']} converged={r['converged']} "
              f"viol={r['max_violation']:.2e}")
    stats = sched.stats()
    hwm = ",".join(
        f"{k}:{v}" for k, v in sorted(
            stats["queue_depth_hwm"].items(), key=lambda kv: str(kv[0])
        )
    )
    print(f"serve stats: mode={stats['mode']} "
          f"instances={stats['instances_done']} "
          f"occupancy={stats['occupancy']:.2f} queue_hwm=[{hwm}] "
          f"refills={stats['refills']} chunks={stats['chunks_run']} "
          f"dead_letters={stats['faults']['dead_letters']} "
          f"throughput={stats['instances_done'] / max(wall, 1e-9):.3f} inst/s "
          f"(wall {wall:.1f}s)")
    sched.close()
    return results


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--graph", default="ba", choices=["ba", "ws", "sbm"])
    ap.add_argument("--edgelist", default=None)
    ap.add_argument("--n", type=int, default=60)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--eps", type=float, default=0.05)
    ap.add_argument("--passes", type=int, default=100)
    ap.add_argument("--chunk", type=int, default=10,
                    help="passes per on-device convergence check")
    ap.add_argument("--buckets", type=int, default=6)
    ap.add_argument("--use-kernel", action="store_true",
                    help="route the sweep through the gen-3 Pallas "
                         "megakernel — identical behavior on solo and "
                         "sharded invocations (DESIGN.md §10)")
    ap.add_argument("--block-c", type=int, default=None,
                    help="kernel lane-tile size (sets the megakernel's "
                         "default block_c; paper Fig. 7 tile-size knob)")
    ap.add_argument("--sharded", action="store_true", help="shard over all devices")
    ap.add_argument("--no-fused", action="store_true",
                    help="legacy one-dispatch-per-pass baseline (both "
                         "solvers; benchmarking only)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--tol", type=float, default=1e-4)
    ap.add_argument("--forget-every", type=int, default=0,
                    help="Project-and-Forget active-set mode (DESIGN.md "
                         "§13): forget/revive constraints every this many "
                         "passes (0 = dense solve). Solo runs only.")
    ap.add_argument("--forget-tol", type=float, default=0.0,
                    help="forget a constraint when max|y| <= this "
                         "(0.0 catches exactly Dykstra's inactive zeros)")
    ap.add_argument("--revive-tol", type=float, default=None,
                    help="re-admit a forgotten constraint violated beyond "
                         "this (default 0.5 * --tol)")
    ap.add_argument("--compact-every", type=int, default=0,
                    help="repack slabs to the active set every this many "
                         "forget rounds (0 = mask only, never repack)")
    ap.add_argument("--stop-rule", default="absolute",
                    choices=["absolute", "rel_gap", "plateau"],
                    help="run_until stopping rule (engine.STOP_RULES)")
    ap.add_argument("--round", action="store_true", help="pivot-round at the end")
    ap.add_argument("--serve", default=None, metavar="SIZES",
                    help="serve mode: route a comma-separated list of "
                         "instance sizes through the BatchScheduler "
                         "(bucketed batched solve) instead of one solo "
                         "solve, and print its occupancy / queue "
                         "high-water / refill stats (DESIGN.md §12)")
    ap.add_argument("--serve-mode", default="drain",
                    choices=["drain", "continuous"],
                    help="scheduler dispatch mode for --serve")
    ap.add_argument("--serve-batch", type=int, default=4,
                    help="batch slots per bucket for --serve")
    ap.add_argument("--serve-ladder", default="32,64,96,128",
                    help="bucket ladder for --serve")
    ap.add_argument("--inject", default=None,
                    help="deterministic fault plan, 'kind@site:at[:k=v,..]' "
                         "specs joined with ';' (serve/faults.py grammar) — "
                         "e.g. 'device_loss@mesh:1:p=4;ckpt_corrupt@ckpt_save:0'")
    ap.add_argument("--fault-seed", type=int, default=None,
                    help="additionally draw a seeded random FaultPlan "
                         "(replayable chaos)")
    args = ap.parse_args(argv)

    if args.block_c is not None:
        from repro.kernels.metric_project import ops as kops

        kops.set_default_block_c(args.block_c)

    if args.serve:
        return run_serve(args)

    dissim, weights = build_instance(args)
    n = dissim.shape[0]
    ncon = 3 * n * (n - 1) * (n - 2) // 6 + n * (n - 1)
    print(f"n={n}  constraints={ncon:,}  eps={args.eps}")

    prob = problems.correlation_clustering_lp(dissim, weights, eps=args.eps)
    sparse = args.forget_every > 0
    if sparse:
        for flag, name in ((args.sharded, "--sharded"),
                           (args.use_kernel, "--use-kernel"),
                           (args.no_fused, "--no-fused"),
                           (args.ckpt_dir, "--ckpt-dir")):
            if flag:
                ap.error(f"--forget-every is solo fused only: {name} is "
                         "not supported with the sparse active-set mode "
                         "(DESIGN.md §13)")
        from repro.sparse import SparseSolver

        solver = SparseSolver(
            prob, bucket_diagonals=args.buckets,
            forget_every=args.forget_every, forget_tol=args.forget_tol,
            revive_tol=args.revive_tol, compact_every=args.compact_every,
        )
    elif args.sharded:
        solver = ShardedSolver(prob, mesh_lib.make_solver_mesh(),
                               num_buckets=args.buckets,
                               use_kernel=args.use_kernel,
                               fused=not args.no_fused)
    else:
        solver = ParallelSolver(prob, bucket_diagonals=args.buckets,
                                use_kernel=args.use_kernel,
                                fused=not args.no_fused)
    injector = build_injector(args)
    state = solver.init_state()
    done = 0
    mgr = None
    if args.ckpt_dir:
        mgr = ckpt_lib.CheckpointManager(
            args.ckpt_dir, every=args.ckpt_every, faults=injector
        )
        state, done = mgr.resume_or(state)
        if done:
            print(f"resumed at pass {done}")

    t0 = time.time()
    converged = False
    extra = {}
    info = {}
    while done < args.passes and not converged:
        if injector is not None and args.sharded:
            # Window boundaries are the degradation points (DESIGN.md
            # §11): an injected device loss reshards the live duals onto
            # the survivor mesh and the same loop continues.
            for spec in injector.poll("mesh"):
                if spec.kind == "device_loss":
                    p_old = int(solver.nproc)
                    p_new = int(spec.payload.get("p", max(1, p_old // 2)))
                    solver, state = elastic.degrade_solver(
                        solver, state, p_new
                    )
                    print(f"degraded p={p_old}->{p_new}, "
                          f"resumed at pass {done}")
        # One checkpoint window = one run_until device program; without
        # checkpointing the whole solve is a single program.
        window = args.passes - done
        if mgr:
            window = min(window, args.ckpt_every)
        prev_done = done
        t_win = time.perf_counter()
        state, info = solver.run_until(
            state, tol=args.tol, max_passes=done + window,
            check_every=min(args.chunk, window), stop_rule=args.stop_rule,
            faults=injector,
        )
        win_s = time.perf_counter() - t_win
        done = info["passes"]
        converged = info["converged"]
        res = info["residuals"]
        res_tail = f" |dx|={res[-1]:.2e}" if len(res) else ""
        if sparse:
            res_tail += f" active_frac={info['active_fraction']:.3f}"
        # Per-window diagnosability at scale (DESIGN.md §14): peak device
        # memory, amortized pass time, and one warm timed stopping probe —
        # so probe-vs-pass split and the memory ceiling read straight off
        # the log. The probe fn is the engine's cached jit; the first
        # window pays its compile in the warm-up call, not the timing.
        probe = solver._probe_fn()
        jax.block_until_ready(probe(state))
        t_pr = time.perf_counter()
        jax.block_until_ready(probe(state))
        probe_ms = (time.perf_counter() - t_pr) * 1e3
        pass_ms = win_s * 1e3 / max(1, int(done) - int(prev_done))
        mem_b, mem_src = mesh_lib.device_memory_bytes()
        print(f"pass {done:4d}: lp={info['lp_objective']:.4f} "
              f"viol={info['max_violation']:.2e} gap={info['duality_gap']:.2e}"
              f"{res_tail} mem={mem_b / 1e6:.1f}MB({mem_src}) "
              f"pass={pass_ms:.1f}ms probe={probe_ms:.1f}ms "
              f"({time.time()-t0:.1f}s)")
        if mgr:
            extra = {
                k: (v.tolist() if isinstance(v, np.ndarray) else v)
                for k, v in info.items()
            }
            # Donated copy-on-save snapshot (DESIGN.md §14): the window's
            # state is rebound to the snapshot program's live alias; the
            # device→host transfer runs on the writer thread.
            _, state = mgr.maybe_save(
                done, state, extra={"n": n, "eps": args.eps, **extra},
                donate=True,
            )
        if info.get("diverged"):
            # the guard already restored the last finite iterate; keep it
            # (and its checkpoint) instead of burning the remaining passes.
            print(f"diverged at pass {done}: stopping with the last "
                  "finite iterate")
            break
    if sparse and info:
        # One-line sparsification report (the CI sparsify leg pins it);
        # lp at full precision so the certificate can be compared against
        # the dense full-constraint solve.
        print(f"sparsify: rounds={info['rounds']} "
              f"compactions={info['compactions']} "
              f"active_frac={info['active_fraction']:.3f} "
              f"lp={info['lp_objective']:.6f}")
    if converged:
        print("converged")
        if mgr and done % args.ckpt_every != 0:
            # the cadence would skip the terminal state — force-save it
            # (satellite of DESIGN.md §11's recoverability contract).
            _, state = mgr.maybe_save(
                done, state, extra={"n": n, "eps": args.eps, **extra},
                force=True, donate=True,
            )
    if mgr:
        ckpt_lib.wait_pending()

    if args.round:
        x = np.asarray(state.x, np.float64)
        cert = rounding.certificate(x, dissim, weights, trials=8)
        print(f"clusters={cert['num_clusters']} cost={cert['cc_cost']:.3f} "
              f"lp_lb={cert['lp_lower_bound']:.3f} "
              f"ratio={cert['approx_ratio_certificate']:.3f}")
    return state


if __name__ == "__main__":
    main()

"""Elastic scaling + fault-tolerance policy.

The framework's failure model for 1000+ node fleets:

  * **Node failure (training)**: jobs are stateless between steps — state
    lives in (checkpoint, data-step counter). On failure the controller
    relaunches with the survivors; ``remesh_plan`` recomputes the mesh and
    the run resumes from the latest atomic checkpoint. Data order is a pure
    function of (seed, step) (train/data.py), so the token stream is
    identical post-restart.

  * **Node failure (solver)**: the Dykstra schedule assigns the r-th set of
    each diagonal to device ``r mod p`` (paper Fig. 3). Because the schedule
    is deterministic in (n, p), restoring (X, F, dual slabs, pass counter)
    under a NEW p re-shards duals exactly: ``reshard_duals`` applies the
    composed slab→slab permutation as one device-side gather, slabs left
    sharded. Convergence is unaffected — Dykstra tolerates any
    constraint-visit order across passes. This is LIVE code, not policy
    prose: ``degrade_solver`` rebuilds a running ``ShardedSolver`` (live
    state included) onto the survivor mesh mid-solve, and
    ``launch/solve.py`` invokes it at the window boundary where an
    (injected or real) device loss surfaces — the chaos tests in
    tests/test_faults.py pin that the degraded solve's final certificate
    matches the fixed-mesh run. Corrupt-checkpoint walk-back lives in
    ``train/checkpoint.py`` (CRC-verified restore + ``resume_or``); the
    deterministic fault source is ``serve/faults.py`` (DESIGN.md §11).

  * **Stragglers**: the ``r mod p`` interleave is the paper's static balance;
    diagonal bucketing bounds per-scan-step skew. For persistent stragglers
    the controller shrinks p at a pass boundary (this module's remesh) rather
    than blocking on the slow node — cheap because pass boundaries are
    frequent and checkpoints are async.

  * **Pods**: the 'pod' mesh axis only carries data-parallel gradient
    reduction; losing a pod halves global batch but changes no parameter
    sharding, so multi-pod elasticity is a remesh along the cheapest axis.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from repro.core import schedule as sched

__all__ = [
    "degrade_solver",
    "remesh_plan",
    "reshard_duals",
    "reshard_duals_dense",
    "reshard_duals_host",
    "shrink_mesh",
    "RemeshPlan",
]


@dataclasses.dataclass(frozen=True)
class RemeshPlan:
    old_devices: int
    new_devices: int
    pod: int
    data: int
    model: int

    @property
    def batch_scale(self) -> float:
        """Keep per-device batch constant → global batch scales with data."""
        return (self.pod * self.data) / self.old_devices


def remesh_plan(old_devices: int, new_devices: int, model_parallel: int = 16,
                chips_per_pod: int = 256) -> RemeshPlan:
    """Choose (pod, data, model) for the surviving device count.

    Keeps model-parallel fixed (parameter shardings unchanged → checkpoint
    loads without resharding weights) and absorbs loss into the data axis.
    """
    if new_devices % model_parallel != 0:
        # shrink to the largest multiple — surplus devices idle (hot spares)
        new_devices = (new_devices // model_parallel) * model_parallel
    if new_devices <= 0:
        raise ValueError("not enough devices for one model replica")
    pods = max(1, new_devices // chips_per_pod)
    data = new_devices // (pods * model_parallel)
    return RemeshPlan(old_devices, new_devices, pods, data, model_parallel)


@functools.lru_cache(maxsize=16)
def _reshard_device_fn(n: int, num_buckets: int, p_old: int, p_new: int,
                       dtype_name: str, mesh: Mesh | None):
    """Cached jitted slab→slab permutation program (see reshard_duals).

    The composed permutation is folded into ONE gather table: ``move``
    maps every flat position of the new slab vector to its source
    position in the old one (real cells), and ``valid`` masks the padding
    cells (which stay zero — old padding holds don't-care values under
    fused execution and must never be copied). With ``mesh`` the
    permutation output is then placed sharded on the mesh axis
    (``device_put``; a second step, because one jitted program cannot
    change its device set) — the slabs never round-trip through the host.
    """
    src, dst, size_old, size_new = sched.compose_slab_permutation(
        n, num_buckets, p_old, p_new
    )
    new = sched.build_layout(n, num_buckets=num_buckets, procs=p_new)
    idt = np.int64 if size_old >= np.iinfo(np.int32).max else np.int32
    move = np.zeros(size_new, idt)
    move[dst] = src.astype(idt)
    valid = np.zeros(size_new, bool)
    valid[dst] = True
    move_d, valid_d = jnp.asarray(move), jnp.asarray(valid)
    dtype = jnp.dtype(dtype_name)
    shapes = [bl.slab_shape for bl in new.buckets]

    @jax.jit
    def permute(slabs):
        flat_old = (
            jnp.concatenate([jnp.reshape(s, (-1,)) for s in slabs])
            if slabs else jnp.zeros((0,), dtype)
        )
        moved = jnp.where(
            valid_d, flat_old[move_d].astype(dtype), jnp.zeros((), dtype)
        )
        out, off = [], 0
        for sh in shapes:
            size = int(np.prod(sh))
            out.append(moved[off : off + size].reshape(sh))
            off += size
        return out

    if mesh is None:
        return permute, new, size_old
    # The permutation jit runs wherever the inputs live (the OLD mesh);
    # the result is then placed sharded on the NEW mesh's first axis.
    # Two steps because one jitted program cannot change its device set —
    # an elastic restart by definition does.
    shard = NamedSharding(mesh, PartitionSpec(mesh.axis_names[0]))

    def permute_and_place(slabs):
        return [jax.device_put(s, shard) for s in permute(slabs)]

    return permute_and_place, new, size_old


def reshard_duals(yd_slabs, n: int, p_old: int, p_new: int,
                  num_buckets: int, dtype=np.float32,
                  mesh: Mesh | None = None):
    """Re-shard solver dual slabs from p_old to p_new devices, on device.

    Applies one **direct slab→slab index permutation**
    (``schedule.compose_slab_permutation``, cached per device-count pair):
    the two layouts' dense conversion maps are composed symbolically, so
    the move is a SINGLE device gather over the real duals — the dense
    (n, n, n) tensor is never materialized and nothing round-trips
    through the host (the historical host-float64 path survives as the
    ``reshard_duals_host`` test oracle). Exact because every triplet's
    slot is determined by the deterministic schedule on both sides, and
    because a gather only moves values (no arithmetic, any dtype).

    Args:
      yd_slabs: per-bucket dual slabs (jax arrays — e.g. a live
        ``ShardedState.yd`` — or numpy); padding cells may hold don't-care
        values (fused execution), they are masked out, never copied.
      mesh: optionally the target mesh; output slabs are then placed
        **sharded on its first axis** (``p_new`` must be divisible by
        the mesh size), so at the 512-chip dry-run scale the permutation
        runs on device and the slabs end up sharded, never hostside.

    Returns (new_slabs, new_layout): slabs shaped ``(p_new, D, 3, T, Cl)``
    per bucket, matching ShardedSolver's schedule-native storage.
    """
    fn, new, size_old = _reshard_device_fn(
        int(n), int(num_buckets), int(p_old), int(p_new),
        np.dtype(dtype).name, mesh,
    )
    held = sum(int(np.prod(np.shape(s))) for s in yd_slabs)
    if held != size_old:
        raise ValueError(
            f"slabs hold {held} elements, layout expects {size_old}"
        )
    return fn(list(yd_slabs)), new


def shrink_mesh(mesh: Mesh, p_new: int) -> Mesh:
    """Survivor mesh after device loss: the first ``p_new`` devices of
    the old 1-D solver mesh, same axis name. Deterministic, so a
    degraded run is replayable."""
    devices = np.asarray(mesh.devices).reshape(-1)
    if not 0 < p_new <= devices.size:
        raise ValueError(
            f"cannot shrink a {devices.size}-device mesh to p={p_new}"
        )
    return Mesh(devices[:p_new], mesh.axis_names[:1])


def degrade_solver(solver, state, p_new: int, mesh: Mesh | None = None):
    """Degrade-and-resume after device loss (DESIGN.md §6/§11): rebuild a
    live ``ShardedSolver`` — mid-solve state included — onto a survivor
    mesh of ``p_new`` devices.

    The dual slabs move through ``reshard_duals`` (one device-side
    gather, exact for any dtype); the replicated leaves (x, f, ypair,
    ybox, pass counter) are re-placed on the new mesh with
    ``device_put``. The new solver inherits every configuration knob
    (dtype, bucketing, kernel/delta/fused/unroll/probe), so the degraded
    run continues under the same compiled semantics — the solve then
    proceeds with ``run_until`` as if nothing happened, and converges to
    the same certificate (Dykstra tolerates any constraint-visit order
    across passes; the schedule under the new p is deterministic).

    Returns ``(new_solver, new_state)``.
    """
    from repro.core.sharded_dykstra import ShardedSolver, ShardedState

    p_old = int(solver.nproc)
    new_mesh = mesh if mesh is not None else shrink_mesh(solver.mesh, p_new)
    new_solver = ShardedSolver(
        solver.p,
        new_mesh,
        dtype=solver.dtype,
        num_buckets=solver.num_buckets,
        use_kernel=solver.use_kernel,
        delta_mode=solver.delta_mode,
        fused=solver.fused,
        sweep_unroll=solver.sweep_unroll,
        probe_every=solver.probe_every,
    )
    new_yd, _ = reshard_duals(
        state.yd, solver.n, p_old, int(p_new), solver.num_buckets,
        dtype=solver.dtype, mesh=new_mesh,
    )
    rep = NamedSharding(new_mesh, PartitionSpec())
    put = lambda a: None if a is None else jax.device_put(jnp.asarray(a), rep)
    new_state = ShardedState(
        x=put(state.x),
        f=put(state.f),
        yd=new_yd,
        ypair=put(state.ypair),
        ybox=put(state.ybox),
        passes=put(state.passes),
    )
    return new_solver, new_state


def reshard_duals_host(yd_slabs, n: int, p_old: int, p_new: int,
                       num_buckets: int, dtype=np.float32):
    """Host-numpy float64 twin of ``reshard_duals`` (the historical
    implementation): same composed permutation, applied on host. Kept as
    a test oracle and for offline checkpoint surgery without devices."""
    src, dst, size_old, size_new = sched.compose_slab_permutation(
        n, num_buckets, p_old, p_new
    )
    new = sched.build_layout(n, num_buckets=num_buckets, procs=p_new)
    flat_old = np.concatenate(
        [np.asarray(s, np.float64).reshape(-1) for s in yd_slabs]
    ) if yd_slabs else np.zeros(0, np.float64)
    if flat_old.shape[0] != size_old:
        raise ValueError(
            f"slabs hold {flat_old.shape[0]} elements, layout expects {size_old}"
        )
    flat_new = np.zeros(size_new, dtype=dtype)
    flat_new[dst] = flat_old[src].astype(dtype)
    out, off = [], 0
    for bl in new.buckets:
        out.append(flat_new[off : off + bl.slab_size].reshape(bl.slab_shape))
        off += bl.slab_size
    return out, new


def reshard_duals_dense(yd_slabs: list[np.ndarray], n: int, p_old: int,
                        p_new: int, num_buckets: int, dtype=np.float32):
    """Dense round-trip re-shard (the historical implementation): convert
    old slabs → (n, n, n) → new slabs. O(n^3) host memory — kept ONLY as
    the test oracle `reshard_duals` is validated against."""
    old = sched.build_layout(n, num_buckets=num_buckets, procs=p_old)
    new = sched.build_layout(n, num_buckets=num_buckets, procs=p_new)
    dense = sched.duals_to_dense(old, yd_slabs)
    return sched.dense_to_duals(new, dense, dtype=dtype), new

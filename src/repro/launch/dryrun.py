"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

MUST be the very first two lines — jax locks the device count on first init:
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse  # noqa: E402
import json  # noqa: E402
import sys  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro import configs  # noqa: E402
from repro.configs.shapes import SHAPES  # noqa: E402
from repro.launch import mesh as mesh_lib  # noqa: E402
from repro.launch import specs as specs_lib  # noqa: E402
from repro.models import common  # noqa: E402
from repro.models.model import build_model  # noqa: E402
from repro.roofline import accounting, analysis  # noqa: E402
from repro.train import optimizer as opt_lib  # noqa: E402
from repro.train.train_step import make_train_step, make_serve_step  # noqa: E402



def lower_cell(arch: str, shape_name: str, multi_pod: bool, remat: str = "dots",
               extra_tag: str = "", overrides: dict | None = None,
               mesh_shape: str | None = None, zero1: bool = False,
               microbatch: int = 1):
    """Lower + compile one cell; returns the roofline report dict."""
    cfg = configs.get_config(arch)
    if overrides:
        cfg = cfg.scaled(**overrides)
    shape = SHAPES[shape_name]
    ok, why = configs.shape_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name,
                "mesh": "multi" if multi_pod else "single",
                "skipped": True, "reason": why}

    if mesh_shape:  # perf variant: rebalance (data, model) at 256 chips
        import numpy as _np
        from jax.sharding import Mesh as _Mesh
        d_, m_ = (int(v) for v in mesh_shape.split("x"))
        mesh = _Mesh(_np.asarray(jax.devices()[: d_ * m_]).reshape(d_, m_),
                     ("data", "model"))
        mesh_name = mesh_shape
    else:
        mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
        mesh_name = "2x16x16" if multi_pod else "16x16"
    chips = mesh.devices.size
    lm = build_model(cfg)
    t0 = time.time()

    with mesh:
        if shape.kind == "train":
            state_structs, state_shard, b_structs, b_shard = specs_lib.train_specs(
                cfg, shape, mesh, zero1=zero1
            )
            step = make_train_step(lm, opt_lib.AdamWConfig(), remat=remat,
                                   microbatch=microbatch)
            lowered = jax.jit(
                step,
                in_shardings=(state_shard, b_shard),
                out_shardings=(state_shard, None),
            ).lower(state_structs, b_structs)
        elif shape.kind == "prefill":
            # inference prefill: forward logits over the full sequence
            state_structs, state_shard, b_structs, b_shard = specs_lib.train_specs(
                cfg, shape, mesh
            )

            def prefill(params, batch):
                logits, _ = lm.forward(params, batch)
                return logits

            lowered = jax.jit(
                prefill,
                in_shardings=(state_shard["params"], b_shard),
                out_shardings=None,
            ).lower(state_structs["params"], b_structs)
        else:  # decode
            (p_structs, p_shard, c_structs, c_shard,
             t_structs, t_shard) = specs_lib.serve_specs(cfg, shape, mesh)
            serve = make_serve_step(lm)
            lowered = jax.jit(
                serve,
                in_shardings=(p_shard, c_shard, t_shard["tokens"]),
                out_shardings=(None, c_shard),
            ).lower(p_structs, c_structs, t_structs["tokens"])

        compiled = lowered.compile()
        hlo = compiled.as_text()
        acct = accounting.cell_accounting(cfg, shape, chips, remat=remat)
        report = analysis.analyze(
            arch, shape_name, mesh_name, chips, compiled, hlo, acct
        )

    out = report.to_dict()
    out["skipped"] = False
    out["compile_seconds"] = time.time() - t0
    out["remat"] = remat
    if extra_tag:
        out["tag"] = extra_tag
    try:
        ma = compiled.memory_analysis()
        out["memory_analysis"] = str(ma)
    except Exception:
        out["memory_analysis"] = "unavailable on this backend"
    return out


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="all", help="arch id or 'all'")
    ap.add_argument("--shape", default="all", help="shape id or 'all'")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--remat", default="dots")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--tag", default="")
    ap.add_argument("--microbatch", type=int, default=1)
    ap.add_argument("--zero1", action="store_true",
                    help="shard optimizer moments over data (ZeRO-1)")
    ap.add_argument("--mesh-shape", default=None,
                    help="override single mesh as DxM, e.g. 32x8")
    ap.add_argument("--set", action="append", default=[],
                    help="config override key=value (int/bool), e.g. kv_repeat=2")
    args = ap.parse_args(argv)
    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        overrides[k] = {"true": True, "false": False}.get(v.lower(),
                                                          None) if v.lower() in ("true", "false") else int(v)

    archs = list(configs.ARCH_NAMES) if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    os.makedirs(args.out, exist_ok=True)
    failures = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                mesh_name = "multi" if mp else "single"
                tag = f"-{args.tag}" if args.tag else ""
                fname = os.path.join(
                    args.out, f"{arch}__{shape}__{mesh_name}{tag}.json"
                )
                if os.path.exists(fname):
                    print(f"[skip cached] {fname}")
                    continue
                print(f"[dryrun] {arch} × {shape} × {mesh_name} ...", flush=True)
                try:
                    rep = lower_cell(arch, shape, mp, remat=args.remat,
                                     extra_tag=args.tag, overrides=overrides,
                                     mesh_shape=args.mesh_shape,
                                     zero1=args.zero1,
                                     microbatch=args.microbatch)
                    with open(fname, "w") as fh:
                        json.dump(rep, fh, indent=1)
                    if rep.get("skipped"):
                        print(f"  skipped: {rep['reason']}")
                    else:
                        print(
                            f"  ok in {rep['compile_seconds']:.0f}s: "
                            f"bottleneck={rep['bottleneck']} "
                            f"t=({rep['t_compute']:.2e},{rep['t_memory']:.2e},"
                            f"{rep['t_collective']:.2e})s "
                            f"useful={rep['useful_flops_fraction']:.2f}"
                        )
                except Exception as e:  # noqa: BLE001
                    failures += 1
                    print(f"  FAILED: {e}")
                    traceback.print_exc()
                    with open(fname + ".fail", "w") as fh:
                        fh.write(traceback.format_exc())
    print(f"done; {failures} failures")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())

"""Training launcher: real steps on whatever devices exist.

    PYTHONPATH=src python -m repro.launch.train --arch olmo-1b --smoke \
        --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

Production flags mirror the dry-run: ``--arch`` picks the config, the mesh is
(data, model) over the available devices, checkpoints are written through
CheckpointManager (auto-resume on restart — kill it mid-run and relaunch to
exercise fault tolerance).
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.launch import mesh as mesh_lib
from repro.models import common
from repro.models.model import build_model
from repro.train import checkpoint as ckpt_lib
from repro.train import data as data_lib
from repro.train import optimizer as opt_lib
from repro.train.train_step import make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--smoke", action="store_true", help="use the reduced config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--remat", default="none")
    ap.add_argument("--grad-compression", default=None)
    ap.add_argument("--microbatch", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--data", default=None, help="binary token file (else synthetic)")
    ap.add_argument("--data-seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--width", type=int, default=None, help="override d_model (smoke)")
    args = ap.parse_args(argv)

    cfg = (configs.get_smoke_config(args.arch) if args.smoke
           else configs.get_config(args.arch))
    cfg = cfg.scaled(dtype=jnp.float32)
    if args.width:
        cfg = cfg.scaled(d_model=args.width)
    lm = build_model(cfg)

    n_dev = len(jax.devices())
    mesh = mesh_lib.make_host_mesh(data=n_dev, model=1)
    print(f"arch={cfg.name} params={common.count_params(lm.param_specs()):,} "
          f"devices={n_dev}")

    opt_cfg = opt_lib.AdamWConfig(peak_lr=args.lr, total_steps=args.steps,
                                  warmup_steps=max(args.steps // 20, 5))
    step_fn = make_train_step(lm, opt_cfg, remat=args.remat,
                              grad_compression=args.grad_compression,
                              microbatch=args.microbatch)

    with mesh:
        params = common.materialize(lm.param_specs(), jax.random.PRNGKey(0), cfg.dtype)
        state = {"params": params, "opt": opt_lib.init_opt_state(params)}
        start_step = 0
        mgr = None
        if args.ckpt_dir:
            mgr = ckpt_lib.CheckpointManager(args.ckpt_dir, every=args.ckpt_every)
            state, start_step = mgr.resume_or(state)
            if start_step:
                print(f"resumed from checkpoint at step {start_step}")

        ds = data_lib.make_dataset(data_lib.DataConfig(
            vocab_size=cfg.vocab_size, seq_len=args.seq,
            global_batch=args.batch, seed=args.data_seed, path=args.data,
        ))
        jstep = jax.jit(step_fn, donate_argnums=(0,))
        t0, losses = time.time(), []
        for step in range(start_step, args.steps):
            batch = ds.batch(step)
            state, metrics = jstep(state, batch)
            losses.append(float(metrics["loss"]))
            if (step + 1) % args.log_every == 0:
                dt = time.time() - t0
                tput = args.log_every * args.batch * args.seq / dt
                print(f"step {step+1:5d} loss={losses[-1]:.4f} "
                      f"gnorm={float(metrics['grad_norm']):.3f} "
                      f"lr={float(metrics['lr']):.2e} tok/s={tput:,.0f}")
                t0 = time.time()
            if mgr:
                mgr.maybe_save(step + 1, state, extra={"arch": cfg.name})
        if mgr:
            ckpt_lib.wait_pending()
        print(f"final loss {losses[-1]:.4f} (first {losses[0]:.4f})")
        return losses


if __name__ == "__main__":
    main()

"""ShapeDtypeStruct stand-ins + shardings for every (arch × shape) cell.

``input_specs`` returns zero-allocation descriptions of every input of
train_step / serve_step: model state (params + optimizer moments) or
(params + decode cache), plus the data batch. The dry-run lowers against
these; real launchers materialize the same trees.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.shapes import InputShape
from repro.models import common
from repro.models.common import ModelConfig, ParamSpec
from repro.models.model import build_model

__all__ = ["train_specs", "serve_specs", "batch_partition"]


def batch_partition(mesh: Mesh):
    """Batch dimension shards over (pod, data) — whichever exist."""
    axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    return axes if axes else None


def _batch_struct(cfg: ModelConfig, shape: InputShape, train: bool):
    B = shape.global_batch
    S = shape.seq_len
    out = {"tokens": jax.ShapeDtypeStruct((B, S + 1) if train else (B, 1), jnp.int32)}
    if cfg.family == "encdec":
        out["frames"] = jax.ShapeDtypeStruct(
            (B, cfg.encoder_seq, cfg.d_model), cfg.dtype
        )
    if cfg.family == "vlm" and train:
        out["patches"] = jax.ShapeDtypeStruct(
            (B, cfg.num_patches, cfg.d_model), cfg.dtype
        )
    return out


def _batch_shardings(batch_struct, mesh: Mesh):
    bp = batch_partition(mesh)
    size = 1
    if bp:
        for a in bp:
            size *= mesh.shape[a]

    def shard(s):
        # divisibility fallback: long_500k has global_batch=1 → replicate
        if bp and s.shape[0] % size == 0:
            return NamedSharding(mesh, P(bp, *([None] * (len(s.shape) - 1))))
        return NamedSharding(mesh, P(*([None] * len(s.shape))))

    return jax.tree.map(shard, batch_struct)


def _zero1_shardings(pspecs, mesh: Mesh):
    """ZeRO-1: additionally shard optimizer moments over the data axes along
    the first dimension that is unsharded-by-rules and divisible."""
    dp = batch_partition(mesh)
    if not dp:
        return common.tree_shardings(pspecs, mesh)
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]

    def shard_one(spec: ParamSpec):
        base = common.logical_to_spec(spec.axes, spec.shape, mesh)
        parts = list(base) + [None] * (len(spec.shape) - len(base))
        for i, (sz, cur) in enumerate(zip(spec.shape, parts)):
            if cur is None and sz % dp_size == 0 and sz > 0:
                parts[i] = dp
                break
        return NamedSharding(mesh, P(*parts))

    return jax.tree.map(shard_one, pspecs, is_leaf=common.is_param_spec)


def train_specs(cfg: ModelConfig, shape: InputShape, mesh: Mesh,
                zero1: bool = False):
    """Returns (state_structs, state_shardings, batch_structs, batch_shardings).

    state = {"params", "opt": {"m","v","step"}} — moments in f32, params in
    cfg.dtype, both sharded by the parameter rules (moments additionally
    data-sharded when zero1=True).
    """
    lm = build_model(cfg)
    pspecs = lm.param_specs()
    p_structs = common.tree_shape_structs(pspecs, cfg.dtype)
    p_shard = common.tree_shardings(pspecs, mesh)
    m_shard = _zero1_shardings(pspecs, mesh) if zero1 else p_shard
    m_structs = common.tree_shape_structs(pspecs, jnp.float32)
    state_structs = {
        "params": p_structs,
        "opt": {"m": m_structs, "v": m_structs,
                "step": jax.ShapeDtypeStruct((), jnp.int32)},
    }
    state_shardings = {
        "params": p_shard,
        "opt": {"m": m_shard, "v": m_shard,
                "step": NamedSharding(mesh, P())},
    }
    b_structs = _batch_struct(cfg, shape, train=True)
    return state_structs, state_shardings, b_structs, _batch_shardings(b_structs, mesh)


def serve_specs(cfg: ModelConfig, shape: InputShape, mesh: Mesh):
    """(param_structs, param_shardings, cache_structs, cache_shardings,
    token_structs, token_shardings) for one decode step against a seq_len
    cache."""
    lm = build_model(cfg)
    pspecs = lm.param_specs()
    p_structs = common.tree_shape_structs(pspecs, cfg.dtype)
    p_shard = common.tree_shardings(pspecs, mesh)

    cspecs = lm.cache_specs(shape.global_batch, max_seq=shape.seq_len)
    bp = batch_partition(mesh)
    rules = dict(common.DEFAULT_RULES, batch=bp)

    def cache_dtype(s: ParamSpec):
        return jnp.int32 if s.shape == () else cfg.dtype

    c_structs = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, cache_dtype(s)),
        cspecs, is_leaf=common.is_param_spec,
    )
    c_shard = common.tree_shardings(cspecs, mesh, rules)
    if cfg.family == "encdec":
        pass  # enc_out spec included in cache_specs
    t_structs = _batch_struct(cfg, shape, train=False)
    return (p_structs, p_shard, c_structs, c_shard,
            t_structs, _batch_shardings(t_structs, mesh))

"""Production mesh construction.

Never touches jax device state at import time — everything is a function.
The production topology is a v5e pod: 16×16 = 256 chips per pod, 2 pods for
the multi-pod dry-run. ``data`` carries batch (and the solver's processor
axis), ``model`` carries TP/EP, ``pod`` is the slow inter-pod axis that folds
into data-parallel gradient reduction.
"""

from __future__ import annotations

import numpy as np

import jax
from jax.sharding import Mesh

__all__ = ["make_production_mesh", "make_solver_mesh", "make_host_mesh"]


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) == n:
        return jax.make_mesh(shape, axes)
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape} but have {len(devices)}; "
            "run under XLA_FLAGS=--xla_force_host_platform_device_count=512"
        )
    return Mesh(np.asarray(devices[:n]).reshape(shape), axes)


def make_solver_mesh(p: int | None = None) -> Mesh:
    """1-D mesh for the distributed Dykstra solver ('solver' axis = the
    paper's processor count)."""
    devices = jax.devices()
    p = p or len(devices)
    return Mesh(np.asarray(devices[:p]), ("solver",))


def make_host_mesh(data: int = 1, model: int = 1) -> Mesh:
    """Small mesh for tests on however many host devices exist."""
    devices = jax.devices()
    need = data * model
    return Mesh(np.asarray(devices[:need]).reshape(data, model), ("data", "model"))

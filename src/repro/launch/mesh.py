"""Production mesh construction and the multi-process (multi-host) entry.

Never touches jax device state at import time — everything is a function.
The production topology is a v5e pod: 16×16 = 256 chips per pod, 2 pods for
the multi-pod dry-run. ``data`` carries batch (and the solver's processor
axis), ``model`` carries TP/EP, ``pod`` is the slow inter-pod axis that folds
into data-parallel gradient reduction.

Multi-process leg (DESIGN.md §14): ``initialize_distributed`` wraps
``jax.distributed.initialize`` so a fleet of processes (one per host, or
per-process CPU workers in tests) assemble one global device list, and
``make_global_solver_mesh`` lays the 1-D "solver" axis over it — the
sharded Dykstra solver is topology-agnostic beyond that axis, so the same
``ShardedSolver`` program runs single-host and multi-host. The module is
also an executable smoke (``python -m repro.launch.mesh``): initialize,
build the global mesh, run a small sharded metric-nearness solve, print
the mesh line and the (viol, gap) certificate. Tests exercise it via
``XLA_FLAGS=--xla_force_host_platform_device_count`` subprocesses.
"""

from __future__ import annotations

import os

import numpy as np

import jax
from jax.sharding import Mesh

__all__ = [
    "device_memory_bytes",
    "initialize_distributed",
    "make_global_solver_mesh",
    "make_production_mesh",
    "make_solver_mesh",
    "make_host_mesh",
]


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) == n:
        return jax.make_mesh(shape, axes)
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape} but have {len(devices)}; "
            "run under XLA_FLAGS=--xla_force_host_platform_device_count=512"
        )
    return Mesh(np.asarray(devices[:n]).reshape(shape), axes)


def make_solver_mesh(p: int | None = None) -> Mesh:
    """1-D mesh for the distributed Dykstra solver ('solver' axis = the
    paper's processor count)."""
    devices = jax.devices()
    p = p or len(devices)
    return Mesh(np.asarray(devices[:p]), ("solver",))


def make_host_mesh(data: int = 1, model: int = 1) -> Mesh:
    """Small mesh for tests on however many host devices exist."""
    devices = jax.devices()
    need = data * model
    return Mesh(np.asarray(devices[:need]).reshape(data, model), ("data", "model"))


def initialize_distributed(
    coordinator_address: str | None = None,
    num_processes: int | None = None,
    process_id: int | None = None,
    local_device_count: int | None = None,
) -> bool:
    """Bring up the multi-process jax runtime when asked; no-op otherwise.

    Returns True when ``jax.distributed.initialize`` ran (multi-process:
    a coordinator address or an explicit process count > 1 was given),
    False for the single-process case — callers never need to branch,
    ``jax.devices()`` is the global list either way.

    ``local_device_count`` forces that many host-platform devices in
    *this* process (the test/bench harness for mesh legs without real
    accelerators). It must take effect before the jax backend
    initializes — call this before any array/device touch, same rule as
    ``jax.distributed.initialize`` itself.
    """
    if local_device_count:
        flags = os.environ.get("XLA_FLAGS", "")
        if "--xla_force_host_platform_device_count" not in flags:
            os.environ["XLA_FLAGS"] = (
                f"{flags} --xla_force_host_platform_device_count="
                f"{int(local_device_count)}"
            ).strip()
    multi = (num_processes or 1) > 1 or coordinator_address is not None
    if not multi:
        return False
    jax.distributed.initialize(
        coordinator_address=coordinator_address,
        num_processes=num_processes,
        process_id=process_id,
    )
    return True


def make_global_solver_mesh(p: int | None = None) -> Mesh:
    """1-D "solver" mesh over the GLOBAL device list — the multi-host twin
    of ``make_solver_mesh``. After ``initialize_distributed`` on every
    process, ``jax.devices()`` spans all hosts; each process calls this
    with the same ``p`` (or None = all) and gets the same mesh, and the
    sharded solver's shard_map programs run SPMD across processes."""
    devices = jax.devices()
    p = p or len(devices)
    if p > len(devices):
        raise RuntimeError(
            f"need {p} devices for the solver mesh but the global list has "
            f"{len(devices)} (processes={jax.process_count()})"
        )
    return Mesh(np.asarray(devices[:p]), ("solver",))


def device_memory_bytes() -> tuple[int, str]:
    """Best-effort peak/live device memory: ``(bytes, source)``.

    Prefers the backend's per-device allocator stats
    (``peak_bytes_in_use`` summed over local devices — real accelerators
    report these); falls back to summing the sizes of every live
    ``jax.Array`` (the CPU backend reports no stats). Diagnostic only —
    the scale campaign and the solve launcher's telemetry line both print
    it — never used for control flow.
    """
    total, got = 0, False
    for d in jax.local_devices():
        try:
            stats = d.memory_stats()
        except Exception:
            stats = None
        if stats and "peak_bytes_in_use" in stats:
            total += int(stats["peak_bytes_in_use"])
            got = True
    if got:
        return total, "device_stats"
    live = 0
    for a in jax.live_arrays():
        try:
            live += int(a.nbytes)
        except Exception:
            pass
    return live, "live_arrays"


def main(argv=None) -> int:
    """Multi-process mesh smoke: initialize, build the global solver mesh,
    run a small sharded metric-nearness solve, print the certificate."""
    import argparse
    import time

    ap = argparse.ArgumentParser(description=main.__doc__)
    ap.add_argument("--coordinator", default=None,
                    help="coordinator address host:port (multi-process)")
    ap.add_argument("--num-processes", type=int, default=None)
    ap.add_argument("--process-id", type=int, default=None)
    ap.add_argument("--local-device-count", type=int, default=None,
                    help="force N host-platform devices in this process")
    ap.add_argument("--n", type=int, default=20)
    ap.add_argument("--p", type=int, default=None,
                    help="solver axis size (default: all global devices)")
    ap.add_argument("--buckets", type=int, default=3)
    ap.add_argument("--max-passes", type=int, default=60)
    ap.add_argument("--tol", type=float, default=1e-3)
    ap.add_argument("--use-kernel", action="store_true")
    args = ap.parse_args(argv)

    dist = initialize_distributed(
        coordinator_address=args.coordinator,
        num_processes=args.num_processes,
        process_id=args.process_id,
        local_device_count=args.local_device_count,
    )
    mesh = make_global_solver_mesh(args.p)
    print(
        f"mesh: distributed={dist} processes={jax.process_count()} "
        f"process={jax.process_index()} global_devices={len(jax.devices())} "
        f"local_devices={len(jax.local_devices())} "
        f"solver_axis={mesh.devices.size}"
    )

    from repro.core.problems import metric_nearness_l2
    from repro.core.sharded_dykstra import ShardedSolver

    rng = np.random.default_rng(0)
    d = rng.random((args.n, args.n))
    d = (d + d.T) / 2
    np.fill_diagonal(d, 0)
    solver = ShardedSolver(
        metric_nearness_l2(d), mesh, num_buckets=args.buckets,
        use_kernel=args.use_kernel,
    )
    t0 = time.perf_counter()
    _, info = solver.run_until(tol=args.tol, max_passes=args.max_passes,
                               check_every=5)
    dt = time.perf_counter() - t0
    mem, src = device_memory_bytes()
    print(
        f"mesh solve: n={args.n} p={mesh.devices.size} "
        f"passes={int(info['passes'])} converged={bool(info['converged'])} "
        f"viol={float(info['max_violation']):.3e} "
        f"gap={float(info['duality_gap']):.3e} "
        f"mem={mem / 1e6:.1f}MB({src}) ({dt:.1f}s)"
    )
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

"""AdamW + learning-rate schedules, pure JAX (no optax dependency).

Optimizer state mirrors the parameter pytree (m, v in f32 regardless of the
parameter dtype — standard mixed-precision practice). State sharding follows
parameter sharding (see launch/dryrun.py), so TP-sharded weights get
TP-sharded moments for free.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "init_opt_state", "adamw_update", "cosine_schedule"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    peak_lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10000
    grad_clip: float = 1.0


def cosine_schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = cfg.peak_lr * step / max(cfg.warmup_steps, 1)
    prog = jnp.clip(
        (step - cfg.warmup_steps) / max(cfg.total_steps - cfg.warmup_steps, 1),
        0.0, 1.0,
    )
    cos = 0.5 * cfg.peak_lr * (1.0 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def init_opt_state(params) -> dict[str, Any]:
    zeros32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros32, params),
        "v": jax.tree.map(zeros32, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(tree))
    )


def adamw_update(cfg: AdamWConfig, params, grads, state):
    """One AdamW step with global-norm clipping. Returns (params, state, stats)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    lr = cosine_schedule(cfg, step)
    bc1 = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh, vh = m / bc1, v / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    out = jax.tree.map(upd, params, grads, state["m"], state["v"])
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return (
        new_params,
        {"m": new_m, "v": new_v, "step": step},
        {"grad_norm": gnorm, "lr": lr},
    )

"""Fault-tolerant checkpointing (solver and LM training).

Design for 1000+ nodes:
  * atomic: write to ``step_XXXX.tmp`` then rename; a crash mid-save never
    corrupts the latest checkpoint;
  * manifest carries step, mesh shape and pytree structure, so restore can
    re-shard onto a *different* device count (elastic restart — the Dykstra
    schedule's determinism makes dual re-sharding exact, DESIGN.md §6);
  * async: ``save_async`` snapshots to host memory and writes on a thread,
    keeping the accelerator busy;
  * retention: keep the last ``keep`` checkpoints.

Storage is .npz per checkpoint (offline container; on a real cluster this
layer is the integration point for a distributed store).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time

import jax
import numpy as np

__all__ = ["save", "save_async", "restore", "latest_step", "CheckpointManager"]


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save(ckpt_dir: str, step: int, tree, extra: dict | None = None) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    leaves, treedef = _flatten(tree)
    tmp = os.path.join(ckpt_dir, f"step_{step:08d}.tmp")
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    arrays = {f"leaf_{t}": np.asarray(leaf) for t, leaf in enumerate(leaves)}
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    manifest = {
        "step": step,
        "num_leaves": len(leaves),
        "treedef": str(treedef),
        "time": time.time(),
        "extra": extra or {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as fh:
        json.dump(manifest, fh)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic commit
    return final


_PENDING: list[threading.Thread] = []


def save_async(ckpt_dir: str, step: int, tree, extra: dict | None = None):
    """Snapshot device arrays to host, then write on a background thread."""
    host_tree = jax.tree.map(lambda x: np.asarray(x), tree)
    th = threading.Thread(target=save, args=(ckpt_dir, step, host_tree, extra))
    th.start()
    _PENDING.append(th)
    return th


def wait_pending():
    for th in _PENDING:
        th.join()
    _PENDING.clear()


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [
        int(d.split("_")[1])
        for d in os.listdir(ckpt_dir)
        if d.startswith("step_") and not d.endswith(".tmp")
    ]
    return max(steps) if steps else None


def restore(ckpt_dir: str, tree_like, step: int | None = None):
    """Restore into the structure of ``tree_like`` (shapes re-validated).
    Returns (tree, manifest)."""
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as fh:
        manifest = json.load(fh)
    data = np.load(os.path.join(path, "arrays.npz"))
    leaves_like, treedef = _flatten(tree_like)
    assert manifest["num_leaves"] == len(leaves_like), "structure mismatch"
    leaves = []
    for t, like in enumerate(leaves_like):
        arr = data[f"leaf_{t}"]
        assert arr.shape == tuple(like.shape), (t, arr.shape, like.shape)
        leaves.append(arr.astype(like.dtype))
    return jax.tree.unflatten(treedef, leaves), manifest


class CheckpointManager:
    """Retention + auto-resume policy around save/restore."""

    def __init__(self, ckpt_dir: str, keep: int = 3, every: int = 100):
        self.dir = ckpt_dir
        self.keep = keep
        self.every = every

    def maybe_save(self, step: int, tree, extra=None, asynchronous=True):
        if step % self.every != 0:
            return None
        fn = save_async if asynchronous else save
        out = fn(self.dir, step, tree, extra)
        self._gc()
        return out

    def _gc(self):
        if not os.path.isdir(self.dir):
            return
        steps = sorted(
            int(d.split("_")[1])
            for d in os.listdir(self.dir)
            if d.startswith("step_") and not d.endswith(".tmp")
        )
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"), ignore_errors=True)

    def resume_or(self, init_tree):
        step = latest_step(self.dir)
        if step is None:
            return init_tree, 0
        tree, manifest = restore(self.dir, init_tree, step)
        return tree, manifest["step"]

"""Fault-tolerant checkpointing (solver and LM training).

Design for 1000+ nodes:
  * atomic: stage to a uniquely-named ``step_XXXX.tmp-<uid>`` dir, then
    commit by renaming the old final dir aside before renaming the new
    one in — there is no instant at which ``step_XXXX`` is missing, and
    a crash anywhere leaves either the old or the new checkpoint intact;
  * verified: the manifest carries a CRC-32 per leaf; ``restore``
    re-checksums every array and raises ``CorruptCheckpointError`` on
    any damage (truncation, bit-flips, torn writes), which
    ``CheckpointManager.resume_or`` handles by walking back to the
    newest *intact* retained step;
  * manifest carries step, pytree structure and leaf checksums, so
    restore can re-shard onto a *different* device count (elastic
    restart — the Dykstra schedule's determinism makes dual re-sharding
    exact, DESIGN.md §6, and `launch/elastic.degrade_solver` is the
    consumer);
  * async: ``save_async`` snapshots **on device** (``snapshot_device``,
    a jitted tree copy — optionally donated, DESIGN.md §14) and both the
    device→host transfer and the write happen on a thread, so the solve
    never blocks on moving the full dual state; background failures are
    surfaced by ``wait_pending`` instead of being dropped, and retention
    GC never collects a step whose save is still in flight;
  * retention: keep the last ``keep`` checkpoints.

Failure injection (DESIGN.md §11): ``save``/``restore`` accept a
duck-typed ``faults`` injector (``serve.faults.FaultInjector``) polled
at the ``ckpt_save`` / ``ckpt_restore`` sites — truncate or corrupt the
staged arrays *before* the atomic commit, kill the process mid-save, or
report a step corrupt on read. This layer never imports serve.

Storage is .npz per checkpoint (offline container; on a real cluster
this layer is the integration point for a distributed store).
"""

from __future__ import annotations

import functools
import json
import os
import re
import sys
import shutil
import threading
import time
import uuid
import zipfile
import zlib

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "CheckpointError",
    "CheckpointManager",
    "CorruptCheckpointError",
    "latest_step",
    "restore",
    "save",
    "save_async",
    "snapshot_device",
    "wait_pending",
]


class CheckpointError(RuntimeError):
    """Structural checkpoint failure (wrong tree/shape for this run) —
    a caller bug, never auto-skipped."""


class CorruptCheckpointError(CheckpointError):
    """Unreadable or checksum-failed checkpoint — ``resume_or`` walks
    back past these to the newest intact step."""


# Only exact final dirs count as checkpoints; staging (.tmp-<uid>) and
# commit-aside (.old-<uid>) dirs never match.
_STEP_RE = re.compile(r"^step_(\d{8})$")

# Serializes commit/GC so retention can never unlink a directory that a
# concurrent commit is renaming.
_IO_LOCK = threading.RLock()

# Errors that mean "this checkpoint's bytes are bad", as opposed to a
# structure mismatch: np.load on a truncated/garbled .npz surfaces any
# of these depending on where the damage landed.
_READ_ERRORS = (OSError, ValueError, KeyError, EOFError, zipfile.BadZipFile, zlib.error)


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def _checksum(arr: np.ndarray) -> int:
    return zlib.crc32(np.ascontiguousarray(arr).tobytes()) & 0xFFFFFFFF


def _apply_save_fault(spec, tmp: str) -> None:
    """Damage the *staged* checkpoint so the fault survives the atomic
    commit — exactly what a torn write or flaky disk produces."""
    npz = os.path.join(tmp, "arrays.npz")
    if spec.kind == "kill":
        sys.stdout.flush()
        os._exit(int(spec.payload.get("code", 17)))
    elif spec.kind == "ckpt_truncate":
        frac = float(spec.payload.get("fraction", 0.5))
        size = os.path.getsize(npz)
        with open(npz, "r+b") as fh:
            fh.truncate(max(1, int(size * frac)))
    elif spec.kind == "ckpt_corrupt":
        size = os.path.getsize(npz)
        with open(npz, "r+b") as fh:
            fh.seek(size // 2)
            fh.write(b"\xa5" * min(64, max(1, size - size // 2)))


def save(ckpt_dir: str, step: int, tree, extra: dict | None = None, faults=None) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    leaves, treedef = _flatten(tree)
    uid = uuid.uuid4().hex[:8]
    name = f"step_{step:08d}"
    tmp = os.path.join(ckpt_dir, f"{name}.tmp-{uid}")
    final = os.path.join(ckpt_dir, name)
    os.makedirs(tmp)
    arrays = {f"leaf_{t}": np.asarray(leaf) for t, leaf in enumerate(leaves)}
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    manifest = {
        "step": step,
        "num_leaves": len(leaves),
        "treedef": str(treedef),
        "checksums": {k: _checksum(a) for k, a in arrays.items()},
        "time": time.time(),
        "extra": extra or {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as fh:
        json.dump(manifest, fh)
    if faults is not None:
        for spec in faults.poll("ckpt_save"):
            _apply_save_fault(spec, tmp)
    with _IO_LOCK:
        if os.path.exists(final):
            # Rename aside, swing the new dir in, then drop the old copy:
            # `final` exists (old or new) at every instant.
            aside = os.path.join(ckpt_dir, f"{name}.old-{uid}")
            os.rename(final, aside)
            os.rename(tmp, final)
            shutil.rmtree(aside, ignore_errors=True)
        else:
            os.rename(tmp, final)  # atomic commit
    return final


class _SaveThread(threading.Thread):
    """Background save whose failure is captured, not dropped."""

    def __init__(self, target, step):
        super().__init__(target=target, daemon=False)
        self.step = step
        self.error: BaseException | None = None

    def run(self):
        try:
            super().run()
        except BaseException as e:  # surfaced by wait_pending
            self.error = e


_PENDING: list[_SaveThread] = []
_PENDING_LOCK = threading.Lock()


def _copy_tree(tree):
    return jax.tree.map(jnp.copy, tree)


@jax.jit
def _snapshot_copy(tree):
    return _copy_tree(tree)


@functools.partial(jax.jit, donate_argnums=0)
def _snapshot_donate(tree):
    # Two aliasable outputs of one donated input: XLA reuses the donated
    # buffers for one of them, allocates the other — net one tree copy,
    # same as the non-donating path, but the caller's old reference is
    # consumed, which is what lets future pass programs donate the live
    # state without tripping on the snapshot alias.
    return tree, _copy_tree(tree)


def snapshot_device(tree, donate: bool = False):
    """On-device copy-on-save stage of an async checkpoint (DESIGN.md
    §14). Returns ``(live, snap)``: ``snap`` is a device-side copy whose
    host transfer can proceed on the writer thread while the solve keeps
    mutating ``live``; the caller-blocking cost is one asynchronously
    dispatched device copy, never the device→host transfer.

    ``donate=True`` donates the caller's tree into the snapshot program
    (backends that support donation reuse its buffers for ``live``); the
    caller MUST replace its state reference with the returned ``live``.
    On CPU — where XLA does not implement donation — the flag is ignored
    to keep the path warning-free.
    """
    if donate and jax.default_backend() != "cpu":
        return _snapshot_donate(tree)
    return tree, _snapshot_copy(tree)


def save_async(ckpt_dir: str, step: int, tree, extra: dict | None = None,
               faults=None, donate: bool = False):
    """Snapshot on device, then transfer + write on a background thread.

    The device→host transfer of the full dual state used to run on the
    caller before the thread started — at scale that serialized the solve
    against the snapshot for the whole transfer. Now the caller only
    dispatches a device-side copy (``snapshot_device``) and the writer
    thread pulls from the snapshot buffer.

    ``donate=False`` (default) returns the save thread, as before.
    ``donate=True`` additionally donates the live tree into the snapshot
    stage and returns ``(thread, live_tree)`` — the caller must rebind
    its state to ``live_tree`` (see ``CheckpointManager.maybe_save``).
    """
    live, snap = snapshot_device(tree, donate=donate)

    def _write():
        host_tree = jax.tree.map(lambda x: np.asarray(x), snap)
        save(ckpt_dir, step, host_tree, extra, faults=faults)

    th = _SaveThread(target=_write, step=step)
    th.start()
    with _PENDING_LOCK:
        _PENDING.append(th)
    return (th, live) if donate else th


def wait_pending():
    """Join all in-flight async saves; raise ``CheckpointError`` if any
    failed (first failure chained as the cause)."""
    with _PENDING_LOCK:
        pending, _PENDING[:] = list(_PENDING), []
    errors = []
    for th in pending:
        th.join()
        if th.error is not None:
            errors.append((th.step, th.error))
    if errors:
        step, first = errors[0]
        raise CheckpointError(
            f"{len(errors)} background checkpoint save(s) failed "
            f"(first: step {step}: {first!r})"
        ) from first


def _pending_steps() -> set[int]:
    with _PENDING_LOCK:
        return {th.step for th in _PENDING if th.error is None}


def _list_steps(ckpt_dir: str) -> list[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for d in os.listdir(ckpt_dir):
        m = _STEP_RE.match(d)
        if m:
            out.append(int(m.group(1)))
    return sorted(out)


def latest_step(ckpt_dir: str) -> int | None:
    steps = _list_steps(ckpt_dir)
    return steps[-1] if steps else None


def clean_orphans(ckpt_dir: str) -> int:
    """Remove staging/aside dirs stranded by a crash or kill mid-save.
    Call at start of run, before any saves are in flight."""
    if not os.path.isdir(ckpt_dir):
        return 0
    n = 0
    with _IO_LOCK:
        for d in os.listdir(ckpt_dir):
            if re.match(r"^step_\d{8}\.(tmp|old)-", d):
                shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)
                n += 1
    return n


def restore(ckpt_dir: str, tree_like, step: int | None = None, faults=None):
    """Restore into the structure of ``tree_like``. Returns
    (tree, manifest). Raises ``CorruptCheckpointError`` for damaged
    bytes or failed checksums, ``CheckpointError`` for a structure
    mismatch (which walking back cannot fix), ``FileNotFoundError``
    when the directory holds no checkpoints at all."""
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    if faults is not None:
        for spec in faults.poll("ckpt_restore"):
            if spec.kind == "ckpt_corrupt":
                raise CorruptCheckpointError(
                    f"injected read fault: step {step} reported corrupt"
                )
    try:
        with open(os.path.join(path, "manifest.json")) as fh:
            manifest = json.load(fh)
        data = np.load(os.path.join(path, "arrays.npz"))
    except _READ_ERRORS as e:
        raise CorruptCheckpointError(f"step {step} unreadable: {e!r}") from e
    leaves_like, treedef = _flatten(tree_like)
    if manifest.get("num_leaves") != len(leaves_like):
        raise CheckpointError(
            f"structure mismatch at step {step}: checkpoint has "
            f"{manifest.get('num_leaves')} leaves, caller expects {len(leaves_like)}"
        )
    checksums = manifest.get("checksums", {})
    leaves = []
    for t, like in enumerate(leaves_like):
        key = f"leaf_{t}"
        try:
            arr = data[key]
        except _READ_ERRORS as e:
            raise CorruptCheckpointError(
                f"step {step} leaf {t} unreadable: {e!r}"
            ) from e
        if key in checksums and _checksum(arr) != checksums[key]:
            raise CorruptCheckpointError(
                f"step {step} leaf {t} failed CRC-32 verification"
            )
        if arr.shape != tuple(like.shape):
            raise CheckpointError(
                f"shape mismatch at step {step} leaf {t}: "
                f"{arr.shape} vs {tuple(like.shape)}"
            )
        leaves.append(arr.astype(like.dtype))
    return jax.tree.unflatten(treedef, leaves), manifest


class CheckpointManager:
    """Retention + auto-resume policy around save/restore.

    ``resume_or`` walks back over corrupt steps; retention GC is
    commit-lock-serialized and skips steps with in-flight async saves;
    stale staging dirs from a previous crashed run are swept at init.
    """

    def __init__(self, ckpt_dir: str, keep: int = 3, every: int = 100, faults=None):
        self.dir = ckpt_dir
        self.keep = keep
        self.every = every
        self.faults = faults
        clean_orphans(ckpt_dir)

    def maybe_save(self, step: int, tree, extra=None, asynchronous=True,
                   force=False, donate=False):
        """Save when ``step`` hits the cadence — or unconditionally with
        ``force=True`` (terminal state at convergence, which rarely lands
        on a multiple of ``every``).

        ``donate=True`` (async only) routes the donated copy-on-save
        snapshot and changes the return to ``(handle, live_tree)`` — the
        caller must rebind its state to ``live_tree``; on a skipped
        cadence that is ``(None, tree)`` unchanged. Idiom::

            _, state = mgr.maybe_save(step, state, donate=True)
        """
        if not force and step % self.every != 0:
            return (None, tree) if donate else None
        if donate and not asynchronous:
            raise ValueError("donate=True requires asynchronous=True: the "
                             "blocking save has no snapshot stage to donate "
                             "into")
        if asynchronous:
            out = save_async(self.dir, step, tree, extra, faults=self.faults,
                             donate=donate)
            if donate:
                out, tree = out
        else:
            out = save(self.dir, step, tree, extra, faults=self.faults)
        self._gc()
        return (out, tree) if donate else out

    def _gc(self):
        with _IO_LOCK:
            steps = _list_steps(self.dir)
            busy = _pending_steps()
            for s in steps[: -self.keep]:
                if s in busy:
                    continue
                shutil.rmtree(
                    os.path.join(self.dir, f"step_{s:08d}"), ignore_errors=True
                )

    def resume_or(self, init_tree):
        """Restore the newest *intact* retained step, walking back over
        corrupt ones; fall through to ``(init_tree, 0)`` when nothing
        usable survives. Structure mismatches still raise."""
        for s in reversed(_list_steps(self.dir)):
            try:
                tree, manifest = restore(self.dir, init_tree, s, faults=self.faults)
            except CorruptCheckpointError:
                continue
            return tree, manifest["step"]
        return init_tree, 0

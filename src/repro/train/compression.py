"""Gradient compression for the data-parallel all-reduce.

Halving (bf16) or quartering (int8 with per-tensor scale) the gradient bytes
directly scales the collective roofline term of train_step (EXPERIMENTS.md
§Perf). Compression is simulated end-to-end — compress → decompress around
the (implicit, XLA-inserted) all-reduce — so training quality with
compression on is measurable in examples/train_lm.py.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["compress_decompress"]


def _int8_roundtrip(g):
    scale = jnp.max(jnp.abs(g)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q.astype(jnp.float32) * scale


def compress_decompress(grads, method: str):
    if method == "bf16":
        return jax.tree.map(lambda g: g.astype(jnp.bfloat16).astype(jnp.float32), grads)
    if method == "int8":
        return jax.tree.map(_int8_roundtrip, grads)
    raise ValueError(f"unknown gradient compression {method!r}")

"""train_step / serve_step factories used by the launcher and the dry-run.

``make_train_step`` builds a jit-able ``(state, batch) → (state, metrics)``
with a configurable remat policy and optional gradient compression on the
data axis. ``make_serve_step`` builds ``(params, cache, tokens) → (logits,
cache)``. Both are pure functions of explicit state — checkpoint/restart
(launch/elastic.py) and the dry-run reuse them unchanged.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.model import LanguageModel
from repro.train import optimizer as opt
from repro.train import compression

__all__ = ["make_train_step", "make_serve_step", "REMAT_POLICIES"]

REMAT_POLICIES = {
    "none": None,
    "full": jax.checkpoint_policies.nothing_saveable,
    "dots": jax.checkpoint_policies.checkpoint_dots,
    "dots_no_batch": jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims,
}


def make_train_step(
    lm: LanguageModel,
    opt_cfg: opt.AdamWConfig,
    remat: str = "dots",
    grad_compression: str | None = None,
    microbatch: int = 1,
):
    """Returns train_step(state, batch) -> (state, metrics).

    state = {"params": ..., "opt": ...}. ``microbatch`` > 1 splits the local
    batch into sequential accumulation steps (pipeline-friendly memory).
    """
    policy = REMAT_POLICIES[remat]

    def loss_fn(params, batch):
        return lm.loss(params, batch, remat_policy=policy)

    def grads_of(params, batch):
        if microbatch == 1:
            return jax.value_and_grad(loss_fn)(params, batch)

        def split(x):
            return x.reshape((microbatch, x.shape[0] // microbatch) + x.shape[1:])

        mb = jax.tree.map(split, batch)

        def body(carry, b):
            loss_acc, g_acc = carry
            l, g = jax.value_and_grad(loss_fn)(params, b)
            return (loss_acc + l, jax.tree.map(jnp.add, g_acc, g)), None

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (loss, grads), _ = jax.lax.scan(body, (jnp.zeros(()), zeros), mb)
        inv = 1.0 / microbatch
        return loss * inv, jax.tree.map(lambda g: g * inv, grads)

    def train_step(state, batch):
        loss, grads = grads_of(state["params"], batch)
        if grad_compression:
            grads = compression.compress_decompress(grads, grad_compression)
        params, opt_state, stats = opt.adamw_update(
            opt_cfg, state["params"], grads, state["opt"]
        )
        metrics = {"loss": loss, **stats}
        return {"params": params, "opt": opt_state}, metrics

    return train_step


def make_serve_step(lm: LanguageModel):
    def serve_step(params, cache, tokens):
        return lm.decode_step(params, cache, tokens)

    return serve_step

"""Token data pipeline.

Deterministic, restart-safe: batches are a pure function of (seed, step), so
an elastic restart at step k reproduces exactly the batch stream a
non-interrupted run would have seen (the LM analogue of the solver's
deterministic constraint schedule). Sources: synthetic (zipfian n-gram-ish)
or a binary token file memory-mapped per host.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["DataConfig", "SyntheticDataset", "FileDataset", "make_dataset"]


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    path: str | None = None  # None → synthetic


class SyntheticDataset:
    """Zipf-distributed tokens with local n-gram correlations — enough
    structure for the loss to drop measurably in a few hundred steps."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg

    def batch(self, step: int) -> dict:
        cfg = self.cfg
        key = jax.random.fold_in(jax.random.PRNGKey(cfg.seed), step)
        k1, k2 = jax.random.split(key)
        # zipfian marginal
        ranks = jnp.arange(1, cfg.vocab_size + 1, dtype=jnp.float32)
        logits = -1.1 * jnp.log(ranks)
        base = jax.random.categorical(
            k1, logits, shape=(cfg.global_batch, cfg.seq_len + 1)
        )
        # local correlation: with p=0.5 repeat the previous token + 1
        rep = jax.random.bernoulli(k2, 0.5, base.shape)
        shifted = jnp.concatenate([base[:, :1], base[:, :-1] + 1], axis=1)
        tokens = jnp.where(rep, shifted % self.cfg.vocab_size, base)
        return {"tokens": tokens.astype(jnp.int32)}


class FileDataset:
    """uint16/uint32 binary token file, strided deterministically by step."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        dtype = np.uint16 if cfg.vocab_size < 65536 else np.uint32
        self.tokens = np.memmap(cfg.path, dtype=dtype, mode="r")

    def batch(self, step: int) -> dict:
        cfg = self.cfg
        span = cfg.seq_len + 1
        total = cfg.global_batch * span
        n = len(self.tokens) - span
        rng = np.random.default_rng(cfg.seed + step)
        starts = rng.integers(0, n, size=cfg.global_batch)
        out = np.stack([self.tokens[s : s + span] for s in starts]).astype(np.int32)
        return {"tokens": jnp.asarray(out % cfg.vocab_size)}


def make_dataset(cfg: DataConfig):
    return FileDataset(cfg) if cfg.path else SyntheticDataset(cfg)

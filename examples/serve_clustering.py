#!/usr/bin/env python
"""Serve a stream of graphs through the batched clustering pipeline
(DESIGN.md §8): mixed-size adjacencies are bucketed, ghost-padded,
solved as vmapped batches on one compiled runner, pivot-rounded on
device, and returned as labels + approximation certificates.

Run:  PYTHONPATH=src python examples/serve_clustering.py
"""

import numpy as np

from repro.graphs import generators
from repro.serve.pipeline import cluster_graphs


def main():
    # a burst of per-community subgraphs of different sizes
    sizes = [18, 24, 21, 30, 19, 26]
    adjs = generators.graph_batch(sizes, kind="sbm", seed=7)

    results, stats = cluster_graphs(
        adjs,
        ladder=(32, 64),     # serving shape buckets
        batch=3,             # instances per vmapped solve
        tol=1e-3,
        max_passes=150,
        stop_rule="rel_gap",  # scale-free stopping across instances
        trials=5,
    )

    for r in results:
        labels = r["labels"]
        print(
            f"graph {r['graph']}: n={r['n']} -> bucket {r['bucket_n']} | "
            f"passes={r['passes']} converged={r['converged']} | "
            f"{r['num_clusters']} clusters, cost={r['cc_cost']:.3f}, "
            f"certificate ratio={r['approx_ratio_certificate']:.3f}"
        )
        assert labels.shape == (r["n"],) and np.all(labels >= 0)

    print(
        f"served {stats['instances_done']} instances in "
        f"{stats['batches_run']} batches | occupancy "
        f"{stats['occupancy']:.2f} | compiled "
        f"{stats['compile_cache']['misses']} bucket runner(s), "
        f"{stats['compile_cache']['hits']} cache hit(s)"
    )


if __name__ == "__main__":
    main()

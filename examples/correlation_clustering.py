#!/usr/bin/env python
"""End-to-end driver: correlation clustering via the metric-constrained LP.

Pipeline (paper §IV): unsigned graph → Jaccard-signed dense CC instance
(Wang et al. construction) → eps-regularized LP solved with the parallel
conflict-free projection schedule → pivot rounding → clustering +
approximation-ratio certificate. This is the paper's headline application.

Run:  PYTHONPATH=src python examples/correlation_clustering.py [n]
"""

import sys
import time

import numpy as np

from repro.core import problems, rounding
from repro.core.parallel_dykstra import ParallelSolver
from repro.graphs import generators, jaccard


def main():
    n = int(sys.argv[1]) if len(sys.argv) > 1 else 50
    adj = generators.collaboration_like(n, m=3, seed=0)
    n = adj.shape[0]
    dissim, weights = jaccard.signed_instance(adj)
    ncon = 3 * n * (n - 1) * (n - 2) // 6 + 2 * n * (n - 1) // 2
    print(f"graph n={n}, CC instance with {ncon:,} constraints")

    prob = problems.correlation_clustering_lp(dissim, weights, eps=0.05)
    solver = ParallelSolver(prob, bucket_diagonals=6)
    state = solver.init_state()
    t0 = time.perf_counter()
    for chunk in range(8):
        state = solver.run(state, passes=25)
        m = solver.metrics(state)
        print(
            f"  pass {m['passes']:3d}: lp_obj={m['lp_objective']:.4f} "
            f"viol={m['max_violation']:.2e} gap={m['duality_gap']:.2e}"
        )
    dt = time.perf_counter() - t0
    print(f"solve time: {dt:.1f}s ({m['passes']} passes)")

    x = np.asarray(state.x, np.float64)
    cert = rounding.certificate(x, dissim, weights, trials=8)
    print(
        f"rounded: {cert['num_clusters']} clusters, cost={cert['cc_cost']:.3f}, "
        f"LP lower bound={cert['lp_lower_bound']:.3f}, "
        f"certificate ratio={cert['approx_ratio_certificate']:.3f}"
    )


if __name__ == "__main__":
    main()

#!/usr/bin/env python
"""Metric nearness (paper eq. (1)) in both norms, with the Pallas kernel path.

Compares p=2 (pure QP) and p=1 (LP via slack variables) on the same weighted
dissimilarity matrix, and demonstrates the kernel-backed solver.

Run:  PYTHONPATH=src python examples/metric_nearness.py
"""

import numpy as np

from repro.core import problems
from repro.core.parallel_dykstra import ParallelSolver


def main():
    n = 32
    rng = np.random.default_rng(1)
    d = np.triu(rng.exponential(0.5, (n, n)), k=1)
    w = np.triu(rng.uniform(0.5, 2.0, (n, n)), k=1)
    w = w + w.T + np.eye(n)

    print("== p=2 (weighted least squares) ==")
    p2 = problems.metric_nearness_l2(d, w)
    s2 = ParallelSolver(p2, bucket_diagonals=4)
    st2 = s2.run(passes=60)
    m2 = s2.metrics(st2)
    print(f"  violation={m2['max_violation']:.2e}  obj={m2['qp_objective']:.4f}")

    print("== p=1 (LP with slacks, eps-regularized) ==")
    p1 = problems.metric_nearness_l1(d, w, eps=0.05)
    s1 = ParallelSolver(p1, bucket_diagonals=4)
    st1 = s1.run(passes=400)
    m1 = s1.metrics(st1)
    print(f"  violation={m1['max_violation']:.2e}  lp obj={m1['lp_objective']:.4f}")

    print("== p=2 again, Pallas kernel path (interpret on CPU) ==")
    sk = ParallelSolver(p2, bucket_diagonals=4, use_kernel=True)
    stk = sk.run(passes=5)
    ref5 = ParallelSolver(p2, bucket_diagonals=4).run(passes=5)
    err = np.abs(np.asarray(stk.x) - np.asarray(ref5.x)).max()
    print(f"  kernel vs ref after 5 passes: max |Δ| = {err:.2e}")
    assert err < 1e-5


if __name__ == "__main__":
    main()

#!/usr/bin/env python
"""Quickstart: solve a small l2 metric-nearness problem with the parallel
conflict-free projection schedule and verify the result is a metric.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import convergence, problems
from repro.core.parallel_dykstra import ParallelSolver


def main():
    n = 40
    rng = np.random.default_rng(0)
    # random dissimilarities — generally NOT a metric
    d = np.triu(rng.uniform(0.0, 1.0, (n, n)), k=1)

    prob = problems.metric_nearness_l2(d)
    solver = ParallelSolver(prob, bucket_diagonals=4)
    state = solver.run(passes=150)

    m = solver.metrics(state)
    print(f"n={n}  triangle constraints={3 * n * (n-1) * (n-2) // 6:,}")
    print(f"passes={m['passes']}  max violation={m['max_violation']:.2e}")
    print(f"||X - D||_2^2 = {m['qp_objective'] + np.sum(d**2):.4f}")
    print(f"duality gap   = {m['duality_gap']:.2e}")
    assert m["max_violation"] < 1e-3, "X should satisfy the triangle inequality"
    print("OK: nearest metric found.")


if __name__ == "__main__":
    main()

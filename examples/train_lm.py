#!/usr/bin/env python
"""End-to-end LM training driver: train a ~100M-param OLMo-style model for a
few hundred steps on synthetic data with checkpointing.

The model is olmo-1b narrowed to ~100M params (--full100m). On this CPU
container the default invocation uses a smaller width so the example finishes
in minutes; pass --full100m on real hardware.

Run:  PYTHONPATH=src python examples/train_lm.py [--full100m] [--steps N]
"""

import argparse
import sys

from repro.launch import train as train_launcher


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full100m", action="store_true")
    ap.add_argument("--steps", type=int, default=None)
    args, _ = ap.parse_known_args()

    if args.full100m:
        # olmo family at ~100M: 8L × d=768 × ff=3072, full 50k vocab
        steps = args.steps or 300
        largs = ["--arch", "olmo-1b", "--smoke", "--width", "768",
                 "--steps", str(steps), "--batch", "8", "--seq", "512",
                 "--lr", "3e-4", "--ckpt-dir", "/tmp/train_lm_ckpt",
                 "--log-every", "10"]
    else:
        steps = args.steps or 120
        largs = ["--arch", "olmo-1b", "--smoke",
                 "--steps", str(steps), "--batch", "8", "--seq", "64",
                 "--lr", "1e-3", "--ckpt-dir", "/tmp/train_lm_ckpt",
                 "--log-every", "10"]

    losses = train_launcher.main(largs)
    drop = losses[0] - losses[-1]
    print(f"loss drop over {len(losses)} steps: {drop:.3f}")
    assert drop > 0.3, "training should make clear progress"
    print("OK")


if __name__ == "__main__":
    sys.exit(main())

#!/usr/bin/env python
"""Batched serving demo: greedy-decode a batch of prompts with the KV-cache
serve_step (the inference path the decode_* dry-run shapes lower).

Run:  PYTHONPATH=src python examples/serve_lm.py
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models import common
from repro.models.model import build_model
from repro.train.train_step import make_serve_step


def main():
    cfg = configs.get_smoke_config("olmo-1b").scaled(dtype=jnp.float32)
    lm = build_model(cfg)
    params = common.materialize(lm.param_specs(), jax.random.PRNGKey(0), jnp.float32)

    B, prompt_len, gen_len, max_seq = 4, 8, 24, 64
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size, size=(B, prompt_len))

    cache = common.materialize(lm.cache_specs(B, max_seq), jax.random.PRNGKey(0),
                               jnp.float32)
    cache = jax.tree.map(jnp.zeros_like, cache)
    step = jax.jit(make_serve_step(lm))

    # prefill token-by-token (prefill-optimized path is the prefill_32k shape)
    tok = jnp.asarray(prompts[:, :1], jnp.int32)
    for t in range(prompt_len):
        logits, cache = step(params, cache, jnp.asarray(prompts[:, t:t+1], jnp.int32))

    out = []
    t0 = time.perf_counter()
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    for _ in range(gen_len):
        out.append(np.asarray(tok)[:, 0])
        logits, cache = step(params, cache, tok)
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    dt = time.perf_counter() - t0
    gen = np.stack(out, axis=1)
    print(f"decoded {B}×{gen_len} tokens in {dt:.2f}s "
          f"({B * gen_len / dt:.1f} tok/s, batch={B})")
    print("sample continuations (token ids):")
    for b in range(B):
        print(f"  prompt {prompts[b].tolist()} → {gen[b].tolist()}")
    assert np.all(gen >= 0) and np.all(gen < cfg.padded_vocab)
    print("OK")


if __name__ == "__main__":
    main()
